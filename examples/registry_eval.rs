//! Registry evaluation (Table 1 protocol on one category, annotated).
//!
//! Walks the §5.2 pipeline end to end on the "apparel" category: Wishart
//! marginal init → EM; L = K(I−K)⁻¹ → Picard; nearest-Kronecker split →
//! KRK-Picard; then train/test log-likelihoods side by side.
//!
//! Run: `cargo run --release --example registry_eval`

use krondpp::data::registry;
use krondpp::dpp::likelihood::log_likelihood;
use krondpp::learn::{init, EmLearner, KrkPicard, Learner, Picard};
use krondpp::rng::Rng;

fn main() -> krondpp::Result<()> {
    let n = 64usize; // paper: 100; 64 keeps this demo under a minute
    let (n1, n2) = (8usize, 8usize);
    let mut rng = Rng::new(2016);

    println!("== simulating the 'apparel' registry category (N = {n}) ==");
    let cat = registry::generate_category("apparel", n, 300, 150, &mut rng)?;
    println!(
        "train: {} registries (mean size {:.1}), test: {}",
        cat.train.len(),
        cat.train.mean_size(),
        cat.test.len()
    );

    // §5.2 initialization chain.
    let k0 = init::wishart_marginal(n, &mut rng)?;
    let l0 = init::l_from_marginal(&k0)?;
    let (l1_0, l2_0) = init::subkernels_from_dense(&l0, n1, n2)?;

    println!("\nEM (δ = 1e-5) ...");
    let mut em = EmLearner::from_marginal(&k0)?;
    let em_r = em.run(&cat.train, 30, 1e-5)?;
    report("em", &em_r, &cat);

    println!("\nPicard (a = 1.3, δ = 1e-4) ...");
    let mut picard = Picard::new(l0, 1.3)?;
    let pic_r = picard.run(&cat.train, 30, 1e-4)?;
    report("picard", &pic_r, &cat);

    println!("\nKRK-Picard (a = 1.8, δ = 1e-4) ...");
    let mut krk = KrkPicard::new(l1_0, l2_0, 1.8)?;
    let krk_r = krk.run(&cat.train, 30, 1e-4)?;
    report("krk-picard", &krk_r, &cat);

    println!("\n(Table-1 shape: the full-kernel methods usually edge out the");
    println!(" Kronecker kernel at this tractable N — the trade-off KronDPP");
    println!(" makes to stay learnable at N where these baselines cannot run.)");
    Ok(())
}

fn report(
    name: &str,
    r: &krondpp::learn::LearnResult,
    cat: &registry::RegistryCategory,
) {
    let test_ll = log_likelihood(&r.kernel, &cat.test.subsets).unwrap();
    println!(
        "  {name:<11} {} iters ({}): train ll {:.3}, test ll {:.3}",
        r.history.len() - 1,
        if r.converged { "converged" } else { "iter cap" },
        r.final_ll(),
        test_ll
    );
}
