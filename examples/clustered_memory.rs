//! §3.3 memory–time trade-off demo: subset clustering.
//!
//! Builds the batch gradient matrix Θ both densely (O(N²) memory) and as
//! clustered sparse blocks (O(mz² + N)), verifies the KRK-Picard
//! contractions agree to machine precision, and reports the memory ratio
//! and the greedy-SUKP partition statistics.
//!
//! Run: `cargo run --release --example clustered_memory`

use krondpp::data;
use krondpp::dpp::likelihood::theta_dense;
use krondpp::learn::clustering::{greedy_partition, ClusteredTheta};
use krondpp::linalg::kron;
use krondpp::rng::Rng;

fn main() -> krondpp::Result<()> {
    let (n1, n2) = (40usize, 40usize);
    let n = n1 * n2;
    let mut rng = Rng::new(11);

    let truth = data::paper_truth_kernel(n1, n2, &mut rng);
    let train = data::sample_training_set(&truth, 120, 8, 60, &mut rng)?;
    let kappa = train.kappa();
    println!("N = {n}, {} subsets, κ = {kappa}", train.len());

    // Greedy SUKP partition with budget z = 3κ.
    let z = 3 * kappa;
    let clusters = greedy_partition(&train.subsets, z)?;
    let m = clusters.len();
    println!("greedy SUKP: m = {m} parts under union budget z = {z}");
    for (i, c) in clusters.iter().enumerate().take(5) {
        println!("  part {i}: {} subsets, union {}", c.members.len(), c.union.len());
    }
    if m > 5 {
        println!("  ... ({} more parts)", m - 5);
    }

    // Dense vs clustered Θ.
    let (l1, l2) = match &truth {
        krondpp::dpp::Kernel::Kron2(a, b) => (a.clone(), b.clone()),
        _ => unreachable!(),
    };
    let t0 = std::time::Instant::now();
    let dense = theta_dense(&truth, &train.subsets)?;
    let t_dense = t0.elapsed();
    let t0 = std::time::Instant::now();
    let clustered = ClusteredTheta::build(&truth, &train.subsets, &clusters, n1, n2)?;
    let t_clustered = t0.elapsed();

    let dense_bytes = n * n * 8;
    let sparse_bytes = clustered.nnz() * (8 + 4) + m * (n + 1) * 8;
    println!("\nmemory: dense Θ {:.1} MiB vs clustered {:.2} MiB  ({:.1}x saving)",
        dense_bytes as f64 / (1 << 20) as f64,
        sparse_bytes as f64 / (1 << 20) as f64,
        dense_bytes as f64 / sparse_bytes as f64
    );
    println!(
        "build time: dense {:.1} ms vs clustered {:.1} ms",
        t_dense.as_secs_f64() * 1e3,
        t_clustered.as_secs_f64() * 1e3
    );

    // Contractions agree.
    let a1_dense = kron::block_trace(&dense, &l2, n1, n2)?;
    let a1_sparse = clustered.block_trace(&l2)?;
    let d1 = a1_sparse.rel_diff(&a1_dense);
    let a2_dense = kron::weighted_block_sum(&dense, &l1, n1, n2)?;
    let a2_sparse = clustered.weighted_block_sum(&l1)?;
    let d2 = a2_sparse.rel_diff(&a2_dense);
    println!("\ncontraction parity: A1 rel-diff {d1:.2e}, A2 rel-diff {d2:.2e}");
    assert!(d1 < 1e-10 && d2 < 1e-10, "clustered path diverged");
    println!("clustered Θ path OK — identical updates at a fraction of the memory");
    Ok(())
}
