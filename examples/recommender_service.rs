//! Diverse-recommendation serving — the paper's motivating application
//! (recommender systems, ref. [31]) as a production workload.
//!
//! A KronDPP over a simulated product catalog (N = 2,500) backs a
//! sampling service: Poisson request arrivals ask for k diverse items,
//! the coordinator batches and routes them across workers, and a
//! background KRK-Picard job keeps refreshing the kernel from (synthetic)
//! interaction data, hot-swapping it into the live service. Reports
//! latency percentiles and throughput.
//!
//! Run: `cargo run --release --example recommender_service`

use krondpp::config::ServiceConfig;
use krondpp::coordinator::{DppService, LearningJob, SampleRequest};
use krondpp::data;
use krondpp::learn::{init, KrkPicard};
use krondpp::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

fn main() -> krondpp::Result<()> {
    let (n1, n2) = (50usize, 50usize);
    let mut rng = Rng::new(42);
    println!("== catalog: N = {} products as a {}x{} KronDPP ==", n1 * n2, n1, n2);

    let truth = data::paper_truth_kernel(n1, n2, &mut rng);
    let cfg = ServiceConfig::default();
    println!(
        "service: {} workers, max_batch {}, window {}µs, queue {}",
        cfg.workers, cfg.max_batch, cfg.batch_window_us, cfg.queue_capacity
    );
    let svc = Arc::new(DppService::start(&truth, &cfg, 7)?);

    // Background learning job: interaction data → kernel refreshes.
    let train = data::sample_training_set(&truth, 80, 10, 60, &mut rng)?;
    let learner = KrkPicard::new(
        init::paper_subkernel(n1, &mut rng),
        init::paper_subkernel(n2, &mut rng),
        1.0,
    )?;
    let job = LearningJob::spawn(Box::new(learner), train, 8, 0.0, Some(Arc::clone(&svc)));

    // Request trace: 4,000 requests at ~800 req/s, k ∈ [5, 25].
    let spec = data::workload::WorkloadSpec { rate_hz: 800.0, count: 4000, k_lo: 5, k_hi: 25 };
    let trace = data::workload::generate(&spec, &mut rng);
    println!("driving {} requests at ~{:.0} req/s ...", trace.len(), spec.rate_hz);

    let t0 = Instant::now();
    let mut tickets = Vec::with_capacity(trace.len());
    let mut rejected = 0usize;
    for req in &trace {
        while t0.elapsed() < req.at {
            std::hint::spin_loop();
        }
        match svc.submit(SampleRequest::new(req.k)) {
            Ok(t) => tickets.push((req.k, t)),
            Err(_) => rejected += 1,
        }
    }
    let mut sizes_ok = true;
    let mut done = 0usize;
    for (k, t) in tickets {
        match t.wait() {
            Ok(y) => {
                done += 1;
                sizes_ok &= y.len() == k;
            }
            Err(e) => eprintln!("request failed: {e}"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\ncompleted {done} requests in {wall:.2}s = {:.0} req/s (rejected {rejected})",
        done as f64 / wall
    );
    assert!(sizes_ok, "some responses had the wrong cardinality");
    println!("{}", svc.report());

    // Learning-job outcome.
    let history = job.join()?;
    println!(
        "\nlearning while serving: ll {:.4} -> {:.4} over {} iterations (kernel hot-swapped live)",
        history.first().map(|r| r.log_likelihood).unwrap_or(f64::NAN),
        history.last().map(|r| r.log_likelihood).unwrap_or(f64::NAN),
        history.len() - 1
    );
    Ok(())
}
