//! Quickstart: the 60-second tour of the krondpp public API.
//!
//! 1. Build a KronDPP kernel `L = L₁ ⊗ L₂` over N = 400 items.
//! 2. Draw exact samples (Alg. 2 via the factored eigendecomposition).
//! 3. Learn the kernel back from the samples with KRK-Picard (Alg. 1).
//! 4. Compare against the O(N³) full-Picard baseline.
//!
//! Run: `cargo run --release --example quickstart`

use krondpp::data;
use krondpp::dpp::{likelihood, Kernel, Sampler};
use krondpp::learn::{init, KrkPicard, Learner, Picard};
use krondpp::rng::Rng;

fn main() -> krondpp::Result<()> {
    let (n1, n2) = (20usize, 20usize);
    let mut rng = Rng::new(7);

    // 1. A ground-truth Kronecker kernel (paper §5.1 construction).
    let truth = data::paper_truth_kernel(n1, n2, &mut rng);
    println!(
        "ground truth: N = {} items, {} parameters (dense kernel would need {})",
        truth.n(),
        truth.param_count(),
        truth.n() * truth.n()
    );

    // 2. Exact sampling: eigendecomposition costs O(N1³+N2³) = O(N^{3/2}).
    let sampler = Sampler::new(&truth)?;
    let sample = sampler.sample(&mut rng);
    println!("a diverse subset: {sample:?}");
    let five = sampler.sample_k(5, &mut rng);
    println!("exactly five diverse items: {five:?}");
    // Batched draws fan across threads; deterministic in the seed.
    let many = sampler.sample_batch(1000, Some(5), 42);
    println!("batched: {} five-item subsets, first = {:?}", many.len(), many[0]);

    // Training data: 80 subsets with sizes in [8, 40].
    let train = data::sample_training_set(&truth, 80, 8, 40, &mut rng)?;
    println!("training data: {} subsets, κ = {}", train.len(), train.kappa());

    // 3. KRK-Picard: O(nκ³ + N²) per iteration, PD + monotone (Thm. 3.2).
    let mut krk = KrkPicard::new(
        init::paper_subkernel(n1, &mut rng),
        init::paper_subkernel(n2, &mut rng),
        1.0,
    )?;
    let start = likelihood::log_likelihood(&krk.kernel(), &train.subsets)?;
    let result = krk.run(&train, 10, 1e-5)?;
    println!(
        "krk-picard:  log-likelihood {start:.3} -> {:.3} in {} iterations ({:.0} ms/iter)",
        result.final_ll(),
        result.history.len() - 1,
        result.mean_iter_secs() * 1e3,
    );

    // 4. The full-Picard baseline pays O(N³) per iteration for the same job.
    let dense_init = {
        let l1 = init::paper_subkernel(n1, &mut rng);
        let l2 = init::paper_subkernel(n2, &mut rng);
        krondpp::linalg::kron::kron(&l1, &l2)
    };
    let mut picard = Picard::new(dense_init, 1.0)?;
    let result_pic = picard.run(&train, 10, 1e-5)?;
    println!(
        "picard:      log-likelihood -> {:.3} ({:.0} ms/iter, {:.1}x slower per iteration)",
        result_pic.final_ll(),
        result_pic.mean_iter_secs() * 1e3,
        result_pic.mean_iter_secs() / result.mean_iter_secs().max(1e-9),
    );

    // Sample from what we learned.
    let learned: Kernel = result.kernel;
    let s = Sampler::new(&learned)?.sample_k(6, &mut rng);
    println!("six items from the learned kernel: {s:?}");
    Ok(())
}
