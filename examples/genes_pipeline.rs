//! End-to-end validation driver (EXPERIMENTS.md §End-to-end).
//!
//! The full §5.3 pipeline on a real (simulated-GENES) workload, proving
//! all layers compose:
//!
//!   features → RBF ground-truth kernel → exact/approx DPP training data
//!   → KRK-Picard (batch + stochastic, optionally with the PJRT/HLO
//!   contraction backend) vs full Picard → loss curves + Table-2-style
//!   runtime rows → results/genes_pipeline.csv
//!
//! Run: `cargo run --release --example genes_pipeline [-- N1 N2 ITERS]`
//! Defaults: 32 32 6 (N = 1024; a couple of minutes). The paper scale is
//! `-- 100 100 8`.

use krondpp::data::genes;
use krondpp::dpp::likelihood::log_likelihood;
use krondpp::learn::{init, KrkPicard, KrkStochastic, Learner, Picard};
use krondpp::rng::Rng;
use krondpp::runtime::{Engine, HloContractions};

fn main() -> krondpp::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n1: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(32);
    let n2: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(32);
    let iters: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(6);
    let n = n1 * n2;

    println!("== GENES pipeline: N = {n} ({n1}x{n2}), {iters} iterations per learner ==");
    println!("[1/4] generating features + ground-truth RBF kernel + training data...");
    let problem = genes::genes_problem(n, (n / 4).clamp(16, 331), 100, (n / 50).max(4), (n / 8).max(8), 2016)?;
    let data = &problem.train;
    println!(
        "      {} samples, κ = {}, ground-truth NLL reference = {:.4}",
        data.len(),
        data.kappa(),
        log_likelihood(&problem.truth, &data.subsets)?
    );

    let mut rng = Rng::new(99);
    let l1 = init::paper_subkernel(n1, &mut rng);
    let l2 = init::paper_subkernel(n2, &mut rng);

    // [2/4] KRK-Picard (batch, Rust contraction backend for the timed run).
    // The AOT/PJRT path is exercised as a cross-layer *parity* check: on
    // CPU-PJRT the Pallas kernels run in interpret-lowered form (grid loops
    // execute sequentially), so it validates numerics, not wall-clock —
    // see DESIGN.md §Hardware-Adaptation.
    println!("[2/4] KRK-Picard (batch)...");
    if let Ok(engine) = Engine::load_default() {
        let hlo = HloContractions::new(engine);
        if hlo.supports(n1, n2) {
            use krondpp::learn::krk::Contractions;
            let theta = krondpp::dpp::likelihood::theta_dense(
                &krondpp::dpp::Kernel::Kron2(l1.clone(), l2.clone()),
                &data.subsets,
            )?;
            let a1_hlo = hlo.block_trace(&theta, &l2, n1, n2)?;
            let a1_cpu = krondpp::linalg::kron::block_trace(&theta, &l2, n1, n2)?;
            println!(
                "      three-layer parity (Pallas→HLO→PJRT vs Rust): A1 rel-diff {:.2e}",
                a1_hlo.rel_diff(&a1_cpu)
            );
            assert!(a1_hlo.rel_diff(&a1_cpu) < 1e-10, "HLO backend diverged");
        } else {
            println!("      (no HLO artifact variant for {n1}x{n2}; parity check skipped)");
        }
    } else {
        println!("      (PJRT unavailable; parity check skipped)");
    }
    let mut krk = KrkPicard::new(l1.clone(), l2.clone(), 1.0)?;
    let krk_result = krk.run(data, iters, 0.0)?;
    print_history("krk-picard", &krk_result);

    println!("[3/4] KRK-Picard (stochastic, minibatch 1)...");
    let mut stoch = KrkStochastic::new(l1.clone(), l2.clone(), 0.8, 1, 123);
    let stoch_result = stoch.run(data, iters, 0.0)?;
    print_history("krk-stochastic", &stoch_result);

    println!("[4/4] full Picard baseline (O(N³)/iter)...");
    let mut picard = Picard::new(krondpp::linalg::kron::kron(&l1, &l2), 1.0)?;
    let picard_result = picard.run(data, iters, 0.0)?;
    print_history("picard", &picard_result);

    // Summary table (Table-2 shape).
    println!("\n== summary (Table-2 shape) ==");
    println!(
        "{:<16} {:>14} {:>18} {:>12}",
        "algorithm", "s/iter", "1st-iter NLL gain", "final ll"
    );
    let mut rows = Vec::new();
    for (name, id, r) in [
        ("picard", 0.0, &picard_result),
        ("krk-picard", 1.0, &krk_result),
        ("krk-stochastic", 3.0, &stoch_result),
    ] {
        println!(
            "{name:<16} {:>14.4} {:>18.4} {:>12.4}",
            r.mean_iter_secs(),
            r.first_iter_gain(),
            r.final_ll()
        );
        for rec in &r.history {
            rows.push(vec![
                id,
                rec.iter as f64,
                rec.elapsed.as_secs_f64(),
                rec.log_likelihood,
            ]);
        }
    }
    let speedup = picard_result.mean_iter_secs() / krk_result.mean_iter_secs().max(1e-12);
    let speedup_s = picard_result.mean_iter_secs() / stoch_result.mean_iter_secs().max(1e-12);
    println!("\nspeed-up over picard: krk {speedup:.1}x, stochastic {speedup_s:.1}x");

    krondpp::figures::emit_csv(
        "genes_pipeline.csv",
        &["algo", "iter", "time_s", "log_likelihood"],
        &rows,
    )?;

    // Hard end-to-end assertions: every learner improved, KRK is not
    // slower than Picard per iteration.
    assert!(krk_result.final_ll() > krk_result.history[0].log_likelihood);
    assert!(stoch_result.final_ll() > stoch_result.history[0].log_likelihood);
    assert!(picard_result.final_ll() > picard_result.history[0].log_likelihood);
    assert!(speedup >= 1.0, "KRK slower than Picard per iteration?!");
    println!("\nend-to-end pipeline OK");
    Ok(())
}

fn print_history(name: &str, r: &krondpp::learn::LearnResult) {
    for rec in &r.history {
        println!(
            "      [{name}] iter {:>2}  t={:>8.2}s  ll={:.5}",
            rec.iter,
            rec.elapsed.as_secs_f64(),
            rec.log_likelihood
        );
    }
}
