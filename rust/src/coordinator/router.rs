//! Work routing: least-loaded assignment of batches to workers.
//!
//! Workers expose an in-flight count; the router picks the least-loaded
//! worker (ties → lowest index, keeping placement deterministic for
//! tests). Load is counted in **jobs**, not batches
//! ([`WorkerLoad::begin_n`]), so the tenant-grouped dispatch of the
//! multi-tenant server weighs a 12-request tenant-group as 12, keeping
//! placement fair when tenant-groups have uneven sizes. Pure logic,
//! property-tested; the server owns the actual worker threads.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Shared per-worker load gauge.
#[derive(Clone)]
pub struct WorkerLoad(Arc<Vec<AtomicUsize>>);

impl WorkerLoad {
    pub fn new(workers: usize) -> Self {
        WorkerLoad(Arc::new((0..workers).map(|_| AtomicUsize::new(0)).collect()))
    }

    pub fn workers(&self) -> usize {
        self.0.len()
    }

    /// Current load of worker `w`.
    pub fn load(&self, w: usize) -> usize {
        self.0[w].load(Ordering::SeqCst)
    }

    /// Record assignment / completion of one unit of work.
    pub fn begin(&self, w: usize) {
        self.begin_n(w, 1);
    }

    pub fn end(&self, w: usize) {
        self.end_n(w, 1);
    }

    /// Record assignment of `n` jobs at once (a dispatched tenant-group).
    pub fn begin_n(&self, w: usize, n: usize) {
        self.0[w].fetch_add(n, Ordering::SeqCst);
    }

    pub fn end_n(&self, w: usize, n: usize) {
        self.0[w].fetch_sub(n, Ordering::SeqCst);
    }

    /// Least-loaded worker (lowest index on ties).
    pub fn pick(&self) -> usize {
        let mut best = 0usize;
        let mut best_load = usize::MAX;
        for w in 0..self.0.len() {
            let l = self.load(w);
            if l < best_load {
                best_load = l;
                best = w;
            }
        }
        best
    }

    /// Total outstanding work.
    pub fn total(&self) -> usize {
        (0..self.0.len()).map(|w| self.load(w)).sum()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::testing::{check, UsizeGen};

    #[test]
    fn picks_least_loaded_deterministically() {
        let r = WorkerLoad::new(3);
        r.begin(0);
        r.begin(0);
        r.begin(1);
        assert_eq!(r.pick(), 2);
        r.begin(2);
        r.begin(2);
        assert_eq!(r.pick(), 1);
        r.end(0);
        r.end(0);
        assert_eq!(r.pick(), 0);
    }

    #[test]
    fn prop_balanced_under_uniform_arrivals() {
        // Assign k jobs with no completions: loads differ by ≤ 1.
        check("router balance", &UsizeGen { lo: 1, hi: 64 }, 40, |&k| {
            let r = WorkerLoad::new(4);
            for _ in 0..k {
                let w = r.pick();
                r.begin(w);
            }
            let loads: Vec<usize> = (0..4).map(|w| r.load(w)).collect();
            let max = *loads.iter().max().unwrap();
            let min = *loads.iter().min().unwrap();
            r.total() == k && max - min <= 1
        });
    }

    #[test]
    fn weighted_groups_steer_placement() {
        // A 5-job group on worker 0 makes three 1-job groups prefer 1.
        let r = WorkerLoad::new(2);
        r.begin_n(0, 5);
        for _ in 0..3 {
            let w = r.pick();
            assert_eq!(w, 1);
            r.begin(w);
        }
        assert_eq!(r.total(), 8);
        r.end_n(0, 5);
        assert_eq!(r.pick(), 0);
        assert_eq!(r.total(), 3);
    }

    #[test]
    fn prop_work_conserving() {
        // As long as any worker is idle, pick() returns an idle worker.
        check("work conserving", &UsizeGen { lo: 1, hi: 3 }, 30, |&busy| {
            let r = WorkerLoad::new(4);
            for w in 0..busy {
                r.begin(w);
            }
            r.load(r.pick()) == 0
        });
    }
}
