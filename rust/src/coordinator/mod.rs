//! The Layer-3 coordinator: a production serving + learning system around
//! the KronDPP core (DESIGN.md §3).
//!
//! - [`server`]: the sampling service (request queue → dynamic batcher →
//!   least-loaded workers → exact DPP samples), with kernel hot-swap.
//! - [`batcher`]: the two-trigger (size/age) batch policy, property-tested.
//! - [`router`]: least-loaded work routing.
//! - [`jobs`]: background learning jobs feeding refreshed kernels to the
//!   service.
//! - [`metrics`]: latency histograms + service counters.

pub mod batcher;
pub mod jobs;
pub mod metrics;
pub mod router;
pub mod server;

pub use jobs::LearningJob;
pub use server::{DppService, SampleRequest, Ticket};
