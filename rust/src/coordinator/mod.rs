//! The Layer-3 coordinator: a production multi-tenant serving + learning
//! system around the KronDPP core (DESIGN.md §3).
//!
//! - [`registry`]: the multi-tenant [`KernelRegistry`] — named tenants
//!   publishing generation-stamped [`SamplerEpoch`]s (kernel + cached
//!   eigendecomposition + sampler) atomically, with an LRU bound on
//!   resident eigendecompositions and lazy rebuild for cold tenants.
//! - [`server`]: the sampling service (admission control → request queue
//!   → dynamic batcher → tenant-grouped least-loaded dispatch → DPP
//!   samples from the tenant's current epoch), constraint-aware end to
//!   end: requests may carry a [`crate::dpp::Constraint`]
//!   (`A ⊆ Y, B ∩ Y = ∅`), validated at admission and served through a
//!   per-group conditioning setup; epochs cache the factored
//!   marginal-diagonal table for instant scoring
//!   ([`server::DppService::marginals`]). Every request selects a
//!   [`crate::dpp::SampleMode`] backend — exact, MCMC, low-rank
//!   projection, or the deterministic greedy MAP slate — gated per
//!   tenant by a [`ModePolicy`] and counted per mode in the metrics.
//! - [`batcher`]: the two-trigger (size/age) batch policy plus the
//!   `(tenant, k, constraint, mode)` coalescer, property-tested.
//! - [`router`]: job-weighted least-loaded work routing.
//! - [`jobs`]: background learning jobs publishing refreshed kernels to
//!   their target tenant.
//! - [`metrics`]: latency histograms + global and per-tenant counters.

pub mod batcher;
pub mod jobs;
pub mod metrics;
pub mod registry;
pub mod router;
pub mod server;

pub use jobs::LearningJob;
pub use registry::{KernelRegistry, ModePolicy, SamplerEpoch, TenantId};
pub use server::{DppService, SampleRequest, Ticket};
