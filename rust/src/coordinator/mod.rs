//! The Layer-3 coordinator: a production multi-tenant serving + learning
//! system around the KronDPP core (DESIGN.md §3).
//!
//! - [`registry`]: the multi-tenant [`KernelRegistry`] — named tenants
//!   publishing generation-stamped [`SamplerEpoch`]s (kernel + cached
//!   eigendecomposition + sampler) atomically, with an LRU bound on
//!   resident eigendecompositions and lazy rebuild for cold tenants.
//!   Candidate publishes are validated (finite scan + spectrum sanity)
//!   and quarantined on failure; a bounded per-tenant history backs
//!   [`KernelRegistry::rollback`]. Catalog churn streams in as
//!   [`crate::dpp::KernelDelta`]s via [`KernelRegistry::publish_delta`],
//!   which refreshes the resident eigendecomposition in place by rank-r
//!   secular updates (depth-bounded, with forced exact republish) instead
//!   of re-eigendecomposing per event.
//! - [`server`]: the sampling service (admission control → request queue
//!   → dynamic batcher → tenant-grouped least-loaded dispatch → DPP
//!   samples from the tenant's current epoch), constraint-aware end to
//!   end: requests may carry a [`crate::dpp::Constraint`]
//!   (`A ⊆ Y, B ∩ Y = ∅`), validated at admission and served through a
//!   per-group conditioning setup; epochs cache the factored
//!   marginal-diagonal table for instant scoring
//!   ([`server::DppService::marginals`]). Every request selects a
//!   [`crate::dpp::SampleMode`] backend — exact, MCMC, low-rank
//!   projection, or the deterministic greedy MAP slate — gated per
//!   tenant by a [`ModePolicy`] and counted per mode in the metrics.
//!   Requests carry optional **deadlines** (checked at admission and
//!   again before expensive per-group setup); per-tenant **circuit
//!   breakers** route `Numerical` failures into a configurable
//!   degraded-mode **fallback chain** (jittered regularization, then
//!   backend downgrade); workers are **supervised** — a panicking job
//!   fails only its own coalesced group and the worker is respawned.
//! - [`net`]: the TCP wire boundary — a single non-blocking event-loop
//!   thread driving length-prefixed JSON frame connections
//!   (DESIGN.md §3.2) into the same admission fast path, pipelining
//!   tickets per connection and writing completions as they resolve;
//!   a wire `shutdown` op drains connections gracefully. Per-tenant
//!   **token-bucket rate limits** and **queue-depth shedding** reject
//!   with retryable [`crate::error::Error::Throttled`] before a queue
//!   slot is burned; per-tenant p50/p99/p999 **SLO tracking** splits
//!   queue-wait from serve-time ([`metrics`]).
//! - [`batcher`]: the two-trigger (size/age) batch policy plus the
//!   `(tenant, k, constraint, mode)` coalescer, property-tested.
//! - [`router`]: job-weighted least-loaded work routing.
//! - [`jobs`]: background learning jobs publishing refreshed kernels to
//!   their target tenant.
//! - [`metrics`]: latency histograms + global and per-tenant counters.
//! - [`faults`] (test / `fault-injection` builds only): the deterministic
//!   seeded fault-injection plan driving the chaos suite.
//!
//! The whole coordinator tree denies `unwrap`/`expect` (clippy): the
//! serving path must degrade, never abort. Lock poisoning in particular
//! is recovered through the [`lock_clean`]/[`read_clean`]/[`write_clean`]
//! helpers below.

#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod batcher;
#[cfg(any(test, feature = "fault-injection"))]
pub mod faults;
pub mod jobs;
pub mod metrics;
pub mod net;
pub mod registry;
pub mod router;
pub mod server;

pub use jobs::LearningJob;
pub use net::{run_replay, NetConfig, NetServer, NetStats, ReplayOutcome, WireClient};
pub use registry::{DeltaOutcome, KernelRegistry, ModePolicy, SamplerEpoch, TenantId};
pub use server::{DppService, SampleRequest, Ticket};

use std::sync::{Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

// Poison recovery, deliberately: a `Mutex`/`RwLock` is poisoned when a
// thread panics while holding it. In this coordinator every panic is
// contained to one coalesced group (see `server`'s catch_unwind
// supervision), and none of the guarded structures carry invariants that
// a half-finished critical section could break mid-write in a way later
// readers would misinterpret (slots are swapped whole `Arc`s, scratches
// are fully overwritten by each build, metric maps are append-only).
// Propagating the poison would instead convert one contained panic into
// a permanent denial of service for the tenant — so we strip it.

/// Lock a mutex, recovering from poisoning.
pub(crate) fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Read-lock an `RwLock`, recovering from poisoning.
pub(crate) fn read_clean<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|p| p.into_inner())
}

/// Write-lock an `RwLock`, recovering from poisoning.
pub(crate) fn write_clean<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|p| p.into_inner())
}
