//! Background jobs around the serving core:
//!
//! - [`LearningJob`]: run a [`crate::learn::Learner`] in the background and
//!   (optionally) publish each improved kernel to a target tenant of a
//!   running [`super::server::DppService`] — continuous learning behind a
//!   live multi-tenant sampling endpoint. Each publication is an epoch
//!   hot-swap: readers of the tenant keep drawing, the eigendecomposition
//!   happens on the job thread.
//! - [`SamplingJob`]: bulk-draw samples off the caller's thread through the
//!   batched engine ([`crate::dpp::Sampler::sample_batch`]) instead of
//!   looping single draws — offline sample caches, evaluation sweeps,
//!   cache warming.

use crate::coordinator::registry::TenantId;
use crate::coordinator::server::DppService;
use crate::dpp::{Kernel, Sampler};
use crate::error::{Error, Result};
use crate::learn::traits::{IterRecord, Learner, TrainingSet};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Progress event emitted after each learning iteration.
#[derive(Clone, Debug)]
pub struct Progress {
    pub record: IterRecord,
    /// True when the kernel was installed into the service.
    pub installed: bool,
    /// Number of [`crate::dpp::KernelDelta`]s streamed into the tenant
    /// for this iteration (streaming jobs only; 0 when the iteration was
    /// installed by a full publish or not installed at all).
    pub deltas: usize,
}

/// A running learning job.
pub struct LearningJob {
    handle: JoinHandle<Result<Vec<IterRecord>>>,
    progress: mpsc::Receiver<Progress>,
    cancel: Arc<AtomicBool>,
}

impl LearningJob {
    /// Spawn: runs `learner` for up to `max_iters` over `data`. If
    /// `service` is given, each iteration's kernel is published to the
    /// service's **default** tenant (swap cost is the sub-kernel
    /// eigendecompositions — cheap for KronDPP, which is exactly the
    /// paper's point). Multi-tenant deployments use
    /// [`LearningJob::spawn_into`] to target a specific tenant.
    pub fn spawn(
        learner: Box<dyn Learner + Send>,
        data: TrainingSet,
        max_iters: usize,
        tol: f64,
        service: Option<Arc<DppService>>,
    ) -> Result<LearningJob> {
        Self::spawn_into(learner, data, max_iters, tol, service, TenantId::DEFAULT)
    }

    /// [`LearningJob::spawn`] publishing refreshed kernels to `tenant`.
    /// Each improving iteration becomes a new epoch generation for that
    /// tenant; other tenants are untouched.
    pub fn spawn_into(
        learner: Box<dyn Learner + Send>,
        data: TrainingSet,
        max_iters: usize,
        tol: f64,
        service: Option<Arc<DppService>>,
        tenant: TenantId,
    ) -> Result<LearningJob> {
        Self::spawn_inner(learner, data, max_iters, tol, service, tenant, false)
    }

    /// Spawn a **streaming** learning job against the service's default
    /// tenant: see [`LearningJob::spawn_streaming_into`].
    pub fn spawn_streaming(
        learner: Box<dyn Learner + Send>,
        data: TrainingSet,
        max_iters: usize,
        tol: f64,
        service: Arc<DppService>,
    ) -> Result<LearningJob> {
        Self::spawn_streaming_into(learner, data, max_iters, tol, service, TenantId::DEFAULT)
    }

    /// Spawn a **streaming** learning job: each iteration runs
    /// [`Learner::step_delta`] and publishes the step's
    /// [`crate::dpp::KernelDelta`]s into `tenant` through
    /// [`DppService::publish_delta`], so the tenant's cached
    /// eigendecomposition is refreshed by rank-r secular updates instead
    /// of rebuilt per iteration. Unlike the batch mode, **every**
    /// iteration is published (deltas must apply in unbroken sequence for
    /// the tenant to stay in lockstep with the learner's iterate); a
    /// learner without a delta form (`step_delta → None`), a raced
    /// publish, or a quarantined delta falls back to a full publish of
    /// the learner's exact kernel, resynchronizing the tenant.
    pub fn spawn_streaming_into(
        learner: Box<dyn Learner + Send>,
        data: TrainingSet,
        max_iters: usize,
        tol: f64,
        service: Arc<DppService>,
        tenant: TenantId,
    ) -> Result<LearningJob> {
        Self::spawn_inner(learner, data, max_iters, tol, Some(service), tenant, true)
    }

    fn spawn_inner(
        mut learner: Box<dyn Learner + Send>,
        data: TrainingSet,
        max_iters: usize,
        tol: f64,
        service: Option<Arc<DppService>>,
        tenant: TenantId,
        stream: bool,
    ) -> Result<LearningJob> {
        let (tx, rx) = mpsc::channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let cancel2 = Arc::clone(&cancel);
        let handle = std::thread::Builder::new()
            .name("krondpp-learn".into())
            .spawn(move || -> Result<Vec<IterRecord>> {
                let mut history = Vec::new();
                // `objective` routes learners with compressed statistics
                // through their fused engine sweep (dedup + parallel);
                // everyone else falls back to the dense Eq.-3 evaluation.
                let ll0 = learner.objective(&data)?;
                history.push(IterRecord {
                    iter: 0,
                    elapsed: Duration::ZERO,
                    log_likelihood: ll0,
                });
                let mut elapsed = Duration::ZERO;
                for it in 1..=max_iters {
                    if cancel2.load(Ordering::SeqCst) {
                        break;
                    }
                    let t = Instant::now();
                    let step_deltas = if stream {
                        learner.step_delta(&data)?
                    } else {
                        learner.step(&data)?;
                        None
                    };
                    elapsed += t.elapsed();
                    let ll = learner.objective(&data)?;
                    let record = IterRecord { iter: it, elapsed, log_likelihood: ll };
                    history.push(record.clone());
                    let mut installed = false;
                    let mut streamed = 0usize;
                    if let Some(svc) = &service {
                        if stream {
                            match &step_deltas {
                                Some(ds) => {
                                    let applied =
                                        ds.iter().take_while(|d| {
                                            svc.publish_delta(tenant, d).is_ok()
                                        })
                                        .count();
                                    if applied == ds.len() {
                                        streamed = applied;
                                    } else {
                                        // Lost lockstep mid-sequence (a
                                        // raced publish or a quarantined
                                        // delta): resync with the
                                        // learner's exact iterate.
                                        svc.publish(tenant, &learner.kernel())?;
                                    }
                                    installed = true;
                                }
                                None => {
                                    svc.publish(tenant, &learner.kernel())?;
                                    installed = true;
                                }
                            }
                        } else {
                            // Batch mode: only publish improving kernels.
                            let prev = history[history.len() - 2].log_likelihood;
                            if ll >= prev {
                                svc.publish(tenant, &learner.kernel())?;
                                installed = true;
                            }
                        }
                    }
                    let _ = tx.send(Progress { record, installed, deltas: streamed });
                    let prev = history[history.len() - 2].log_likelihood;
                    if tol > 0.0 && (ll - prev).abs() < tol {
                        break;
                    }
                }
                Ok(history)
            })
            .map_err(Error::Io)?;
        Ok(LearningJob { handle, progress: rx, cancel })
    }

    /// Non-blocking progress poll.
    pub fn poll(&self) -> Vec<Progress> {
        self.progress.try_iter().collect()
    }

    /// Request cancellation (takes effect at the next iteration boundary).
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::SeqCst);
    }

    /// Wait for completion, returning the full history.
    pub fn join(self) -> Result<Vec<IterRecord>> {
        self.handle.join().map_err(|_| {
            crate::error::Error::Service("learning job panicked".into())
        })?
    }
}

/// A background bulk-sampling job: eigendecomposes once, then draws through
/// the batched multi-threaded engine in cancellable chunks. The draw-stream
/// layout is chunking-invariant, so a completed job returns exactly
/// `Sampler::sample_batch(draws, k, seed)` and a cancelled job returns an
/// exact prefix of it.
pub struct SamplingJob {
    handle: JoinHandle<Vec<Vec<usize>>>,
    cancel: Arc<AtomicBool>,
}

impl SamplingJob {
    /// Chunk size between cancellation checks. Each chunk pays the batch
    /// fan-out setup (thread spawn + per-thread scratch + shared k-DPP
    /// table), so it is sized to keep that overhead well under a percent
    /// of the chunk's drawing time while still cancelling promptly.
    const CHUNK: usize = 1024;

    /// Spawn: draws `draws` samples from `kernel` (`k = None` for
    /// unconstrained DPP draws, `Some(κ)` for k-DPPs). The
    /// eigendecomposition runs on the caller's thread so invalid kernels
    /// fail fast.
    pub fn spawn(
        kernel: &Kernel,
        draws: usize,
        k: Option<usize>,
        seed: u64,
    ) -> Result<SamplingJob> {
        let sampler = Sampler::new(kernel)?;
        if let Some(kk) = k {
            if kk > sampler.n() {
                return Err(Error::Invalid(format!(
                    "sampling job: k={kk} > ground set {}",
                    sampler.n()
                )));
            }
        }
        let cancel = Arc::new(AtomicBool::new(false));
        let cancel2 = Arc::clone(&cancel);
        let handle = std::thread::Builder::new()
            .name("krondpp-sample".into())
            .spawn(move || {
                let threads = crate::linalg::matmul::available_threads();
                let mut out: Vec<Vec<usize>> = Vec::with_capacity(draws);
                while out.len() < draws && !cancel2.load(Ordering::SeqCst) {
                    let m = Self::CHUNK.min(draws - out.len());
                    out.extend(sampler.sample_batch_offset(out.len(), m, k, seed, threads));
                }
                out
            })
            .map_err(Error::Io)?;
        Ok(SamplingJob { handle, cancel })
    }

    /// Request cancellation (takes effect at the next chunk boundary).
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::SeqCst);
    }

    /// Wait for completion, returning the draws.
    pub fn join(self) -> Result<Vec<Vec<usize>>> {
        self.handle
            .join()
            .map_err(|_| Error::Service("sampling job panicked".into()))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::config::ServiceConfig;
    use crate::dpp::{Kernel, Sampler};
    use crate::learn::KrkPicard;
    use crate::rng::Rng;

    fn setup() -> (TrainingSet, KrkPicard, Kernel) {
        let mut rng = Rng::new(1);
        let mk = |n: usize, rng: &mut Rng| {
            let mut m = rng.paper_init_kernel(n);
            m.scale_mut(1.5 / n as f64);
            m.add_diag_mut(0.3);
            m
        };
        let truth = Kernel::Kron2(mk(3, &mut rng), mk(3, &mut rng));
        let sampler = Sampler::new(&truth).unwrap();
        let subsets: Vec<Vec<usize>> = (0..30).map(|_| sampler.sample(&mut rng)).collect();
        let data = TrainingSet::new(9, subsets).unwrap();
        let learner = KrkPicard::new(mk(3, &mut rng), mk(3, &mut rng), 1.0).unwrap();
        (data, learner, truth)
    }

    #[test]
    fn job_runs_to_completion_with_progress() {
        let (data, learner, _) = setup();
        let job = LearningJob::spawn(Box::new(learner), data, 5, 0.0, None).unwrap();
        let history = job.join().unwrap();
        assert_eq!(history.len(), 6);
        for w in history.windows(2) {
            assert!(w[1].log_likelihood >= w[0].log_likelihood - 1e-9);
        }
    }

    #[test]
    fn job_installs_kernels_into_service() {
        let (data, learner, truth) = setup();
        let cfg = ServiceConfig {
            workers: 1,
            max_batch: 2,
            batch_window_us: 100,
            queue_capacity: 16,
            ..ServiceConfig::default()
        };
        let svc = Arc::new(DppService::start(&truth, &cfg, 3).unwrap());
        let job = LearningJob::spawn(Box::new(learner), data, 4, 0.0, Some(Arc::clone(&svc)))
            .unwrap();
        let history = job.join().unwrap();
        assert_eq!(history.len(), 5);
        // Service still serves after swaps.
        let y = svc.sample(3).unwrap();
        assert_eq!(y.len(), 3);
    }

    #[test]
    fn job_publishes_into_target_tenant_only() {
        let (data, learner, truth) = setup();
        let cfg = ServiceConfig {
            workers: 1,
            max_batch: 2,
            batch_window_us: 100,
            queue_capacity: 16,
            ..ServiceConfig::default()
        };
        let svc = Arc::new(DppService::start(&truth, &cfg, 4).unwrap());
        let fresh = svc.add_tenant("fresh", &truth).unwrap();
        let job = LearningJob::spawn_into(
            Box::new(learner),
            data,
            3,
            0.0,
            Some(Arc::clone(&svc)),
            fresh,
        )
        .unwrap();
        let history = job.join().unwrap();
        assert!(history.len() >= 2);
        // The target tenant advanced generations; default stayed at 1.
        let reg = svc.registry();
        assert!(reg.entry(fresh).unwrap().generation() > 1);
        assert_eq!(reg.entry(TenantId::DEFAULT).unwrap().generation(), 1);
        let y = svc.sample_tenant(fresh, 3).unwrap();
        assert_eq!(y.len(), 3);
    }

    #[test]
    fn streaming_job_keeps_tenant_in_lockstep_with_learner() {
        use crate::learn::KrkStochastic;
        let mut rng = Rng::new(9);
        let mk = |n: usize, rng: &mut Rng| {
            let mut m = rng.paper_init_kernel(n);
            m.scale_mut(1.5 / n as f64);
            m.add_diag_mut(0.3);
            m
        };
        let truth = Kernel::Kron2(mk(3, &mut rng), mk(3, &mut rng));
        let sampler = Sampler::new(&truth).unwrap();
        let subsets: Vec<Vec<usize>> = (0..30).map(|_| sampler.sample(&mut rng)).collect();
        let data = TrainingSet::new(9, subsets).unwrap();
        let l1 = mk(3, &mut rng);
        let l2 = mk(3, &mut rng);
        // The service starts from the learner's initial iterate, so the
        // delta stream applies to exactly the kernel the tenant holds.
        let init = Kernel::Kron2(l1.clone(), l2.clone());
        let learner = KrkStochastic::new(l1, l2, 0.5, 4, 11);
        let cfg = ServiceConfig {
            workers: 1,
            max_batch: 2,
            batch_window_us: 100,
            queue_capacity: 16,
            ..ServiceConfig::default()
        };
        let svc = Arc::new(DppService::start(&init, &cfg, 5).unwrap());
        let job =
            LearningJob::spawn_streaming(Box::new(learner), data, 5, 0.0, Arc::clone(&svc))
                .unwrap();
        while !job.handle.is_finished() {
            std::thread::sleep(Duration::from_millis(2));
        }
        let events = job.poll();
        let history = job.join().unwrap();
        assert_eq!(history.len(), 6);
        assert_eq!(events.len(), 5);
        assert!(events.iter().all(|e| e.installed));
        let streamed: usize = events.iter().map(|e| e.deltas).sum();
        assert!(streamed >= 5, "each iteration should stream ≥1 delta, got {streamed}");
        // Clean streaming: every publication went through the delta path
        // (no full-publish resyncs), so the tenant advanced exactly one
        // generation per streamed delta.
        let reg = svc.registry();
        assert_eq!(reg.delta_publishes(), streamed as u64);
        let entry = reg.entry(TenantId::DEFAULT).unwrap();
        assert_eq!(entry.generation(), 1 + streamed as u64);
        assert_eq!(entry.deltas_published(), streamed as u64);
        // The service still serves off the delta-built epochs.
        let y = svc.sample(3).unwrap();
        assert_eq!(y.len(), 3);
    }

    #[test]
    fn sampling_job_matches_direct_batch() {
        let (_, _, truth) = setup();
        let job = SamplingJob::spawn(&truth, 150, Some(3), 77).unwrap();
        let got = job.join().unwrap();
        let want = Sampler::new(&truth).unwrap().sample_batch(150, Some(3), 77);
        assert_eq!(got, want);
    }

    #[test]
    fn sampling_job_rejects_oversized_k() {
        let (_, _, truth) = setup();
        assert!(SamplingJob::spawn(&truth, 5, Some(1000), 1).is_err());
    }

    #[test]
    fn cancelled_sampling_job_returns_prefix() {
        let (_, _, truth) = setup();
        let job = SamplingJob::spawn(&truth, 100_000, None, 3).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        job.cancel();
        let got = job.join().unwrap();
        assert!(got.len() % SamplingJob::CHUNK == 0 || got.len() == 100_000);
        let want = Sampler::new(&truth).unwrap().sample_batch(got.len(), None, 3);
        assert_eq!(got, want);
    }

    #[test]
    fn cancellation_stops_early() {
        let (data, learner, _) = setup();
        let job = LearningJob::spawn(Box::new(learner), data, 10_000, 0.0, None).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        job.cancel();
        let history = job.join().unwrap();
        assert!(history.len() < 10_001, "cancel had no effect");
        assert!(!history.is_empty());
    }
}
