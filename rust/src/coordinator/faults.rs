//! Deterministic fault injection for the serving coordinator (compiled
//! only into test builds and `--features fault-injection` builds).
//!
//! A [`FaultPlan`] is a set of per-tenant fault budgets wired through the
//! service's serving seams ([`super::server::DppService::
//! start_with_registry_and_faults`]): each budget fires an exact number
//! of times and counts every firing, so a chaos test can assert *exact*
//! accounting afterwards — "3 injected exact-path failures produced
//! exactly 3 fallback serves and 1 breaker trip" — instead of sampling
//! probabilistically and hoping.
//!
//! The injectable faults map one-to-one onto the coordinator's failure
//! domains:
//!
//! - [`FaultKind::ExactFailure`] — the primary exact path reports a
//!   `Numerical` error before touching the sampler (drives the circuit
//!   breaker + fallback chain);
//! - [`FaultKind::FallbackFailure`] — the next fallback rung is skipped
//!   as if its rebuild failed (drives rung climbing / exhaustion);
//! - [`FaultKind::WorkerPanic`] — the group serve panics (drives
//!   `catch_unwind` containment and supervisor respawn);
//! - [`FaultKind::SlowServe`] — the group serve sleeps before starting
//!   (drives deadline expiry under load).
//!
//! Budgets are consumed with sequentially-consistent compare-and-swap,
//! so concurrent workers never over-fire a budget. The `seed` carried by
//! the plan does not randomize the plan itself (budgets are exact); it
//! is the chaos suite's single source of RNG seeds — pinned in CI via
//! the `KRONDPP_FAULT_SEED` env var so a failing run reproduces exactly.

use crate::coordinator::registry::TenantId;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Environment variable the chaos suite reads its seed from
/// (see [`FaultPlan::seeded_from_env`]); CI pins it.
pub const FAULT_SEED_ENV: &str = "KRONDPP_FAULT_SEED";

/// Which serving seam a fault budget fires at.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Primary exact path fails with an injected `Numerical` error.
    ExactFailure,
    /// The next fallback rung is skipped as if its rebuild failed.
    FallbackFailure,
    /// The group serve panics inside the worker's `catch_unwind` domain.
    WorkerPanic,
    /// The group serve sleeps `delay` before starting.
    SlowServe,
}

struct Rule {
    tenant: TenantId,
    kind: FaultKind,
    /// Firings left; decremented by CAS so concurrent workers never
    /// over-consume the budget.
    remaining: AtomicU64,
    /// Firings so far — the test-side accounting ledger.
    fired: AtomicU64,
    /// Sleep length for [`FaultKind::SlowServe`] (zero otherwise).
    delay: Duration,
}

impl Rule {
    /// Consume one firing if any budget remains.
    fn try_take(&self) -> bool {
        let mut cur = self.remaining.load(Ordering::SeqCst);
        while cur > 0 {
            match self.remaining.compare_exchange(
                cur,
                cur - 1,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => {
                    self.fired.fetch_add(1, Ordering::SeqCst);
                    return true;
                }
                Err(seen) => cur = seen,
            }
        }
        false
    }
}

/// A deterministic, exactly-budgeted fault-injection plan.
pub struct FaultPlan {
    seed: u64,
    rules: Vec<Rule>,
}

impl FaultPlan {
    /// An empty plan carrying `seed` (see the module docs for what the
    /// seed governs).
    pub fn new(seed: u64) -> Self {
        FaultPlan { seed, rules: Vec::new() }
    }

    /// [`FaultPlan::new`] seeded from [`FAULT_SEED_ENV`], falling back to
    /// `default` when unset or unparseable. CI pins the variable so chaos
    /// runs are reproducible across machines.
    pub fn seeded_from_env(default: u64) -> Self {
        let seed = std::env::var(FAULT_SEED_ENV)
            .ok()
            .and_then(|s| s.trim().parse().ok())
            .unwrap_or(default);
        Self::new(seed)
    }

    /// The seed this plan carries (chaos tests derive every other RNG
    /// seed from it).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    fn rule(mut self, tenant: TenantId, kind: FaultKind, count: u64, delay: Duration) -> Self {
        self.rules.push(Rule {
            tenant,
            kind,
            remaining: AtomicU64::new(count),
            fired: AtomicU64::new(0),
            delay,
        });
        self
    }

    /// Fail `tenant`'s next `count` primary exact serves with an injected
    /// `Numerical` error.
    pub fn fail_exact(self, tenant: TenantId, count: u64) -> Self {
        self.rule(tenant, FaultKind::ExactFailure, count, Duration::ZERO)
    }

    /// Skip `tenant`'s next `count` fallback-rung attempts as if each
    /// rung's rebuild failed.
    pub fn fail_fallback(self, tenant: TenantId, count: u64) -> Self {
        self.rule(tenant, FaultKind::FallbackFailure, count, Duration::ZERO)
    }

    /// Panic `count` of `tenant`'s group serves (one panic per coalesced
    /// group, caught by the worker's `catch_unwind`).
    pub fn panic_worker(self, tenant: TenantId, count: u64) -> Self {
        self.rule(tenant, FaultKind::WorkerPanic, count, Duration::ZERO)
    }

    /// Sleep `delay` at the start of `tenant`'s next `count` group serves
    /// (deadline pressure).
    pub fn slow_serve(self, tenant: TenantId, count: u64, delay: Duration) -> Self {
        self.rule(tenant, FaultKind::SlowServe, count, delay)
    }

    fn take(&self, tenant: TenantId, kind: FaultKind) -> Option<&Rule> {
        self.rules
            .iter()
            .find(|r| r.tenant == tenant && r.kind == kind && r.try_take())
    }

    /// How many times a budget of `kind` has fired for `tenant`.
    pub fn fired(&self, tenant: TenantId, kind: FaultKind) -> u64 {
        self.rules
            .iter()
            .filter(|r| r.tenant == tenant && r.kind == kind)
            .map(|r| r.fired.load(Ordering::SeqCst))
            .sum()
    }

    pub fn fired_exact(&self, tenant: TenantId) -> u64 {
        self.fired(tenant, FaultKind::ExactFailure)
    }

    pub fn fired_fallback(&self, tenant: TenantId) -> u64 {
        self.fired(tenant, FaultKind::FallbackFailure)
    }

    pub fn fired_panics(&self, tenant: TenantId) -> u64 {
        self.fired(tenant, FaultKind::WorkerPanic)
    }

    pub fn fired_slow(&self, tenant: TenantId) -> u64 {
        self.fired(tenant, FaultKind::SlowServe)
    }

    /// Group-serve hook, called by the worker inside its `catch_unwind`
    /// domain before any deadline check or setup: injects latency
    /// ([`FaultKind::SlowServe`]) and/or a panic
    /// ([`FaultKind::WorkerPanic`]).
    pub fn on_group(&self, tenant: TenantId) {
        if let Some(r) = self.take(tenant, FaultKind::SlowServe) {
            std::thread::sleep(r.delay);
        }
        if self.take(tenant, FaultKind::WorkerPanic).is_some() {
            panic!("injected worker panic (tenant {tenant:?})");
        }
    }

    /// Should the primary exact path fail right now?
    pub fn exact_failure(&self, tenant: TenantId) -> bool {
        self.take(tenant, FaultKind::ExactFailure).is_some()
    }

    /// Should the next fallback rung be skipped right now?
    pub fn fallback_failure(&self, tenant: TenantId) -> bool {
        self.take(tenant, FaultKind::FallbackFailure).is_some()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use std::sync::Arc;

    const T0: TenantId = TenantId(0);
    const T1: TenantId = TenantId(1);

    #[test]
    fn budgets_fire_exactly_and_per_tenant() {
        let plan = FaultPlan::new(3).fail_exact(T0, 2).panic_worker(T1, 1);
        assert_eq!(plan.seed(), 3);
        // T0's exact budget: exactly two firings, then dry.
        assert!(plan.exact_failure(T0));
        assert!(plan.exact_failure(T0));
        assert!(!plan.exact_failure(T0));
        assert_eq!(plan.fired_exact(T0), 2);
        // Other tenants and other kinds never cross-fire.
        assert!(!plan.exact_failure(T1));
        assert!(!plan.fallback_failure(T0));
        assert_eq!(plan.fired_panics(T1), 0);
        assert_eq!(plan.fired_slow(T0), 0);
    }

    #[test]
    fn on_group_panics_exactly_budget_times() {
        let plan = FaultPlan::new(1).panic_worker(T0, 1);
        let err = std::panic::catch_unwind(|| plan.on_group(T0));
        assert!(err.is_err(), "first on_group must panic");
        assert_eq!(plan.fired_panics(T0), 1);
        // Budget exhausted: subsequent calls are clean.
        plan.on_group(T0);
        assert_eq!(plan.fired_panics(T0), 1);
    }

    #[test]
    fn concurrent_takers_never_overfire() {
        let plan = Arc::new(FaultPlan::new(2).fail_exact(T0, 100));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let p = Arc::clone(&plan);
            handles.push(std::thread::spawn(move || {
                let mut took = 0u64;
                for _ in 0..100 {
                    if p.exact_failure(T0) {
                        took += 1;
                    }
                }
                took
            }));
        }
        let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 100, "800 attempts over a budget of 100");
        assert_eq!(plan.fired_exact(T0), 100);
    }

    #[test]
    fn env_seed_overrides_default() {
        // No env var set in the test environment: the default wins.
        // (Setting the var here would race sibling tests; the CI chaos
        // job exercises the env path for real.)
        if std::env::var(FAULT_SEED_ENV).is_err() {
            assert_eq!(FaultPlan::seeded_from_env(77).seed(), 77);
        }
    }
}
