//! Multi-tenant kernel registry with epoch-published hot swaps.
//!
//! One deployment serves many catalogs/models at once — per-market
//! kernels, A/B variants, freshly learned refreshes — each with its own
//! cached `O(N₁³+N₂³)` eigendecomposition that is too expensive to rebuild
//! per request and too large to keep resident unboundedly. The
//! [`KernelRegistry`] holds named **tenants**; each tenant publishes
//! generation-stamped [`SamplerEpoch`]s (kernel + cached eigendecomposition
//! + sampler) atomically:
//!
//! - **Readers never block on writers.** A reader grabs the current epoch
//!   with an `Arc` clone under a briefly-held per-tenant `RwLock` read
//!   guard; no reader-visible lock is ever held while an
//!   eigendecomposition runs.
//! - **Writers build off the read path.** [`KernelRegistry::publish`]
//!   eigendecomposes the next kernel through the shared swap scratch
//!   (locked only by writers/rebuilders; concurrent builds fall back to a
//!   fresh scratch instead of serializing across tenants), then installs
//!   the new epoch and bumps the generation under a momentary write lock —
//!   a pointer swap. In-flight draws keep their old epoch alive through
//!   their `Arc` until they finish.
//! - **Cold tenants are evicted, not dropped.** A `max_resident_epochs`
//!   LRU bound caps how many eigendecompositions stay resident; an evicted
//!   tenant keeps its (cheap, factored) kernel and lazily rebuilds its
//!   epoch on the next [`KernelRegistry::acquire`].
//!
//! The serving stack ([`super::server`]) resolves tenants to [`TenantId`]s
//! at admission, coalesces requests by `(tenant, k)`, and acquires one
//! epoch per coalesced group, so per-tenant elementary-DP tables and the
//! batched engine's determinism guarantees are preserved.
//!
//! **Fault tolerance.** [`KernelRegistry::publish`] *validates* every
//! candidate before install: a non-finite entry scan on the factors, then
//! an eigenvalue sanity check on the freshly built spectrum. A failing
//! candidate is **quarantined** — counted, its reason recorded on the
//! tenant, and the tenant keeps serving its last-good generation
//! untouched. Each successful publish also pushes the outgoing
//! `(generation, kernel)` into a bounded per-tenant history, so
//! [`KernelRegistry::rollback`] can restore any recent generation as a
//! *new* publication (generations stay monotone; readers never observe
//! time moving backwards). Per-tenant circuit-breaker state for the
//! serving-side fallback chain also lives on [`TenantEntry`] — lock-free
//! atomics, same discipline as the mode-policy mask.
//!
//! **Delta publishing.** Catalog churn (item adds/removes/retires, small
//! factor perturbations) arrives as [`KernelDelta`]s through
//! [`KernelRegistry::publish_delta`]. The exact post-delta kernel is
//! always computed and validated first (the ground truth every fallback
//! converges to); then, when the tenant's eigendecomposition is resident
//! and the delta lowers to a rank-r factor perturbation, the cached
//! spectrum is **refreshed in place** by the secular-equation update
//! ([`crate::linalg::eigen_update`]) instead of re-eigendecomposed —
//! `O(r·N₁²)` against `O(N₁³)` per churn event. A per-tenant
//! `delta_depth` counter bounds how many incremental refreshes may stack
//! before an exact republish is forced (resetting accumulated drift to
//! zero); structural deltas, evicted tenants, and refreshes the updater
//! refuses ([`crate::linalg::eigen_update::UpdateOutcome::NeedExact`])
//! fall back to the exact path. Deltas to an **evicted** tenant update
//! the stored kernel only — the next acquire's lazy rebuild collapses
//! every pending delta into one eigendecomposition. Malformed or
//! poisoned deltas are quarantined exactly like poisoned full publishes.

use crate::config::AdmissionPolicy;
use crate::coordinator::metrics::TenantMetrics;
use crate::coordinator::{read_clean, write_clean};
use crate::dpp::backend::SampleMode;
use crate::dpp::{
    EigenVectors, Kernel, KernelDelta, KernelEigen, MarginalScratch, SampleScratch,
    Sampler,
};
use crate::linalg::eigen_update::{
    self, EigenUpdateScratch, UpdateOptions, UpdateOutcome,
};
use crate::linalg::{kron, Matrix};
use crate::error::{Error, Result};
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock, TryLockError};

/// Default bound on each tenant's rollback history (outgoing generations
/// kept as `(generation, kernel)` records; kernels are factored, so a
/// record is `O(N₁²+N₂²)` — cheap).
pub const DEFAULT_EPOCH_HISTORY: usize = 4;

/// Relative tolerance for the publish-time spectrum sanity check: a
/// candidate whose most-negative eigenvalue dips below
/// `-tol · max(1, λ_max)` is not a rounding artifact but a genuinely
/// indefinite kernel, and is quarantined.
const SPECTRUM_TOL: f64 = 1e-8;

/// Default bound on consecutive incremental delta refreshes before
/// [`KernelRegistry::publish_delta`] forces an exact republish. Each
/// secular-equation pass contributes `O(1e-12)` orthogonality drift
/// (gated per-pass at [`UpdateOptions::max_drift`]); sixteen stacked
/// passes keep the worst accumulated drift orders of magnitude below the
/// serving spectrum tolerance while amortizing ~16 eigendecompositions
/// per forced rebuild. `0` disables incremental absorption entirely
/// (every delta republishes exactly).
pub const DEFAULT_MAX_DELTA_DEPTH: u64 = 16;

/// Which sampler-zoo mode *families* a tenant may request — the
/// admission-time policy knob (a cheap per-mode capability mask; the
/// parameters inside a mode, `steps`/`rank`, are validated separately).
/// Policies default to allow-all and are swappable at runtime without a
/// republish ([`KernelRegistry::set_mode_policy`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModePolicy {
    mask: u8,
}

impl ModePolicy {
    const ALL: u8 = 0b1111;

    fn bit(mode: SampleMode) -> u8 {
        match mode {
            SampleMode::Exact => 0b0001,
            SampleMode::Mcmc { .. } => 0b0010,
            SampleMode::LowRank { .. } => 0b0100,
            SampleMode::Map => 0b1000,
        }
    }

    /// Every mode allowed (the default for new tenants).
    pub fn allow_all() -> Self {
        ModePolicy { mask: Self::ALL }
    }

    /// Only exact sampling allowed — the conservative policy for tenants
    /// that must not serve approximate draws.
    pub fn exact_only() -> Self {
        ModePolicy { mask: Self::bit(SampleMode::Exact) }
    }

    /// Remove a mode family from the policy.
    pub fn without(self, mode: SampleMode) -> Self {
        ModePolicy { mask: self.mask & !Self::bit(mode) }
    }

    /// Add a mode family to the policy.
    pub fn with(self, mode: SampleMode) -> Self {
        ModePolicy { mask: self.mask | Self::bit(mode) }
    }

    /// Does this policy admit requests of `mode`'s family?
    pub fn allows(&self, mode: SampleMode) -> bool {
        self.mask & Self::bit(mode) != 0
    }
}

impl Default for ModePolicy {
    fn default() -> Self {
        ModePolicy::allow_all()
    }
}

/// Stable, copyable handle to a registry tenant. Ids are assigned densely
/// in creation order and never reused (tenants' epochs are evicted, the
/// tenants themselves are never removed), so an id stays valid for the
/// registry's lifetime.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TenantId(pub(crate) u32);

impl TenantId {
    /// The first tenant created (single-tenant deployments' implicit
    /// tenant; [`super::server::DppService::start`] names it "default").
    pub const DEFAULT: TenantId = TenantId(0);

    /// Dense index of this tenant (creation order).
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// One published serving state of a tenant: the kernel, its cached
/// eigendecomposition wrapped in a ready [`Sampler`], and the factored
/// marginal-diagonal table, stamped with the generation that produced
/// them. Immutable once published; shared by `Arc` clone. A draw that
/// started on generation `g` finishes on generation `g` even if `g+1` is
/// published mid-draw.
pub struct SamplerEpoch {
    /// Owning tenant.
    pub tenant: TenantId,
    /// Owning tenant's name (for logs/metrics labels).
    pub name: String,
    /// Monotone per-tenant publication counter (1 = initial kernel).
    pub generation: u64,
    /// The epoch's source kernel (factored: `O(N₁²+N₂²)` to keep) — what
    /// conditioned requests gather their Schur blocks from, pinned to the
    /// epoch so a hot swap mid-draw can't mix generations.
    pub kernel: Kernel,
    /// Ready sampler over the epoch's cached eigendecomposition.
    pub sampler: Sampler,
    /// Cached inclusion probabilities `P(i ∈ Y) = K_ii` for all `N`
    /// items, computed once per publish by the factored
    /// `O(N·(N₁+N₂))` path
    /// ([`crate::dpp::KernelEigen::inclusion_probabilities_into`]) — the
    /// instant "relevance × diversity" scoring table; never a dense `K`.
    /// `Arc`-wrapped so scoring endpoints hand it out without copying.
    pub marginal_diag: Arc<Vec<f64>>,
}

impl SamplerEpoch {
    /// The cached factored marginal-diagonal table.
    pub fn inclusion_probabilities(&self) -> &[f64] {
        &self.marginal_diag
    }
}

/// Mutable per-tenant state behind the per-tenant `RwLock`: the source
/// kernel (always resident — factored kernels are `O(N₁²+N₂²)`, cheap),
/// the ground-set size (admission checks read it without touching the
/// epoch), the generation counter, and the possibly-evicted epoch.
struct TenantSlot {
    kernel: Kernel,
    n: usize,
    generation: u64,
    epoch: Option<Arc<SamplerEpoch>>,
    /// Recent outgoing generations, oldest first, bounded by the
    /// registry's `max_history`. Only the defining state is kept (the
    /// factored kernel); a rollback re-eigendecomposes it, exactly like a
    /// publish of a known-good kernel.
    history: VecDeque<EpochRecord>,
    /// Consecutive incremental delta refreshes stacked on the resident
    /// eigendecomposition since its last exact build. Reset to zero by
    /// every exact path (publish, rollback, lazy rebuild, forced
    /// republish); compared against the registry's `max_delta_depth` to
    /// force periodic exact republishes under sustained churn.
    delta_depth: u64,
}

/// One rollback point: a previously-served generation and its kernel.
#[derive(Clone)]
struct EpochRecord {
    generation: u64,
    kernel: Kernel,
}

/// Token-bucket state behind a tenant's admission mutex. The lock is held
/// for a handful of float ops on the submit fast path — contention is
/// per-tenant and negligible next to the queue mutex it fronts.
struct AdmissionBucket {
    policy: AdmissionPolicy,
    tokens: f64,
    last: std::time::Instant,
}

/// A registry tenant: identity, the epoch slot, LRU/load accounting and
/// per-tenant metrics. Shared as `Arc` between the registry, queued jobs
/// and metric reporters.
pub struct TenantEntry {
    name: String,
    id: TenantId,
    slot: RwLock<TenantSlot>,
    /// Lamport-style touch stamp for LRU eviction.
    last_touch: AtomicU64,
    /// Jobs dispatched to workers and not yet finished (per-tenant load).
    pub(crate) in_flight: AtomicUsize,
    /// Requests accepted at admission and not yet finished (queued *or*
    /// dispatched) — what the admission policy's `max_outstanding` caps.
    pub(crate) outstanding: AtomicUsize,
    /// Admission-control token bucket + policy (live-tunable).
    admission: Mutex<AdmissionBucket>,
    /// Allowed sampler-mode families ([`ModePolicy`] mask), checked at
    /// admission. Atomic so policy swaps need no lock and no republish.
    mode_policy: AtomicU8,
    metrics: TenantMetrics,
    /// Candidate publishes rejected by validation for this tenant.
    quarantined: AtomicU64,
    /// Reason the most recent candidate was quarantined.
    last_quarantine: Mutex<Option<String>>,
    /// Deltas successfully published to this tenant (churn volume).
    deltas: AtomicU64,
    /// Of those, how many were absorbed by the incremental secular
    /// refresh (the rest rebuilt exactly: structural change, depth
    /// budget, updater refusal, or an evicted epoch).
    delta_refreshes: AtomicU64,
    /// Circuit breaker (serving-side degraded mode). All lock-free:
    /// `open` is the trip state, `forced` pins it open for operator-forced
    /// degradation, `failures` counts *consecutive* numerical failures,
    /// `open_serves` clocks half-open probes while tripped.
    breaker_open: AtomicBool,
    breaker_forced: AtomicBool,
    breaker_failures: AtomicU32,
    breaker_open_serves: AtomicU32,
    breaker_trips: AtomicU64,
    breaker_recoveries: AtomicU64,
}

impl TenantEntry {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn id(&self) -> TenantId {
        self.id
    }

    /// Per-tenant counters + latency histogram.
    pub fn metrics(&self) -> &TenantMetrics {
        &self.metrics
    }

    /// Jobs currently dispatched for this tenant (load accounting).
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Requests accepted and not yet finished (queued or dispatched).
    pub fn outstanding(&self) -> usize {
        self.outstanding.load(Ordering::SeqCst)
    }

    /// The tenant's current admission policy.
    pub fn admission_policy(&self) -> AdmissionPolicy {
        crate::coordinator::lock_clean(&self.admission).policy
    }

    /// Swap the tenant's admission policy (live-tunable; takes effect on
    /// the next submit). The bucket refills to the new burst so a tenant
    /// whose limit was just *raised* isn't still throttled by old debt,
    /// and the SLO mirror on the metrics updates atomically with it.
    pub fn set_admission(&self, policy: AdmissionPolicy) {
        {
            let mut b = crate::coordinator::lock_clean(&self.admission);
            b.policy = policy;
            b.tokens = policy.effective_burst();
            b.last = std::time::Instant::now();
        }
        self.metrics
            .slo_us
            .store(policy.slo_ms.saturating_mul(1000), Ordering::Relaxed);
    }

    /// Admission fast path: enforce the outstanding cap, then refill and
    /// take one token. `Err(reason)` means "shed with
    /// [`crate::error::Error::Throttled`]" — checked *before* any queue
    /// slot is considered, so shedding costs one mutex and a few float
    /// ops. The outstanding cap is checked before the bucket so a capped
    /// request doesn't burn a token it was never going to use.
    pub(crate) fn try_admit(&self, now: std::time::Instant) -> std::result::Result<(), String> {
        let mut b = crate::coordinator::lock_clean(&self.admission);
        let policy = b.policy;
        let outstanding = self.outstanding.load(Ordering::SeqCst);
        if policy.max_outstanding > 0 && outstanding >= policy.max_outstanding {
            return Err(format!(
                "tenant '{}': {} requests outstanding (cap {})",
                self.name, outstanding, policy.max_outstanding
            ));
        }
        if policy.rate_hz > 0.0 {
            let dt = now.saturating_duration_since(b.last).as_secs_f64();
            b.last = now;
            b.tokens = (b.tokens + dt * policy.rate_hz).min(policy.effective_burst());
            if b.tokens < 1.0 {
                return Err(format!(
                    "tenant '{}': rate limit {:.0}/s exceeded",
                    self.name, policy.rate_hz
                ));
            }
            b.tokens -= 1.0;
        }
        Ok(())
    }

    /// Current ground-set size — readable without building an epoch, so
    /// admission control can reject `k > n` for a cold tenant without
    /// forcing an eigendecomposition.
    pub fn n(&self) -> usize {
        read_clean(&self.slot).n
    }

    /// Current publication generation.
    pub fn generation(&self) -> u64 {
        read_clean(&self.slot).generation
    }

    /// Is this tenant's eigendecomposition resident right now?
    pub fn resident(&self) -> bool {
        read_clean(&self.slot).epoch.is_some()
    }

    /// Generations currently available for [`KernelRegistry::rollback`],
    /// oldest first.
    pub fn rollback_generations(&self) -> Vec<u64> {
        read_clean(&self.slot).history.iter().map(|r| r.generation).collect()
    }

    /// The tenant's current sampler-mode policy.
    pub fn mode_policy(&self) -> ModePolicy {
        ModePolicy { mask: self.mode_policy.load(Ordering::Relaxed) }
    }

    /// Swap the tenant's sampler-mode policy (takes effect on the next
    /// admission; queued requests were admitted under the old policy and
    /// still complete).
    pub fn set_mode_policy(&self, policy: ModePolicy) {
        self.mode_policy.store(policy.mask, Ordering::Relaxed);
    }

    /// Candidate publishes rejected by validation for this tenant.
    pub fn quarantined_candidates(&self) -> u64 {
        self.quarantined.load(Ordering::Relaxed)
    }

    /// Why the most recent candidate was quarantined (None if none was).
    pub fn last_quarantine(&self) -> Option<String> {
        crate::coordinator::lock_clean(&self.last_quarantine).clone()
    }

    pub(crate) fn record_quarantine(&self, reason: String) {
        self.quarantined.fetch_add(1, Ordering::Relaxed);
        *crate::coordinator::lock_clean(&self.last_quarantine) = Some(reason);
    }

    // --- churn accounting ------------------------------------------------

    /// Deltas successfully published to this tenant so far.
    pub fn deltas_published(&self) -> u64 {
        self.deltas.load(Ordering::Relaxed)
    }

    /// Of the published deltas, how many refreshed the resident
    /// eigendecomposition incrementally (vs an exact rebuild).
    pub fn delta_refreshes(&self) -> u64 {
        self.delta_refreshes.load(Ordering::Relaxed)
    }

    /// Incremental refreshes currently stacked on the resident
    /// eigendecomposition since its last exact build.
    pub fn delta_depth(&self) -> u64 {
        read_clean(&self.slot).delta_depth
    }

    // --- circuit breaker -------------------------------------------------
    //
    // SeqCst throughout: breaker transitions are rare (failures, trips,
    // probes) and correctness under concurrent workers matters more than
    // the fence cost.

    /// Is this tenant currently serving in degraded (tripped) mode?
    pub fn breaker_is_open(&self) -> bool {
        self.breaker_open.load(Ordering::SeqCst)
    }

    /// `"closed"`, `"open"` or `"forced"` — for reports and logs.
    pub fn breaker_state(&self) -> &'static str {
        if self.breaker_forced.load(Ordering::SeqCst) {
            "forced"
        } else if self.breaker_is_open() {
            "open"
        } else {
            "closed"
        }
    }

    /// Times the breaker tripped / recovered so far.
    pub fn breaker_trips(&self) -> u64 {
        self.breaker_trips.load(Ordering::SeqCst)
    }

    pub fn breaker_recoveries(&self) -> u64 {
        self.breaker_recoveries.load(Ordering::SeqCst)
    }

    /// Record one numerical failure event on the primary serving path.
    /// Trips the breaker once `threshold` *consecutive* failures
    /// accumulate (`threshold == 0` disables tripping). Returns `true`
    /// when this call newly tripped it.
    pub(crate) fn breaker_record_failure(&self, threshold: u32) -> bool {
        let failures = self.breaker_failures.fetch_add(1, Ordering::SeqCst) + 1;
        if threshold == 0 || failures < threshold {
            return false;
        }
        let tripped = !self.breaker_open.swap(true, Ordering::SeqCst);
        if tripped {
            self.breaker_open_serves.store(0, Ordering::SeqCst);
            self.breaker_trips.fetch_add(1, Ordering::SeqCst);
        }
        tripped
    }

    /// Record a successful primary serve: resets the consecutive-failure
    /// count and closes a tripped breaker (half-open probe recovery) —
    /// unless an operator forced degraded mode.
    pub(crate) fn breaker_record_success(&self) {
        self.breaker_failures.store(0, Ordering::SeqCst);
        if self.breaker_forced.load(Ordering::SeqCst) {
            return;
        }
        if self.breaker_open.swap(false, Ordering::SeqCst) {
            self.breaker_recoveries.fetch_add(1, Ordering::SeqCst);
        }
    }

    /// While open, every `every`-th serve event is a half-open probe that
    /// retries the primary path (`every == 0` disables probing; forced
    /// degradation never probes). Call once per serve event.
    pub(crate) fn breaker_probe_due(&self, every: u32) -> bool {
        if every == 0 || self.breaker_forced.load(Ordering::SeqCst) {
            return false;
        }
        let n = self.breaker_open_serves.fetch_add(1, Ordering::SeqCst) + 1;
        n % every == 0
    }

    /// Operator override: pin the tenant into (or release it from)
    /// degraded mode regardless of failure history. Used by ops runbooks
    /// and the degraded-mode bench.
    pub fn force_degraded(&self, on: bool) {
        self.breaker_forced.store(on, Ordering::SeqCst);
        if on {
            self.breaker_open.store(true, Ordering::SeqCst);
            self.breaker_open_serves.store(0, Ordering::SeqCst);
        } else {
            self.breaker_open.store(false, Ordering::SeqCst);
            self.breaker_failures.store(0, Ordering::SeqCst);
        }
    }
}

/// Name → id map plus id-indexed entry list, guarded together so tenant
/// creation is atomic.
#[derive(Default)]
struct Tenants {
    list: Vec<Arc<TenantEntry>>,
    names: BTreeMap<String, TenantId>,
}

/// The multi-tenant kernel registry. See the module docs for the epoch
/// publication protocol.
pub struct KernelRegistry {
    tenants: RwLock<Tenants>,
    /// LRU bound on resident eigendecompositions (0 = unbounded).
    max_resident: usize,
    /// Monotone clock stamping tenant touches for LRU ordering.
    clock: AtomicU64,
    /// Shared kernel-assembly workspace: epoch builds (publish or lazy
    /// rebuild) re-eigendecompose through one reused scratch — panels,
    /// rotation buffers, GEMM pack buffers — instead of reallocating.
    /// Writer-side only; readers never take this lock, and concurrent
    /// builders fall back to a fresh scratch rather than contending
    /// (see `build_sampler`).
    swap_scratch: Mutex<SampleScratch>,
    /// Companion workspace for the epoch marginal-diagonal build (squared
    /// eigenvector matrices, weight grid, GEMM packs) — same
    /// writer-side-only, try-lock-or-fresh discipline as `swap_scratch`.
    marginal_scratch: Mutex<MarginalScratch>,
    /// Per-tenant bound on rollback history records (0 = no history).
    max_history: usize,
    /// Workspace for the incremental delta path's secular-equation
    /// refresh — same writer-side-only, try-lock-or-fresh discipline as
    /// `swap_scratch`.
    delta_scratch: Mutex<EigenUpdateScratch>,
    /// Drift/rank acceptance gates handed to the secular updater.
    delta_opts: UpdateOptions,
    /// Bound on consecutive incremental refreshes before a forced exact
    /// republish (0 = incremental absorption disabled).
    max_delta_depth: u64,
    evictions: AtomicU64,
    rebuilds: AtomicU64,
    publishes: AtomicU64,
    quarantines: AtomicU64,
    rollbacks: AtomicU64,
    delta_publishes: AtomicU64,
    delta_incremental: AtomicU64,
    delta_exact: AtomicU64,
}

/// What a [`KernelRegistry::publish_delta`] call did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DeltaOutcome {
    /// The freshly installed generation.
    pub generation: u64,
    /// `true` when the resident eigendecomposition was refreshed in place
    /// by the rank-r secular update; `false` when the delta was absorbed
    /// by an exact rebuild (structural change, depth budget exhausted,
    /// updater refusal) or recorded kernel-only on an evicted tenant.
    pub incremental: bool,
    /// Incremental refreshes stacked since the last exact build, *after*
    /// this publish (0 right after an exact path).
    pub depth: u64,
}

impl KernelRegistry {
    /// Empty registry. `max_resident_epochs = 0` disables eviction;
    /// rollback history defaults to [`DEFAULT_EPOCH_HISTORY`].
    pub fn new(max_resident_epochs: usize) -> Self {
        Self::with_history(max_resident_epochs, DEFAULT_EPOCH_HISTORY)
    }

    /// [`KernelRegistry::new`] with an explicit per-tenant rollback
    /// history bound (`0` disables rollback).
    pub fn with_history(max_resident_epochs: usize, max_history: usize) -> Self {
        KernelRegistry {
            tenants: RwLock::new(Tenants::default()),
            max_resident: max_resident_epochs,
            clock: AtomicU64::new(0),
            swap_scratch: Mutex::new(SampleScratch::new()),
            marginal_scratch: Mutex::new(MarginalScratch::new()),
            max_history,
            delta_scratch: Mutex::new(EigenUpdateScratch::new()),
            delta_opts: UpdateOptions::default(),
            max_delta_depth: DEFAULT_MAX_DELTA_DEPTH,
            evictions: AtomicU64::new(0),
            rebuilds: AtomicU64::new(0),
            publishes: AtomicU64::new(0),
            quarantines: AtomicU64::new(0),
            rollbacks: AtomicU64::new(0),
            delta_publishes: AtomicU64::new(0),
            delta_incremental: AtomicU64::new(0),
            delta_exact: AtomicU64::new(0),
        }
    }

    /// Override the forced-republish depth bound (pre-sharing
    /// configuration; `0` disables incremental absorption so every delta
    /// republishes exactly).
    pub fn set_max_delta_depth(&mut self, depth: u64) {
        self.max_delta_depth = depth;
    }

    /// Configured bound on consecutive incremental refreshes.
    pub fn max_delta_depth(&self) -> u64 {
        self.max_delta_depth
    }

    /// Register a new tenant with its initial kernel (published as
    /// generation 1). Fails on duplicate names.
    pub fn add_tenant(&self, name: &str, kernel: &Kernel) -> Result<TenantId> {
        // An initial kernel gets the same scrutiny as a refresh — there is
        // no last-good generation to fall back to, so poison must not
        // become a tenant at all.
        Self::validate_candidate(kernel)?;
        // Eigendecompose before taking the registry lock: tenant creation
        // never stalls readers of other tenants.
        let (sampler, marginal_diag) = self.build_parts(kernel)?;
        Self::validate_spectrum(&sampler)?;
        let touch = self.tick();
        let mut tenants = write_clean(&self.tenants);
        if tenants.names.contains_key(name) {
            return Err(Error::Invalid(format!("tenant '{name}' already exists")));
        }
        let id = TenantId(u32::try_from(tenants.list.len()).map_err(|_| {
            Error::Invalid("tenant id space exhausted".into())
        })?);
        let epoch = Arc::new(SamplerEpoch {
            tenant: id,
            name: name.to_string(),
            generation: 1,
            kernel: kernel.clone(),
            sampler,
            marginal_diag,
        });
        tenants.list.push(Arc::new(TenantEntry {
            name: name.to_string(),
            id,
            slot: RwLock::new(TenantSlot {
                kernel: kernel.clone(),
                n: kernel.n(),
                generation: 1,
                epoch: Some(epoch),
                history: VecDeque::new(),
                delta_depth: 0,
            }),
            last_touch: AtomicU64::new(touch),
            in_flight: AtomicUsize::new(0),
            outstanding: AtomicUsize::new(0),
            admission: Mutex::new(AdmissionBucket {
                policy: AdmissionPolicy::default(),
                tokens: AdmissionPolicy::default().effective_burst(),
                last: std::time::Instant::now(),
            }),
            mode_policy: AtomicU8::new(ModePolicy::allow_all().mask),
            metrics: TenantMetrics::new(),
            quarantined: AtomicU64::new(0),
            last_quarantine: Mutex::new(None),
            deltas: AtomicU64::new(0),
            delta_refreshes: AtomicU64::new(0),
            breaker_open: AtomicBool::new(false),
            breaker_forced: AtomicBool::new(false),
            breaker_failures: AtomicU32::new(0),
            breaker_open_serves: AtomicU32::new(0),
            breaker_trips: AtomicU64::new(0),
            breaker_recoveries: AtomicU64::new(0),
        }));
        tenants.names.insert(name.to_string(), id);
        drop(tenants);
        self.publishes.fetch_add(1, Ordering::Relaxed);
        self.enforce_budget(id);
        Ok(id)
    }

    /// Look up a tenant id by name.
    pub fn resolve(&self, name: &str) -> Option<TenantId> {
        read_clean(&self.tenants).names.get(name).copied()
    }

    /// Tenant entry by id (shared handle).
    pub fn entry(&self, id: TenantId) -> Result<Arc<TenantEntry>> {
        read_clean(&self.tenants)
            .list
            .get(id.index())
            .cloned()
            .ok_or_else(|| Error::Rejected(format!("unknown tenant id {}", id.0)))
    }

    /// All tenant names in id order.
    pub fn tenant_names(&self) -> Vec<String> {
        read_clean(&self.tenants).list.iter().map(|e| e.name.clone()).collect()
    }

    /// Snapshot of all tenant entries in id order (metrics/report sweeps).
    pub fn entries(&self) -> Vec<Arc<TenantEntry>> {
        read_clean(&self.tenants).list.clone()
    }

    /// Number of registered tenants.
    pub fn len(&self) -> usize {
        read_clean(&self.tenants).list.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Grab the tenant's current epoch: an `Arc` clone under a momentary
    /// read lock. If the tenant was evicted, rebuild its
    /// eigendecomposition first — entirely off the read path (only the
    /// writer-side swap scratch is locked while the eigensolver runs).
    pub fn acquire(&self, id: TenantId) -> Result<Arc<SamplerEpoch>> {
        let entry = self.entry(id)?;
        self.acquire_entry(&entry)
    }

    /// [`KernelRegistry::acquire`] given an already-resolved entry (the
    /// server's worker path — jobs carry their entry).
    pub fn acquire_entry(&self, entry: &Arc<TenantEntry>) -> Result<Arc<SamplerEpoch>> {
        entry.last_touch.store(self.tick(), Ordering::Relaxed);
        loop {
            let (kernel, generation) = {
                let slot = read_clean(&entry.slot);
                match &slot.epoch {
                    Some(e) => return Ok(Arc::clone(e)),
                    // Cold tenant: copy out what the rebuild needs, then
                    // release the reader-visible lock before any heavy work.
                    None => (slot.kernel.clone(), slot.generation),
                }
            };
            let (sampler, marginal_diag) = self.build_parts(&kernel)?;
            let epoch = Arc::new(SamplerEpoch {
                tenant: entry.id,
                name: entry.name.clone(),
                generation,
                kernel: kernel.clone(),
                sampler,
                marginal_diag,
            });
            let installed = {
                let mut slot = write_clean(&entry.slot);
                if slot.generation != generation {
                    // A publish landed mid-rebuild; our epoch is stale.
                    // Retry against the new generation (usually resident).
                    None
                } else if let Some(e) = &slot.epoch {
                    // A concurrent rebuilder won the race; epochs of the
                    // same generation are interchangeable — use theirs.
                    Some(Arc::clone(e))
                } else {
                    slot.epoch = Some(Arc::clone(&epoch));
                    // The rebuild eigendecomposed the stored kernel
                    // exactly — any deltas pending since eviction (and
                    // their would-be drift) are collapsed into it.
                    slot.delta_depth = 0;
                    self.rebuilds.fetch_add(1, Ordering::Relaxed);
                    Some(epoch)
                }
            };
            if let Some(e) = installed {
                self.enforce_budget(entry.id);
                return Ok(e);
            }
        }
    }

    /// Publish a refreshed kernel to a tenant: **validate the candidate**,
    /// eigendecompose off the read path, then atomically install the new
    /// epoch and bump the generation. Returns the new generation. Readers
    /// holding the old epoch finish on it; new acquires see the new one
    /// immediately.
    ///
    /// A candidate that fails validation (non-finite entries, eigensolver
    /// failure, an indefinite spectrum) is **quarantined**: the error is
    /// returned, the tenant's quarantine counters/reason are updated, and
    /// the tenant keeps serving its last-good generation untouched.
    pub fn publish(&self, id: TenantId, kernel: &Kernel) -> Result<u64> {
        let entry = self.entry(id)?;
        // Stamp the LRU touch before building: a long-cold tenant being
        // refreshed must not look like an eviction victim to a concurrent
        // enforce_budget while (or right after) its new epoch is built.
        entry.last_touch.store(self.tick(), Ordering::Relaxed);
        let (sampler, marginal_diag) = self
            .validated_parts(kernel)
            .map_err(|e| self.quarantine(&entry, e))?;
        let generation = self.install(&entry, kernel, sampler, marginal_diag);
        self.publishes.fetch_add(1, Ordering::Relaxed);
        self.enforce_budget(id);
        Ok(generation)
    }

    /// Restore a prior generation from the tenant's bounded rollback
    /// history. The restored state is installed as a **new** generation
    /// (generations stay monotone — readers never observe time moving
    /// backwards); the pre-rollback kernel itself goes into the history,
    /// so a rollback can be rolled back. Returns the new generation.
    pub fn rollback(&self, id: TenantId, generation: u64) -> Result<u64> {
        let entry = self.entry(id)?;
        entry.last_touch.store(self.tick(), Ordering::Relaxed);
        let record = {
            let slot = read_clean(&entry.slot);
            if generation == slot.generation {
                return Err(Error::Invalid(format!(
                    "tenant '{}': generation {generation} is already current",
                    entry.name
                )));
            }
            // Newest match wins if a generation ever repeats (it cannot —
            // generations are monotone — but be defensive).
            slot.history.iter().rev().find(|r| r.generation == generation).cloned()
        };
        let Some(record) = record else {
            return Err(Error::Invalid(format!(
                "tenant '{}': generation {generation} not in rollback history {:?}",
                entry.name,
                entry.rollback_generations()
            )));
        };
        // The historical kernel was validated when first published, but it
        // is rebuilt here, so run the full gauntlet again — a rollback must
        // never install something the validator would quarantine today.
        let (sampler, marginal_diag) = self
            .validated_parts(&record.kernel)
            .map_err(|e| self.quarantine(&entry, e))?;
        let new_gen = self.install(&entry, &record.kernel, sampler, marginal_diag);
        self.publishes.fetch_add(1, Ordering::Relaxed);
        self.rollbacks.fetch_add(1, Ordering::Relaxed);
        self.enforce_budget(id);
        Ok(new_gen)
    }

    /// Publish a [`KernelDelta`] to a tenant — the incremental churn
    /// path. The exact post-delta kernel is always computed and screened
    /// first (ground truth; a malformed or poisoned delta is quarantined
    /// like a poisoned full publish, leaving the tenant untouched). Then,
    /// cheapest-first:
    ///
    /// 1. **Evicted tenant** — record the new kernel and bump the
    ///    generation; no eigenwork at all. The next acquire's lazy
    ///    rebuild collapses every pending delta into one exact
    ///    eigendecomposition.
    /// 2. **Incremental refresh** — when the delta lowers to a rank-r
    ///    factor perturbation ([`KernelDelta::as_perturbation`]), the
    ///    `delta_depth` budget has room, and the secular updater accepts
    ///    it within drift tolerance, the resident epoch's cached
    ///    eigendecomposition is refreshed in place (`O(r·N₁²)` vs
    ///    `O(N₁³)`) and the product spectrum recombined in `O(N)`.
    /// 3. **Exact republish** — structural deltas (add/remove), an
    ///    exhausted depth budget, or an updater refusal rebuild the epoch
    ///    exactly through the same validated path as
    ///    [`KernelRegistry::publish`], resetting `delta_depth` (and any
    ///    accumulated drift) to zero.
    ///
    /// The install refuses (with `Error::Rejected`) if another publish
    /// landed between the snapshot and the swap — the delta was derived
    /// against that exact generation, so the caller must re-derive.
    pub fn publish_delta(&self, id: TenantId, delta: &KernelDelta) -> Result<DeltaOutcome> {
        let entry = self.entry(id)?;
        entry.last_touch.store(self.tick(), Ordering::Relaxed);
        // Snapshot the generation the delta applies to.
        let (kernel, epoch, generation, depth) = {
            let slot = read_clean(&entry.slot);
            (slot.kernel.clone(), slot.epoch.clone(), slot.generation, slot.delta_depth)
        };
        // Ground truth: the delta's exact effect on the factored kernel,
        // through the same non-finite screen as a full publish.
        let new_kernel = delta
            .validate(&kernel)
            .and_then(|()| delta.apply(&kernel))
            .and_then(|k| {
                Self::validate_candidate(&k)?;
                Ok(k)
            })
            .map_err(|e| self.quarantine(&entry, e))?;

        // Evicted tenant: kernel-only install, zero eigenwork.
        let Some(epoch) = epoch else {
            let new_gen = self.install_delta(&entry, generation, &new_kernel, None, 0)?;
            self.publishes.fetch_add(1, Ordering::Relaxed);
            self.delta_publishes.fetch_add(1, Ordering::Relaxed);
            self.delta_exact.fetch_add(1, Ordering::Relaxed);
            entry.deltas.fetch_add(1, Ordering::Relaxed);
            return Ok(DeltaOutcome { generation: new_gen, incremental: false, depth: 0 });
        };

        // Incremental: rank-r secular refresh of the resident spectrum.
        let mut refreshed: Option<Sampler> = None;
        if depth < self.max_delta_depth {
            if let Some((side, rhos, vs)) = delta.as_perturbation(&kernel).ok().flatten()
            {
                refreshed = self.refresh_epoch(&epoch, side, &rhos, &vs);
            }
        }
        let incremental = refreshed.is_some();
        let (sampler, marginal_diag) = match refreshed {
            Some(sampler) => {
                let diag = self.marginal_table(&sampler);
                (sampler, diag)
            }
            // Exact republish: same validated gauntlet as a full publish
            // of the post-delta kernel. A candidate the validator rejects
            // here (e.g. a perturbation that drove the kernel indefinite)
            // is quarantined and the tenant keeps serving untouched.
            None => self
                .validated_parts(&new_kernel)
                .map_err(|e| self.quarantine(&entry, e))?,
        };
        let new_depth = if incremental { depth + 1 } else { 0 };
        let new_gen = self.install_delta(
            &entry,
            generation,
            &new_kernel,
            Some((sampler, marginal_diag)),
            new_depth,
        )?;
        self.publishes.fetch_add(1, Ordering::Relaxed);
        self.delta_publishes.fetch_add(1, Ordering::Relaxed);
        entry.deltas.fetch_add(1, Ordering::Relaxed);
        if incremental {
            self.delta_incremental.fetch_add(1, Ordering::Relaxed);
            entry.delta_refreshes.fetch_add(1, Ordering::Relaxed);
        } else {
            self.delta_exact.fetch_add(1, Ordering::Relaxed);
        }
        self.enforce_budget(id);
        Ok(DeltaOutcome { generation: new_gen, incremental, depth: new_depth })
    }

    /// Try to absorb a rank-r perturbation of factor `side` into the
    /// epoch's cached eigendecomposition via the secular update. Returns
    /// `None` (→ exact fallback) when the updater refuses or the
    /// refreshed spectrum fails the serving sanity check.
    fn refresh_epoch(
        &self,
        epoch: &SamplerEpoch,
        side: usize,
        rhos: &[f64],
        vs: &Matrix,
    ) -> Option<Sampler> {
        let eigen = epoch.sampler.eigen();
        // The perturbed factor's spectrum and eigenvector matrix.
        let (values, vectors): (&[f64], &Matrix) = match (&eigen.vectors, side) {
            (EigenVectors::Dense(p), 0) => (eigen.values.as_slice(), p),
            (EigenVectors::Kron2 { p1, .. }, 0) => (eigen.factor_values.first()?.as_slice(), p1),
            (EigenVectors::Kron2 { p2, .. }, 1) => (eigen.factor_values.get(1)?.as_slice(), p2),
            (EigenVectors::Kron3 { p1, .. }, 0) => (eigen.factor_values.first()?.as_slice(), p1),
            (EigenVectors::Kron3 { p2, .. }, 1) => (eigen.factor_values.get(1)?.as_slice(), p2),
            (EigenVectors::Kron3 { p3, .. }, 2) => (eigen.factor_values.get(2)?.as_slice(), p3),
            _ => return None,
        };
        let refresh = |sc: &mut EigenUpdateScratch| -> Option<KernelEigen> {
            match eigen_update::refresh_into(values, vectors, rhos, vs, &self.delta_opts, sc)
            {
                UpdateOutcome::Applied { .. } => {
                    Some(Self::recombined_eigen(eigen, side, &sc.values, &sc.vectors))
                }
                UpdateOutcome::NeedExact { .. } => None,
            }
        };
        // Same try-lock-or-fresh discipline as the swap scratch: a
        // concurrent delta on another tenant builds with a fresh local
        // scratch instead of queueing behind this one's refresh.
        let new_eigen = match self.delta_scratch.try_lock() {
            Ok(mut sc) => refresh(&mut sc),
            Err(TryLockError::Poisoned(p)) => refresh(&mut p.into_inner()),
            Err(TryLockError::WouldBlock) => refresh(&mut EigenUpdateScratch::new()),
        }?;
        let sampler = Sampler::from_eigen(new_eigen);
        Self::validate_spectrum(&sampler).ok()?;
        Some(sampler)
    }

    /// Rebuild a [`KernelEigen`] with factor `side`'s eigenpairs replaced
    /// by the refreshed `(values, vectors)`, recombining the product
    /// eigenvalue grid from the per-factor spectra in `O(N)`.
    fn recombined_eigen(
        eigen: &KernelEigen,
        side: usize,
        values: &[f64],
        vectors: &Matrix,
    ) -> KernelEigen {
        match &eigen.vectors {
            EigenVectors::Dense(_) => KernelEigen {
                values: values.to_vec(),
                factor_values: Vec::new(),
                vectors: EigenVectors::Dense(vectors.clone()),
            },
            EigenVectors::Kron2 { p1, p2 } => {
                let mut fv = eigen.factor_values.clone();
                fv[side] = values.to_vec();
                let product = kron::kron_eigenvalues(&fv[0], &fv[1]);
                let (p1, p2) = if side == 0 {
                    (vectors.clone(), p2.clone())
                } else {
                    (p1.clone(), vectors.clone())
                };
                KernelEigen {
                    values: product,
                    factor_values: fv,
                    vectors: EigenVectors::Kron2 { p1, p2 },
                }
            }
            EigenVectors::Kron3 { p1, p2, p3 } => {
                let mut fv = eigen.factor_values.clone();
                fv[side] = values.to_vec();
                let inner = kron::kron_eigenvalues(&fv[1], &fv[2]);
                let product = kron::kron_eigenvalues(&fv[0], &inner);
                let mut ps = [p1.clone(), p2.clone(), p3.clone()];
                ps[side] = vectors.clone();
                let [p1, p2, p3] = ps;
                KernelEigen {
                    values: product,
                    factor_values: fv,
                    vectors: EigenVectors::Kron3 { p1, p2, p3 },
                }
            }
        }
    }

    /// [`KernelRegistry::install`] for the delta path: refuses if another
    /// publish landed since `expect` was snapshotted (the delta's exact
    /// apply and its perturbation lowering were both derived against that
    /// generation's kernel), records the post-install `delta_depth`, and
    /// installs kernel-only (`parts = None`) for an evicted tenant.
    fn install_delta(
        &self,
        entry: &TenantEntry,
        expect: u64,
        kernel: &Kernel,
        parts: Option<(Sampler, Arc<Vec<f64>>)>,
        depth: u64,
    ) -> Result<u64> {
        let mut slot = write_clean(&entry.slot);
        if slot.generation != expect {
            return Err(Error::Rejected(format!(
                "tenant '{}': generation advanced {} → {} while the delta was being \
                 absorbed; re-derive the delta against the current kernel",
                entry.name, expect, slot.generation
            )));
        }
        if self.max_history > 0 {
            let outgoing =
                EpochRecord { generation: slot.generation, kernel: slot.kernel.clone() };
            slot.history.push_back(outgoing);
            while slot.history.len() > self.max_history {
                slot.history.pop_front();
            }
        }
        slot.generation += 1;
        slot.kernel = kernel.clone();
        slot.n = kernel.n();
        slot.delta_depth = depth;
        slot.epoch = parts.map(|(sampler, marginal_diag)| {
            Arc::new(SamplerEpoch {
                tenant: entry.id,
                name: entry.name.clone(),
                generation: slot.generation,
                kernel: kernel.clone(),
                sampler,
                marginal_diag,
            })
        });
        Ok(slot.generation)
    }

    /// Pre-eigensolve candidate screen: the non-finite entry scan. Public
    /// so callers (and the publish-latency bench) can price the screen
    /// separately from the eigensolve it guards.
    pub fn validate_candidate(kernel: &Kernel) -> Result<()> {
        kernel.validate_finite()
    }

    /// Post-build sanity check on the freshly computed spectrum: every
    /// eigenvalue finite, none meaningfully negative (PSD up to
    /// `SPECTRUM_TOL` roundoff).
    fn validate_spectrum(sampler: &Sampler) -> Result<()> {
        let values = &sampler.eigen().values;
        let mut max = 0.0f64;
        for &v in values {
            if !v.is_finite() {
                return Err(Error::Numerical(format!(
                    "candidate spectrum contains non-finite eigenvalue {v}"
                )));
            }
            max = max.max(v.abs());
        }
        let floor = -SPECTRUM_TOL * max.max(1.0);
        if let Some(&lo) =
            values.iter().filter(|v| **v < floor).min_by(|a, b| a.total_cmp(b))
        {
            return Err(Error::Numerical(format!(
                "candidate spectrum is indefinite: eigenvalue {lo} < {floor:.3e}"
            )));
        }
        Ok(())
    }

    /// Candidate screen + epoch build + spectrum check, in order.
    fn validated_parts(&self, kernel: &Kernel) -> Result<(Sampler, Arc<Vec<f64>>)> {
        Self::validate_candidate(kernel)?;
        let parts = self.build_parts(kernel)?;
        Self::validate_spectrum(&parts.0)?;
        Ok(parts)
    }

    /// Record a quarantined candidate and hand the error back.
    fn quarantine(&self, entry: &TenantEntry, e: Error) -> Error {
        self.quarantines.fetch_add(1, Ordering::Relaxed);
        entry.record_quarantine(e.to_string());
        e
    }

    /// Swap a validated epoch in under the write lock, pushing the
    /// outgoing generation into the bounded rollback history.
    fn install(
        &self,
        entry: &TenantEntry,
        kernel: &Kernel,
        sampler: Sampler,
        marginal_diag: Arc<Vec<f64>>,
    ) -> u64 {
        let mut slot = write_clean(&entry.slot);
        if self.max_history > 0 {
            let outgoing =
                EpochRecord { generation: slot.generation, kernel: slot.kernel.clone() };
            slot.history.push_back(outgoing);
            while slot.history.len() > self.max_history {
                slot.history.pop_front();
            }
        }
        slot.generation += 1;
        slot.kernel = kernel.clone();
        slot.n = kernel.n();
        // A full publish installs an exactly-built spectrum: accumulated
        // incremental drift is gone.
        slot.delta_depth = 0;
        slot.epoch = Some(Arc::new(SamplerEpoch {
            tenant: entry.id,
            name: entry.name.clone(),
            generation: slot.generation,
            kernel: kernel.clone(),
            sampler,
            marginal_diag,
        }));
        slot.generation
    }

    /// Set a tenant's sampler-mode policy (admission-time capability
    /// mask). Cheap — an atomic store, no epoch rebuild.
    pub fn set_mode_policy(&self, id: TenantId, policy: ModePolicy) -> Result<()> {
        self.entry(id)?.set_mode_policy(policy);
        Ok(())
    }

    /// Number of tenants whose eigendecomposition is currently resident.
    pub fn resident_epochs(&self) -> usize {
        read_clean(&self.tenants)
            .list
            .iter()
            .filter(|e| read_clean(&e.slot).epoch.is_some())
            .count()
    }

    /// Epochs dropped by the LRU bound so far.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Lazy epoch rebuilds after eviction so far.
    pub fn rebuilds(&self) -> u64 {
        self.rebuilds.load(Ordering::Relaxed)
    }

    /// Epoch publications (tenant creations + kernel refreshes +
    /// rollbacks) so far.
    pub fn publishes(&self) -> u64 {
        self.publishes.load(Ordering::Relaxed)
    }

    /// Candidate publishes rejected by validation so far (all tenants).
    pub fn quarantines(&self) -> u64 {
        self.quarantines.load(Ordering::Relaxed)
    }

    /// Rollback installs so far (all tenants).
    pub fn rollbacks(&self) -> u64 {
        self.rollbacks.load(Ordering::Relaxed)
    }

    /// Delta publishes applied so far (all tenants, all paths).
    pub fn delta_publishes(&self) -> u64 {
        self.delta_publishes.load(Ordering::Relaxed)
    }

    /// Delta publishes absorbed by the incremental secular refresh.
    pub fn delta_incremental(&self) -> u64 {
        self.delta_incremental.load(Ordering::Relaxed)
    }

    /// Delta publishes that took an exact path instead (structural
    /// change, depth budget, updater refusal, or an evicted epoch).
    pub fn delta_exact(&self) -> u64 {
        self.delta_exact.load(Ordering::Relaxed)
    }

    /// Configured LRU bound (0 = unbounded).
    pub fn max_resident_epochs(&self) -> usize {
        self.max_resident
    }

    /// Configured per-tenant rollback history bound (0 = disabled).
    pub fn max_epoch_history(&self) -> usize {
        self.max_history
    }

    /// One-line registry gauge for reports: tenant count, resident
    /// epochs vs bound, eviction/rebuild/publication counters, and the
    /// fault-tolerance counters (quarantined candidates, rollbacks).
    pub fn report(&self) -> String {
        let bound = if self.max_resident == 0 {
            "∞".to_string()
        } else {
            self.max_resident.to_string()
        };
        format!(
            "tenants={} resident_epochs={}/{} evictions={} rebuilds={} publishes={} \
             quarantined={} rollbacks={} deltas={} delta_incremental={} delta_exact={}",
            self.len(),
            self.resident_epochs(),
            bound,
            self.evictions(),
            self.rebuilds(),
            self.publishes(),
            self.quarantines(),
            self.rollbacks(),
            self.delta_publishes(),
            self.delta_incremental(),
            self.delta_exact(),
        )
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Eigendecompose `kernel` and derive the epoch's factored
    /// marginal-diagonal table, preferably through the shared swap
    /// scratch. This is the only heavy step of a publish/rebuild, and it
    /// holds no lock any reader ever takes. The scratch is an allocation
    /// optimization, not a serialization point: if another publish or
    /// rebuild holds it (its eigendecomposition can run for a while), we
    /// build with a fresh local scratch instead of queueing this tenant
    /// behind that tenant's work — so a cold tenant's lazy rebuild never
    /// waits on an unrelated tenant's publish.
    fn build_parts(&self, kernel: &Kernel) -> Result<(Sampler, Arc<Vec<f64>>)> {
        // Like `lock_clean`, a scratch poisoned by a panicking builder is
        // recovered rather than abandoned — scratches carry no cross-call
        // invariants (every build fully overwrites what it reads).
        let sampler = match self.swap_scratch.try_lock() {
            Ok(mut scratch) => Sampler::new_with_scratch(kernel, &mut scratch),
            Err(TryLockError::Poisoned(p)) => {
                Sampler::new_with_scratch(kernel, &mut p.into_inner())
            }
            Err(TryLockError::WouldBlock) => {
                Sampler::new_with_scratch(kernel, &mut SampleScratch::new())
            }
        }?;
        Ok((sampler, self.marginal_table(&sampler)))
    }

    /// O(N·(N₁+N₂)) factored marginal diagonal for a freshly built
    /// sampler — cheap next to the eigendecomposition (or secular
    /// refresh) it rides on, cached for the epoch's lifetime and built
    /// through the reused writer-side scratch.
    fn marginal_table(&self, sampler: &Sampler) -> Arc<Vec<f64>> {
        let mut diag = Vec::new();
        match self.marginal_scratch.try_lock() {
            Ok(mut scratch) => {
                sampler.eigen().inclusion_probabilities_into(&mut diag, &mut scratch)
            }
            Err(TryLockError::Poisoned(p)) => sampler
                .eigen()
                .inclusion_probabilities_into(&mut diag, &mut p.into_inner()),
            Err(TryLockError::WouldBlock) => sampler
                .eigen()
                .inclusion_probabilities_into(&mut diag, &mut MarginalScratch::new()),
        }
        Arc::new(diag)
    }

    /// Evict least-recently-touched epochs until the resident count is
    /// within `max_resident`, never evicting `keep` (the tenant that was
    /// just touched). Eviction only drops the registry's `Arc`; in-flight
    /// draws keep their epoch alive until they finish.
    fn enforce_budget(&self, keep: TenantId) {
        if self.max_resident == 0 {
            return;
        }
        loop {
            let entries: Vec<Arc<TenantEntry>> = read_clean(&self.tenants).list.clone();
            let mut resident: Vec<(u64, usize)> = entries
                .iter()
                .enumerate()
                .filter(|(_, e)| read_clean(&e.slot).epoch.is_some())
                .map(|(i, e)| (e.last_touch.load(Ordering::Relaxed), i))
                .collect();
            if resident.len() <= self.max_resident {
                return;
            }
            resident.sort_unstable();
            let Some(victim) = resident
                .iter()
                .map(|&(_, i)| i)
                .find(|&i| entries[i].id != keep)
            else {
                return;
            };
            let dropped = write_clean(&entries[victim].slot).epoch.take();
            if dropped.is_some() {
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::rng::Rng;

    fn test_kernel(n1: usize, n2: usize, seed: u64) -> Kernel {
        let mut rng = Rng::new(seed);
        let mk = |n: usize, rng: &mut Rng| -> Matrix {
            let mut m = rng.paper_init_kernel(n);
            m.scale_mut(1.0 / n as f64);
            m.add_diag_mut(0.3);
            m
        };
        Kernel::Kron2(mk(n1, &mut rng), mk(n2, &mut rng))
    }

    #[test]
    fn create_resolve_acquire_roundtrip() {
        let reg = KernelRegistry::new(0);
        let a = reg.add_tenant("market-eu", &test_kernel(3, 4, 1)).unwrap();
        let b = reg.add_tenant("market-us", &test_kernel(2, 3, 2)).unwrap();
        assert_eq!(a, TenantId::DEFAULT);
        assert_ne!(a, b);
        assert_eq!(reg.resolve("market-eu"), Some(a));
        assert_eq!(reg.resolve("market-us"), Some(b));
        assert_eq!(reg.resolve("nope"), None);
        assert_eq!(reg.tenant_names(), vec!["market-eu".to_string(), "market-us".into()]);
        let ea = reg.acquire(a).unwrap();
        assert_eq!(ea.generation, 1);
        assert_eq!(ea.name, "market-eu");
        assert_eq!(ea.sampler.n(), 12);
        let eb = reg.acquire(b).unwrap();
        assert_eq!(eb.sampler.n(), 6);
        // Same generation → same Arc (no rebuild on a warm acquire).
        assert!(Arc::ptr_eq(&ea, &reg.acquire(a).unwrap()));
        assert_eq!(reg.resident_epochs(), 2);
        assert_eq!(reg.rebuilds(), 0);
    }

    #[test]
    fn duplicate_tenant_rejected() {
        let reg = KernelRegistry::new(0);
        reg.add_tenant("t", &test_kernel(2, 2, 3)).unwrap();
        assert!(reg.add_tenant("t", &test_kernel(2, 2, 4)).is_err());
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn unknown_tenant_is_rejected_error() {
        let reg = KernelRegistry::new(0);
        match reg.acquire(TenantId(9)) {
            Err(Error::Rejected(_)) => {}
            Err(other) => panic!("expected Rejected, got {other:?}"),
            Ok(_) => panic!("expected Rejected, got an epoch"),
        }
    }

    #[test]
    fn publish_bumps_generation_and_old_epoch_survives() {
        let reg = KernelRegistry::new(0);
        let t = reg.add_tenant("t", &test_kernel(2, 2, 5)).unwrap();
        let old = reg.acquire(t).unwrap();
        assert_eq!((old.generation, old.sampler.n()), (1, 4));
        let g = reg.publish(t, &test_kernel(3, 4, 6)).unwrap();
        assert_eq!(g, 2);
        let new = reg.acquire(t).unwrap();
        assert_eq!((new.generation, new.sampler.n()), (2, 12));
        // The held pre-swap epoch still draws from the old kernel.
        let mut rng = Rng::new(7);
        let y = old.sampler.sample_k(2, &mut rng);
        assert!(y.iter().all(|&i| i < 4));
        let entry = reg.entry(t).unwrap();
        assert_eq!(entry.generation(), 2);
        assert_eq!(entry.n(), 12);
    }

    #[test]
    fn lru_evicts_cold_tenant_and_lazily_rebuilds() {
        let reg = KernelRegistry::new(1);
        let a = reg.add_tenant("a", &test_kernel(2, 2, 8)).unwrap();
        let b = reg.add_tenant("b", &test_kernel(2, 3, 9)).unwrap();
        // Creating b evicted a (bound 1, a least-recently-touched).
        assert_eq!(reg.resident_epochs(), 1);
        assert_eq!(reg.evictions(), 1);
        assert!(!reg.entry(a).unwrap().resident());
        assert!(reg.entry(b).unwrap().resident());
        // Touching a rebuilds it lazily and evicts b.
        let ea = reg.acquire(a).unwrap();
        assert_eq!(ea.generation, 1, "rebuild must not change the generation");
        assert_eq!(ea.sampler.n(), 4);
        assert_eq!(reg.rebuilds(), 1);
        assert_eq!(reg.resident_epochs(), 1);
        assert_eq!(reg.evictions(), 2);
        assert!(!reg.entry(b).unwrap().resident());
        // Round-trip: b comes back too, and draws stay valid.
        let eb = reg.acquire(b).unwrap();
        let mut rng = Rng::new(11);
        assert!(eb.sampler.sample_k(2, &mut rng).iter().all(|&i| i < 6));
        assert_eq!(reg.rebuilds(), 2);
        assert!(reg.report().contains("evictions=3"));
    }

    #[test]
    fn epoch_caches_kernel_and_factored_marginal_table() {
        let reg = KernelRegistry::new(0);
        let kernel = test_kernel(3, 4, 12);
        let t = reg.add_tenant("t", &kernel).unwrap();
        let epoch = reg.acquire(t).unwrap();
        assert_eq!(epoch.kernel.n(), 12);
        // The cached table is the factored diagonal of the epoch's kernel.
        let want = kernel.eigen().unwrap().inclusion_probabilities();
        assert_eq!(epoch.inclusion_probabilities().len(), 12);
        for (a, b) in epoch.inclusion_probabilities().iter().zip(&want) {
            assert!((a - b).abs() < 1e-14);
            assert!((0.0..=1.0).contains(a));
        }
        // A publish refreshes both kernel and table atomically.
        let next = test_kernel(2, 3, 13);
        reg.publish(t, &next).unwrap();
        let epoch2 = reg.acquire(t).unwrap();
        assert_eq!(epoch2.kernel.n(), 6);
        let want2 = next.eigen().unwrap().inclusion_probabilities();
        for (a, b) in epoch2.inclusion_probabilities().iter().zip(&want2) {
            assert!((a - b).abs() < 1e-14);
        }
        // The held pre-publish epoch keeps its own kernel and table.
        assert_eq!(epoch.kernel.n(), 12);
    }

    #[test]
    fn mode_policy_defaults_open_and_swaps_atomically() {
        let reg = KernelRegistry::new(0);
        let t = reg.add_tenant("t", &test_kernel(2, 2, 90)).unwrap();
        let entry = reg.entry(t).unwrap();
        for mode in [
            SampleMode::Exact,
            SampleMode::Mcmc { steps: 10 },
            SampleMode::LowRank { rank: 2 },
            SampleMode::Map,
        ] {
            assert!(entry.mode_policy().allows(mode), "default denies {mode:?}");
        }
        reg.set_mode_policy(t, ModePolicy::exact_only()).unwrap();
        assert!(entry.mode_policy().allows(SampleMode::Exact));
        assert!(!entry.mode_policy().allows(SampleMode::Mcmc { steps: 10 }));
        assert!(!entry.mode_policy().allows(SampleMode::Map));
        // Family-level mask: parameters don't matter.
        let p = ModePolicy::exact_only().with(SampleMode::LowRank { rank: 1 });
        assert!(p.allows(SampleMode::LowRank { rank: 64 }));
        assert!(!p.without(SampleMode::Exact).allows(SampleMode::Exact));
        assert!(reg.set_mode_policy(TenantId(7), ModePolicy::allow_all()).is_err());
    }

    #[test]
    fn unbounded_registry_never_evicts() {
        let reg = KernelRegistry::new(0);
        for i in 0..6u64 {
            reg.add_tenant(&format!("t{i}"), &test_kernel(2, 2, 20 + i)).unwrap();
        }
        assert_eq!(reg.resident_epochs(), 6);
        assert_eq!(reg.evictions(), 0);
    }

    #[test]
    fn epoch_draws_are_tenant_count_and_thread_invariant() {
        // The engine's one-RNG-stream-per-draw guarantee must survive the
        // registry: the same kernel served as the only tenant, or as one
        // of many (with eviction + lazy rebuild in between), draws
        // identical batches for the same seed — on any thread count.
        let kernel = test_kernel(3, 4, 70);
        let solo = KernelRegistry::new(0);
        let t = solo.add_tenant("only", &kernel).unwrap();
        let crowded = KernelRegistry::new(2);
        for i in 0..4u64 {
            crowded.add_tenant(&format!("noise-{i}"), &test_kernel(2, 2, 80 + i)).unwrap();
        }
        let u = crowded.add_tenant("same", &kernel).unwrap();
        // Touch the noise tenants so "same" gets evicted and must rebuild.
        for i in 0..2u64 {
            crowded.acquire(crowded.resolve(&format!("noise-{i}")).unwrap()).unwrap();
        }
        let a = solo.acquire(t).unwrap().sampler.sample_batch(16, Some(3), 9);
        let b = crowded.acquire(u).unwrap().sampler.sample_batch(16, Some(3), 9);
        assert_eq!(a, b, "tenant count changed draws");
        let c = crowded.acquire(u).unwrap().sampler.sample_batch_threads(16, Some(3), 9, 1);
        assert_eq!(a, c, "thread count changed draws");
    }

    #[test]
    fn concurrent_acquire_and_publish_smoke() {
        let reg = Arc::new(KernelRegistry::new(1));
        let a = reg.add_tenant("a", &test_kernel(3, 3, 30)).unwrap();
        let b = reg.add_tenant("b", &test_kernel(3, 3, 31)).unwrap();
        let mut handles = Vec::new();
        for (t, seed) in [(a, 40u64), (b, 41)] {
            for r in 0..2u64 {
                let reg2 = Arc::clone(&reg);
                handles.push(std::thread::spawn(move || {
                    let mut rng = Rng::new(seed * 10 + r);
                    for _ in 0..40 {
                        let epoch = reg2.acquire(t).unwrap();
                        let y = epoch.sampler.sample_k(3, &mut rng);
                        assert_eq!(y.len(), 3);
                        assert!(y.iter().all(|&i| i < 9));
                    }
                }));
            }
        }
        {
            let reg2 = Arc::clone(&reg);
            handles.push(std::thread::spawn(move || {
                for s in 0..8u64 {
                    reg2.publish(a, &test_kernel(3, 3, 50 + s)).unwrap();
                    reg2.publish(b, &test_kernel(3, 3, 60 + s)).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(reg.entry(a).unwrap().generation(), 9);
        assert_eq!(reg.entry(b).unwrap().generation(), 9);
        // With bound 1 and two hot tenants, evictions + rebuilds happened.
        assert!(reg.evictions() > 0);
        assert!(reg.resident_epochs() <= 1);
    }

    /// A factored kernel with a poisoned entry in one factor.
    fn poisoned_kernel() -> Kernel {
        let mut k = test_kernel(2, 3, 100);
        if let Kernel::Kron2(_, b) = &mut k {
            b.set(1, 2, f64::NAN);
        }
        k
    }

    /// Finite everywhere but genuinely indefinite: one factor is a swap
    /// matrix with eigenvalues ±1, so the Kronecker spectrum has negative
    /// entries far below the roundoff floor.
    fn indefinite_kernel() -> Kernel {
        let swap = Matrix::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]).unwrap();
        let mut psd = Matrix::from_fn(2, 2, |i, j| if i == j { 1.0 } else { 0.2 });
        psd.add_diag_mut(0.1);
        Kernel::Kron2(swap, psd)
    }

    #[test]
    fn poisoned_publish_is_quarantined_and_tenant_keeps_serving() {
        let reg = KernelRegistry::new(0);
        let t = reg.add_tenant("t", &test_kernel(2, 2, 101)).unwrap();
        let entry = reg.entry(t).unwrap();
        let before = reg.acquire(t).unwrap();

        let err = reg.publish(t, &poisoned_kernel()).unwrap_err();
        assert!(matches!(err, Error::Invalid(_)), "got {err:?}");
        assert!(err.to_string().contains("non-finite"), "{err}");

        // The tenant is untouched: same generation, same epoch, serving.
        assert_eq!(entry.generation(), 1);
        assert!(Arc::ptr_eq(&before, &reg.acquire(t).unwrap()));
        assert_eq!(entry.quarantined_candidates(), 1);
        assert!(entry.last_quarantine().unwrap().contains("non-finite"));
        assert_eq!(reg.quarantines(), 1);
        // Quarantine is not a publication.
        assert_eq!(reg.publishes(), 1);

        // A later good publish clears the serving path (reason is kept as
        // a tombstone of the last rejection).
        assert_eq!(reg.publish(t, &test_kernel(3, 2, 102)).unwrap(), 2);
        assert_eq!(entry.generation(), 2);
    }

    #[test]
    fn indefinite_spectrum_is_quarantined() {
        let reg = KernelRegistry::new(0);
        let t = reg.add_tenant("t", &test_kernel(2, 2, 103)).unwrap();
        let err = reg.publish(t, &indefinite_kernel()).unwrap_err();
        assert!(matches!(err, Error::Numerical(_)), "got {err:?}");
        assert!(err.to_string().contains("indefinite"), "{err}");
        assert_eq!(reg.entry(t).unwrap().generation(), 1);
        assert_eq!(reg.quarantines(), 1);
        // An indefinite *initial* kernel can't become a tenant either.
        assert!(reg.add_tenant("bad", &indefinite_kernel()).is_err());
        assert!(reg.resolve("bad").is_none());
    }

    #[test]
    fn rollback_restores_prior_generation_as_new_generation() {
        let reg = KernelRegistry::new(0);
        let k1 = test_kernel(2, 2, 110); // n = 4
        let k2 = test_kernel(3, 2, 111); // n = 6
        let k3 = test_kernel(3, 4, 112); // n = 12
        let t = reg.add_tenant("t", &k1).unwrap();
        reg.publish(t, &k2).unwrap();
        reg.publish(t, &k3).unwrap();
        let entry = reg.entry(t).unwrap();
        assert_eq!(entry.rollback_generations(), vec![1, 2]);

        // Restore generation 1: installed as generation 4, old n back.
        let g = reg.rollback(t, 1).unwrap();
        assert_eq!(g, 4);
        let epoch = reg.acquire(t).unwrap();
        assert_eq!((epoch.generation, epoch.kernel.n()), (4, 4));
        assert_eq!(reg.rollbacks(), 1);
        // A rollback is also a publish, and pushes the pre-rollback
        // generation (3) into history — so the rollback can be rolled back.
        assert_eq!(reg.publishes(), 4);
        assert_eq!(entry.rollback_generations(), vec![1, 2, 3]);
        let g = reg.rollback(t, 3).unwrap();
        assert_eq!(g, 5);
        assert_eq!(reg.acquire(t).unwrap().kernel.n(), 12);

        // Current and unknown generations are rejected.
        let err = reg.rollback(t, 5).unwrap_err();
        assert!(err.to_string().contains("already current"), "{err}");
        let err = reg.rollback(t, 99).unwrap_err();
        assert!(err.to_string().contains("not in rollback history"), "{err}");
    }

    #[test]
    fn rollback_history_is_bounded_and_can_be_disabled() {
        let reg = KernelRegistry::with_history(0, 2);
        let t = reg.add_tenant("t", &test_kernel(2, 2, 120)).unwrap();
        for s in 0..4u64 {
            reg.publish(t, &test_kernel(2, 2, 121 + s)).unwrap();
        }
        // Generations 1..=5 existed; only the two newest outgoing remain.
        assert_eq!(reg.entry(t).unwrap().rollback_generations(), vec![3, 4]);
        assert!(reg.rollback(t, 1).is_err());
        assert_eq!(reg.max_epoch_history(), 2);

        let none = KernelRegistry::with_history(0, 0);
        let t = none.add_tenant("t", &test_kernel(2, 2, 130)).unwrap();
        none.publish(t, &test_kernel(2, 2, 131)).unwrap();
        assert!(none.entry(t).unwrap().rollback_generations().is_empty());
        assert!(none.rollback(t, 1).is_err());
    }

    #[test]
    fn breaker_trips_after_consecutive_failures_and_probe_recovers() {
        let reg = KernelRegistry::new(0);
        let t = reg.add_tenant("t", &test_kernel(2, 2, 140)).unwrap();
        let e = reg.entry(t).unwrap();
        assert_eq!(e.breaker_state(), "closed");

        // Two failures then a success: consecutive count resets, no trip.
        assert!(!e.breaker_record_failure(3));
        assert!(!e.breaker_record_failure(3));
        e.breaker_record_success();
        assert!(!e.breaker_is_open());
        assert_eq!(e.breaker_trips(), 0);

        // Three consecutive failures trip it exactly once.
        assert!(!e.breaker_record_failure(3));
        assert!(!e.breaker_record_failure(3));
        assert!(e.breaker_record_failure(3));
        assert!(!e.breaker_record_failure(3), "re-tripping an open breaker");
        assert_eq!((e.breaker_state(), e.breaker_trips()), ("open", 1));

        // Every 2nd serve while open is a half-open probe.
        assert!(!e.breaker_probe_due(2));
        assert!(e.breaker_probe_due(2));
        // Probe succeeded: breaker closes, recovery counted.
        e.breaker_record_success();
        assert_eq!((e.breaker_state(), e.breaker_recoveries()), ("closed", 1));

        // threshold 0 disables tripping entirely.
        for _ in 0..10 {
            assert!(!e.breaker_record_failure(0));
        }
        assert!(!e.breaker_is_open());
        e.breaker_record_success();
    }

    #[test]
    fn forced_degradation_pins_the_breaker_open() {
        let reg = KernelRegistry::new(0);
        let t = reg.add_tenant("t", &test_kernel(2, 2, 150)).unwrap();
        let e = reg.entry(t).unwrap();
        e.force_degraded(true);
        assert_eq!(e.breaker_state(), "forced");
        assert!(e.breaker_is_open());
        // Forced mode never probes and never auto-recovers.
        for _ in 0..8 {
            assert!(!e.breaker_probe_due(2));
        }
        e.breaker_record_success();
        assert!(e.breaker_is_open(), "success must not release a forced breaker");
        assert_eq!(e.breaker_recoveries(), 0);
        e.force_degraded(false);
        assert_eq!(e.breaker_state(), "closed");
    }

    // --- delta publishing ------------------------------------------------

    /// A small rank-r perturbation of factor `side` (of size `n`), scaled
    /// so the perturbed kernel stays comfortably PD.
    fn perturb_delta(side: usize, n: usize, rank: usize, seed: u64, scale: f64) -> KernelDelta {
        let mut rng = Rng::new(seed);
        let vectors = rng.uniform_matrix(n, rank, -scale, scale);
        let rhos = (0..rank).map(|k| if k % 2 == 0 { 1.0 } else { -0.5 }).collect();
        KernelDelta::Perturb { side, rhos, vectors }
    }

    fn assert_factors_bitwise_eq(got: &Kernel, want: &Kernel) {
        match (got, want) {
            (Kernel::Kron2(a1, b1), Kernel::Kron2(a2, b2)) => {
                assert_eq!(a1.as_slice(), a2.as_slice());
                assert_eq!(b1.as_slice(), b2.as_slice());
            }
            _ => panic!("kernel structure changed"),
        }
    }

    #[test]
    fn delta_publish_refreshes_incrementally_and_tracks_exact_recompute() {
        let reg = KernelRegistry::new(0);
        let k = test_kernel(8, 5, 200);
        let t = reg.add_tenant("t", &k).unwrap();
        let delta = perturb_delta(0, 8, 2, 201, 0.05);
        let out = reg.publish_delta(t, &delta).unwrap();
        assert_eq!(out, DeltaOutcome { generation: 2, incremental: true, depth: 1 });

        // The installed epoch's kernel is the *exact* post-delta kernel
        // (deltas never let the serving kernel drift, only its cached
        // spectrum within tolerance).
        let want_kernel = delta.apply(&k).unwrap();
        let epoch = reg.acquire(t).unwrap();
        assert_eq!(epoch.generation, 2);
        assert_factors_bitwise_eq(&epoch.kernel, &want_kernel);

        // Spectrum and marginals agree with a full recompute within the
        // documented drift tolerance (per-pass gate 1e-9; one pass here
        // typically lands near 1e-12).
        let exact = want_kernel.eigen().unwrap();
        for (a, b) in epoch.sampler.eigen().values.iter().zip(&exact.values) {
            assert!((a - b).abs() <= 1e-9 * b.abs().max(1.0), "{a} vs {b}");
        }
        let want = exact.inclusion_probabilities();
        assert_eq!(epoch.inclusion_probabilities().len(), want.len());
        for (a, b) in epoch.inclusion_probabilities().iter().zip(&want) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }

        let entry = reg.entry(t).unwrap();
        assert_eq!((entry.deltas_published(), entry.delta_refreshes()), (1, 1));
        assert_eq!(entry.delta_depth(), 1);
        assert_eq!((reg.delta_publishes(), reg.delta_incremental(), reg.delta_exact()), (1, 1, 0));
        assert!(reg.report().contains("deltas=1 delta_incremental=1 delta_exact=0"));
        // A delta publish is a publication: rollback to gen 1 works.
        assert_eq!(entry.rollback_generations(), vec![1]);
        reg.rollback(t, 1).unwrap();
        assert_factors_bitwise_eq(&reg.acquire(t).unwrap().kernel, &k);
    }

    #[test]
    fn depth_budget_forces_exact_republish_restoring_bitwise_agreement() {
        let mut reg = KernelRegistry::new(0);
        reg.set_max_delta_depth(2);
        assert_eq!(reg.max_delta_depth(), 2);
        let k = test_kernel(5, 4, 210);
        let t = reg.add_tenant("t", &k).unwrap();
        let mut cur = k;
        for step in 0..3u64 {
            let side = (step % 2) as usize;
            let delta = perturb_delta(side, if side == 0 { 5 } else { 4 }, 1, 211 + step, 0.03);
            let out = reg.publish_delta(t, &delta).unwrap();
            cur = delta.apply(&cur).unwrap();
            assert_eq!(out.generation, 2 + step);
            if step < 2 {
                assert!(out.incremental, "step {step} should refresh in place");
                assert_eq!(out.depth, step + 1);
            } else {
                assert!(!out.incremental, "depth budget must force an exact republish");
                assert_eq!(out.depth, 0);
            }
        }
        // The forced republish eigendecomposed the accumulated kernel
        // exactly: **bitwise** agreement with an independent full build,
        // no residual incremental drift.
        let epoch = reg.acquire(t).unwrap();
        let exact = cur.eigen().unwrap();
        assert_eq!(epoch.sampler.eigen().values, exact.values);
        assert_eq!((reg.delta_incremental(), reg.delta_exact()), (2, 1));
        assert_eq!(reg.entry(t).unwrap().delta_depth(), 0);
        // A full publish also resets the depth.
        reg.publish_delta(t, &perturb_delta(0, 5, 1, 219, 0.03)).unwrap();
        assert_eq!(reg.entry(t).unwrap().delta_depth(), 1);
        reg.publish(t, &test_kernel(5, 4, 218)).unwrap();
        assert_eq!(reg.entry(t).unwrap().delta_depth(), 0);
    }

    #[test]
    fn deltas_to_evicted_tenants_collapse_on_lazy_rebuild() {
        let reg = KernelRegistry::new(1);
        let ka = test_kernel(3, 4, 220);
        let a = reg.add_tenant("a", &ka).unwrap();
        reg.add_tenant("b", &test_kernel(2, 2, 221)).unwrap();
        assert!(!reg.entry(a).unwrap().resident(), "bound 1: creating b evicted a");

        // Two deltas land while a is cold: kernel-only installs, no
        // eigenwork, epoch stays evicted.
        let d1 = perturb_delta(0, 3, 1, 222, 0.05);
        let out = reg.publish_delta(a, &d1).unwrap();
        assert_eq!(out, DeltaOutcome { generation: 2, incremental: false, depth: 0 });
        let k1 = d1.apply(&ka).unwrap();
        let d2 = KernelDelta::RetireItem { side: 1, index: 2, damping: 0.5 };
        let out = reg.publish_delta(a, &d2).unwrap();
        assert_eq!((out.generation, out.incremental), (3, false));
        let k2 = d2.apply(&k1).unwrap();
        assert!(!reg.entry(a).unwrap().resident(), "cold deltas must not resurrect the epoch");
        let rebuilds = reg.rebuilds();

        // One lazy rebuild collapses both pending deltas exactly.
        let epoch = reg.acquire(a).unwrap();
        assert_eq!(epoch.generation, 3);
        assert_eq!(reg.rebuilds(), rebuilds + 1);
        assert_factors_bitwise_eq(&epoch.kernel, &k2);
        let exact = k2.eigen().unwrap();
        assert_eq!(epoch.sampler.eigen().values, exact.values);
        assert_eq!(reg.entry(a).unwrap().delta_depth(), 0);
        assert_eq!((reg.delta_publishes(), reg.delta_incremental(), reg.delta_exact()), (2, 0, 2));
    }

    #[test]
    fn poisoned_and_indefinite_deltas_are_quarantined_epoch_unchanged() {
        let reg = KernelRegistry::new(0);
        let t = reg.add_tenant("t", &test_kernel(3, 3, 230)).unwrap();
        let entry = reg.entry(t).unwrap();
        let before = reg.acquire(t).unwrap();

        // Non-finite perturbation vector → rejected by the delta screen.
        let mut vs = Matrix::from_fn(3, 1, |_, _| 0.1);
        vs.set(1, 0, f64::NAN);
        let bad = KernelDelta::Perturb { side: 0, rhos: vec![1.0], vectors: vs };
        let err = reg.publish_delta(t, &bad).unwrap_err();
        assert!(matches!(err, Error::Invalid(_)), "got {err:?}");
        assert_eq!(entry.quarantined_candidates(), 1);
        assert!(entry.last_quarantine().is_some());

        // A perturbation that drives the kernel indefinite: the secular
        // refresh refuses (or fails the spectrum check), and the exact
        // fallback's validated rebuild quarantines the candidate.
        let dir = Matrix::from_fn(3, 1, |i, _| if i == 0 { 1.0 } else { 0.2 });
        let bad2 = KernelDelta::Perturb { side: 0, rhos: vec![-100.0], vectors: dir };
        let err = reg.publish_delta(t, &bad2).unwrap_err();
        assert!(matches!(err, Error::Numerical(_)), "got {err:?}");
        assert!(err.to_string().contains("indefinite"), "{err}");
        assert_eq!(entry.quarantined_candidates(), 2);

        // The tenant is untouched: same generation, same epoch Arc, no
        // delta counted as published.
        assert_eq!(entry.generation(), 1);
        assert!(Arc::ptr_eq(&before, &reg.acquire(t).unwrap()));
        assert_eq!((entry.deltas_published(), reg.delta_publishes()), (0, 0));
        assert_eq!(reg.quarantines(), 2);
    }

    #[test]
    fn structural_deltas_resize_and_retire_absorbs_incrementally() {
        let reg = KernelRegistry::new(0);
        let t = reg.add_tenant("t", &test_kernel(2, 8, 240)).unwrap();
        assert_eq!(reg.acquire(t).unwrap().sampler.n(), 16);

        // Add an item to factor 1: N = 2·9 = 18. Structural → exact.
        let mut rng = Rng::new(241);
        let row: Vec<f64> = (0..8).map(|_| rng.uniform_range(-0.02, 0.02)).collect();
        let add = KernelDelta::AddItem { side: 1, row, diag: 0.9 };
        let out = reg.publish_delta(t, &add).unwrap();
        assert!(!out.incremental);
        let epoch = reg.acquire(t).unwrap();
        assert_eq!((epoch.sampler.n(), epoch.inclusion_probabilities().len()), (18, 18));
        assert_eq!(reg.entry(t).unwrap().n(), 18);

        // Retiring an item is a rank-2 perturbation → incremental.
        let retire = KernelDelta::RetireItem { side: 1, index: 1, damping: 0.3 };
        let out = reg.publish_delta(t, &retire).unwrap();
        assert!(out.incremental, "retire should lower to a rank-2 refresh");
        assert_eq!(out.depth, 1);

        // Removing the added item restores N = 16; exact, depth resets.
        let rm = KernelDelta::RemoveItem { side: 1, index: 8 };
        let out = reg.publish_delta(t, &rm).unwrap();
        assert!(!out.incremental);
        assert_eq!((out.depth, reg.acquire(t).unwrap().sampler.n()), (0, 16));
        assert_eq!(reg.delta_publishes(), 3);
        assert!(reg.report().contains("deltas=3 delta_incremental=1 delta_exact=2"));
    }
}
