//! The serving coordinator: a multi-tenant diverse-subset sampling service.
//!
//! This is the production face of KronDPP (the paper's motivating
//! recommender application): clients submit "give me k diverse items from
//! catalog T" requests — optionally constrained ("the user already picked
//! items A, never show items B": a [`Constraint`] rides on the
//! [`SampleRequest`]); the service validates them at admission
//! ([`DppService::submit`] fails fast on unknown tenants, oversized `k`
//! and unsatisfiable constraints), batches them ([`super::batcher`]),
//! routes each tenant-group to the least-loaded worker
//! ([`super::router`]), and each worker draws exact DPP/k-DPP samples
//! from the tenant's current [`super::registry::SamplerEpoch`] — an
//! `Arc`-published kernel + cached eigendecomposition + factored
//! marginal-diagonal table grabbed from the [`KernelRegistry`] without
//! ever blocking on writers. Each request also carries a [`SampleMode`]
//! — the fidelity knob of the sampler zoo ([`crate::dpp::backend`]):
//! exact spectral draws, MCMC chains, low-rank spectral projection, or a
//! deterministic greedy MAP slate ([`crate::dpp::map`]). Admission
//! checks the mode against the tenant's [`ModePolicy`] and the mode's
//! parameters against the ground set; workers coalesce by
//! `(tenant, k, constraint, mode)` so repeated slate contexts share one
//! conditioning setup ([`crate::dpp::ConditionedSampler`], built through
//! per-worker [`ConditionScratch`]es), one MCMC/low-rank backend build,
//! or one greedy MAP slate. Learning jobs ([`super::jobs`])
//! hot-swap refreshed kernels into their target tenant while requests
//! keep flowing: in-flight draws finish on the epoch they started with.
//!
//! Threading: one pump thread runs the batch policy and splits each batch
//! by tenant; `workers` threads consume per-worker channels; requests
//! carry a oneshot-style mpsc response channel. Backpressure is a hard
//! queue-capacity bound — beyond it, `submit` fails fast instead of
//! growing latency unboundedly. Within a dispatched tenant-group, workers
//! coalesce same-`k` jobs so one per-tenant elementary-DP table serves the
//! whole group; the engine's one-RNG-stream-per-draw guarantee
//! ([`crate::dpp::Sampler::sample_batch`]) is untouched by tenant count.
//!
//! **Fault tolerance.** Requests carry optional deadlines
//! ([`SampleRequest::with_deadline`]/[`SampleRequest::with_budget`]):
//! an already-expired request is fast-rejected at admission without
//! burning a queue slot, and workers re-check before the expensive
//! per-delivery epoch acquire and per-group conditioning setup, failing
//! expired jobs with the distinct [`Error::Deadline`] class
//! (`deadline_exceeded` in the metrics). A per-tenant **circuit breaker**
//! counts consecutive `Numerical` failures of the primary exact path;
//! once tripped (threshold in [`FallbackPolicy`]), exact-mode groups are
//! served through the **fallback chain** — jittered regularization
//! (`L + εI` rebuild), then backend downgrades (low-rank / MCMC over the
//! existing epoch) — with half-open probes retrying the primary path
//! every `probe_every` serves. Each worker wraps every coalesced group in
//! `catch_unwind`: a panicking job fails only its own group, the worker's
//! scratches are replaced wholesale, and a **supervisor** thread respawns
//! the worker (the job channel survives the handover, so queued
//! deliveries are never lost). Test/`fault-injection` builds thread a
//! deterministic [`crate::coordinator::faults::FaultPlan`] through these
//! seams.

use crate::config::{AdmissionPolicy, FallbackPolicy, ServiceConfig};
use crate::coordinator::batcher::{coalesce_by_key, BatchPolicy, BatchQueue, Pending};
use crate::coordinator::lock_clean;
use crate::coordinator::metrics::ServiceMetrics;
use crate::coordinator::registry::{
    DeltaOutcome, KernelRegistry, ModePolicy, TenantEntry, TenantId,
};
use crate::coordinator::router::WorkerLoad;
use crate::dpp::map::{map_slate_into, MapScratch};
use crate::dpp::{
    ConditionScratch, ConditionedSampler, Constraint, Kernel, KernelDelta, LowRankBackend,
    McmcBackend, SampleMode, SampleScratch, Sampler, SamplerBackend,
};
use crate::error::{Error, ErrorKind, Result};
use crate::rng::Rng;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

#[cfg(any(test, feature = "fault-injection"))]
use crate::coordinator::faults::FaultPlan;

/// The fault-injection seam carried by [`Shared`]: a deterministic
/// [`FaultPlan`] in test/`fault-injection` builds, a zero-sized unit in
/// production builds (no branch, no memory).
#[cfg(any(test, feature = "fault-injection"))]
type FaultSeam = Option<Arc<FaultPlan>>;
#[cfg(not(any(test, feature = "fault-injection")))]
type FaultSeam = ();

/// One sampling request against a tenant: `k = 0` draws an unconstrained
/// DPP sample, `k > 0` a k-DPP sample of exactly that size (`k` counts
/// any forced include items). An optional [`Constraint`] conditions the
/// draw on `A ⊆ Y, B ∩ Y = ∅` — the slate-filling scenario: items the
/// user already picked, items never to show.
#[derive(Clone, Debug)]
pub struct SampleRequest {
    /// Target tenant (resolve names via [`DppService::tenant`]).
    pub tenant: TenantId,
    pub k: usize,
    /// Optional conditioning constraint; `None` (or an empty constraint,
    /// normalized away at admission) draws unconditioned samples.
    pub constraint: Option<Constraint>,
    /// Which backend of the sampler zoo serves the draw — exact spectral
    /// sampling by default; MCMC / low-rank trade fidelity for cost;
    /// [`SampleMode::Map`] returns the deterministic greedy MAP slate
    /// (`k = 0` auto-sizes it).
    pub mode: SampleMode,
    /// Optional deadline: past it the request is worthless to the caller
    /// and the service drops it ([`Error::Deadline`]) instead of burning
    /// sampler time — at admission if already expired, at the worker
    /// before expensive per-group setup otherwise. `None` inherits the
    /// service's `default_budget_ms` (or never expires if that is 0).
    pub deadline: Option<Instant>,
}

impl SampleRequest {
    /// Request against the default tenant (single-tenant deployments).
    pub fn new(k: usize) -> Self {
        SampleRequest {
            tenant: TenantId::DEFAULT,
            k,
            constraint: None,
            mode: SampleMode::Exact,
            deadline: None,
        }
    }

    /// Request against a specific tenant.
    pub fn for_tenant(tenant: TenantId, k: usize) -> Self {
        SampleRequest {
            tenant,
            k,
            constraint: None,
            mode: SampleMode::Exact,
            deadline: None,
        }
    }

    /// Attach a conditioning constraint (builder style).
    pub fn with_constraint(mut self, constraint: Constraint) -> Self {
        self.constraint = Some(constraint);
        self
    }

    /// Select a sampling backend (builder style).
    pub fn with_mode(mut self, mode: SampleMode) -> Self {
        self.mode = mode;
        self
    }

    /// Set an absolute deadline (builder style).
    pub fn with_deadline(mut self, deadline: Instant) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Set a relative budget from now (builder style).
    pub fn with_budget(self, budget: Duration) -> Self {
        self.with_deadline(Instant::now() + budget)
    }
}

struct Job {
    req: SampleRequest,
    /// Resolved at admission so workers and metrics never re-lock the
    /// registry name table.
    entry: Arc<TenantEntry>,
    respond: mpsc::Sender<Result<Vec<usize>>>,
    accepted: Instant,
    /// Stamped by [`dispatch`] when the job leaves the queue for a worker
    /// — splits end-to-end latency into queue-wait (accepted → dispatched)
    /// and serve-time (dispatched → finish) sketch components.
    dispatched: Option<Instant>,
    /// Set by [`finish`]; lets the worker's panic handler fail exactly the
    /// jobs of a panicked group that never produced an outcome, without
    /// double-counting the ones that did.
    done: Arc<AtomicBool>,
}

impl Job {
    fn expired(&self, now: Instant) -> bool {
        self.req.deadline.is_some_and(|d| now >= d)
    }
}

/// What the panic handler needs to settle a job that a panicking serve
/// never finished — captured before `catch_unwind` because the jobs
/// themselves move into the serve call.
struct JobMeta {
    done: Arc<AtomicBool>,
    respond: mpsc::Sender<Result<Vec<usize>>>,
    entry: Arc<TenantEntry>,
    accepted: Instant,
    dispatched: Option<Instant>,
}

impl JobMeta {
    fn of(job: &Job) -> Self {
        JobMeta {
            done: Arc::clone(&job.done),
            respond: job.respond.clone(),
            entry: Arc::clone(&job.entry),
            accepted: job.accepted,
            dispatched: job.dispatched,
        }
    }

    /// Fail-finish a job whose serve panicked before reaching [`finish`]:
    /// same accounting (`failed`, latency splits, outstanding release,
    /// SLO check) and a definitive error on the ticket, skipping jobs
    /// that already completed.
    fn fail_if_unfinished(self, shared: &Shared) {
        if self.done.load(Ordering::SeqCst) {
            return;
        }
        let elapsed = self.accepted.elapsed();
        shared.metrics.latency.record(elapsed);
        shared.metrics.failed.fetch_add(1, Ordering::Relaxed);
        let tm = self.entry.metrics();
        tm.latency.record(elapsed);
        tm.failed.fetch_add(1, Ordering::Relaxed);
        if let Some(d) = self.dispatched {
            let serve = d.elapsed();
            shared.metrics.serve_time.record(serve);
            tm.serve_time.record(serve);
        }
        if tm.check_slo(elapsed) {
            shared.metrics.slo_violations.fetch_add(1, Ordering::Relaxed);
        }
        self.entry.outstanding.fetch_sub(1, Ordering::SeqCst);
        let _ = self.respond.send(Err(Error::Service(format!(
            "tenant '{}': worker panicked while serving the group",
            self.entry.name()
        ))));
    }
}

/// Handle to a pending response.
pub struct Ticket {
    rx: mpsc::Receiver<Result<Vec<usize>>>,
}

impl Ticket {
    /// Block until the sample is ready.
    pub fn wait(self) -> Result<Vec<usize>> {
        self.rx
            .recv()
            .map_err(|_| Error::Service("service dropped the request".into()))?
    }

    /// Wait with a timeout. A timeout is the *client's* deadline class
    /// ([`Error::Deadline`]) — the service may still complete the request
    /// in the background; a disconnect means the service dropped it.
    pub fn wait_timeout(self, d: Duration) -> Result<Vec<usize>> {
        match self.rx.recv_timeout(d) {
            Ok(r) => r,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                Err(Error::Deadline("client-side wait timed out".into()))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(Error::Service("service dropped the request".into()))
            }
        }
    }

    /// Non-blocking poll: `None` while the request is still in flight,
    /// `Some(result)` once it resolved (a disconnect resolves to the
    /// usual `Service` error). The result is delivered exactly once —
    /// after `Some`, the ticket is spent and further polls return the
    /// disconnect error. This is the readiness probe the non-blocking
    /// connection layer ([`super::net`]) drives its event loop with.
    pub fn try_ready(&self) -> Option<Result<Vec<usize>>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(Error::Service("service dropped the request".into())))
            }
        }
    }
}

struct Shared {
    queue: Mutex<BatchQueue<Job>>,
    cv: Condvar,
    /// The multi-tenant kernel registry: epoch publication, LRU eviction
    /// and the writer-side swap scratch all live here.
    registry: Arc<KernelRegistry>,
    metrics: ServiceMetrics,
    shutdown: AtomicBool,
    capacity: usize,
    /// Queue depth at which admission starts shedding with the retryable
    /// [`Error::Throttled`] (0 = disabled; see
    /// [`crate::config::ServiceConfig::shed_queue_depth`]).
    shed_queue_depth: usize,
    /// Service-wide default admission policy, applied to tenants
    /// registered on the live service.
    default_admission: AdmissionPolicy,
    /// Degraded-mode fallback chain + circuit-breaker thresholds.
    fallback: FallbackPolicy,
    /// Default per-request budget applied at admission when a request
    /// carries no explicit deadline (`None` = requests never expire).
    default_budget: Option<Duration>,
    /// Deterministic fault-injection plan (unit in production builds).
    faults: FaultSeam,
}

impl Shared {
    /// Group-serve fault hook: may sleep (latency injection) or panic
    /// (supervision drill). No-op in production builds.
    #[cfg(any(test, feature = "fault-injection"))]
    fn fault_on_group(&self, tenant: TenantId) {
        if let Some(plan) = &self.faults {
            plan.on_group(tenant);
        }
    }
    #[cfg(not(any(test, feature = "fault-injection")))]
    fn fault_on_group(&self, _tenant: TenantId) {}

    /// Should the primary exact path fail (injected `Numerical` error)?
    #[cfg(any(test, feature = "fault-injection"))]
    fn fault_exact(&self, tenant: TenantId) -> bool {
        self.faults.as_ref().is_some_and(|p| p.exact_failure(tenant))
    }
    #[cfg(not(any(test, feature = "fault-injection")))]
    fn fault_exact(&self, _tenant: TenantId) -> bool {
        false
    }

    /// Should the next fallback rung fail (injected rung skip)?
    #[cfg(any(test, feature = "fault-injection"))]
    fn fault_fallback(&self, tenant: TenantId) -> bool {
        self.faults.as_ref().is_some_and(|p| p.fallback_failure(tenant))
    }
    #[cfg(not(any(test, feature = "fault-injection")))]
    fn fault_fallback(&self, _tenant: TenantId) -> bool {
        false
    }
}

/// Supervisor mailbox: a worker that caught a panic hands its receiver
/// back for respawn; shutdown sends the explicit sentinel (the supervisor
/// holds its own sender clone for respawned workers, so channel
/// disconnection alone could never wake it).
enum Supervision {
    /// `(worker index, the worker's job receiver)` — respawn a fresh
    /// thread continuing the same channel; queued deliveries survive the
    /// handover (mpsc receivers drain buffered messages even after a
    /// sender drops).
    Respawn(usize, mpsc::Receiver<Vec<Job>>),
    Shutdown,
}

/// The running service.
pub struct DppService {
    shared: Arc<Shared>,
    pump: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    worker_txs: Vec<mpsc::Sender<Vec<Job>>>,
    loads: WorkerLoad,
    supervisor: Option<JoinHandle<()>>,
    supervise_tx: Option<mpsc::Sender<Supervision>>,
}

impl DppService {
    /// Start the service with `kernel` as the "default" tenant, plus any
    /// tenants declared in `cfg` (each provisioned with a synthetic
    /// paper-style KronDPP from its spec — production callers publish
    /// learned kernels over them).
    pub fn start(kernel: &Kernel, cfg: &ServiceConfig, seed: u64) -> Result<Self> {
        let registry = Arc::new(KernelRegistry::with_history(
            cfg.max_resident_epochs,
            cfg.epoch_history,
        ));
        registry.add_tenant("default", kernel)?;
        for spec in &cfg.tenants {
            let mut rng = Rng::new(spec.seed);
            let k = crate::data::paper_truth_kernel(spec.n1, spec.n2, &mut rng);
            registry.add_tenant(&spec.name, &k)?;
        }
        Self::start_with_registry(registry, cfg, seed)
    }

    /// Start the service over a pre-populated registry (multi-tenant
    /// deployments that build their own tenants/kernels).
    pub fn start_with_registry(
        registry: Arc<KernelRegistry>,
        cfg: &ServiceConfig,
        seed: u64,
    ) -> Result<Self> {
        Self::boot(registry, cfg, seed, FaultSeam::default())
    }

    /// Start with a deterministic fault-injection plan threaded through
    /// the serving seams (chaos testing; see [`crate::coordinator::faults`]).
    #[cfg(any(test, feature = "fault-injection"))]
    pub fn start_with_registry_and_faults(
        registry: Arc<KernelRegistry>,
        cfg: &ServiceConfig,
        seed: u64,
        faults: Arc<FaultPlan>,
    ) -> Result<Self> {
        Self::boot(registry, cfg, seed, Some(faults))
    }

    fn boot(
        registry: Arc<KernelRegistry>,
        cfg: &ServiceConfig,
        seed: u64,
        faults: FaultSeam,
    ) -> Result<Self> {
        if registry.is_empty() {
            return Err(Error::Invalid("registry has no tenants".into()));
        }
        // Seed admission control: per-tenant overrides from the config,
        // the service-wide default for everyone else (the "default"
        // tenant and pre-registered tenants included). Live-tunable later
        // via [`DppService::set_admission`].
        for entry in registry.entries() {
            let policy = cfg
                .tenants
                .iter()
                .find(|t| t.name == entry.name())
                .and_then(|t| t.admission)
                .unwrap_or(cfg.admission);
            entry.set_admission(policy);
        }
        let shared = Arc::new(Shared {
            queue: Mutex::new(BatchQueue::new(BatchPolicy {
                max_batch: cfg.max_batch,
                window: Duration::from_micros(cfg.batch_window_us),
            })),
            cv: Condvar::new(),
            registry,
            metrics: ServiceMetrics::new(),
            shutdown: AtomicBool::new(false),
            capacity: cfg.queue_capacity,
            shed_queue_depth: cfg.shed_queue_depth,
            default_admission: cfg.admission,
            fallback: cfg.fallback.clone(),
            default_budget: if cfg.default_budget_ms == 0 {
                None
            } else {
                Some(Duration::from_millis(cfg.default_budget_ms))
            },
            faults,
        });
        let loads = WorkerLoad::new(cfg.workers);
        let (sup_tx, sup_rx) = mpsc::channel::<Supervision>();
        let mut worker_txs = Vec::with_capacity(cfg.workers);
        let mut workers = Vec::with_capacity(cfg.workers);
        let mut seeder = Rng::new(seed);
        for w in 0..cfg.workers {
            let (tx, rx) = mpsc::channel::<Vec<Job>>();
            worker_txs.push(tx);
            let shared2 = Arc::clone(&shared);
            let loads2 = loads.clone();
            let mut rng = seeder.split(w as u64);
            let supervise = sup_tx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("krondpp-sampler-{w}"))
                    .spawn(move || worker_loop(w, rx, shared2, loads2, &mut rng, supervise))
                    .map_err(Error::Io)?,
            );
        }
        let supervisor = {
            let shared2 = Arc::clone(&shared);
            let loads2 = loads.clone();
            let respawn_seeder = seeder.split(1_000_000);
            let sup_tx2 = sup_tx.clone();
            std::thread::Builder::new()
                .name("krondpp-supervisor".into())
                .spawn(move || supervisor_loop(sup_rx, sup_tx2, shared2, loads2, respawn_seeder))
                .map_err(Error::Io)?
        };
        let pump = {
            let shared2 = Arc::clone(&shared);
            let txs = worker_txs.clone();
            let loads2 = loads.clone();
            std::thread::Builder::new()
                .name("krondpp-pump".into())
                .spawn(move || pump_loop(shared2, txs, loads2))
                .map_err(Error::Io)?
        };
        Ok(DppService {
            shared,
            pump: Some(pump),
            workers,
            worker_txs,
            loads,
            supervisor: Some(supervisor),
            supervise_tx: Some(sup_tx),
        })
    }

    /// The underlying registry (for direct publishes, gauges, tenants).
    pub fn registry(&self) -> &Arc<KernelRegistry> {
        &self.shared.registry
    }

    /// Resolve a tenant name to its id.
    pub fn tenant(&self, name: &str) -> Result<TenantId> {
        self.shared
            .registry
            .resolve(name)
            .ok_or_else(|| Error::Rejected(format!("unknown tenant '{name}'")))
    }

    /// Register a new tenant on the live service (inherits the
    /// service-wide default admission policy; override with
    /// [`Self::set_admission`]).
    pub fn add_tenant(&self, name: &str, kernel: &Kernel) -> Result<TenantId> {
        let id = self.shared.registry.add_tenant(name, kernel)?;
        self.shared.registry.entry(id)?.set_admission(self.shared.default_admission);
        Ok(id)
    }

    /// Submit a request; fails fast on admission errors (unknown tenant,
    /// `k` larger than the tenant's current ground set, an unsatisfiable
    /// or out-of-bounds [`Constraint`] — these return [`Error::Rejected`]
    /// without burning a queue slot), on admission throttling (the
    /// tenant's token bucket / outstanding cap, or the service's queue
    /// shed depth — the *retryable* [`Error::Throttled`], same no-slot
    /// fast path), and under backpressure.
    pub fn submit(&self, req: SampleRequest) -> Result<Ticket> {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return Err(Error::Service("service is shut down".into()));
        }
        let mut req = req;
        let entry = match self.shared.registry.entry(req.tenant) {
            Ok(e) => e,
            Err(e) => {
                self.shared.metrics.rejected_invalid.fetch_add(1, Ordering::Relaxed);
                return Err(e);
            }
        };
        let n = entry.n();
        let reject = |msg: String| {
            self.shared.metrics.rejected_invalid.fetch_add(1, Ordering::Relaxed);
            entry.metrics().rejected_invalid.fetch_add(1, Ordering::Relaxed);
            Err(Error::Rejected(format!("tenant '{}': {msg}", entry.name())))
        };
        if req.k > n {
            return reject(format!("requested k={} > ground set {n}", req.k));
        }
        // Normalize the empty constraint away so workers coalesce it with
        // plain requests; validate real constraints against the tenant's
        // current ground set (the slate must fit include/exclude).
        if req.constraint.as_ref().is_some_and(|c| c.is_empty()) {
            req.constraint = None;
        }
        if let Some(c) = &req.constraint {
            let check =
                if req.k > 0 { c.validate_k(req.k, n) } else { c.validate(n) };
            if let Err(e) = check {
                let msg = match e {
                    Error::Invalid(m) => m,
                    other => other.to_string(),
                };
                return reject(msg);
            }
        }
        // Mode admission: the tenant's policy gates which backends it
        // serves, and mode parameters must be feasible against the current
        // ground set — both fail fast without burning a queue slot.
        if !entry.mode_policy().allows(req.mode) {
            return reject(format!(
                "mode '{}' disabled by tenant policy",
                req.mode.label()
            ));
        }
        match req.mode {
            SampleMode::Exact | SampleMode::Map => {}
            SampleMode::Mcmc { steps } => {
                if steps == 0 {
                    return reject("mcmc mode needs steps >= 1".into());
                }
            }
            SampleMode::LowRank { rank } => {
                if rank == 0 || rank > n {
                    return reject(format!("lowrank rank={rank} outside 1..={n}"));
                }
                // det L_r(Y) = 0 for |Y| > rank: the projection cannot
                // emit a slate larger than its rank.
                if req.k > rank {
                    return reject(format!(
                        "requested k={} exceeds projection rank {rank}",
                        req.k
                    ));
                }
            }
        }
        // Admission control: the tenant's token bucket and outstanding
        // cap shed with the *retryable* [`Error::Throttled`] on the same
        // fast path as [`Error::Rejected`] — before any queue interaction,
        // so a shed request costs one per-tenant mutex and burns no queue
        // slot and no accept count.
        if let Err(reason) = entry.try_admit(Instant::now()) {
            self.shared.metrics.throttled.fetch_add(1, Ordering::Relaxed);
            entry.metrics().throttled.fetch_add(1, Ordering::Relaxed);
            return Err(Error::Throttled(reason));
        }
        // Deadline admission: apply the service default budget to
        // undeadlined requests, then fast-reject anything already expired
        // — no queue slot, no accept count; only `deadline_exceeded`
        // moves (globally and for the tenant), keeping the worker-side
        // invariant accepted = completed + failed + rejected_invalid +
        // deadline_exceeded intact.
        if req.deadline.is_none() {
            if let Some(budget) = self.shared.default_budget {
                req.deadline = Some(Instant::now() + budget);
            }
        }
        if req.deadline.is_some_and(|d| Instant::now() >= d) {
            self.shared.metrics.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
            entry.metrics().deadline_exceeded.fetch_add(1, Ordering::Relaxed);
            return Err(Error::Deadline(format!(
                "tenant '{}': deadline passed before admission",
                entry.name()
            )));
        }
        let (tx, rx) = mpsc::channel();
        {
            let mut q = lock_clean(&self.shared.queue);
            if q.len() >= self.shared.capacity {
                self.shared.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(Error::Service(format!(
                    "queue full ({} requests)",
                    self.shared.capacity
                )));
            }
            // Load shedding: past the shed depth the service is already
            // drowning — shed with the retryable `Throttled` *before* the
            // hard capacity wall turns into non-retryable `Service`
            // errors. Still no slot burned, nothing accepted.
            if self.shared.shed_queue_depth > 0 && q.len() >= self.shared.shed_queue_depth {
                self.shared.metrics.throttled.fetch_add(1, Ordering::Relaxed);
                entry.metrics().throttled.fetch_add(1, Ordering::Relaxed);
                return Err(Error::Throttled(format!(
                    "queue depth {} at shed threshold {}",
                    q.len(),
                    self.shared.shed_queue_depth
                )));
            }
            let job = Job {
                req,
                entry: Arc::clone(&entry),
                respond: tx,
                accepted: Instant::now(),
                dispatched: None,
                done: Arc::new(AtomicBool::new(false)),
            };
            q.push(job, Instant::now());
            self.shared.metrics.accepted.fetch_add(1, Ordering::Relaxed);
            entry.metrics().accepted.fetch_add(1, Ordering::Relaxed);
            entry.outstanding.fetch_add(1, Ordering::SeqCst);
        }
        self.shared.cv.notify_one();
        Ok(Ticket { rx })
    }

    /// Convenience: submit against the default tenant and wait.
    pub fn sample(&self, k: usize) -> Result<Vec<usize>> {
        self.submit(SampleRequest::new(k))?.wait()
    }

    /// Convenience: submit against `tenant` and wait.
    pub fn sample_tenant(&self, tenant: TenantId, k: usize) -> Result<Vec<usize>> {
        self.submit(SampleRequest::for_tenant(tenant, k))?.wait()
    }

    /// Convenience: submit a constrained request against `tenant` and
    /// wait — "user already picked `constraint.include()`, never show
    /// `constraint.exclude()`, fill the slate to `k` diverse items".
    pub fn sample_constrained(
        &self,
        tenant: TenantId,
        k: usize,
        constraint: Constraint,
    ) -> Result<Vec<usize>> {
        self.submit(SampleRequest::for_tenant(tenant, k).with_constraint(constraint))?.wait()
    }

    /// Convenience: submit against `tenant` with an explicit backend
    /// [`SampleMode`] and wait.
    pub fn sample_mode(
        &self,
        tenant: TenantId,
        k: usize,
        mode: SampleMode,
    ) -> Result<Vec<usize>> {
        self.submit(SampleRequest::for_tenant(tenant, k).with_mode(mode))?.wait()
    }

    /// Convenience: the deterministic greedy MAP slate for `tenant` —
    /// `k = 0` auto-sizes the slate (items are added while they increase
    /// `det L_Y`), an optional constraint forces/forbids items.
    pub fn map_slate(
        &self,
        tenant: TenantId,
        k: usize,
        constraint: Option<Constraint>,
    ) -> Result<Vec<usize>> {
        let mut req = SampleRequest::for_tenant(tenant, k).with_mode(SampleMode::Map);
        if let Some(c) = constraint {
            req = req.with_constraint(c);
        }
        self.submit(req)?.wait()
    }

    /// Restrict which sample modes `tenant` accepts — enforced at
    /// admission, swappable on the live service without republishing.
    pub fn set_mode_policy(&self, tenant: TenantId, policy: ModePolicy) -> Result<()> {
        self.shared.registry.set_mode_policy(tenant, policy)
    }

    /// Live-tune `tenant`'s admission control: token-bucket rate/burst,
    /// outstanding cap, latency SLO. Takes effect on the next submit; the
    /// bucket refills to the new burst. Queued requests were admitted
    /// under the old policy and still complete.
    pub fn set_admission(&self, tenant: TenantId, policy: AdmissionPolicy) -> Result<()> {
        self.shared.registry.entry(tenant)?.set_admission(policy);
        Ok(())
    }

    /// The tenant's current admission policy.
    pub fn admission_policy(&self, tenant: TenantId) -> Result<AdmissionPolicy> {
        Ok(self.shared.registry.entry(tenant)?.admission_policy())
    }

    /// Has shutdown begun? (Admission refuses new work once it has.) The
    /// connection layer polls this to start its graceful drain.
    pub fn is_shutdown(&self) -> bool {
        self.shared.shutdown.load(Ordering::SeqCst)
    }

    /// All `N` inclusion probabilities `P(i ∈ Y) = K_ii` for `tenant`,
    /// served from the epoch's cached factored marginal-diagonal table —
    /// no eigen work, no dense `K`, no copy (an `Arc` clone of the
    /// epoch's table: the "relevance × diversity" scoring endpoint). A
    /// cold tenant lazily rebuilds its epoch first.
    pub fn marginals(&self, tenant: TenantId) -> Result<Arc<Vec<f64>>> {
        Ok(Arc::clone(&self.shared.registry.acquire(tenant)?.marginal_diag))
    }

    /// Hot-swap the default tenant's kernel (single-tenant deployments).
    /// The eigendecomposition happens on the caller's thread, off the read
    /// path; in-flight requests finish on the old epoch. Returns the new
    /// generation.
    pub fn update_kernel(&self, kernel: &Kernel) -> Result<u64> {
        self.publish(TenantId::DEFAULT, kernel)
    }

    /// Publish a refreshed kernel to `tenant` (e.g. from a learning job).
    /// Returns the tenant's new generation. A candidate that fails
    /// validation (non-finite entries, unusable spectrum) is quarantined:
    /// the tenant keeps serving its last good epoch.
    pub fn publish(&self, tenant: TenantId, kernel: &Kernel) -> Result<u64> {
        self.shared.registry.publish(tenant, kernel)
    }

    /// Roll `tenant` back to the kernel of a prior `generation` still in
    /// its bounded history, installing it as a **new** generation (the
    /// operator's escape hatch after a bad publish slipped past
    /// validation). Returns the new generation.
    pub fn rollback(&self, tenant: TenantId, generation: u64) -> Result<u64> {
        self.shared.registry.rollback(tenant, generation)
    }

    /// Publish a [`KernelDelta`] to a live tenant — the incremental churn
    /// path. The delta's exact post-kernel is validated like any publish
    /// (poisoned deltas are quarantined, the tenant keeps serving); when
    /// the delta lowers to a rank-r factor perturbation the resident
    /// eigendecomposition is refreshed in place instead of rebuilt.
    /// In-flight draws finish on their old epoch, exactly as with
    /// [`DppService::publish`].
    pub fn publish_delta(&self, tenant: TenantId, delta: &KernelDelta) -> Result<DeltaOutcome> {
        self.shared.registry.publish_delta(tenant, delta)
    }

    /// Append a new item to factor `side` of `tenant`'s kernel:
    /// `row` holds its similarities to the factor's existing items,
    /// `diag` its (positive) self-similarity. Structural — absorbed by an
    /// exact republish; the ground set grows immediately.
    pub fn add_item(
        &self,
        tenant: TenantId,
        side: usize,
        row: Vec<f64>,
        diag: f64,
    ) -> Result<DeltaOutcome> {
        self.publish_delta(tenant, &KernelDelta::AddItem { side, row, diag })
    }

    /// Delete item `index` from factor `side` of `tenant`'s kernel
    /// (structural; the ground set shrinks immediately).
    pub fn remove_item(
        &self,
        tenant: TenantId,
        side: usize,
        index: usize,
    ) -> Result<DeltaOutcome> {
        self.publish_delta(tenant, &KernelDelta::RemoveItem { side, index })
    }

    /// Soft-retire item `index` of factor `side`: damp its similarity
    /// row/column by `damping ∈ [0, 1]` (0 silences it entirely) without
    /// changing the ground set — a rank-2 perturbation the registry
    /// absorbs incrementally while the item fades from slates.
    pub fn retire_item(
        &self,
        tenant: TenantId,
        side: usize,
        index: usize,
        damping: f64,
    ) -> Result<DeltaOutcome> {
        self.publish_delta(tenant, &KernelDelta::RetireItem { side, index, damping })
    }

    /// Pin (`on = true`) or release (`on = false`) `tenant`'s circuit
    /// breaker: a pinned tenant serves exact-mode requests through the
    /// degraded fallback chain unconditionally — no half-open probes, no
    /// auto-recovery — until released.
    pub fn force_degraded(&self, tenant: TenantId, on: bool) -> Result<()> {
        self.shared.registry.entry(tenant)?.force_degraded(on);
        Ok(())
    }

    /// Service metrics (global counters; per-tenant counters live on the
    /// registry entries).
    pub fn metrics(&self) -> &ServiceMetrics {
        &self.shared.metrics
    }

    /// Full report: global counters, registry gauge, per-tenant lines.
    pub fn report(&self) -> String {
        let mut out = self.shared.metrics.report();
        out.push_str("\n  registry: ");
        out.push_str(&self.shared.registry.report());
        for entry in self.shared.registry.entries() {
            out.push_str(&format!(
                "\n  tenant {} (gen {}): {} churn[deltas={} incremental={} depth={}]",
                entry.name(),
                entry.generation(),
                entry.metrics().summary(),
                entry.deltas_published(),
                entry.delta_refreshes(),
                entry.delta_depth(),
            ));
        }
        out
    }

    /// Current total in-flight work across workers.
    pub fn in_flight(&self) -> usize {
        self.loads.total()
    }

    /// Current in-flight work for one tenant.
    pub fn tenant_in_flight(&self, tenant: TenantId) -> usize {
        self.shared
            .registry
            .entry(tenant)
            .map(|e| e.in_flight())
            .unwrap_or(0)
    }

    /// Begin a graceful shutdown without blocking: admission starts
    /// refusing new work immediately and the pump drains the queue to
    /// the workers; already-accepted requests still resolve. A later
    /// [`Self::shutdown`] (or drop) joins the threads. Idempotent, and
    /// safe to call from any thread holding a shared reference.
    pub fn begin_shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
    }

    /// Stop accepting work, drain, and join all threads.
    pub fn shutdown(mut self) {
        self.do_shutdown();
    }

    fn do_shutdown(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        if let Some(p) = self.pump.take() {
            let _ = p.join();
        }
        // Close worker channels: each worker drains its queued deliveries
        // (mpsc buffers survive sender drop) and exits on disconnect.
        self.worker_txs.clear();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // The supervisor holds its own sender clone, so disconnection
        // never wakes it: send the explicit sentinel. Channel FIFO
        // guarantees any Respawn queued by a just-joined worker is
        // processed first, and the supervisor joins its respawned workers
        // (whose channels are already closed) before exiting.
        if let Some(tx) = self.supervise_tx.take() {
            let _ = tx.send(Supervision::Shutdown);
        }
        if let Some(s) = self.supervisor.take() {
            let _ = s.join();
        }
    }
}

impl Drop for DppService {
    fn drop(&mut self) {
        if self.pump.is_some() {
            self.do_shutdown();
        }
    }
}

fn pump_loop(shared: Arc<Shared>, txs: Vec<mpsc::Sender<Vec<Job>>>, loads: WorkerLoad) {
    loop {
        let batch = {
            let mut q = lock_clean(&shared.queue);
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    // Drain everything to the workers before exiting.
                    let rest = q.drain_all();
                    drop(q);
                    if !rest.is_empty() {
                        dispatch(&shared, &txs, &loads, rest);
                    }
                    return;
                }
                let now = Instant::now();
                if let Some(batch) = q.pop_batch(now) {
                    break batch;
                }
                let wait = q
                    .next_deadline(now)
                    .unwrap_or(Duration::from_millis(50))
                    .max(Duration::from_micros(50));
                q = match shared.cv.wait_timeout(q, wait) {
                    Ok((guard, _)) => guard,
                    Err(p) => p.into_inner().0,
                };
            }
        };
        dispatch(&shared, &txs, &loads, batch);
    }
}

/// Split a popped batch by tenant and route each tenant-group to the
/// least-loaded worker (job-weighted, so uneven tenant-groups balance).
/// Keeping a tenant's jobs together is what lets the worker share one
/// epoch acquire and one elementary-DP table per `(tenant, k)` group.
fn dispatch(
    shared: &Arc<Shared>,
    txs: &[mpsc::Sender<Vec<Job>>],
    loads: &WorkerLoad,
    batch: Vec<Pending<Job>>,
) {
    if batch.is_empty() {
        return;
    }
    let now = Instant::now();
    for p in &batch {
        shared.metrics.queue_wait.record(now.duration_since(p.enqueued));
    }
    let jobs: Vec<Job> = batch
        .into_iter()
        .map(|p| {
            let mut job = p.item;
            job.dispatched = Some(now);
            job.entry
                .metrics()
                .queue_wait
                .record(now.saturating_duration_since(job.accepted));
            job
        })
        .collect();
    for (_, group) in coalesce_by_key(jobs, |j| j.req.tenant) {
        shared.metrics.batches.fetch_add(1, Ordering::Relaxed);
        shared
            .metrics
            .batched_requests
            .fetch_add(group.len() as u64, Ordering::Relaxed);
        let n = group.len();
        let entry = Arc::clone(&group[0].entry);
        entry.in_flight.fetch_add(n, Ordering::SeqCst);
        let w = loads.pick();
        loads.begin_n(w, n);
        if let Err(mpsc::SendError(group)) = txs[w].send(group) {
            // Only reachable if the worker thread died (panic): fail the
            // group's jobs so accepted = completed + failed +
            // rejected_invalid stays exact and tickets get a real error
            // instead of a disconnect.
            loads.end_n(w, n);
            entry.in_flight.fetch_sub(n, Ordering::SeqCst);
            for job in group {
                finish(shared, job, Err(Error::Service("worker unavailable".into())));
            }
        }
    }
}

/// Per-worker scratch bundle: every draw, conditioning setup and MAP
/// slate this worker computes reuses these buffers (the batched engine's
/// zero-allocation hot path). Replaced wholesale after a caught panic so
/// no half-written buffer state leaks into the next group.
struct WorkerScratches {
    sample: SampleScratch,
    cond: ConditionScratch,
    map: MapScratch,
    map_out: Vec<usize>,
}

impl WorkerScratches {
    fn new() -> Self {
        WorkerScratches {
            sample: SampleScratch::new(),
            cond: ConditionScratch::new(),
            map: MapScratch::new(),
            map_out: Vec::new(),
        }
    }
}

fn worker_loop(
    w: usize,
    rx: mpsc::Receiver<Vec<Job>>,
    shared: Arc<Shared>,
    loads: WorkerLoad,
    rng: &mut Rng,
    supervise: mpsc::Sender<Supervision>,
) {
    let mut scratches = WorkerScratches::new();
    loop {
        // The pump dispatches single-tenant groups: acquire the tenant's
        // current epoch once for the whole delivery (an `Arc` clone; a
        // cold tenant lazily rebuilds here, off every other tenant's path).
        let jobs = match rx.recv() {
            Ok(jobs) => jobs,
            Err(_) => return, // channel closed and drained: shutdown
        };
        let entry = Arc::clone(&jobs[0].entry);
        let n_jobs = jobs.len();
        // Deadline sweep before the (possibly expensive) epoch acquire —
        // queue wait may already have consumed the budget.
        let now = Instant::now();
        let (expired, live): (Vec<Job>, Vec<Job>) =
            jobs.into_iter().partition(|j| j.expired(now));
        for job in expired {
            deadline_finish(&shared, job);
        }
        let mut panicked = false;
        if !live.is_empty() {
            match shared.registry.acquire_entry(&entry) {
                Err(e) => {
                    let msg = format!("tenant '{}': epoch build failed: {e}", entry.name());
                    for job in live {
                        finish(&shared, job, Err(Error::Service(msg.clone())));
                    }
                }
                Ok(epoch) => {
                    // Coalesce same-(k, constraint, mode) jobs so one
                    // phase-1 setup — and for conditioned groups one whole
                    // conditioning setup (Schur assembly +
                    // eigendecomposition), for MCMC/low-rank groups one
                    // backend build, for MAP groups one deterministic
                    // slate — serves repeated slate contexts instead of
                    // looping single draws. The constraint fingerprint
                    // leads the key so distinct slate contexts compare on
                    // one u64; the full constraint follows as the
                    // exactness tiebreak (a fingerprint collision can
                    // never merge different constraints).
                    for ((k, _fp, constraint, mode), group) in coalesce_by_key(live, |j| {
                        (
                            j.req.k,
                            j.req.constraint.as_ref().map(Constraint::fingerprint),
                            j.req.constraint.clone(),
                            j.req.mode,
                        )
                    }) {
                        // Each coalesced group is one failure domain: a
                        // panic anywhere inside its serve fails exactly
                        // this group's unfinished jobs; sibling groups in
                        // the same delivery still serve.
                        let metas: Vec<JobMeta> = group.iter().map(JobMeta::of).collect();
                        let served = catch_unwind(AssertUnwindSafe(|| {
                            serve_group(
                                &shared,
                                &entry,
                                &epoch,
                                k,
                                constraint,
                                mode,
                                group,
                                rng,
                                &mut scratches,
                            )
                        }));
                        if served.is_err() {
                            shared.metrics.worker_panics.fetch_add(1, Ordering::Relaxed);
                            for meta in metas {
                                meta.fail_if_unfinished(&shared);
                            }
                            // The unwound serve may have left scratch
                            // buffers half-written: replace them wholesale.
                            scratches = WorkerScratches::new();
                            panicked = true;
                        }
                    }
                }
            }
        }
        entry.in_flight.fetch_sub(n_jobs, Ordering::SeqCst);
        loads.end_n(w, n_jobs);
        if panicked {
            // Retire for respawn: a fresh thread (fresh stack, fresh
            // scratches, fresh RNG stream) is cheaper to reason about
            // than a worker that keeps serving after N caught panics.
            // The intact receiver rides along so queued deliveries
            // survive the handover.
            let _ = supervise.send(Supervision::Respawn(w, rx));
            return;
        }
    }
}

/// The supervisor: respawns workers that retired after catching a panic
/// (each respawn continues the dead worker's channel, so no queued
/// delivery is lost) and, at shutdown, joins its respawns and settles any
/// respawn request that raced the sentinel.
fn supervisor_loop(
    sup_rx: mpsc::Receiver<Supervision>,
    sup_tx: mpsc::Sender<Supervision>,
    shared: Arc<Shared>,
    loads: WorkerLoad,
    mut seeder: Rng,
) {
    let mut respawned: Vec<JoinHandle<()>> = Vec::new();
    let mut count: u64 = 0;
    loop {
        match sup_rx.recv() {
            Ok(Supervision::Respawn(w, rx)) => {
                count += 1;
                shared.metrics.worker_respawns.fetch_add(1, Ordering::Relaxed);
                let shared2 = Arc::clone(&shared);
                let loads2 = loads.clone();
                let mut rng = seeder.split(count);
                let supervise = sup_tx.clone();
                let spawned = std::thread::Builder::new()
                    .name(format!("krondpp-sampler-{w}r{count}"))
                    .spawn(move || worker_loop(w, rx, shared2, loads2, &mut rng, supervise));
                if let Ok(h) = spawned {
                    respawned.push(h);
                }
                // Spawn failure (OS resource exhaustion) drops the
                // receiver: dispatch then fails future groups with
                // "worker unavailable" instead of queueing into a void.
            }
            Ok(Supervision::Shutdown) | Err(_) => break,
        }
    }
    for h in respawned {
        let _ = h.join();
    }
    // A respawned worker may itself have panicked after the shutdown
    // sentinel was queued: its in-flight jobs were settled by its panic
    // handler, but deliveries still buffered in its channel were not —
    // fail them so no ticket is left dangling.
    while let Ok(Supervision::Respawn(w, rx)) = sup_rx.try_recv() {
        while let Ok(jobs) = rx.try_recv() {
            let n = jobs.len();
            let entry = Arc::clone(&jobs[0].entry);
            for job in jobs {
                finish(&shared, job, Err(Error::Service("worker unavailable".into())));
            }
            entry.in_flight.fetch_sub(n, Ordering::SeqCst);
            loads.end_n(w, n);
        }
    }
}

/// Serve one coalesced `(k, constraint, mode)` group from its epoch: the
/// per-group fault seam (injection hook, deadline re-check at the last
/// cheap moment) and the mode dispatch, all inside the worker's
/// `catch_unwind` domain.
#[allow(clippy::too_many_arguments)]
fn serve_group(
    shared: &Arc<Shared>,
    entry: &Arc<TenantEntry>,
    epoch: &crate::coordinator::registry::SamplerEpoch,
    k: usize,
    constraint: Option<Constraint>,
    mode: SampleMode,
    group: Vec<Job>,
    rng: &mut Rng,
    s: &mut WorkerScratches,
) {
    // Fault hook: may inject latency or a panic (supervision drill).
    shared.fault_on_group(entry.id());
    // Deadline re-check after queue wait, dispatch and epoch acquire,
    // before the expensive per-group setup (conditioning eigensolve,
    // backend build).
    let now = Instant::now();
    let (expired, group): (Vec<Job>, Vec<Job>) =
        group.into_iter().partition(|j| j.expired(now));
    for job in expired {
        deadline_finish(shared, job);
    }
    if group.is_empty() {
        return;
    }
    match (mode, constraint) {
        (SampleMode::Exact, constraint) => {
            serve_exact_with_breaker(shared, entry, epoch, k, constraint, group, rng, s)
        }
        (SampleMode::Mcmc { steps }, constraint) => {
            serve_mcmc(shared, epoch, k, constraint, steps, group, rng, &mut s.sample)
        }
        (SampleMode::LowRank { rank }, constraint) => {
            serve_low_rank(shared, epoch, k, constraint, rank, group, rng, &mut s.sample)
        }
        (SampleMode::Map, constraint) => {
            serve_map(shared, epoch, k, constraint, group, &mut s.map, &mut s.map_out)
        }
    }
}

/// The exact-mode path wrapped in the tenant's circuit breaker: an open
/// breaker routes straight to the fallback chain (except on half-open
/// probes, which retry the primary path); a primary `Numerical` failure
/// records a breaker failure and falls back; success closes the breaker.
#[allow(clippy::too_many_arguments)]
fn serve_exact_with_breaker(
    shared: &Arc<Shared>,
    entry: &Arc<TenantEntry>,
    epoch: &crate::coordinator::registry::SamplerEpoch,
    k: usize,
    constraint: Option<Constraint>,
    group: Vec<Job>,
    rng: &mut Rng,
    s: &mut WorkerScratches,
) {
    let policy = &shared.fallback;
    if entry.breaker_is_open() && policy.enabled {
        if !entry.breaker_probe_due(policy.probe_every) {
            // Tripped and no probe due: serve degraded without touching
            // the primary path at all.
            return serve_fallback(shared, entry, epoch, k, constraint, group, rng, s);
        }
        shared.metrics.fallback.probes.fetch_add(1, Ordering::Relaxed);
    }
    match serve_exact(shared, epoch, k, constraint.clone(), group, rng, s) {
        Ok(()) => entry.breaker_record_success(),
        Err((e, group)) => {
            if e.kind() == ErrorKind::Numerical {
                entry.breaker_record_failure(policy.breaker_threshold);
                if policy.enabled {
                    return serve_fallback(shared, entry, epoch, k, constraint, group, rng, s);
                }
            }
            fail_group(shared, epoch, "exact serve", e, group);
        }
    }
}

/// The primary exact path. Returns the group on a retryable setup error
/// so the breaker/fallback layer can take over (`Invalid` errors still
/// reject internally — the request is bad, not the path).
fn serve_exact(
    shared: &Arc<Shared>,
    epoch: &crate::coordinator::registry::SamplerEpoch,
    k: usize,
    constraint: Option<Constraint>,
    group: Vec<Job>,
    rng: &mut Rng,
    s: &mut WorkerScratches,
) -> std::result::Result<(), (Error, Vec<Job>)> {
    if shared.fault_exact(group[0].req.tenant) {
        return Err((Error::Numerical("injected exact-path failure".into()), group));
    }
    match constraint {
        None => {
            serve_plain(shared, epoch, &epoch.sampler, k, group, rng, &mut s.sample, None);
            Ok(())
        }
        Some(c) => serve_conditioned(
            shared,
            epoch,
            &epoch.kernel,
            k,
            c,
            group,
            rng,
            &mut s.sample,
            &mut s.cond,
            None,
        ),
    }
}

/// The degraded-mode chain for exact requests when the primary path is
/// down. Rung 1 retries with jittered regularization — `L + εI` lifts a
/// numerically-indefinite spectrum back into PSD range, and the jitter
/// decorrelates retry storms across workers climbing the same ε ladder.
/// Rung 2 downgrades the backend over the existing epoch: the low-rank
/// projection reuses the cached eigendecomposition, and MCMC works
/// straight off the kernel — the one rung that needs no eigensolve at
/// all. A group every rung declines fails with a definitive error.
#[allow(clippy::too_many_arguments)]
fn serve_fallback(
    shared: &Arc<Shared>,
    entry: &Arc<TenantEntry>,
    epoch: &crate::coordinator::registry::SamplerEpoch,
    k: usize,
    constraint: Option<Constraint>,
    mut group: Vec<Job>,
    rng: &mut Rng,
    s: &mut WorkerScratches,
) {
    let policy = &shared.fallback;
    let tenant = entry.id();
    for &eps in &policy.regularize_eps {
        let eps_j = eps * (0.75 + 0.5 * rng.uniform());
        if shared.fault_fallback(tenant) {
            continue;
        }
        let kernel = epoch.kernel.regularized(eps_j);
        match serve_regularized(shared, epoch, &kernel, k, constraint.clone(), group, rng, s) {
            Ok(()) => return,
            Err(g) => group = g,
        }
    }
    for &mode in &policy.degrade {
        if shared.fault_fallback(tenant) {
            continue;
        }
        match mode {
            SampleMode::LowRank { rank } => {
                let rank = rank.min(epoch.sampler.n());
                if rank == 0 || k > rank {
                    // det L_r(Y) = 0 for |Y| > rank: this rung cannot
                    // emit the requested slate.
                    continue;
                }
                let backend = match LowRankBackend::from_eigen(
                    epoch.sampler.eigen(),
                    rank,
                    constraint.clone().unwrap_or_else(Constraint::none),
                ) {
                    Ok(b) => b,
                    Err(_) => continue,
                };
                serve_backend_draws(
                    shared,
                    epoch,
                    &backend,
                    k,
                    constraint.is_some(),
                    group,
                    rng,
                    &mut s.sample,
                    Some(&shared.metrics.fallback.degraded_low_rank),
                );
                return;
            }
            SampleMode::Mcmc { steps } => {
                let backend = match McmcBackend::new(
                    &epoch.kernel,
                    constraint.clone().unwrap_or_else(Constraint::none),
                    steps,
                ) {
                    Ok(b) => b,
                    Err(_) => continue,
                };
                serve_backend_draws(
                    shared,
                    epoch,
                    &backend,
                    k,
                    constraint.is_some(),
                    group,
                    rng,
                    &mut s.sample,
                    Some(&shared.metrics.fallback.degraded_mcmc),
                );
                return;
            }
            // `FallbackPolicy::parse_rung` rejects exact/map rungs; an
            // unexpected one is skipped rather than recursed into.
            _ => continue,
        }
    }
    shared
        .metrics
        .fallback
        .exhausted
        .fetch_add(group.len() as u64, Ordering::Relaxed);
    let msg = format!(
        "tenant '{}': primary exact path down and degraded-mode fallback exhausted",
        entry.name()
    );
    for job in group {
        finish(shared, job, Err(Error::Service(msg.clone())));
    }
}

/// One rung-1 attempt: rebuild the sampler over the regularized kernel
/// and serve the group through it. Returns the group on a rebuild failure
/// so the caller climbs to the next rung.
#[allow(clippy::too_many_arguments)]
fn serve_regularized(
    shared: &Arc<Shared>,
    epoch: &crate::coordinator::registry::SamplerEpoch,
    kernel: &Kernel,
    k: usize,
    constraint: Option<Constraint>,
    group: Vec<Job>,
    rng: &mut Rng,
    s: &mut WorkerScratches,
) -> std::result::Result<(), Vec<Job>> {
    let rung = Some(&shared.metrics.fallback.regularized);
    match constraint {
        None => match Sampler::new_with_scratch(kernel, &mut s.sample) {
            Ok(sampler) => {
                serve_plain(shared, epoch, &sampler, k, group, rng, &mut s.sample, rung);
                Ok(())
            }
            Err(_) => Err(group),
        },
        Some(c) => match serve_conditioned(
            shared,
            epoch,
            kernel,
            k,
            c,
            group,
            rng,
            &mut s.sample,
            &mut s.cond,
            rung,
        ) {
            Ok(()) => Ok(()),
            Err((_e, g)) => Err(g),
        },
    }
}

/// Count a job served through a degraded-mode rung (the rung's counter
/// plus the tenant's `fallback_served`); no-op on the primary path.
fn count_fallback(rung: Option<&AtomicU64>, job: &Job) {
    if let Some(r) = rung {
        r.fetch_add(1, Ordering::Relaxed);
        job.entry.metrics().fallback_served.fetch_add(1, Ordering::Relaxed);
    }
}

/// Serve one unconstrained `(tenant, k)` group through `sampler` — the
/// epoch's own sampler on the primary path, a regularized rebuild on the
/// fallback path (`rung` counts the latter).
#[allow(clippy::too_many_arguments)]
fn serve_plain(
    shared: &Arc<Shared>,
    epoch: &crate::coordinator::registry::SamplerEpoch,
    sampler: &Sampler,
    k: usize,
    group: Vec<Job>,
    rng: &mut Rng,
    scratch: &mut SampleScratch,
    rung: Option<&AtomicU64>,
) {
    if k > sampler.n() {
        // Admission raced a shrinking publish; reject late with the same
        // distinct error class.
        for job in group {
            finish(
                shared,
                job,
                Err(Error::Rejected(format!(
                    "tenant '{}': requested k={k} > ground set {} (gen {})",
                    epoch.name,
                    sampler.n(),
                    epoch.generation
                ))),
            );
        }
        return;
    }
    // Respond per draw (not per group) so coalescing never inflates
    // head-of-group latency beyond a single draw.
    if k == 0 {
        for job in group {
            let y = sampler.sample_with_scratch(rng, scratch);
            count_fallback(rung, &job);
            finish(shared, job, Ok(y));
        }
    } else {
        let n = group.len();
        let mut jobs = group.into_iter();
        sampler.sample_k_each(k, n, rng, scratch, |y| {
            if let Some(job) = jobs.next() {
                count_fallback(rung, &job);
                finish(shared, job, Ok(y));
            }
        });
    }
}

/// Serve one conditioned `(tenant, k, constraint)` group over `kernel`
/// (the epoch's own kernel on the primary path, a regularized rebuild on
/// the fallback path): one conditioning setup (counted in
/// `conditioning_setups`) shared by every job in the group, then per-draw
/// responses like the plain path. `Invalid` setup errors reject the group
/// internally — an out-of-bounds constraint (admission raced a shrinking
/// publish) or a zero-probability include set mean the request is bad,
/// not the service. Anything else (eigensolver non-convergence is the
/// canonical case) hands the group back so the breaker/fallback layer can
/// decide.
#[allow(clippy::too_many_arguments)]
fn serve_conditioned(
    shared: &Arc<Shared>,
    epoch: &crate::coordinator::registry::SamplerEpoch,
    kernel: &Kernel,
    k: usize,
    constraint: Constraint,
    group: Vec<Job>,
    rng: &mut Rng,
    scratch: &mut SampleScratch,
    cond_scratch: &mut ConditionScratch,
    rung: Option<&AtomicU64>,
) -> std::result::Result<(), (Error, Vec<Job>)> {
    let cs = match ConditionedSampler::new_with_scratch(kernel, constraint, cond_scratch) {
        Ok(cs) => cs,
        Err(Error::Invalid(m)) => {
            let msg = format!("tenant '{}' (gen {}): {m}", epoch.name, epoch.generation);
            for job in group {
                finish(shared, job, Err(Error::Rejected(msg.clone())));
            }
            return Ok(());
        }
        Err(other) => return Err((other, group)),
    };
    shared.metrics.conditioning_setups.fetch_add(1, Ordering::Relaxed);
    if k > 0 && !(cs.min_k()..=cs.max_k()).contains(&k) {
        // Only reachable through a shrinking hot-swap race (admission
        // validated against the old ground set).
        for job in group {
            finish(
                shared,
                job,
                Err(Error::Rejected(format!(
                    "tenant '{}': constrained k={k} outside [{}, {}] (gen {})",
                    epoch.name,
                    cs.min_k(),
                    cs.max_k(),
                    epoch.generation
                ))),
            );
        }
        return Ok(());
    }
    let count_conditioned = |job: &Job| {
        shared.metrics.conditioned.fetch_add(1, Ordering::Relaxed);
        job.entry.metrics().conditioned.fetch_add(1, Ordering::Relaxed);
    };
    if k == 0 {
        for job in group {
            let y = cs.sample_with_scratch(rng, scratch);
            count_conditioned(&job);
            count_fallback(rung, &job);
            finish(shared, job, Ok(y));
        }
    } else {
        let n = group.len();
        let mut jobs = group.into_iter();
        cs.sample_k_each(k, n, rng, scratch, |y| {
            if let Some(job) = jobs.next() {
                count_conditioned(&job);
                count_fallback(rung, &job);
                finish(shared, job, Ok(y));
            }
        });
    }
    Ok(())
}

/// Fail every job in a group on a backend-setup error, splitting
/// `Invalid` (a bad request surfacing late, e.g. a shrinking hot-swap
/// raced admission, or a zero-probability include set — `Rejected`) from
/// service faults (`Service`, counted in `failed`).
fn fail_group(
    shared: &Arc<Shared>,
    epoch: &crate::coordinator::registry::SamplerEpoch,
    what: &str,
    e: Error,
    group: Vec<Job>,
) {
    let (reject, msg) = match e {
        Error::Invalid(m) => {
            (true, format!("tenant '{}' (gen {}): {m}", epoch.name, epoch.generation))
        }
        other => (false, format!("tenant '{}': {what} failed: {other}", epoch.name)),
    };
    for job in group {
        let err = if reject {
            Error::Rejected(msg.clone())
        } else {
            Error::Service(msg.clone())
        };
        finish(shared, job, Err(err));
    }
}

/// Per-job draws against a zoo backend built once per coalesced group:
/// `Invalid` draw errors (a shrinking hot-swap raced admission) reject,
/// anything else is a service fault.
#[allow(clippy::too_many_arguments)]
fn serve_backend_draws<B: SamplerBackend>(
    shared: &Arc<Shared>,
    epoch: &crate::coordinator::registry::SamplerEpoch,
    backend: &B,
    k: usize,
    constrained: bool,
    group: Vec<Job>,
    rng: &mut Rng,
    scratch: &mut SampleScratch,
    rung: Option<&AtomicU64>,
) {
    let k_opt = if k == 0 { None } else { Some(k) };
    for job in group {
        let mut y = Vec::new();
        let result = match backend.draw_into(k_opt, rng, scratch, &mut y) {
            Ok(()) => {
                if constrained {
                    shared.metrics.conditioned.fetch_add(1, Ordering::Relaxed);
                    job.entry.metrics().conditioned.fetch_add(1, Ordering::Relaxed);
                }
                count_fallback(rung, &job);
                Ok(y)
            }
            Err(Error::Invalid(m)) => Err(Error::Rejected(format!(
                "tenant '{}' (gen {}): {m}",
                epoch.name, epoch.generation
            ))),
            Err(other) => Err(Error::Service(format!(
                "tenant '{}': {} draw failed: {other}",
                epoch.name,
                backend.name()
            ))),
        };
        finish(shared, job, result);
    }
}

/// Serve one `(tenant, k, constraint, mcmc)` group: one chain-backend
/// build shared by the group, one independent `steps`-move chain per job.
#[allow(clippy::too_many_arguments)]
fn serve_mcmc(
    shared: &Arc<Shared>,
    epoch: &crate::coordinator::registry::SamplerEpoch,
    k: usize,
    constraint: Option<Constraint>,
    steps: usize,
    group: Vec<Job>,
    rng: &mut Rng,
    scratch: &mut SampleScratch,
) {
    let constrained = constraint.is_some();
    let backend = match McmcBackend::new(
        &epoch.kernel,
        constraint.unwrap_or_else(Constraint::none),
        steps,
    ) {
        Ok(b) => b,
        Err(e) => return fail_group(shared, epoch, "mcmc setup", e, group),
    };
    serve_backend_draws(shared, epoch, &backend, k, constrained, group, rng, scratch, None);
}

/// Serve one `(tenant, k, constraint, lowrank)` group: one `O(N·r)`
/// spectral-projection gather off the epoch's cached eigendecomposition
/// (no eigensolve), shared by every draw in the group.
#[allow(clippy::too_many_arguments)]
fn serve_low_rank(
    shared: &Arc<Shared>,
    epoch: &crate::coordinator::registry::SamplerEpoch,
    k: usize,
    constraint: Option<Constraint>,
    rank: usize,
    group: Vec<Job>,
    rng: &mut Rng,
    scratch: &mut SampleScratch,
) {
    let constrained = constraint.is_some();
    let backend = match LowRankBackend::from_eigen(
        epoch.sampler.eigen(),
        rank,
        constraint.unwrap_or_else(Constraint::none),
    ) {
        Ok(b) => b,
        Err(e) => return fail_group(shared, epoch, "lowrank setup", e, group),
    };
    if constrained {
        // The constrained projection conditions its truncated kernel —
        // one conditioning setup per coalesced group, like the exact path.
        shared.metrics.conditioning_setups.fetch_add(1, Ordering::Relaxed);
    }
    serve_backend_draws(shared, epoch, &backend, k, constrained, group, rng, scratch, None);
}

/// Serve one `(tenant, k, constraint, map)` group: greedy MAP is
/// deterministic, so the worker computes **one** slate per group (into
/// its per-worker [`MapScratch`] — allocation-free when warmed) and every
/// job in the group receives a copy.
fn serve_map(
    shared: &Arc<Shared>,
    epoch: &crate::coordinator::registry::SamplerEpoch,
    k: usize,
    constraint: Option<Constraint>,
    group: Vec<Job>,
    map_scratch: &mut MapScratch,
    out: &mut Vec<usize>,
) {
    let constrained = constraint.is_some();
    let c = constraint.unwrap_or_else(Constraint::none);
    let k_opt = if k == 0 { None } else { Some(k) };
    match map_slate_into(&epoch.kernel, k_opt, &c, map_scratch, out) {
        Ok(_logdet) => {
            for job in group {
                if constrained {
                    shared.metrics.conditioned.fetch_add(1, Ordering::Relaxed);
                    job.entry.metrics().conditioned.fetch_add(1, Ordering::Relaxed);
                }
                finish(shared, job, Ok(out.clone()));
            }
        }
        Err(e) => fail_group(shared, epoch, "map slate", e, group),
    }
}

/// Respond to one job and account for its outcome: every accepted request
/// ends in exactly one of `completed` (Ok — also counted into the global
/// and per-tenant per-mode counters), `rejected_invalid` (a shrinking
/// hot-swap raced the queue — worker-side `Error::Rejected`),
/// `deadline_exceeded` (the budget ran out before a worker could serve
/// it), or `failed` (epoch build error, exhausted fallback, panic),
/// globally and per tenant.
fn finish(shared: &Shared, job: Job, result: Result<Vec<usize>>) {
    job.done.store(true, Ordering::SeqCst);
    let elapsed = job.accepted.elapsed();
    shared.metrics.latency.record(elapsed);
    let tm = job.entry.metrics();
    tm.latency.record(elapsed);
    if let Some(d) = job.dispatched {
        let serve = d.elapsed();
        shared.metrics.serve_time.record(serve);
        tm.serve_time.record(serve);
    }
    if tm.check_slo(elapsed) {
        shared.metrics.slo_violations.fetch_add(1, Ordering::Relaxed);
    }
    // Release the admission-side outstanding slot: workers never produce
    // `Throttled` (it is admission-only), so every accepted job passes
    // through here (or the panic handler) exactly once.
    job.entry.outstanding.fetch_sub(1, Ordering::SeqCst);
    match &result {
        Ok(_) => {
            shared.metrics.completed.fetch_add(1, Ordering::Relaxed);
            shared.metrics.modes.count(job.req.mode);
            tm.completed.fetch_add(1, Ordering::Relaxed);
            tm.modes.count(job.req.mode);
        }
        Err(Error::Rejected(_)) => {
            shared.metrics.rejected_invalid.fetch_add(1, Ordering::Relaxed);
            tm.rejected_invalid.fetch_add(1, Ordering::Relaxed);
        }
        Err(Error::Deadline(_)) => {
            shared.metrics.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
            tm.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
        }
        Err(_) => {
            shared.metrics.failed.fetch_add(1, Ordering::Relaxed);
            tm.failed.fetch_add(1, Ordering::Relaxed);
        }
    }
    let _ = job.respond.send(result);
}

/// Fail one accepted job whose deadline passed before a worker could
/// start its draw — the distinct [`Error::Deadline`] class, which
/// [`finish`] books under `deadline_exceeded` rather than `failed`.
fn deadline_finish(shared: &Shared, job: Job) {
    let msg = format!(
        "tenant '{}': budget exhausted before the draw started",
        job.entry.name()
    );
    finish(shared, job, Err(Error::Deadline(msg)));
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::coordinator::faults::FaultPlan;
    use crate::linalg::Matrix;

    fn test_kernel(n1: usize, n2: usize, seed: u64) -> Kernel {
        let mut rng = Rng::new(seed);
        let mk = |n: usize, rng: &mut Rng| -> Matrix {
            let mut m = rng.paper_init_kernel(n);
            m.scale_mut(1.0 / n as f64);
            m.add_diag_mut(0.3);
            m
        };
        Kernel::Kron2(mk(n1, &mut rng), mk(n2, &mut rng))
    }

    fn small_cfg() -> ServiceConfig {
        ServiceConfig {
            workers: 2,
            max_batch: 4,
            batch_window_us: 200,
            queue_capacity: 64,
            ..ServiceConfig::default()
        }
    }

    #[test]
    fn serves_unconstrained_and_k_requests() {
        let svc = DppService::start(&test_kernel(3, 4, 1), &small_cfg(), 7).unwrap();
        let y = svc.sample(0).unwrap();
        assert!(y.iter().all(|&i| i < 12));
        let y5 = svc.sample(5).unwrap();
        assert_eq!(y5.len(), 5);
        svc.shutdown();
    }

    #[test]
    fn token_bucket_throttles_and_is_live_tunable() {
        let mut cfg = small_cfg();
        // 1 req/s sustained, burst of 2: the third immediate submit sheds.
        cfg.admission = AdmissionPolicy {
            rate_hz: 1.0,
            burst: 2.0,
            max_outstanding: 0,
            slo_ms: 0,
        };
        let svc = DppService::start(&test_kernel(2, 2, 3), &cfg, 5).unwrap();
        assert_eq!(
            svc.admission_policy(TenantId::DEFAULT).unwrap().rate_hz,
            1.0
        );
        let t1 = svc.submit(SampleRequest::new(2)).unwrap();
        let t2 = svc.submit(SampleRequest::new(2)).unwrap();
        let e = svc.submit(SampleRequest::new(2));
        match &e {
            Err(Error::Throttled(m)) => assert!(m.contains("rate limit"), "{m}"),
            other => panic!("expected Throttled, got {other:?}"),
        }
        assert!(e.unwrap_err().is_retryable());
        assert!(t1.wait().is_ok());
        assert!(t2.wait().is_ok());
        // Live-tune to unlimited: admission reopens immediately.
        svc.set_admission(TenantId::DEFAULT, AdmissionPolicy::default()).unwrap();
        assert!(svc.sample(2).is_ok());
        // Ledger: the shed burned no queue slot and was never accepted.
        let m = svc.metrics();
        assert_eq!(m.throttled.load(Ordering::Relaxed), 1);
        assert_eq!(m.accepted.load(Ordering::Relaxed), 3);
        assert_eq!(m.completed.load(Ordering::Relaxed), 3);
        assert_eq!(m.rejected.load(Ordering::Relaxed), 0);
        let tm = svc.registry().entry(TenantId::DEFAULT).unwrap();
        assert_eq!(tm.metrics().throttled.load(Ordering::Relaxed), 1);
        svc.shutdown();
    }

    #[test]
    fn outstanding_cap_sheds_and_reopens_after_finish() {
        let mut cfg = small_cfg();
        cfg.admission = AdmissionPolicy {
            rate_hz: 0.0,
            burst: 0.0,
            max_outstanding: 1,
            slo_ms: 0,
        };
        let svc = DppService::start(&test_kernel(2, 2, 9), &cfg, 11).unwrap();
        // Outstanding counts from accept, so the cap binds immediately and
        // deterministically — no worker race.
        let t1 = svc.submit(SampleRequest::new(2)).unwrap();
        let e = svc.submit(SampleRequest::new(2));
        match &e {
            Err(Error::Throttled(m)) => assert!(m.contains("outstanding"), "{m}"),
            other => panic!("expected Throttled, got {other:?}"),
        }
        // finish() releases the slot before responding, so after wait()
        // the next submit is admitted.
        assert!(t1.wait().is_ok());
        assert!(svc.sample(2).is_ok());
        let entry = svc.registry().entry(TenantId::DEFAULT).unwrap();
        assert_eq!(entry.outstanding(), 0);
        svc.shutdown();
    }

    #[test]
    fn queue_depth_shed_throttles_before_capacity() {
        let mut cfg = small_cfg();
        cfg.workers = 1;
        cfg.queue_capacity = 64;
        cfg.shed_queue_depth = 2;
        // A huge batch window so submissions pile up in the queue.
        cfg.batch_window_us = 200_000;
        cfg.max_batch = 64;
        let svc = DppService::start(&test_kernel(2, 2, 4), &cfg, 6).unwrap();
        let mut tickets = Vec::new();
        let mut sheds = 0;
        for _ in 0..8 {
            match svc.submit(SampleRequest::new(2)) {
                Ok(t) => tickets.push(t),
                Err(Error::Throttled(m)) => {
                    assert!(m.contains("shed threshold"), "{m}");
                    sheds += 1;
                }
                Err(other) => panic!("unexpected {other:?}"),
            }
        }
        assert!(sheds > 0, "queue shed never engaged");
        let m = svc.metrics();
        assert_eq!(m.throttled.load(Ordering::Relaxed), sheds);
        assert_eq!(m.rejected.load(Ordering::Relaxed), 0, "hard wall never hit");
        for t in tickets {
            assert!(t.wait().is_ok());
        }
        svc.shutdown();
    }

    #[test]
    fn slo_violations_count_per_tenant_and_globally() {
        let mut cfg = small_cfg();
        // Absurdly tight SLO: every completed request breaches it.
        cfg.admission = AdmissionPolicy { slo_ms: 0, ..AdmissionPolicy::default() };
        let svc = DppService::start(&test_kernel(2, 2, 8), &cfg, 13).unwrap();
        let entry = svc.registry().entry(TenantId::DEFAULT).unwrap();
        entry.metrics().slo_us.store(1, Ordering::Relaxed); // 1 µs
        for _ in 0..4 {
            svc.sample(2).unwrap();
        }
        let m = svc.metrics();
        assert_eq!(m.slo_violations.load(Ordering::Relaxed), 4);
        assert_eq!(entry.metrics().slo_violations.load(Ordering::Relaxed), 4);
        // Queue-wait and serve-time splits were recorded for each.
        assert_eq!(entry.metrics().queue_wait.count(), 4);
        assert_eq!(entry.metrics().serve_time.count(), 4);
        assert_eq!(m.serve_time.count(), 4);
        svc.shutdown();
    }

    #[test]
    fn churn_endpoints_resize_retire_and_report() {
        let svc = DppService::start(&test_kernel(2, 8, 60), &small_cfg(), 61).unwrap();
        let t = TenantId::DEFAULT;
        assert_eq!(svc.marginals(t).unwrap().len(), 16);

        // Live add: the ground set grows and requests keep serving.
        let mut rng = Rng::new(62);
        let row: Vec<f64> = (0..8).map(|_| rng.uniform_range(-0.02, 0.02)).collect();
        let out = svc.add_item(t, 1, row, 0.9).unwrap();
        assert!(!out.incremental, "add is structural");
        assert_eq!(svc.marginals(t).unwrap().len(), 18);
        assert_eq!(svc.sample(3).unwrap().len(), 3);

        // Soft retire: absorbed incrementally; the item's inclusion
        // probability drops while the ground set is unchanged.
        let before = svc.marginals(t).unwrap();
        let out = svc.retire_item(t, 1, 1, 0.2).unwrap();
        assert!(out.incremental, "retire should refresh the spectrum in place");
        let after = svc.marginals(t).unwrap();
        assert_eq!(after.len(), 18);
        // Side-1 index 1 is item t = 0·9 + 1.
        assert!(after[1] < before[1], "{} !< {}", after[1], before[1]);

        // Remove the appended item: back to N = 16, still serving.
        let out = svc.remove_item(t, 1, 8).unwrap();
        assert!(!out.incremental, "remove is structural");
        assert_eq!(svc.marginals(t).unwrap().len(), 16);
        assert_eq!(svc.sample(2).unwrap().len(), 2);

        let report = svc.report();
        assert!(report.contains("deltas=3 delta_incremental=1 delta_exact=2"), "{report}");
        assert!(report.contains("churn[deltas=3 incremental=1 depth=0]"), "{report}");
        svc.shutdown();
    }

    #[test]
    fn concurrent_requests_all_complete() {
        let svc = Arc::new(DppService::start(&test_kernel(3, 3, 2), &small_cfg(), 8).unwrap());
        let mut handles = Vec::new();
        for t in 0..8 {
            let svc2 = Arc::clone(&svc);
            handles.push(std::thread::spawn(move || {
                let mut ok = 0;
                for _ in 0..20 {
                    if svc2.sample((t % 3) + 1).is_ok() {
                        ok += 1;
                    }
                }
                ok
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 160);
        assert_eq!(
            svc.metrics().completed.load(Ordering::Relaxed),
            svc.metrics().accepted.load(Ordering::Relaxed)
        );
        assert!(svc.metrics().batches.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn coalesced_mixed_k_batch_serves_each_request() {
        // A burst with repeated k values coalesces into grouped draws; every
        // request must still get its own correctly-sized response.
        let mut cfg = small_cfg();
        cfg.max_batch = 16;
        cfg.batch_window_us = 5_000;
        let svc = DppService::start(&test_kernel(3, 4, 6), &cfg, 13).unwrap();
        let ks = [0usize, 3, 3, 5, 0, 3, 5, 1];
        let tickets: Vec<Ticket> =
            ks.iter().map(|&k| svc.submit(SampleRequest::new(k)).unwrap()).collect();
        for (k, t) in ks.iter().zip(tickets) {
            let y = t.wait().unwrap();
            if *k > 0 {
                assert_eq!(y.len(), *k);
            }
            assert!(y.iter().all(|&i| i < 12));
        }
        svc.shutdown();
    }

    #[test]
    fn multi_tenant_requests_route_to_their_kernels() {
        let mut cfg = small_cfg();
        cfg.max_batch = 16;
        cfg.batch_window_us = 2_000;
        let svc = DppService::start(&test_kernel(2, 2, 3), &cfg, 14).unwrap();
        let big = svc.add_tenant("big", &test_kernel(3, 4, 4)).unwrap();
        let deflt = svc.tenant("default").unwrap();
        assert_eq!(deflt, TenantId::DEFAULT);
        // Interleave tenants in one burst: the pump splits per tenant.
        let mut tickets = Vec::new();
        for i in 0..12usize {
            let (t, k) = if i % 2 == 0 { (deflt, 2) } else { (big, 7) };
            tickets.push((t, k, svc.submit(SampleRequest::for_tenant(t, k)).unwrap()));
        }
        for (t, k, ticket) in tickets {
            let y = ticket.wait().unwrap();
            assert_eq!(y.len(), k);
            let bound = if t == deflt { 4 } else { 12 };
            assert!(y.iter().all(|&i| i < bound), "tenant bound violated: {y:?}");
        }
        // Per-tenant accounting saw both tenants.
        let e = svc.registry().entry(big).unwrap();
        assert_eq!(e.metrics().completed.load(Ordering::Relaxed), 6);
        assert!(svc.report().contains("tenant big"));
        svc.shutdown();
    }

    #[test]
    fn constrained_requests_honor_include_exclude_and_share_setups() {
        let mut cfg = small_cfg();
        cfg.max_batch = 16;
        cfg.batch_window_us = 5_000;
        cfg.workers = 1;
        let svc = DppService::start(&test_kernel(3, 4, 20), &cfg, 21).unwrap();
        let c = Constraint::new(vec![0, 5], vec![3]).unwrap();
        // One burst of identical slate contexts: the worker coalesces them
        // into a single conditioning setup.
        let tickets: Vec<Ticket> = (0..8)
            .map(|_| {
                svc.submit(SampleRequest::new(5).with_constraint(c.clone())).unwrap()
            })
            .collect();
        for t in tickets {
            let y = t.wait().unwrap();
            assert_eq!(y.len(), 5);
            assert!(y.contains(&0) && y.contains(&5), "include violated: {y:?}");
            assert!(!y.contains(&3), "exclude violated: {y:?}");
            assert!(y.iter().all(|&i| i < 12));
        }
        assert_eq!(svc.metrics().conditioned.load(Ordering::Relaxed), 8);
        // One setup per dispatched batch of this slate context: typically 1
        // (one burst, one batch), never more than one per request even if
        // the pump's timing splits the burst.
        let setups = svc.metrics().conditioning_setups.load(Ordering::Relaxed);
        assert!(
            (1..=8).contains(&setups),
            "8 identical contexts produced {setups} conditioning setups"
        );
        let e = svc.registry().entry(TenantId::DEFAULT).unwrap();
        assert_eq!(e.metrics().conditioned.load(Ordering::Relaxed), 8);
        assert!(svc.report().contains("conditioned=8"));
        // An unconstrained and an empty-constraint request still serve.
        let y = svc.sample(4).unwrap();
        assert_eq!(y.len(), 4);
        let y = svc
            .submit(SampleRequest::new(2).with_constraint(Constraint::none()))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(y.len(), 2);
        svc.shutdown();
    }

    #[test]
    fn rejects_bad_constraints_at_admission() {
        let svc = DppService::start(&test_kernel(2, 2, 22), &small_cfg(), 23).unwrap();
        // Out-of-bounds item.
        let c = Constraint::including(vec![99]).unwrap();
        match svc.submit(SampleRequest::new(0).with_constraint(c)) {
            Err(Error::Rejected(m)) => assert!(m.contains("outside ground set"), "{m}"),
            other => panic!("expected admission rejection, got {other:?}"),
        }
        // Slate smaller than the forced include set.
        let c = Constraint::including(vec![0, 1, 2]).unwrap();
        match svc.submit(SampleRequest::new(2).with_constraint(c)) {
            Err(Error::Rejected(m)) => assert!(m.contains("smaller than"), "{m}"),
            other => panic!("expected admission rejection, got {other:?}"),
        }
        // Slate larger than what survives exclusion.
        let c = Constraint::excluding(vec![0, 1]).unwrap();
        match svc.submit(SampleRequest::new(3).with_constraint(c)) {
            Err(Error::Rejected(m)) => assert!(m.contains("surviving exclusion"), "{m}"),
            other => panic!("expected admission rejection, got {other:?}"),
        }
        assert_eq!(svc.metrics().rejected_invalid.load(Ordering::Relaxed), 3);
        assert_eq!(svc.metrics().accepted.load(Ordering::Relaxed), 0);
        svc.shutdown();
    }

    #[test]
    fn marginals_endpoint_serves_cached_table() {
        let kernel = test_kernel(3, 3, 24);
        let svc = DppService::start(&kernel, &small_cfg(), 25).unwrap();
        let got = svc.marginals(TenantId::DEFAULT).unwrap();
        let want = kernel.eigen().unwrap().inclusion_probabilities();
        assert_eq!(got.len(), 9);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-14);
        }
        svc.shutdown();
    }

    #[test]
    fn rejects_oversized_k_at_admission() {
        let svc = DppService::start(&test_kernel(2, 2, 3), &small_cfg(), 9).unwrap();
        match svc.sample(100) {
            Err(Error::Rejected(m)) => assert!(m.contains("k=100")),
            other => panic!("expected admission rejection, got {other:?}"),
        }
        // No queue slot burned: never accepted, counted as invalid.
        assert_eq!(svc.metrics().accepted.load(Ordering::Relaxed), 0);
        assert_eq!(svc.metrics().rejected_invalid.load(Ordering::Relaxed), 1);
        let e = svc.registry().entry(TenantId::DEFAULT).unwrap();
        assert_eq!(e.metrics().rejected_invalid.load(Ordering::Relaxed), 1);
        svc.shutdown();
    }

    #[test]
    fn rejects_unknown_tenant_at_admission() {
        let svc = DppService::start(&test_kernel(2, 2, 4), &small_cfg(), 10).unwrap();
        match svc.submit(SampleRequest::for_tenant(TenantId(7), 2)) {
            Err(Error::Rejected(m)) => assert!(m.contains("unknown tenant")),
            Err(other) => panic!("expected admission rejection, got {other:?}"),
            Ok(_) => panic!("expected admission rejection, got a ticket"),
        }
        assert!(svc.tenant("nope").is_err());
        assert_eq!(svc.metrics().rejected_invalid.load(Ordering::Relaxed), 1);
        assert_eq!(svc.metrics().accepted.load(Ordering::Relaxed), 0);
        svc.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let mut cfg = small_cfg();
        cfg.queue_capacity = 2;
        cfg.workers = 1;
        cfg.max_batch = 1;
        cfg.batch_window_us = 0;
        let svc = DppService::start(&test_kernel(3, 3, 4), &cfg, 10).unwrap();
        // Flood without waiting; some must be rejected.
        let mut tickets = Vec::new();
        let mut rejected = 0;
        for _ in 0..200 {
            match svc.submit(SampleRequest::new(3)) {
                Ok(t) => tickets.push(t),
                Err(_) => rejected += 1,
            }
        }
        for t in tickets {
            let _ = t.wait();
        }
        // Either we saw rejections, or the worker kept up; metrics must
        // agree with what we observed.
        assert_eq!(svc.metrics().rejected.load(Ordering::Relaxed), rejected as u64);
        assert_eq!(svc.metrics().rejected_invalid.load(Ordering::Relaxed), 0);
        svc.shutdown();
    }

    #[test]
    fn kernel_hot_swap_changes_ground_set() {
        let svc = DppService::start(&test_kernel(2, 2, 5), &small_cfg(), 11).unwrap();
        let y = svc.sample(2).unwrap();
        assert!(y.iter().all(|&i| i < 4));
        let generation = svc.update_kernel(&test_kernel(3, 4, 6)).unwrap();
        assert_eq!(generation, 2);
        let y2 = svc.sample(8).unwrap();
        assert_eq!(y2.len(), 8);
        assert!(y2.iter().any(|&i| i >= 4), "new kernel should expose items ≥ 4");
        svc.shutdown();
    }

    #[test]
    fn config_declared_tenants_are_provisioned() {
        let mut cfg = small_cfg();
        cfg.tenants = vec![
            crate::config::TenantSpec { name: "eu".into(), n1: 3, n2: 3, seed: 1, admission: None },
            crate::config::TenantSpec { name: "us".into(), n1: 2, n2: 4, seed: 2, admission: None },
        ];
        let svc = DppService::start(&test_kernel(2, 2, 7), &cfg, 12).unwrap();
        assert_eq!(
            svc.registry().tenant_names(),
            vec!["default".to_string(), "eu".into(), "us".into()]
        );
        let eu = svc.tenant("eu").unwrap();
        let y = svc.sample_tenant(eu, 4).unwrap();
        assert_eq!(y.len(), 4);
        assert!(y.iter().all(|&i| i < 9));
        svc.shutdown();
    }

    #[test]
    fn shutdown_drains_pending() {
        let svc = DppService::start(&test_kernel(3, 3, 7), &small_cfg(), 12).unwrap();
        let tickets: Vec<Ticket> =
            (0..16).map(|_| svc.submit(SampleRequest::new(2)).unwrap()).collect();
        svc.shutdown();
        let mut done = 0;
        for t in tickets {
            if t.wait_timeout(Duration::from_secs(2)).is_ok() {
                done += 1;
            }
        }
        assert_eq!(done, 16, "shutdown dropped pending requests");
    }

    #[test]
    fn mode_requests_serve_and_count_per_mode() {
        let mut cfg = small_cfg();
        cfg.workers = 1;
        let svc = DppService::start(&test_kernel(3, 4, 30), &cfg, 31).unwrap();
        let t = TenantId::DEFAULT;
        let y = svc.sample_mode(t, 4, SampleMode::Exact).unwrap();
        assert_eq!(y.len(), 4);
        let y = svc.sample_mode(t, 3, SampleMode::Mcmc { steps: 40 }).unwrap();
        assert_eq!(y.len(), 3);
        assert!(y.windows(2).all(|w| w[0] < w[1]));
        assert!(y.iter().all(|&i| i < 12));
        let y = svc.sample_mode(t, 2, SampleMode::LowRank { rank: 5 }).unwrap();
        assert_eq!(y.len(), 2);
        let y = svc.sample_mode(t, 4, SampleMode::Map).unwrap();
        assert_eq!(y.len(), 4);
        let m = svc.metrics();
        assert_eq!(m.modes.get(SampleMode::Exact), 1);
        assert_eq!(m.modes.get(SampleMode::Mcmc { steps: 40 }), 1);
        assert_eq!(m.modes.get(SampleMode::LowRank { rank: 5 }), 1);
        assert_eq!(m.modes.get(SampleMode::Map), 1);
        let e = svc.registry().entry(t).unwrap();
        assert_eq!(e.metrics().modes.get(SampleMode::Map), 1);
        assert!(svc.report().contains("modes: exact=1 mcmc=1 lowrank=1 map=1"));
        svc.shutdown();
    }

    #[test]
    fn map_mode_is_deterministic_and_respects_constraints() {
        let mut cfg = small_cfg();
        cfg.max_batch = 8;
        cfg.batch_window_us = 5_000;
        let svc = DppService::start(&test_kernel(3, 4, 32), &cfg, 33).unwrap();
        let t = TenantId::DEFAULT;
        let a = svc.map_slate(t, 5, None).unwrap();
        let b = svc.map_slate(t, 5, None).unwrap();
        assert_eq!(a, b, "greedy MAP must be deterministic");
        assert_eq!(a.len(), 5);
        assert!(a.windows(2).all(|w| w[0] < w[1]));
        let c = Constraint::new(vec![2], vec![0, 7]).unwrap();
        let y = svc.map_slate(t, 4, Some(c)).unwrap();
        assert_eq!(y.len(), 4);
        assert!(y.contains(&2), "include violated: {y:?}");
        assert!(!y.contains(&0) && !y.contains(&7), "exclude violated: {y:?}");
        assert_eq!(svc.metrics().conditioned.load(Ordering::Relaxed), 1);
        // Auto-sized slate: k = 0 lets the greedy stop on its own.
        let y = svc.map_slate(t, 0, None).unwrap();
        assert!(y.windows(2).all(|w| w[0] < w[1]));
        assert!(y.iter().all(|&i| i < 12));
        svc.shutdown();
    }

    #[test]
    fn mode_policy_and_bad_mode_parameters_reject_at_admission() {
        let svc = DppService::start(&test_kernel(3, 3, 34), &small_cfg(), 35).unwrap();
        let t = TenantId::DEFAULT;
        // Parameter validation against the 9-item ground set.
        match svc.sample_mode(t, 2, SampleMode::Mcmc { steps: 0 }) {
            Err(Error::Rejected(m)) => assert!(m.contains("steps"), "{m}"),
            other => panic!("expected rejection, got {other:?}"),
        }
        match svc.sample_mode(t, 2, SampleMode::LowRank { rank: 0 }) {
            Err(Error::Rejected(m)) => assert!(m.contains("rank"), "{m}"),
            other => panic!("expected rejection, got {other:?}"),
        }
        match svc.sample_mode(t, 2, SampleMode::LowRank { rank: 99 }) {
            Err(Error::Rejected(m)) => assert!(m.contains("rank"), "{m}"),
            other => panic!("expected rejection, got {other:?}"),
        }
        match svc.sample_mode(t, 5, SampleMode::LowRank { rank: 3 }) {
            Err(Error::Rejected(m)) => assert!(m.contains("projection rank"), "{m}"),
            other => panic!("expected rejection, got {other:?}"),
        }
        assert_eq!(svc.metrics().accepted.load(Ordering::Relaxed), 0);
        // A constrained low-rank request within the rank budget serves.
        let c = Constraint::including(vec![0, 1, 2]).unwrap();
        let req = SampleRequest::new(5)
            .with_constraint(c)
            .with_mode(SampleMode::LowRank { rank: 6 });
        let y = svc.submit(req).unwrap().wait().unwrap();
        assert_eq!(y.len(), 5);
        assert!(y.contains(&0) && y.contains(&1) && y.contains(&2));
        // Policy gates modes per tenant, live.
        svc.set_mode_policy(t, ModePolicy::exact_only()).unwrap();
        match svc.sample_mode(t, 2, SampleMode::Map) {
            Err(Error::Rejected(m)) => assert!(m.contains("policy"), "{m}"),
            other => panic!("expected policy rejection, got {other:?}"),
        }
        assert_eq!(svc.sample_mode(t, 2, SampleMode::Exact).unwrap().len(), 2);
        // Re-opening the policy restores service.
        svc.set_mode_policy(t, ModePolicy::allow_all()).unwrap();
        assert_eq!(svc.sample_mode(t, 2, SampleMode::Map).unwrap().len(), 2);
        assert_eq!(svc.metrics().accepted.load(Ordering::Relaxed), 4);
        svc.shutdown();
    }

    #[test]
    fn expired_deadline_fast_rejects_at_admission() {
        let svc = DppService::start(&test_kernel(2, 2, 40), &small_cfg(), 41).unwrap();
        let past = Instant::now() - Duration::from_millis(5);
        match svc.submit(SampleRequest::new(2).with_deadline(past)) {
            Err(Error::Deadline(m)) => assert!(m.contains("before admission"), "{m}"),
            other => panic!("expected deadline rejection, got {other:?}"),
        }
        // Never accepted, never a queue slot: only deadline_exceeded moves.
        assert_eq!(svc.metrics().accepted.load(Ordering::Relaxed), 0);
        assert_eq!(svc.metrics().deadline_exceeded.load(Ordering::Relaxed), 1);
        let e = svc.registry().entry(TenantId::DEFAULT).unwrap();
        assert_eq!(e.metrics().deadline_exceeded.load(Ordering::Relaxed), 1);
        // A generous deadline still serves.
        let y = svc
            .submit(SampleRequest::new(2).with_budget(Duration::from_secs(30)))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(y.len(), 2);
        svc.shutdown();
    }

    #[test]
    fn tight_budget_expires_at_the_worker_and_counts() {
        // A long batch window + a budget far smaller than it: the request
        // is accepted, then expires in the queue and the worker fails it
        // with the distinct Deadline class.
        let mut cfg = small_cfg();
        cfg.workers = 1;
        cfg.max_batch = 64;
        cfg.batch_window_us = 100_000; // 100ms window
        let svc = DppService::start(&test_kernel(2, 2, 42), &cfg, 43).unwrap();
        let t = svc
            .submit(SampleRequest::new(2).with_budget(Duration::from_millis(1)))
            .unwrap();
        match t.wait() {
            Err(Error::Deadline(m)) => assert!(m.contains("budget exhausted"), "{m}"),
            other => panic!("expected worker-side deadline, got {other:?}"),
        }
        let m = svc.metrics();
        assert_eq!(m.accepted.load(Ordering::Relaxed), 1);
        assert_eq!(m.deadline_exceeded.load(Ordering::Relaxed), 1);
        assert_eq!(m.completed.load(Ordering::Relaxed), 0);
        assert_eq!(m.failed.load(Ordering::Relaxed), 0);
        assert!(svc.report().contains("deadline_exceeded=1"));
        svc.shutdown();
    }

    #[test]
    fn default_budget_applies_to_undeadlined_requests() {
        let mut cfg = small_cfg();
        cfg.workers = 1;
        cfg.max_batch = 64;
        cfg.batch_window_us = 200_000; // batch window dwarfs the budget
        cfg.default_budget_ms = 1;
        let svc = DppService::start(&test_kernel(2, 2, 44), &cfg, 45).unwrap();
        let t = svc.submit(SampleRequest::new(2)).unwrap();
        match t.wait() {
            Err(Error::Deadline(_)) => {}
            other => panic!("expected default-budget expiry, got {other:?}"),
        }
        assert_eq!(svc.metrics().deadline_exceeded.load(Ordering::Relaxed), 1);
        svc.shutdown();
    }

    #[test]
    fn forced_degradation_serves_exact_requests_through_fallback() {
        let mut cfg = small_cfg();
        cfg.workers = 1;
        let svc = DppService::start(&test_kernel(3, 3, 46), &cfg, 47).unwrap();
        let t = TenantId::DEFAULT;
        svc.force_degraded(t, true).unwrap();
        for _ in 0..4 {
            let y = svc.sample(3).unwrap();
            assert_eq!(y.len(), 3);
        }
        let served = svc.metrics().fallback.served();
        assert_eq!(served, 4, "forced-degraded serves must ride a fallback rung");
        // The first rung (regularized exact) is healthy here, so all
        // degraded serves land on it and no probes fire while forced.
        assert_eq!(svc.metrics().fallback.regularized.load(Ordering::Relaxed), 4);
        assert_eq!(svc.metrics().fallback.probes.load(Ordering::Relaxed), 0);
        let e = svc.registry().entry(t).unwrap();
        assert_eq!(e.metrics().fallback_served.load(Ordering::Relaxed), 4);
        assert_eq!(e.breaker_state(), "forced");
        // Releasing the pin restores the primary path.
        svc.force_degraded(t, false).unwrap();
        assert_eq!(svc.sample(3).unwrap().len(), 3);
        assert_eq!(svc.metrics().fallback.served(), 4);
        assert!(svc.report().contains("fallback: probes=0 regularized=4"));
        svc.shutdown();
    }

    #[test]
    fn injected_exact_failures_trip_breaker_and_fallback_serves() {
        let mut cfg = small_cfg();
        cfg.workers = 1;
        cfg.max_batch = 1; // one group per serve: deterministic accounting
        cfg.batch_window_us = 0;
        cfg.fallback.breaker_threshold = 2;
        cfg.fallback.probe_every = 2;
        let kernel = test_kernel(3, 3, 48);
        let registry = Arc::new(KernelRegistry::new(0));
        let t = registry.add_tenant("default", &kernel).unwrap();
        let plan = Arc::new(FaultPlan::new(99).fail_exact(t, 3));
        let svc =
            DppService::start_with_registry_and_faults(registry, &cfg, 49, Arc::clone(&plan))
                .unwrap();
        // Every request still serves: injected primary failures divert to
        // the regularization rung.
        for _ in 0..6 {
            assert_eq!(svc.sample(2).unwrap().len(), 2);
        }
        let e = svc.registry().entry(t).unwrap();
        assert_eq!(plan.fired_exact(t), 3, "all injected faults consumed");
        // Failures 1+2 trip the breaker (threshold 2); failure 3 burns the
        // first half-open probe; the next probe succeeds and recovers.
        assert_eq!(e.breaker_trips(), 1);
        assert_eq!(e.breaker_recoveries(), 1);
        assert_eq!(e.breaker_state(), "closed");
        let m = svc.metrics();
        assert_eq!(m.completed.load(Ordering::Relaxed), 6);
        assert_eq!(m.failed.load(Ordering::Relaxed), 0);
        assert_eq!(
            m.fallback.regularized.load(Ordering::Relaxed),
            m.fallback.served()
        );
        assert!(m.fallback.served() >= 3, "each injected failure must fall back");
        svc.shutdown();
    }

    #[test]
    fn worker_panic_fails_only_its_group_and_respawns() {
        let mut cfg = small_cfg();
        cfg.workers = 1;
        cfg.max_batch = 1;
        cfg.batch_window_us = 0;
        let kernel = test_kernel(2, 2, 50);
        let registry = Arc::new(KernelRegistry::new(0));
        let t = registry.add_tenant("default", &kernel).unwrap();
        let plan = Arc::new(FaultPlan::new(7).panic_worker(t, 1));
        let svc =
            DppService::start_with_registry_and_faults(registry, &cfg, 51, Arc::clone(&plan))
                .unwrap();
        // First request hits the injected panic: its ticket still gets a
        // definitive error (never a hang, never a disconnect).
        match svc.sample(2) {
            Err(Error::Service(m)) => assert!(m.contains("panicked"), "{m}"),
            other => panic!("expected a contained panic failure, got {other:?}"),
        }
        // The respawned worker serves the next request on the same channel.
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            match svc.sample(2) {
                Ok(y) => {
                    assert_eq!(y.len(), 2);
                    break;
                }
                Err(e) => {
                    // The respawn may still be in flight; only the
                    // worker-unavailable window is acceptable, briefly.
                    assert!(Instant::now() < deadline, "respawn never landed: {e}");
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
        }
        let m = svc.metrics();
        assert_eq!(m.worker_panics.load(Ordering::Relaxed), 1);
        assert_eq!(m.worker_respawns.load(Ordering::Relaxed), 1);
        assert_eq!(plan.fired_panics(t), 1);
        assert!(svc.report().contains("worker_panics=1"));
        svc.shutdown();
    }
}
