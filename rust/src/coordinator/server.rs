//! The serving coordinator: a multi-tenant diverse-subset sampling service.
//!
//! This is the production face of KronDPP (the paper's motivating
//! recommender application): clients submit "give me k diverse items from
//! catalog T" requests — optionally constrained ("the user already picked
//! items A, never show items B": a [`Constraint`] rides on the
//! [`SampleRequest`]); the service validates them at admission
//! ([`DppService::submit`] fails fast on unknown tenants, oversized `k`
//! and unsatisfiable constraints), batches them ([`super::batcher`]),
//! routes each tenant-group to the least-loaded worker
//! ([`super::router`]), and each worker draws exact DPP/k-DPP samples
//! from the tenant's current [`super::registry::SamplerEpoch`] — an
//! `Arc`-published kernel + cached eigendecomposition + factored
//! marginal-diagonal table grabbed from the [`KernelRegistry`] without
//! ever blocking on writers. Each request also carries a [`SampleMode`]
//! — the fidelity knob of the sampler zoo ([`crate::dpp::backend`]):
//! exact spectral draws, MCMC chains, low-rank spectral projection, or a
//! deterministic greedy MAP slate ([`crate::dpp::map`]). Admission
//! checks the mode against the tenant's [`ModePolicy`] and the mode's
//! parameters against the ground set; workers coalesce by
//! `(tenant, k, constraint, mode)` so repeated slate contexts share one
//! conditioning setup ([`crate::dpp::ConditionedSampler`], built through
//! per-worker [`ConditionScratch`]es), one MCMC/low-rank backend build,
//! or one greedy MAP slate. Learning jobs ([`super::jobs`])
//! hot-swap refreshed kernels into their target tenant while requests
//! keep flowing: in-flight draws finish on the epoch they started with.
//!
//! Threading: one pump thread runs the batch policy and splits each batch
//! by tenant; `workers` threads consume per-worker channels; requests
//! carry a oneshot-style mpsc response channel. Backpressure is a hard
//! queue-capacity bound — beyond it, `submit` fails fast instead of
//! growing latency unboundedly. Within a dispatched tenant-group, workers
//! coalesce same-`k` jobs so one per-tenant elementary-DP table serves the
//! whole group; the engine's one-RNG-stream-per-draw guarantee
//! ([`crate::dpp::Sampler::sample_batch`]) is untouched by tenant count.

use crate::config::ServiceConfig;
use crate::coordinator::batcher::{coalesce_by_key, BatchPolicy, BatchQueue, Pending};
use crate::coordinator::metrics::ServiceMetrics;
use crate::coordinator::registry::{KernelRegistry, ModePolicy, TenantEntry, TenantId};
use crate::coordinator::router::WorkerLoad;
use crate::dpp::map::{map_slate_into, MapScratch};
use crate::dpp::{
    ConditionScratch, ConditionedSampler, Constraint, Kernel, LowRankBackend, McmcBackend,
    SampleMode, SampleScratch, SamplerBackend,
};
use crate::error::{Error, Result};
use crate::rng::Rng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One sampling request against a tenant: `k = 0` draws an unconstrained
/// DPP sample, `k > 0` a k-DPP sample of exactly that size (`k` counts
/// any forced include items). An optional [`Constraint`] conditions the
/// draw on `A ⊆ Y, B ∩ Y = ∅` — the slate-filling scenario: items the
/// user already picked, items never to show.
#[derive(Clone, Debug)]
pub struct SampleRequest {
    /// Target tenant (resolve names via [`DppService::tenant`]).
    pub tenant: TenantId,
    pub k: usize,
    /// Optional conditioning constraint; `None` (or an empty constraint,
    /// normalized away at admission) draws unconditioned samples.
    pub constraint: Option<Constraint>,
    /// Which backend of the sampler zoo serves the draw — exact spectral
    /// sampling by default; MCMC / low-rank trade fidelity for cost;
    /// [`SampleMode::Map`] returns the deterministic greedy MAP slate
    /// (`k = 0` auto-sizes it).
    pub mode: SampleMode,
}

impl SampleRequest {
    /// Request against the default tenant (single-tenant deployments).
    pub fn new(k: usize) -> Self {
        SampleRequest {
            tenant: TenantId::DEFAULT,
            k,
            constraint: None,
            mode: SampleMode::Exact,
        }
    }

    /// Request against a specific tenant.
    pub fn for_tenant(tenant: TenantId, k: usize) -> Self {
        SampleRequest { tenant, k, constraint: None, mode: SampleMode::Exact }
    }

    /// Attach a conditioning constraint (builder style).
    pub fn with_constraint(mut self, constraint: Constraint) -> Self {
        self.constraint = Some(constraint);
        self
    }

    /// Select a sampling backend (builder style).
    pub fn with_mode(mut self, mode: SampleMode) -> Self {
        self.mode = mode;
        self
    }
}

struct Job {
    req: SampleRequest,
    /// Resolved at admission so workers and metrics never re-lock the
    /// registry name table.
    entry: Arc<TenantEntry>,
    respond: mpsc::Sender<Result<Vec<usize>>>,
    accepted: Instant,
}

/// Handle to a pending response.
pub struct Ticket {
    rx: mpsc::Receiver<Result<Vec<usize>>>,
}

impl Ticket {
    /// Block until the sample is ready.
    pub fn wait(self) -> Result<Vec<usize>> {
        self.rx
            .recv()
            .map_err(|_| Error::Service("service dropped the request".into()))?
    }

    /// Wait with a timeout.
    pub fn wait_timeout(self, d: Duration) -> Result<Vec<usize>> {
        match self.rx.recv_timeout(d) {
            Ok(r) => r,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                Err(Error::Service("request timed out".into()))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(Error::Service("service dropped the request".into()))
            }
        }
    }
}

struct Shared {
    queue: Mutex<BatchQueue<Job>>,
    cv: Condvar,
    /// The multi-tenant kernel registry: epoch publication, LRU eviction
    /// and the writer-side swap scratch all live here.
    registry: Arc<KernelRegistry>,
    metrics: ServiceMetrics,
    shutdown: AtomicBool,
    capacity: usize,
}

/// The running service.
pub struct DppService {
    shared: Arc<Shared>,
    pump: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    worker_txs: Vec<mpsc::Sender<Vec<Job>>>,
    loads: WorkerLoad,
}

impl DppService {
    /// Start the service with `kernel` as the "default" tenant, plus any
    /// tenants declared in `cfg` (each provisioned with a synthetic
    /// paper-style KronDPP from its spec — production callers publish
    /// learned kernels over them).
    pub fn start(kernel: &Kernel, cfg: &ServiceConfig, seed: u64) -> Result<Self> {
        let registry = Arc::new(KernelRegistry::new(cfg.max_resident_epochs));
        registry.add_tenant("default", kernel)?;
        for spec in &cfg.tenants {
            let mut rng = Rng::new(spec.seed);
            let k = crate::data::paper_truth_kernel(spec.n1, spec.n2, &mut rng);
            registry.add_tenant(&spec.name, &k)?;
        }
        Self::start_with_registry(registry, cfg, seed)
    }

    /// Start the service over a pre-populated registry (multi-tenant
    /// deployments that build their own tenants/kernels).
    pub fn start_with_registry(
        registry: Arc<KernelRegistry>,
        cfg: &ServiceConfig,
        seed: u64,
    ) -> Result<Self> {
        if registry.is_empty() {
            return Err(Error::Invalid("registry has no tenants".into()));
        }
        let shared = Arc::new(Shared {
            queue: Mutex::new(BatchQueue::new(BatchPolicy {
                max_batch: cfg.max_batch,
                window: Duration::from_micros(cfg.batch_window_us),
            })),
            cv: Condvar::new(),
            registry,
            metrics: ServiceMetrics::new(),
            shutdown: AtomicBool::new(false),
            capacity: cfg.queue_capacity,
        });
        let loads = WorkerLoad::new(cfg.workers);
        let mut worker_txs = Vec::with_capacity(cfg.workers);
        let mut workers = Vec::with_capacity(cfg.workers);
        let mut seeder = Rng::new(seed);
        for w in 0..cfg.workers {
            let (tx, rx) = mpsc::channel::<Vec<Job>>();
            worker_txs.push(tx);
            let shared2 = Arc::clone(&shared);
            let loads2 = loads.clone();
            let mut rng = seeder.split(w as u64);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("krondpp-sampler-{w}"))
                    .spawn(move || worker_loop(w, rx, shared2, loads2, &mut rng))
                    .map_err(Error::Io)?,
            );
        }
        let pump = {
            let shared2 = Arc::clone(&shared);
            let txs = worker_txs.clone();
            let loads2 = loads.clone();
            std::thread::Builder::new()
                .name("krondpp-pump".into())
                .spawn(move || pump_loop(shared2, txs, loads2))
                .map_err(Error::Io)?
        };
        Ok(DppService { shared, pump: Some(pump), workers, worker_txs, loads })
    }

    /// The underlying registry (for direct publishes, gauges, tenants).
    pub fn registry(&self) -> &Arc<KernelRegistry> {
        &self.shared.registry
    }

    /// Resolve a tenant name to its id.
    pub fn tenant(&self, name: &str) -> Result<TenantId> {
        self.shared
            .registry
            .resolve(name)
            .ok_or_else(|| Error::Rejected(format!("unknown tenant '{name}'")))
    }

    /// Register a new tenant on the live service.
    pub fn add_tenant(&self, name: &str, kernel: &Kernel) -> Result<TenantId> {
        self.shared.registry.add_tenant(name, kernel)
    }

    /// Submit a request; fails fast on admission errors (unknown tenant,
    /// `k` larger than the tenant's current ground set, an unsatisfiable
    /// or out-of-bounds [`Constraint`] — these return [`Error::Rejected`]
    /// without burning a queue slot) and under backpressure.
    pub fn submit(&self, req: SampleRequest) -> Result<Ticket> {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return Err(Error::Service("service is shut down".into()));
        }
        let mut req = req;
        let entry = match self.shared.registry.entry(req.tenant) {
            Ok(e) => e,
            Err(e) => {
                self.shared.metrics.rejected_invalid.fetch_add(1, Ordering::Relaxed);
                return Err(e);
            }
        };
        let n = entry.n();
        let reject = |msg: String| {
            self.shared.metrics.rejected_invalid.fetch_add(1, Ordering::Relaxed);
            entry.metrics().rejected_invalid.fetch_add(1, Ordering::Relaxed);
            Err(Error::Rejected(format!("tenant '{}': {msg}", entry.name())))
        };
        if req.k > n {
            return reject(format!("requested k={} > ground set {n}", req.k));
        }
        // Normalize the empty constraint away so workers coalesce it with
        // plain requests; validate real constraints against the tenant's
        // current ground set (the slate must fit include/exclude).
        if req.constraint.as_ref().is_some_and(|c| c.is_empty()) {
            req.constraint = None;
        }
        if let Some(c) = &req.constraint {
            let check =
                if req.k > 0 { c.validate_k(req.k, n) } else { c.validate(n) };
            if let Err(e) = check {
                let msg = match e {
                    Error::Invalid(m) => m,
                    other => other.to_string(),
                };
                return reject(msg);
            }
        }
        // Mode admission: the tenant's policy gates which backends it
        // serves, and mode parameters must be feasible against the current
        // ground set — both fail fast without burning a queue slot.
        if !entry.mode_policy().allows(req.mode) {
            return reject(format!(
                "mode '{}' disabled by tenant policy",
                req.mode.label()
            ));
        }
        match req.mode {
            SampleMode::Exact | SampleMode::Map => {}
            SampleMode::Mcmc { steps } => {
                if steps == 0 {
                    return reject("mcmc mode needs steps >= 1".into());
                }
            }
            SampleMode::LowRank { rank } => {
                if rank == 0 || rank > n {
                    return reject(format!("lowrank rank={rank} outside 1..={n}"));
                }
                // det L_r(Y) = 0 for |Y| > rank: the projection cannot
                // emit a slate larger than its rank.
                if req.k > rank {
                    return reject(format!(
                        "requested k={} exceeds projection rank {rank}",
                        req.k
                    ));
                }
            }
        }
        let (tx, rx) = mpsc::channel();
        {
            let mut q = self.shared.queue.lock().unwrap();
            if q.len() >= self.shared.capacity {
                self.shared.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(Error::Service(format!(
                    "queue full ({} requests)",
                    self.shared.capacity
                )));
            }
            let job =
                Job { req, entry: Arc::clone(&entry), respond: tx, accepted: Instant::now() };
            q.push(job, Instant::now());
            self.shared.metrics.accepted.fetch_add(1, Ordering::Relaxed);
            entry.metrics().accepted.fetch_add(1, Ordering::Relaxed);
        }
        self.shared.cv.notify_one();
        Ok(Ticket { rx })
    }

    /// Convenience: submit against the default tenant and wait.
    pub fn sample(&self, k: usize) -> Result<Vec<usize>> {
        self.submit(SampleRequest::new(k))?.wait()
    }

    /// Convenience: submit against `tenant` and wait.
    pub fn sample_tenant(&self, tenant: TenantId, k: usize) -> Result<Vec<usize>> {
        self.submit(SampleRequest::for_tenant(tenant, k))?.wait()
    }

    /// Convenience: submit a constrained request against `tenant` and
    /// wait — "user already picked `constraint.include()`, never show
    /// `constraint.exclude()`, fill the slate to `k` diverse items".
    pub fn sample_constrained(
        &self,
        tenant: TenantId,
        k: usize,
        constraint: Constraint,
    ) -> Result<Vec<usize>> {
        self.submit(SampleRequest::for_tenant(tenant, k).with_constraint(constraint))?.wait()
    }

    /// Convenience: submit against `tenant` with an explicit backend
    /// [`SampleMode`] and wait.
    pub fn sample_mode(
        &self,
        tenant: TenantId,
        k: usize,
        mode: SampleMode,
    ) -> Result<Vec<usize>> {
        self.submit(SampleRequest::for_tenant(tenant, k).with_mode(mode))?.wait()
    }

    /// Convenience: the deterministic greedy MAP slate for `tenant` —
    /// `k = 0` auto-sizes the slate (items are added while they increase
    /// `det L_Y`), an optional constraint forces/forbids items.
    pub fn map_slate(
        &self,
        tenant: TenantId,
        k: usize,
        constraint: Option<Constraint>,
    ) -> Result<Vec<usize>> {
        let mut req = SampleRequest::for_tenant(tenant, k).with_mode(SampleMode::Map);
        if let Some(c) = constraint {
            req = req.with_constraint(c);
        }
        self.submit(req)?.wait()
    }

    /// Restrict which sample modes `tenant` accepts — enforced at
    /// admission, swappable on the live service without republishing.
    pub fn set_mode_policy(&self, tenant: TenantId, policy: ModePolicy) -> Result<()> {
        self.shared.registry.set_mode_policy(tenant, policy)
    }

    /// All `N` inclusion probabilities `P(i ∈ Y) = K_ii` for `tenant`,
    /// served from the epoch's cached factored marginal-diagonal table —
    /// no eigen work, no dense `K`, no copy (an `Arc` clone of the
    /// epoch's table: the "relevance × diversity" scoring endpoint). A
    /// cold tenant lazily rebuilds its epoch first.
    pub fn marginals(&self, tenant: TenantId) -> Result<Arc<Vec<f64>>> {
        Ok(Arc::clone(&self.shared.registry.acquire(tenant)?.marginal_diag))
    }

    /// Hot-swap the default tenant's kernel (single-tenant deployments).
    /// The eigendecomposition happens on the caller's thread, off the read
    /// path; in-flight requests finish on the old epoch. Returns the new
    /// generation.
    pub fn update_kernel(&self, kernel: &Kernel) -> Result<u64> {
        self.publish(TenantId::DEFAULT, kernel)
    }

    /// Publish a refreshed kernel to `tenant` (e.g. from a learning job).
    /// Returns the tenant's new generation.
    pub fn publish(&self, tenant: TenantId, kernel: &Kernel) -> Result<u64> {
        self.shared.registry.publish(tenant, kernel)
    }

    /// Service metrics (global counters; per-tenant counters live on the
    /// registry entries).
    pub fn metrics(&self) -> &ServiceMetrics {
        &self.shared.metrics
    }

    /// Full report: global counters, registry gauge, per-tenant lines.
    pub fn report(&self) -> String {
        let mut out = self.shared.metrics.report();
        out.push_str("\n  registry: ");
        out.push_str(&self.shared.registry.report());
        for entry in self.shared.registry.entries() {
            out.push_str(&format!(
                "\n  tenant {} (gen {}): {}",
                entry.name(),
                entry.generation(),
                entry.metrics().summary()
            ));
        }
        out
    }

    /// Current total in-flight work across workers.
    pub fn in_flight(&self) -> usize {
        self.loads.total()
    }

    /// Current in-flight work for one tenant.
    pub fn tenant_in_flight(&self, tenant: TenantId) -> usize {
        self.shared
            .registry
            .entry(tenant)
            .map(|e| e.in_flight())
            .unwrap_or(0)
    }

    /// Stop accepting work, drain, and join all threads.
    pub fn shutdown(mut self) {
        self.do_shutdown();
    }

    fn do_shutdown(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        if let Some(p) = self.pump.take() {
            let _ = p.join();
        }
        // Close worker channels.
        self.worker_txs.clear();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for DppService {
    fn drop(&mut self) {
        if self.pump.is_some() {
            self.do_shutdown();
        }
    }
}

fn pump_loop(shared: Arc<Shared>, txs: Vec<mpsc::Sender<Vec<Job>>>, loads: WorkerLoad) {
    loop {
        let batch = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    // Drain everything to the workers before exiting.
                    let rest = q.drain_all();
                    drop(q);
                    if !rest.is_empty() {
                        dispatch(&shared, &txs, &loads, rest);
                    }
                    return;
                }
                let now = Instant::now();
                if let Some(batch) = q.pop_batch(now) {
                    break batch;
                }
                let wait = q
                    .next_deadline(now)
                    .unwrap_or(Duration::from_millis(50))
                    .max(Duration::from_micros(50));
                let (guard, _) = shared.cv.wait_timeout(q, wait).unwrap();
                q = guard;
            }
        };
        dispatch(&shared, &txs, &loads, batch);
    }
}

/// Split a popped batch by tenant and route each tenant-group to the
/// least-loaded worker (job-weighted, so uneven tenant-groups balance).
/// Keeping a tenant's jobs together is what lets the worker share one
/// epoch acquire and one elementary-DP table per `(tenant, k)` group.
fn dispatch(
    shared: &Arc<Shared>,
    txs: &[mpsc::Sender<Vec<Job>>],
    loads: &WorkerLoad,
    batch: Vec<Pending<Job>>,
) {
    if batch.is_empty() {
        return;
    }
    let now = Instant::now();
    for p in &batch {
        shared.metrics.queue_wait.record(now.duration_since(p.enqueued));
    }
    let jobs: Vec<Job> = batch.into_iter().map(|p| p.item).collect();
    for (_, group) in coalesce_by_key(jobs, |j| j.req.tenant) {
        shared.metrics.batches.fetch_add(1, Ordering::Relaxed);
        shared
            .metrics
            .batched_requests
            .fetch_add(group.len() as u64, Ordering::Relaxed);
        let n = group.len();
        let entry = Arc::clone(&group[0].entry);
        entry.in_flight.fetch_add(n, Ordering::SeqCst);
        let w = loads.pick();
        loads.begin_n(w, n);
        if let Err(mpsc::SendError(group)) = txs[w].send(group) {
            // Only reachable if the worker thread died (panic): fail the
            // group's jobs so accepted = completed + failed +
            // rejected_invalid stays exact and tickets get a real error
            // instead of a disconnect.
            loads.end_n(w, n);
            entry.in_flight.fetch_sub(n, Ordering::SeqCst);
            for job in group {
                finish(shared, job, Err(Error::Service("worker unavailable".into())));
            }
        }
    }
}

fn worker_loop(
    w: usize,
    rx: mpsc::Receiver<Vec<Job>>,
    shared: Arc<Shared>,
    loads: WorkerLoad,
    rng: &mut Rng,
) {
    // One scratch pair per worker: every draw this worker ever makes
    // reuses the same sample buffers (the batched engine's
    // zero-allocation hot path), and every conditioning setup reuses the
    // same bordered-block/eigensolver buffers.
    let mut scratch = SampleScratch::new();
    let mut cond_scratch = ConditionScratch::new();
    let mut map_scratch = MapScratch::new();
    let mut map_out = Vec::new();
    while let Ok(jobs) = rx.recv() {
        // The pump dispatches single-tenant groups: acquire the tenant's
        // current epoch once for the whole delivery (an `Arc` clone; a
        // cold tenant lazily rebuilds here, off every other tenant's path).
        let entry = Arc::clone(&jobs[0].entry);
        let n_jobs = jobs.len();
        match shared.registry.acquire_entry(&entry) {
            Err(e) => {
                let msg = format!("tenant '{}': epoch build failed: {e}", entry.name());
                for job in jobs {
                    finish(&shared, job, Err(Error::Service(msg.clone())));
                }
            }
            Ok(epoch) => {
                // Coalesce same-(k, constraint, mode) jobs so one phase-1
                // setup — and for conditioned groups one whole
                // conditioning setup (Schur assembly +
                // eigendecomposition), for MCMC/low-rank groups one
                // backend build, for MAP groups one deterministic slate —
                // serves repeated slate contexts instead of looping
                // single draws. The constraint fingerprint leads the key
                // so distinct slate contexts compare on one u64; the full
                // constraint follows as the exactness tiebreak (a
                // fingerprint collision can never merge different
                // constraints).
                for ((k, _fp, constraint, mode), group) in coalesce_by_key(jobs, |j| {
                    (
                        j.req.k,
                        j.req.constraint.as_ref().map(Constraint::fingerprint),
                        j.req.constraint.clone(),
                        j.req.mode,
                    )
                }) {
                    match (mode, constraint) {
                        (SampleMode::Exact, None) => {
                            serve_plain(&shared, &epoch, k, group, rng, &mut scratch)
                        }
                        (SampleMode::Exact, Some(c)) => serve_conditioned(
                            &shared,
                            &epoch,
                            k,
                            c,
                            group,
                            rng,
                            &mut scratch,
                            &mut cond_scratch,
                        ),
                        (SampleMode::Mcmc { steps }, constraint) => serve_mcmc(
                            &shared,
                            &epoch,
                            k,
                            constraint,
                            steps,
                            group,
                            rng,
                            &mut scratch,
                        ),
                        (SampleMode::LowRank { rank }, constraint) => serve_low_rank(
                            &shared,
                            &epoch,
                            k,
                            constraint,
                            rank,
                            group,
                            rng,
                            &mut scratch,
                        ),
                        (SampleMode::Map, constraint) => serve_map(
                            &shared,
                            &epoch,
                            k,
                            constraint,
                            group,
                            &mut map_scratch,
                            &mut map_out,
                        ),
                    }
                }
            }
        }
        entry.in_flight.fetch_sub(n_jobs, Ordering::SeqCst);
        loads.end_n(w, n_jobs);
    }
}

/// Serve one unconstrained `(tenant, k)` group from its epoch.
fn serve_plain(
    shared: &Arc<Shared>,
    epoch: &crate::coordinator::registry::SamplerEpoch,
    k: usize,
    group: Vec<Job>,
    rng: &mut Rng,
    scratch: &mut SampleScratch,
) {
    let sampler = &epoch.sampler;
    if k > sampler.n() {
        // Admission raced a shrinking publish; reject late with the same
        // distinct error class.
        for job in group {
            finish(
                shared,
                job,
                Err(Error::Rejected(format!(
                    "tenant '{}': requested k={k} > ground set {} (gen {})",
                    epoch.name,
                    sampler.n(),
                    epoch.generation
                ))),
            );
        }
        return;
    }
    // Respond per draw (not per group) so coalescing never inflates
    // head-of-group latency beyond a single draw.
    if k == 0 {
        for job in group {
            let y = sampler.sample_with_scratch(rng, scratch);
            finish(shared, job, Ok(y));
        }
    } else {
        let n = group.len();
        let mut jobs = group.into_iter();
        sampler.sample_k_each(k, n, rng, scratch, |y| {
            let job = jobs.next().expect("one job per draw");
            finish(shared, job, Ok(y));
        });
    }
}

/// Serve one conditioned `(tenant, k, constraint)` group: one conditioning
/// setup (counted in `conditioning_setups`) shared by every job in the
/// group, then per-draw responses like the plain path.
#[allow(clippy::too_many_arguments)]
fn serve_conditioned(
    shared: &Arc<Shared>,
    epoch: &crate::coordinator::registry::SamplerEpoch,
    k: usize,
    constraint: Constraint,
    group: Vec<Job>,
    rng: &mut Rng,
    scratch: &mut SampleScratch,
    cond_scratch: &mut ConditionScratch,
) {
    let cs = match ConditionedSampler::new_with_scratch(&epoch.kernel, constraint, cond_scratch)
    {
        Ok(cs) => cs,
        Err(e) => {
            // Out-of-bounds constraint (admission raced a shrinking
            // publish) or a zero-probability include set surface as
            // `Invalid`: the request is bad, not the service. Anything
            // else (e.g. eigensolver non-convergence, also `Numerical`)
            // is a service fault and counts in `failed`.
            let (reject, msg) = match e {
                Error::Invalid(m) => (
                    true,
                    format!("tenant '{}' (gen {}): {m}", epoch.name, epoch.generation),
                ),
                other => (
                    false,
                    format!("tenant '{}': conditioning setup failed: {other}", epoch.name),
                ),
            };
            for job in group {
                let err = if reject {
                    Error::Rejected(msg.clone())
                } else {
                    Error::Service(msg.clone())
                };
                finish(shared, job, Err(err));
            }
            return;
        }
    };
    shared.metrics.conditioning_setups.fetch_add(1, Ordering::Relaxed);
    if k > 0 && !(cs.min_k()..=cs.max_k()).contains(&k) {
        // Only reachable through a shrinking hot-swap race (admission
        // validated against the old ground set).
        for job in group {
            finish(
                shared,
                job,
                Err(Error::Rejected(format!(
                    "tenant '{}': constrained k={k} outside [{}, {}] (gen {})",
                    epoch.name,
                    cs.min_k(),
                    cs.max_k(),
                    epoch.generation
                ))),
            );
        }
        return;
    }
    let count_conditioned = |job: &Job| {
        shared.metrics.conditioned.fetch_add(1, Ordering::Relaxed);
        job.entry.metrics().conditioned.fetch_add(1, Ordering::Relaxed);
    };
    if k == 0 {
        for job in group {
            let y = cs.sample_with_scratch(rng, scratch);
            count_conditioned(&job);
            finish(shared, job, Ok(y));
        }
    } else {
        let n = group.len();
        let mut jobs = group.into_iter();
        cs.sample_k_each(k, n, rng, scratch, |y| {
            let job = jobs.next().expect("one job per draw");
            count_conditioned(&job);
            finish(shared, job, Ok(y));
        });
    }
}

/// Fail every job in a group on a backend-setup error, splitting
/// `Invalid` (a bad request surfacing late, e.g. a shrinking hot-swap
/// raced admission, or a zero-probability include set — `Rejected`) from
/// service faults (`Service`, counted in `failed`).
fn fail_group(
    shared: &Arc<Shared>,
    epoch: &crate::coordinator::registry::SamplerEpoch,
    what: &str,
    e: Error,
    group: Vec<Job>,
) {
    let (reject, msg) = match e {
        Error::Invalid(m) => {
            (true, format!("tenant '{}' (gen {}): {m}", epoch.name, epoch.generation))
        }
        other => (false, format!("tenant '{}': {what} failed: {other}", epoch.name)),
    };
    for job in group {
        let err = if reject {
            Error::Rejected(msg.clone())
        } else {
            Error::Service(msg.clone())
        };
        finish(shared, job, Err(err));
    }
}

/// Per-job draws against a zoo backend built once per coalesced group:
/// `Invalid` draw errors (a shrinking hot-swap raced admission) reject,
/// anything else is a service fault.
#[allow(clippy::too_many_arguments)]
fn serve_backend_draws<B: SamplerBackend>(
    shared: &Arc<Shared>,
    epoch: &crate::coordinator::registry::SamplerEpoch,
    backend: &B,
    k: usize,
    constrained: bool,
    group: Vec<Job>,
    rng: &mut Rng,
    scratch: &mut SampleScratch,
) {
    let k_opt = if k == 0 { None } else { Some(k) };
    for job in group {
        let mut y = Vec::new();
        let result = match backend.draw_into(k_opt, rng, scratch, &mut y) {
            Ok(()) => {
                if constrained {
                    shared.metrics.conditioned.fetch_add(1, Ordering::Relaxed);
                    job.entry.metrics().conditioned.fetch_add(1, Ordering::Relaxed);
                }
                Ok(y)
            }
            Err(Error::Invalid(m)) => Err(Error::Rejected(format!(
                "tenant '{}' (gen {}): {m}",
                epoch.name, epoch.generation
            ))),
            Err(other) => Err(Error::Service(format!(
                "tenant '{}': {} draw failed: {other}",
                epoch.name,
                backend.name()
            ))),
        };
        finish(shared, job, result);
    }
}

/// Serve one `(tenant, k, constraint, mcmc)` group: one chain-backend
/// build shared by the group, one independent `steps`-move chain per job.
#[allow(clippy::too_many_arguments)]
fn serve_mcmc(
    shared: &Arc<Shared>,
    epoch: &crate::coordinator::registry::SamplerEpoch,
    k: usize,
    constraint: Option<Constraint>,
    steps: usize,
    group: Vec<Job>,
    rng: &mut Rng,
    scratch: &mut SampleScratch,
) {
    let constrained = constraint.is_some();
    let backend = match McmcBackend::new(
        &epoch.kernel,
        constraint.unwrap_or_else(Constraint::none),
        steps,
    ) {
        Ok(b) => b,
        Err(e) => return fail_group(shared, epoch, "mcmc setup", e, group),
    };
    serve_backend_draws(shared, epoch, &backend, k, constrained, group, rng, scratch);
}

/// Serve one `(tenant, k, constraint, lowrank)` group: one `O(N·r)`
/// spectral-projection gather off the epoch's cached eigendecomposition
/// (no eigensolve), shared by every draw in the group.
#[allow(clippy::too_many_arguments)]
fn serve_low_rank(
    shared: &Arc<Shared>,
    epoch: &crate::coordinator::registry::SamplerEpoch,
    k: usize,
    constraint: Option<Constraint>,
    rank: usize,
    group: Vec<Job>,
    rng: &mut Rng,
    scratch: &mut SampleScratch,
) {
    let constrained = constraint.is_some();
    let backend = match LowRankBackend::from_eigen(
        epoch.sampler.eigen(),
        rank,
        constraint.unwrap_or_else(Constraint::none),
    ) {
        Ok(b) => b,
        Err(e) => return fail_group(shared, epoch, "lowrank setup", e, group),
    };
    if constrained {
        // The constrained projection conditions its truncated kernel —
        // one conditioning setup per coalesced group, like the exact path.
        shared.metrics.conditioning_setups.fetch_add(1, Ordering::Relaxed);
    }
    serve_backend_draws(shared, epoch, &backend, k, constrained, group, rng, scratch);
}

/// Serve one `(tenant, k, constraint, map)` group: greedy MAP is
/// deterministic, so the worker computes **one** slate per group (into
/// its per-worker [`MapScratch`] — allocation-free when warmed) and every
/// job in the group receives a copy.
fn serve_map(
    shared: &Arc<Shared>,
    epoch: &crate::coordinator::registry::SamplerEpoch,
    k: usize,
    constraint: Option<Constraint>,
    group: Vec<Job>,
    map_scratch: &mut MapScratch,
    out: &mut Vec<usize>,
) {
    let constrained = constraint.is_some();
    let c = constraint.unwrap_or_else(Constraint::none);
    let k_opt = if k == 0 { None } else { Some(k) };
    match map_slate_into(&epoch.kernel, k_opt, &c, map_scratch, out) {
        Ok(_logdet) => {
            for job in group {
                if constrained {
                    shared.metrics.conditioned.fetch_add(1, Ordering::Relaxed);
                    job.entry.metrics().conditioned.fetch_add(1, Ordering::Relaxed);
                }
                finish(shared, job, Ok(out.clone()));
            }
        }
        Err(e) => fail_group(shared, epoch, "map slate", e, group),
    }
}

/// Respond to one job and account for its outcome: every accepted request
/// ends in exactly one of `completed` (Ok — also counted into the global
/// and per-tenant per-mode counters), `rejected_invalid` (a shrinking
/// hot-swap raced the queue — worker-side `Error::Rejected`), or `failed`
/// (epoch build error), globally and per tenant.
fn finish(shared: &Shared, job: Job, result: Result<Vec<usize>>) {
    let elapsed = job.accepted.elapsed();
    shared.metrics.latency.record(elapsed);
    let tm = job.entry.metrics();
    tm.latency.record(elapsed);
    match &result {
        Ok(_) => {
            shared.metrics.completed.fetch_add(1, Ordering::Relaxed);
            shared.metrics.modes.count(job.req.mode);
            tm.completed.fetch_add(1, Ordering::Relaxed);
            tm.modes.count(job.req.mode);
        }
        Err(Error::Rejected(_)) => {
            shared.metrics.rejected_invalid.fetch_add(1, Ordering::Relaxed);
            tm.rejected_invalid.fetch_add(1, Ordering::Relaxed);
        }
        Err(_) => {
            shared.metrics.failed.fetch_add(1, Ordering::Relaxed);
            tm.failed.fetch_add(1, Ordering::Relaxed);
        }
    }
    let _ = job.respond.send(result);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    fn test_kernel(n1: usize, n2: usize, seed: u64) -> Kernel {
        let mut rng = Rng::new(seed);
        let mk = |n: usize, rng: &mut Rng| -> Matrix {
            let mut m = rng.paper_init_kernel(n);
            m.scale_mut(1.0 / n as f64);
            m.add_diag_mut(0.3);
            m
        };
        Kernel::Kron2(mk(n1, &mut rng), mk(n2, &mut rng))
    }

    fn small_cfg() -> ServiceConfig {
        ServiceConfig {
            workers: 2,
            max_batch: 4,
            batch_window_us: 200,
            queue_capacity: 64,
            ..ServiceConfig::default()
        }
    }

    #[test]
    fn serves_unconstrained_and_k_requests() {
        let svc = DppService::start(&test_kernel(3, 4, 1), &small_cfg(), 7).unwrap();
        let y = svc.sample(0).unwrap();
        assert!(y.iter().all(|&i| i < 12));
        let y5 = svc.sample(5).unwrap();
        assert_eq!(y5.len(), 5);
        svc.shutdown();
    }

    #[test]
    fn concurrent_requests_all_complete() {
        let svc = Arc::new(DppService::start(&test_kernel(3, 3, 2), &small_cfg(), 8).unwrap());
        let mut handles = Vec::new();
        for t in 0..8 {
            let svc2 = Arc::clone(&svc);
            handles.push(std::thread::spawn(move || {
                let mut ok = 0;
                for _ in 0..20 {
                    if svc2.sample((t % 3) + 1).is_ok() {
                        ok += 1;
                    }
                }
                ok
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 160);
        assert_eq!(
            svc.metrics().completed.load(Ordering::Relaxed),
            svc.metrics().accepted.load(Ordering::Relaxed)
        );
        assert!(svc.metrics().batches.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn coalesced_mixed_k_batch_serves_each_request() {
        // A burst with repeated k values coalesces into grouped draws; every
        // request must still get its own correctly-sized response.
        let mut cfg = small_cfg();
        cfg.max_batch = 16;
        cfg.batch_window_us = 5_000;
        let svc = DppService::start(&test_kernel(3, 4, 6), &cfg, 13).unwrap();
        let ks = [0usize, 3, 3, 5, 0, 3, 5, 1];
        let tickets: Vec<Ticket> =
            ks.iter().map(|&k| svc.submit(SampleRequest::new(k)).unwrap()).collect();
        for (k, t) in ks.iter().zip(tickets) {
            let y = t.wait().unwrap();
            if *k > 0 {
                assert_eq!(y.len(), *k);
            }
            assert!(y.iter().all(|&i| i < 12));
        }
        svc.shutdown();
    }

    #[test]
    fn multi_tenant_requests_route_to_their_kernels() {
        let mut cfg = small_cfg();
        cfg.max_batch = 16;
        cfg.batch_window_us = 2_000;
        let svc = DppService::start(&test_kernel(2, 2, 3), &cfg, 14).unwrap();
        let big = svc.add_tenant("big", &test_kernel(3, 4, 4)).unwrap();
        let deflt = svc.tenant("default").unwrap();
        assert_eq!(deflt, TenantId::DEFAULT);
        // Interleave tenants in one burst: the pump splits per tenant.
        let mut tickets = Vec::new();
        for i in 0..12usize {
            let (t, k) = if i % 2 == 0 { (deflt, 2) } else { (big, 7) };
            tickets.push((t, k, svc.submit(SampleRequest::for_tenant(t, k)).unwrap()));
        }
        for (t, k, ticket) in tickets {
            let y = ticket.wait().unwrap();
            assert_eq!(y.len(), k);
            let bound = if t == deflt { 4 } else { 12 };
            assert!(y.iter().all(|&i| i < bound), "tenant bound violated: {y:?}");
        }
        // Per-tenant accounting saw both tenants.
        let e = svc.registry().entry(big).unwrap();
        assert_eq!(e.metrics().completed.load(Ordering::Relaxed), 6);
        assert!(svc.report().contains("tenant big"));
        svc.shutdown();
    }

    #[test]
    fn constrained_requests_honor_include_exclude_and_share_setups() {
        let mut cfg = small_cfg();
        cfg.max_batch = 16;
        cfg.batch_window_us = 5_000;
        cfg.workers = 1;
        let svc = DppService::start(&test_kernel(3, 4, 20), &cfg, 21).unwrap();
        let c = Constraint::new(vec![0, 5], vec![3]).unwrap();
        // One burst of identical slate contexts: the worker coalesces them
        // into a single conditioning setup.
        let tickets: Vec<Ticket> = (0..8)
            .map(|_| {
                svc.submit(SampleRequest::new(5).with_constraint(c.clone())).unwrap()
            })
            .collect();
        for t in tickets {
            let y = t.wait().unwrap();
            assert_eq!(y.len(), 5);
            assert!(y.contains(&0) && y.contains(&5), "include violated: {y:?}");
            assert!(!y.contains(&3), "exclude violated: {y:?}");
            assert!(y.iter().all(|&i| i < 12));
        }
        assert_eq!(svc.metrics().conditioned.load(Ordering::Relaxed), 8);
        // One setup per dispatched batch of this slate context: typically 1
        // (one burst, one batch), never more than one per request even if
        // the pump's timing splits the burst.
        let setups = svc.metrics().conditioning_setups.load(Ordering::Relaxed);
        assert!(
            (1..=8).contains(&setups),
            "8 identical contexts produced {setups} conditioning setups"
        );
        let e = svc.registry().entry(TenantId::DEFAULT).unwrap();
        assert_eq!(e.metrics().conditioned.load(Ordering::Relaxed), 8);
        assert!(svc.report().contains("conditioned=8"));
        // An unconstrained and an empty-constraint request still serve.
        let y = svc.sample(4).unwrap();
        assert_eq!(y.len(), 4);
        let y = svc
            .submit(SampleRequest::new(2).with_constraint(Constraint::none()))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(y.len(), 2);
        svc.shutdown();
    }

    #[test]
    fn rejects_bad_constraints_at_admission() {
        let svc = DppService::start(&test_kernel(2, 2, 22), &small_cfg(), 23).unwrap();
        // Out-of-bounds item.
        let c = Constraint::including(vec![99]).unwrap();
        match svc.submit(SampleRequest::new(0).with_constraint(c)) {
            Err(Error::Rejected(m)) => assert!(m.contains("outside ground set"), "{m}"),
            other => panic!("expected admission rejection, got {other:?}"),
        }
        // Slate smaller than the forced include set.
        let c = Constraint::including(vec![0, 1, 2]).unwrap();
        match svc.submit(SampleRequest::new(2).with_constraint(c)) {
            Err(Error::Rejected(m)) => assert!(m.contains("smaller than"), "{m}"),
            other => panic!("expected admission rejection, got {other:?}"),
        }
        // Slate larger than what survives exclusion.
        let c = Constraint::excluding(vec![0, 1]).unwrap();
        match svc.submit(SampleRequest::new(3).with_constraint(c)) {
            Err(Error::Rejected(m)) => assert!(m.contains("surviving exclusion"), "{m}"),
            other => panic!("expected admission rejection, got {other:?}"),
        }
        assert_eq!(svc.metrics().rejected_invalid.load(Ordering::Relaxed), 3);
        assert_eq!(svc.metrics().accepted.load(Ordering::Relaxed), 0);
        svc.shutdown();
    }

    #[test]
    fn marginals_endpoint_serves_cached_table() {
        let kernel = test_kernel(3, 3, 24);
        let svc = DppService::start(&kernel, &small_cfg(), 25).unwrap();
        let got = svc.marginals(TenantId::DEFAULT).unwrap();
        let want = kernel.eigen().unwrap().inclusion_probabilities();
        assert_eq!(got.len(), 9);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-14);
        }
        svc.shutdown();
    }

    #[test]
    fn rejects_oversized_k_at_admission() {
        let svc = DppService::start(&test_kernel(2, 2, 3), &small_cfg(), 9).unwrap();
        match svc.sample(100) {
            Err(Error::Rejected(m)) => assert!(m.contains("k=100")),
            other => panic!("expected admission rejection, got {other:?}"),
        }
        // No queue slot burned: never accepted, counted as invalid.
        assert_eq!(svc.metrics().accepted.load(Ordering::Relaxed), 0);
        assert_eq!(svc.metrics().rejected_invalid.load(Ordering::Relaxed), 1);
        let e = svc.registry().entry(TenantId::DEFAULT).unwrap();
        assert_eq!(e.metrics().rejected_invalid.load(Ordering::Relaxed), 1);
        svc.shutdown();
    }

    #[test]
    fn rejects_unknown_tenant_at_admission() {
        let svc = DppService::start(&test_kernel(2, 2, 4), &small_cfg(), 10).unwrap();
        match svc.submit(SampleRequest::for_tenant(TenantId(7), 2)) {
            Err(Error::Rejected(m)) => assert!(m.contains("unknown tenant")),
            Err(other) => panic!("expected admission rejection, got {other:?}"),
            Ok(_) => panic!("expected admission rejection, got a ticket"),
        }
        assert!(svc.tenant("nope").is_err());
        assert_eq!(svc.metrics().rejected_invalid.load(Ordering::Relaxed), 1);
        assert_eq!(svc.metrics().accepted.load(Ordering::Relaxed), 0);
        svc.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let mut cfg = small_cfg();
        cfg.queue_capacity = 2;
        cfg.workers = 1;
        cfg.max_batch = 1;
        cfg.batch_window_us = 0;
        let svc = DppService::start(&test_kernel(3, 3, 4), &cfg, 10).unwrap();
        // Flood without waiting; some must be rejected.
        let mut tickets = Vec::new();
        let mut rejected = 0;
        for _ in 0..200 {
            match svc.submit(SampleRequest::new(3)) {
                Ok(t) => tickets.push(t),
                Err(_) => rejected += 1,
            }
        }
        for t in tickets {
            let _ = t.wait();
        }
        // Either we saw rejections, or the worker kept up; metrics must
        // agree with what we observed.
        assert_eq!(svc.metrics().rejected.load(Ordering::Relaxed), rejected as u64);
        assert_eq!(svc.metrics().rejected_invalid.load(Ordering::Relaxed), 0);
        svc.shutdown();
    }

    #[test]
    fn kernel_hot_swap_changes_ground_set() {
        let svc = DppService::start(&test_kernel(2, 2, 5), &small_cfg(), 11).unwrap();
        let y = svc.sample(2).unwrap();
        assert!(y.iter().all(|&i| i < 4));
        let generation = svc.update_kernel(&test_kernel(3, 4, 6)).unwrap();
        assert_eq!(generation, 2);
        let y2 = svc.sample(8).unwrap();
        assert_eq!(y2.len(), 8);
        assert!(y2.iter().any(|&i| i >= 4), "new kernel should expose items ≥ 4");
        svc.shutdown();
    }

    #[test]
    fn config_declared_tenants_are_provisioned() {
        let mut cfg = small_cfg();
        cfg.tenants = vec![
            crate::config::TenantSpec { name: "eu".into(), n1: 3, n2: 3, seed: 1 },
            crate::config::TenantSpec { name: "us".into(), n1: 2, n2: 4, seed: 2 },
        ];
        let svc = DppService::start(&test_kernel(2, 2, 7), &cfg, 12).unwrap();
        assert_eq!(
            svc.registry().tenant_names(),
            vec!["default".to_string(), "eu".into(), "us".into()]
        );
        let eu = svc.tenant("eu").unwrap();
        let y = svc.sample_tenant(eu, 4).unwrap();
        assert_eq!(y.len(), 4);
        assert!(y.iter().all(|&i| i < 9));
        svc.shutdown();
    }

    #[test]
    fn shutdown_drains_pending() {
        let svc = DppService::start(&test_kernel(3, 3, 7), &small_cfg(), 12).unwrap();
        let tickets: Vec<Ticket> =
            (0..16).map(|_| svc.submit(SampleRequest::new(2)).unwrap()).collect();
        svc.shutdown();
        let mut done = 0;
        for t in tickets {
            if t.wait_timeout(Duration::from_secs(2)).is_ok() {
                done += 1;
            }
        }
        assert_eq!(done, 16, "shutdown dropped pending requests");
    }

    #[test]
    fn mode_requests_serve_and_count_per_mode() {
        let mut cfg = small_cfg();
        cfg.workers = 1;
        let svc = DppService::start(&test_kernel(3, 4, 30), &cfg, 31).unwrap();
        let t = TenantId::DEFAULT;
        let y = svc.sample_mode(t, 4, SampleMode::Exact).unwrap();
        assert_eq!(y.len(), 4);
        let y = svc.sample_mode(t, 3, SampleMode::Mcmc { steps: 40 }).unwrap();
        assert_eq!(y.len(), 3);
        assert!(y.windows(2).all(|w| w[0] < w[1]));
        assert!(y.iter().all(|&i| i < 12));
        let y = svc.sample_mode(t, 2, SampleMode::LowRank { rank: 5 }).unwrap();
        assert_eq!(y.len(), 2);
        let y = svc.sample_mode(t, 4, SampleMode::Map).unwrap();
        assert_eq!(y.len(), 4);
        let m = svc.metrics();
        assert_eq!(m.modes.get(SampleMode::Exact), 1);
        assert_eq!(m.modes.get(SampleMode::Mcmc { steps: 40 }), 1);
        assert_eq!(m.modes.get(SampleMode::LowRank { rank: 5 }), 1);
        assert_eq!(m.modes.get(SampleMode::Map), 1);
        let e = svc.registry().entry(t).unwrap();
        assert_eq!(e.metrics().modes.get(SampleMode::Map), 1);
        assert!(svc.report().contains("modes: exact=1 mcmc=1 lowrank=1 map=1"));
        svc.shutdown();
    }

    #[test]
    fn map_mode_is_deterministic_and_respects_constraints() {
        let mut cfg = small_cfg();
        cfg.max_batch = 8;
        cfg.batch_window_us = 5_000;
        let svc = DppService::start(&test_kernel(3, 4, 32), &cfg, 33).unwrap();
        let t = TenantId::DEFAULT;
        let a = svc.map_slate(t, 5, None).unwrap();
        let b = svc.map_slate(t, 5, None).unwrap();
        assert_eq!(a, b, "greedy MAP must be deterministic");
        assert_eq!(a.len(), 5);
        assert!(a.windows(2).all(|w| w[0] < w[1]));
        let c = Constraint::new(vec![2], vec![0, 7]).unwrap();
        let y = svc.map_slate(t, 4, Some(c)).unwrap();
        assert_eq!(y.len(), 4);
        assert!(y.contains(&2), "include violated: {y:?}");
        assert!(!y.contains(&0) && !y.contains(&7), "exclude violated: {y:?}");
        assert_eq!(svc.metrics().conditioned.load(Ordering::Relaxed), 1);
        // Auto-sized slate: k = 0 lets the greedy stop on its own.
        let y = svc.map_slate(t, 0, None).unwrap();
        assert!(y.windows(2).all(|w| w[0] < w[1]));
        assert!(y.iter().all(|&i| i < 12));
        svc.shutdown();
    }

    #[test]
    fn mode_policy_and_bad_mode_parameters_reject_at_admission() {
        let svc = DppService::start(&test_kernel(3, 3, 34), &small_cfg(), 35).unwrap();
        let t = TenantId::DEFAULT;
        // Parameter validation against the 9-item ground set.
        match svc.sample_mode(t, 2, SampleMode::Mcmc { steps: 0 }) {
            Err(Error::Rejected(m)) => assert!(m.contains("steps"), "{m}"),
            other => panic!("expected rejection, got {other:?}"),
        }
        match svc.sample_mode(t, 2, SampleMode::LowRank { rank: 0 }) {
            Err(Error::Rejected(m)) => assert!(m.contains("rank"), "{m}"),
            other => panic!("expected rejection, got {other:?}"),
        }
        match svc.sample_mode(t, 2, SampleMode::LowRank { rank: 99 }) {
            Err(Error::Rejected(m)) => assert!(m.contains("rank"), "{m}"),
            other => panic!("expected rejection, got {other:?}"),
        }
        match svc.sample_mode(t, 5, SampleMode::LowRank { rank: 3 }) {
            Err(Error::Rejected(m)) => assert!(m.contains("projection rank"), "{m}"),
            other => panic!("expected rejection, got {other:?}"),
        }
        assert_eq!(svc.metrics().accepted.load(Ordering::Relaxed), 0);
        // A constrained low-rank request within the rank budget serves.
        let c = Constraint::including(vec![0, 1, 2]).unwrap();
        let req = SampleRequest::new(5)
            .with_constraint(c)
            .with_mode(SampleMode::LowRank { rank: 6 });
        let y = svc.submit(req).unwrap().wait().unwrap();
        assert_eq!(y.len(), 5);
        assert!(y.contains(&0) && y.contains(&1) && y.contains(&2));
        // Policy gates modes per tenant, live.
        svc.set_mode_policy(t, ModePolicy::exact_only()).unwrap();
        match svc.sample_mode(t, 2, SampleMode::Map) {
            Err(Error::Rejected(m)) => assert!(m.contains("policy"), "{m}"),
            other => panic!("expected policy rejection, got {other:?}"),
        }
        assert_eq!(svc.sample_mode(t, 2, SampleMode::Exact).unwrap().len(), 2);
        // Re-opening the policy restores service.
        svc.set_mode_policy(t, ModePolicy::allow_all()).unwrap();
        assert_eq!(svc.sample_mode(t, 2, SampleMode::Map).unwrap().len(), 2);
        assert_eq!(svc.metrics().accepted.load(Ordering::Relaxed), 4);
        svc.shutdown();
    }
}
