//! The serving coordinator: a diverse-subset sampling service.
//!
//! This is the production face of KronDPP (the paper's motivating
//! recommender application): clients submit "give me k diverse items"
//! requests; the service batches them ([`super::batcher`]), routes batches
//! to the least-loaded worker ([`super::router`]), and each worker draws
//! exact DPP/k-DPP samples from the current kernel's cached
//! eigendecomposition. Learning jobs ([`super::jobs`]) hot-swap refreshed
//! kernels without stopping the service.
//!
//! Threading: one pump thread runs the batch policy; `workers` threads
//! consume per-worker channels; requests carry a oneshot-style mpsc
//! response channel. Backpressure is a hard queue-capacity bound — beyond
//! it, `submit` fails fast instead of growing latency unboundedly.

use crate::config::ServiceConfig;
use crate::coordinator::batcher::{coalesce_by_key, BatchPolicy, BatchQueue, Pending};
use crate::coordinator::metrics::ServiceMetrics;
use crate::coordinator::router::WorkerLoad;
use crate::dpp::{Kernel, SampleScratch, Sampler};
use crate::error::{Error, Result};
use crate::rng::Rng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One sampling request: `k = 0` draws an unconstrained DPP sample,
/// `k > 0` a k-DPP sample of exactly that size.
#[derive(Clone, Copy, Debug)]
pub struct SampleRequest {
    pub k: usize,
}

struct Job {
    req: SampleRequest,
    respond: mpsc::Sender<Result<Vec<usize>>>,
    accepted: Instant,
}

/// Handle to a pending response.
pub struct Ticket {
    rx: mpsc::Receiver<Result<Vec<usize>>>,
}

impl Ticket {
    /// Block until the sample is ready.
    pub fn wait(self) -> Result<Vec<usize>> {
        self.rx
            .recv()
            .map_err(|_| Error::Service("service dropped the request".into()))?
    }

    /// Wait with a timeout.
    pub fn wait_timeout(self, d: Duration) -> Result<Vec<usize>> {
        match self.rx.recv_timeout(d) {
            Ok(r) => r,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                Err(Error::Service("request timed out".into()))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(Error::Service("service dropped the request".into()))
            }
        }
    }
}

struct Shared {
    queue: Mutex<BatchQueue<Job>>,
    cv: Condvar,
    sampler: RwLock<Arc<Sampler>>,
    metrics: ServiceMetrics,
    shutdown: AtomicBool,
    capacity: usize,
    /// Kernel-assembly workspace for hot swaps: repeated `update_kernel`
    /// calls re-eigendecompose through one reused scratch (panels,
    /// rotation buffers, GEMM pack buffers) instead of reallocating.
    swap_scratch: Mutex<SampleScratch>,
}

/// The running service.
pub struct DppService {
    shared: Arc<Shared>,
    pump: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    worker_txs: Vec<mpsc::Sender<Vec<Job>>>,
    loads: WorkerLoad,
}

impl DppService {
    /// Start the service over an initial kernel.
    pub fn start(kernel: &Kernel, cfg: &ServiceConfig, seed: u64) -> Result<Self> {
        let sampler = Arc::new(Sampler::new(kernel)?);
        let shared = Arc::new(Shared {
            queue: Mutex::new(BatchQueue::new(BatchPolicy {
                max_batch: cfg.max_batch,
                window: Duration::from_micros(cfg.batch_window_us),
            })),
            cv: Condvar::new(),
            sampler: RwLock::new(sampler),
            metrics: ServiceMetrics::new(),
            shutdown: AtomicBool::new(false),
            capacity: cfg.queue_capacity,
            swap_scratch: Mutex::new(SampleScratch::new()),
        });
        let loads = WorkerLoad::new(cfg.workers);
        let mut worker_txs = Vec::with_capacity(cfg.workers);
        let mut workers = Vec::with_capacity(cfg.workers);
        let mut seeder = Rng::new(seed);
        for w in 0..cfg.workers {
            let (tx, rx) = mpsc::channel::<Vec<Job>>();
            worker_txs.push(tx);
            let shared2 = Arc::clone(&shared);
            let loads2 = loads.clone();
            let mut rng = seeder.split(w as u64);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("krondpp-sampler-{w}"))
                    .spawn(move || worker_loop(w, rx, shared2, loads2, &mut rng))
                    .map_err(Error::Io)?,
            );
        }
        let pump = {
            let shared2 = Arc::clone(&shared);
            let txs = worker_txs.clone();
            let loads2 = loads.clone();
            std::thread::Builder::new()
                .name("krondpp-pump".into())
                .spawn(move || pump_loop(shared2, txs, loads2))
                .map_err(Error::Io)?
        };
        Ok(DppService { shared, pump: Some(pump), workers, worker_txs, loads })
    }

    /// Submit a request; fails fast under backpressure.
    pub fn submit(&self, req: SampleRequest) -> Result<Ticket> {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return Err(Error::Service("service is shut down".into()));
        }
        let (tx, rx) = mpsc::channel();
        {
            let mut q = self.shared.queue.lock().unwrap();
            if q.len() >= self.shared.capacity {
                self.shared.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(Error::Service(format!(
                    "queue full ({} requests)",
                    self.shared.capacity
                )));
            }
            q.push(Job { req, respond: tx, accepted: Instant::now() }, Instant::now());
            self.shared.metrics.accepted.fetch_add(1, Ordering::Relaxed);
        }
        self.shared.cv.notify_one();
        Ok(Ticket { rx })
    }

    /// Convenience: submit and wait.
    pub fn sample(&self, k: usize) -> Result<Vec<usize>> {
        self.submit(SampleRequest { k })?.wait()
    }

    /// Hot-swap the serving kernel (e.g. from a learning job). The
    /// eigendecomposition happens on the caller's thread; in-flight
    /// requests finish on the old kernel.
    pub fn update_kernel(&self, kernel: &Kernel) -> Result<()> {
        let sampler = {
            let mut scratch = self.shared.swap_scratch.lock().unwrap();
            Arc::new(Sampler::new_with_scratch(kernel, &mut scratch)?)
        };
        *self.shared.sampler.write().unwrap() = sampler;
        Ok(())
    }

    /// Service metrics.
    pub fn metrics(&self) -> &ServiceMetrics {
        &self.shared.metrics
    }

    /// Current total in-flight work across workers.
    pub fn in_flight(&self) -> usize {
        self.loads.total()
    }

    /// Stop accepting work, drain, and join all threads.
    pub fn shutdown(mut self) {
        self.do_shutdown();
    }

    fn do_shutdown(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        if let Some(p) = self.pump.take() {
            let _ = p.join();
        }
        // Close worker channels.
        self.worker_txs.clear();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for DppService {
    fn drop(&mut self) {
        if self.pump.is_some() {
            self.do_shutdown();
        }
    }
}

fn pump_loop(shared: Arc<Shared>, txs: Vec<mpsc::Sender<Vec<Job>>>, loads: WorkerLoad) {
    loop {
        let batch = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    // Drain everything to the workers before exiting.
                    let rest = q.drain_all();
                    drop(q);
                    if !rest.is_empty() {
                        dispatch(&shared, &txs, &loads, rest);
                    }
                    return;
                }
                let now = Instant::now();
                if let Some(batch) = q.pop_batch(now) {
                    break batch;
                }
                let wait = q
                    .next_deadline(now)
                    .unwrap_or(Duration::from_millis(50))
                    .max(Duration::from_micros(50));
                let (guard, _) = shared.cv.wait_timeout(q, wait).unwrap();
                q = guard;
            }
        };
        dispatch(&shared, &txs, &loads, batch);
    }
}

fn dispatch(
    shared: &Arc<Shared>,
    txs: &[mpsc::Sender<Vec<Job>>],
    loads: &WorkerLoad,
    batch: Vec<Pending<Job>>,
) {
    if batch.is_empty() {
        return;
    }
    shared.metrics.batches.fetch_add(1, Ordering::Relaxed);
    shared
        .metrics
        .batched_requests
        .fetch_add(batch.len() as u64, Ordering::Relaxed);
    let now = Instant::now();
    for p in &batch {
        shared.metrics.queue_wait.record(now.duration_since(p.enqueued));
    }
    let jobs: Vec<Job> = batch.into_iter().map(|p| p.item).collect();
    let w = loads.pick();
    loads.begin(w);
    if txs[w].send(jobs).is_err() {
        loads.end(w);
    }
}

fn worker_loop(
    w: usize,
    rx: mpsc::Receiver<Vec<Job>>,
    shared: Arc<Shared>,
    loads: WorkerLoad,
    rng: &mut Rng,
) {
    // One scratch per worker: every draw this worker ever makes reuses the
    // same buffers (the batched engine's zero-allocation hot path).
    let mut scratch = SampleScratch::new();
    while let Ok(jobs) = rx.recv() {
        let sampler = Arc::clone(&shared.sampler.read().unwrap());
        // Coalesce same-k jobs so one phase-1 setup serves the whole group
        // instead of looping single draws.
        for (k, group) in coalesce_by_key(jobs, |j| j.req.k) {
            if k > sampler.n() {
                for job in group {
                    finish(
                        &shared,
                        job,
                        Err(Error::Invalid(format!(
                            "requested k={} > ground set {}",
                            k,
                            sampler.n()
                        ))),
                    );
                }
                continue;
            }
            // Respond per draw (not per group) so coalescing never inflates
            // head-of-group latency beyond a single draw.
            if k == 0 {
                for job in group {
                    let y = sampler.sample_with_scratch(rng, &mut scratch);
                    finish(&shared, job, Ok(y));
                }
            } else {
                let n = group.len();
                let mut jobs = group.into_iter();
                sampler.sample_k_each(k, n, rng, &mut scratch, |y| {
                    let job = jobs.next().expect("one job per draw");
                    finish(&shared, job, Ok(y));
                });
            }
        }
        loads.end(w);
    }
}

fn finish(shared: &Shared, job: Job, result: Result<Vec<usize>>) {
    shared.metrics.latency.record(job.accepted.elapsed());
    shared.metrics.completed.fetch_add(1, Ordering::Relaxed);
    let _ = job.respond.send(result);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    fn test_kernel(n1: usize, n2: usize, seed: u64) -> Kernel {
        let mut rng = Rng::new(seed);
        let mk = |n: usize, rng: &mut Rng| -> Matrix {
            let mut m = rng.paper_init_kernel(n);
            m.scale_mut(1.0 / n as f64);
            m.add_diag_mut(0.3);
            m
        };
        Kernel::Kron2(mk(n1, &mut rng), mk(n2, &mut rng))
    }

    fn small_cfg() -> ServiceConfig {
        ServiceConfig { workers: 2, max_batch: 4, batch_window_us: 200, queue_capacity: 64 }
    }

    #[test]
    fn serves_unconstrained_and_k_requests() {
        let svc = DppService::start(&test_kernel(3, 4, 1), &small_cfg(), 7).unwrap();
        let y = svc.sample(0).unwrap();
        assert!(y.iter().all(|&i| i < 12));
        let y5 = svc.sample(5).unwrap();
        assert_eq!(y5.len(), 5);
        svc.shutdown();
    }

    #[test]
    fn concurrent_requests_all_complete() {
        let svc = Arc::new(DppService::start(&test_kernel(3, 3, 2), &small_cfg(), 8).unwrap());
        let mut handles = Vec::new();
        for t in 0..8 {
            let svc2 = Arc::clone(&svc);
            handles.push(std::thread::spawn(move || {
                let mut ok = 0;
                for _ in 0..20 {
                    if svc2.sample((t % 3) + 1).is_ok() {
                        ok += 1;
                    }
                }
                ok
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 160);
        assert_eq!(
            svc.metrics().completed.load(Ordering::Relaxed),
            svc.metrics().accepted.load(Ordering::Relaxed)
        );
        assert!(svc.metrics().batches.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn coalesced_mixed_k_batch_serves_each_request() {
        // A burst with repeated k values coalesces into grouped draws; every
        // request must still get its own correctly-sized response.
        let mut cfg = small_cfg();
        cfg.max_batch = 16;
        cfg.batch_window_us = 5_000;
        let svc = DppService::start(&test_kernel(3, 4, 6), &cfg, 13).unwrap();
        let ks = [0usize, 3, 3, 5, 0, 3, 5, 1];
        let tickets: Vec<Ticket> =
            ks.iter().map(|&k| svc.submit(SampleRequest { k }).unwrap()).collect();
        for (k, t) in ks.iter().zip(tickets) {
            let y = t.wait().unwrap();
            if *k > 0 {
                assert_eq!(y.len(), *k);
            }
            assert!(y.iter().all(|&i| i < 12));
        }
        svc.shutdown();
    }

    #[test]
    fn rejects_oversized_k() {
        let svc = DppService::start(&test_kernel(2, 2, 3), &small_cfg(), 9).unwrap();
        assert!(svc.sample(100).is_err());
        svc.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let mut cfg = small_cfg();
        cfg.queue_capacity = 2;
        cfg.workers = 1;
        cfg.max_batch = 1;
        cfg.batch_window_us = 0;
        let svc = DppService::start(&test_kernel(3, 3, 4), &cfg, 10).unwrap();
        // Flood without waiting; some must be rejected.
        let mut tickets = Vec::new();
        let mut rejected = 0;
        for _ in 0..200 {
            match svc.submit(SampleRequest { k: 3 }) {
                Ok(t) => tickets.push(t),
                Err(_) => rejected += 1,
            }
        }
        for t in tickets {
            let _ = t.wait();
        }
        // Either we saw rejections, or the worker kept up; metrics must
        // agree with what we observed.
        assert_eq!(svc.metrics().rejected.load(Ordering::Relaxed), rejected as u64);
        svc.shutdown();
    }

    #[test]
    fn kernel_hot_swap_changes_ground_set() {
        let svc = DppService::start(&test_kernel(2, 2, 5), &small_cfg(), 11).unwrap();
        let y = svc.sample(2).unwrap();
        assert!(y.iter().all(|&i| i < 4));
        svc.update_kernel(&test_kernel(3, 4, 6)).unwrap();
        let y2 = svc.sample(8).unwrap();
        assert_eq!(y2.len(), 8);
        assert!(y2.iter().any(|&i| i >= 4), "new kernel should expose items ≥ 4");
        svc.shutdown();
    }

    #[test]
    fn shutdown_drains_pending() {
        let svc = DppService::start(&test_kernel(3, 3, 7), &small_cfg(), 12).unwrap();
        let tickets: Vec<Ticket> =
            (0..16).map(|_| svc.submit(SampleRequest { k: 2 }).unwrap()).collect();
        svc.shutdown();
        let mut done = 0;
        for t in tickets {
            if t.wait_timeout(Duration::from_secs(2)).is_ok() {
                done += 1;
            }
        }
        assert_eq!(done, 16, "shutdown dropped pending requests");
    }
}
