//! Service metrics: latency histograms, counters, throughput windows.
//!
//! Lock-free-ish (atomics for counters; a mutex-guarded log-bucketed
//! histogram for latencies — contention is negligible next to a sampling
//! operation). Global counters live in [`ServiceMetrics`]; each registry
//! tenant additionally carries its own [`TenantMetrics`] (per-tenant
//! counters + latency histogram), and the registry itself exposes a gauge
//! line (resident epochs, evictions, rebuilds) via
//! [`super::registry::KernelRegistry::report`]. The serving benches print
//! these as the latency/throughput rows in EXPERIMENTS.md.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::coordinator::lock_clean;
use crate::dpp::backend::SampleMode;

/// Per-mode completion counters — how much traffic each sampler-zoo
/// fidelity tier actually serves. Counted once per *completed* request,
/// keyed by the request's [`SampleMode`]; mirrored globally and per
/// tenant.
#[derive(Default)]
pub struct ModeCounters {
    pub exact: AtomicU64,
    pub mcmc: AtomicU64,
    pub low_rank: AtomicU64,
    pub map: AtomicU64,
}

impl ModeCounters {
    pub fn count(&self, mode: SampleMode) {
        self.counter(mode).fetch_add(1, Ordering::Relaxed);
    }

    pub fn get(&self, mode: SampleMode) -> u64 {
        self.counter(mode).load(Ordering::Relaxed)
    }

    fn counter(&self, mode: SampleMode) -> &AtomicU64 {
        match mode {
            SampleMode::Exact => &self.exact,
            SampleMode::Mcmc { .. } => &self.mcmc,
            SampleMode::LowRank { .. } => &self.low_rank,
            SampleMode::Map => &self.map,
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "modes: exact={} mcmc={} lowrank={} map={}",
            self.exact.load(Ordering::Relaxed),
            self.mcmc.load(Ordering::Relaxed),
            self.low_rank.load(Ordering::Relaxed),
            self.map.load(Ordering::Relaxed),
        )
    }
}

/// Log-bucketed latency histogram (1 µs .. ~1000 s, 5 buckets/decade).
pub struct LatencyHistogram {
    buckets: Mutex<Vec<u64>>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const BUCKETS_PER_DECADE: usize = 5;
const DECADES: usize = 9; // 1 µs → 10^9 µs
const NBUCKETS: usize = BUCKETS_PER_DECADE * DECADES;

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: Mutex::new(vec![0; NBUCKETS + 1]),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_index(us: f64) -> usize {
        if us < 1.0 {
            return 0;
        }
        let idx = (us.log10() * BUCKETS_PER_DECADE as f64).floor() as usize;
        idx.min(NBUCKETS)
    }

    fn bucket_upper_us(idx: usize) -> f64 {
        10f64.powf((idx + 1) as f64 / BUCKETS_PER_DECADE as f64)
    }

    /// Record one latency sample.
    pub fn record(&self, d: Duration) {
        let us = d.as_secs_f64() * 1e6;
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us as u64, Ordering::Relaxed);
        self.max_us.fetch_max(us as u64, Ordering::Relaxed);
        let mut b = lock_clean(&self.buckets);
        b[Self::bucket_index(us)] += 1;
    }

    /// Total samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency.
    pub fn mean(&self) -> Duration {
        let c = self.count().max(1);
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / c)
    }

    /// Max latency.
    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    /// Approximate quantile from bucket upper bounds.
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let b = lock_clean(&self.buckets);
        let mut acc = 0u64;
        for (i, &c) in b.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Duration::from_secs_f64(Self::bucket_upper_us(i) / 1e6);
            }
        }
        self.max()
    }

    /// One-line summary for logs/benches.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.3}ms p50={:.3}ms p95={:.3}ms p99={:.3}ms max={:.3}ms",
            self.count(),
            self.mean().as_secs_f64() * 1e3,
            self.quantile(0.5).as_secs_f64() * 1e3,
            self.quantile(0.95).as_secs_f64() * 1e3,
            self.quantile(0.99).as_secs_f64() * 1e3,
            self.max().as_secs_f64() * 1e3,
        )
    }
}

/// Degraded-mode (fallback chain) counters — how the service kept serving
/// when the primary path failed. Counted once per *request served* on a
/// given rung (a coalesced group of `g` requests served by one
/// regularized rebuild counts `g`), except `probes`, which counts
/// half-open probe *attempts* per serve event.
#[derive(Default)]
pub struct FallbackCounters {
    /// Half-open probes of the primary path while a breaker was open.
    pub probes: AtomicU64,
    /// Requests served by a jittered-regularization rung (`L + εI`).
    pub regularized: AtomicU64,
    /// Requests served by the low-rank downgrade rung.
    pub degraded_low_rank: AtomicU64,
    /// Requests served by the MCMC downgrade rung.
    pub degraded_mcmc: AtomicU64,
    /// Requests that exhausted every rung and failed.
    pub exhausted: AtomicU64,
}

impl FallbackCounters {
    /// Requests served by any fallback rung (excludes probes/exhausted).
    pub fn served(&self) -> u64 {
        self.regularized.load(Ordering::Relaxed)
            + self.degraded_low_rank.load(Ordering::Relaxed)
            + self.degraded_mcmc.load(Ordering::Relaxed)
    }

    pub fn summary(&self) -> String {
        format!(
            "fallback: probes={} regularized={} lowrank={} mcmc={} exhausted={}",
            self.probes.load(Ordering::Relaxed),
            self.regularized.load(Ordering::Relaxed),
            self.degraded_low_rank.load(Ordering::Relaxed),
            self.degraded_mcmc.load(Ordering::Relaxed),
            self.exhausted.load(Ordering::Relaxed),
        )
    }
}

/// Per-tenant counters + latency histogram, held by each registry tenant.
#[derive(Default)]
pub struct TenantMetrics {
    /// Requests accepted into the queue for this tenant.
    pub accepted: AtomicU64,
    /// Requests rejected as invalid (`k` > ground set, unsatisfiable or
    /// out-of-bounds constraint) — at admission or, after a shrinking
    /// hot-swap raced the queue, at the worker.
    pub rejected_invalid: AtomicU64,
    /// Requests completed successfully for this tenant.
    pub completed: AtomicU64,
    /// Completed requests that carried a conditioning constraint.
    pub conditioned: AtomicU64,
    /// Accepted requests that failed service-side (epoch build error).
    pub failed: AtomicU64,
    /// Accepted requests whose deadline expired before they were served.
    pub deadline_exceeded: AtomicU64,
    /// Completed requests served by a fallback rung rather than the
    /// primary path (subset of `completed`).
    pub fallback_served: AtomicU64,
    /// Completed requests by sampler mode.
    pub modes: ModeCounters,
    /// End-to-end latency of this tenant's requests.
    pub latency: LatencyHistogram,
}

impl TenantMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// One-line per-tenant summary for reports.
    pub fn summary(&self) -> String {
        format!(
            "accepted={} rejected_invalid={} completed={} conditioned={} failed={} \
             deadline_exceeded={} fallback_served={} {} latency: {}",
            self.accepted.load(Ordering::Relaxed),
            self.rejected_invalid.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.conditioned.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.deadline_exceeded.load(Ordering::Relaxed),
            self.fallback_served.load(Ordering::Relaxed),
            self.modes.summary(),
            self.latency.summary(),
        )
    }
}

/// Service-wide counters.
#[derive(Default)]
pub struct ServiceMetrics {
    /// Requests accepted into the queue.
    pub accepted: AtomicU64,
    /// Requests rejected by backpressure.
    pub rejected: AtomicU64,
    /// Requests rejected as invalid with [`crate::error::Error::Rejected`]:
    /// at admission control (unknown tenant, `k` larger than the tenant's
    /// current ground set — no queue slot burned) or, rarely, at the
    /// worker when a shrinking hot-swap raced an already-queued request.
    pub rejected_invalid: AtomicU64,
    /// Requests completed successfully.
    pub completed: AtomicU64,
    /// Completed requests that carried a conditioning constraint.
    pub conditioned: AtomicU64,
    /// Conditioning setups performed by workers (Schur assembly + `Lᶜ`
    /// eigendecomposition). `conditioned / conditioning_setups` is the
    /// slate-context sharing ratio the `(tenant, k, constraint)`
    /// coalescing buys.
    pub conditioning_setups: AtomicU64,
    /// Accepted requests that failed service-side (epoch build error).
    /// Invariant: every accepted request ends in exactly one of
    /// `completed`, `failed`, `deadline_exceeded`, or (worker-side)
    /// `rejected_invalid`.
    pub failed: AtomicU64,
    /// Accepted requests whose deadline expired before they were served
    /// (admission fast-rejects of already-expired requests are *not*
    /// accepted and count here only, without burning a queue slot).
    pub deadline_exceeded: AtomicU64,
    /// Coalesced groups whose serve panicked (contained by the worker's
    /// `catch_unwind`; the group's requests count as `failed`).
    pub worker_panics: AtomicU64,
    /// Workers respawned by the supervisor after a panic.
    pub worker_respawns: AtomicU64,
    /// Degraded-mode serving counters (circuit breaker + fallback chain).
    pub fallback: FallbackCounters,
    /// Completed requests by sampler mode (the zoo's traffic mix).
    pub modes: ModeCounters,
    /// Batches dispatched.
    pub batches: AtomicU64,
    /// Sum of batch sizes (for mean batch size).
    pub batched_requests: AtomicU64,
    /// End-to-end request latency.
    pub latency: LatencyHistogram,
    /// Queue wait before dispatch.
    pub queue_wait: LatencyHistogram,
}

impl ServiceMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn report(&self) -> String {
        format!(
            "accepted={} rejected={} rejected_invalid={} completed={} conditioned={} \
             conditioning_setups={} failed={} deadline_exceeded={} worker_panics={} \
             worker_respawns={} batches={} mean_batch={:.2} {} {}\n  latency: {}\n  queue:   {}",
            self.accepted.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.rejected_invalid.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.conditioned.load(Ordering::Relaxed),
            self.conditioning_setups.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.deadline_exceeded.load(Ordering::Relaxed),
            self.worker_panics.load(Ordering::Relaxed),
            self.worker_respawns.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.modes.summary(),
            self.fallback.summary(),
            self.latency.summary(),
            self.queue_wait.summary(),
        )
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i * 10));
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99, "{p50:?} {p95:?} {p99:?}");
        // p50 ≈ 5ms within bucket resolution (x1.6 per bucket).
        let p50ms = p50.as_secs_f64() * 1e3;
        assert!(p50ms > 2.0 && p50ms < 13.0, "p50 {p50ms}ms");
    }

    #[test]
    fn empty_histogram_safe() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn service_metrics_mean_batch() {
        let m = ServiceMetrics::new();
        m.batches.store(4, Ordering::Relaxed);
        m.batched_requests.store(10, Ordering::Relaxed);
        assert!((m.mean_batch_size() - 2.5).abs() < 1e-12);
        assert!(m.report().contains("mean_batch=2.50"));
    }

    #[test]
    fn mode_counters_key_by_family_not_parameters() {
        let m = ModeCounters::default();
        m.count(SampleMode::Mcmc { steps: 10 });
        m.count(SampleMode::Mcmc { steps: 999 });
        m.count(SampleMode::LowRank { rank: 4 });
        m.count(SampleMode::Map);
        assert_eq!(m.get(SampleMode::Mcmc { steps: 1 }), 2);
        assert_eq!(m.get(SampleMode::LowRank { rank: 7 }), 1);
        assert_eq!(m.get(SampleMode::Map), 1);
        assert_eq!(m.get(SampleMode::Exact), 0);
        assert!(m.summary().contains("mcmc=2"));
        let s = ServiceMetrics::new();
        s.modes.count(SampleMode::Exact);
        assert!(s.report().contains("modes: exact=1 mcmc=0 lowrank=0 map=0"));
    }

    #[test]
    fn fallback_counters_sum_and_summarize() {
        let f = FallbackCounters::default();
        f.probes.store(3, Ordering::Relaxed);
        f.regularized.store(4, Ordering::Relaxed);
        f.degraded_low_rank.store(2, Ordering::Relaxed);
        f.degraded_mcmc.store(1, Ordering::Relaxed);
        f.exhausted.store(5, Ordering::Relaxed);
        // served = the rungs only, not probes or exhausted.
        assert_eq!(f.served(), 7);
        let s = f.summary();
        assert!(s.contains("probes=3") && s.contains("exhausted=5"), "{s}");
        let m = ServiceMetrics::new();
        let r = m.report();
        assert!(r.contains("deadline_exceeded=0"), "{r}");
        assert!(r.contains("worker_panics=0"), "{r}");
        assert!(r.contains("fallback: probes=0"), "{r}");
    }

    #[test]
    fn tenant_metrics_summary() {
        let t = TenantMetrics::new();
        t.accepted.store(7, Ordering::Relaxed);
        t.rejected_invalid.store(2, Ordering::Relaxed);
        t.completed.store(5, Ordering::Relaxed);
        t.latency.record(Duration::from_micros(250));
        let s = t.summary();
        assert!(s.contains("accepted=7"));
        assert!(s.contains("rejected_invalid=2"));
        assert!(s.contains("completed=5"));
    }
}
