//! Service metrics: latency histograms, counters, throughput windows.
//!
//! Lock-free-ish (atomics for counters; a mutex-guarded log-bucketed
//! histogram for latencies — contention is negligible next to a sampling
//! operation). Global counters live in [`ServiceMetrics`]; each registry
//! tenant additionally carries its own [`TenantMetrics`] (per-tenant
//! counters + latency histogram), and the registry itself exposes a gauge
//! line (resident epochs, evictions, rebuilds) via
//! [`super::registry::KernelRegistry::report`]. The serving benches print
//! these as the latency/throughput rows in EXPERIMENTS.md.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::coordinator::lock_clean;
use crate::dpp::backend::SampleMode;

/// Per-mode completion counters — how much traffic each sampler-zoo
/// fidelity tier actually serves. Counted once per *completed* request,
/// keyed by the request's [`SampleMode`]; mirrored globally and per
/// tenant.
#[derive(Default)]
pub struct ModeCounters {
    pub exact: AtomicU64,
    pub mcmc: AtomicU64,
    pub low_rank: AtomicU64,
    pub map: AtomicU64,
}

impl ModeCounters {
    pub fn count(&self, mode: SampleMode) {
        self.counter(mode).fetch_add(1, Ordering::Relaxed);
    }

    pub fn get(&self, mode: SampleMode) -> u64 {
        self.counter(mode).load(Ordering::Relaxed)
    }

    fn counter(&self, mode: SampleMode) -> &AtomicU64 {
        match mode {
            SampleMode::Exact => &self.exact,
            SampleMode::Mcmc { .. } => &self.mcmc,
            SampleMode::LowRank { .. } => &self.low_rank,
            SampleMode::Map => &self.map,
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "modes: exact={} mcmc={} lowrank={} map={}",
            self.exact.load(Ordering::Relaxed),
            self.mcmc.load(Ordering::Relaxed),
            self.low_rank.load(Ordering::Relaxed),
            self.map.load(Ordering::Relaxed),
        )
    }
}

/// Log-bucketed latency histogram (1 µs .. ~1000 s, 5 buckets/decade).
pub struct LatencyHistogram {
    buckets: Mutex<Vec<u64>>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const BUCKETS_PER_DECADE: usize = 5;
const DECADES: usize = 9; // 1 µs → 10^9 µs
const NBUCKETS: usize = BUCKETS_PER_DECADE * DECADES;

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: Mutex::new(vec![0; NBUCKETS + 1]),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_index(us: f64) -> usize {
        if us < 1.0 {
            return 0;
        }
        let idx = (us.log10() * BUCKETS_PER_DECADE as f64).floor() as usize;
        idx.min(NBUCKETS)
    }

    fn bucket_upper_us(idx: usize) -> f64 {
        10f64.powf((idx + 1) as f64 / BUCKETS_PER_DECADE as f64)
    }

    /// Record one latency sample.
    pub fn record(&self, d: Duration) {
        let us = d.as_secs_f64() * 1e6;
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us as u64, Ordering::Relaxed);
        self.max_us.fetch_max(us as u64, Ordering::Relaxed);
        let mut b = lock_clean(&self.buckets);
        b[Self::bucket_index(us)] += 1;
    }

    /// Total samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency.
    pub fn mean(&self) -> Duration {
        let c = self.count().max(1);
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / c)
    }

    /// Max latency.
    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    /// Approximate quantile from bucket upper bounds.
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let b = lock_clean(&self.buckets);
        let mut acc = 0u64;
        for (i, &c) in b.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Duration::from_secs_f64(Self::bucket_upper_us(i) / 1e6);
            }
        }
        self.max()
    }

    /// One-line summary for logs/benches — same shape as
    /// [`LatencySketch::summary`], so either type can back a `report()`
    /// line without changing its parseable layout.
    pub fn summary(&self) -> String {
        summary_line(
            self.count(),
            self.mean(),
            self.max(),
            |q| self.quantile(q),
        )
    }
}

/// Shared one-line latency summary: the single `report()` shape both the
/// legacy [`LatencyHistogram`] and the [`LatencySketch`] render through.
fn summary_line(
    count: u64,
    mean: Duration,
    max: Duration,
    quantile: impl Fn(f64) -> Duration,
) -> String {
    format!(
        "n={} mean={:.3}ms p50={:.3}ms p95={:.3}ms p99={:.3}ms p999={:.3}ms max={:.3}ms",
        count,
        mean.as_secs_f64() * 1e3,
        quantile(0.5).as_secs_f64() * 1e3,
        quantile(0.95).as_secs_f64() * 1e3,
        quantile(0.99).as_secs_f64() * 1e3,
        quantile(0.999).as_secs_f64() * 1e3,
        max.as_secs_f64() * 1e3,
    )
}

/// Streaming quantile sketch with guaranteed relative accuracy
/// (DDSketch-style, Masson et al. 2019): logarithmic buckets at powers of
/// `γ = (1+α)/(1−α)` with `α = 1%`, so any reported quantile is within
/// `±1%` (relative) of the exact sample quantile — unlike
/// [`LatencyHistogram`]'s 5-buckets-per-decade grid, whose bucket-upper
/// readout can overstate a tail quantile by up to `10^{1/5} ≈ 58%`.
///
/// Fully lock-free: the bucket array is fixed (no collapsing) and every
/// record is three relaxed atomic adds + one atomic max. 1042 buckets
/// cover 1 µs .. ~1000 s; sub-µs samples land in the underflow bucket
/// (reported as 1 µs — absolute error ≤ 1 µs there), and the top bucket
/// saturates. This is the tenant-facing p50/p99/p999 SLO instrument.
pub struct LatencySketch {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

/// Relative-accuracy target of [`LatencySketch`].
pub const SKETCH_ALPHA: f64 = 0.01;
/// Bucket count: `ceil(ln(10^9)/ln(γ)) ≈ 1037` indices for 1 µs..10^9 µs,
/// plus the underflow bucket and a little headroom before saturation.
const SKETCH_BUCKETS: usize = 1042;

#[inline]
fn sketch_gamma() -> f64 {
    (1.0 + SKETCH_ALPHA) / (1.0 - SKETCH_ALPHA)
}

impl Default for LatencySketch {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencySketch {
    pub fn new() -> Self {
        LatencySketch {
            buckets: (0..SKETCH_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    /// Bucket `i ≥ 1` covers `(γ^{i-1}, γ^i]` µs; bucket 0 is `(0, 1]` µs.
    fn index(us: f64) -> usize {
        if us <= 1.0 {
            return 0;
        }
        let i = (us.ln() / sketch_gamma().ln()).ceil() as usize;
        i.min(SKETCH_BUCKETS - 1)
    }

    /// Midpoint estimate `2γ^i/(γ+1)` for bucket `i`: for any sample `x`
    /// in the bucket, `(1−α)·x ≤ estimate ≤ (1+α)·x`.
    fn value_us(idx: usize) -> f64 {
        if idx == 0 {
            return 1.0;
        }
        let g = sketch_gamma();
        g.powi(idx as i32) * 2.0 / (g + 1.0)
    }

    /// Record one latency sample (lock-free).
    pub fn record(&self, d: Duration) {
        let us = d.as_secs_f64() * 1e6;
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us as u64, Ordering::Relaxed);
        self.max_us.fetch_max(us.ceil() as u64, Ordering::Relaxed);
        self.buckets[Self::index(us)].fetch_add(1, Ordering::Relaxed);
    }

    /// Total samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency.
    pub fn mean(&self) -> Duration {
        let c = self.count().max(1);
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / c)
    }

    /// Max latency.
    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    /// Quantile `q ∈ [0, 1]` by nearest rank, within `±α` relative error
    /// of the exact sorted-sample quantile (±1 µs in the underflow
    /// bucket). Concurrent records may race the bucket walk; the readout
    /// is a consistent-enough snapshot for reporting.
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (i, c) in self.buckets.iter().enumerate() {
            acc += c.load(Ordering::Relaxed);
            if acc >= target {
                return Duration::from_secs_f64(Self::value_us(i) / 1e6);
            }
        }
        self.max()
    }

    /// One-line summary — same shape as [`LatencyHistogram::summary`].
    pub fn summary(&self) -> String {
        summary_line(
            self.count(),
            self.mean(),
            self.max(),
            |q| self.quantile(q),
        )
    }
}

/// Degraded-mode (fallback chain) counters — how the service kept serving
/// when the primary path failed. Counted once per *request served* on a
/// given rung (a coalesced group of `g` requests served by one
/// regularized rebuild counts `g`), except `probes`, which counts
/// half-open probe *attempts* per serve event.
#[derive(Default)]
pub struct FallbackCounters {
    /// Half-open probes of the primary path while a breaker was open.
    pub probes: AtomicU64,
    /// Requests served by a jittered-regularization rung (`L + εI`).
    pub regularized: AtomicU64,
    /// Requests served by the low-rank downgrade rung.
    pub degraded_low_rank: AtomicU64,
    /// Requests served by the MCMC downgrade rung.
    pub degraded_mcmc: AtomicU64,
    /// Requests that exhausted every rung and failed.
    pub exhausted: AtomicU64,
}

impl FallbackCounters {
    /// Requests served by any fallback rung (excludes probes/exhausted).
    pub fn served(&self) -> u64 {
        self.regularized.load(Ordering::Relaxed)
            + self.degraded_low_rank.load(Ordering::Relaxed)
            + self.degraded_mcmc.load(Ordering::Relaxed)
    }

    pub fn summary(&self) -> String {
        format!(
            "fallback: probes={} regularized={} lowrank={} mcmc={} exhausted={}",
            self.probes.load(Ordering::Relaxed),
            self.regularized.load(Ordering::Relaxed),
            self.degraded_low_rank.load(Ordering::Relaxed),
            self.degraded_mcmc.load(Ordering::Relaxed),
            self.exhausted.load(Ordering::Relaxed),
        )
    }
}

/// Per-tenant counters + latency sketches, held by each registry tenant.
#[derive(Default)]
pub struct TenantMetrics {
    /// Requests accepted into the queue for this tenant.
    pub accepted: AtomicU64,
    /// Requests shed at admission by this tenant's rate limiter /
    /// outstanding cap / queue-depth shed ([`crate::error::Error::Throttled`]).
    /// Never accepted; no queue slot burned.
    pub throttled: AtomicU64,
    /// Requests rejected as invalid (`k` > ground set, unsatisfiable or
    /// out-of-bounds constraint) — at admission or, after a shrinking
    /// hot-swap raced the queue, at the worker.
    pub rejected_invalid: AtomicU64,
    /// Requests completed successfully for this tenant.
    pub completed: AtomicU64,
    /// Completed requests that carried a conditioning constraint.
    pub conditioned: AtomicU64,
    /// Accepted requests that failed service-side (epoch build error).
    pub failed: AtomicU64,
    /// Accepted requests whose deadline expired before they were served.
    pub deadline_exceeded: AtomicU64,
    /// Completed requests served by a fallback rung rather than the
    /// primary path (subset of `completed`).
    pub fallback_served: AtomicU64,
    /// Completed requests by sampler mode.
    pub modes: ModeCounters,
    /// End-to-end latency of this tenant's requests (accept → finish).
    pub latency: LatencySketch,
    /// Queue-wait component: accept → dispatch to a worker.
    pub queue_wait: LatencySketch,
    /// Serve-time component: dispatch → finish.
    pub serve_time: LatencySketch,
    /// End-to-end latency SLO for this tenant, in µs (0 = no SLO).
    /// Live-tunable; mirrors the tenant's configured `AdmissionPolicy`.
    pub slo_us: AtomicU64,
    /// Finished requests whose end-to-end latency exceeded `slo_us`.
    pub slo_violations: AtomicU64,
}

impl TenantMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a finished request's end-to-end latency against the SLO
    /// (no-op when no SLO is configured). Returns `true` on a breach so
    /// the caller can mirror it into the global counter.
    pub fn check_slo(&self, elapsed: Duration) -> bool {
        let slo = self.slo_us.load(Ordering::Relaxed);
        if slo > 0 && elapsed.as_micros() as u64 > slo {
            self.slo_violations.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }

    /// One-line per-tenant summary for reports.
    pub fn summary(&self) -> String {
        format!(
            "accepted={} throttled={} rejected_invalid={} completed={} conditioned={} failed={} \
             deadline_exceeded={} fallback_served={} slo_violations={} {} latency: {} \
             queue[p50={:.3}ms p99={:.3}ms] serve[p50={:.3}ms p99={:.3}ms]",
            self.accepted.load(Ordering::Relaxed),
            self.throttled.load(Ordering::Relaxed),
            self.rejected_invalid.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.conditioned.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.deadline_exceeded.load(Ordering::Relaxed),
            self.fallback_served.load(Ordering::Relaxed),
            self.slo_violations.load(Ordering::Relaxed),
            self.modes.summary(),
            self.latency.summary(),
            self.queue_wait.quantile(0.5).as_secs_f64() * 1e3,
            self.queue_wait.quantile(0.99).as_secs_f64() * 1e3,
            self.serve_time.quantile(0.5).as_secs_f64() * 1e3,
            self.serve_time.quantile(0.99).as_secs_f64() * 1e3,
        )
    }
}

/// Service-wide counters.
#[derive(Default)]
pub struct ServiceMetrics {
    /// Requests accepted into the queue.
    pub accepted: AtomicU64,
    /// Requests rejected by backpressure.
    pub rejected: AtomicU64,
    /// Requests shed at admission with [`crate::error::Error::Throttled`]
    /// (tenant token bucket, outstanding cap, or queue-depth shed). Never
    /// accepted; no queue slot burned — same fast path as `rejected_invalid`.
    pub throttled: AtomicU64,
    /// Requests rejected as invalid with [`crate::error::Error::Rejected`]:
    /// at admission control (unknown tenant, `k` larger than the tenant's
    /// current ground set — no queue slot burned) or, rarely, at the
    /// worker when a shrinking hot-swap raced an already-queued request.
    pub rejected_invalid: AtomicU64,
    /// Requests completed successfully.
    pub completed: AtomicU64,
    /// Completed requests that carried a conditioning constraint.
    pub conditioned: AtomicU64,
    /// Conditioning setups performed by workers (Schur assembly + `Lᶜ`
    /// eigendecomposition). `conditioned / conditioning_setups` is the
    /// slate-context sharing ratio the `(tenant, k, constraint)`
    /// coalescing buys.
    pub conditioning_setups: AtomicU64,
    /// Accepted requests that failed service-side (epoch build error).
    /// Invariant: every accepted request ends in exactly one of
    /// `completed`, `failed`, `deadline_exceeded`, or (worker-side)
    /// `rejected_invalid`.
    pub failed: AtomicU64,
    /// Accepted requests whose deadline expired before they were served
    /// (admission fast-rejects of already-expired requests are *not*
    /// accepted and count here only, without burning a queue slot).
    pub deadline_exceeded: AtomicU64,
    /// Coalesced groups whose serve panicked (contained by the worker's
    /// `catch_unwind`; the group's requests count as `failed`).
    pub worker_panics: AtomicU64,
    /// Workers respawned by the supervisor after a panic.
    pub worker_respawns: AtomicU64,
    /// Degraded-mode serving counters (circuit breaker + fallback chain).
    pub fallback: FallbackCounters,
    /// Completed requests by sampler mode (the zoo's traffic mix).
    pub modes: ModeCounters,
    /// Batches dispatched.
    pub batches: AtomicU64,
    /// Sum of batch sizes (for mean batch size).
    pub batched_requests: AtomicU64,
    /// End-to-end request latency (accept → finish).
    pub latency: LatencySketch,
    /// Queue wait before dispatch (accept → dispatch).
    pub queue_wait: LatencySketch,
    /// Serve time at the worker (dispatch → finish).
    pub serve_time: LatencySketch,
    /// Finished requests that blew their tenant's end-to-end SLO
    /// (sum over tenants with an SLO configured).
    pub slo_violations: AtomicU64,
}

impl ServiceMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn report(&self) -> String {
        format!(
            "accepted={} rejected={} throttled={} rejected_invalid={} completed={} conditioned={} \
             conditioning_setups={} failed={} deadline_exceeded={} slo_violations={} \
             worker_panics={} worker_respawns={} batches={} mean_batch={:.2} {} {}\n  \
             latency: {}\n  queue:   {}\n  serve:   {}",
            self.accepted.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.throttled.load(Ordering::Relaxed),
            self.rejected_invalid.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.conditioned.load(Ordering::Relaxed),
            self.conditioning_setups.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.deadline_exceeded.load(Ordering::Relaxed),
            self.slo_violations.load(Ordering::Relaxed),
            self.worker_panics.load(Ordering::Relaxed),
            self.worker_respawns.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.modes.summary(),
            self.fallback.summary(),
            self.latency.summary(),
            self.queue_wait.summary(),
            self.serve_time.summary(),
        )
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i * 10));
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99, "{p50:?} {p95:?} {p99:?}");
        // p50 ≈ 5ms within bucket resolution (x1.6 per bucket).
        let p50ms = p50.as_secs_f64() * 1e3;
        assert!(p50ms > 2.0 && p50ms < 13.0, "p50 {p50ms}ms");
    }

    #[test]
    fn empty_histogram_safe() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn service_metrics_mean_batch() {
        let m = ServiceMetrics::new();
        m.batches.store(4, Ordering::Relaxed);
        m.batched_requests.store(10, Ordering::Relaxed);
        assert!((m.mean_batch_size() - 2.5).abs() < 1e-12);
        assert!(m.report().contains("mean_batch=2.50"));
    }

    #[test]
    fn mode_counters_key_by_family_not_parameters() {
        let m = ModeCounters::default();
        m.count(SampleMode::Mcmc { steps: 10 });
        m.count(SampleMode::Mcmc { steps: 999 });
        m.count(SampleMode::LowRank { rank: 4 });
        m.count(SampleMode::Map);
        assert_eq!(m.get(SampleMode::Mcmc { steps: 1 }), 2);
        assert_eq!(m.get(SampleMode::LowRank { rank: 7 }), 1);
        assert_eq!(m.get(SampleMode::Map), 1);
        assert_eq!(m.get(SampleMode::Exact), 0);
        assert!(m.summary().contains("mcmc=2"));
        let s = ServiceMetrics::new();
        s.modes.count(SampleMode::Exact);
        assert!(s.report().contains("modes: exact=1 mcmc=0 lowrank=0 map=0"));
    }

    #[test]
    fn fallback_counters_sum_and_summarize() {
        let f = FallbackCounters::default();
        f.probes.store(3, Ordering::Relaxed);
        f.regularized.store(4, Ordering::Relaxed);
        f.degraded_low_rank.store(2, Ordering::Relaxed);
        f.degraded_mcmc.store(1, Ordering::Relaxed);
        f.exhausted.store(5, Ordering::Relaxed);
        // served = the rungs only, not probes or exhausted.
        assert_eq!(f.served(), 7);
        let s = f.summary();
        assert!(s.contains("probes=3") && s.contains("exhausted=5"), "{s}");
        let m = ServiceMetrics::new();
        let r = m.report();
        assert!(r.contains("deadline_exceeded=0"), "{r}");
        assert!(r.contains("worker_panics=0"), "{r}");
        assert!(r.contains("fallback: probes=0"), "{r}");
    }

    #[test]
    fn tenant_metrics_summary() {
        let t = TenantMetrics::new();
        t.accepted.store(7, Ordering::Relaxed);
        t.rejected_invalid.store(2, Ordering::Relaxed);
        t.completed.store(5, Ordering::Relaxed);
        t.throttled.store(3, Ordering::Relaxed);
        t.latency.record(Duration::from_micros(250));
        let s = t.summary();
        assert!(s.contains("accepted=7"));
        assert!(s.contains("throttled=3"));
        assert!(s.contains("rejected_invalid=2"));
        assert!(s.contains("completed=5"));
        assert!(s.contains("slo_violations=0"));
        assert!(s.contains("queue[") && s.contains("serve["), "{s}");
    }

    #[test]
    fn tenant_slo_check_counts_only_breaches() {
        let t = TenantMetrics::new();
        // No SLO configured: nothing counts.
        t.check_slo(Duration::from_secs(10));
        assert_eq!(t.slo_violations.load(Ordering::Relaxed), 0);
        t.slo_us.store(5_000, Ordering::Relaxed); // 5 ms SLO
        t.check_slo(Duration::from_millis(4));
        t.check_slo(Duration::from_millis(5)); // exactly at SLO: not a breach
        t.check_slo(Duration::from_millis(6));
        t.check_slo(Duration::from_millis(60));
        assert_eq!(t.slo_violations.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn sketch_empty_safe() {
        let s = LatencySketch::new();
        assert_eq!(s.quantile(0.99), Duration::ZERO);
        assert_eq!(s.mean(), Duration::ZERO);
        assert_eq!(s.max(), Duration::ZERO);
        assert_eq!(s.count(), 0);
    }

    #[test]
    fn sketch_quantiles_ordered_and_summary_shape_matches_histogram() {
        let s = LatencySketch::new();
        for i in 1..=1000u64 {
            s.record(Duration::from_micros(i * 10));
        }
        assert_eq!(s.count(), 1000);
        let p50 = s.quantile(0.5);
        let p95 = s.quantile(0.95);
        let p99 = s.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99, "{p50:?} {p95:?} {p99:?}");
        // One report() shape: the sketch and the legacy histogram render
        // identical field layouts, so readers never branch on the backing.
        let h = LatencyHistogram::new();
        h.record(Duration::from_micros(100));
        let keys = |line: &str| -> Vec<String> {
            line.split_whitespace()
                .map(|f| f.split('=').next().unwrap_or("").to_string())
                .collect()
        };
        assert_eq!(keys(&s.summary()), keys(&h.summary()));
        for key in ["n", "mean", "p50", "p95", "p99", "p999", "max"] {
            assert!(s.summary().contains(&format!("{key}=")), "{key}");
        }
    }

    /// The sketch's guarantee, checked against a sorted-sample oracle:
    /// every reported quantile is within `α = 1%` (relative) of the exact
    /// nearest-rank sample quantile, across a heavy-tailed deterministic
    /// workload spanning five decades.
    #[test]
    fn sketch_error_bounds_against_sorted_oracle() {
        let s = LatencySketch::new();
        let mut samples: Vec<f64> = Vec::new();
        // Deterministic LCG; log-uniform-ish spread over 10 µs .. 1 s.
        let mut state = 0x2016_2016u64;
        for _ in 0..20_000 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let u = (state >> 11) as f64 / (1u64 << 53) as f64;
            let us = 10f64 * 10f64.powf(5.0 * u); // 10 µs → 1e6 µs
            samples.push(us);
            s.record(Duration::from_secs_f64(us / 1e6));
        }
        samples.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
        for q in [0.01, 0.1, 0.25, 0.5, 0.9, 0.95, 0.99, 0.999] {
            let rank = ((q * samples.len() as f64).ceil() as usize).max(1) - 1;
            let oracle = samples[rank];
            let got = s.quantile(q).as_secs_f64() * 1e6;
            let rel = (got - oracle).abs() / oracle;
            assert!(
                rel <= SKETCH_ALPHA + 1e-9,
                "q={q}: sketch {got:.1}µs vs oracle {oracle:.1}µs (rel err {rel:.4})"
            );
        }
        // Mean/max agree with the oracle too (mean within per-sample
        // truncation + integer division, ≤2 µs; max within the ceil's 1 µs).
        let mean_oracle = samples.iter().sum::<f64>() / samples.len() as f64;
        let mean_got = s.mean().as_secs_f64() * 1e6;
        assert!((mean_got - mean_oracle).abs() <= 2.0, "{mean_got} vs {mean_oracle}");
        let max_oracle = samples[samples.len() - 1];
        let max_got = s.max().as_secs_f64() * 1e6;
        assert!((max_got - max_oracle).abs() <= 1.0, "{max_got} vs {max_oracle}");
    }

    #[test]
    fn sketch_underflow_and_saturation_edges() {
        let s = LatencySketch::new();
        s.record(Duration::from_nanos(50)); // sub-µs → underflow bucket
        assert_eq!(s.count(), 1);
        let q = s.quantile(0.5).as_secs_f64() * 1e6;
        assert!(q <= 1.0 + 1e-12, "underflow reported as ≤1µs, got {q}");
        // Hours-scale sample lands in (or clamps to) the top region
        // without panicking.
        s.record(Duration::from_secs(3600));
        let p99 = s.quantile(0.99).as_secs_f64();
        assert!(p99 > 3000.0, "p99 {p99}s should reflect the huge sample");
    }

    #[test]
    fn service_metrics_report_has_throttle_and_slo_fields() {
        let m = ServiceMetrics::new();
        m.throttled.store(9, Ordering::Relaxed);
        m.slo_violations.store(4, Ordering::Relaxed);
        let r = m.report();
        assert!(r.contains("throttled=9"), "{r}");
        assert!(r.contains("slo_violations=4"), "{r}");
        assert!(r.contains("serve:"), "{r}");
    }
}
