//! Non-blocking TCP serving layer: the wire boundary in front of
//! [`DppService`] (DESIGN.md §3.2).
//!
//! One event-loop thread owns a non-blocking listener plus every
//! connection state machine — no thread-per-connection, no external
//! event library (the crate is dependency-free, so readiness is driven
//! by `WouldBlock` with an adaptive sleep backoff instead of epoll
//! registration; at serving batch sizes the backoff floor is far below
//! the batcher's own window). Each connection:
//!
//! - decodes length-prefixed JSON frames incrementally
//!   ([`crate::ser::wire::FrameReader`], bounded by
//!   [`NetConfig::max_frame_bytes`]);
//! - submits sample requests through the **same admission fast path**
//!   as in-process callers — tenant resolution, constraint validation,
//!   token-bucket throttling and queue-depth shedding all reject before
//!   a queue slot is burned, and the typed error travels back as a
//!   `{"err": {...}}` envelope with its retryability intact;
//! - pipelines up to [`NetConfig::max_pipeline`] in-flight tickets,
//!   polling [`Ticket::try_ready`] each loop turn and writing
//!   completions back **as they resolve** (responses may be reordered;
//!   the `id` field correlates);
//! - bounds its write buffer: a peer that stops reading past
//!   [`NetConfig::write_buf_limit`] is disconnected rather than allowed
//!   to balloon memory.
//!
//! Frame-level violations (oversized frames, unreadable sockets) close
//! the connection; payload-level violations (garbage JSON, unknown ops,
//! bad fields) produce an error envelope and leave it open. A wire
//! `shutdown` op calls [`DppService::begin_shutdown`] and flips the
//! loop into **drain mode**: the listener refuses new connections,
//! every connection finishes its pending tickets, flushes, and closes,
//! then the loop exits.

use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::dpp::Constraint;
use crate::error::{Error, Result};
use crate::ser::wire::{encode_frame, FrameReader, WireRequest, WireResponse, DEFAULT_MAX_FRAME};

use super::server::{DppService, SampleRequest, Ticket};

/// Tuning for the connection layer. Defaults are sized for the loopback
/// integration and bench harnesses; production deployments scale
/// `max_connections` and `max_pipeline` with client fan-in.
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// Per-frame payload cap (bytes); oversized frames close the
    /// connection before the payload is buffered.
    pub max_frame_bytes: usize,
    /// Accepted-connection cap; beyond it new sockets are refused
    /// (accepted then immediately dropped) and counted.
    pub max_connections: usize,
    /// In-flight sample tickets per connection; excess requests are
    /// answered [`Error::Throttled`] without touching the service queue.
    pub max_pipeline: usize,
    /// Pending-write cap per connection; a peer that stops reading past
    /// this is disconnected.
    pub write_buf_limit: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            max_frame_bytes: DEFAULT_MAX_FRAME,
            max_connections: 256,
            max_pipeline: 64,
            write_buf_limit: 4 << 20,
        }
    }
}

/// Counters owned by the event loop, shared with the handle.
#[derive(Debug, Default)]
pub struct NetStats {
    /// Connections accepted into the loop.
    pub accepted: AtomicU64,
    /// Connections closed (any reason).
    pub closed: AtomicU64,
    /// Sockets refused at the connection cap or during drain.
    pub refused: AtomicU64,
    /// Complete request frames decoded.
    pub frames_in: AtomicU64,
    /// Response frames fully written.
    pub frames_out: AtomicU64,
    /// Payload-level decode failures answered with an error envelope.
    pub payload_errors: AtomicU64,
    /// Frame/socket-level violations that closed a connection.
    pub protocol_errors: AtomicU64,
    /// Requests refused at the per-connection pipeline cap.
    pub pipeline_rejections: AtomicU64,
}

impl NetStats {
    fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }
}

/// Why a connection is being torn down (folded into `stats.closed`).
enum CloseReason {
    PeerClosed,
    Protocol,
    Io,
    Drained,
}

/// Per-connection state machine.
struct Connection {
    stream: TcpStream,
    reader: FrameReader,
    write_buf: Vec<u8>,
    /// Frames queued but not yet fully flushed (feeds `stats.frames_out`).
    queued_frames: usize,
    /// `(client id, ticket)` pairs awaiting worker completion.
    pending: Vec<(u64, Ticket)>,
    /// Set on frame-level violation or peer EOF: finish pending work,
    /// flush, then close. No further reads.
    closing: bool,
    close_reason: CloseReason,
}

impl Connection {
    fn new(stream: TcpStream, max_frame: usize) -> Connection {
        Connection {
            stream,
            reader: FrameReader::new(max_frame),
            write_buf: Vec::new(),
            queued_frames: 0,
            pending: Vec::new(),
            closing: false,
            close_reason: CloseReason::Drained,
        }
    }

    /// Drive the connection one turn; returns `true` if any byte moved
    /// or any ticket resolved (feeds the loop's sleep backoff).
    fn progress(&mut self, svc: &DppService, cfg: &NetConfig, stats: &NetStats) -> bool {
        let mut worked = false;
        if !self.closing {
            worked |= self.read_frames(svc, cfg, stats);
        }
        worked |= self.poll_tickets(cfg, stats);
        worked |= self.flush(stats);
        worked
    }

    /// Non-blocking read + frame decode + request dispatch.
    fn read_frames(&mut self, svc: &DppService, cfg: &NetConfig, stats: &NetStats) -> bool {
        let mut worked = false;
        let mut chunk = [0u8; 8192];
        loop {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    self.begin_close(CloseReason::PeerClosed);
                    break;
                }
                Ok(n) => {
                    worked = true;
                    self.reader.push(&chunk[..n]);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    NetStats::bump(&stats.protocol_errors);
                    self.begin_close(CloseReason::Io);
                    break;
                }
            }
        }
        loop {
            match self.reader.next() {
                Ok(Some(payload)) => {
                    // Frames already buffered are still served even if the
                    // peer half-closed or a shutdown op flipped `closing` —
                    // only frame-level errors abandon the decode loop.
                    worked = true;
                    NetStats::bump(&stats.frames_in);
                    self.handle_payload(&payload, svc, cfg, stats);
                }
                Ok(None) => break,
                Err(_) => {
                    // Oversized frame: hard protocol error. Best-effort
                    // error envelope, then close.
                    NetStats::bump(&stats.protocol_errors);
                    self.queue_response(
                        &WireResponse::Failure {
                            id: 0,
                            kind: "parse".into(),
                            retryable: false,
                            message: format!(
                                "frame exceeds {} byte cap",
                                cfg.max_frame_bytes
                            ),
                        },
                        cfg,
                    );
                    self.begin_close(CloseReason::Protocol);
                    break;
                }
            }
        }
        worked
    }

    /// Decode one payload and dispatch the op. Payload-level failures
    /// answer an error envelope and keep the connection open.
    fn handle_payload(&mut self, payload: &[u8], svc: &DppService, cfg: &NetConfig, stats: &NetStats) {
        let req = match WireRequest::from_payload(payload) {
            Ok(req) => req,
            Err(e) => {
                NetStats::bump(&stats.payload_errors);
                self.queue_response(&WireResponse::from_error(0, &e), cfg);
                return;
            }
        };
        let id = req.id();
        match req {
            WireRequest::Sample { tenant, k, mode, include, exclude, budget_ms, .. } => {
                if self.pending.len() >= cfg.max_pipeline {
                    NetStats::bump(&stats.pipeline_rejections);
                    let err = Error::Throttled(format!(
                        "connection pipeline full ({} in flight)",
                        self.pending.len()
                    ));
                    self.queue_response(&WireResponse::from_error(id, &err), cfg);
                    return;
                }
                let built = svc.tenant(&tenant).and_then(|tid| {
                    let mut sr = SampleRequest::for_tenant(tid, k).with_mode(mode);
                    if !include.is_empty() || !exclude.is_empty() {
                        sr = sr.with_constraint(Constraint::new(include, exclude)?);
                    }
                    if let Some(ms) = budget_ms {
                        sr = sr.with_budget(Duration::from_millis(ms));
                    }
                    svc.submit(sr)
                });
                match built {
                    // Completion is polled by `poll_tickets`.
                    Ok(ticket) => self.pending.push((id, ticket)),
                    // Admission fast path: throttle/shed/reject without a
                    // queue slot — the typed error goes straight back.
                    Err(e) => self.queue_response(&WireResponse::from_error(id, &e), cfg),
                }
            }
            WireRequest::Marginals { tenant, .. } => {
                let resp = match svc.tenant(&tenant).and_then(|tid| svc.marginals(tid)) {
                    Ok(m) => WireResponse::Marginals { id, marginals: m.as_ref().clone() },
                    Err(e) => WireResponse::from_error(id, &e),
                };
                self.queue_response(&resp, cfg);
            }
            WireRequest::PublishDelta { tenant, delta, .. } => {
                let resp = match svc.tenant(&tenant).and_then(|tid| svc.publish_delta(tid, &delta))
                {
                    Ok(out) => WireResponse::Delta {
                        id,
                        generation: out.generation,
                        incremental: out.incremental,
                        depth: out.depth,
                    },
                    Err(e) => WireResponse::from_error(id, &e),
                };
                self.queue_response(&resp, cfg);
            }
            WireRequest::Report { .. } => {
                let resp = WireResponse::Report { id, report: svc.report() };
                self.queue_response(&resp, cfg);
            }
            WireRequest::Shutdown { .. } => {
                // Global drain: the loop observes `svc.is_shutdown()` and
                // stops accepting; this connection acknowledges, finishes
                // its pending tickets, and closes.
                svc.begin_shutdown();
                self.queue_response(&WireResponse::ShuttingDown { id }, cfg);
                self.begin_close(CloseReason::Drained);
            }
        }
    }

    /// Poll in-flight tickets; completed ones are written back in
    /// completion order (client correlates by id).
    fn poll_tickets(&mut self, cfg: &NetConfig, _stats: &NetStats) -> bool {
        let mut worked = false;
        let mut i = 0;
        while i < self.pending.len() {
            if let Some(result) = self.pending[i].1.try_ready() {
                let (id, _) = self.pending.swap_remove(i);
                let resp = match result {
                    Ok(items) => WireResponse::Items { id, items },
                    Err(e) => WireResponse::from_error(id, &e),
                };
                self.queue_response(&resp, cfg);
                worked = true;
            } else {
                i += 1;
            }
        }
        worked
    }

    /// Append an encoded frame to the write buffer.
    fn queue_response(&mut self, resp: &WireResponse, cfg: &NetConfig) {
        self.queued_frames += 1;
        match encode_frame(resp.encode().to_string().as_bytes(), cfg.max_frame_bytes) {
            Ok(frame) => self.write_buf.extend_from_slice(&frame),
            Err(_) => {
                // A response we cannot frame (report larger than the cap):
                // replace with a minimal error envelope.
                if let Ok(frame) = WireResponse::Failure {
                    id: resp.id(),
                    kind: "service".into(),
                    retryable: false,
                    message: "response exceeds frame cap".into(),
                }
                .to_frame(cfg.max_frame_bytes)
                {
                    self.write_buf.extend_from_slice(&frame);
                }
            }
        }
    }

    /// Non-blocking write of the buffered frames.
    fn flush(&mut self, stats: &NetStats) -> bool {
        let mut worked = false;
        while !self.write_buf.is_empty() {
            match self.stream.write(&self.write_buf) {
                Ok(0) => {
                    self.begin_close(CloseReason::Io);
                    break;
                }
                Ok(n) => {
                    worked = true;
                    self.write_buf.drain(..n);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    NetStats::bump(&stats.protocol_errors);
                    self.begin_close(CloseReason::Io);
                    break;
                }
            }
        }
        if self.write_buf.is_empty() && self.queued_frames > 0 {
            stats.frames_out.fetch_add(self.queued_frames as u64, Ordering::Relaxed);
            self.queued_frames = 0;
        }
        worked
    }

    fn begin_close(&mut self, reason: CloseReason) {
        if !self.closing {
            self.closing = true;
            self.close_reason = reason;
        }
    }

    /// Ready to drop: closing, nothing in flight, nothing to flush.
    /// On hard IO errors pending tickets are abandoned — the workers
    /// still run them and the service ledger still books one outcome
    /// per accepted job; only the reply has nowhere to go.
    fn finished(&self) -> bool {
        match self.close_reason {
            CloseReason::Io => self.closing,
            _ => self.closing && self.pending.is_empty() && self.write_buf.is_empty(),
        }
    }

    /// Over the pending-write cap: the peer has stopped reading.
    fn write_overflow(&self, cfg: &NetConfig) -> bool {
        self.write_buf.len() > cfg.write_buf_limit
    }
}

/// Handle to the serving thread. Dropping it does NOT stop the loop;
/// call [`NetServer::stop`] (or drive a wire `shutdown`).
pub struct NetServer {
    local_addr: SocketAddr,
    stats: Arc<NetStats>,
    stop: Arc<AtomicBool>,
    handle: Option<thread::JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"`) and start the event loop
    /// serving `svc`.
    pub fn start(svc: Arc<DppService>, addr: &str, cfg: NetConfig) -> Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let stats = Arc::new(NetStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let loop_stats = Arc::clone(&stats);
        let loop_stop = Arc::clone(&stop);
        let handle = thread::Builder::new()
            .name("krondpp-net".into())
            .spawn(move || event_loop(listener, svc, cfg, loop_stats, loop_stop))
            .map_err(|e| Error::Service(format!("failed to spawn net thread: {e}")))?;
        Ok(NetServer { local_addr, stats, stop, handle: Some(handle) })
    }

    /// The bound address (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Live counters.
    pub fn stats(&self) -> &NetStats {
        &self.stats
    }

    /// `true` once the event loop has exited (all connections drained).
    pub fn is_finished(&self) -> bool {
        self.handle.as_ref().map(|h| h.is_finished()).unwrap_or(true)
    }

    /// Request the loop to drain and exit, then join it. Existing
    /// connections finish pending work; new ones are refused.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    /// Join without signalling — for callers that already drove a wire
    /// `shutdown` and want to wait for the natural drain.
    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn event_loop(
    listener: TcpListener,
    svc: Arc<DppService>,
    cfg: NetConfig,
    stats: Arc<NetStats>,
    stop: Arc<AtomicBool>,
) {
    const BACKOFF_FLOOR: Duration = Duration::from_micros(50);
    const BACKOFF_CEIL: Duration = Duration::from_millis(2);
    let mut conns: Vec<Connection> = Vec::new();
    let mut backoff = BACKOFF_FLOOR;
    loop {
        let draining = stop.load(Ordering::SeqCst) || svc.is_shutdown();
        let mut worked = false;

        // Accept phase (skipped while draining).
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    if draining || conns.len() >= cfg.max_connections {
                        NetStats::bump(&stats.refused);
                        drop(stream);
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        NetStats::bump(&stats.refused);
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    NetStats::bump(&stats.accepted);
                    conns.push(Connection::new(stream, cfg.max_frame_bytes));
                    worked = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }

        // Connection phase.
        let mut i = 0;
        while i < conns.len() {
            worked |= conns[i].progress(&svc, &cfg, &stats);
            if draining && !conns[i].closing && conns[i].pending.is_empty() {
                // Global drain: close idle connections once their queue
                // is empty; in-flight work is allowed to finish first.
                conns[i].begin_close(CloseReason::Drained);
            }
            if conns[i].write_overflow(&cfg) {
                NetStats::bump(&stats.protocol_errors);
                conns[i].begin_close(CloseReason::Io);
            }
            if conns[i].finished() {
                NetStats::bump(&stats.closed);
                conns.swap_remove(i);
                worked = true;
            } else {
                i += 1;
            }
        }

        if draining && conns.is_empty() {
            return;
        }

        // Adaptive backoff: busy turns reset to the floor, idle turns
        // double toward the ceiling.
        if worked {
            backoff = BACKOFF_FLOOR;
        } else {
            thread::sleep(backoff);
            backoff = (backoff * 2).min(BACKOFF_CEIL);
        }
    }
}

/// Blocking client for the wire protocol — used by the CLI `client`
/// subcommand, the loopback tests, and the saturation bench. Supports
/// pipelining via the split [`WireClient::send`] / [`WireClient::recv`]
/// halves; [`WireClient::request`] is the one-in-one-out convenience.
pub struct WireClient {
    stream: TcpStream,
    reader: FrameReader,
    next_id: u64,
    max_frame: usize,
}

impl WireClient {
    /// Connect (blocking) to a serving endpoint.
    pub fn connect(addr: &str) -> Result<WireClient> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(WireClient {
            stream,
            reader: FrameReader::new(DEFAULT_MAX_FRAME),
            next_id: 1,
            max_frame: DEFAULT_MAX_FRAME,
        })
    }

    /// Connect with a read timeout so a dead server cannot hang tests.
    pub fn connect_timeout(addr: &str, read_timeout: Duration) -> Result<WireClient> {
        let c = WireClient::connect(addr)?;
        c.stream.set_read_timeout(Some(read_timeout))?;
        Ok(c)
    }

    /// Allocate the next client-side correlation id.
    pub fn next_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Write one request frame (blocking).
    pub fn send(&mut self, req: &WireRequest) -> Result<()> {
        let frame = req.to_frame(self.max_frame)?;
        self.stream.write_all(&frame)?;
        Ok(())
    }

    /// Non-blocking receive: drain whatever the socket has buffered and
    /// return the next complete response, or `None` if nothing is ready.
    pub fn try_recv(&mut self) -> Result<Option<WireResponse>> {
        if let Some(payload) = self.reader.next()? {
            return Ok(Some(WireResponse::from_payload(&payload)?));
        }
        self.stream.set_nonblocking(true)?;
        let mut chunk = [0u8; 8192];
        let mut closed = false;
        loop {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    closed = true;
                    break;
                }
                Ok(n) => self.reader.push(&chunk[..n]),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    let _ = self.stream.set_nonblocking(false);
                    return Err(Error::Io(e));
                }
            }
        }
        self.stream.set_nonblocking(false)?;
        if let Some(payload) = self.reader.next()? {
            return Ok(Some(WireResponse::from_payload(&payload)?));
        }
        if closed {
            return Err(Error::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            )));
        }
        Ok(None)
    }

    /// Read the next response frame (blocking).
    pub fn recv(&mut self) -> Result<WireResponse> {
        loop {
            if let Some(payload) = self.reader.next()? {
                return WireResponse::from_payload(&payload);
            }
            let mut chunk = [0u8; 8192];
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    return Err(Error::Io(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "server closed the connection",
                    )))
                }
                Ok(n) => self.reader.push(&chunk[..n]),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(Error::Io(e)),
            }
        }
    }

    /// One-in-one-out request/response.
    pub fn request(&mut self, req: &WireRequest) -> Result<WireResponse> {
        self.send(req)?;
        self.recv()
    }

    /// Sample a slate; typed errors (throttled, rejected, deadline, …)
    /// come back as the original [`Error`] kind.
    pub fn sample(
        &mut self,
        tenant: &str,
        k: usize,
        mode: crate::dpp::SampleMode,
        include: Vec<usize>,
        exclude: Vec<usize>,
        budget_ms: Option<u64>,
    ) -> Result<Vec<usize>> {
        let id = self.next_id();
        self.request(&WireRequest::Sample {
            id,
            tenant: tenant.into(),
            k,
            mode,
            include,
            exclude,
            budget_ms,
        })?
        .into_items()
    }

    /// Fetch per-item inclusion marginals.
    pub fn marginals(&mut self, tenant: &str) -> Result<Vec<f64>> {
        let id = self.next_id();
        match self.request(&WireRequest::Marginals { id, tenant: tenant.into() })? {
            WireResponse::Marginals { marginals, .. } => Ok(marginals),
            WireResponse::Failure { kind, message, .. } => {
                Err(crate::ser::wire::decode_error(&kind, &message))
            }
            other => Err(Error::Parse(format!("unexpected response {other:?}"))),
        }
    }

    /// Fetch the service metrics report.
    pub fn report(&mut self) -> Result<String> {
        let id = self.next_id();
        match self.request(&WireRequest::Report { id })? {
            WireResponse::Report { report, .. } => Ok(report),
            WireResponse::Failure { kind, message, .. } => {
                Err(crate::ser::wire::decode_error(&kind, &message))
            }
            other => Err(Error::Parse(format!("unexpected response {other:?}"))),
        }
    }

    /// Ask the server to drain and shut down.
    pub fn shutdown_server(&mut self) -> Result<()> {
        let id = self.next_id();
        match self.request(&WireRequest::Shutdown { id })? {
            WireResponse::ShuttingDown { .. } => Ok(()),
            WireResponse::Failure { kind, message, .. } => {
                Err(crate::ser::wire::decode_error(&kind, &message))
            }
            other => Err(Error::Parse(format!("unexpected response {other:?}"))),
        }
    }
}

/// Client-observed tallies from one tenant of a replay run. Latency
/// percentiles are exact (sorted samples) over *completed* requests.
#[derive(Clone, Debug, Default)]
pub struct TenantReplay {
    pub name: String,
    pub sent: usize,
    pub completed: usize,
    pub throttled: usize,
    pub rejected: usize,
    pub deadline: usize,
    pub failed: usize,
    /// Client-observed round-trip p50 of completed requests (ms).
    pub p50_ms: f64,
    /// Client-observed round-trip p99 of completed requests (ms).
    pub p99_ms: f64,
}

/// Aggregate outcome of [`run_replay`].
#[derive(Clone, Debug, Default)]
pub struct ReplayOutcome {
    pub sent: usize,
    pub completed: usize,
    pub throttled: usize,
    pub rejected: usize,
    pub deadline: usize,
    pub failed: usize,
    /// Wall-clock from first send to last settled response.
    pub wall: Duration,
    pub per_tenant: Vec<TenantReplay>,
}

impl ReplayOutcome {
    /// Sustained completion throughput (completed / wall, req/s).
    pub fn sustained_hz(&self) -> f64 {
        let w = self.wall.as_secs_f64();
        if w > 0.0 {
            self.completed as f64 / w
        } else {
            0.0
        }
    }

    /// Fraction of sent requests shed by admission control.
    pub fn shed_fraction(&self) -> f64 {
        if self.sent > 0 {
            self.throttled as f64 / self.sent as f64
        } else {
            0.0
        }
    }
}

fn exact_quantile_ms(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((q * sorted.len() as f64).ceil() as usize).max(1) - 1;
    sorted[rank.min(sorted.len() - 1)]
}

/// Drive a [`crate::data::workload::replay`] trace against a serving
/// endpoint, **open loop**: each request fires at its scheduled arrival
/// offset no matter how many earlier ones are still in flight, so an
/// overloaded server sees the full offered rate — the regime where
/// admission control must shed. The trace is partitioned round-robin
/// over `conns` pipelined connections, each on its own thread;
/// `req.tenant` indexes `tenant_names` (mod its length).
pub fn run_replay(
    addr: &str,
    tenant_names: &[String],
    trace: &[crate::data::workload::ReplayRequest],
    conns: usize,
    budget_ms: Option<u64>,
) -> Result<ReplayOutcome> {
    let conns = conns.max(1);
    if tenant_names.is_empty() {
        return Err(Error::Invalid("replay needs at least one tenant name".into()));
    }
    let t0 = std::time::Instant::now();
    let mut handles = Vec::with_capacity(conns);
    for c in 0..conns {
        let my_trace: Vec<crate::data::workload::ReplayRequest> =
            trace.iter().skip(c).step_by(conns).cloned().collect();
        let names: Vec<String> = tenant_names.to_vec();
        let addr = addr.to_string();
        let handle = thread::Builder::new()
            .name(format!("replay-{c}"))
            .spawn(move || replay_worker(&addr, &names, &my_trace, budget_ms, t0))
            .map_err(|e| Error::Service(format!("failed to spawn replay worker: {e}")))?;
        handles.push(handle);
    }
    let mut out = ReplayOutcome {
        per_tenant: tenant_names
            .iter()
            .map(|n| TenantReplay { name: n.clone(), ..TenantReplay::default() })
            .collect(),
        ..ReplayOutcome::default()
    };
    let mut latencies: Vec<Vec<f64>> = vec![Vec::new(); tenant_names.len()];
    for handle in handles {
        let part = handle
            .join()
            .map_err(|_| Error::Service("replay worker panicked".into()))??;
        out.sent += part.sent;
        out.completed += part.completed;
        out.throttled += part.throttled;
        out.rejected += part.rejected;
        out.deadline += part.deadline;
        out.failed += part.failed;
        for (t, mut lat) in part.latencies_ms.into_iter().enumerate() {
            latencies[t].append(&mut lat);
        }
        for (t, counts) in part.per_tenant.into_iter().enumerate() {
            out.per_tenant[t].sent += counts.0;
            out.per_tenant[t].completed += counts.1;
            out.per_tenant[t].throttled += counts.2;
            out.per_tenant[t].rejected += counts.3;
            out.per_tenant[t].deadline += counts.4;
            out.per_tenant[t].failed += counts.5;
        }
    }
    out.wall = t0.elapsed();
    for (t, lat) in latencies.iter_mut().enumerate() {
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        out.per_tenant[t].p50_ms = exact_quantile_ms(lat, 0.50);
        out.per_tenant[t].p99_ms = exact_quantile_ms(lat, 0.99);
    }
    Ok(out)
}

/// One replay connection: open-loop sends, opportunistic drains, final
/// blocking drain. Returns per-tenant `(sent, completed, throttled,
/// rejected, deadline, failed)` plus completed-request latencies.
struct ReplayPart {
    sent: usize,
    completed: usize,
    throttled: usize,
    rejected: usize,
    deadline: usize,
    failed: usize,
    per_tenant: Vec<(usize, usize, usize, usize, usize, usize)>,
    latencies_ms: Vec<Vec<f64>>,
}

fn replay_worker(
    addr: &str,
    names: &[String],
    trace: &[crate::data::workload::ReplayRequest],
    budget_ms: Option<u64>,
    t0: std::time::Instant,
) -> Result<ReplayPart> {
    use std::collections::HashMap;
    let mut client = WireClient::connect_timeout(addr, Duration::from_secs(30))?;
    let mut part = ReplayPart {
        sent: 0,
        completed: 0,
        throttled: 0,
        rejected: 0,
        deadline: 0,
        failed: 0,
        per_tenant: vec![(0, 0, 0, 0, 0, 0); names.len()],
        latencies_ms: vec![Vec::new(); names.len()],
    };
    // id -> (tenant index, send instant)
    let mut inflight: HashMap<u64, (usize, std::time::Instant)> = HashMap::new();

    let mut settle =
        |resp: WireResponse,
         inflight: &mut HashMap<u64, (usize, std::time::Instant)>,
         part: &mut ReplayPart| {
            let Some((tenant, sent_at)) = inflight.remove(&resp.id()) else {
                return;
            };
            match resp.into_items() {
                Ok(_) => {
                    part.completed += 1;
                    part.per_tenant[tenant].1 += 1;
                    part.latencies_ms[tenant].push(sent_at.elapsed().as_secs_f64() * 1e3);
                }
                Err(e) => match e.kind() {
                    crate::error::ErrorKind::Throttled => {
                        part.throttled += 1;
                        part.per_tenant[tenant].2 += 1;
                    }
                    crate::error::ErrorKind::Rejected => {
                        part.rejected += 1;
                        part.per_tenant[tenant].3 += 1;
                    }
                    crate::error::ErrorKind::Deadline => {
                        part.deadline += 1;
                        part.per_tenant[tenant].4 += 1;
                    }
                    _ => {
                        part.failed += 1;
                        part.per_tenant[tenant].5 += 1;
                    }
                },
            }
        };

    for req in trace {
        // Open loop: fire at the scheduled offset regardless of backlog.
        loop {
            let now = t0.elapsed();
            if now >= req.at {
                break;
            }
            let gap = req.at - now;
            if gap > Duration::from_micros(300) {
                thread::sleep(gap - Duration::from_micros(200));
            } else {
                std::hint::spin_loop();
            }
        }
        let tenant = req.tenant % names.len();
        let id = client.next_id();
        let wire = WireRequest::Sample {
            id,
            tenant: names[tenant].clone(),
            k: req.k,
            mode: req.mode,
            include: req.include.clone(),
            exclude: req.exclude.clone(),
            budget_ms,
        };
        match client.send(&wire) {
            Ok(()) => {
                part.sent += 1;
                part.per_tenant[tenant].0 += 1;
                inflight.insert(id, (tenant, std::time::Instant::now()));
            }
            Err(_) => {
                part.failed += 1;
                part.per_tenant[tenant].5 += 1;
                continue;
            }
        }
        // Opportunistic drain keeps the pipeline inside the server's
        // per-connection cap during long traces.
        while let Ok(Some(resp)) = client.try_recv() {
            settle(resp, &mut inflight, &mut part);
        }
    }
    // Final drain: everything still in flight (bounded by the client
    // read timeout if the server dies).
    while !inflight.is_empty() {
        match client.recv() {
            Ok(resp) => settle(resp, &mut inflight, &mut part),
            Err(_) => break,
        }
    }
    // Whatever never came back is a failure from the client's seat.
    for (tenant, _) in inflight.into_values() {
        part.failed += 1;
        part.per_tenant[tenant].5 += 1;
    }
    Ok(part)
}
