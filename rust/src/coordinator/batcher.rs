//! Dynamic batching policy.
//!
//! Sampling requests against the same kernel share the eigendecomposition,
//! so grouping them amortizes dispatch overhead and keeps workers hot. The
//! policy is the standard two-trigger design (vLLM-router style): dispatch
//! when `max_batch` requests are waiting, or when the oldest waiting
//! request has aged past `window`.
//!
//! The policy itself is pure (no threads, no clocks injected) so its
//! invariants are property-tested directly; the server wraps it in a pump
//! thread.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Batching parameters.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max requests per batch.
    pub max_batch: usize,
    /// Max time the oldest request may wait before forced dispatch.
    pub window: Duration,
}

/// A queued item with its enqueue time.
#[derive(Debug)]
pub struct Pending<T> {
    pub item: T,
    pub enqueued: Instant,
}

/// FIFO batching queue governed by a [`BatchPolicy`].
pub struct BatchQueue<T> {
    policy: BatchPolicy,
    queue: VecDeque<Pending<T>>,
}

impl<T> BatchQueue<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        BatchQueue { policy, queue: VecDeque::new() }
    }

    /// Number of waiting requests.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Enqueue a request at time `now`.
    pub fn push(&mut self, item: T, now: Instant) {
        self.queue.push_back(Pending { item, enqueued: now });
    }

    /// Should a batch be dispatched at `now`?
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.policy.max_batch {
            return true;
        }
        match self.queue.front() {
            Some(p) => now.duration_since(p.enqueued) >= self.policy.window,
            None => false,
        }
    }

    /// Time until the age trigger would fire (None if queue empty).
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|p| {
            let age = now.duration_since(p.enqueued);
            self.policy.window.saturating_sub(age)
        })
    }

    /// Pop a batch if ready: oldest-first, at most `max_batch` items.
    pub fn pop_batch(&mut self, now: Instant) -> Option<Vec<Pending<T>>> {
        if !self.ready(now) {
            return None;
        }
        let take = self.queue.len().min(self.policy.max_batch);
        Some(self.queue.drain(..take).collect())
    }

    /// Drain everything unconditionally (shutdown path).
    pub fn drain_all(&mut self) -> Vec<Pending<T>> {
        self.queue.drain(..).collect()
    }
}

/// Group a dispatched batch by an ordered key, preserving arrival (FIFO)
/// order within each group; groups come out in ascending key order.
///
/// The server uses this twice per batch: the pump groups by tenant (so
/// each tenant-group routes as one unit and per-tenant load is accounted
/// exactly), and each worker re-groups its tenant batch by
/// `(k, constraint)` so the batched engine
/// ([`crate::dpp::Sampler::sample_k_many`]) shares the per-tenant,
/// per-`k` phase-1 elementary-DP table — and, for conditioned requests,
/// one whole conditioning setup (Schur assembly + eigendecomposition,
/// [`crate::dpp::ConditionedSampler`]) — across every job of the same
/// slate context instead of looping single draws. Keys are anything `Ord`
/// — `usize`, `TenantId`, or the worker's `(k, fingerprint, constraint)`
/// triple (constraints are normalized on construction, so equal slate
/// contexts compare equal; the fingerprint leads so distinct contexts
/// usually compare on one `u64`).
pub fn coalesce_by_key<T, K: Ord>(
    items: Vec<T>,
    key: impl Fn(&T) -> K,
) -> Vec<(K, Vec<T>)> {
    let mut groups: std::collections::BTreeMap<K, Vec<T>> =
        std::collections::BTreeMap::new();
    for item in items {
        groups.entry(key(&item)).or_default().push(item);
    }
    groups.into_iter().collect()
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::testing::{check, Gen, UsizeGen};
    use std::time::Duration;

    fn policy(max_batch: usize, window_ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch, window: Duration::from_millis(window_ms) }
    }

    #[test]
    fn dispatches_on_size_trigger() {
        let mut q = BatchQueue::new(policy(3, 1_000));
        let t0 = Instant::now();
        q.push(1, t0);
        q.push(2, t0);
        assert!(!q.ready(t0));
        q.push(3, t0);
        assert!(q.ready(t0));
        let batch = q.pop_batch(t0).unwrap();
        assert_eq!(batch.len(), 3);
        assert!(q.is_empty());
    }

    #[test]
    fn dispatches_on_age_trigger() {
        let mut q = BatchQueue::new(policy(100, 10));
        let t0 = Instant::now();
        q.push(1, t0);
        assert!(!q.ready(t0));
        let later = t0 + Duration::from_millis(11);
        assert!(q.ready(later));
        assert_eq!(q.pop_batch(later).unwrap().len(), 1);
    }

    #[test]
    fn batch_respects_max_and_fifo() {
        let mut q = BatchQueue::new(policy(2, 0));
        let t0 = Instant::now();
        for i in 0..5 {
            q.push(i, t0);
        }
        let b1 = q.pop_batch(t0).unwrap();
        assert_eq!(b1.iter().map(|p| p.item).collect::<Vec<_>>(), vec![0, 1]);
        let b2 = q.pop_batch(t0).unwrap();
        assert_eq!(b2.iter().map(|p| p.item).collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn next_deadline_counts_down() {
        let mut q = BatchQueue::new(policy(10, 100));
        let t0 = Instant::now();
        assert!(q.next_deadline(t0).is_none());
        q.push(1, t0);
        let d = q.next_deadline(t0 + Duration::from_millis(40)).unwrap();
        assert!(d <= Duration::from_millis(60));
    }

    // Property: for any sequence of pushes and pops, no request is lost or
    // duplicated, every batch ≤ max_batch, and dispatch order is FIFO.
    #[test]
    fn prop_no_loss_no_dup_fifo() {
        let gen = UsizeGen { lo: 1, hi: 8 };
        check("batcher invariants", &gen, 50, |&max_batch| {
            let mut q = BatchQueue::new(policy(max_batch, 0)); // window 0 → always ready
            let t0 = Instant::now();
            let mut seen = Vec::new();
            let mut next_id = 0usize;
            // Interleave pushes and pops deterministically from max_batch.
            for round in 0..20 {
                for _ in 0..(round % 5) {
                    q.push(next_id, t0);
                    next_id += 1;
                }
                if let Some(batch) = q.pop_batch(t0) {
                    if batch.len() > max_batch {
                        return false;
                    }
                    seen.extend(batch.into_iter().map(|p| p.item));
                }
            }
            seen.extend(q.drain_all().into_iter().map(|p| p.item));
            // FIFO over the whole run → seen is exactly 0..next_id in order.
            seen == (0..next_id).collect::<Vec<_>>()
        });
    }

    #[test]
    fn coalesce_groups_by_key_fifo_within_group() {
        let items = vec![(3usize, 'a'), (1, 'b'), (3, 'c'), (2, 'd'), (1, 'e')];
        let groups = coalesce_by_key(items, |&(k, _)| k);
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].0, 1);
        assert_eq!(groups[0].1, vec![(1, 'b'), (1, 'e')]);
        assert_eq!(groups[1].0, 2);
        assert_eq!(groups[1].1, vec![(2, 'd')]);
        assert_eq!(groups[2].0, 3);
        assert_eq!(groups[2].1, vec![(3, 'a'), (3, 'c')]);
        // No loss, no duplication.
        let total: usize = groups.iter().map(|(_, g)| g.len()).sum();
        assert_eq!(total, 5);
        assert!(coalesce_by_key(Vec::<(usize, char)>::new(), |&(k, _)| k).is_empty());
    }

    #[test]
    fn coalesce_supports_composite_keys() {
        // (tenant, k) grouping: same tenant+k coalesce, everything else
        // stays separate, FIFO within each group.
        let items = vec![(0u32, 3usize, 'a'), (1, 3, 'b'), (0, 3, 'c'), (0, 5, 'd')];
        let groups = coalesce_by_key(items, |&(t, k, _)| (t, k));
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].0, (0, 3));
        assert_eq!(groups[0].1, vec![(0, 3, 'a'), (0, 3, 'c')]);
        assert_eq!(groups[1].0, (0, 5));
        assert_eq!(groups[2].0, (1, 3));
    }

    // Property: ready() is monotone in time — once ready, stays ready.
    #[test]
    fn prop_ready_monotone() {
        struct P;
        impl Gen for P {
            type Value = (usize, u64);
            fn generate(&self, rng: &mut crate::rng::Rng) -> Self::Value {
                (rng.int_range(1, 5), rng.int_range(0, 50) as u64)
            }
        }
        check("ready monotone", &P, 50, |&(n, window_ms)| {
            let mut q = BatchQueue::new(policy(n + 1, window_ms));
            let t0 = Instant::now();
            for i in 0..n {
                q.push(i, t0);
            }
            let t1 = t0 + Duration::from_millis(window_ms);
            let t2 = t1 + Duration::from_millis(5);
            !q.ready(t0 + Duration::from_millis(window_ms.saturating_sub(1)))
                || (q.ready(t1) && q.ready(t2))
        });
    }
}
