//! Execution substrate: a fixed-size thread pool with a shared injector
//! queue (tokio is not available offline; the coordinator's event loop is
//! built on this pool plus `std::sync::mpsc` channels).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolShared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: AtomicBool,
    in_flight: AtomicUsize,
    done_cv: Condvar,
    done_lock: Mutex<()>,
}

/// Fixed-size thread pool. Jobs are `FnOnce() + Send`; `join` blocks until
/// the queue is drained and all in-flight jobs finish.
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool with `n` workers (at least 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            done_cv: Condvar::new(),
            done_lock: Mutex::new(()),
        });
        let workers = (0..n)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("krondpp-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Pool sized to the machine (respecting `KRONDPP_THREADS`).
    pub fn default_size() -> Self {
        Self::new(crate::linalg::matmul::available_threads())
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Box::new(job));
        }
        self.shared.cv.notify_one();
    }

    /// Block until every submitted job has completed.
    pub fn join(&self) {
        let mut guard = self.shared.done_lock.lock().unwrap();
        while self.shared.in_flight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.done_cv.wait(guard).unwrap();
        }
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, U, F>(&self, items: Vec<T>, f: F) -> Vec<U>
    where
        T: Send + 'static,
        U: Send + 'static,
        F: Fn(T) -> U + Send + Sync + 'static,
    {
        let n = items.len();
        let results: Arc<Mutex<Vec<Option<U>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        let f = Arc::new(f);
        for (i, item) in items.into_iter().enumerate() {
            let results = Arc::clone(&results);
            let f = Arc::clone(&f);
            self.execute(move || {
                let out = f(item);
                results.lock().unwrap()[i] = Some(out);
            });
        }
        self.join();
        Arc::try_unwrap(results)
            .unwrap_or_else(|_| panic!("map results still shared after join"))
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|o| o.expect("job completed"))
            .collect()
    }
}

fn worker_loop(shared: Arc<PoolShared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break Some(job);
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        match job {
            Some(job) => {
                // A panicking job must not wedge `join`.
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                if shared.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
                    let _g = shared.done_lock.lock().unwrap();
                    shared.done_cv.notify_all();
                }
                if res.is_err() {
                    // Swallow: the submitting side observes missing results.
                }
            }
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<u64>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x: u64| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn join_with_no_jobs_returns() {
        let pool = ThreadPool::new(2);
        pool.join();
    }

    #[test]
    fn survives_panicking_job() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        let flag = Arc::new(AtomicU64::new(0));
        let f = Arc::clone(&flag);
        pool.execute(move || {
            f.store(7, Ordering::SeqCst);
        });
        pool.join();
        assert_eq!(flag.load(Ordering::SeqCst), 7);
    }

    #[test]
    fn reusable_after_join() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&c);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.join();
            assert_eq!(c.load(Ordering::SeqCst), (round + 1) * 10);
        }
    }
}
