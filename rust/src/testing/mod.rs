//! Property-based testing harness (proptest is not available offline).
//!
//! [`check`] runs a property against `cases` randomized inputs drawn from a
//! generator; on failure it performs greedy shrinking via the generator's
//! `shrink` hook and reports the minimal failing input. Deterministic per
//! seed, with the seed printed on failure so a run is reproducible with
//! `KRONDPP_PROP_SEED`.

use crate::rng::Rng;

/// A value generator with optional shrinking.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    /// Draw a random value.
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate simplifications of a failing value (smaller-first).
    fn shrink(&self, _value: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 64;

/// Run a property. Panics (test failure) with the minimal failing case.
pub fn check<G: Gen>(name: &str, gen: &G, cases: usize, prop: impl Fn(&G::Value) -> bool) {
    let seed = std::env::var("KRONDPP_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xD1CE5EED_u64);
    let mut rng = Rng::new(seed ^ fxhash(name));
    for case in 0..cases {
        let value = gen.generate(&mut rng);
        if !prop(&value) {
            let minimal = shrink_loop(gen, value, &prop);
            panic!(
                "property '{name}' failed (case {case}, seed {seed}); minimal failing input: {minimal:?}"
            );
        }
    }
}

fn shrink_loop<G: Gen>(
    gen: &G,
    mut failing: G::Value,
    prop: &impl Fn(&G::Value) -> bool,
) -> G::Value {
    // Greedy descent, bounded to avoid pathological generators.
    for _ in 0..200 {
        let mut advanced = false;
        for cand in gen.shrink(&failing) {
            if !prop(&cand) {
                failing = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    failing
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Generator for usize in `[lo, hi]`, shrinking toward `lo`.
pub struct UsizeGen {
    pub lo: usize,
    pub hi: usize,
}

impl Gen for UsizeGen {
    type Value = usize;
    fn generate(&self, rng: &mut Rng) -> usize {
        rng.int_range(self.lo, self.hi)
    }
    fn shrink(&self, value: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *value > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*value - self.lo) / 2);
            out.push(value - 1);
        }
        out.dedup();
        out
    }
}

/// Generator pairing two sub-generators.
pub struct PairGen<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairGen<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, (a, b): &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> =
            self.0.shrink(a).into_iter().map(|a2| (a2, b.clone())).collect();
        out.extend(self.1.shrink(b).into_iter().map(|b2| (a.clone(), b2)));
        out
    }
}

/// Generator for symmetric PD matrices of a size drawn from `[nlo, nhi]`.
pub struct SpdGen {
    pub nlo: usize,
    pub nhi: usize,
    /// Diagonal boost, controls conditioning.
    pub ridge: f64,
}

impl Gen for SpdGen {
    type Value = crate::linalg::Matrix;
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let n = rng.int_range(self.nlo, self.nhi);
        let x = rng.normal_matrix(n, n);
        let mut g = crate::linalg::matmul::matmul_nt(&x, &x).expect("square");
        g.scale_mut(1.0 / n as f64);
        g.add_diag_mut(self.ridge);
        g
    }
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        // Shrink by taking leading principal submatrices (stay PD).
        let n = value.rows();
        let mut out = Vec::new();
        if n > self.nlo {
            for target in [self.nlo, n / 2, n - 1] {
                if target >= self.nlo && target < n {
                    let idx: Vec<usize> = (0..target).collect();
                    out.push(value.principal_submatrix(&idx));
                }
            }
        }
        out
    }
}

/// Generator for random subsets of `{0..n}` with size in `[klo, khi]`.
pub struct SubsetGen {
    pub n: usize,
    pub klo: usize,
    pub khi: usize,
}

impl Gen for SubsetGen {
    type Value = Vec<usize>;
    fn generate(&self, rng: &mut Rng) -> Vec<usize> {
        let k = rng.int_range(self.klo, self.khi.min(self.n));
        rng.subset(self.n, k)
    }
    fn shrink(&self, value: &Vec<usize>) -> Vec<Vec<usize>> {
        let mut out = Vec::new();
        if value.len() > self.klo {
            out.push(value[..value.len() - 1].to_vec());
            out.push(value[1..].to_vec());
            out.push(value[..self.klo.max(1)].to_vec());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("usize in range", &UsizeGen { lo: 3, hi: 10 }, 100, |&v| (3..=10).contains(&v));
    }

    #[test]
    #[should_panic(expected = "minimal failing input: 6")]
    fn failing_property_shrinks_to_boundary() {
        // Fails for v >= 6; shrinking should land exactly on 6.
        check("shrinks", &UsizeGen { lo: 0, hi: 100 }, 200, |&v| v < 6);
    }

    #[test]
    fn spd_gen_produces_pd_matrices() {
        check("spd gen PD", &SpdGen { nlo: 2, nhi: 8, ridge: 0.1 }, 20, |m| {
            crate::linalg::cholesky::is_pd(m)
        });
    }

    #[test]
    fn subset_gen_in_range() {
        let g = SubsetGen { n: 12, klo: 1, khi: 5 };
        check("subset gen", &g, 50, |s| {
            !s.is_empty()
                && s.len() <= 5
                && s.iter().all(|&i| i < 12)
                && s.windows(2).all(|w| w[0] < w[1])
        });
    }

    #[test]
    fn pair_gen_shrinks_both_sides() {
        let g = PairGen(UsizeGen { lo: 0, hi: 10 }, UsizeGen { lo: 0, hi: 10 });
        let shrunk = g.shrink(&(5, 7));
        assert!(shrunk.iter().any(|&(a, b)| a < 5 && b == 7));
        assert!(shrunk.iter().any(|&(a, b)| a == 5 && b < 7));
    }
}
