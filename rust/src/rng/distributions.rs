//! Non-uniform distributions and random matrix/subset helpers.

use super::Rng;
use crate::linalg::{matmul, Matrix};

impl Rng {
    /// Standard normal via Box–Muller (one value; simple and adequate —
    /// Gaussian draws are not on any hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 0.0 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Gamma(shape k, scale 1) via Marsaglia–Tsang (k ≥ 0 handled through
    /// the boost trick for k < 1).
    pub fn gamma(&mut self, k: f64) -> f64 {
        assert!(k > 0.0, "gamma: shape must be positive");
        if k < 1.0 {
            // boost: Gamma(k) = Gamma(k+1) · U^{1/k}
            let g = self.gamma(k + 1.0);
            let u = self.uniform().max(f64::MIN_POSITIVE);
            return g * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Chi-square with `df` degrees of freedom.
    pub fn chi_square(&mut self, df: f64) -> f64 {
        2.0 * self.gamma(df / 2.0)
    }

    /// Matrix with i.i.d. uniform entries in `[lo, hi)`.
    pub fn uniform_matrix(&mut self, rows: usize, cols: usize, lo: f64, hi: f64) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| self.uniform_range(lo, hi))
    }

    /// Matrix with i.i.d. standard normal entries.
    pub fn normal_matrix(&mut self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| self.normal())
    }

    /// Random PD kernel `XᵀX` with `X` uniform in `[0, √2)` — the paper's
    /// synthetic sub-kernel initializer (§5.1).
    pub fn paper_init_kernel(&mut self, n: usize) -> Matrix {
        let x = self.uniform_matrix(n, n, 0.0, std::f64::consts::SQRT_2);
        matmul::matmul_tn(&x, &x).expect("square by construction")
    }

    /// Wishart(identity/`n`·scale, df) sample via Bartlett decomposition:
    /// `W = A·Aᵀ` with `A` lower triangular, `A[i,i] = √χ²(df−i)`,
    /// `A[i,j] ~ N(0,1)` below the diagonal, then scaled.
    /// Used to initialize EM's marginal kernel `K` (§5.2 uses
    /// Wishart(N, I)/N).
    pub fn wishart(&mut self, n: usize, df: f64, scale: f64) -> Matrix {
        assert!(df > (n - 1) as f64, "wishart: df must exceed n-1");
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            a.set(i, i, self.chi_square(df - i as f64).sqrt());
            for j in 0..i {
                a.set(i, j, self.normal());
            }
        }
        let mut w = matmul::matmul_nt(&a, &a).expect("square by construction");
        w.scale_mut(scale);
        w.symmetrize_mut();
        w
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Uniform random subset of `{0..n}` of size `k` (sorted).
    pub fn subset(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "subset: k > n");
        // Floyd's algorithm.
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "weighted_index: all-zero weights");
        let mut target = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky::is_pd;

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(5);
        let n = 50_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.03, "var {v}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut rng = Rng::new(6);
        for &k in &[0.5, 1.0, 2.5, 8.0] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| rng.gamma(k)).sum::<f64>() / n as f64;
            assert!((mean - k).abs() < 0.1 * k.max(1.0), "shape {k}: mean {mean}");
        }
    }

    #[test]
    fn chi_square_mean() {
        let mut rng = Rng::new(7);
        let df = 10.0;
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.chi_square(df)).sum::<f64>() / n as f64;
        assert!((mean - df).abs() < 0.2, "mean {mean}");
    }

    #[test]
    fn wishart_is_pd_and_mean_scales() {
        let mut rng = Rng::new(8);
        let n = 10;
        let w = rng.wishart(n, n as f64, 1.0 / n as f64);
        assert!(is_pd(&w));
        // E[Wishart(df, I)] = df·I, so scaled by 1/n: trace ≈ n.
        let mut tr = 0.0;
        for _ in 0..50 {
            tr += rng.wishart(n, n as f64, 1.0 / n as f64).trace();
        }
        tr /= 50.0;
        assert!((tr - n as f64).abs() < 1.5, "avg trace {tr}");
    }

    #[test]
    fn paper_init_kernel_pd() {
        let mut rng = Rng::new(9);
        let k = rng.paper_init_kernel(20);
        assert!(k.is_symmetric(1e-9));
        assert!(is_pd(&k));
    }

    #[test]
    fn subset_sorted_unique_correct_size() {
        let mut rng = Rng::new(10);
        for _ in 0..100 {
            let s = rng.subset(50, 12);
            assert_eq!(s.len(), 12);
            for w in s.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(*s.last().unwrap() < 50);
        }
    }

    #[test]
    fn subset_full_and_empty() {
        let mut rng = Rng::new(11);
        assert_eq!(rng.subset(5, 5), vec![0, 1, 2, 3, 4]);
        assert!(rng.subset(5, 0).is_empty());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(12);
        let mut v: Vec<usize> = (0..20).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut rng = Rng::new(13);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..8000 {
            counts[rng.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.4, "ratio {ratio}");
    }
}
