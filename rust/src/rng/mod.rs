//! Pseudo-random number generation substrate (no external `rand` crate).
//!
//! [`Rng`] is a PCG64 (XSL-RR 128/64) generator: small state, excellent
//! statistical quality, splittable via independent streams, and fully
//! deterministic from a seed — which is what makes every experiment in
//! EXPERIMENTS.md exactly re-runnable.
//!
//! Distributions implemented here are the ones the paper's experiments
//! need: uniforms, Gaussians (Box–Muller), gamma (Marsaglia–Tsang) →
//! chi-square → Wishart (Bartlett decomposition, used to initialize EM's
//! marginal kernel as in §5.2), shuffles and subset draws.

pub mod distributions;

/// PCG64 XSL-RR generator.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ED051FC65DA44385DF649FCCF645;

impl Rng {
    /// Seed a generator; `stream` selects an independent sequence.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Rng { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Seed a generator on the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xDA3E39CB94B95BDB)
    }

    /// Derive an independent child generator (for worker threads /
    /// repeated experiments).
    pub fn split(&mut self, stream: u64) -> Rng {
        let seed = self.next_u64();
        Rng::with_stream(seed, stream.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free bound).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply method; bias < 2^-64, irrelevant for our sizes.
        let x = self.next_u64() as u128;
        ((x * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn int_range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn streams_independent() {
        let mut a = Rng::with_stream(7, 1);
        let mut b = Rng::with_stream(7, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut rng = Rng::new(3);
        let n = 20_000;
        let mut mean = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            mean += u;
        }
        mean /= n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut rng = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn int_range_inclusive() {
        let mut rng = Rng::new(11);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = rng.int_range(3, 5);
            assert!((3..=5).contains(&v));
            lo_seen |= v == 3;
            hi_seen |= v == 5;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn split_children_decorrelated() {
        let mut root = Rng::new(123);
        let mut c1 = root.split(0);
        let mut c2 = root.split(1);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }
}
