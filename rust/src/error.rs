//! Crate-wide error type.
//!
//! We avoid external error-handling crates on the hot path; `Error` is a
//! small enum covering the failure classes of the library: shape mismatches,
//! numerical breakdowns (non-PD matrices, singular solves), IO/parse errors,
//! runtime (PJRT) errors and coordinator failures.

use std::fmt;

/// Library result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All errors produced by the krondpp library.
#[derive(Debug)]
pub enum Error {
    /// Dimension mismatch in a linear-algebra operation.
    Shape(String),
    /// Numerical failure: non-positive-definite matrix, singular pivot,
    /// eigensolver non-convergence, etc.
    Numerical(String),
    /// Invalid argument or configuration.
    Invalid(String),
    /// IO failure (file read/write).
    Io(std::io::Error),
    /// Parse failure (JSON, CSV, config, CLI).
    Parse(String),
    /// PJRT runtime failure (artifact load/compile/execute).
    Runtime(String),
    /// Coordinator/service failure (queue closed, worker died, timeout).
    Service(String),
    /// Request rejected at admission control (unknown tenant, `k` larger
    /// than the tenant's current ground set) — distinct from [`Error::Service`]
    /// so clients can tell a bad request from a saturated or dying service.
    Rejected(String),
    /// Deadline or budget exhausted: the request expired before (or while)
    /// being served, or a client-side wait timed out. Distinct from
    /// [`Error::Service`] so retry loops can tell "too slow" from "broken"
    /// — a deadline miss is retryable with a fresh budget, a dropped
    /// request channel usually is not.
    Deadline(String),
    /// Request shed by admission control — the tenant's token bucket is
    /// empty, its outstanding-request cap is reached, or the service queue
    /// is past its shed depth. Same fast path as [`Error::Rejected`] (no
    /// queue slot burned), but *retryable*: unlike a bad request, the same
    /// request resubmitted after backoff is expected to succeed.
    Throttled(String),
}

/// Discriminant-only view of [`Error`], for metrics labels and exhaustive
/// dispatch without string matching. One variant per `Error` variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ErrorKind {
    Shape,
    Numerical,
    Invalid,
    Io,
    Parse,
    Runtime,
    Service,
    Rejected,
    Deadline,
    Throttled,
}

impl ErrorKind {
    /// Short stable label (metrics keys, log fields).
    pub fn label(&self) -> &'static str {
        match self {
            ErrorKind::Shape => "shape",
            ErrorKind::Numerical => "numerical",
            ErrorKind::Invalid => "invalid",
            ErrorKind::Io => "io",
            ErrorKind::Parse => "parse",
            ErrorKind::Runtime => "runtime",
            ErrorKind::Service => "service",
            ErrorKind::Rejected => "rejected",
            ErrorKind::Deadline => "deadline",
            ErrorKind::Throttled => "throttled",
        }
    }
}

impl Error {
    /// The error's kind — a copyable discriminant for dispatch and metrics.
    pub fn kind(&self) -> ErrorKind {
        match self {
            Error::Shape(_) => ErrorKind::Shape,
            Error::Numerical(_) => ErrorKind::Numerical,
            Error::Invalid(_) => ErrorKind::Invalid,
            Error::Io(_) => ErrorKind::Io,
            Error::Parse(_) => ErrorKind::Parse,
            Error::Runtime(_) => ErrorKind::Runtime,
            Error::Service(_) => ErrorKind::Service,
            Error::Rejected(_) => ErrorKind::Rejected,
            Error::Deadline(_) => ErrorKind::Deadline,
            Error::Throttled(_) => ErrorKind::Throttled,
        }
    }

    /// Whether a client may reasonably retry the same request. Transient
    /// service-side conditions (saturation, a dying worker, a missed
    /// deadline, an admission throttle, IO hiccups) are retryable;
    /// deterministic failures of the request itself (bad shapes, invalid
    /// arguments, numerical breakdown of the kernel, admission rejection)
    /// are not — resubmitting them yields the same answer.
    pub fn is_retryable(&self) -> bool {
        match self.kind() {
            ErrorKind::Service | ErrorKind::Deadline | ErrorKind::Io | ErrorKind::Throttled => {
                true
            }
            ErrorKind::Shape
            | ErrorKind::Numerical
            | ErrorKind::Invalid
            | ErrorKind::Parse
            | ErrorKind::Runtime
            | ErrorKind::Rejected => false,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Numerical(m) => write!(f, "numerical error: {m}"),
            Error::Invalid(m) => write!(f, "invalid argument: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Service(m) => write!(f, "service error: {m}"),
            Error::Rejected(m) => write!(f, "request rejected: {m}"),
            Error::Deadline(m) => write!(f, "deadline exceeded: {m}"),
            Error::Throttled(m) => write!(f, "throttled: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Construct a shape error with format args.
#[macro_export]
macro_rules! shape_err {
    ($($arg:tt)*) => { $crate::error::Error::Shape(format!($($arg)*)) };
}

/// Construct a numerical error with format args.
#[macro_export]
macro_rules! num_err {
    ($($arg:tt)*) => { $crate::error::Error::Numerical(format!($($arg)*)) };
}

/// Construct an invalid-argument error with format args.
#[macro_export]
macro_rules! invalid_err {
    ($($arg:tt)*) => { $crate::error::Error::Invalid(format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_variants() {
        let e = Error::Shape("2x3 vs 4x5".into());
        assert!(e.to_string().contains("shape"));
        let e = Error::Numerical("not PD".into());
        assert!(e.to_string().contains("numerical"));
        let e = Error::Parse("bad json".into());
        assert!(e.to_string().contains("parse"));
        let e = Error::Rejected("k=9 > ground set 4".into());
        assert!(e.to_string().contains("rejected"));
    }

    #[test]
    fn io_source_preserved() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(std::error::Error::source(&e).is_some());
    }

    #[test]
    fn macros_build_variants() {
        let e = shape_err!("got {}x{}", 2, 3);
        assert!(matches!(e, Error::Shape(_)));
        let e = num_err!("pivot {} too small", 1e-20);
        assert!(matches!(e, Error::Numerical(_)));
        let e = invalid_err!("bad arg {}", "x");
        assert!(matches!(e, Error::Invalid(_)));
    }

    /// One instance of every variant, for the exhaustive-match tests below.
    fn all_variants() -> Vec<Error> {
        vec![
            Error::Shape("s".into()),
            Error::Numerical("n".into()),
            Error::Invalid("i".into()),
            Error::Io(std::io::Error::new(std::io::ErrorKind::Other, "io")),
            Error::Parse("p".into()),
            Error::Runtime("r".into()),
            Error::Service("svc".into()),
            Error::Rejected("rej".into()),
            Error::Deadline("late".into()),
            Error::Throttled("rate".into()),
        ]
    }

    #[test]
    fn kind_covers_every_variant_exactly_once() {
        let kinds: Vec<ErrorKind> = all_variants().iter().map(Error::kind).collect();
        assert_eq!(
            kinds,
            vec![
                ErrorKind::Shape,
                ErrorKind::Numerical,
                ErrorKind::Invalid,
                ErrorKind::Io,
                ErrorKind::Parse,
                ErrorKind::Runtime,
                ErrorKind::Service,
                ErrorKind::Rejected,
                ErrorKind::Deadline,
                ErrorKind::Throttled,
            ]
        );
        // Labels are distinct and stable (metrics depend on them).
        let mut labels: Vec<&str> = kinds.iter().map(ErrorKind::label).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), 10, "duplicate ErrorKind labels");
    }

    #[test]
    fn retryability_partitions_the_kinds() {
        for e in all_variants() {
            let want = matches!(
                e.kind(),
                ErrorKind::Service | ErrorKind::Deadline | ErrorKind::Io | ErrorKind::Throttled
            );
            assert_eq!(e.is_retryable(), want, "retryable mismatch for {e}");
        }
    }

    #[test]
    fn deadline_is_distinct_from_service() {
        let late = Error::Deadline("budget 5ms exhausted".into());
        assert!(late.to_string().contains("deadline exceeded"));
        assert_ne!(late.kind(), ErrorKind::Service);
        assert!(late.is_retryable());
        assert!(!Error::Rejected("bad k".into()).is_retryable());
    }

    #[test]
    fn throttled_is_retryable_and_distinct_from_rejected() {
        let t = Error::Throttled("tenant rate 100/s exceeded".into());
        assert!(t.to_string().contains("throttled"));
        assert_eq!(t.kind(), ErrorKind::Throttled);
        assert_eq!(t.kind().label(), "throttled");
        // The whole point of the variant: same admission fast path as
        // Rejected, opposite retry semantics.
        assert!(t.is_retryable());
        assert_ne!(t.kind(), ErrorKind::Rejected);
        assert_ne!(t.kind(), ErrorKind::Service);
    }
}
