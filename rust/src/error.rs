//! Crate-wide error type.
//!
//! We avoid external error-handling crates on the hot path; `Error` is a
//! small enum covering the failure classes of the library: shape mismatches,
//! numerical breakdowns (non-PD matrices, singular solves), IO/parse errors,
//! runtime (PJRT) errors and coordinator failures.

use std::fmt;

/// Library result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All errors produced by the krondpp library.
#[derive(Debug)]
pub enum Error {
    /// Dimension mismatch in a linear-algebra operation.
    Shape(String),
    /// Numerical failure: non-positive-definite matrix, singular pivot,
    /// eigensolver non-convergence, etc.
    Numerical(String),
    /// Invalid argument or configuration.
    Invalid(String),
    /// IO failure (file read/write).
    Io(std::io::Error),
    /// Parse failure (JSON, CSV, config, CLI).
    Parse(String),
    /// PJRT runtime failure (artifact load/compile/execute).
    Runtime(String),
    /// Coordinator/service failure (queue closed, worker died, timeout).
    Service(String),
    /// Request rejected at admission control (unknown tenant, `k` larger
    /// than the tenant's current ground set) — distinct from [`Error::Service`]
    /// so clients can tell a bad request from a saturated or dying service.
    Rejected(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Numerical(m) => write!(f, "numerical error: {m}"),
            Error::Invalid(m) => write!(f, "invalid argument: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Service(m) => write!(f, "service error: {m}"),
            Error::Rejected(m) => write!(f, "request rejected: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Construct a shape error with format args.
#[macro_export]
macro_rules! shape_err {
    ($($arg:tt)*) => { $crate::error::Error::Shape(format!($($arg)*)) };
}

/// Construct a numerical error with format args.
#[macro_export]
macro_rules! num_err {
    ($($arg:tt)*) => { $crate::error::Error::Numerical(format!($($arg)*)) };
}

/// Construct an invalid-argument error with format args.
#[macro_export]
macro_rules! invalid_err {
    ($($arg:tt)*) => { $crate::error::Error::Invalid(format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_variants() {
        let e = Error::Shape("2x3 vs 4x5".into());
        assert!(e.to_string().contains("shape"));
        let e = Error::Numerical("not PD".into());
        assert!(e.to_string().contains("numerical"));
        let e = Error::Parse("bad json".into());
        assert!(e.to_string().contains("parse"));
        let e = Error::Rejected("k=9 > ground set 4".into());
        assert!(e.to_string().contains("rejected"));
    }

    #[test]
    fn io_source_preserved() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(std::error::Error::source(&e).is_some());
    }

    #[test]
    fn macros_build_variants() {
        let e = shape_err!("got {}x{}", 2, 3);
        assert!(matches!(e, Error::Shape(_)));
        let e = num_err!("pivot {} too small", 1e-20);
        assert!(matches!(e, Error::Numerical(_)));
        let e = invalid_err!("bad arg {}", "x");
        assert!(matches!(e, Error::Invalid(_)));
    }
}
