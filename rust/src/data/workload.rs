//! Service workload generation for the coordinator benchmarks: Poisson
//! request arrivals with configurable subset-size distribution, mirroring
//! a diverse-recommendation serving trace — plus a deterministic **churn
//! plan** interleaving catalog mutations (item add/remove/retire, low-rank
//! feedback perturbations) with the request stream, the workload shape
//! behind the delta-publish latency sweep.
//!
//! For the TCP serving-layer saturation sweep there is additionally a
//! **multi-tenant replay** generator ([`ReplaySpec`] → [`replay`]):
//! Zipf-skewed tenant selection, a sampling-mode mix across the backend
//! zoo, a configurable fraction of constraint-carrying slates, and
//! open-loop Poisson arrivals (the offered rate does not slow down when
//! the service does — exactly the regime that exposes shedding and SLO
//! behavior under overload).

use crate::dpp::SampleMode;
use crate::rng::Rng;
use std::time::Duration;

/// One synthetic request: arrival offset + requested subset size.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    /// Offset from trace start.
    pub at: Duration,
    /// Requested number of diverse items (k-DPP size); 0 = unconstrained
    /// DPP draw.
    pub k: usize,
}

/// Workload shape.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    /// Mean arrival rate (requests/second).
    pub rate_hz: f64,
    /// Total requests.
    pub count: usize,
    /// Subset-size range (inclusive); `0..=0` for unconstrained draws.
    pub k_lo: usize,
    pub k_hi: usize,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec { rate_hz: 200.0, count: 1000, k_lo: 5, k_hi: 20 }
    }
}

/// Generate a Poisson-arrival trace.
pub fn generate(spec: &WorkloadSpec, rng: &mut Rng) -> Vec<Request> {
    let mut at = 0.0f64;
    let mut out = Vec::with_capacity(spec.count);
    for _ in 0..spec.count {
        // Exponential inter-arrival.
        let u = rng.uniform().max(f64::MIN_POSITIVE);
        at += -u.ln() / spec.rate_hz;
        let k = if spec.k_hi == 0 { 0 } else { rng.int_range(spec.k_lo, spec.k_hi) };
        out.push(Request { at: Duration::from_secs_f64(at), k });
    }
    out
}

/// One catalog mutation in a churn trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChurnOp {
    /// Low-rank feedback perturbation of one sub-kernel (the shape a
    /// `KrkStochastic` minibatch step streams).
    Perturb,
    /// Append one item to a sub-kernel's catalog side.
    Add,
    /// Damp one item's interactions toward exclusion (soft delete).
    Retire,
    /// Hard-delete one item from a sub-kernel's catalog side.
    Remove,
}

/// Churn shape: how often the catalog mutates under the request stream.
#[derive(Clone, Debug)]
pub struct ChurnSpec {
    /// One mutation every `every` requests (0 disables churn).
    pub every: usize,
    /// Rank of `Perturb` events (the `r` of the rank-r delta).
    pub rank: usize,
    /// Entry magnitude of `Perturb` events.
    pub scale: f64,
}

impl Default for ChurnSpec {
    fn default() -> Self {
        ChurnSpec { every: 50, rank: 2, scale: 0.02 }
    }
}

/// One scheduled mutation: apply `op` just before serving request
/// `at_index`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChurnEvent {
    pub at_index: usize,
    pub op: ChurnOp,
}

/// Deterministic churn plan over a `requests`-long trace: one event every
/// `spec.every` requests, cycling Perturb → Add → Retire → Perturb →
/// Remove so adds and removes balance and the ground-set size stays
/// bounded. The caller materializes each event into a concrete
/// `KernelDelta` against the tenant's current factor shapes (this module
/// stays shape-agnostic).
pub fn churn_plan(spec: &ChurnSpec, requests: usize) -> Vec<ChurnEvent> {
    const CYCLE: [ChurnOp; 5] =
        [ChurnOp::Perturb, ChurnOp::Add, ChurnOp::Retire, ChurnOp::Perturb, ChurnOp::Remove];
    if spec.every == 0 {
        return Vec::new();
    }
    (0..requests / spec.every)
        .map(|i| ChurnEvent { at_index: (i + 1) * spec.every - 1, op: CYCLE[i % CYCLE.len()] })
        .collect()
}

/// Mixture weights over the sampling-backend zoo for replay traces.
/// Weights are relative (normalized internally); all-zero falls back to
/// exact-only.
#[derive(Clone, Copy, Debug)]
pub struct ModeMix {
    pub exact: f64,
    pub mcmc: f64,
    pub lowrank: f64,
    pub map: f64,
}

impl Default for ModeMix {
    fn default() -> Self {
        ModeMix { exact: 0.55, mcmc: 0.2, lowrank: 0.15, map: 0.1 }
    }
}

/// Shape of a multi-tenant serving replay (the saturation-sweep input).
#[derive(Clone, Debug)]
pub struct ReplaySpec {
    /// Number of tenants; requests target tenant indices `0..tenants`.
    pub tenants: usize,
    /// Zipf skew exponent `s`: tenant rank `r` (0-based) is chosen with
    /// weight `1/(r+1)^s`. `0` is uniform; `~1` is classic web skew.
    pub zipf_s: f64,
    /// Open-loop offered arrival rate (requests/second) across all
    /// tenants.
    pub rate_hz: f64,
    /// Total requests in the trace.
    pub count: usize,
    /// Subset-size range (inclusive).
    pub k_lo: usize,
    pub k_hi: usize,
    /// Fraction of requests carrying an include/exclude constraint.
    pub constraint_fraction: f64,
    /// Ground-set size constraints draw their item indices from.
    pub ground_size: usize,
    /// Relative backend mix.
    pub mode_mix: ModeMix,
    /// Chain length for `Mcmc` draws in the mix.
    pub mcmc_steps: usize,
    /// Projection rank for `LowRank` draws in the mix.
    pub lowrank_rank: usize,
}

impl Default for ReplaySpec {
    fn default() -> Self {
        ReplaySpec {
            tenants: 4,
            zipf_s: 1.1,
            rate_hz: 500.0,
            count: 2000,
            k_lo: 2,
            k_hi: 8,
            constraint_fraction: 0.25,
            ground_size: 24,
            mode_mix: ModeMix::default(),
            mcmc_steps: 500,
            lowrank_rank: 8,
        }
    }
}

/// One request in a replay trace. `at` is the open-loop send time: a
/// replaying client sleeps until `at` and fires regardless of how many
/// earlier requests are still outstanding.
#[derive(Clone, Debug)]
pub struct ReplayRequest {
    /// Offset from trace start (open-loop arrival).
    pub at: Duration,
    /// Target tenant index (`0..spec.tenants`, Zipf-skewed).
    pub tenant: usize,
    /// Requested slate size.
    pub k: usize,
    /// Backend for this draw.
    pub mode: SampleMode,
    /// Must-include item indices (possibly empty).
    pub include: Vec<usize>,
    /// Must-exclude item indices (disjoint from `include`).
    pub exclude: Vec<usize>,
}

/// Generate a Zipf-skewed, mode-mixed, open-loop replay trace.
pub fn replay(spec: &ReplaySpec, rng: &mut Rng) -> Vec<ReplayRequest> {
    let tenants = spec.tenants.max(1);
    // Zipf inverse-CDF table over tenant ranks.
    let weights: Vec<f64> =
        (0..tenants).map(|r| 1.0 / ((r + 1) as f64).powf(spec.zipf_s)).collect();
    let total_w: f64 = weights.iter().sum();

    let mix = [
        spec.mode_mix.exact.max(0.0),
        spec.mode_mix.mcmc.max(0.0),
        spec.mode_mix.lowrank.max(0.0),
        spec.mode_mix.map.max(0.0),
    ];
    let mix_total: f64 = mix.iter().sum();

    let mut at = 0.0f64;
    let mut out = Vec::with_capacity(spec.count);
    for _ in 0..spec.count {
        let u = rng.uniform().max(f64::MIN_POSITIVE);
        at += -u.ln() / spec.rate_hz;

        // Tenant: linear scan of the Zipf CDF (tenant counts are small).
        let mut target = rng.uniform() * total_w;
        let mut tenant = tenants - 1;
        for (r, w) in weights.iter().enumerate() {
            if target < *w {
                tenant = r;
                break;
            }
            target -= *w;
        }

        let k = if spec.k_hi == 0 { 0 } else { rng.int_range(spec.k_lo, spec.k_hi) };

        let mode = if mix_total <= 0.0 {
            SampleMode::Exact
        } else {
            let mut m = rng.uniform() * mix_total;
            if m < mix[0] {
                SampleMode::Exact
            } else {
                m -= mix[0];
                if m < mix[1] {
                    SampleMode::Mcmc { steps: spec.mcmc_steps }
                } else if m - mix[1] < mix[2] {
                    SampleMode::LowRank { rank: spec.lowrank_rank }
                } else {
                    SampleMode::Map
                }
            }
        };

        let (include, exclude) = if spec.ground_size > 2
            && k > 0
            && k < spec.ground_size
            && rng.bernoulli(spec.constraint_fraction)
        {
            // One pinned item plus one or two excluded items, all
            // distinct, with room left for the k - |include| free picks.
            let pin = rng.below(spec.ground_size);
            let mut exclude = Vec::new();
            let want = 1 + rng.below(2.min(spec.ground_size.saturating_sub(k + 1)).max(1));
            let mut guard = 0;
            while exclude.len() < want && guard < 32 {
                guard += 1;
                let e = rng.below(spec.ground_size);
                if e != pin && !exclude.contains(&e) {
                    exclude.push(e);
                }
            }
            (vec![pin], exclude)
        } else {
            (Vec::new(), Vec::new())
        };

        out.push(ReplayRequest {
            at: Duration::from_secs_f64(at),
            tenant,
            k,
            mode,
            include,
            exclude,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_monotone_and_rate_about_right() {
        let mut rng = Rng::new(1);
        let spec = WorkloadSpec { rate_hz: 100.0, count: 2000, k_lo: 3, k_hi: 7 };
        let trace = generate(&spec, &mut rng);
        assert_eq!(trace.len(), 2000);
        for w in trace.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
        let total = trace.last().unwrap().at.as_secs_f64();
        let rate = 2000.0 / total;
        assert!((rate - 100.0).abs() < 10.0, "rate {rate}");
        assert!(trace.iter().all(|r| (3..=7).contains(&r.k)));
    }

    #[test]
    fn unconstrained_mode() {
        let mut rng = Rng::new(2);
        let spec = WorkloadSpec { rate_hz: 10.0, count: 10, k_lo: 0, k_hi: 0 };
        let trace = generate(&spec, &mut rng);
        assert!(trace.iter().all(|r| r.k == 0));
    }

    #[test]
    fn churn_plan_cycles_and_balances_size() {
        let spec = ChurnSpec { every: 10, rank: 2, scale: 0.02 };
        let plan = churn_plan(&spec, 100);
        assert_eq!(plan.len(), 10);
        // Events land inside the trace, strictly increasing.
        assert!(plan.iter().all(|e| e.at_index < 100));
        for w in plan.windows(2) {
            assert!(w[1].at_index > w[0].at_index);
        }
        // One full cycle adds exactly as many items as it removes.
        let adds = plan.iter().filter(|e| e.op == ChurnOp::Add).count();
        let removes = plan.iter().filter(|e| e.op == ChurnOp::Remove).count();
        assert_eq!(adds, removes);
        assert_eq!(plan[0].op, ChurnOp::Perturb);
        assert_eq!(plan[1].op, ChurnOp::Add);
    }

    #[test]
    fn churn_disabled_by_zero_every() {
        let spec = ChurnSpec { every: 0, ..ChurnSpec::default() };
        assert!(churn_plan(&spec, 1000).is_empty());
    }

    #[test]
    fn replay_zipf_skew_orders_tenant_frequencies() {
        let mut rng = Rng::new(7);
        let spec = ReplaySpec { tenants: 4, zipf_s: 1.2, count: 4000, ..ReplaySpec::default() };
        let trace = replay(&spec, &mut rng);
        assert_eq!(trace.len(), 4000);
        let mut counts = [0usize; 4];
        for r in &trace {
            assert!(r.tenant < 4);
            counts[r.tenant] += 1;
        }
        // Rank-0 strictly dominates, and the tail is still exercised.
        assert!(counts[0] > counts[1] && counts[1] > counts[2]);
        assert!(counts[3] > 0, "tail tenant never hit: {counts:?}");
        // Zipf s=1.2 over 4 ranks gives rank 0 ≈ 55% of mass.
        let frac0 = counts[0] as f64 / 4000.0;
        assert!((0.4..0.7).contains(&frac0), "rank-0 fraction {frac0}");
    }

    #[test]
    fn replay_arrivals_open_loop_monotone() {
        let mut rng = Rng::new(8);
        let spec = ReplaySpec { rate_hz: 250.0, count: 1000, ..ReplaySpec::default() };
        let trace = replay(&spec, &mut rng);
        for w in trace.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
        let total = trace.last().unwrap().at.as_secs_f64();
        let rate = 1000.0 / total;
        assert!((rate - 250.0).abs() < 30.0, "offered rate {rate}");
    }

    #[test]
    fn replay_mode_mix_and_constraints_respected() {
        let mut rng = Rng::new(9);
        let spec = ReplaySpec {
            count: 3000,
            constraint_fraction: 0.3,
            ground_size: 24,
            k_lo: 2,
            k_hi: 8,
            ..ReplaySpec::default()
        };
        let trace = replay(&spec, &mut rng);
        let mut modes = std::collections::BTreeMap::new();
        let mut constrained = 0usize;
        for r in &trace {
            *modes.entry(r.mode.label()).or_insert(0usize) += 1;
            assert!((2..=8).contains(&r.k));
            if !r.include.is_empty() || !r.exclude.is_empty() {
                constrained += 1;
                // Include/exclude disjoint and in range.
                for i in &r.include {
                    assert!(*i < 24);
                    assert!(!r.exclude.contains(i));
                }
                assert!(r.exclude.iter().all(|e| *e < 24));
            }
        }
        // Every backend of the default mix appears.
        for label in ["exact", "mcmc", "lowrank", "map"] {
            assert!(modes.contains_key(label), "missing mode {label}: {modes:?}");
        }
        let frac = constrained as f64 / 3000.0;
        assert!((0.2..0.4).contains(&frac), "constraint fraction {frac}");
    }

    #[test]
    fn replay_deterministic_for_fixed_seed() {
        let spec = ReplaySpec { count: 100, ..ReplaySpec::default() };
        let a = replay(&spec, &mut Rng::new(42));
        let b = replay(&spec, &mut Rng::new(42));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.k, y.k);
            assert_eq!(x.mode, y.mode);
            assert_eq!(x.include, y.include);
            assert_eq!(x.exclude, y.exclude);
        }
    }
}
