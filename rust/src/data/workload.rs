//! Service workload generation for the coordinator benchmarks: Poisson
//! request arrivals with configurable subset-size distribution, mirroring
//! a diverse-recommendation serving trace — plus a deterministic **churn
//! plan** interleaving catalog mutations (item add/remove/retire, low-rank
//! feedback perturbations) with the request stream, the workload shape
//! behind the delta-publish latency sweep.

use crate::rng::Rng;
use std::time::Duration;

/// One synthetic request: arrival offset + requested subset size.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    /// Offset from trace start.
    pub at: Duration,
    /// Requested number of diverse items (k-DPP size); 0 = unconstrained
    /// DPP draw.
    pub k: usize,
}

/// Workload shape.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    /// Mean arrival rate (requests/second).
    pub rate_hz: f64,
    /// Total requests.
    pub count: usize,
    /// Subset-size range (inclusive); `0..=0` for unconstrained draws.
    pub k_lo: usize,
    pub k_hi: usize,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec { rate_hz: 200.0, count: 1000, k_lo: 5, k_hi: 20 }
    }
}

/// Generate a Poisson-arrival trace.
pub fn generate(spec: &WorkloadSpec, rng: &mut Rng) -> Vec<Request> {
    let mut at = 0.0f64;
    let mut out = Vec::with_capacity(spec.count);
    for _ in 0..spec.count {
        // Exponential inter-arrival.
        let u = rng.uniform().max(f64::MIN_POSITIVE);
        at += -u.ln() / spec.rate_hz;
        let k = if spec.k_hi == 0 { 0 } else { rng.int_range(spec.k_lo, spec.k_hi) };
        out.push(Request { at: Duration::from_secs_f64(at), k });
    }
    out
}

/// One catalog mutation in a churn trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChurnOp {
    /// Low-rank feedback perturbation of one sub-kernel (the shape a
    /// `KrkStochastic` minibatch step streams).
    Perturb,
    /// Append one item to a sub-kernel's catalog side.
    Add,
    /// Damp one item's interactions toward exclusion (soft delete).
    Retire,
    /// Hard-delete one item from a sub-kernel's catalog side.
    Remove,
}

/// Churn shape: how often the catalog mutates under the request stream.
#[derive(Clone, Debug)]
pub struct ChurnSpec {
    /// One mutation every `every` requests (0 disables churn).
    pub every: usize,
    /// Rank of `Perturb` events (the `r` of the rank-r delta).
    pub rank: usize,
    /// Entry magnitude of `Perturb` events.
    pub scale: f64,
}

impl Default for ChurnSpec {
    fn default() -> Self {
        ChurnSpec { every: 50, rank: 2, scale: 0.02 }
    }
}

/// One scheduled mutation: apply `op` just before serving request
/// `at_index`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChurnEvent {
    pub at_index: usize,
    pub op: ChurnOp,
}

/// Deterministic churn plan over a `requests`-long trace: one event every
/// `spec.every` requests, cycling Perturb → Add → Retire → Perturb →
/// Remove so adds and removes balance and the ground-set size stays
/// bounded. The caller materializes each event into a concrete
/// `KernelDelta` against the tenant's current factor shapes (this module
/// stays shape-agnostic).
pub fn churn_plan(spec: &ChurnSpec, requests: usize) -> Vec<ChurnEvent> {
    const CYCLE: [ChurnOp; 5] =
        [ChurnOp::Perturb, ChurnOp::Add, ChurnOp::Retire, ChurnOp::Perturb, ChurnOp::Remove];
    if spec.every == 0 {
        return Vec::new();
    }
    (0..requests / spec.every)
        .map(|i| ChurnEvent { at_index: (i + 1) * spec.every - 1, op: CYCLE[i % CYCLE.len()] })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_monotone_and_rate_about_right() {
        let mut rng = Rng::new(1);
        let spec = WorkloadSpec { rate_hz: 100.0, count: 2000, k_lo: 3, k_hi: 7 };
        let trace = generate(&spec, &mut rng);
        assert_eq!(trace.len(), 2000);
        for w in trace.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
        let total = trace.last().unwrap().at.as_secs_f64();
        let rate = 2000.0 / total;
        assert!((rate - 100.0).abs() < 10.0, "rate {rate}");
        assert!(trace.iter().all(|r| (3..=7).contains(&r.k)));
    }

    #[test]
    fn unconstrained_mode() {
        let mut rng = Rng::new(2);
        let spec = WorkloadSpec { rate_hz: 10.0, count: 10, k_lo: 0, k_hi: 0 };
        let trace = generate(&spec, &mut rng);
        assert!(trace.iter().all(|r| r.k == 0));
    }

    #[test]
    fn churn_plan_cycles_and_balances_size() {
        let spec = ChurnSpec { every: 10, rank: 2, scale: 0.02 };
        let plan = churn_plan(&spec, 100);
        assert_eq!(plan.len(), 10);
        // Events land inside the trace, strictly increasing.
        assert!(plan.iter().all(|e| e.at_index < 100));
        for w in plan.windows(2) {
            assert!(w[1].at_index > w[0].at_index);
        }
        // One full cycle adds exactly as many items as it removes.
        let adds = plan.iter().filter(|e| e.op == ChurnOp::Add).count();
        let removes = plan.iter().filter(|e| e.op == ChurnOp::Remove).count();
        assert_eq!(adds, removes);
        assert_eq!(plan[0].op, ChurnOp::Perturb);
        assert_eq!(plan[1].op, ChurnOp::Add);
    }

    #[test]
    fn churn_disabled_by_zero_every() {
        let spec = ChurnSpec { every: 0, ..ChurnSpec::default() };
        assert!(churn_plan(&spec, 1000).is_empty());
    }
}
