//! Service workload generation for the coordinator benchmarks: Poisson
//! request arrivals with configurable subset-size distribution, mirroring
//! a diverse-recommendation serving trace.

use crate::rng::Rng;
use std::time::Duration;

/// One synthetic request: arrival offset + requested subset size.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    /// Offset from trace start.
    pub at: Duration,
    /// Requested number of diverse items (k-DPP size); 0 = unconstrained
    /// DPP draw.
    pub k: usize,
}

/// Workload shape.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    /// Mean arrival rate (requests/second).
    pub rate_hz: f64,
    /// Total requests.
    pub count: usize,
    /// Subset-size range (inclusive); `0..=0` for unconstrained draws.
    pub k_lo: usize,
    pub k_hi: usize,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec { rate_hz: 200.0, count: 1000, k_lo: 5, k_hi: 20 }
    }
}

/// Generate a Poisson-arrival trace.
pub fn generate(spec: &WorkloadSpec, rng: &mut Rng) -> Vec<Request> {
    let mut at = 0.0f64;
    let mut out = Vec::with_capacity(spec.count);
    for _ in 0..spec.count {
        // Exponential inter-arrival.
        let u = rng.uniform().max(f64::MIN_POSITIVE);
        at += -u.ln() / spec.rate_hz;
        let k = if spec.k_hi == 0 { 0 } else { rng.int_range(spec.k_lo, spec.k_hi) };
        out.push(Request { at: Duration::from_secs_f64(at), k });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_monotone_and_rate_about_right() {
        let mut rng = Rng::new(1);
        let spec = WorkloadSpec { rate_hz: 100.0, count: 2000, k_lo: 3, k_hi: 7 };
        let trace = generate(&spec, &mut rng);
        assert_eq!(trace.len(), 2000);
        for w in trace.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
        let total = trace.last().unwrap().at.as_secs_f64();
        let rate = 2000.0 / total;
        assert!((rate - 100.0).abs() < 10.0, "rate {rate}");
        assert!(trace.iter().all(|r| (3..=7).contains(&r.k)));
    }

    #[test]
    fn unconstrained_mode() {
        let mut rng = Rng::new(2);
        let spec = WorkloadSpec { rate_hz: 10.0, count: 10, k_lo: 0, k_hi: 0 };
        let trace = generate(&spec, &mut rng);
        assert!(trace.iter().all(|r| r.k == 0));
    }
}
