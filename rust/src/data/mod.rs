//! Dataset generators and workloads for every experiment in the paper's
//! evaluation (plus the serving traces used by the coordinator benches).
//! Substitutions for the paper's proprietary datasets are documented in
//! DESIGN.md §5.

pub mod genes;
pub mod registry;
pub mod synthetic;
pub mod workload;

pub use synthetic::{approx_sample_k, fig1_problem, paper_truth_kernel, sample_training_set};
