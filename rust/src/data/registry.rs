//! Baby-registry-like dataset (§5.2 substitution — see DESIGN.md §5).
//!
//! The paper's Table 1 uses the Amazon baby-registry dataset of [10]:
//! 17 product categories, the 6 largest with N = 100 products each, and
//! registries (observed subsets) per category. We don't have the Amazon
//! data, so we simulate category corpora with the structure that makes
//! registries DPP-like: products grouped into functional sub-types
//! (bottles, bibs, ...) with within-type redundancy (shoppers rarely buy
//! two of the same sub-type) and popularity-weighted quality.
//!
//! For each category a ground-truth DPP kernel is built as
//! `L[i,j] = q_i·q_j·sim(i,j)` (quality × diversity decomposition, as in
//! Kulesza–Taskar) and registries are exact DPP samples — so Table 1's
//! quantity, the achievable test log-likelihood of each estimator on
//! held-out registries, is measured against genuinely DPP-distributed
//! data, preserving the paper's qualitative ordering.

use crate::dpp::{Kernel, Sampler};
use crate::error::Result;
use crate::learn::traits::TrainingSet;
use crate::linalg::Matrix;
use crate::rng::Rng;

/// The six large categories of the paper's Table 1.
pub const CATEGORIES: [&str; 6] = ["apparel", "bath", "bedding", "diaper", "feeding", "gear"];

/// One simulated category: ground truth + train/test registries.
pub struct RegistryCategory {
    pub name: String,
    pub truth: Kernel,
    pub train: TrainingSet,
    pub test: TrainingSet,
}

/// Ground-truth kernel for one category of `n` products with `subtypes`
/// functional groups.
pub fn category_kernel(n: usize, subtypes: usize, rng: &mut Rng) -> Matrix {
    // Product embeddings: sub-type direction + idiosyncratic component.
    let dim = subtypes + 6;
    let mut feats = Matrix::zeros(n, dim);
    for i in 0..n {
        let t = rng.below(subtypes);
        // strong sub-type coordinate → within-type similarity
        feats.set(i, t, 1.0);
        for j in subtypes..dim {
            feats.set(i, j, 0.45 * rng.normal());
        }
        // normalize row
        let norm: f64 = feats.row(i).iter().map(|v| v * v).sum::<f64>().sqrt();
        for j in 0..dim {
            let v = feats.get(i, j) / norm;
            feats.set(i, j, v);
        }
    }
    // Quality: log-normal popularity.
    let quality: Vec<f64> = (0..n).map(|_| (0.35 * rng.normal()).exp() * 0.55).collect();
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            let sim: f64 = feats.row(i).iter().zip(feats.row(j)).map(|(a, b)| a * b).sum();
            let v = quality[i] * quality[j] * sim;
            l.set(i, j, v);
            l.set(j, i, v);
        }
    }
    l.add_diag_mut(1e-6);
    l
}

/// Generate one category: `n_train`/`n_test` registries, exact DPP draws.
pub fn generate_category(
    name: &str,
    n: usize,
    n_train: usize,
    n_test: usize,
    rng: &mut Rng,
) -> Result<RegistryCategory> {
    let subtypes = (n / 8).max(4);
    let l = category_kernel(n, subtypes, rng);
    let truth = Kernel::Full(l);
    let sampler = Sampler::new(&truth)?;
    let draw = |count: usize, rng: &mut Rng| -> Result<TrainingSet> {
        let mut subsets = Vec::with_capacity(count);
        while subsets.len() < count {
            let y = sampler.sample(rng);
            // Registries are non-empty baskets.
            if !y.is_empty() {
                subsets.push(y);
            }
        }
        TrainingSet::new(n, subsets)
    };
    let train = draw(n_train, rng)?;
    let test = draw(n_test, rng)?;
    Ok(RegistryCategory { name: name.to_string(), truth, train, test })
}

/// The full 6-category benchmark of Table 1 (N = 100 per category).
pub fn all_categories(
    n: usize,
    n_train: usize,
    n_test: usize,
    seed: u64,
) -> Result<Vec<RegistryCategory>> {
    let mut rng = Rng::new(seed);
    CATEGORIES
        .iter()
        .map(|name| {
            let mut crng = rng.split(fx(name));
            generate_category(name, n, n_train, n_test, &mut crng)
        })
        .collect()
}

fn fx(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky;

    #[test]
    fn kernel_is_pd_with_quality_diversity_structure() {
        let mut rng = Rng::new(1);
        let l = category_kernel(40, 5, &mut rng);
        assert!(cholesky::is_pd(&l));
        // Diagonal (quality²) positive, off-diagonal mixed magnitudes.
        for i in 0..40 {
            assert!(l.get(i, i) > 0.0);
        }
    }

    #[test]
    fn registries_nonempty_and_in_range() {
        let mut rng = Rng::new(2);
        let cat = generate_category("bath", 30, 25, 10, &mut rng).unwrap();
        assert_eq!(cat.train.len(), 25);
        assert_eq!(cat.test.len(), 10);
        for y in cat.train.subsets.iter().chain(&cat.test.subsets) {
            assert!(!y.is_empty());
            assert!(y.iter().all(|&i| i < 30));
        }
    }

    #[test]
    fn six_categories_deterministic() {
        let a = all_categories(20, 5, 3, 7).unwrap();
        let b = all_categories(20, 5, 3, 7).unwrap();
        assert_eq!(a.len(), 6);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.train.subsets, y.train.subsets);
        }
        // Categories differ from each other.
        assert_ne!(a[0].train.subsets, a[1].train.subsets);
    }
}
