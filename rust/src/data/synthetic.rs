//! Synthetic ground-truth kernels and training sets (§5.1 protocol).
//!
//! The paper draws a "true" KronDPP kernel with sub-kernels `L_i = XᵀX`,
//! `X ~ U[0,√2)`, then samples training subsets with sizes uniform in a
//! range. Exact sampling is used wherever tractable; above a size
//! threshold the generator switches to the leverage-score approximation
//! ([`crate::data::approx_sample_k`]) — a documented substitution (see
//! DESIGN.md §5): the learning-curve experiments only require plausibly
//! DPP-distributed data, not exact draws, at the scales where exact
//! sampling is the paper's own acknowledged bottleneck (§6).

use crate::dpp::{Kernel, Sampler};
use crate::error::Result;
use crate::learn::traits::TrainingSet;

use crate::rng::Rng;

/// Ground-truth kernel + sampled training data.
pub struct SyntheticProblem {
    pub truth: Kernel,
    pub train: TrainingSet,
}

/// §5.1 ground-truth Kron2 kernel with paper-style sub-kernels.
pub fn paper_truth_kernel(n1: usize, n2: usize, rng: &mut Rng) -> Kernel {
    let l1 = crate::learn::init::paper_subkernel(n1, rng);
    let l2 = crate::learn::init::paper_subkernel(n2, rng);
    Kernel::Kron2(l1, l2)
}

/// Sample `count` subsets with sizes uniform in `[size_lo, size_hi]`
/// (k-DPP draws from the truth). Uses exact sampling when
/// `N·k³ ≤ budget`, else the leverage-score approximation.
pub fn sample_training_set(
    truth: &Kernel,
    count: usize,
    size_lo: usize,
    size_hi: usize,
    rng: &mut Rng,
) -> Result<TrainingSet> {
    let n = truth.n();
    let sampler = Sampler::new(truth)?;
    let mut subsets = Vec::with_capacity(count);
    // Exact-phase-2 budget: ~2·N·k² per contraction step, k steps.
    const EXACT_FLOP_BUDGET: f64 = 2e10;
    for _ in 0..count {
        let k = rng.int_range(size_lo, size_hi.min(n));
        let cost = 2.0 * n as f64 * (k as f64).powi(3);
        let y = if cost <= EXACT_FLOP_BUDGET {
            sampler.sample_k(k, rng)
        } else {
            approx_sample_k(&sampler, k, rng)
        };
        subsets.push(y);
    }
    TrainingSet::new(n, subsets)
}

/// Leverage-score approximate k-DPP draw: exact phase 1 (elementary
/// symmetric polynomials over the true spectrum), then weighted sampling
/// *without replacement* by the leverage scores `ℓ_i = Σ_{j∈J} v_{ij}²` of
/// the selected eigenvectors — i.e. Alg. 2 without the orthogonalization
/// between picks. Cost `O(Nk)` after the shared eigendecomposition.
pub fn approx_sample_k(sampler: &Sampler, k: usize, rng: &mut Rng) -> Vec<usize> {
    let n = sampler.n();
    let eig = sampler.eigen();
    let lam: Vec<f64> = eig.values.iter().map(|&l| l.max(0.0)).collect();
    let j = crate::dpp::elementary::sample_k_eigenvectors(&lam, k, rng);
    let mut weights = vec![0.0f64; n];
    for &jj in &j {
        let col = eig.vectors.column(jj);
        for (w, c) in weights.iter_mut().zip(&col) {
            *w += c * c;
        }
    }
    // Weighted draw without replacement.
    let mut out = Vec::with_capacity(k);
    for _ in 0..k {
        let i = rng.weighted_index(&weights);
        out.push(i);
        weights[i] = 0.0;
    }
    out.sort_unstable();
    out
}

/// Full §5.1 problem: truth + data, matching Figure 1a/1b's protocol
/// (100 subsets, sizes U[10,190] at N=2500; scaled proportionally for
/// other N so the expected κ stays ≈ N·0.04–0.08).
pub fn fig1_problem(n1: usize, n2: usize, count: usize, seed: u64) -> Result<SyntheticProblem> {
    let mut rng = Rng::new(seed);
    let truth = paper_truth_kernel(n1, n2, &mut rng);
    let n = n1 * n2;
    // Paper sizes at N=2500: U[10, 190]. Scale linearly with N.
    let lo = ((10 * n) as f64 / 2500.0).round().max(2.0) as usize;
    let hi = ((190 * n) as f64 / 2500.0).round().max(4.0) as usize;
    let train = sample_training_set(&truth, count, lo, hi.min(n / 2), &mut rng)?;
    Ok(SyntheticProblem { truth, train })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    #[test]
    fn training_sizes_in_range() {
        let mut rng = Rng::new(1);
        let truth = paper_truth_kernel(5, 5, &mut rng);
        let data = sample_training_set(&truth, 20, 3, 8, &mut rng).unwrap();
        assert_eq!(data.len(), 20);
        for y in &data.subsets {
            assert!((3..=8).contains(&y.len()), "size {}", y.len());
        }
    }

    #[test]
    fn approx_sampler_respects_leverage() {
        // With a near-singular direction, the approximate sampler should
        // rarely pick the null item.
        let mut l = Matrix::identity(6);
        l.set(5, 5, 1e-9);
        let kernel = Kernel::Full(l);
        let sampler = Sampler::new(&kernel).unwrap();
        let mut rng = Rng::new(2);
        let mut null_picks = 0;
        for _ in 0..200 {
            let y = approx_sample_k(&sampler, 2, &mut rng);
            assert_eq!(y.len(), 2);
            if y.contains(&5) {
                null_picks += 1;
            }
        }
        assert!(null_picks < 10, "null item picked {null_picks}/200");
    }

    #[test]
    fn fig1_problem_scales_sizes() {
        let p = fig1_problem(5, 5, 10, 3).unwrap();
        assert_eq!(p.train.ground_size, 25);
        assert!(p.train.kappa() <= 12);
        assert!(p.train.len() == 10);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = fig1_problem(4, 4, 5, 42).unwrap();
        let b = fig1_problem(4, 4, 5, 42).unwrap();
        assert_eq!(a.train.subsets, b.train.subsets);
    }
}
