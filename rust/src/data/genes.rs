//! GENES-like dataset (§5.3 substitution — see DESIGN.md §5).
//!
//! The paper's GENES data is 10,000 genes × 331 features (distances to
//! hubs of the BioGRID interaction network), on which the authors
//! *construct a synthetic ground-truth Gaussian DPP kernel* and sample
//! training sets from it. We don't have BioGRID, so we simulate the
//! feature geometry — genes clustered around functional modules, features
//! = distances to hub points — and then follow the paper's own protocol:
//! Gaussian (RBF) ground-truth kernel, 100 samples with sizes U[50, 200].
//!
//! The kernel is held in low-rank-friendly feature form where possible;
//! the dense RBF kernel is only materialized when a learner needs it.

use crate::dpp::Kernel;
use crate::error::Result;
use crate::learn::traits::TrainingSet;
use crate::linalg::{matmul, Matrix};
use crate::rng::Rng;

/// Simulated GENES feature matrix + derived ground-truth kernel.
pub struct GenesProblem {
    /// `N × d` feature matrix (d = 331 in the paper's configuration).
    pub features: Matrix,
    /// Dense ground-truth kernel (Gaussian RBF over features).
    pub truth: Kernel,
    pub train: TrainingSet,
}

/// Generate clustered "gene" features: `clusters` module centers in
/// `d`-dim space; each gene = center + noise; features are distances to
/// `d` hub points (mirroring BioGRID hub-distance features).
pub fn genes_features(n: usize, d: usize, clusters: usize, rng: &mut Rng) -> Matrix {
    // Hub points.
    let hubs = rng.normal_matrix(d, 8); // d hubs in an 8-dim latent space
    // Module centers.
    let centers = rng.normal_matrix(clusters, 8);
    let mut x = Matrix::zeros(n, d);
    for g in 0..n {
        let c = rng.below(clusters);
        // gene position = center + noise in latent space
        let mut pos = [0.0f64; 8];
        for (k, p) in pos.iter_mut().enumerate() {
            *p = centers.get(c, k) + 0.35 * rng.normal();
        }
        // feature j = distance from gene to hub j
        for j in 0..d {
            let mut dist2 = 0.0;
            for (k, p) in pos.iter().enumerate() {
                let diff = p - hubs.get(j, k);
                dist2 += diff * diff;
            }
            x.set(g, j, dist2.sqrt());
        }
    }
    x
}

/// Gaussian RBF kernel `L[i,j] = s·exp(−‖x_i−x_j‖²/(2σ²))` over feature
/// rows. `σ` defaults to the median pairwise distance heuristic estimated
/// on a subsample.
pub fn rbf_kernel(x: &Matrix, scale: f64, rng: &mut Rng) -> Matrix {
    let n = x.rows();
    // Median-distance heuristic on ≤256 sampled pairs.
    let mut d2s: Vec<f64> = Vec::new();
    for _ in 0..256 {
        let i = rng.below(n);
        let j = rng.below(n);
        if i != j {
            d2s.push(row_dist2(x, i, j));
        }
    }
    d2s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let sigma2 = d2s.get(d2s.len() / 2).copied().unwrap_or(1.0).max(1e-12);
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            let v = scale * (-row_dist2(x, i, j) / (2.0 * sigma2)).exp();
            l.set(i, j, v);
            l.set(j, i, v);
        }
    }
    // RBF Gram matrices are PSD; add a small ridge for strict PD.
    l.add_diag_mut(scale * 1e-6);
    l
}

fn row_dist2(x: &Matrix, i: usize, j: usize) -> f64 {
    let (ri, rj) = (x.row(i), x.row(j));
    let mut acc = 0.0;
    for (a, b) in ri.iter().zip(rj) {
        let d = a - b;
        acc += d * d;
    }
    acc
}

/// Build the full §5.3 problem: features → RBF truth → training samples
/// with sizes `U[size_lo, size_hi]`. The kernel `scale` is chosen so the
/// spectrum supports subsets of the requested sizes.
pub fn genes_problem(
    n: usize,
    d: usize,
    count: usize,
    size_lo: usize,
    size_hi: usize,
    seed: u64,
) -> Result<GenesProblem> {
    let mut rng = Rng::new(seed);
    let features = genes_features(n, d, (n / 64).clamp(4, 48), &mut rng);
    let truth_matrix = rbf_kernel(&features, 1.0, &mut rng);
    let truth = Kernel::Full(truth_matrix);
    let train = crate::data::synthetic::sample_training_set(
        &truth, count, size_lo, size_hi, &mut rng,
    )?;
    Ok(GenesProblem { features, truth, train })
}

/// Low-rank "Gram" ground truth `L = (1/d)·X·Xᵀ` used by the Fig-1c
/// out-of-memory experiment (rank `d` kernel on a huge ground set).
pub fn lowrank_truth(x: &Matrix) -> Kernel {
    let mut l = matmul::gram_rows(x);
    l.scale_mut(1.0 / x.cols() as f64);
    l.add_diag_mut(1e-8);
    Kernel::Full(l)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky;

    #[test]
    fn features_have_requested_shape() {
        let mut rng = Rng::new(1);
        let x = genes_features(50, 12, 4, &mut rng);
        assert_eq!(x.shape(), (50, 12));
        // Distances are non-negative.
        assert!(x.as_slice().iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn rbf_kernel_pd_and_unit_diagonalish() {
        let mut rng = Rng::new(2);
        let x = genes_features(30, 8, 3, &mut rng);
        let l = rbf_kernel(&x, 1.0, &mut rng);
        assert!(l.is_symmetric(1e-12));
        assert!(cholesky::is_pd(&l));
        for i in 0..30 {
            assert!((l.get(i, i) - 1.0).abs() < 1e-3);
        }
        // Off-diagonals in (0,1).
        assert!(l.get(0, 1) > 0.0 && l.get(0, 1) < 1.0);
    }

    #[test]
    fn clustered_genes_more_similar_within_cluster() {
        // Average kernel value should exceed the global minimum for
        // same-cluster pairs — weak structural check via variance.
        let mut rng = Rng::new(3);
        let x = genes_features(60, 10, 3, &mut rng);
        let l = rbf_kernel(&x, 1.0, &mut rng);
        let vals: Vec<f64> =
            (0..60).flat_map(|i| ((i + 1)..60).map(move |j| (i, j))).map(|(i, j)| l.get(i, j)).collect();
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        let var =
            vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / vals.len() as f64;
        assert!(var > 1e-4, "kernel has no cluster structure (var {var})");
    }

    #[test]
    fn problem_generation_end_to_end() {
        let p = genes_problem(64, 16, 10, 4, 12, 7).unwrap();
        assert_eq!(p.train.ground_size, 64);
        assert_eq!(p.train.len(), 10);
        assert!(p.train.kappa() <= 12);
    }

    #[test]
    fn lowrank_truth_is_pd() {
        let mut rng = Rng::new(4);
        let x = rng.normal_matrix(40, 6);
        let k = lowrank_truth(&x);
        if let Kernel::Full(l) = &k {
            assert!(cholesky::is_pd(l));
        } else {
            panic!("expected dense kernel");
        }
    }
}
