//! Benchmark harness (criterion is not available offline).
//!
//! [`Bencher`] runs warmup iterations, then measures until either a target
//! wall-clock budget or an iteration cap is reached, and reports
//! min/median/mean/p95 with a throughput hook. `cargo bench` targets set
//! `harness = false` and drive this directly, printing rows that the
//! EXPERIMENTS.md tables are copied from.

use crate::ser::json::Json;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Result statistics for one benchmark case.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
    pub max: Duration,
}

impl Stats {
    /// Seconds per iteration (mean).
    pub fn secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    /// Human line used by the bench binaries.
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  median {:>12}  min {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_dur(self.mean),
            fmt_dur(self.median),
            fmt_dur(self.min),
            fmt_dur(self.p95),
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} µs", s * 1e6)
    }
}

/// Benchmark runner configuration.
pub struct Bencher {
    /// Max wall-clock per case (measurement phase).
    pub budget: Duration,
    /// Warmup wall-clock per case.
    pub warmup: Duration,
    /// Hard cap on measured iterations.
    pub max_iters: usize,
    /// Minimum measured iterations (even if over budget).
    pub min_iters: usize,
}

/// Parse a `usize` knob from the environment (the `KRONDPP_BENCH_*`
/// variables), falling back to `default` when unset or unparsable. One
/// definition so every bench binary agrees on the parse rule.
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// The `KRONDPP_BENCH_BUDGET_MS` per-case budget (default 1500 ms — keeps
/// full `cargo bench` runs in minutes; CI smoke sets it low).
pub fn bench_budget_ms() -> usize {
    env_usize("KRONDPP_BENCH_BUDGET_MS", 1500)
}

/// The `KRONDPP_BENCH_MAX_N` case-size cap (default unbounded; CI smoke
/// sets it low so runs finish in seconds).
pub fn bench_max_n() -> usize {
    env_usize("KRONDPP_BENCH_MAX_N", usize::MAX)
}

impl Default for Bencher {
    fn default() -> Self {
        let ms = bench_budget_ms() as u64;
        Bencher {
            budget: Duration::from_millis(ms),
            warmup: Duration::from_millis(ms / 5),
            max_iters: 10_000,
            min_iters: 3,
        }
    }
}

impl Bencher {
    /// Time `f`, which must consume its own inputs (clone outside if needed).
    pub fn run(&self, name: &str, mut f: impl FnMut()) -> Stats {
        // Warmup.
        let wstart = Instant::now();
        while wstart.elapsed() < self.warmup {
            f();
        }
        // Measure.
        let mut samples: Vec<Duration> = Vec::new();
        let mstart = Instant::now();
        while (mstart.elapsed() < self.budget || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        samples.sort();
        let iters = samples.len();
        let total: Duration = samples.iter().sum();
        let stats = Stats {
            name: name.to_string(),
            iters,
            min: samples[0],
            median: samples[iters / 2],
            mean: total / iters as u32,
            p95: samples[(iters * 95 / 100).min(iters - 1)],
            max: samples[iters - 1],
        };
        println!("{}", stats.row());
        stats
    }

    /// Time a single invocation (for long-running cases like full learning
    /// iterations where repeated sampling is too expensive).
    pub fn run_once(&self, name: &str, f: impl FnOnce()) -> Stats {
        let t = Instant::now();
        f();
        let d = t.elapsed();
        let stats = Stats {
            name: name.to_string(),
            iters: 1,
            min: d,
            median: d,
            mean: d,
            p95: d,
            max: d,
        };
        println!("{}", stats.row());
        stats
    }
}

/// Structured bench output: accumulates measured cases plus derived
/// quantities (speedup ratios) and writes a `BENCH_<name>.json` document,
/// so CI can archive the perf trajectory per commit.
#[derive(Default)]
pub struct Report {
    cases: Vec<Json>,
    derived: BTreeMap<String, Json>,
}

impl Report {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a measured case with optional named throughput metrics
    /// (e.g. `("gflops", 12.3)`).
    pub fn case(&mut self, stats: &Stats, metrics: &[(&str, f64)]) {
        let mut obj = BTreeMap::new();
        obj.insert("name".into(), Json::Str(stats.name.clone()));
        obj.insert("iters".into(), Json::Num(stats.iters as f64));
        obj.insert("mean_s".into(), Json::Num(stats.mean.as_secs_f64()));
        obj.insert("median_s".into(), Json::Num(stats.median.as_secs_f64()));
        obj.insert("min_s".into(), Json::Num(stats.min.as_secs_f64()));
        obj.insert("p95_s".into(), Json::Num(stats.p95.as_secs_f64()));
        for (k, v) in metrics {
            obj.insert((*k).into(), Json::Num(*v));
        }
        self.cases.push(Json::Obj(obj));
    }

    /// Record a case from raw named metrics — for benches that measure
    /// end-to-end throughput/latency themselves (e.g. the service bench
    /// driving a live coordinator) instead of timing a closure via
    /// [`Bencher`].
    pub fn case_raw(&mut self, name: &str, metrics: &[(&str, f64)]) {
        let mut obj = BTreeMap::new();
        obj.insert("name".into(), Json::Str(name.into()));
        for (k, v) in metrics {
            obj.insert((*k).into(), Json::Num(*v));
        }
        self.cases.push(Json::Obj(obj));
    }

    /// Record a derived quantity (e.g. `packed_vs_legacy_speedup_n1024`).
    pub fn derived(&mut self, key: &str, value: f64) {
        self.derived.insert(key.into(), Json::Num(value));
    }

    /// Write the report (compact JSON) to `path`.
    pub fn write(&self, bench: &str, path: &str) -> std::io::Result<()> {
        let mut root = BTreeMap::new();
        root.insert("bench".into(), Json::Str(bench.into()));
        root.insert("cases".into(), Json::Arr(self.cases.clone()));
        root.insert("derived".into(), Json::Obj(self.derived.clone()));
        std::fs::write(path, Json::Obj(root).to_string())
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_collects_stats() {
        let b = Bencher {
            budget: Duration::from_millis(30),
            warmup: Duration::from_millis(5),
            max_iters: 1000,
            min_iters: 3,
        };
        let mut acc = 0u64;
        let stats = b.run("tiny", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(stats.iters >= 3);
        assert!(stats.min <= stats.median && stats.median <= stats.max);
    }

    #[test]
    fn report_writes_valid_json() {
        let b = Bencher {
            budget: Duration::from_millis(10),
            warmup: Duration::from_millis(2),
            max_iters: 50,
            min_iters: 3,
        };
        let stats = b.run("case-a", || {
            black_box(1 + 1);
        });
        let mut r = Report::new();
        r.case(&stats, &[("gflops", 1.5)]);
        r.derived("speedup", 2.0);
        let path = std::env::temp_dir().join("krondpp_bench_report_test.json");
        r.write("unit", path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&text).unwrap();
        let Json::Obj(root) = parsed else { panic!("not an object") };
        assert_eq!(root["bench"], Json::Str("unit".into()));
        let Json::Arr(cases) = &root["cases"] else { panic!("no cases") };
        assert_eq!(cases.len(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn run_once_single_iter() {
        let b = Bencher::default();
        let stats = b.run_once("once", || {
            std::thread::sleep(Duration::from_millis(2));
        });
        assert_eq!(stats.iters, 1);
        assert!(stats.mean >= Duration::from_millis(2));
    }
}
