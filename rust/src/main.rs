//! krondpp CLI — leader entrypoint.
//!
//! Subcommands:
//!   figures   regenerate the paper's tables/figures (CSV + stdout rows)
//!   learn     fit a DPP kernel to a dataset file (or synthetic data)
//!   sample    draw subsets from a learned kernel (optionally conditioned
//!             on --include/--exclude item sets, backend chosen by --mode)
//!   map       deterministic greedy MAP slate (argmax-det heuristic)
//!   marginals print factored inclusion probabilities P(i ∈ Y) = K_ii
//!   serve     run the sampling service over a synthetic request trace
//!             (optionally with catalog churn interleaved via delta
//!             publishes), or expose it over TCP with --listen
//!   client    drive a serve --listen endpoint over the wire protocol
//!             (single ops or an open-loop --replay saturation sweep)
//!   churn     drive item add/retire/remove + low-rank perturbations
//!             through a live tenant's delta-publish path
//!   datagen   generate + save datasets (registry / genes / synthetic)
//!   info      environment + artifact status

use krondpp::cli::Args;
use krondpp::config::{Algorithm, ServiceConfig};
use krondpp::coordinator::{
    run_replay, DeltaOutcome, DppService, NetConfig, NetServer, TenantId, WireClient,
};
use krondpp::data::workload::{churn_plan, replay, ChurnOp, ChurnSpec, ReplaySpec};
use krondpp::dpp::{
    map_slate_into, ConditionedSampler, Constraint, Kernel, KernelDelta, LowRankBackend,
    MapScratch, McmcBackend, SampleMode, SampleScratch, Sampler, SamplerBackend,
};
use krondpp::error::Result;
use krondpp::figures::{fig1, fig2, tables, Scale};
use krondpp::learn::{init, Learner};
use krondpp::rng::Rng;
use krondpp::ser::matio;
use std::path::Path;

const USAGE: &str = "\
krondpp — Kronecker Determinantal Point Processes (NIPS 2016 reproduction)

USAGE: krondpp <command> [flags]

COMMANDS:
  figures  --fig 1a|1b|1c|2 | --table 1|2   [--scale small|paper] [--seed S]
  learn    --algo picard|krk|krk-stochastic|joint|em --data FILE.kds
           [--n1 N --n2 N] [--iters I] [--step A] [--tol T] [--out PREFIX]
  sample   --kernel PREFIX [--tenant NAME] [--k K] [--count C] [--seed S]
           [--include I1,I2,..] [--exclude J1,J2,..]
           [--mode exact|mcmc|lowrank|map] [--steps S] [--rank R]
  map      --kernel PREFIX [--tenant NAME] [--k K]
           [--include I1,I2,..] [--exclude J1,J2,..]
  marginals --kernel PREFIX [--tenant NAME] [--top T]
  serve    [--n1 N --n2 N] [--requests R] [--rate HZ] [--workers W]
           [--config FILE.json] [--tenants T] [--tenant NAME] [--learn-live]
           [--budget-ms MS] [--churn-every E] [--churn-rank R]
           [--listen HOST:PORT]
  client   --addr HOST:PORT [--op sample|map|marginals|report|shutdown]
           [--tenant NAME] [--k K] [--count C] [--mode M] [--budget-ms MS]
           [--include I1,..] [--exclude J1,..]
           | --replay [--requests R] [--rate HZ] [--conns C] [--zipf S]
           [--tenants n1,n2,..] [--constraint-frac F] [--k-lo K --k-hi K]
  churn    [--n1 N --n2 N] [--ops C] [--rank R] [--scale S] [--seed S]
           [--max-depth D]
  datagen  --kind synthetic|genes|registry --out FILE.kds [--n1 N --n2 N]
           [--count C] [--seed S]
  info

Multi-tenant serving: --config declares named tenants + the LRU epoch
bound (see configs/service.json); --tenants T provisions T extra synthetic
market tenants; --tenant NAME pins the request trace (and the --learn-live
publish target) to one tenant instead of round-robining over all of them.
For `sample`/`marginals`, --tenant NAME loads the kernel saved under
PREFIX.NAME.

Serving over TCP: `serve --listen 127.0.0.1:7333` exposes the service on
the length-prefixed JSON wire protocol (DESIGN.md §3.2) instead of the
local synthetic trace; `client --addr HOST:PORT` drives it — single ops,
or `--replay` for an open-loop Zipf-skewed saturation sweep that reports
client-observed shed fractions and per-tenant p50/p99. Per-tenant
admission control (token-bucket \"admission\" blocks + \"shed_queue_depth\"
in the config) sheds overload with retryable `throttled` errors before a
queue slot is burned; the report tracks per-tenant SLO violations.

Fault tolerance: `serve --budget-ms MS` gives every request a deadline
budget (expired work is shed as `deadline_exceeded`, never served late);
the config file's \"fallback\" block tunes the per-tenant circuit breaker
and degraded-mode chain, and \"epoch_history\" bounds rollback depth.

Conditioned sampling: `sample --include 0,5 --exclude 3` draws from the
DPP conditioned on those items being in / out of every subset (with --k,
the slate size counts the forced includes). `marginals` prints the
factored inclusion probabilities P(i in Y) = K_ii without forming the
dense N x N marginal kernel.

Catalog churn: `churn --ops C` applies C mutations (rank-r feedback
perturbations, item add/retire/remove) to a live tenant through the
incremental delta-publish path — each op refreshes the cached
eigendecomposition by a rank-r secular update (O(r·N₁²)) instead of a
full re-eigendecomposition, falling back to exact when the rank gate or
the --max-depth drift budget says so. `serve --churn-every E` interleaves
the same mutations into the request trace (one per E requests), so the
report's per-tenant churn[deltas/incremental/depth] line shows the live
mix.

Sampler zoo: `sample --mode mcmc --steps 4000` runs one independent
insert/delete (or fixed-size swap) chain per draw; `--mode lowrank
--rank R` samples the top-R spectral projection of the kernel exactly;
`--mode map` (or the `map` subcommand, which also prints log det) builds
the deterministic greedy MAP slate — `--k 0` auto-sizes it.
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(args) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("{USAGE}");
            std::process::exit(1);
        }
    }
}

fn run(tokens: Vec<String>) -> Result<()> {
    let args = Args::parse(tokens, &["learn-live", "help", "replay"])?;
    match args.command.as_deref() {
        Some("figures") => cmd_figures(&args),
        Some("learn") => cmd_learn(&args),
        Some("sample") => cmd_sample(&args),
        Some("map") => cmd_map(&args),
        Some("marginals") => cmd_marginals(&args),
        Some("serve") => cmd_serve(&args),
        Some("client") => cmd_client(&args),
        Some("churn") => cmd_churn(&args),
        Some("datagen") => cmd_datagen(&args),
        Some("info") => cmd_info(),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_figures(args: &Args) -> Result<()> {
    let scale = Scale::parse(args.str_flag("scale").unwrap_or("small"))?;
    let seed: u64 = args.get_or("seed", 2016)?;
    let mut ran = false;
    if let Some(fig) = args.str_flag("fig") {
        ran = true;
        match fig {
            "1a" => fig1::fig1a(scale, seed)?,
            "1b" => fig1::fig1b(scale, seed)?,
            "1c" => fig1::fig1c(scale, seed)?,
            "2" | "2a" | "2b" => fig2::fig2(scale, seed)?,
            "all" => {
                fig1::fig1a(scale, seed)?;
                fig1::fig1b(scale, seed)?;
                fig1::fig1c(scale, seed)?;
                fig2::fig2(scale, seed)?;
            }
            other => return Err(krondpp::Error::Parse(format!("unknown figure '{other}'"))),
        }
    }
    if let Some(table) = args.str_flag("table") {
        ran = true;
        match table {
            "1" => {
                tables::table1(scale, seed)?;
            }
            "2" => fig2::table2(scale, seed)?,
            "all" => {
                tables::table1(scale, seed)?;
                fig2::table2(scale, seed)?;
            }
            other => return Err(krondpp::Error::Parse(format!("unknown table '{other}'"))),
        }
    }
    if !ran {
        // Default: everything.
        fig1::fig1a(scale, seed)?;
        fig1::fig1b(scale, seed)?;
        fig1::fig1c(scale, seed)?;
        fig2::fig2(scale, seed)?;
        tables::table1(scale, seed)?;
        fig2::table2(scale, seed)?;
    }
    Ok(())
}

fn cmd_learn(args: &Args) -> Result<()> {
    let algo = Algorithm::parse(args.str_flag("algo").unwrap_or("krk"))?;
    let iters: usize = args.get_or("iters", 20)?;
    let step: f64 = args.get_or("step", 1.0)?;
    let tol: f64 = args.get_or("tol", 1e-4)?;
    let seed: u64 = args.get_or("seed", 2016)?;

    // Load or synthesize data.
    let (n, subsets) = match args.str_flag("data") {
        Some(path) => matio::read_dataset(Path::new(path))?,
        None => {
            let n1: usize = args.get_or("n1", 20)?;
            let n2: usize = args.get_or("n2", 20)?;
            let count: usize = args.get_or("count", 100)?;
            let mut rng = Rng::new(seed);
            let truth = krondpp::data::paper_truth_kernel(n1, n2, &mut rng);
            let data = krondpp::data::sample_training_set(
                &truth,
                count,
                (n1 * n2 / 50).max(2),
                (n1 * n2 / 8).max(4),
                &mut rng,
            )?;
            println!("synthetic data: N={} n={count}", n1 * n2);
            (n1 * n2, data.subsets)
        }
    };
    let data = krondpp::learn::TrainingSet::new(n, subsets)?;
    let n1: usize = args.get_or("n1", (n as f64).sqrt() as usize)?;
    let n2: usize = args.get_or("n2", n / n1.max(1))?;
    if n1 * n2 != n
        && matches!(
            algo,
            Algorithm::Krk | Algorithm::KrkStochastic | Algorithm::JointPicard
        )
    {
        return Err(krondpp::Error::Invalid(format!(
            "n1*n2 = {} must equal N = {n} for Kronecker learners",
            n1 * n2
        )));
    }
    println!(
        "learning: algo={} N={n} n={} κ={} iters≤{iters} a={step} δ={tol}",
        algo.name(),
        data.len(),
        data.kappa()
    );
    let mut rng = Rng::new(seed ^ 0x1EA2);
    let result = match algo {
        Algorithm::Picard => {
            let l = if n1 * n2 == n {
                let l1 = init::paper_subkernel(n1, &mut rng);
                let l2 = init::paper_subkernel(n2, &mut rng);
                krondpp::linalg::kron::kron(&l1, &l2)
            } else {
                init::paper_subkernel(n, &mut rng)
            };
            krondpp::learn::Picard::new(l, step)?.run(&data, iters, tol)?
        }
        Algorithm::Krk => {
            let l1 = init::paper_subkernel(n1, &mut rng);
            let l2 = init::paper_subkernel(n2, &mut rng);
            krondpp::learn::KrkPicard::new(l1, l2, step)?.run(&data, iters, tol)?
        }
        Algorithm::KrkStochastic => {
            let l1 = init::paper_subkernel(n1, &mut rng);
            let l2 = init::paper_subkernel(n2, &mut rng);
            let mb: usize = args.get_or("minibatch", 1)?;
            krondpp::learn::KrkStochastic::new(l1, l2, step, mb, seed).run(&data, iters, tol)?
        }
        Algorithm::JointPicard => {
            let l1 = init::paper_subkernel(n1, &mut rng);
            let l2 = init::paper_subkernel(n2, &mut rng);
            krondpp::learn::JointPicard::new(l1, l2, step)?.run(&data, iters, tol)?
        }
        Algorithm::Em => {
            let k0 = init::wishart_marginal(n, &mut rng)?;
            krondpp::learn::EmLearner::from_marginal(&k0)?.run(&data, iters, tol)?
        }
    };
    for r in &result.history {
        println!(
            "  iter {:>3}  t={:>8.2}s  ll={:.6}",
            r.iter,
            r.elapsed.as_secs_f64(),
            r.log_likelihood
        );
    }
    println!(
        "done: final ll {:.6} ({} iterations, converged={})",
        result.final_ll(),
        result.history.len() - 1,
        result.converged
    );
    if let Some(prefix) = args.str_flag("out") {
        save_kernel(&result.kernel, prefix)?;
    }
    Ok(())
}

fn save_kernel(kernel: &Kernel, prefix: &str) -> Result<()> {
    match kernel {
        Kernel::Full(l) => {
            matio::write_matrix(Path::new(&format!("{prefix}.full.kdm")), l)?;
            println!("saved {prefix}.full.kdm");
        }
        Kernel::Kron2(l1, l2) => {
            matio::write_matrix(Path::new(&format!("{prefix}.l1.kdm")), l1)?;
            matio::write_matrix(Path::new(&format!("{prefix}.l2.kdm")), l2)?;
            println!("saved {prefix}.l1.kdm / {prefix}.l2.kdm");
        }
        Kernel::Kron3(l1, l2, l3) => {
            matio::write_matrix(Path::new(&format!("{prefix}.l1.kdm")), l1)?;
            matio::write_matrix(Path::new(&format!("{prefix}.l2.kdm")), l2)?;
            matio::write_matrix(Path::new(&format!("{prefix}.l3.kdm")), l3)?;
            println!("saved {prefix}.l{{1,2,3}}.kdm");
        }
    }
    Ok(())
}

fn load_kernel(prefix: &str) -> Result<Kernel> {
    let full = format!("{prefix}.full.kdm");
    if Path::new(&full).exists() {
        return Ok(Kernel::Full(matio::read_matrix(Path::new(&full))?));
    }
    let l1 = format!("{prefix}.l1.kdm");
    let l2 = format!("{prefix}.l2.kdm");
    let l3 = format!("{prefix}.l3.kdm");
    if Path::new(&l3).exists() {
        return Ok(Kernel::Kron3(
            matio::read_matrix(Path::new(&l1))?,
            matio::read_matrix(Path::new(&l2))?,
            matio::read_matrix(Path::new(&l3))?,
        ));
    }
    Ok(Kernel::Kron2(
        matio::read_matrix(Path::new(&l1))?,
        matio::read_matrix(Path::new(&l2))?,
    ))
}

/// Parse a `--include`/`--exclude` comma-separated index list.
fn parse_items(args: &Args, flag: &str) -> Result<Vec<usize>> {
    match args.str_flag(flag) {
        None => Ok(Vec::new()),
        Some(list) => list
            .split(',')
            .filter(|t| !t.is_empty())
            .map(|t| {
                t.trim().parse().map_err(|_| {
                    krondpp::Error::Parse(format!("--{flag}: cannot parse item '{t}'"))
                })
            })
            .collect(),
    }
}

/// Resolve the kernel-file prefix, honoring the multi-tenant PREFIX.TENANT
/// layout (see `learn --out`).
fn tenant_prefix(args: &Args) -> Result<String> {
    let prefix = args.require_str("kernel")?;
    Ok(match args.str_flag("tenant") {
        Some(tenant) => format!("{prefix}.{tenant}"),
        None => prefix.to_string(),
    })
}

fn cmd_sample(args: &Args) -> Result<()> {
    let kernel = load_kernel(&tenant_prefix(args)?)?;
    let k: usize = args.get_or("k", 0)?;
    let count: usize = args.get_or("count", 5)?;
    let seed: u64 = args.get_or("seed", 0)?;
    let constraint = Constraint::new(parse_items(args, "include")?, parse_items(args, "exclude")?)?;
    let mode = SampleMode::parse(
        args.str_flag("mode").unwrap_or("exact"),
        args.get_opt::<usize>("steps")?,
        args.get_opt::<usize>("rank")?,
    )?;
    if !constraint.is_empty() {
        if k > 0 {
            constraint.validate_k(k, kernel.n())?;
        } else {
            constraint.validate(kernel.n())?;
        }
    }
    let k_opt = if k == 0 { None } else { Some(k) };
    match mode {
        SampleMode::Map => {
            // Deterministic: one slate regardless of --count/--seed.
            let mut scratch = MapScratch::new();
            let mut slate = Vec::new();
            let logdet =
                map_slate_into(&kernel, k_opt, &constraint, &mut scratch, &mut slate)?;
            println!("map slate ({} items, log det = {logdet:.6}): {slate:?}", slate.len());
        }
        SampleMode::Mcmc { steps } => {
            // One independent `steps`-move chain per draw, proposing only
            // over items the constraint leaves free.
            let backend = McmcBackend::new(&kernel, constraint, steps)?;
            draw_loop(&backend, k_opt, count, seed)?;
        }
        SampleMode::LowRank { rank } => {
            // Exact sampling of the top-`rank` spectral projection.
            let backend = LowRankBackend::new(&kernel, rank, constraint)?;
            draw_loop(&backend, k_opt, count, seed)?;
        }
        SampleMode::Exact if !constraint.is_empty() => {
            // Conditioned draws: one Schur-complement setup, then
            // scratch-reuse sampling (A ⊆ Y, B ∩ Y = ∅ in every draw).
            let cs = ConditionedSampler::new(&kernel, constraint)?;
            let mut rng = Rng::new(seed);
            let mut scratch = SampleScratch::new();
            for i in 0..count {
                let y = if k == 0 {
                    cs.sample_with_scratch(&mut rng, &mut scratch)
                } else {
                    let mut y = Vec::new();
                    cs.sample_k_into(k, &mut rng, &mut scratch, &mut y);
                    y
                };
                println!("sample {i}: {y:?}");
            }
        }
        SampleMode::Exact => {
            let sampler = Sampler::new(&kernel)?;
            if k > sampler.n() {
                return Err(krondpp::Error::Invalid(format!(
                    "requested k={k} > ground set {}",
                    sampler.n()
                )));
            }
            // Batched engine: one eigendecomposition, draws fanned across
            // threads, deterministic in --seed regardless of thread count.
            let draws = sampler.sample_batch(count, k_opt, seed);
            for (i, y) in draws.iter().enumerate() {
                println!("sample {i}: {y:?}");
            }
        }
    }
    Ok(())
}

/// Draw `count` subsets from a zoo backend with one shared scratch.
fn draw_loop<B: SamplerBackend>(
    backend: &B,
    k: Option<usize>,
    count: usize,
    seed: u64,
) -> Result<()> {
    let mut rng = Rng::new(seed);
    let mut scratch = SampleScratch::new();
    let mut y = Vec::new();
    for i in 0..count {
        backend.draw_into(k, &mut rng, &mut scratch, &mut y)?;
        println!("sample {i}: {y:?}");
    }
    Ok(())
}

/// `map` subcommand: the deterministic greedy MAP slate with its
/// objective value (`--k 0` auto-sizes via the gain rule).
fn cmd_map(args: &Args) -> Result<()> {
    let kernel = load_kernel(&tenant_prefix(args)?)?;
    let k: usize = args.get_or("k", 0)?;
    let constraint = Constraint::new(parse_items(args, "include")?, parse_items(args, "exclude")?)?;
    let mut scratch = MapScratch::new();
    let mut slate = Vec::new();
    let k_opt = if k == 0 { None } else { Some(k) };
    let logdet = map_slate_into(&kernel, k_opt, &constraint, &mut scratch, &mut slate)?;
    println!("N = {}  slate size = {}  log det(L_S) = {logdet:.6}", kernel.n(), slate.len());
    println!("slate: {slate:?}");
    Ok(())
}

fn cmd_marginals(args: &Args) -> Result<()> {
    let kernel = load_kernel(&tenant_prefix(args)?)?;
    let eigen = kernel.eigen()?;
    // Factored diagonal: O(N·(N₁+N₂)), no dense K.
    let probs = eigen.inclusion_probabilities();
    let expected_size: f64 = probs.iter().sum();
    println!("N = {}  E[|Y|] = {expected_size:.3}", kernel.n());
    let top: usize = args.get_or("top", probs.len())?;
    let mut ranked: Vec<(usize, f64)> = probs.into_iter().enumerate().collect();
    ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    for (i, p) in ranked.into_iter().take(top) {
        println!("item {i:>6}  P(i in Y) = {p:.6}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let n1: usize = args.get_or("n1", 20)?;
    let n2: usize = args.get_or("n2", 20)?;
    let requests: usize = args.get_or("requests", 2000)?;
    let rate: f64 = args.get_or("rate", 500.0)?;
    let seed: u64 = args.get_or("seed", 2016)?;
    let mut cfg = match args.str_flag("config") {
        Some(path) => ServiceConfig::load(Path::new(path))?,
        None => ServiceConfig::default(),
    };
    if let Some(w) = args.get_opt::<usize>("workers")? {
        cfg.workers = w.max(1);
    }
    // --budget-ms MS deadlines every request in the trace (0 = none);
    // overrides the config file's default_budget_ms.
    if let Some(b) = args.get_opt::<u64>("budget-ms")? {
        cfg.default_budget_ms = b;
    }
    // --tenants T provisions T extra synthetic market tenants on top of
    // the default one and anything the config file declares.
    let extra_tenants: usize = args.get_or("tenants", 0)?;
    for t in 0..extra_tenants {
        cfg.tenants.push(krondpp::config::TenantSpec {
            name: format!("market-{t}"),
            n1,
            n2,
            seed: seed ^ (t as u64 + 1),
            admission: None,
        });
    }
    let mut rng = Rng::new(seed);
    let truth = krondpp::data::paper_truth_kernel(n1, n2, &mut rng);
    let svc = std::sync::Arc::new(DppService::start(&truth, &cfg, seed)?);
    println!(
        "starting service: N={} workers={} max_batch={} tenants={:?} \
         (max_resident_epochs={} epoch_history={} default_budget_ms={} fallback={})",
        n1 * n2,
        cfg.workers,
        cfg.max_batch,
        svc.registry().tenant_names(),
        cfg.max_resident_epochs,
        cfg.epoch_history,
        cfg.default_budget_ms,
        if cfg.fallback.enabled { "on" } else { "off" },
    );
    // --listen ADDR serves the wire protocol over TCP instead of driving
    // the synthetic local trace: the event loop runs until a client sends
    // the `shutdown` op (graceful drain) and the final report prints.
    if let Some(listen) = args.str_flag("listen") {
        let net_cfg = NetConfig::default();
        let server = NetServer::start(std::sync::Arc::clone(&svc), listen, net_cfg)?;
        println!(
            "listening on {} (length-prefixed JSON frames, DESIGN.md §3.2; \
             send op \"shutdown\" to drain)",
            server.local_addr()
        );
        server.join();
        println!("{}", svc.report());
        return Ok(());
    }

    // The trace targets one pinned tenant (--tenant) or round-robins all.
    let targets: Vec<krondpp::coordinator::TenantId> = match args.str_flag("tenant") {
        Some(name) => vec![svc.tenant(name)?],
        None => svc
            .registry()
            .tenant_names()
            .iter()
            .map(|n| svc.tenant(n))
            .collect::<Result<Vec<_>>>()?,
    };

    // Optional live learning job publishing kernel refreshes to the first
    // target tenant.
    let job = if args.switch("learn-live") {
        let data =
            krondpp::data::sample_training_set(&truth, 60, (n1 / 2).max(2), n1 + 2, &mut rng)?;
        let l1 = init::paper_subkernel(n1, &mut rng);
        let l2 = init::paper_subkernel(n2, &mut rng);
        let learner = krondpp::learn::KrkPicard::new(l1, l2, 1.0)?;
        println!(
            "live learning job started (KRK-Picard, epoch publish per iteration, target tenant id {:?})",
            targets[0]
        );
        Some(krondpp::coordinator::LearningJob::spawn_into(
            Box::new(learner),
            data,
            10,
            0.0,
            Some(std::sync::Arc::clone(&svc)),
            targets[0],
        )?)
    } else {
        None
    };

    // Optional catalog churn interleaved with the trace: one mutation per
    // --churn-every requests, pushed through the delta-publish path
    // against the first target tenant (assumed to have the --n1/--n2
    // shape; a mismatched config tenant just records failed publishes).
    let churn_spec = ChurnSpec {
        every: args.get_or("churn-every", 0)?,
        rank: args.get_or("churn-rank", 2)?,
        scale: 0.02,
    };
    let churn = churn_plan(&churn_spec, requests);
    let mut churn_it = churn.iter().peekable();
    let mut sizes = [n1, n2];
    let mut churn_ok = 0usize;
    let mut churn_failed = 0usize;

    // Drive the synthetic trace.
    let spec = krondpp::data::workload::WorkloadSpec {
        rate_hz: rate,
        count: requests,
        k_lo: 3,
        k_hi: n1.max(4),
    };
    let trace = krondpp::data::workload::generate(&spec, &mut rng);
    let t0 = std::time::Instant::now();
    let mut tickets = Vec::with_capacity(trace.len());
    for (i, req) in trace.iter().enumerate() {
        while churn_it.peek().is_some_and(|e| e.at_index == i) {
            let op = churn_it.next().map(|e| e.op).unwrap_or(ChurnOp::Perturb);
            match apply_churn(&svc, targets[0], op, &mut sizes, &churn_spec, &mut rng) {
                Ok(_) => churn_ok += 1,
                Err(_) => churn_failed += 1, // quarantined/rejected; in metrics
            }
        }
        let target = req.at;
        while t0.elapsed() < target {
            std::thread::yield_now();
        }
        let tenant = targets[i % targets.len()];
        match svc.submit(krondpp::coordinator::SampleRequest::for_tenant(tenant, req.k)) {
            Ok(t) => tickets.push(t),
            Err(_) => {} // rejected (backpressure/admission); in metrics
        }
    }
    let mut ok = 0usize;
    for t in tickets {
        if t.wait().is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("completed {ok}/{requests} in {wall:.2}s ({:.0} req/s)", ok as f64 / wall);
    if !churn.is_empty() {
        println!("churn: {}/{} mutations published ({churn_failed} failed)", churn_ok, churn.len());
    }
    println!("{}", svc.report());
    if let Some(job) = job {
        job.cancel();
        let history = job.join()?;
        println!(
            "learning job: ll {:.4} -> {:.4} over {} iterations",
            history.first().map(|r| r.log_likelihood).unwrap_or(f64::NAN),
            history.last().map(|r| r.log_likelihood).unwrap_or(f64::NAN),
            history.len() - 1
        );
    }
    Ok(())
}

/// `client` subcommand: talk to a `serve --listen` endpoint over the wire
/// protocol — single ops (`--op sample|map|marginals|report|shutdown`) or
/// a full open-loop replay sweep (`--replay`) with Zipf-skewed tenants,
/// a backend-mode mix, and constraint-carrying slates.
fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.require_str("addr")?;
    if args.switch("replay") {
        return client_replay(args, addr);
    }
    let op = args.str_flag("op").unwrap_or("sample");
    let mut client =
        WireClient::connect_timeout(addr, std::time::Duration::from_secs(30))?;
    match op {
        "sample" | "map" => {
            let tenant = args.str_flag("tenant").unwrap_or("default");
            let k: usize = args.get_or("k", 5)?;
            let count: usize = args.get_or("count", 1)?;
            let mode = if op == "map" {
                SampleMode::Map
            } else {
                SampleMode::parse(
                    args.str_flag("mode").unwrap_or("exact"),
                    args.get_opt::<usize>("steps")?,
                    args.get_opt::<usize>("rank")?,
                )?
            };
            let include = parse_items(args, "include")?;
            let exclude = parse_items(args, "exclude")?;
            let budget = args.get_opt::<u64>("budget-ms")?;
            for i in 0..count {
                match client.sample(
                    tenant,
                    k,
                    mode,
                    include.clone(),
                    exclude.clone(),
                    budget,
                ) {
                    Ok(y) => println!("sample {i}: {y:?}"),
                    Err(e) => println!("sample {i}: error ({}): {e}", e.kind().label()),
                }
            }
        }
        "marginals" => {
            let tenant = args.str_flag("tenant").unwrap_or("default");
            let probs = client.marginals(tenant)?;
            let expected: f64 = probs.iter().sum();
            println!("N = {}  E[|Y|] = {expected:.3}", probs.len());
            let top: usize = args.get_or("top", 10)?;
            let mut ranked: Vec<(usize, f64)> = probs.into_iter().enumerate().collect();
            ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
            for (i, p) in ranked.into_iter().take(top) {
                println!("item {i:>6}  P(i in Y) = {p:.6}");
            }
        }
        "report" => println!("{}", client.report()?),
        "shutdown" => {
            client.shutdown_server()?;
            println!("server draining");
        }
        other => return Err(krondpp::Error::Parse(format!("unknown client op '{other}'"))),
    }
    Ok(())
}

/// `client --replay`: the saturation-sweep driver. Sends an open-loop
/// Poisson trace (the offered rate never slows for backlog) and prints
/// client-observed outcome tallies + exact per-tenant p50/p99.
fn client_replay(args: &Args, addr: &str) -> Result<()> {
    let names: Vec<String> = match args.str_flag("tenants") {
        Some(list) => list.split(',').map(|s| s.trim().to_string()).collect(),
        None => vec!["default".to_string()],
    };
    let spec = ReplaySpec {
        tenants: names.len(),
        zipf_s: args.get_or("zipf", 1.1)?,
        rate_hz: args.get_or("rate", 500.0)?,
        count: args.get_or("requests", 2000)?,
        k_lo: args.get_or("k-lo", 2)?,
        k_hi: args.get_or("k-hi", 8)?,
        constraint_fraction: args.get_or("constraint-frac", 0.25)?,
        ground_size: args.get_or("ground", 24)?,
        ..ReplaySpec::default()
    };
    let conns: usize = args.get_or("conns", 4)?;
    let seed: u64 = args.get_or("seed", 2016)?;
    let budget = args.get_opt::<u64>("budget-ms")?;
    let trace = replay(&spec, &mut Rng::new(seed));
    println!(
        "replay: {} requests at {:.0}/s offered over {} conns, tenants {:?} (zipf s={})",
        spec.count, spec.rate_hz, conns, names, spec.zipf_s
    );
    let out = run_replay(addr, &names, &trace, conns, budget)?;
    println!(
        "sent={} completed={} throttled={} rejected={} deadline={} failed={} \
         wall={:.2}s sustained={:.0}/s shed_fraction={:.3}",
        out.sent,
        out.completed,
        out.throttled,
        out.rejected,
        out.deadline,
        out.failed,
        out.wall.as_secs_f64(),
        out.sustained_hz(),
        out.shed_fraction(),
    );
    for t in &out.per_tenant {
        println!(
            "  tenant {:<12} sent={:<6} completed={:<6} throttled={:<6} \
             p50={:.3}ms p99={:.3}ms",
            t.name, t.sent, t.completed, t.throttled, t.p50_ms, t.p99_ms
        );
    }
    Ok(())
}

/// Materialize one churn-plan event into a concrete `KernelDelta` against
/// the tenant's current factor shapes and push it through the service's
/// churn endpoints. `sizes` tracks both factor sizes across structural
/// ops so rows/indices stay in range.
fn apply_churn(
    svc: &DppService,
    tenant: TenantId,
    op: ChurnOp,
    sizes: &mut [usize; 2],
    spec: &ChurnSpec,
    rng: &mut Rng,
) -> Result<DeltaOutcome> {
    // Perturb/Retire hit the larger side (friendlier to the r ≤ N/4
    // incremental gate); Add grows the smaller side and Remove shrinks
    // the larger, so the shape stays balanced over a full plan cycle.
    let larger = if sizes[0] >= sizes[1] { 0 } else { 1 };
    let smaller = 1 - larger;
    match op {
        ChurnOp::Perturb => {
            let n = sizes[larger];
            let r = spec.rank.clamp(1, n);
            let rhos: Vec<f64> =
                (0..r).map(|j| if j % 2 == 0 { 1.0 } else { -0.5 }).collect();
            let vectors = rng.uniform_matrix(n, r, -spec.scale, spec.scale);
            svc.publish_delta(tenant, &KernelDelta::Perturb { side: larger, rhos, vectors })
        }
        ChurnOp::Add => {
            let n = sizes[smaller];
            let row: Vec<f64> =
                (0..n).map(|_| rng.uniform_range(-spec.scale, spec.scale)).collect();
            let out = svc.add_item(tenant, smaller, row, 1.0)?;
            sizes[smaller] += 1;
            Ok(out)
        }
        ChurnOp::Retire => {
            let n = sizes[larger];
            svc.retire_item(tenant, larger, rng.int_range(0, n - 1), 0.3)
        }
        ChurnOp::Remove => {
            let n = sizes[larger];
            let out = svc.remove_item(tenant, larger, rng.int_range(0, n - 1))?;
            sizes[larger] -= 1;
            Ok(out)
        }
    }
}

/// `churn` subcommand: hammer one tenant's catalog with add / retire /
/// remove / perturb mutations through the incremental delta-publish path
/// and show each publication's outcome (incremental secular refresh vs
/// forced exact re-eigendecomposition) plus the churn ledger.
fn cmd_churn(args: &Args) -> Result<()> {
    let n1: usize = args.get_or("n1", 40)?;
    let n2: usize = args.get_or("n2", 40)?;
    let ops: usize = args.get_or("ops", 20)?;
    let seed: u64 = args.get_or("seed", 2016)?;
    let spec = ChurnSpec {
        every: 1, // every "request" slot is a mutation here
        rank: args.get_or("rank", 2)?,
        scale: args.get_or("scale", 0.02)?,
    };
    let cfg = ServiceConfig::default();
    let mut registry =
        krondpp::coordinator::KernelRegistry::with_history(cfg.max_resident_epochs, cfg.epoch_history);
    if let Some(d) = args.get_opt::<u64>("max-depth")? {
        // Bound accumulated secular-refresh drift: force an exact
        // republish after d consecutive incremental deltas.
        registry.set_max_delta_depth(d);
    }
    let max_depth = registry.max_delta_depth();
    let registry = std::sync::Arc::new(registry);
    let mut rng = Rng::new(seed);
    let truth = krondpp::data::paper_truth_kernel(n1, n2, &mut rng);
    registry.add_tenant("default", &truth)?;
    let svc = DppService::start_with_registry(registry, &cfg, seed)?;
    let tenant = svc.tenant("default")?;
    println!(
        "churn: N = {}×{} = {}  ops={ops}  perturb rank={}  max delta depth={max_depth}",
        n1,
        n2,
        n1 * n2,
        spec.rank,
    );
    let plan = churn_plan(&spec, ops);
    let mut sizes = [n1, n2];
    for (i, event) in plan.iter().enumerate() {
        match apply_churn(&svc, tenant, event.op, &mut sizes, &spec, &mut rng) {
            Ok(out) => println!(
                "  op {i:>3} {:<7}  gen={:<4} {}  depth={}",
                format!("{:?}", event.op).to_lowercase(),
                out.generation,
                if out.incremental { "incremental" } else { "exact      " },
                out.depth,
            ),
            Err(e) => println!("  op {i:>3} {:<7}  rejected: {e}", format!("{:?}", event.op)),
        }
    }
    // The tenant keeps serving off the delta-built epochs.
    let y = svc.sample_tenant(tenant, 5.min(sizes[0] * sizes[1]))?;
    println!("post-churn sample: {y:?}");
    println!("{}", svc.report());
    Ok(())
}

fn cmd_datagen(args: &Args) -> Result<()> {
    let kind = args.str_flag("kind").unwrap_or("synthetic");
    let out = args.require_str("out")?;
    let seed: u64 = args.get_or("seed", 2016)?;
    let count: usize = args.get_or("count", 100)?;
    match kind {
        "synthetic" => {
            let n1: usize = args.get_or("n1", 50)?;
            let n2: usize = args.get_or("n2", 50)?;
            let p = krondpp::data::fig1_problem(n1, n2, count, seed)?;
            matio::write_dataset(Path::new(out), p.train.ground_size, &p.train.subsets)?;
            println!("wrote {} ({} subsets over N={})", out, count, n1 * n2);
        }
        "genes" => {
            let n: usize = args.get_or("n", 576)?;
            let p =
                krondpp::data::genes::genes_problem(n, 48, count, n / 50 + 2, n / 12 + 4, seed)?;
            matio::write_dataset(Path::new(out), n, &p.train.subsets)?;
            println!("wrote {out} ({count} subsets over N={n})");
        }
        "registry" => {
            let n: usize = args.get_or("n", 100)?;
            let cats = krondpp::data::registry::all_categories(n, count, count / 2, seed)?;
            for cat in &cats {
                let path = format!("{out}.{}.kds", cat.name);
                matio::write_dataset(Path::new(&path), n, &cat.train.subsets)?;
                println!("wrote {path}");
            }
        }
        other => return Err(krondpp::Error::Parse(format!("unknown kind '{other}'"))),
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("krondpp {}", env!("CARGO_PKG_VERSION"));
    println!("threads: {}", krondpp::linalg::matmul::available_threads());
    match krondpp::runtime::Engine::load_default() {
        Ok(engine) => {
            println!(
                "pjrt: {} ({} artifacts)",
                engine.platform(),
                engine.manifest().artifacts.len()
            );
            for a in &engine.manifest().artifacts {
                println!("  {} in={:?} out={:?}", a.name, a.inputs, a.outputs);
            }
        }
        Err(e) => println!("pjrt: unavailable ({e})"),
    }
    Ok(())
}
