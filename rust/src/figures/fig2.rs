//! Figure 2 + Table 2: the GENES experiment (§5.3).
//!
//! NLL vs iteration (2a) and vs wall-clock including the stochastic
//! variant (2b), plus Table 2's per-iteration runtime and first-iteration
//! NLL-increase rows. Data is the simulated GENES problem (DESIGN.md §5):
//! clustered hub-distance features → Gaussian RBF ground-truth kernel →
//! n training samples.
//!
//! Expected shape (paper, N1=N2=100): KRK ≈ 18× faster per iteration than
//! Picard; stochastic ≈ 134×; stochastic shows the largest 1st-iteration
//! NLL gain.

use super::{emit_csv, trace_rows, Scale, TRACE_HEADER};
use crate::data::genes;
use crate::error::Result;
use crate::learn::traits::TrainingSet;
use crate::learn::{init, KrkPicard, KrkStochastic, Learner, Picard};
use crate::rng::Rng;

/// Results needed by Table 2.
pub struct GenesRunStats {
    pub algo: &'static str,
    pub mean_iter_secs: f64,
    pub first_iter_gain: f64,
    pub final_ll: f64,
}

/// Run one GENES configuration; returns Table-2 stats per algorithm.
pub fn run_genes(
    n1: usize,
    n2: usize,
    n_train: usize,
    iters: usize,
    seed: u64,
    include_picard: bool,
) -> Result<(Vec<GenesRunStats>, Vec<Vec<f64>>)> {
    let n = n1 * n2;
    println!("  generating GENES-like problem at N={n} (one-time eigendecomposition)...");
    let problem = genes::genes_problem(n, 331.min(n / 4).max(8), n_train, 50.min(n / 8).max(4), 200.min(n / 4).max(8), seed)?;
    let data = &problem.train;
    println!("  data: {} samples, κ={}", data.len(), data.kappa());
    let mut rng = Rng::new(seed ^ 0x6E9E5);
    let l1 = init::paper_subkernel(n1, &mut rng);
    let l2 = init::paper_subkernel(n2, &mut rng);
    let mut stats = Vec::new();
    let mut rows = Vec::new();

    let mut krk = KrkPicard::new(l1.clone(), l2.clone(), 1.0)?;
    let r = krk.run(data, iters, 0.0)?;
    println!(
        "  krk-picard:     {:.2}s/iter, 1st-iter gain {:.4}, final ll {:.4}",
        r.mean_iter_secs(),
        r.first_iter_gain(),
        r.final_ll()
    );
    rows.extend(trace_rows(super::fig1::ALGO_KRK, 0, &r.history));
    stats.push(GenesRunStats {
        algo: "krk-picard",
        mean_iter_secs: r.mean_iter_secs(),
        first_iter_gain: r.first_iter_gain(),
        final_ll: r.final_ll(),
    });

    let mut stoch = KrkStochastic::new(l1.clone(), l2.clone(), 0.8, 1, seed ^ 0x57);
    let r = stoch.run(data, iters, 0.0)?;
    println!(
        "  krk-stochastic: {:.3}s/iter, 1st-iter gain {:.4}, final ll {:.4}",
        r.mean_iter_secs(),
        r.first_iter_gain(),
        r.final_ll()
    );
    rows.extend(trace_rows(super::fig1::ALGO_KRK_STOCH, 0, &r.history));
    stats.push(GenesRunStats {
        algo: "krk-stochastic",
        mean_iter_secs: r.mean_iter_secs(),
        first_iter_gain: r.first_iter_gain(),
        final_ll: r.final_ll(),
    });

    if include_picard {
        let dense = crate::linalg::kron::kron(&l1, &l2);
        let mut picard = Picard::new(dense, 1.0)?;
        let r = picard.run(data, iters, 0.0)?;
        println!(
            "  picard:         {:.2}s/iter, 1st-iter gain {:.4}, final ll {:.4}",
            r.mean_iter_secs(),
            r.first_iter_gain(),
            r.final_ll()
        );
        rows.extend(trace_rows(super::fig1::ALGO_PICARD, 0, &r.history));
        stats.push(GenesRunStats {
            algo: "picard",
            mean_iter_secs: r.mean_iter_secs(),
            first_iter_gain: r.first_iter_gain(),
            final_ll: r.final_ll(),
        });
    }
    Ok((stats, rows))
}

/// Figures 2a/2b (one run emits both series; the CSV carries both the
/// iteration index and the cumulative time).
pub fn fig2(scale: Scale, seed: u64) -> Result<()> {
    let (n1, n2, n_train, iters) = match scale {
        Scale::Small => (32, 32, 80, 6),
        Scale::Paper => (100, 100, 150, 8),
    };
    println!("=== Figure 2a/2b: GENES N1={n1} N2={n2}, n={n_train}, a=1 ===");
    let (_, rows) = run_genes(n1, n2, n_train, iters, seed, true)?;
    emit_csv("fig2.csv", &TRACE_HEADER, &rows)?;
    Ok(())
}

/// Table 2: average runtime + first-iteration NLL increase.
pub fn table2(scale: Scale, seed: u64) -> Result<()> {
    let (n1, n2, n_train, iters, repeats) = match scale {
        Scale::Small => (32, 32, 80, 3, 2),
        Scale::Paper => (100, 100, 150, 3, 5),
    };
    println!("=== Table 2: GENES N1={n1} N2={n2} (N={}) ===", n1 * n2);
    let mut agg: std::collections::BTreeMap<&'static str, (Vec<f64>, Vec<f64>)> =
        Default::default();
    for rep in 0..repeats {
        let (stats, _) = run_genes(n1, n2, n_train, iters, seed + 31 * rep as u64, true)?;
        for s in stats {
            let e = agg.entry(s.algo).or_default();
            e.0.push(s.mean_iter_secs);
            e.1.push(s.first_iter_gain);
        }
    }
    println!("\n  {:<16} {:>18} {:>22}", "algorithm", "avg runtime (s/iter)", "NLL increase (1st iter)");
    let mut rows = Vec::new();
    let mut picard_time = None;
    for (algo, (times, gains)) in &agg {
        let (tm, ts) = mean_std(times);
        let (gm, gs) = mean_std(gains);
        println!("  {algo:<16} {tm:>12.3} ± {ts:<6.3} {gm:>14.4} ± {gs:<8.4}");
        let id = match *algo {
            "picard" => super::fig1::ALGO_PICARD,
            "krk-picard" => super::fig1::ALGO_KRK,
            _ => super::fig1::ALGO_KRK_STOCH,
        };
        if *algo == "picard" {
            picard_time = Some(tm);
        }
        rows.push(vec![id, tm, ts, gm, gs]);
    }
    if let Some(pt) = picard_time {
        for (algo, (times, _)) in &agg {
            if *algo != "picard" {
                let (tm, _) = mean_std(times);
                println!("  speed-up of {algo} over picard: {:.1}x", pt / tm);
            }
        }
    }
    emit_csv(
        "table2.csv",
        &["algo", "mean_iter_s", "std_iter_s", "first_gain_mean", "first_gain_std"],
        &rows,
    )?;
    Ok(())
}

fn mean_std(xs: &[f64]) -> (f64, f64) {
    let n = xs.len().max(1) as f64;
    let m = xs.iter().sum::<f64>() / n;
    let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n;
    (m, v.sqrt())
}

/// Verify the §3.3 clustered-Θ path agrees with the dense path on a GENES
/// slice — used by the clustering bench and exposed for tests.
pub fn clustering_consistency(n1: usize, n2: usize, seed: u64) -> Result<f64> {
    let mut rng = Rng::new(seed);
    let truth = crate::data::synthetic::paper_truth_kernel(n1, n2, &mut rng);
    let data: TrainingSet =
        crate::data::synthetic::sample_training_set(&truth, 20, 3, (n1 * n2 / 4).max(4), &mut rng)?;
    let z = data.kappa() * 3;
    let clusters = crate::learn::clustering::greedy_partition(&data.subsets, z)?;
    let kernel = truth;
    let ct = crate::learn::clustering::ClusteredTheta::build(
        &kernel,
        &data.subsets,
        &clusters,
        n1,
        n2,
    )?;
    let (l1, l2) = match &kernel {
        crate::dpp::Kernel::Kron2(a, b) => (a.clone(), b.clone()),
        _ => unreachable!(),
    };
    let dense = crate::dpp::likelihood::theta_dense(&kernel, &data.subsets)?;
    let a1_fast = ct.block_trace(&l2)?;
    let a1_dense = crate::linalg::kron::block_trace(&dense, &l2, n1, n2)?;
    let _ = l1;
    Ok(a1_fast.rel_diff(&a1_dense))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn genes_run_tiny() {
        let (stats, rows) = run_genes(6, 6, 12, 2, 3, true).unwrap();
        assert_eq!(stats.len(), 3);
        assert!(!rows.is_empty());
        // KRK per-iteration should not be slower than Picard even at
        // this tiny scale (same O(N³)-free structure).
        let krk = stats.iter().find(|s| s.algo == "krk-picard").unwrap();
        assert!(krk.mean_iter_secs.is_finite());
    }

    #[test]
    fn clustering_consistency_small() {
        let diff = clustering_consistency(5, 5, 11).unwrap();
        assert!(diff < 1e-10, "clustered Θ diverges: {diff}");
    }
}
