//! Table 1: baby-registry final log-likelihoods (§5.2).
//!
//! Six categories at N = 100; EM vs Picard vs KRK-Picard, each run to its
//! δ threshold with the paper's exact initialization protocol:
//! K ~ Wishart(N, I)/N for EM, L = K(I−K)⁻¹ for Picard, and (L₁, L₂)
//! minimizing ‖L − L₁⊗L₂‖ for KRK-Picard. Step sizes a_PIC = 1.3,
//! a_KRK = 1.8; δ_PIC = δ_KRK = 1e-4, δ_EM = 1e-5.
//!
//! Expected shape: KRK-Picard's final log-likelihoods are comparable but
//! slightly worse than Picard/EM — at tractable N the full kernel's extra
//! capacity wins (the paper's own conclusion).

use super::{emit_csv, Scale};
use crate::data::registry;
use crate::dpp::likelihood::log_likelihood;
use crate::error::Result;
use crate::learn::{init, EmLearner, KrkPicard, Learner, Picard};
use crate::rng::Rng;

/// One category's results.
pub struct Table1Row {
    pub category: String,
    /// (train_ll, test_ll) per algorithm.
    pub em: (f64, f64),
    pub picard: (f64, f64),
    pub krk: (f64, f64),
}

/// Run Table 1. Returns the rows (also printed + CSV'd).
pub fn table1(scale: Scale, seed: u64) -> Result<Vec<Table1Row>> {
    let (n, n_train, n_test, max_iters) = match scale {
        Scale::Small => (36, 150, 75, 60),
        Scale::Paper => (100, 400, 200, 40),
    };
    println!("=== Table 1: registry categories, N={n}, {n_train} train / {n_test} test ===");
    let categories = registry::all_categories(n, n_train, n_test, seed)?;
    let n1 = (n as f64).sqrt() as usize;
    let n2 = n / n1;
    assert_eq!(n1 * n2, n, "table1 requires n1*n2 == n");
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    println!(
        "\n  {:<10} | {:>8} {:>8} {:>8} (train) | {:>8} {:>8} {:>8} (test)",
        "category", "EM", "Picard", "KrK", "EM", "Picard", "KrK"
    );
    for (ci, cat) in categories.iter().enumerate() {
        let mut rng = Rng::new(seed ^ (ci as u64 + 1) * 0x9E37);
        // §5.2 initialization chain.
        let k0 = init::wishart_marginal(n, &mut rng)?;
        let l0 = init::l_from_marginal(&k0)?;
        let (l1_0, l2_0) = init::subkernels_from_dense(&l0, n1, n2)?;

        let mut em = EmLearner::from_marginal(&k0)?;
        let em_result = em.run(&cat.train, max_iters, 1e-5)?;
        let em_train = em_result.final_ll();
        let em_test = log_likelihood(&em_result.kernel, &cat.test.subsets)?;

        let mut picard = Picard::new(l0.clone(), 1.3)?;
        let pic_result = picard.run(&cat.train, max_iters, 1e-4)?;
        let pic_train = pic_result.final_ll();
        let pic_test = log_likelihood(&pic_result.kernel, &cat.test.subsets)?;

        let mut krk = KrkPicard::new(l1_0, l2_0, 1.8)?;
        let krk_result = krk.run(&cat.train, max_iters, 1e-4)?;
        let krk_train = krk_result.final_ll();
        let krk_test = log_likelihood(&krk_result.kernel, &cat.test.subsets)?;

        println!(
            "  {:<10} | {:>8.2} {:>8.2} {:>8.2}        | {:>8.2} {:>8.2} {:>8.2}",
            cat.name, em_train, pic_train, krk_train, em_test, pic_test, krk_test
        );
        csv.push(vec![
            ci as f64, em_train, pic_train, krk_train, em_test, pic_test, krk_test,
        ]);
        rows.push(Table1Row {
            category: cat.name.clone(),
            em: (em_train, em_test),
            picard: (pic_train, pic_test),
            krk: (krk_train, krk_test),
        });
    }
    emit_csv(
        "table1.csv",
        &[
            "category",
            "em_train",
            "picard_train",
            "krk_train",
            "em_test",
            "picard_test",
            "krk_test",
        ],
        &csv,
    )?;
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_protocol_tiny() {
        // One miniature category through the full §5.2 protocol.
        let mut rng = Rng::new(3);
        let cat = registry::generate_category("bath", 16, 40, 20, &mut rng).unwrap();
        let k0 = init::wishart_marginal(16, &mut rng).unwrap();
        let l0 = init::l_from_marginal(&k0).unwrap();
        let (l1_0, l2_0) = init::subkernels_from_dense(&l0, 4, 4).unwrap();

        let mut picard = Picard::new(l0, 1.3).unwrap();
        let pr = picard.run(&cat.train, 10, 1e-4).unwrap();
        let mut krk = KrkPicard::new(l1_0, l2_0, 1.8).unwrap();
        let kr = krk.run(&cat.train, 10, 1e-4).unwrap();
        let mut em = EmLearner::from_marginal(&k0).unwrap();
        let er = em.run(&cat.train, 6, 1e-5).unwrap();

        // All three should land in a sane likelihood range and improve.
        for r in [&pr, &kr, &er] {
            assert!(r.final_ll() >= r.history[0].log_likelihood - 1e-6);
            assert!(r.final_ll().is_finite());
        }
        // All three estimators must generalize: test likelihood within a
        // few nats of train likelihood (the Table-1 ordering itself is a
        // convergence-scale property checked by the full harness, not at
        // this 10-iteration miniature).
        for (r, name) in [(&pr, "picard"), (&kr, "krk"), (&er, "em")] {
            let test_ll = log_likelihood(&r.kernel, &cat.test.subsets).unwrap();
            assert!(
                (test_ll - r.final_ll()).abs() < 5.0,
                "{name}: test {test_ll} far from train {}",
                r.final_ll()
            );
        }
    }
}
