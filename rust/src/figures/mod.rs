//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§5). Each function prints the series/rows the paper reports
//! and writes a CSV under `results/`. The experiment → module → bench map
//! lives in DESIGN.md §4; measured-vs-paper numbers in EXPERIMENTS.md.
//!
//! Every experiment takes a [`Scale`]: `Small` keeps full `make test`-style
//! runs in minutes on a laptop-class container, `Paper` reproduces the
//! paper's dimensions (N = 10⁴ GENES runs take tens of minutes on this
//! substrate — the Picard baseline's O(N³) is the paper's villain, and it
//! is just as slow here).

pub mod fig1;
pub mod fig2;
pub mod tables;

use crate::error::Result;
use std::path::{Path, PathBuf};

/// Experiment scale.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Reduced dimensions; same shapes/ratios, minutes of runtime.
    Small,
    /// The paper's dimensions.
    Paper,
}

impl Scale {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "small" => Ok(Scale::Small),
            "paper" => Ok(Scale::Paper),
            other => Err(crate::Error::Parse(format!("unknown scale '{other}'"))),
        }
    }
}

/// Where result CSVs land.
pub fn results_dir() -> PathBuf {
    let dir = std::env::var("KRONDPP_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("results"));
    let _ = std::fs::create_dir_all(&dir);
    dir
}

/// Write a CSV into the results directory and announce it.
pub fn emit_csv(name: &str, header: &[&str], rows: &[Vec<f64>]) -> Result<PathBuf> {
    let path = results_dir().join(name);
    crate::ser::matio::write_csv(Path::new(&path), header, rows)?;
    println!("  wrote {}", path.display());
    Ok(path)
}

/// A learning-trace row: (algo-id, repeat, iter, seconds, log-likelihood).
pub fn trace_rows(
    algo_id: f64,
    repeat: usize,
    history: &[crate::learn::IterRecord],
) -> Vec<Vec<f64>> {
    history
        .iter()
        .map(|r| {
            vec![
                algo_id,
                repeat as f64,
                r.iter as f64,
                r.elapsed.as_secs_f64(),
                r.log_likelihood,
            ]
        })
        .collect()
}

pub const TRACE_HEADER: [&str; 5] = ["algo", "repeat", "iter", "time_s", "log_likelihood"];
