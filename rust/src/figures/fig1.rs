//! Figure 1: synthetic-data learning curves (§5.1).
//!
//! - **1a/1b**: NLL vs wall-clock for Picard, KRK-Picard and Joint-Picard
//!   on data drawn from a true Kron kernel (a = 1), at two ground-set
//!   sizes. Expected shape: KRK converges fastest per second; Joint-Picard
//!   ascends but slowly and with visibly higher variance across repeats.
//! - **1c**: stochastic KRK on a kernel too large for batch methods'
//!   memory/time budget; the likelihood jumps within the first couple of
//!   iterations.

use super::{emit_csv, trace_rows, Scale, TRACE_HEADER};
use crate::data::synthetic;
use crate::dpp::likelihood::log_likelihood;
use crate::error::Result;
use crate::learn::{init, JointPicard, KrkPicard, KrkStochastic, Learner, Picard};
use crate::linalg::kron;
use crate::rng::Rng;

/// Algo ids used in the CSVs.
pub const ALGO_PICARD: f64 = 0.0;
pub const ALGO_KRK: f64 = 1.0;
pub const ALGO_JOINT: f64 = 2.0;
pub const ALGO_KRK_STOCH: f64 = 3.0;

/// Shared driver for 1a/1b: one sub-kernel size, several repeats.
pub fn run_fig1(
    label: &str,
    n1: usize,
    n2: usize,
    n_subsets: usize,
    iters: usize,
    repeats: usize,
    seed: u64,
) -> Result<()> {
    println!("=== Figure {label}: N1={n1} N2={n2} (N={}) a=1, {repeats} repeats ===", n1 * n2);
    let mut rows = Vec::new();
    for rep in 0..repeats {
        let problem = synthetic::fig1_problem(n1, n2, n_subsets, seed + rep as u64)?;
        let data = &problem.train;
        let mut rng = Rng::new(seed ^ 0x5eed ^ rep as u64);
        // Shared initialization (§5.1): L_i = XᵀX; Picard starts from
        // L1⊗L2.
        let l1 = init::paper_subkernel(n1, &mut rng);
        let l2 = init::paper_subkernel(n2, &mut rng);

        let mut krk = KrkPicard::new(l1.clone(), l2.clone(), 1.0)?;
        let r = krk.run(data, iters, 0.0)?;
        println!(
            "  [rep {rep}] krk-picard:   {:.4} -> {:.4}  ({:.2}s/iter)",
            r.history[0].log_likelihood,
            r.final_ll(),
            r.mean_iter_secs()
        );
        rows.extend(trace_rows(ALGO_KRK, rep, &r.history));

        let mut joint = JointPicard::new(l1.clone(), l2.clone(), 1.0)?;
        let r = joint.run(data, iters, 0.0)?;
        println!(
            "  [rep {rep}] joint-picard: {:.4} -> {:.4}  ({:.2}s/iter)",
            r.history[0].log_likelihood,
            r.final_ll(),
            r.mean_iter_secs()
        );
        rows.extend(trace_rows(ALGO_JOINT, rep, &r.history));

        let mut picard = Picard::new(kron::kron(&l1, &l2), 1.0)?;
        let r = picard.run(data, iters, 0.0)?;
        println!(
            "  [rep {rep}] picard:       {:.4} -> {:.4}  ({:.2}s/iter)",
            r.history[0].log_likelihood,
            r.final_ll(),
            r.mean_iter_secs()
        );
        rows.extend(trace_rows(ALGO_PICARD, rep, &r.history));
    }
    emit_csv(&format!("fig{label}.csv"), &TRACE_HEADER, &rows)?;
    Ok(())
}

/// Figure 1a (smaller N).
pub fn fig1a(scale: Scale, seed: u64) -> Result<()> {
    match scale {
        Scale::Small => run_fig1("1a", 24, 24, 60, 6, 2, seed),
        Scale::Paper => run_fig1("1a", 50, 50, 100, 12, 5, seed),
    }
}

/// Figure 1b (larger N).
pub fn fig1b(scale: Scale, seed: u64) -> Result<()> {
    match scale {
        Scale::Small => run_fig1("1b", 36, 36, 60, 5, 2, seed),
        Scale::Paper => run_fig1("1b", 70, 70, 100, 10, 5, seed),
    }
}

/// Figure 1c: stochastic learning where batch methods don't fit.
/// The ground truth is a Kron kernel over a large ground set; only
/// KRK-Picard with stochastic updates is run (the paper notes the other
/// methods exceed memory — here the batch Θ alone would be N² ≈ 4 GB at
/// the paper scale).
pub fn fig1c(scale: Scale, seed: u64) -> Result<()> {
    let (n1, n2, n_subsets, iters) = match scale {
        Scale::Small => (60, 60, 60, 8),
        Scale::Paper => (150, 150, 100, 10),
    };
    println!("=== Figure 1c: stochastic KRK at N={} ===", n1 * n2);
    let mut rng = Rng::new(seed);
    let truth = synthetic::paper_truth_kernel(n1, n2, &mut rng);
    // Subset sizes ~ rank/|Y| ≈ a healthy fraction of sqrt(N), mirroring
    // the paper's |Y| ≈ rank setup scaled to our substrate (DESIGN.md §5).
    let lo = (n1 / 2).max(4);
    let hi = n1 + n1 / 2;
    let data = synthetic::sample_training_set(&truth, n_subsets, lo, hi, &mut rng)?;
    println!("  data: {} subsets, κ={}", data.len(), data.kappa());
    let l1 = init::paper_subkernel(n1, &mut rng);
    let l2 = init::paper_subkernel(n2, &mut rng);
    let mut learner = KrkStochastic::new(l1, l2, 0.7, 4, seed ^ 0xF16C);
    // Track NLL on a fixed evaluation subsample (full data) per iteration.
    let mut rows = Vec::new();
    let ll0 = log_likelihood(&learner.kernel(), &data.subsets)?;
    println!("  iter 0: ll {ll0:.4}");
    rows.push(vec![ALGO_KRK_STOCH, 0.0, 0.0, 0.0, ll0]);
    let mut elapsed = 0.0;
    for it in 1..=iters {
        let t = std::time::Instant::now();
        learner.step(&data)?;
        elapsed += t.elapsed().as_secs_f64();
        let ll = log_likelihood(&learner.kernel(), &data.subsets)?;
        println!("  iter {it}: ll {ll:.4}  ({elapsed:.2}s cumulative)");
        rows.push(vec![ALGO_KRK_STOCH, 0.0, it as f64, elapsed, ll]);
    }
    emit_csv("fig1c.csv", &TRACE_HEADER, &rows)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1a_tiny_smoke() {
        // A miniature end-to-end pass of the 1a harness (own sizes, not
        // Scale::Small, to keep unit tests fast).
        run_fig1("1a-test", 6, 6, 15, 2, 1, 99).unwrap();
        let path = super::super::results_dir().join("fig1a-test.csv");
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.starts_with("algo,repeat,iter,time_s,log_likelihood"));
        // 3 algos × (2 iters + initial) = 9 rows.
        assert_eq!(text.lines().count(), 1 + 9);
    }

    #[test]
    fn fig1c_tiny_smoke() {
        let (n1, n2) = (8, 8);
        let mut rng = Rng::new(5);
        let truth = synthetic::paper_truth_kernel(n1, n2, &mut rng);
        let data = synthetic::sample_training_set(&truth, 10, 3, 8, &mut rng).unwrap();
        let l1 = init::paper_subkernel(n1, &mut rng);
        let l2 = init::paper_subkernel(n2, &mut rng);
        let mut learner = KrkStochastic::new(l1, l2, 0.6, 2, 7);
        let ll0 = log_likelihood(&learner.kernel(), &data.subsets).unwrap();
        for _ in 0..6 {
            learner.step(&data).unwrap();
        }
        let ll1 = log_likelihood(&learner.kernel(), &data.subsets).unwrap();
        assert!(ll1 > ll0);
    }
}
