//! Configuration system.
//!
//! Experiments and the serving coordinator are configured by JSON files
//! (parsed with [`crate::ser::json`]) with programmatic defaults, so every
//! example/binary can run with zero flags, and every paper experiment is a
//! small checked-in config. CLI flags override file values.

use crate::dpp::backend::SampleMode;
use crate::error::Result;
use crate::ser::Json;
use std::path::Path;

/// Kernel structure choice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Full unstructured N×N kernel.
    Full,
    /// Kronecker of two sub-kernels (the paper's main case, m=2).
    Kron2,
    /// Kronecker of three sub-kernels (m=3).
    Kron3,
}

impl KernelKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "full" => Ok(KernelKind::Full),
            "kron2" => Ok(KernelKind::Kron2),
            "kron3" => Ok(KernelKind::Kron3),
            other => Err(crate::Error::Parse(format!("unknown kernel kind '{other}'"))),
        }
    }
}

/// Learning algorithm choice (the paper's three + EM baseline).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// Full Picard iteration [25].
    Picard,
    /// KRK-Picard (Alg. 1), batch updates.
    Krk,
    /// KRK-Picard with stochastic (minibatch) updates.
    KrkStochastic,
    /// Joint-Picard (Alg. 3).
    JointPicard,
    /// EM of Gillenwater et al. [10].
    Em,
}

impl Algorithm {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "picard" => Ok(Algorithm::Picard),
            "krk" => Ok(Algorithm::Krk),
            "krk-stochastic" | "krk_stochastic" => Ok(Algorithm::KrkStochastic),
            "joint" | "joint-picard" => Ok(Algorithm::JointPicard),
            "em" => Ok(Algorithm::Em),
            other => Err(crate::Error::Parse(format!("unknown algorithm '{other}'"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Picard => "picard",
            Algorithm::Krk => "krk",
            Algorithm::KrkStochastic => "krk-stochastic",
            Algorithm::JointPicard => "joint-picard",
            Algorithm::Em => "em",
        }
    }
}

/// Configuration for a learning run.
#[derive(Clone, Debug)]
pub struct LearnConfig {
    /// Sub-kernel sizes; `n = n1 * n2 (* n3)`.
    pub n1: usize,
    pub n2: usize,
    /// Step size `a` (§3.1.1 generalization; 1.0 = guaranteed ascent).
    pub step_size: f64,
    /// Max iterations.
    pub max_iters: usize,
    /// Convergence threshold δ on objective change (0 disables).
    pub tol: f64,
    /// Minibatch size for stochastic updates (1 = pure stochastic).
    pub minibatch: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for LearnConfig {
    fn default() -> Self {
        LearnConfig {
            n1: 50,
            n2: 50,
            step_size: 1.0,
            max_iters: 20,
            tol: 1e-4,
            minibatch: 1,
            seed: 2016,
        }
    }
}

impl LearnConfig {
    /// Ground-set size.
    pub fn n(&self) -> usize {
        self.n1 * self.n2
    }

    /// Parse from a JSON object, starting from defaults.
    pub fn from_json(v: &Json) -> Result<Self> {
        let mut c = LearnConfig::default();
        if let Some(x) = v.get_opt("n1") {
            c.n1 = x.as_usize()?;
        }
        if let Some(x) = v.get_opt("n2") {
            c.n2 = x.as_usize()?;
        }
        if let Some(x) = v.get_opt("step_size") {
            c.step_size = x.as_f64()?;
        }
        if let Some(x) = v.get_opt("max_iters") {
            c.max_iters = x.as_usize()?;
        }
        if let Some(x) = v.get_opt("tol") {
            c.tol = x.as_f64()?;
        }
        if let Some(x) = v.get_opt("minibatch") {
            c.minibatch = x.as_usize()?;
        }
        if let Some(x) = v.get_opt("seed") {
            c.seed = x.as_f64()? as u64;
        }
        Ok(c)
    }

    /// Load from a JSON file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }
}

/// Per-tenant admission-control policy: token-bucket rate limiting, an
/// outstanding-request cap, and an end-to-end latency SLO. Applied at the
/// [`crate::coordinator::DppService::submit`] fast path *before* a queue
/// slot is taken — violations reject with the retryable
/// [`crate::error::Error::Throttled`]. Live-tunable per tenant via
/// [`crate::coordinator::DppService::set_admission`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdmissionPolicy {
    /// Sustained admitted-request rate in requests/s (0 = unlimited).
    pub rate_hz: f64,
    /// Token-bucket depth — the burst admitted after an idle period.
    /// 0 means "auto": `max(rate_hz, 1)`.
    pub burst: f64,
    /// Max accepted-but-unfinished requests in flight for the tenant
    /// (0 = unlimited).
    pub max_outstanding: usize,
    /// End-to-end latency SLO in milliseconds (0 = none). Purely an
    /// instrument: breaches count in `slo_violations`, nothing is shed.
    pub slo_ms: u64,
}

impl Default for AdmissionPolicy {
    /// Unlimited: admission control disabled, no SLO.
    fn default() -> Self {
        AdmissionPolicy { rate_hz: 0.0, burst: 0.0, max_outstanding: 0, slo_ms: 0 }
    }
}

impl AdmissionPolicy {
    /// Effective bucket depth (resolves the `burst = 0` auto rule).
    pub fn effective_burst(&self) -> f64 {
        if self.burst > 0.0 {
            self.burst
        } else {
            self.rate_hz.max(1.0)
        }
    }

    /// Parse from a JSON object, starting from defaults (all unlimited).
    pub fn from_json(v: &Json) -> Result<Self> {
        let mut p = AdmissionPolicy::default();
        if let Some(x) = v.get_opt("rate_hz") {
            p.rate_hz = x.as_f64()?;
            if !p.rate_hz.is_finite() || p.rate_hz < 0.0 {
                return Err(crate::Error::Parse(
                    "admission rate_hz must be finite and ≥ 0".into(),
                ));
            }
        }
        if let Some(x) = v.get_opt("burst") {
            p.burst = x.as_f64()?;
            if !p.burst.is_finite() || p.burst < 0.0 {
                return Err(crate::Error::Parse(
                    "admission burst must be finite and ≥ 0".into(),
                ));
            }
        }
        if let Some(x) = v.get_opt("max_outstanding") {
            p.max_outstanding = x.as_usize()?;
        }
        if let Some(x) = v.get_opt("slo_ms") {
            p.slo_ms = x.as_f64()? as u64;
        }
        Ok(p)
    }
}

/// Declaration of one serving tenant (a named catalog/model): the
/// coordinator provisions a synthetic `n1×n2` KronDPP for it at startup
/// (production deployments publish learned kernels over it via
/// [`crate::coordinator::KernelRegistry::publish`]).
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSpec {
    /// Registry name (`--tenant` on the CLI).
    pub name: String,
    /// Sub-kernel sizes; ground set `n = n1 * n2`.
    pub n1: usize,
    pub n2: usize,
    /// Seed for the tenant's synthetic kernel.
    pub seed: u64,
    /// Admission-control override for this tenant; `None` inherits the
    /// service-wide [`ServiceConfig::admission`] default.
    pub admission: Option<AdmissionPolicy>,
}

impl TenantSpec {
    pub fn from_json(v: &Json) -> Result<Self> {
        let name = v.get("name")?.as_str()?.to_string();
        if name.is_empty() {
            return Err(crate::Error::Parse("tenant name must be non-empty".into()));
        }
        let n1 = v.get("n1")?.as_usize()?;
        let n2 = v.get("n2")?.as_usize()?;
        if n1 == 0 || n2 == 0 {
            return Err(crate::Error::Parse(format!(
                "tenant '{name}': n1/n2 must be positive"
            )));
        }
        let seed = match v.get_opt("seed") {
            Some(x) => x.as_f64()? as u64,
            None => 2016,
        };
        let admission = match v.get_opt("admission") {
            Some(x) => Some(AdmissionPolicy::from_json(x)?),
            None => None,
        };
        Ok(TenantSpec { name, n1, n2, seed, admission })
    }
}

/// Degraded-mode policy: the per-tenant circuit breaker plus the chain of
/// fallback rungs a tripped (or probing-and-failing) tenant is served
/// through. Rungs are tried in order per coalesced group:
///
/// 1. each `regularize_eps` value — rebuild the epoch's kernel as
///    `L + εI` (ε jittered per attempt) and retry the exact path;
/// 2. each `degrade` mode — downgrade to an approximate backend
///    (low-rank projection or MCMC) over the *existing* epoch;
/// 3. exhausted → the group fails with a `Service` error.
#[derive(Clone, Debug, PartialEq)]
pub struct FallbackPolicy {
    /// Master switch: `false` restores fail-fast behavior (failures are
    /// still counted by the breaker, but nothing is served degraded).
    pub enabled: bool,
    /// Consecutive `Numerical` primary-path failures that trip a tenant's
    /// breaker (0 disables tripping).
    pub breaker_threshold: u32,
    /// While tripped, every `probe_every`-th serve event retries the
    /// primary path (half-open probe; 0 disables probing — the breaker
    /// then only closes by operator action).
    pub probe_every: u32,
    /// Regularization rungs: ε values for the `L + εI` retry, tried in
    /// order (each jittered ±25% per attempt to avoid resonant failures).
    pub regularize_eps: Vec<f64>,
    /// Backend-downgrade rungs, tried after regularization. Only
    /// approximate families are meaningful here (`lowrank:R`, `mcmc:S`).
    pub degrade: Vec<SampleMode>,
}

impl Default for FallbackPolicy {
    fn default() -> Self {
        FallbackPolicy {
            enabled: true,
            breaker_threshold: 3,
            probe_every: 4,
            regularize_eps: vec![1e-6, 1e-3],
            degrade: vec![
                SampleMode::LowRank { rank: 32 },
                SampleMode::Mcmc { steps: 2000 },
            ],
        }
    }
}

impl FallbackPolicy {
    /// Parse one degrade rung spec: `"lowrank:32"` or `"mcmc:2000"`.
    fn parse_rung(s: &str) -> Result<SampleMode> {
        let (name, param) = match s.split_once(':') {
            Some((n, p)) => {
                let v: usize = p.trim().parse().map_err(|_| {
                    crate::Error::Parse(format!("fallback rung '{s}': bad parameter"))
                })?;
                (n.trim(), Some(v))
            }
            None => (s.trim(), None),
        };
        let mode = match name {
            "mcmc" => SampleMode::parse(name, param, None)?,
            "lowrank" | "low-rank" => SampleMode::parse(name, None, param)?,
            other => {
                return Err(crate::Error::Parse(format!(
                    "fallback rung '{other}': only approximate families \
                     (mcmc, lowrank) can serve as degrade rungs"
                )))
            }
        };
        // SampleMode::parse defers parameter validation to backend
        // construction; a config must fail at parse time instead.
        match mode {
            SampleMode::Mcmc { steps: 0 } => {
                Err(crate::Error::Parse(format!("fallback rung '{s}': steps must be ≥ 1")))
            }
            SampleMode::LowRank { rank: 0 } => {
                Err(crate::Error::Parse(format!("fallback rung '{s}': rank must be ≥ 1")))
            }
            m => Ok(m),
        }
    }

    /// Parse from a JSON object, starting from defaults.
    pub fn from_json(v: &Json) -> Result<Self> {
        let mut p = FallbackPolicy::default();
        if let Some(x) = v.get_opt("enabled") {
            p.enabled = x.as_bool()?;
        }
        if let Some(x) = v.get_opt("breaker_threshold") {
            p.breaker_threshold = x.as_f64()? as u32;
        }
        if let Some(x) = v.get_opt("probe_every") {
            p.probe_every = x.as_f64()? as u32;
        }
        if let Some(x) = v.get_opt("regularize_eps") {
            p.regularize_eps =
                x.as_arr()?.iter().map(Json::as_f64).collect::<Result<Vec<_>>>()?;
            if p.regularize_eps.iter().any(|&e| !(e > 0.0) || !e.is_finite()) {
                return Err(crate::Error::Parse(
                    "regularize_eps values must be finite and positive".into(),
                ));
            }
        }
        if let Some(x) = v.get_opt("degrade") {
            p.degrade = x
                .as_arr()?
                .iter()
                .map(|r| Self::parse_rung(r.as_str()?))
                .collect::<Result<Vec<_>>>()?;
        }
        Ok(p)
    }
}

/// Configuration for the serving coordinator.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Worker threads sampling from the kernel.
    pub workers: usize,
    /// Max requests per dynamic batch.
    pub max_batch: usize,
    /// Max time a request waits for batch-mates before dispatch (µs).
    pub batch_window_us: u64,
    /// Bounded queue capacity (backpressure limit).
    pub queue_capacity: usize,
    /// LRU bound on resident per-tenant eigendecompositions (0 =
    /// unbounded): cold tenants drop their cached epoch and lazily
    /// rebuild on the next request.
    pub max_resident_epochs: usize,
    /// Per-tenant rollback history bound — outgoing generations kept for
    /// [`crate::coordinator::KernelRegistry::rollback`] (0 disables).
    pub epoch_history: usize,
    /// Default per-request budget in milliseconds, applied at admission
    /// to requests that carry no explicit deadline (0 = no default —
    /// such requests never expire).
    pub default_budget_ms: u64,
    /// Circuit-breaker + degraded-mode fallback chain policy.
    pub fallback: FallbackPolicy,
    /// Service-wide default admission policy, applied to every tenant
    /// without a [`TenantSpec::admission`] override (including the
    /// programmatic "default" tenant). Defaults to unlimited.
    pub admission: AdmissionPolicy,
    /// Queue depth at which admission starts shedding with the retryable
    /// [`crate::error::Error::Throttled`] instead of letting the queue
    /// fill to `queue_capacity` (where backpressure rejects with a
    /// non-retryable-looking `Service` error). 0 disables shedding.
    /// Meaningful values sit below `queue_capacity`.
    pub shed_queue_depth: usize,
    /// Tenants to provision at startup. Empty means the caller supplies
    /// the (single, "default") tenant kernel programmatically.
    pub tenants: Vec<TenantSpec>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: crate::linalg::matmul::available_threads(),
            max_batch: 32,
            batch_window_us: 500,
            queue_capacity: 1024,
            max_resident_epochs: 0,
            epoch_history: crate::coordinator::registry::DEFAULT_EPOCH_HISTORY,
            default_budget_ms: 0,
            fallback: FallbackPolicy::default(),
            admission: AdmissionPolicy::default(),
            shed_queue_depth: 0,
            tenants: Vec::new(),
        }
    }
}

impl ServiceConfig {
    pub fn from_json(v: &Json) -> Result<Self> {
        let mut c = ServiceConfig::default();
        if let Some(x) = v.get_opt("workers") {
            c.workers = x.as_usize()?.max(1);
        }
        if let Some(x) = v.get_opt("max_batch") {
            c.max_batch = x.as_usize()?.max(1);
        }
        if let Some(x) = v.get_opt("batch_window_us") {
            c.batch_window_us = x.as_f64()? as u64;
        }
        if let Some(x) = v.get_opt("queue_capacity") {
            c.queue_capacity = x.as_usize()?.max(1);
        }
        if let Some(x) = v.get_opt("max_resident_epochs") {
            c.max_resident_epochs = x.as_usize()?;
        }
        if let Some(x) = v.get_opt("epoch_history") {
            c.epoch_history = x.as_usize()?;
        }
        if let Some(x) = v.get_opt("default_budget_ms") {
            c.default_budget_ms = x.as_f64()? as u64;
        }
        if let Some(x) = v.get_opt("fallback") {
            c.fallback = FallbackPolicy::from_json(x)?;
        }
        if let Some(x) = v.get_opt("admission") {
            c.admission = AdmissionPolicy::from_json(x)?;
        }
        if let Some(x) = v.get_opt("shed_queue_depth") {
            c.shed_queue_depth = x.as_usize()?;
        }
        if let Some(x) = v.get_opt("tenants") {
            c.tenants = x
                .as_arr()?
                .iter()
                .map(TenantSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            if c.tenants.iter().any(|t| t.name == "default") {
                // The coordinator registers the initial kernel under this
                // name; catch the collision at parse time, not startup.
                return Err(crate::Error::Parse(
                    "tenant name 'default' is reserved for the initial kernel".into(),
                ));
            }
            let mut names: Vec<&str> =
                c.tenants.iter().map(|t| t.name.as_str()).collect();
            names.sort_unstable();
            if names.windows(2).any(|w| w[0] == w[1]) {
                return Err(crate::Error::Parse("duplicate tenant names".into()));
            }
        }
        Ok(c)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = LearnConfig::default();
        assert_eq!(c.n(), 2500);
        assert!(c.step_size > 0.0);
        let s = ServiceConfig::default();
        assert!(s.workers >= 1);
    }

    #[test]
    fn from_json_overrides() {
        let j = Json::parse(r#"{"n1": 10, "n2": 20, "step_size": 1.8, "max_iters": 7}"#).unwrap();
        let c = LearnConfig::from_json(&j).unwrap();
        assert_eq!(c.n1, 10);
        assert_eq!(c.n2, 20);
        assert_eq!(c.n(), 200);
        assert_eq!(c.step_size, 1.8);
        assert_eq!(c.max_iters, 7);
        // untouched default
        assert_eq!(c.minibatch, 1);
    }

    #[test]
    fn service_from_json() {
        let j = Json::parse(r#"{"workers": 2, "max_batch": 8}"#).unwrap();
        let s = ServiceConfig::from_json(&j).unwrap();
        assert_eq!(s.workers, 2);
        assert_eq!(s.max_batch, 8);
        // Untouched multi-tenant defaults: unbounded, no declarations.
        assert_eq!(s.max_resident_epochs, 0);
        assert!(s.tenants.is_empty());
    }

    #[test]
    fn service_tenants_parse() {
        let j = Json::parse(
            r#"{"max_resident_epochs": 2, "tenants": [
                {"name": "market-eu", "n1": 8, "n2": 8, "seed": 1},
                {"name": "market-us", "n1": 10, "n2": 6}
            ]}"#,
        )
        .unwrap();
        let s = ServiceConfig::from_json(&j).unwrap();
        assert_eq!(s.max_resident_epochs, 2);
        assert_eq!(s.tenants.len(), 2);
        assert_eq!(
            s.tenants[0],
            TenantSpec { name: "market-eu".into(), n1: 8, n2: 8, seed: 1, admission: None }
        );
        assert_eq!(s.tenants[1].seed, 2016, "seed defaults");
    }

    #[test]
    fn admission_policy_defaults_and_parse() {
        let d = AdmissionPolicy::default();
        assert_eq!(d.rate_hz, 0.0);
        assert_eq!(d.max_outstanding, 0);
        assert_eq!(d.slo_ms, 0);
        // Auto burst: max(rate, 1).
        assert_eq!(d.effective_burst(), 1.0);
        assert_eq!(
            AdmissionPolicy { rate_hz: 50.0, ..Default::default() }.effective_burst(),
            50.0
        );
        assert_eq!(
            AdmissionPolicy { rate_hz: 50.0, burst: 8.0, ..Default::default() }
                .effective_burst(),
            8.0
        );

        let j = Json::parse(
            r#"{"admission": {"rate_hz": 200, "burst": 16, "max_outstanding": 64,
                              "slo_ms": 250},
                "shed_queue_depth": 512,
                "tenants": [
                  {"name": "hog", "n1": 4, "n2": 4,
                   "admission": {"rate_hz": 10, "slo_ms": 50}},
                  {"name": "quiet", "n1": 4, "n2": 4}
                ]}"#,
        )
        .unwrap();
        let s = ServiceConfig::from_json(&j).unwrap();
        assert_eq!(s.admission.rate_hz, 200.0);
        assert_eq!(s.admission.burst, 16.0);
        assert_eq!(s.admission.max_outstanding, 64);
        assert_eq!(s.admission.slo_ms, 250);
        assert_eq!(s.shed_queue_depth, 512);
        let hog = s.tenants[0].admission.expect("override parsed");
        assert_eq!(hog.rate_hz, 10.0);
        assert_eq!(hog.slo_ms, 50);
        assert_eq!(hog.burst, 0.0, "unspecified burst stays auto");
        assert!(s.tenants[1].admission.is_none(), "no override inherits default");
        // Defaults untouched by other configs.
        let plain = ServiceConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(plain.admission, AdmissionPolicy::default());
        assert_eq!(plain.shed_queue_depth, 0);
    }

    #[test]
    fn admission_policy_rejects_bad_values() {
        // (Non-finite literals like 1e999 are already rejected by the JSON
        // parser itself; the policy check guards programmatic construction.)
        for bad in [r#"{"rate_hz": -1}"#, r#"{"burst": -0.5}"#] {
            let j = Json::parse(bad).unwrap();
            assert!(AdmissionPolicy::from_json(&j).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn fallback_policy_defaults_and_parse() {
        let d = FallbackPolicy::default();
        assert!(d.enabled);
        assert_eq!(d.breaker_threshold, 3);
        assert_eq!(d.regularize_eps, vec![1e-6, 1e-3]);
        assert_eq!(d.degrade.len(), 2);

        let j = Json::parse(
            r#"{"fallback": {"enabled": true, "breaker_threshold": 2,
                 "probe_every": 5, "regularize_eps": [1e-4],
                 "degrade": ["mcmc:500", "lowrank:16"]},
                "default_budget_ms": 250, "epoch_history": 8}"#,
        )
        .unwrap();
        let s = ServiceConfig::from_json(&j).unwrap();
        assert_eq!(s.fallback.breaker_threshold, 2);
        assert_eq!(s.fallback.probe_every, 5);
        assert_eq!(s.fallback.regularize_eps, vec![1e-4]);
        assert_eq!(
            s.fallback.degrade,
            vec![SampleMode::Mcmc { steps: 500 }, SampleMode::LowRank { rank: 16 }]
        );
        assert_eq!(s.default_budget_ms, 250);
        assert_eq!(s.epoch_history, 8);
        // Untouched by other configs: robustness defaults.
        let plain = ServiceConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(plain.default_budget_ms, 0);
        assert_eq!(plain.fallback, FallbackPolicy::default());
    }

    #[test]
    fn fallback_policy_rejects_bad_rungs_and_eps() {
        for bad in [
            r#"{"degrade": ["exact"]}"#,           // primary can't be a rung
            r#"{"degrade": ["map:3"]}"#,           // nor MAP
            r#"{"degrade": ["mcmc:zero"]}"#,       // bad parameter
            r#"{"degrade": ["mcmc:0"]}"#,          // steps must be ≥ 1
            r#"{"regularize_eps": [0.0]}"#,        // ε must be positive
            r#"{"regularize_eps": [-1e-6]}"#,      // and not negative
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(FallbackPolicy::from_json(&j).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn service_tenants_validate() {
        let dup = r#"{"tenants": [{"name": "a", "n1": 2, "n2": 2},
                                  {"name": "a", "n1": 3, "n2": 3}]}"#;
        assert!(ServiceConfig::from_json(&Json::parse(dup).unwrap()).is_err());
        let zero = r#"{"tenants": [{"name": "a", "n1": 0, "n2": 2}]}"#;
        assert!(ServiceConfig::from_json(&Json::parse(zero).unwrap()).is_err());
        let unnamed = r#"{"tenants": [{"n1": 2, "n2": 2}]}"#;
        assert!(ServiceConfig::from_json(&Json::parse(unnamed).unwrap()).is_err());
        let reserved = r#"{"tenants": [{"name": "default", "n1": 2, "n2": 2}]}"#;
        assert!(ServiceConfig::from_json(&Json::parse(reserved).unwrap()).is_err());
    }

    #[test]
    fn enums_parse() {
        assert_eq!(Algorithm::parse("krk").unwrap(), Algorithm::Krk);
        assert_eq!(Algorithm::parse("em").unwrap(), Algorithm::Em);
        assert!(Algorithm::parse("sgd").is_err());
        assert_eq!(KernelKind::parse("kron2").unwrap(), KernelKind::Kron2);
        assert!(KernelKind::parse("x").is_err());
    }
}
