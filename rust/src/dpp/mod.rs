//! Determinantal point process core: kernels, likelihoods, samplers.
//!
//! - [`kernel`]: dense / Kron2 / Kron3 kernel representations with
//!   structure-exploiting spectra (§2 of the paper).
//! - [`likelihood`]: the learning objective `φ(L)` (Eq. 3) and the `Θ`
//!   gradient component (Eq. 4), dense and sparse.
//! - [`sampler`]: exact sampling (Alg. 2) and k-DPP sampling — the
//!   incremental batched engine ([`sampler::SampleScratch`],
//!   [`Sampler::sample_batch`]).
//! - [`elementary`]: elementary symmetric polynomials (k-DPP phase 1).
//! - [`mcmc`]: the approximate insert/delete chain baseline (§4, ref [13]).

pub mod elementary;
pub mod kernel;
pub mod likelihood;
pub mod mcmc;
pub mod sampler;

pub use kernel::{EigenVectors, Kernel, KernelEigen};
pub use sampler::{SampleScratch, Sampler};
