//! Determinantal point process core: kernels, likelihoods, samplers.
//!
//! - [`kernel`]: dense / Kron2 / Kron3 kernel representations with
//!   structure-exploiting spectra (§2 of the paper) and factored marginal
//!   queries ([`KernelEigen::inclusion_probabilities_into`] and friends —
//!   the dense `K` is never formed).
//! - [`likelihood`]: the learning objective `φ(L)` (Eq. 3) and the `Θ`
//!   gradient component (Eq. 4), dense and sparse.
//! - [`sampler`]: exact sampling (Alg. 2) and k-DPP sampling — the
//!   incremental batched engine ([`sampler::SampleScratch`],
//!   [`Sampler::sample_batch`]).
//! - [`condition`]: conditional inference — [`Constraint`]-constrained
//!   sampling (`A ⊆ Y, B ∩ Y = ∅`) via Schur-complement conditional
//!   kernels on the restricted ground set.
//! - [`elementary`]: elementary symmetric polynomials (k-DPP phase 1).
//! - [`mcmc`]: the approximate insert/delete chain baseline (§4, ref [13])
//!   with an incrementally maintained `L_Y` Cholesky factor.

pub mod condition;
pub mod elementary;
pub mod kernel;
pub mod likelihood;
pub mod mcmc;
pub mod sampler;

pub use condition::{ConditionScratch, ConditionedSampler, Constraint};
pub use kernel::{EigenVectors, Kernel, KernelEigen, MarginalScratch};
pub use sampler::{SampleScratch, Sampler};
