//! Determinantal point process core: kernels, likelihoods, samplers.
//!
//! - [`kernel`]: dense / Kron2 / Kron3 kernel representations with
//!   structure-exploiting spectra (§2 of the paper) and factored marginal
//!   queries ([`KernelEigen::inclusion_probabilities_into`] and friends —
//!   the dense `K` is never formed).
//! - [`likelihood`]: the learning objective `φ(L)` (Eq. 3) and the `Θ`
//!   gradient component (Eq. 4), dense and sparse.
//! - [`sampler`]: exact sampling (Alg. 2) and k-DPP sampling — the
//!   incremental batched engine ([`sampler::SampleScratch`],
//!   [`Sampler::sample_batch`]).
//! - [`condition`]: conditional inference — [`Constraint`]-constrained
//!   sampling (`A ⊆ Y, B ∩ Y = ∅`) via Schur-complement conditional
//!   kernels on the restricted ground set.
//! - [`delta`]: [`KernelDelta`] — item add/remove/retire and rank-r
//!   factor perturbations, the unit of incremental catalog churn that the
//!   registry's delta-publish path absorbs without re-eigendecomposing.
//! - [`elementary`]: elementary symmetric polynomials (k-DPP phase 1).
//! - [`mcmc`]: the approximate insert/delete chain baseline (§4, ref [13])
//!   with an incrementally maintained `L_Y` Cholesky factor, plus the
//!   restricted-proposal conditional chain and the fixed-size swap chain.
//! - [`map`]: greedy MAP inference — fast `O(Nκ)`-per-step logdet-greedy
//!   slate construction, constraint-aware and allocation-free when warmed.
//! - [`backend`]: the sampler zoo — [`SamplerBackend`] unifying exact,
//!   MCMC and low-rank spectral-projection sampling behind the
//!   [`SampleMode`] fidelity knob the serving stack selects per request.

pub mod backend;
pub mod condition;
pub mod delta;
pub mod elementary;
pub mod kernel;
pub mod likelihood;
pub mod map;
pub mod mcmc;
pub mod sampler;

pub use backend::{LowRankBackend, McmcBackend, SampleMode, SamplerBackend};
pub use condition::{ConditionScratch, ConditionedSampler, Constraint};
pub use delta::KernelDelta;
pub use kernel::{EigenVectors, Kernel, KernelEigen, MarginalScratch};
pub use map::{map_slate, map_slate_auto, map_slate_constrained, map_slate_into, MapScratch};
pub use sampler::{SampleScratch, Sampler};
