//! Conditional DPP inference: constrained sampling under
//! `A ⊆ Y, B ∩ Y = ∅` (the canonical recommendation scenario: "user
//! already picked items A, never show items B, fill the slate with diverse
//! complements").
//!
//! Both constraints keep the model inside the DPP family (Borodin–Rains;
//! Kulesza & Taskar §2.4):
//!
//! - **Exclusion** is ground-set restriction: for an L-ensemble,
//!   `P(Y | Y ∩ B = ∅) ∝ det(L_Y)` over `Y ⊆ [N]∖B`, i.e. the DPP of the
//!   principal submatrix `L_R`.
//! - **Inclusion** is a Schur complement on the restricted problem: with
//!   `R = [N] ∖ (A ∪ B)`,
//!
//!   ```text
//!   det(L_{A∪Z}) = det(L_A) · det((Lᶜ)_Z),
//!   Lᶜ = L_R − L_{R,A} · L_A⁻¹ · L_{A,R}
//!   ```
//!
//!   so `P(Y = A ∪ Z | A ⊆ Y, B ∩ Y = ∅)` is the L-ensemble of `Lᶜ` over
//!   `R`, and the conditional k-DPP of slate size `κ` is the
//!   `(κ−|A|)`-DPP of `Lᶜ` (numpy-verified against full subset
//!   enumeration; see `tests/conditioning.rs` for the in-tree oracle).
//!
//! The assembly never touches the dense `N×N` `L`: the `|A|`-bordered
//! blocks `L_A`, `L_{A,R}`, `L_R` come from factored
//! [`Kernel::principal_submatrix_into`] / [`Kernel::cross_submatrix_into`]
//! gathers, the correction is rank-`|A|` — one small Cholesky of `L_A`
//! plus a triangular solve ([`crate::linalg::trisolve`]) putting the
//! coupling block in the factor's coefficient space, then a single
//! `XᵀX` GEMM. Setup cost is `O(M³)` in the restricted size
//! `M = |R|` (the eigendecomposition of `Lᶜ`, reusing
//! [`crate::linalg::eigen::SymEigenScratch`]); an empty constraint
//! short-circuits to the factored Cor. 2.2 path with no dense object at
//! all. Draws then run through the same incremental phase-1/phase-2
//! engine and [`SampleScratch`] as unconstrained sampling, so the
//! conditioned hot path (fixed constraint, repeated draws) is
//! allocation-free in steady state (`tests/alloc_free.rs`, region C).

use crate::dpp::kernel::{EigenVectors, Kernel, KernelEigen};
use crate::dpp::sampler::{SampleScratch, Sampler};
use crate::error::{Error, Result};
use crate::linalg::eigen::{SymEigen, SymEigenScratch};
use crate::linalg::{cholesky::Cholesky, matmul, trisolve, Matrix};
use crate::rng::Rng;

/// A conditioning constraint: items that **must** appear in every sample
/// (`include`, the paper-reproduction's `A`) and items that **must not**
/// (`exclude`, `B`). Normalized on construction (sorted, deduplicated,
/// disjoint), so equal constraints compare equal — the serving batcher
/// coalesces requests by `(tenant, k, constraint)` and shares one
/// conditioning setup per group.
#[derive(Clone, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Constraint {
    include: Vec<usize>,
    exclude: Vec<usize>,
}

impl Constraint {
    /// Build a constraint; sorts and deduplicates both sides and rejects
    /// overlapping include/exclude sets (`i ∈ A ∩ B` is unsatisfiable).
    pub fn new(include: Vec<usize>, exclude: Vec<usize>) -> Result<Self> {
        let mut c = Constraint { include, exclude };
        c.include.sort_unstable();
        c.include.dedup();
        c.exclude.sort_unstable();
        c.exclude.dedup();
        if let Some(i) = first_common(&c.include, &c.exclude) {
            return Err(Error::Invalid(format!(
                "constraint includes and excludes item {i}"
            )));
        }
        Ok(c)
    }

    /// The unconstrained constraint (`A = B = ∅`).
    pub fn none() -> Self {
        Constraint::default()
    }

    /// Include-only constraint.
    pub fn including(items: Vec<usize>) -> Result<Self> {
        Constraint::new(items, Vec::new())
    }

    /// Exclude-only constraint.
    pub fn excluding(items: Vec<usize>) -> Result<Self> {
        Constraint::new(Vec::new(), items)
    }

    /// Items forced into every sample (sorted, deduplicated).
    pub fn include(&self) -> &[usize] {
        &self.include
    }

    /// Items banned from every sample (sorted, deduplicated).
    pub fn exclude(&self) -> &[usize] {
        &self.exclude
    }

    /// `A = B = ∅`?
    pub fn is_empty(&self) -> bool {
        self.include.is_empty() && self.exclude.is_empty()
    }

    /// Check the constraint against a ground set of size `n`.
    pub fn validate(&self, n: usize) -> Result<()> {
        for &i in self.include.iter().chain(&self.exclude) {
            if i >= n {
                return Err(Error::Invalid(format!(
                    "constraint item {i} outside ground set of size {n}"
                )));
            }
        }
        Ok(())
    }

    /// Check a fixed-size (k-DPP) request against this constraint:
    /// the slate must fit the forced items and the surviving ground set.
    pub fn validate_k(&self, k: usize, n: usize) -> Result<()> {
        self.validate(n)?;
        if k < self.include.len() {
            return Err(Error::Invalid(format!(
                "requested k={k} smaller than the {} forced include items",
                self.include.len()
            )));
        }
        if k > n - self.exclude.len() {
            return Err(Error::Invalid(format!(
                "requested k={k} larger than the {} items surviving exclusion",
                n - self.exclude.len()
            )));
        }
        Ok(())
    }

    /// 64-bit fingerprint of the normalized constraint — the leading
    /// component of the serving worker's `(k, fingerprint, constraint)`
    /// coalescing key, so distinct slate contexts usually compare on one
    /// `u64` instead of two `Vec`s. The full constraint follows in the
    /// key as the exactness tiebreak, so fingerprint collisions can never
    /// merge distinct constraints.
    pub fn fingerprint(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut eat = |x: u64| {
            h ^= x;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        };
        eat(self.include.len() as u64);
        for &i in &self.include {
            eat(i as u64 + 1);
        }
        eat(0xB10C_ED);
        for &i in &self.exclude {
            eat(i as u64 + 1);
        }
        h
    }
}

/// First element common to two sorted slices.
fn first_common(a: &[usize], b: &[usize]) -> Option<usize> {
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => return Some(a[i]),
        }
    }
    None
}

/// Reusable workspace for conditioning setups: the bordered-block gathers,
/// the `L_A` Cholesky factor, the triangular-solve/GEMM staging for the
/// rank-`|A|` correction, and the eigensolver scratch for `Lᶜ`. Serving
/// workers hold one alongside their [`SampleScratch`], so repeated slate
/// contexts rebuild conditioned samplers without buffer churn.
#[derive(Default)]
pub struct ConditionScratch {
    /// `L_A` gather.
    la: Matrix,
    /// Cholesky factor of `L_A`.
    lfac: Matrix,
    /// `L_{A,R}` gather, overwritten in place by `X = F⁻¹·L_{A,R}`.
    cross: Matrix,
    /// Rank-`|A|` correction `XᵀX`.
    corr: Matrix,
    /// `L_R` gather, downdated in place to the conditional kernel `Lᶜ`.
    lc: Matrix,
    /// Eigensolver workspace for the `Lᶜ` decomposition.
    eigen: SymEigenScratch,
    /// GEMM pack buffers for the correction product.
    gemm: matmul::GemmScratch,
}

impl ConditionScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// A sampler for `DPP(L)` conditioned on a [`Constraint`]: every draw
/// contains all of `A`, none of `B`, and is exactly distributed as
/// `P(Y | A ⊆ Y, B ∩ Y = ∅)` (oracle-tested against full subset
/// enumeration). Like [`Sampler`], the expensive setup happens once in
/// [`ConditionedSampler::new`]; draws are then cheap and reuse a
/// caller-held [`SampleScratch`].
pub struct ConditionedSampler {
    constraint: Constraint,
    /// Surviving ground set `R` in ascending order (local → global map).
    rest: Vec<usize>,
    /// Sampler over the conditional kernel `Lᶜ` (ground set `R`).
    inner: Sampler,
    /// Full ground-set size.
    n: usize,
}

impl ConditionedSampler {
    /// Build the conditional kernel and its decomposition (allocating
    /// convenience for [`ConditionedSampler::new_with_scratch`]).
    pub fn new(kernel: &Kernel, constraint: Constraint) -> Result<Self> {
        Self::new_with_scratch(kernel, constraint, &mut ConditionScratch::new())
    }

    /// Build the conditioned sampler through caller-held buffers. The
    /// setup is `O(|A|³ + |A|²·M + M³)` with `M = N − |A| − |B|` (the
    /// `Lᶜ` eigendecomposition dominating) and never forms an `N×N`
    /// object; an empty constraint keeps the factored Cor. 2.2
    /// decomposition (no dense matrix at any size).
    pub fn new_with_scratch(
        kernel: &Kernel,
        constraint: Constraint,
        scratch: &mut ConditionScratch,
    ) -> Result<Self> {
        let n = kernel.n();
        constraint.validate(n)?;
        if constraint.is_empty() {
            // No conditioning: keep the structured eigendecomposition.
            let inner = Sampler::from_eigen(kernel.eigen_with(&mut scratch.eigen)?);
            return Ok(ConditionedSampler {
                constraint,
                rest: (0..n).collect(),
                inner,
                n,
            });
        }
        let rest = complement(n, &constraint.include, &constraint.exclude);
        let m = rest.len();
        let eigen = if m == 0 {
            // Everything is pinned or banned; the only valid sample is A.
            KernelEigen {
                values: Vec::new(),
                factor_values: Vec::new(),
                vectors: EigenVectors::Dense(Matrix::zeros(0, 0)),
            }
        } else {
            kernel.principal_submatrix_into(&rest, &mut scratch.lc);
            if !constraint.include.is_empty() {
                // Rank-|A| Schur correction through the L_A factor's
                // coefficient space: X = F⁻¹·L_{A,R}, Lᶜ = L_R − XᵀX.
                kernel.principal_submatrix_into(&constraint.include, &mut scratch.la);
                // A singular L_A means P(A ⊆ Y) = 0: the *request* is
                // unsatisfiable (Invalid, which the server rejects as a
                // client fault), unlike a downstream eigensolver failure
                // (Numerical — a service fault).
                Cholesky::factor_into(&scratch.la, &mut scratch.lfac).map_err(|_| {
                    Error::Invalid(
                        "conditioning: include set has zero probability (L_A not PD)".into(),
                    )
                })?;
                kernel.cross_submatrix_into(&constraint.include, &rest, &mut scratch.cross);
                trisolve::solve_lower_in_place(scratch.lfac.view(), &mut scratch.cross, false);
                scratch.corr.resize_zeroed(m, m);
                matmul::gemm_into(
                    scratch.corr.view_mut(),
                    1.0,
                    scratch.cross.view().t(),
                    scratch.cross.view(),
                    false,
                    &mut scratch.gemm,
                );
                scratch.lc -= &scratch.corr;
                scratch.lc.symmetrize_mut();
            }
            let e = SymEigen::new_with(&scratch.lc, &mut scratch.eigen)?;
            KernelEigen {
                values: e.values,
                factor_values: Vec::new(),
                vectors: EigenVectors::Dense(e.vectors),
            }
        };
        Ok(ConditionedSampler { constraint, rest, inner: Sampler::from_eigen(eigen), n })
    }

    /// Full ground-set size `N`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The normalized constraint this sampler conditions on.
    pub fn constraint(&self) -> &Constraint {
        &self.constraint
    }

    /// Size of the surviving ground set `R`.
    pub fn rest_len(&self) -> usize {
        self.rest.len()
    }

    /// Smallest admissible slate size (`|A|` — every draw contains `A`).
    pub fn min_k(&self) -> usize {
        self.constraint.include.len()
    }

    /// Largest admissible slate size (`|A| + |R|`).
    pub fn max_k(&self) -> usize {
        self.constraint.include.len() + self.rest.len()
    }

    /// Draw one conditioned subset.
    pub fn sample(&self, rng: &mut Rng) -> Vec<usize> {
        self.sample_with_scratch(rng, &mut SampleScratch::new())
    }

    /// Draw one conditioned subset of exactly `k` items (including the
    /// `|A|` forced ones). Panics if `k` is outside
    /// `[min_k(), max_k()]` — validate with [`Constraint::validate_k`]
    /// first on untrusted input.
    pub fn sample_k(&self, k: usize, rng: &mut Rng) -> Vec<usize> {
        let mut y = Vec::new();
        self.sample_k_into(k, rng, &mut SampleScratch::new(), &mut y);
        y
    }

    /// [`ConditionedSampler::sample`] with caller-held scratch.
    pub fn sample_with_scratch(&self, rng: &mut Rng, scratch: &mut SampleScratch) -> Vec<usize> {
        let mut y = Vec::new();
        self.sample_into(rng, scratch, &mut y);
        y
    }

    /// Draw into a caller-held result buffer — with warmed scratch and
    /// `out`, a conditioned draw performs zero heap allocations.
    pub fn sample_into(&self, rng: &mut Rng, scratch: &mut SampleScratch, out: &mut Vec<usize>) {
        self.inner.sample_into_with_scratch(rng, scratch, out);
        self.finish(out);
    }

    /// Fixed-size draw into a caller-held buffer (`k` counts the forced
    /// include items). See [`ConditionedSampler::sample_k`] for bounds.
    pub fn sample_k_into(
        &self,
        k: usize,
        rng: &mut Rng,
        scratch: &mut SampleScratch,
        out: &mut Vec<usize>,
    ) {
        assert!(
            (self.min_k()..=self.max_k()).contains(&k),
            "conditioned k-DPP: k={k} outside [{}, {}]",
            self.min_k(),
            self.max_k()
        );
        self.inner
            .sample_k_into_with_scratch(k - self.constraint.include.len(), rng, scratch, out);
        self.finish(out);
    }

    /// Draw `draws` conditioned k-DPP subsets sequentially, sharing one
    /// elementary-DP table across the group (the serving worker's
    /// coalesced same-`(k, constraint)` path), delivering each completed
    /// draw to `each`.
    pub fn sample_k_each(
        &self,
        k: usize,
        draws: usize,
        rng: &mut Rng,
        scratch: &mut SampleScratch,
        mut each: impl FnMut(Vec<usize>),
    ) {
        assert!(
            (self.min_k()..=self.max_k()).contains(&k),
            "conditioned k-DPP: k={k} outside [{}, {}]",
            self.min_k(),
            self.max_k()
        );
        let inner_k = k - self.constraint.include.len();
        self.inner.sample_k_each(inner_k, draws, rng, scratch, |mut y| {
            self.finish(&mut y);
            each(y);
        });
    }

    /// Map a draw over `R` back to global indices and merge the forced
    /// include items (in place; no allocation once `out` has capacity).
    fn finish(&self, out: &mut Vec<usize>) {
        for v in out.iter_mut() {
            *v = self.rest[*v];
        }
        out.extend_from_slice(&self.constraint.include);
        out.sort_unstable();
    }
}

/// Ascending complement of two sorted disjoint index sets in `0..n`.
fn complement(n: usize, a: &[usize], b: &[usize]) -> Vec<usize> {
    let mut out = Vec::with_capacity(n - a.len() - b.len());
    let (mut ia, mut ib) = (0usize, 0usize);
    for i in 0..n {
        if ia < a.len() && a[ia] == i {
            ia += 1;
        } else if ib < b.len() && b[ib] == i {
            ib += 1;
        } else {
            out.push(i);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = rng.paper_init_kernel(n);
        m.scale_mut(1.5 / n as f64);
        m.add_diag_mut(0.3);
        m
    }

    fn kron2(n1: usize, n2: usize, seed: u64) -> Kernel {
        Kernel::Kron2(spd(n1, seed), spd(n2, seed + 100))
    }

    #[test]
    fn constraint_normalizes_and_rejects_overlap() {
        let c = Constraint::new(vec![5, 1, 5], vec![7, 3, 3]).unwrap();
        assert_eq!(c.include(), &[1, 5]);
        assert_eq!(c.exclude(), &[3, 7]);
        assert!(!c.is_empty());
        assert!(Constraint::none().is_empty());
        assert!(Constraint::new(vec![1, 2], vec![2, 9]).is_err());
        assert!(c.validate(8).is_ok());
        assert!(c.validate(7).is_err(), "item 7 out of bounds for n=7");
        assert!(c.validate_k(2, 12).is_ok());
        assert!(c.validate_k(1, 12).is_err(), "k < |A|");
        assert!(c.validate_k(11, 12).is_err(), "k > n - |B|");
    }

    #[test]
    fn fingerprint_distinguishes_and_normalizes() {
        let a = Constraint::new(vec![1, 5], vec![3]).unwrap();
        let b = Constraint::new(vec![5, 1, 1], vec![3, 3]).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.fingerprint(), b.fingerprint());
        // Include vs exclude of the same items must differ.
        let c = Constraint::new(vec![3], vec![1, 5]).unwrap();
        assert_ne!(a.fingerprint(), c.fingerprint());
        assert_ne!(a.fingerprint(), Constraint::none().fingerprint());
    }

    #[test]
    fn empty_constraint_matches_unconstrained_sampler_bitwise() {
        // A=B=∅ keeps the factored decomposition: identical RNG streams
        // must give identical draws to the plain Sampler.
        let kernel = kron2(3, 4, 1);
        let cs = ConditionedSampler::new(&kernel, Constraint::none()).unwrap();
        let s = Sampler::new(&kernel).unwrap();
        let (mut ra, mut rb) = (Rng::new(7), Rng::new(7));
        for i in 0..40 {
            if i % 2 == 0 {
                assert_eq!(cs.sample(&mut ra), s.sample(&mut rb), "draw {i}");
            } else {
                assert_eq!(cs.sample_k(3, &mut ra), s.sample_k(3, &mut rb), "draw {i}");
            }
        }
        assert_eq!(cs.min_k(), 0);
        assert_eq!(cs.max_k(), 12);
    }

    #[test]
    fn draws_honor_include_and_exclude() {
        let kernel = kron2(3, 4, 2);
        let c = Constraint::new(vec![0, 7], vec![3, 11]).unwrap();
        let cs = ConditionedSampler::new(&kernel, c).unwrap();
        let mut rng = Rng::new(9);
        let mut scratch = SampleScratch::new();
        for i in 0..60 {
            let y = if i % 2 == 0 {
                cs.sample_with_scratch(&mut rng, &mut scratch)
            } else {
                cs.sample_k(4, &mut rng)
            };
            assert!(y.windows(2).all(|w| w[0] < w[1]), "sorted unique: {y:?}");
            assert!(y.contains(&0) && y.contains(&7), "include violated: {y:?}");
            assert!(!y.contains(&3) && !y.contains(&11), "exclude violated: {y:?}");
            assert!(y.iter().all(|&v| v < 12));
            if i % 2 == 1 {
                assert_eq!(y.len(), 4);
            }
        }
    }

    #[test]
    fn fully_pinned_ground_set_returns_include() {
        let kernel = kron2(2, 2, 3);
        let c = Constraint::new(vec![0, 2], vec![1, 3]).unwrap();
        let cs = ConditionedSampler::new(&kernel, c).unwrap();
        let mut rng = Rng::new(4);
        assert_eq!(cs.rest_len(), 0);
        assert_eq!(cs.sample(&mut rng), vec![0, 2]);
        assert_eq!(cs.sample_k(2, &mut rng), vec![0, 2]);
    }

    #[test]
    fn sample_k_each_matches_individual_draws_plus_merge() {
        let kernel = kron2(3, 3, 5);
        let c = Constraint::new(vec![4], vec![0]).unwrap();
        let cs = ConditionedSampler::new(&kernel, c).unwrap();
        let (mut ra, mut rb) = (Rng::new(11), Rng::new(11));
        let mut sa = SampleScratch::new();
        let mut collected = Vec::new();
        cs.sample_k_each(3, 10, &mut ra, &mut sa, |y| collected.push(y));
        assert_eq!(collected.len(), 10);
        // Same RNG stream on the inner sampler must reproduce the draws.
        let cs2 = ConditionedSampler::new(&kernel, Constraint::new(vec![4], vec![0]).unwrap())
            .unwrap();
        let mut sb = SampleScratch::new();
        let mut again = Vec::new();
        cs2.sample_k_each(3, 10, &mut rb, &mut sb, |y| again.push(y));
        assert_eq!(collected, again);
        for y in &collected {
            assert_eq!(y.len(), 3);
            assert!(y.contains(&4) && !y.contains(&0));
        }
    }

    #[test]
    fn scratch_reuse_across_constraints_matches_fresh() {
        let kernel = kron2(3, 4, 6);
        let mut scratch = ConditionScratch::new();
        for c in [
            Constraint::including(vec![2]).unwrap(),
            Constraint::excluding(vec![5, 6]).unwrap(),
            Constraint::new(vec![1, 8], vec![0]).unwrap(),
        ] {
            let reused =
                ConditionedSampler::new_with_scratch(&kernel, c.clone(), &mut scratch).unwrap();
            let fresh = ConditionedSampler::new(&kernel, c).unwrap();
            let (mut ra, mut rb) = (Rng::new(13), Rng::new(13));
            for _ in 0..10 {
                assert_eq!(reused.sample(&mut ra), fresh.sample(&mut rb));
            }
        }
    }
}
