//! The sampler zoo: one interface over exact, MCMC and low-rank DPP
//! sampling, plus the [`SampleMode`] fidelity knob the serving stack
//! threads from request admission down to the workers.
//!
//! Following DPPy's catalogue of interchangeable exact/approximate DPP
//! samplers, every backend implements [`SamplerBackend::draw_into`] — one
//! subset per call, `k = None` for the size-varying law, `k = Some`
//! for the k-DPP — so callers (the service workers, the conformance
//! harness in `tests/sampler_conformance.rs`, `benches/bench_sampler_zoo`)
//! can swap fidelity-for-throughput without touching call sites:
//!
//! - **Exact** — the eigendecomposition sampler ([`Sampler`], and
//!   [`ConditionedSampler`] when a constraint is attached). The reference
//!   law; every other backend is measured against it.
//! - **MCMC** ([`McmcBackend`]) — the `O(κ²)`-per-move insert/delete chain
//!   of [`crate::dpp::mcmc`]. Constraints need no Schur setup at all: the
//!   chain simply proposes only from `R = [N] ∖ (A ∪ B)` starting at `A`,
//!   which restricts the stationary law `∝ det(L_Y)` to the admissible
//!   lattice `A ⊆ Y ⊆ A ∪ R` — exactly the conditional DPP. Fixed-size
//!   draws run the symmetric swap chain at the requested cardinality. The
//!   knob is `steps`: each draw is an independent chain, so fidelity is
//!   mixing, not machinery.
//! - **Low-rank** ([`LowRankBackend`]) — a spectral-projection (Nyström-
//!   style) approximation: the kernel's spectrum is truncated to its top
//!   `rank` eigenpairs and the rank-`r` kernel `L_r = V_r Λ_r V_rᵀ` is
//!   sampled *exactly* through the same phase-1/phase-2 engine. The knob
//!   is `rank`: phase 2 contracts an `N×r` basis instead of `N×N`, and
//!   draws can never exceed `r` items. Conformance therefore checks the
//!   backend against enumeration of **its own** truncated kernel (it is an
//!   exact sampler of an approximate law), while the zoo bench reports its
//!   total-variation distance from the full law as the fidelity cost.
//!
//! Greedy MAP ([`crate::dpp::map`]) is the fourth mode of the serving
//! stack but not a `SamplerBackend` — it is deterministic, so the service
//! computes one slate per coalesced group instead of one draw per request.

use std::fmt;

use crate::dpp::condition::{ConditionedSampler, Constraint};
use crate::dpp::kernel::{EigenVectors, Kernel, KernelEigen};
use crate::dpp::mcmc::McmcSampler;
use crate::dpp::sampler::{SampleScratch, Sampler};
use crate::error::Result;
use crate::invalid_err;
use crate::linalg::Matrix;
use crate::rng::Rng;

/// Default chain length for MCMC-mode draws when the caller does not pick
/// one (CLI `--mode mcmc` without `--steps`).
pub const DEFAULT_MCMC_STEPS: usize = 4000;

/// Per-request sampling mode — the fidelity knob carried by
/// `SampleRequest` through admission, coalescing and the per-mode
/// metrics. `Ord`/`Hash` so it can key worker coalescing.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SampleMode {
    /// Exact eigendecomposition sampling (the default).
    Exact,
    /// Approximate insert/delete (or fixed-size swap) chain; each draw is
    /// an independent `steps`-move chain.
    Mcmc { steps: usize },
    /// Spectral-projection sampling of the top-`rank` truncated kernel.
    LowRank { rank: usize },
    /// Deterministic greedy MAP slate instead of a random draw.
    Map,
}

impl SampleMode {
    /// Short stable name, used by metrics and the CLI.
    pub fn label(&self) -> &'static str {
        match self {
            SampleMode::Exact => "exact",
            SampleMode::Mcmc { .. } => "mcmc",
            SampleMode::LowRank { .. } => "lowrank",
            SampleMode::Map => "map",
        }
    }

    /// Parse a CLI mode name plus its optional parameters.
    pub fn parse(name: &str, steps: Option<usize>, rank: Option<usize>) -> Result<SampleMode> {
        match name {
            "exact" => Ok(SampleMode::Exact),
            "mcmc" => {
                Ok(SampleMode::Mcmc { steps: steps.unwrap_or(DEFAULT_MCMC_STEPS) })
            }
            "lowrank" | "low-rank" => match rank {
                Some(rank) => Ok(SampleMode::LowRank { rank }),
                None => Err(invalid_err!("--rank is required for --mode lowrank")),
            },
            "map" => Ok(SampleMode::Map),
            other => {
                Err(invalid_err!("unknown sample mode '{other}' (exact|mcmc|lowrank|map)"))
            }
        }
    }
}

impl fmt::Display for SampleMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SampleMode::Exact => write!(f, "exact"),
            SampleMode::Mcmc { steps } => write!(f, "mcmc(steps={steps})"),
            SampleMode::LowRank { rank } => write!(f, "lowrank(rank={rank})"),
            SampleMode::Map => write!(f, "map"),
        }
    }
}

/// One randomized DPP sampling backend: a single subset per call, written
/// into a caller-held buffer against a caller-held scratch.
pub trait SamplerBackend {
    /// Backend family name (matches [`SampleMode::label`]).
    fn name(&self) -> &'static str;

    /// Ground-set size.
    fn n(&self) -> usize;

    /// Draw one subset — `k = None` samples the size-varying law,
    /// `k = Some(k)` the k-DPP. The result is sorted and deduplicated.
    fn draw_into(
        &self,
        k: Option<usize>,
        rng: &mut Rng,
        scratch: &mut SampleScratch,
        out: &mut Vec<usize>,
    ) -> Result<()>;
}

impl SamplerBackend for Sampler {
    fn name(&self) -> &'static str {
        "exact"
    }

    fn n(&self) -> usize {
        self.n()
    }

    fn draw_into(
        &self,
        k: Option<usize>,
        rng: &mut Rng,
        scratch: &mut SampleScratch,
        out: &mut Vec<usize>,
    ) -> Result<()> {
        match k {
            None => self.sample_into_with_scratch(rng, scratch, out),
            Some(k) => {
                if k > self.n() {
                    return Err(invalid_err!("exact: k={k} exceeds ground set {}", self.n()));
                }
                self.sample_k_into_with_scratch(k, rng, scratch, out);
            }
        }
        Ok(())
    }
}

impl SamplerBackend for ConditionedSampler {
    fn name(&self) -> &'static str {
        "exact"
    }

    fn n(&self) -> usize {
        self.n()
    }

    fn draw_into(
        &self,
        k: Option<usize>,
        rng: &mut Rng,
        scratch: &mut SampleScratch,
        out: &mut Vec<usize>,
    ) -> Result<()> {
        match k {
            None => self.sample_into(rng, scratch, out),
            Some(k) => {
                if k < self.min_k() || k > self.max_k() {
                    return Err(invalid_err!(
                        "exact: k={k} outside constrained range [{}, {}]",
                        self.min_k(),
                        self.max_k()
                    ));
                }
                self.sample_k_into(k, rng, scratch, out);
            }
        }
        Ok(())
    }
}

/// MCMC sampling backend: independent Metropolis chains over the
/// constraint-restricted subset lattice (see the module docs).
pub struct McmcBackend<'a> {
    kernel: &'a Kernel,
    constraint: Constraint,
    /// Free items `R = [N] ∖ (A ∪ B)` — the proposal pool.
    rest: Vec<usize>,
    steps: usize,
}

impl<'a> McmcBackend<'a> {
    pub fn new(kernel: &'a Kernel, constraint: Constraint, steps: usize) -> Result<Self> {
        let n = kernel.n();
        constraint.validate(n)?;
        if steps == 0 {
            return Err(invalid_err!("mcmc: steps must be positive"));
        }
        let rest: Vec<usize> = (0..n)
            .filter(|i| {
                constraint.include().binary_search(i).is_err()
                    && constraint.exclude().binary_search(i).is_err()
            })
            .collect();
        Ok(McmcBackend { kernel, constraint, rest, steps })
    }

    /// Smallest / largest admissible fixed size (mirrors
    /// [`ConditionedSampler::min_k`] / [`ConditionedSampler::max_k`]).
    pub fn min_k(&self) -> usize {
        self.constraint.include().len()
    }

    pub fn max_k(&self) -> usize {
        self.constraint.include().len() + self.rest.len()
    }

    pub fn steps(&self) -> usize {
        self.steps
    }
}

impl SamplerBackend for McmcBackend<'_> {
    fn name(&self) -> &'static str {
        "mcmc"
    }

    fn n(&self) -> usize {
        self.kernel.n()
    }

    fn draw_into(
        &self,
        k: Option<usize>,
        rng: &mut Rng,
        _scratch: &mut SampleScratch,
        out: &mut Vec<usize>,
    ) -> Result<()> {
        let include = self.constraint.include();
        match k {
            None => {
                // Size-varying conditional chain: start at A, propose only
                // from R.
                let mut chain = if include.is_empty() {
                    McmcSampler::new(self.kernel)
                } else {
                    McmcSampler::with_state(self.kernel, include.to_vec())?
                };
                if !self.rest.is_empty() {
                    for _ in 0..self.steps {
                        chain.step_candidates(&self.rest, rng)?;
                    }
                }
                out.clear();
                out.extend_from_slice(chain.state());
            }
            Some(k) => {
                if k < self.min_k() || k > self.max_k() {
                    return Err(invalid_err!(
                        "mcmc: k={k} outside constrained range [{}, {}]",
                        self.min_k(),
                        self.max_k()
                    ));
                }
                let free = k - include.len();
                if free == 0 {
                    out.clear();
                    out.extend_from_slice(include);
                    return Ok(());
                }
                // Random admissible start: A plus `free` items of R drawn
                // by partial Fisher–Yates; the remainder is the out-pool.
                let mut pool = self.rest.clone();
                for i in 0..free {
                    let j = i + rng.below(pool.len() - i);
                    pool.swap(i, j);
                }
                let mut start = Vec::with_capacity(k);
                start.extend_from_slice(include);
                start.extend_from_slice(&pool[..free]);
                let mut chain = McmcSampler::with_state(self.kernel, start)?;
                let (inside, outside) = pool.split_at_mut(free);
                if !outside.is_empty() {
                    // Symmetric swap proposals (u ∈ Y ∖ A, v ∈ R ∖ Y) keep
                    // |Y| = k and A pinned.
                    for _ in 0..self.steps {
                        let iu = rng.below(inside.len());
                        let iv = rng.below(outside.len());
                        let u = inside[iu];
                        let pos = chain
                            .state()
                            .binary_search(&u)
                            .expect("swap-chain bookkeeping out of sync");
                        if chain.step_swap(pos, outside[iv], rng)? {
                            inside[iu] = outside[iv];
                            outside[iv] = u;
                        }
                    }
                }
                out.clear();
                out.extend_from_slice(chain.state());
            }
        }
        Ok(())
    }
}

enum LowRankInner {
    Plain(Sampler),
    Cond(ConditionedSampler),
}

/// Spectral-projection (Nyström-style) approximate sampler: an exact
/// sampler of the top-`rank` truncated kernel `L_r = V_r Λ_r V_rᵀ`.
pub struct LowRankBackend {
    /// Top-`rank` eigenvalues (clamped at zero), ascending-index order.
    values: Vec<f64>,
    /// Gathered `N×rank` eigenvector block matching `values`.
    vectors: Matrix,
    rank: usize,
    n: usize,
    inner: LowRankInner,
}

impl LowRankBackend {
    /// Build from a kernel (computes the eigendecomposition).
    pub fn new(kernel: &Kernel, rank: usize, constraint: Constraint) -> Result<Self> {
        LowRankBackend::from_eigen(&kernel.eigen()?, rank, constraint)
    }

    /// Build from a precomputed spectrum — the serving path reuses the
    /// registry epoch's cached eigendecomposition, so constructing the
    /// backend is an `O(N·r)` gather, not an eigensolve.
    pub fn from_eigen(eigen: &KernelEigen, rank: usize, constraint: Constraint) -> Result<Self> {
        let n = eigen.n();
        if rank == 0 || rank > n {
            return Err(invalid_err!("lowrank: rank {rank} outside 1..={n}"));
        }
        constraint.validate(n)?;
        // Top-`rank` eigenpairs, deterministically (value desc, index ties
        // asc), then restored to ascending index order.
        let mut idx: Vec<usize> = (0..eigen.values.len()).collect();
        idx.sort_unstable_by(|&a, &b| {
            eigen.values[b]
                .partial_cmp(&eigen.values[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        idx.truncate(rank);
        idx.sort_unstable();
        let values: Vec<f64> = idx.iter().map(|&t| eigen.values[t].max(0.0)).collect();
        let mut vectors = Matrix::zeros(n, rank);
        let mut col = vec![0.0; n];
        for (c, &t) in idx.iter().enumerate() {
            eigen.vectors.column_into(t, &mut col);
            for i in 0..n {
                vectors.set(i, c, col[i]);
            }
        }
        let inner = if constraint.is_empty() {
            let truncated = KernelEigen {
                values: values.clone(),
                factor_values: Vec::new(),
                vectors: EigenVectors::Dense(vectors.clone()),
            };
            LowRankInner::Plain(Sampler::from_eigen(truncated))
        } else {
            // Constrained draws condition the truncated kernel exactly —
            // the one place the projection goes dense.
            let dense = dense_from_pairs(&values, &vectors);
            LowRankInner::Cond(ConditionedSampler::new(&Kernel::Full(dense), constraint)?)
        };
        Ok(LowRankBackend { values, vectors, rank, n, inner })
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Dense `L_r = V_r Λ_r V_rᵀ` — the backend's *own* target law, used
    /// by the conformance oracle and the zoo bench (`O(N²r)`, test-side).
    pub fn truncated_dense(&self) -> Matrix {
        dense_from_pairs(&self.values, &self.vectors)
    }

    /// Largest subset the projection can emit (`rank`, minus nothing: the
    /// constrained variant's bound is handled by its conditional
    /// spectrum).
    pub fn max_draw(&self) -> usize {
        self.rank
    }
}

fn dense_from_pairs(values: &[f64], vectors: &Matrix) -> Matrix {
    let n = vectors.rows();
    let r = vectors.cols();
    let mut dense = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut v = 0.0;
            for t in 0..r {
                v += values[t] * vectors.get(i, t) * vectors.get(j, t);
            }
            dense.set(i, j, v);
            dense.set(j, i, v);
        }
    }
    dense
}

impl SamplerBackend for LowRankBackend {
    fn name(&self) -> &'static str {
        "lowrank"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn draw_into(
        &self,
        k: Option<usize>,
        rng: &mut Rng,
        scratch: &mut SampleScratch,
        out: &mut Vec<usize>,
    ) -> Result<()> {
        match &self.inner {
            LowRankInner::Plain(s) => match k {
                None => s.sample_into_with_scratch(rng, scratch, out),
                Some(k) => {
                    if k > self.rank {
                        return Err(invalid_err!(
                            "lowrank: k={k} exceeds projection rank {}",
                            self.rank
                        ));
                    }
                    s.sample_k_into_with_scratch(k, rng, scratch, out);
                }
            },
            LowRankInner::Cond(cs) => match k {
                None => cs.sample_into(rng, scratch, out),
                Some(k) => {
                    // A rank-`r` kernel gives zero mass to every subset
                    // larger than `r`, include items counted.
                    if k < cs.min_k() || k > cs.max_k() || k > self.rank {
                        return Err(invalid_err!(
                            "lowrank: k={k} outside constrained rank-{} range [{}, {}]",
                            self.rank,
                            cs.min_k(),
                            cs.max_k().min(self.rank)
                        ));
                    }
                    cs.sample_k_into(k, rng, scratch, out);
                }
            },
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::lu;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = rng.paper_init_kernel(n);
        m.scale_mut(1.5 / n as f64);
        m.add_diag_mut(0.3);
        m
    }

    #[test]
    fn mode_parsing_round_trips() {
        assert_eq!(SampleMode::parse("exact", None, None).unwrap(), SampleMode::Exact);
        assert_eq!(
            SampleMode::parse("mcmc", Some(77), None).unwrap(),
            SampleMode::Mcmc { steps: 77 }
        );
        assert_eq!(
            SampleMode::parse("mcmc", None, None).unwrap(),
            SampleMode::Mcmc { steps: DEFAULT_MCMC_STEPS }
        );
        assert_eq!(
            SampleMode::parse("lowrank", None, Some(8)).unwrap(),
            SampleMode::LowRank { rank: 8 }
        );
        assert!(SampleMode::parse("lowrank", None, None).is_err());
        assert!(SampleMode::parse("gibbs", None, None).is_err());
        assert_eq!(SampleMode::Map.label(), "map");
        assert_eq!(format!("{}", SampleMode::Mcmc { steps: 5 }), "mcmc(steps=5)");
    }

    #[test]
    fn full_rank_projection_reproduces_the_kernel() {
        let kernel = Kernel::Kron2(spd(3, 1), spd(2, 2));
        let n = kernel.n();
        let lr = LowRankBackend::new(&kernel, n, Constraint::none()).unwrap();
        let dense = lr.truncated_dense();
        for i in 0..n {
            for j in 0..n {
                assert!(
                    (dense.get(i, j) - kernel.entry(i, j)).abs() < 1e-10,
                    "L_r[{i},{j}] = {} vs L = {}",
                    dense.get(i, j),
                    kernel.entry(i, j)
                );
            }
        }
    }

    #[test]
    fn truncated_dense_is_psd_with_rank_bounded_draws() {
        let kernel = Kernel::Kron2(spd(3, 3), spd(3, 4));
        let rank = 4;
        let lr = LowRankBackend::new(&kernel, rank, Constraint::none()).unwrap();
        let mut rng = Rng::new(5);
        let mut scratch = SampleScratch::new();
        let mut out = Vec::new();
        for _ in 0..200 {
            lr.draw_into(None, &mut rng, &mut scratch, &mut out).unwrap();
            assert!(out.len() <= rank, "projection emitted {} > rank {rank} items", out.len());
            assert!(out.windows(2).all(|w| w[0] < w[1]));
            if !out.is_empty() {
                // Every drawn subset has positive mass under L_r.
                let d = lu::det(&lr.truncated_dense().principal_submatrix(&out)).unwrap();
                assert!(d > 0.0, "subset {out:?} has det {d}");
            }
        }
        assert!(lr.draw_into(Some(rank + 1), &mut rng, &mut scratch, &mut out).is_err());
    }

    #[test]
    fn mcmc_backend_respects_constraints_and_sizes() {
        let kernel = Kernel::Kron2(spd(3, 6), spd(2, 7));
        let c = Constraint::new(vec![1], vec![4]).unwrap();
        let backend = McmcBackend::new(&kernel, c, 60).unwrap();
        let mut rng = Rng::new(8);
        let mut scratch = SampleScratch::new();
        let mut out = Vec::new();
        for _ in 0..30 {
            backend.draw_into(None, &mut rng, &mut scratch, &mut out).unwrap();
            assert!(out.contains(&1) && !out.contains(&4));
            assert!(out.windows(2).all(|w| w[0] < w[1]));
            backend.draw_into(Some(3), &mut rng, &mut scratch, &mut out).unwrap();
            assert_eq!(out.len(), 3);
            assert!(out.contains(&1) && !out.contains(&4));
        }
        assert!(backend.draw_into(Some(0), &mut rng, &mut scratch, &mut out).is_err());
        assert!(backend.draw_into(Some(6), &mut rng, &mut scratch, &mut out).is_err());
    }

    #[test]
    fn constrained_low_rank_draws_stay_admissible() {
        let kernel = Kernel::Kron2(spd(3, 9), spd(3, 10));
        let c = Constraint::new(vec![2], vec![7]).unwrap();
        let lr = LowRankBackend::new(&kernel, 6, c).unwrap();
        let mut rng = Rng::new(11);
        let mut scratch = SampleScratch::new();
        let mut out = Vec::new();
        for _ in 0..100 {
            lr.draw_into(None, &mut rng, &mut scratch, &mut out).unwrap();
            assert!(out.contains(&2) && !out.contains(&7));
        }
        lr.draw_into(Some(3), &mut rng, &mut scratch, &mut out).unwrap();
        assert_eq!(out.len(), 3);
        assert!(out.contains(&2));
    }

    #[test]
    fn backend_trait_objects_unify_the_zoo() {
        let kernel = Kernel::Kron2(spd(2, 12), spd(3, 13));
        let exact = Sampler::new(&kernel).unwrap();
        let mcmc = McmcBackend::new(&kernel, Constraint::none(), 40).unwrap();
        let lowrank = LowRankBackend::new(&kernel, 4, Constraint::none()).unwrap();
        let zoo: Vec<&dyn SamplerBackend> = vec![&exact, &mcmc, &lowrank];
        let mut rng = Rng::new(14);
        let mut scratch = SampleScratch::new();
        let mut out = Vec::new();
        for backend in zoo {
            assert_eq!(backend.n(), 6);
            backend.draw_into(None, &mut rng, &mut scratch, &mut out).unwrap();
            assert!(out.iter().all(|&i| i < 6));
            backend.draw_into(Some(2), &mut rng, &mut scratch, &mut out).unwrap();
            assert_eq!(out.len(), 2);
        }
    }
}
