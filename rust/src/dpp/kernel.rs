//! DPP kernel representations.
//!
//! [`Kernel`] is the central type: a PSD matrix `L` defining
//! `P(Y) ∝ det(L_Y)`, stored either densely ([`Kernel::Full`]) or as a
//! Kronecker product of two/three sub-kernels ([`Kernel::Kron2`],
//! [`Kernel::Kron3`] — the paper's KronDPP). All DPP operations dispatch on
//! the structure and exploit it:
//!
//! - entries and principal submatrices come from sub-kernel products in
//!   `O(1)` per entry (never materializing `L`),
//! - `log det(L + I)` uses sub-spectra (`O(N₁³+N₂³)` instead of `O(N³)`),
//! - the eigendecomposition factorizes per Cor. 2.2, giving the paper's
//!   `O(N^{3/2})` (m=2) / `O(N)` (m=3) sampling preprocessing,
//! - marginal-kernel queries (`P(i ∈ Y) = K_ii`, slate blocks `K_A`) stay
//!   factored: [`KernelEigen::inclusion_probabilities_into`] produces all
//!   `N` diagonals of `K = L(L+I)⁻¹` in `O(N·(N₁+N₂))` (m=2) /
//!   `O(N·(N₁+N₂+N₃))` (m=3) as GEMMs over squared eigenvector matrices
//!   against the `λ/(1+λ)` grid; [`KernelEigen::marginal_entry`] /
//!   [`KernelEigen::marginal_block_into`] answer `O(κ²)`-entry slate
//!   queries without ever materializing the `N×N` `K`
//!   ([`Kernel::marginal_kernel`] remains as the small-N test oracle).

use crate::error::{Error, Result};
use crate::linalg::simd::{self, Kernels};
use crate::linalg::view::{MatMut, MatRef};
use crate::linalg::{cholesky, eigen::SymEigen, kron, matmul, Matrix};

/// Largest ground set for which [`Kernel::marginal_kernel`] will densify a
/// *structured* kernel in debug builds. The dense `K` is a test oracle;
/// production marginal queries go through the factored
/// [`KernelEigen`] paths, which never allocate an `N×N` intermediate.
pub const MARGINAL_ORACLE_MAX_N: usize = 4096;

/// A DPP kernel `L`, dense or Kronecker-structured.
#[derive(Clone, Debug)]
pub enum Kernel {
    /// Unstructured dense kernel.
    Full(Matrix),
    /// `L = L₁ ⊗ L₂`.
    Kron2(Matrix, Matrix),
    /// `L = L₁ ⊗ L₂ ⊗ L₃`.
    Kron3(Matrix, Matrix, Matrix),
}

impl Kernel {
    /// Ground-set size `N`.
    pub fn n(&self) -> usize {
        match self {
            Kernel::Full(l) => l.rows(),
            Kernel::Kron2(a, b) => a.rows() * b.rows(),
            Kernel::Kron3(a, b, c) => a.rows() * b.rows() * c.rows(),
        }
    }

    /// Number of free parameters (the paper's `N² → O(N^{2/m})` saving).
    pub fn param_count(&self) -> usize {
        match self {
            Kernel::Full(l) => l.rows() * l.rows(),
            Kernel::Kron2(a, b) => a.rows() * a.rows() + b.rows() * b.rows(),
            Kernel::Kron3(a, b, c) => {
                a.rows() * a.rows() + b.rows() * b.rows() + c.rows() * c.rows()
            }
        }
    }

    /// Entry `L[i, j]` without materializing the product.
    #[inline]
    pub fn entry(&self, i: usize, j: usize) -> f64 {
        match self {
            Kernel::Full(l) => l.get(i, j),
            Kernel::Kron2(a, b) => {
                let n2 = b.rows();
                a.get(i / n2, j / n2) * b.get(i % n2, j % n2)
            }
            Kernel::Kron3(a, b, c) => {
                let n3 = c.rows();
                let n2 = b.rows();
                let (i2, ir) = (i / (n2 * n3), i % (n2 * n3));
                let (j2, jr) = (j / (n2 * n3), j % (n2 * n3));
                a.get(i2, j2) * b.get(ir / n3, jr / n3) * c.get(ir % n3, jr % n3)
            }
        }
    }

    /// Principal submatrix `L_Y` (κ×κ) — `O(κ²)` for any structure.
    pub fn principal_submatrix(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(0, 0);
        self.principal_submatrix_into(idx, &mut out);
        out
    }

    /// [`Kernel::principal_submatrix`] into a caller-held buffer — the
    /// allocation-free form behind the per-subset likelihood sweep.
    ///
    /// For the Kronecker structures, each index's sub-kernel split
    /// (`i ↦ (i₁, i₂[, i₃])`) is precomputed once per call (`O(κ)` setup
    /// into thread-local staging, allocation-free after warmup) instead of
    /// re-deriving the div/mod pairs inside the `κ²` entry loop.
    pub fn principal_submatrix_into(&self, idx: &[usize], out: &mut Matrix) {
        use std::cell::RefCell;
        thread_local! {
            static SPLIT2: RefCell<Vec<(usize, usize)>> = RefCell::new(Vec::new());
            static SPLIT3: RefCell<Vec<(usize, usize, usize)>> = RefCell::new(Vec::new());
        }
        let k = idx.len();
        out.resize_zeroed(k, k);
        match self {
            Kernel::Full(l) => {
                for (a, &i) in idx.iter().enumerate() {
                    let src = l.row(i);
                    let dst = out.row_mut(a);
                    for (b, &j) in idx.iter().enumerate() {
                        dst[b] = src[j];
                    }
                }
            }
            Kernel::Kron2(l1, l2) => {
                let n2 = l2.rows();
                SPLIT2.with(|buf| {
                    let mut split = buf.borrow_mut();
                    split.clear();
                    split.extend(idx.iter().map(|&i| (i / n2, i % n2)));
                    for (r, &(i1, i2)) in split.iter().enumerate() {
                        let dst = out.row_mut(r);
                        for (c, &(j1, j2)) in split.iter().enumerate() {
                            dst[c] = l1.get(i1, j1) * l2.get(i2, j2);
                        }
                    }
                });
            }
            Kernel::Kron3(l1, l2, l3) => {
                let n3 = l3.rows();
                let n23 = l2.rows() * n3;
                SPLIT3.with(|buf| {
                    let mut split = buf.borrow_mut();
                    split.clear();
                    split.extend(idx.iter().map(|&i| {
                        let r = i % n23;
                        (i / n23, r / n3, r % n3)
                    }));
                    for (r, &(i1, i2, i3)) in split.iter().enumerate() {
                        let dst = out.row_mut(r);
                        for (c, &(j1, j2, j3)) in split.iter().enumerate() {
                            dst[c] = l1.get(i1, j1) * l2.get(i2, j2) * l3.get(i3, j3);
                        }
                    }
                });
            }
        }
    }

    /// Rectangular gather `L[rows, cols]` into a caller-held buffer — the
    /// conditioning path's bordered-block form of
    /// [`Kernel::principal_submatrix_into`] (the `L_{A,R}` coupling block
    /// of the Schur complement). Same discipline: each axis's sub-kernel
    /// splits are precomputed once per call into thread-local staging
    /// (allocation-free after warmup), so the `|rows|·|cols|` entry loop
    /// does no div/mod.
    pub fn cross_submatrix_into(&self, rows: &[usize], cols: &[usize], out: &mut Matrix) {
        use std::cell::RefCell;
        thread_local! {
            static RSPLIT2: RefCell<Vec<(usize, usize)>> = RefCell::new(Vec::new());
            static CSPLIT2: RefCell<Vec<(usize, usize)>> = RefCell::new(Vec::new());
            static RSPLIT3: RefCell<Vec<(usize, usize, usize)>> = RefCell::new(Vec::new());
            static CSPLIT3: RefCell<Vec<(usize, usize, usize)>> = RefCell::new(Vec::new());
        }
        out.resize_zeroed(rows.len(), cols.len());
        match self {
            Kernel::Full(l) => {
                for (a, &i) in rows.iter().enumerate() {
                    let src = l.row(i);
                    let dst = out.row_mut(a);
                    for (b, &j) in cols.iter().enumerate() {
                        dst[b] = src[j];
                    }
                }
            }
            Kernel::Kron2(l1, l2) => {
                let n2 = l2.rows();
                RSPLIT2.with(|rb| {
                    CSPLIT2.with(|cb| {
                        let (mut rs, mut cs) = (rb.borrow_mut(), cb.borrow_mut());
                        rs.clear();
                        rs.extend(rows.iter().map(|&i| (i / n2, i % n2)));
                        cs.clear();
                        cs.extend(cols.iter().map(|&j| (j / n2, j % n2)));
                        for (r, &(i1, i2)) in rs.iter().enumerate() {
                            let dst = out.row_mut(r);
                            for (c, &(j1, j2)) in cs.iter().enumerate() {
                                dst[c] = l1.get(i1, j1) * l2.get(i2, j2);
                            }
                        }
                    })
                });
            }
            Kernel::Kron3(l1, l2, l3) => {
                let n3 = l3.rows();
                let n23 = l2.rows() * n3;
                let split = |i: usize| {
                    let r = i % n23;
                    (i / n23, r / n3, r % n3)
                };
                RSPLIT3.with(|rb| {
                    CSPLIT3.with(|cb| {
                        let (mut rs, mut cs) = (rb.borrow_mut(), cb.borrow_mut());
                        rs.clear();
                        rs.extend(rows.iter().map(|&i| split(i)));
                        cs.clear();
                        cs.extend(cols.iter().map(|&j| split(j)));
                        for (r, &(i1, i2, i3)) in rs.iter().enumerate() {
                            let dst = out.row_mut(r);
                            for (c, &(j1, j2, j3)) in cs.iter().enumerate() {
                                dst[c] = l1.get(i1, j1) * l2.get(i2, j2) * l3.get(i3, j3);
                            }
                        }
                    })
                });
            }
        }
    }

    /// Materialize the dense `N×N` matrix (small N / tests only).
    pub fn to_dense(&self) -> Matrix {
        match self {
            Kernel::Full(l) => l.clone(),
            Kernel::Kron2(a, b) => kron::kron(a, b),
            Kernel::Kron3(a, b, c) => kron::kron3(a, b, c),
        }
    }

    /// `log det(L + I)` — the DPP normalizer denominator. Structured
    /// kernels use sub-spectra: `det(L₁⊗L₂ + I) = Π_{ij}(1 + λ_i μ_j)`.
    pub fn logdet_l_plus_i(&self) -> Result<f64> {
        match self {
            Kernel::Full(l) => {
                let mut li = l.clone();
                li.add_diag_mut(1.0);
                cholesky::logdet_pd(&li)
            }
            Kernel::Kron2(a, b) => {
                let ea = crate::linalg::eigen::eigvals(a)?;
                let eb = crate::linalg::eigen::eigvals(b)?;
                let mut s = 0.0;
                for &x in &ea {
                    for &y in &eb {
                        let v = 1.0 + x * y;
                        if v <= 0.0 {
                            return Err(Error::Numerical(
                                "logdet(L+I): non-PD Kron spectrum".into(),
                            ));
                        }
                        s += v.ln();
                    }
                }
                Ok(s)
            }
            Kernel::Kron3(a, b, c) => {
                let ea = crate::linalg::eigen::eigvals(a)?;
                let eb = crate::linalg::eigen::eigvals(b)?;
                let ec = crate::linalg::eigen::eigvals(c)?;
                let mut s = 0.0;
                for &x in &ea {
                    for &y in &eb {
                        let xy = x * y;
                        for &z in &ec {
                            let v = 1.0 + xy * z;
                            if v <= 0.0 {
                                return Err(Error::Numerical(
                                    "logdet(L+I): non-PD Kron spectrum".into(),
                                ));
                            }
                            s += v.ln();
                        }
                    }
                }
                Ok(s)
            }
        }
    }

    /// Scan every stored entry for NaN/±inf. `O(N²)` dense,
    /// `O(N₁²+N₂²(+N₃²))` factored — cheap next to an eigensolve, so the
    /// registry runs it on every candidate publish before the epoch build.
    /// The error names the offending factor and `(row, col)` index.
    pub fn validate_finite(&self) -> Result<()> {
        fn scan(label: &str, m: &Matrix) -> Result<()> {
            let cols = m.cols().max(1);
            for (idx, &x) in m.as_slice().iter().enumerate() {
                if !x.is_finite() {
                    return Err(Error::Invalid(format!(
                        "kernel {label}: non-finite entry {x} at ({}, {})",
                        idx / cols,
                        idx % cols
                    )));
                }
            }
            Ok(())
        }
        match self {
            Kernel::Full(l) => scan("L", l),
            Kernel::Kron2(a, b) => {
                scan("L1", a)?;
                scan("L2", b)
            }
            Kernel::Kron3(a, b, c) => {
                scan("L1", a)?;
                scan("L2", b)?;
                scan("L3", c)
            }
        }
    }

    /// A regularized copy `≈ L + εI`: each factor gets `ε` added to its
    /// diagonal (for Kronecker structures `(L₁+εI)⊗(L₂+εI)` — the factored
    /// analogue of diagonal loading, which keeps the structure and lifts
    /// every product eigenvalue `λμ` to `(λ+ε)(μ+ε) > 0` for PSD factors).
    /// The degraded-mode fallback chain uses this to retry a numerically
    /// failing tenant with a slightly loaded spectrum.
    pub fn regularized(&self, eps: f64) -> Kernel {
        let load = |m: &Matrix| {
            let mut out = m.clone();
            out.add_diag_mut(eps);
            out
        };
        match self {
            Kernel::Full(l) => Kernel::Full(load(l)),
            Kernel::Kron2(a, b) => Kernel::Kron2(load(a), load(b)),
            Kernel::Kron3(a, b, c) => Kernel::Kron3(load(a), load(b), load(c)),
        }
    }

    /// Is the kernel PD (all factors PD)?
    pub fn is_pd(&self) -> bool {
        match self {
            Kernel::Full(l) => cholesky::is_pd(l),
            Kernel::Kron2(a, b) => {
                // (PD, PD) or (ND, ND) both give a PD product; we require
                // the canonical PD-factor form.
                cholesky::is_pd(a) && cholesky::is_pd(b)
            }
            Kernel::Kron3(a, b, c) => {
                cholesky::is_pd(a) && cholesky::is_pd(b) && cholesky::is_pd(c)
            }
        }
    }

    /// Eigendecompose, exploiting structure (Cor. 2.2).
    pub fn eigen(&self) -> Result<KernelEigen> {
        let mut scratch = crate::linalg::eigen::SymEigenScratch::new();
        self.eigen_with(&mut scratch)
    }

    /// [`Kernel::eigen`] reusing a caller-held eigensolver scratch (panel,
    /// rotation and GEMM pack buffers) across the per-factor
    /// decompositions — and across repeated kernel assemblies when the
    /// caller keeps the scratch alive (the coordinator's hot-swap path).
    pub fn eigen_with(
        &self,
        scratch: &mut crate::linalg::eigen::SymEigenScratch,
    ) -> Result<KernelEigen> {
        match self {
            Kernel::Full(l) => {
                let e = SymEigen::new_with(l, scratch)?;
                Ok(KernelEigen {
                    values: e.values,
                    factor_values: Vec::new(),
                    vectors: EigenVectors::Dense(e.vectors),
                })
            }
            Kernel::Kron2(a, b) => {
                let ea = SymEigen::new_with(a, scratch)?;
                let eb = SymEigen::new_with(b, scratch)?;
                let values = kron::kron_eigenvalues(&ea.values, &eb.values);
                Ok(KernelEigen {
                    values,
                    factor_values: vec![ea.values, eb.values],
                    vectors: EigenVectors::Kron2 { p1: ea.vectors, p2: eb.vectors },
                })
            }
            Kernel::Kron3(a, b, c) => {
                let ea = SymEigen::new_with(a, scratch)?;
                let eb = SymEigen::new_with(b, scratch)?;
                let ec = SymEigen::new_with(c, scratch)?;
                let inner = kron::kron_eigenvalues(&eb.values, &ec.values);
                let values = kron::kron_eigenvalues(&ea.values, &inner);
                Ok(KernelEigen {
                    values,
                    factor_values: vec![ea.values, eb.values, ec.values],
                    vectors: EigenVectors::Kron3 {
                        p1: ea.vectors,
                        p2: eb.vectors,
                        p3: ec.vectors,
                    },
                })
            }
        }
    }

    /// Marginal kernel `K = L(L+I)⁻¹` (`P(i ∈ Y) = K_ii`) — **small-N test
    /// oracle only**. This materializes the dense `N×N` `L` and inverts
    /// `L+I`, silently costing `O(N²)` memory and `O(N³)` time even for a
    /// Kronecker kernel whose whole point is never to form that matrix.
    /// Production callers that need diagonals or `κ×κ` slate blocks must
    /// use the factored queries instead:
    /// [`KernelEigen::inclusion_probabilities_into`] (all `N` diagonals in
    /// `O(N·(N₁+N₂))`), [`KernelEigen::marginal_entry`] and
    /// [`KernelEigen::marginal_block_into`]. Debug builds assert a size
    /// guard ([`MARGINAL_ORACLE_MAX_N`]) on structured kernels to catch
    /// accidental dense materialization.
    pub fn marginal_kernel(&self) -> Result<Matrix> {
        if !matches!(self, Kernel::Full(_)) {
            debug_assert!(
                self.n() <= MARGINAL_ORACLE_MAX_N,
                "marginal_kernel would materialize a dense {0}×{0} K for a Kronecker \
                 kernel; use the factored KernelEigen marginal queries instead",
                self.n()
            );
        }
        let l = self.to_dense();
        let mut li = l.clone();
        li.add_diag_mut(1.0);
        let inv = cholesky::inverse_pd(&li)?;
        let mut k = matmul::matmul(&l, &inv)?;
        k.symmetrize_mut();
        Ok(k)
    }
}

/// Eigendecomposition of a kernel, with structure-aware vector access.
pub struct KernelEigen {
    /// Eigenvalues in item order for structured kernels (index
    /// `t = i·N₂ + j` pairs `λ_i(L₁)·λ_j(L₂)`), ascending for dense.
    pub values: Vec<f64>,
    /// Per-factor eigenvalue vectors (ascending, paired with the factor
    /// eigenvector matrices of [`EigenVectors::Kron2`]/`Kron3`); empty for
    /// dense kernels. Delta publishing refreshes one factor's spectrum
    /// incrementally and recombines the product grid from these in `O(N)`
    /// — without them the per-factor spectra would be unrecoverable from
    /// the product `values`.
    pub factor_values: Vec<Vec<f64>>,
    /// Eigenvector accessor.
    pub vectors: EigenVectors,
}

/// Eigenvectors of a kernel, stored dense or factored.
pub enum EigenVectors {
    Dense(Matrix),
    Kron2 { p1: Matrix, p2: Matrix },
    Kron3 { p1: Matrix, p2: Matrix, p3: Matrix },
}

impl EigenVectors {
    /// Ground-set size `N` (the length of each eigenvector).
    pub fn dim(&self) -> usize {
        match self {
            EigenVectors::Dense(p) => p.rows(),
            EigenVectors::Kron2 { p1, p2 } => p1.rows() * p2.rows(),
            EigenVectors::Kron3 { p1, p2, p3 } => p1.rows() * p2.rows() * p3.rows(),
        }
    }

    /// Extract eigenvector `idx` as a dense column — `O(N)` for all
    /// structures (the paper's "k eigenvectors in O(kN)" claim, §4).
    pub fn column(&self, idx: usize) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        self.column_into(idx, &mut out);
        out
    }

    /// Write eigenvector `idx` into `out` (length `N`) without allocating —
    /// the batched sampling engine's scratch-reuse gather path.
    pub fn column_into(&self, idx: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.dim());
        match self {
            EigenVectors::Dense(p) => {
                for (i, o) in out.iter_mut().enumerate() {
                    *o = p.get(i, idx);
                }
            }
            EigenVectors::Kron2 { p1, p2 } => {
                let n2 = p2.rows();
                let (c1, c2) = (idx / n2, idx % n2);
                let mut t = 0usize;
                for i in 0..p1.rows() {
                    let a = p1.get(i, c1);
                    for r in 0..n2 {
                        out[t] = a * p2.get(r, c2);
                        t += 1;
                    }
                }
            }
            EigenVectors::Kron3 { p1, p2, p3 } => {
                let n23 = p2.rows() * p3.rows();
                let n3 = p3.rows();
                let (c1, rest) = (idx / n23, idx % n23);
                let (c2, c3) = (rest / n3, rest % n3);
                let mut t = 0usize;
                for i in 0..p1.rows() {
                    let a = p1.get(i, c1);
                    for j in 0..p2.rows() {
                        let ab = a * p2.get(j, c2);
                        for k in 0..p3.rows() {
                            out[t] = ab * p3.get(k, c3);
                            t += 1;
                        }
                    }
                }
            }
        }
    }

    /// Gather columns `idx` into a dense `N×k` matrix.
    pub fn gather(&self, idx: &[usize]) -> Matrix {
        let cols: Vec<Vec<f64>> = idx.iter().map(|&i| self.column(i)).collect();
        let n = cols.first().map(|c| c.len()).unwrap_or(0);
        let mut m = Matrix::zeros(n, idx.len());
        for (j, col) in cols.iter().enumerate() {
            for i in 0..n {
                m.set(i, j, col[i]);
            }
        }
        m
    }
}

/// Reusable workspace for the factored marginal queries: squared
/// eigenvector matrices, the `λ/(1+λ)` weight grid, GEMM staging and pack
/// buffers. Holding one across repeated queries (the registry's epoch
/// builds, the benches) keeps the diagonal sweep allocation-free once
/// capacity suffices.
#[derive(Default)]
pub struct MarginalScratch {
    sq1: Matrix,
    sq2: Matrix,
    sq3: Matrix,
    w: Matrix,
    t1: Matrix,
    t2: Matrix,
    gemm: matmul::GemmScratch,
}

impl MarginalScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// `λ ↦ λ/(1+λ)` with the same tiny-negative clamp the sampler applies to
/// round-off in the factored spectrum. Scalar form of the vectorized
/// [`Kernels::marginal_weights`] grid sweep, used by the per-entry
/// bilinear queries.
#[inline]
fn marginal_weight(lam: f64) -> f64 {
    let l = if lam > 0.0 { lam } else { 0.0 };
    l / (1.0 + l)
}

/// `out[i][t] = p[i][t]²` (resized in place), via the dispatched kernel.
fn square_into(p: &Matrix, out: &mut Matrix, kern: &Kernels) {
    out.resize_zeroed(p.rows(), p.cols());
    kern.square_into(out.as_mut_slice(), p.as_slice());
}

impl KernelEigen {
    /// Number of eigenpairs.
    pub fn n(&self) -> usize {
        self.values.len()
    }

    /// All `N` inclusion probabilities `P(i ∈ Y) = K_ii` (allocating
    /// convenience for [`KernelEigen::inclusion_probabilities_into`]).
    pub fn inclusion_probabilities(&self) -> Vec<f64> {
        let mut out = Vec::new();
        self.inclusion_probabilities_into(&mut out, &mut MarginalScratch::new());
        out
    }

    /// Write all `N` diagonals of `K = L(L+I)⁻¹` into `out` **without ever
    /// forming `K`**. Because `K_ii = Σ_t w_t v_t[i]²` with
    /// `w_t = λ_t/(1+λ_t)` and factored eigenvectors square factor-wise
    /// (`v_t[i]² = p₁[i₁,t₁]²·p₂[i₂,t₂]²`, Cor. 2.2), the whole diagonal is
    ///
    /// ```text
    /// m = 2:  diag(K) = (P₁∘P₁) · W · (P₂∘P₂)ᵀ          (N₁×N₂ grid)
    /// m = 3:  one more squared-GEMM pass per i₁ block    (N₂×N₃ grids)
    /// ```
    ///
    /// — two GEMMs over squared eigenvector matrices against the
    /// `λ/(1+λ)` grid `W`: `O(N·(N₁+N₂))` for m=2, `O(N·(N₁+N₂+N₃))` for
    /// m=3, versus `O(N³)` for the dense oracle. Item order matches the
    /// kernel's (`i = i₁·N₂ + i₂`), so `out[i]` is item `i`'s probability.
    pub fn inclusion_probabilities_into(&self, out: &mut Vec<f64>, s: &mut MarginalScratch) {
        self.inclusion_probabilities_into_with(out, s, simd::active())
    }

    /// [`KernelEigen::inclusion_probabilities_into`] pinned to an explicit
    /// dispatch arm — the conformance tests and benches compare the
    /// forced-scalar oracle against the detected kernel through this seam.
    /// The dispatch is resolved once here; the grid sweeps and GEMMs below
    /// only make direct fn-pointer calls.
    pub fn inclusion_probabilities_into_with(
        &self,
        out: &mut Vec<f64>,
        s: &mut MarginalScratch,
        kern: &Kernels,
    ) {
        let n = self.values.len();
        out.clear();
        out.resize(n, 0.0);
        match &self.vectors {
            EigenVectors::Dense(p) => {
                // K_ii = Σ_t w_t P[i,t]² — one vectorized weight grid,
                // then one weighted-sum-of-squares row sweep per item.
                s.w.resize_zeroed(1, n);
                kern.marginal_weights(s.w.as_mut_slice(), &self.values);
                let w = s.w.as_slice();
                for (i, o) in out.iter_mut().enumerate() {
                    *o = kern.weighted_sumsq(w, p.row(i));
                }
            }
            EigenVectors::Kron2 { p1, p2 } => {
                let (n1, n2) = (p1.rows(), p2.rows());
                square_into(p1, &mut s.sq1, kern);
                square_into(p2, &mut s.sq2, kern);
                s.w.resize_zeroed(n1, n2);
                kern.marginal_weights(s.w.as_mut_slice(), &self.values);
                s.t1.resize_zeroed(n1, n2);
                matmul::gemm_into_with(
                    s.t1.view_mut(),
                    1.0,
                    s.sq1.view(),
                    s.w.view(),
                    false,
                    &mut s.gemm,
                    kern,
                );
                let grid = MatMut::from_parts(out, n1, n2, n2, 1);
                matmul::gemm_into_with(
                    grid,
                    1.0,
                    s.t1.view(),
                    s.sq2.view().t(),
                    false,
                    &mut s.gemm,
                    kern,
                );
            }
            EigenVectors::Kron3 { p1, p2, p3 } => {
                let (n1, n2, n3) = (p1.rows(), p2.rows(), p3.rows());
                let n23 = n2 * n3;
                square_into(p1, &mut s.sq1, kern);
                square_into(p2, &mut s.sq2, kern);
                square_into(p3, &mut s.sq3, kern);
                s.w.resize_zeroed(n1, n23);
                kern.marginal_weights(s.w.as_mut_slice(), &self.values);
                s.t1.resize_zeroed(n1, n23);
                matmul::gemm_into_with(
                    s.t1.view_mut(),
                    1.0,
                    s.sq1.view(),
                    s.w.view(),
                    false,
                    &mut s.gemm,
                    kern,
                );
                s.t2.resize_zeroed(n2, n3);
                for i1 in 0..n1 {
                    // Row i1 of t1, reshaped to an N₂×N₃ grid over (t₂,t₃).
                    let g = MatRef::from_parts(s.t1.row(i1), n2, n3, n3, 1);
                    matmul::gemm_into_with(
                        s.t2.view_mut(),
                        1.0,
                        s.sq2.view(),
                        g,
                        false,
                        &mut s.gemm,
                        kern,
                    );
                    let blk =
                        MatMut::from_parts(&mut out[i1 * n23..(i1 + 1) * n23], n2, n3, n3, 1);
                    matmul::gemm_into_with(
                        blk,
                        1.0,
                        s.t2.view(),
                        s.sq3.view().t(),
                        false,
                        &mut s.gemm,
                        kern,
                    );
                }
            }
        }
    }

    /// One entry `K_ij` of the marginal kernel, factored:
    /// `K_ij = Σ_t w_t v_t[i] v_t[j]` collapses to a bilinear form
    /// `aᵀ W b` over per-factor eigenvector products (`O(N)` per entry for
    /// Kron2/Kron3, `O(N)` for dense) — no `N×N` intermediate.
    pub fn marginal_entry(&self, i: usize, j: usize) -> f64 {
        use std::cell::RefCell;
        thread_local! {
            static STAGE: RefCell<(Vec<f64>, Vec<f64>, Vec<f64>)> =
                RefCell::new((Vec::new(), Vec::new(), Vec::new()));
        }
        match &self.vectors {
            EigenVectors::Dense(p) => {
                let (ri, rj) = (p.row(i), p.row(j));
                let mut acc = 0.0;
                for (t, (&a, &b)) in ri.iter().zip(rj).enumerate() {
                    acc += marginal_weight(self.values[t]) * a * b;
                }
                acc
            }
            EigenVectors::Kron2 { p1, p2 } => {
                let n2 = p2.rows();
                let (i1, i2) = (i / n2, i % n2);
                let (j1, j2) = (j / n2, j % n2);
                let kern = simd::active();
                STAGE.with(|st| {
                    let (a, b, _) = &mut *st.borrow_mut();
                    fill_products(p1, i1, j1, a, kern);
                    fill_products(p2, i2, j2, b, kern);
                    let mut acc = 0.0;
                    for (t1, &av) in a.iter().enumerate() {
                        if av == 0.0 {
                            continue;
                        }
                        let vals = &self.values[t1 * n2..(t1 + 1) * n2];
                        let mut inner = 0.0;
                        for (&bv, &lam) in b.iter().zip(vals) {
                            inner += bv * marginal_weight(lam);
                        }
                        acc += av * inner;
                    }
                    acc
                })
            }
            EigenVectors::Kron3 { p1, p2, p3 } => {
                let (n2, n3) = (p2.rows(), p3.rows());
                let n23 = n2 * n3;
                let (i1, ir) = (i / n23, i % n23);
                let (j1, jr) = (j / n23, j % n23);
                let (i2, i3) = (ir / n3, ir % n3);
                let (j2, j3) = (jr / n3, jr % n3);
                let kern = simd::active();
                STAGE.with(|st| {
                    let (a, b, c) = &mut *st.borrow_mut();
                    fill_products(p1, i1, j1, a, kern);
                    fill_products(p2, i2, j2, b, kern);
                    fill_products(p3, i3, j3, c, kern);
                    let mut acc = 0.0;
                    for (t1, &av) in a.iter().enumerate() {
                        if av == 0.0 {
                            continue;
                        }
                        for (t2, &bv) in b.iter().enumerate() {
                            let ab = av * bv;
                            if ab == 0.0 {
                                continue;
                            }
                            let base = t1 * n23 + t2 * n3;
                            let vals = &self.values[base..base + n3];
                            let mut inner = 0.0;
                            for (&cv, &lam) in c.iter().zip(vals) {
                                inner += cv * marginal_weight(lam);
                            }
                            acc += ab * inner;
                        }
                    }
                    acc
                })
            }
        }
    }

    /// Gather the `κ×κ` marginal block `K[idx, idx]` into a caller-held
    /// buffer — `κ²` factored [`KernelEigen::marginal_entry`] evaluations
    /// (symmetry halves the work), so a slate probability
    /// `P(A ⊆ Y) = det(K_A)` costs `O(κ²·N) + O(κ³)` instead of the dense
    /// oracle's `O(N³)`.
    pub fn marginal_block_into(&self, idx: &[usize], out: &mut Matrix) {
        let k = idx.len();
        out.resize_zeroed(k, k);
        for (a, &i) in idx.iter().enumerate() {
            for (b, &j) in idx.iter().enumerate().skip(a) {
                let v = self.marginal_entry(i, j);
                out.set(a, b, v);
                out.set(b, a, v);
            }
        }
    }

    /// Allocating convenience for [`KernelEigen::marginal_block_into`].
    pub fn marginal_block(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(0, 0);
        self.marginal_block_into(idx, &mut out);
        out
    }

    /// Slate inclusion probability `P(A ⊆ Y) = det(K_A)` through the
    /// factored block gather.
    pub fn subset_inclusion_probability(&self, idx: &[usize]) -> Result<f64> {
        if idx.is_empty() {
            return Ok(1.0);
        }
        let block = self.marginal_block(idx);
        crate::linalg::lu::det(&block)
    }
}

/// `out[t] = p[i,t]·p[j,t]` — the per-factor eigenvector product vector of
/// the bilinear marginal-entry form, via the dispatched kernel.
fn fill_products(p: &Matrix, i: usize, j: usize, out: &mut Vec<f64>, kern: &Kernels) {
    out.clear();
    out.resize(p.cols(), 0.0);
    kern.mul_into(out, p.row(i), p.row(j));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = rng.paper_init_kernel(n);
        m.scale_mut(1.0 / n as f64);
        m.add_diag_mut(0.1);
        m
    }

    #[test]
    fn entry_matches_dense() {
        let a = spd(3, 1);
        let b = spd(4, 2);
        let k = Kernel::Kron2(a.clone(), b.clone());
        let dense = k.to_dense();
        for i in 0..12 {
            for j in 0..12 {
                assert!((k.entry(i, j) - dense[(i, j)]).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn validate_finite_names_the_factor_and_index() {
        let clean = Kernel::Kron2(spd(3, 1), spd(4, 2));
        clean.validate_finite().unwrap();
        let mut b = spd(4, 2);
        b.set(2, 1, f64::NAN);
        let poisoned = Kernel::Kron2(spd(3, 1), b);
        let msg = poisoned.validate_finite().unwrap_err().to_string();
        assert!(msg.contains("L2") && msg.contains("(2, 1)"), "{msg}");
        let mut l = spd(5, 3);
        l.set(0, 4, f64::INFINITY);
        let msg = Kernel::Full(l).validate_finite().unwrap_err().to_string();
        assert!(msg.contains("(0, 4)"), "{msg}");
    }

    #[test]
    fn regularized_loads_every_factor_diagonal() {
        let k = Kernel::Kron2(spd(3, 7), spd(2, 8));
        let r = k.regularized(0.5);
        match (&k, &r) {
            (Kernel::Kron2(a, b), Kernel::Kron2(ra, rb)) => {
                for i in 0..3 {
                    assert!((ra.get(i, i) - a.get(i, i) - 0.5).abs() < 1e-15);
                }
                for i in 0..2 {
                    assert!((rb.get(i, i) - b.get(i, i) - 0.5).abs() < 1e-15);
                    assert_eq!(rb.get(0, 1), b.get(0, 1));
                }
            }
            _ => panic!("structure changed"),
        }
        // Loading strictly raises the smallest product eigenvalue.
        let lo = |k: &Kernel| {
            k.eigen().unwrap().values.iter().cloned().fold(f64::INFINITY, f64::min)
        };
        assert!(lo(&r) > lo(&k));
    }

    #[test]
    fn entry_kron3_matches_dense() {
        let a = spd(2, 3);
        let b = spd(3, 4);
        let c = spd(2, 5);
        let k = Kernel::Kron3(a, b, c);
        let dense = k.to_dense();
        for i in 0..12 {
            for j in 0..12 {
                assert!((k.entry(i, j) - dense[(i, j)]).abs() < 1e-14, "({i},{j})");
            }
        }
    }

    #[test]
    fn submatrix_matches_dense() {
        let k = Kernel::Kron2(spd(3, 5), spd(4, 6));
        let idx = [0usize, 3, 7, 11];
        let sub = k.principal_submatrix(&idx);
        let dense_sub = k.to_dense().principal_submatrix(&idx);
        assert!(sub.rel_diff(&dense_sub) < 1e-13);
    }

    #[test]
    fn submatrix_kron3_matches_dense() {
        let k = Kernel::Kron3(spd(2, 30), spd(3, 31), spd(2, 32));
        // Duplicates, unsorted order, boundary indices all exercise the
        // precomputed split path.
        for idx in [vec![0usize, 5, 11], vec![11, 0, 4, 4, 7], vec![6]] {
            let sub = k.principal_submatrix(&idx);
            let dense_sub = k.to_dense().principal_submatrix(&idx);
            assert!(sub.rel_diff(&dense_sub) < 1e-13, "idx {idx:?}");
        }
    }

    #[test]
    fn submatrix_entrywise_against_entry_oracle() {
        // The split-precompute path must agree with Kernel::entry exactly
        // (same factor products, bitwise).
        let k2 = Kernel::Kron2(spd(3, 33), spd(4, 34));
        let k3 = Kernel::Kron3(spd(2, 35), spd(2, 36), spd(3, 37));
        for kern in [&k2, &k3] {
            let idx = [1usize, 2, 5, 10, 11];
            let sub = kern.principal_submatrix(&idx);
            for (a, &i) in idx.iter().enumerate() {
                for (b, &j) in idx.iter().enumerate() {
                    assert_eq!(sub[(a, b)], kern.entry(i, j), "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn logdet_structured_matches_dense() {
        let k = Kernel::Kron2(spd(4, 7), spd(5, 8));
        let fast = k.logdet_l_plus_i().unwrap();
        let mut dense = k.to_dense();
        dense.add_diag_mut(1.0);
        let slow = cholesky::logdet_pd(&dense).unwrap();
        assert!((fast - slow).abs() < 1e-8, "{fast} vs {slow}");
    }

    #[test]
    fn logdet_kron3_matches_dense() {
        let k = Kernel::Kron3(spd(2, 9), spd(3, 10), spd(2, 11));
        let fast = k.logdet_l_plus_i().unwrap();
        let mut dense = k.to_dense();
        dense.add_diag_mut(1.0);
        let slow = cholesky::logdet_pd(&dense).unwrap();
        assert!((fast - slow).abs() < 1e-8);
    }

    #[test]
    fn eigen_factored_matches_dense_spectrum() {
        let k = Kernel::Kron2(spd(3, 12), spd(4, 13));
        let mut fast = k.eigen().unwrap().values;
        fast.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let slow = SymEigen::new(&k.to_dense()).unwrap().values;
        for (p, q) in fast.iter().zip(&slow) {
            assert!((p - q).abs() < 1e-9);
        }
    }

    #[test]
    fn eigen_columns_are_eigenvectors() {
        let k = Kernel::Kron2(spd(3, 14), spd(3, 15));
        let eig = k.eigen().unwrap();
        let dense = k.to_dense();
        for t in [0usize, 4, 8] {
            let v = eig.vectors.column(t);
            let av = dense.matvec(&v).unwrap();
            let lam = eig.values[t];
            let res: f64 =
                av.iter().zip(&v).map(|(p, q)| (p - lam * q).powi(2)).sum::<f64>().sqrt();
            assert!(res < 1e-9, "col {t}: residual {res}");
        }
    }

    #[test]
    fn gather_builds_matrix() {
        let k = Kernel::Kron2(spd(2, 16), spd(3, 17));
        let eig = k.eigen().unwrap();
        let m = eig.vectors.gather(&[1, 3]);
        assert_eq!(m.shape(), (6, 2));
        let c1 = eig.vectors.column(3);
        for i in 0..6 {
            assert_eq!(m[(i, 1)], c1[i]);
        }
    }

    #[test]
    fn marginal_kernel_diag_are_probabilities() {
        let k = Kernel::Kron2(spd(3, 18), spd(3, 19));
        let marg = k.marginal_kernel().unwrap();
        for i in 0..9 {
            let p = marg[(i, i)];
            assert!((0.0..=1.0).contains(&p), "K_ii = {p}");
        }
    }

    #[test]
    fn cross_submatrix_matches_entry_oracle() {
        let k2 = Kernel::Kron2(spd(3, 50), spd(4, 51));
        let k3 = Kernel::Kron3(spd(2, 52), spd(3, 53), spd(2, 54));
        let kf = Kernel::Full(spd(12, 55));
        let mut out = Matrix::zeros(0, 0);
        for kern in [&k2, &k3, &kf] {
            let rows = [1usize, 7, 7, 0];
            let cols = [11usize, 2, 5];
            kern.cross_submatrix_into(&rows, &cols, &mut out);
            assert_eq!(out.shape(), (4, 3));
            for (a, &i) in rows.iter().enumerate() {
                for (b, &j) in cols.iter().enumerate() {
                    assert_eq!(out[(a, b)], kern.entry(i, j), "({i},{j})");
                }
            }
            // Rows == cols reduces to the principal submatrix.
            kern.cross_submatrix_into(&cols, &cols, &mut out);
            assert_eq!(out, kern.principal_submatrix(&cols));
        }
    }

    #[test]
    fn factored_inclusion_probabilities_match_dense_oracle() {
        let kernels = [
            Kernel::Kron2(spd(4, 60), spd(5, 61)),
            Kernel::Kron3(spd(3, 62), spd(2, 63), spd(3, 64)),
            Kernel::Full(spd(10, 65)),
        ];
        for k in &kernels {
            let eig = k.eigen().unwrap();
            let fast = eig.inclusion_probabilities();
            let dense = k.marginal_kernel().unwrap();
            assert_eq!(fast.len(), k.n());
            for (i, &p) in fast.iter().enumerate() {
                assert!(
                    (p - dense[(i, i)]).abs() < 1e-12,
                    "item {i}: factored {p} vs dense {}",
                    dense[(i, i)]
                );
            }
        }
    }

    #[test]
    fn inclusion_probabilities_into_reuses_buffers_across_kernels() {
        // Same scratch, different structures and sizes: results must match
        // the allocating path exactly.
        let mut scratch = MarginalScratch::new();
        let mut out = Vec::new();
        for k in [
            Kernel::Kron2(spd(3, 66), spd(4, 67)),
            Kernel::Kron3(spd(2, 68), spd(2, 69), spd(2, 70)),
            Kernel::Kron2(spd(5, 71), spd(2, 72)),
        ] {
            let eig = k.eigen().unwrap();
            eig.inclusion_probabilities_into(&mut out, &mut scratch);
            assert_eq!(out, eig.inclusion_probabilities());
        }
    }

    #[test]
    fn marginal_entry_and_block_match_dense_oracle() {
        let kernels = [
            Kernel::Kron2(spd(3, 73), spd(4, 74)),
            Kernel::Kron3(spd(2, 75), spd(3, 76), spd(2, 77)),
            Kernel::Full(spd(9, 78)),
        ];
        for k in &kernels {
            let eig = k.eigen().unwrap();
            let dense = k.marginal_kernel().unwrap();
            let n = k.n();
            for i in 0..n {
                for j in 0..n {
                    let e = eig.marginal_entry(i, j);
                    assert!(
                        (e - dense[(i, j)]).abs() < 1e-12,
                        "K[{i},{j}]: factored {e} vs dense {}",
                        dense[(i, j)]
                    );
                }
            }
            let idx = [0usize, 2, 5, n - 1];
            let block = eig.marginal_block(&idx);
            let dense_block = dense.principal_submatrix(&idx);
            assert!(block.rel_diff(&dense_block) < 1e-12);
            // P(A ⊆ Y) = det(K_A) stays a probability.
            let p = eig.subset_inclusion_probability(&idx).unwrap();
            let oracle = crate::linalg::lu::det(&dense_block).unwrap();
            assert!((p - oracle).abs() < 1e-12);
            assert!((0.0..=1.0 + 1e-12).contains(&p), "det K_A = {p}");
            assert_eq!(eig.subset_inclusion_probability(&[]).unwrap(), 1.0);
        }
    }

    #[test]
    fn param_count_savings() {
        let k = Kernel::Kron2(Matrix::identity(100), Matrix::identity(100));
        assert_eq!(k.n(), 10_000);
        assert_eq!(k.param_count(), 20_000); // vs 10^8 dense
    }

    #[test]
    fn is_pd_checks_factors() {
        assert!(Kernel::Kron2(spd(3, 20), spd(3, 21)).is_pd());
        let mut bad = spd(3, 22);
        bad.set(0, 0, -5.0);
        assert!(!Kernel::Kron2(bad, spd(3, 23)).is_pd());
    }
}
