//! Greedy MAP inference: logdet-maximizing slate construction.
//!
//! Production slate serving frequently wants *the* best diverse subset
//! rather than a random draw. Exact MAP for a DPP is NP-hard, but
//! `f(Y) = log det(L_Y)` is submodular, so the classic greedy ascent —
//! repeatedly add the item with the largest marginal determinant gain —
//! carries the usual `(1 − 1/e)` guarantee for cardinality-constrained
//! maximization whenever `f` is monotone on the relevant range (all
//! eigenvalues of `L` at least one), and is exactly optimal on diagonal
//! kernels.
//!
//! The implementation is the *fast greedy* scheme built on the same
//! incremental-Cholesky ratio machinery as the MCMC chain
//! ([`crate::dpp::mcmc`]): with `S` the current slate and `F` the
//! maintained Cholesky factor of `L_S`, the marginal gain of item `i` is
//! the Schur complement `d_i = L_ii − ‖c_i‖²` where `F·c_i = L_{S,i}`.
//! Instead of re-solving for every candidate each round (`O(Nκ²)` per
//! step), every candidate's solve row `c_i` is maintained *incrementally*:
//! when item `j` with gain `d_j` is accepted, each candidate's row grows by
//! one entry
//!
//! ```text
//!   e_i = (L_ij − ⟨c_i, c_j⟩) / √d_j ,   d_i ← d_i − e_i² ,
//! ```
//!
//! one `O(κ)` inner product per candidate — `O(Nκ)` per greedy step and
//! `O(Nκ²)` for a whole slate, with `O(Nκ)` scratch. Kronecker kernels
//! feed this through their `O(1)` [`Kernel::entry`] so no dense `N×N` is
//! ever formed.
//!
//! Constraints ride along naturally: `include` items are seeded as forced
//! first picks through the identical update (a non-PD seed surfaces as
//! [`Error::Invalid`], mirroring conditioning's zero-probability check),
//! `exclude` items are retired before the first scan. All buffers live in
//! a caller-held [`MapScratch`], so warmed calls are allocation-free
//! (asserted by `tests/alloc_free.rs`, region D).

use crate::dpp::condition::Constraint;
use crate::dpp::kernel::Kernel;
use crate::error::{Error, Result};
use crate::{invalid_err, num_err};

/// Gains at or below this floor are treated as a numerically singular
/// extension (the greedy analogue of "the subset has zero probability").
const PD_FLOOR: f64 = 1e-12;

/// Caller-held buffers for [`map_slate_into`] — sized `O(N·κ_max)`, grown
/// once and reused across calls.
#[derive(Default)]
pub struct MapScratch {
    /// Row-major candidate solve rows: row `i` holds `c_i = F⁻¹·L_{S,i}`
    /// (valid prefix length = current slate size, stride = `κ_max`).
    ci: Vec<f64>,
    /// Current marginal determinant gain per item (`−∞` marks selected or
    /// excluded items).
    gain: Vec<f64>,
    /// Copy of the accepted item's solve row, read while other rows are
    /// being written.
    cj: Vec<f64>,
}

impl MapScratch {
    pub fn new() -> Self {
        MapScratch::default()
    }
}

/// Greedy MAP slate of exactly `k` items (unconstrained convenience
/// wrapper). Returns the sorted slate.
pub fn map_slate(kernel: &Kernel, k: usize) -> Result<Vec<usize>> {
    map_slate_constrained(kernel, Some(k), &Constraint::none())
}

/// Greedy MAP slate with the size chosen by the gain rule: items are added
/// while the best marginal gain exceeds one (adding multiplies `det(L_S)`
/// by the gain, so gains above one improve the objective relative to
/// `det(L_∅) = 1`).
pub fn map_slate_auto(kernel: &Kernel) -> Result<Vec<usize>> {
    map_slate_constrained(kernel, None, &Constraint::none())
}

/// Constraint-aware greedy MAP: `include` items are forced into the slate,
/// `exclude` items are never selected; `k = None` uses the auto-size gain
/// rule over the remaining candidates.
pub fn map_slate_constrained(
    kernel: &Kernel,
    k: Option<usize>,
    constraint: &Constraint,
) -> Result<Vec<usize>> {
    let mut scratch = MapScratch::new();
    let mut out = Vec::new();
    map_slate_into(kernel, k, constraint, &mut scratch, &mut out)?;
    Ok(out)
}

/// Core allocation-free entry point: greedy MAP into a caller-held result
/// buffer. Returns `log det(L_S)` of the constructed slate (the sum of
/// log-gains; `0.0` for the empty slate).
///
/// Errors: [`Error::Invalid`] if the constraint is malformed for this
/// ground set / slate size or the include set is numerically singular;
/// [`Error::Numerical`] if a forced extension hits a non-PD direction.
pub fn map_slate_into(
    kernel: &Kernel,
    k: Option<usize>,
    constraint: &Constraint,
    scratch: &mut MapScratch,
    out: &mut Vec<usize>,
) -> Result<f64> {
    let n = kernel.n();
    match k {
        Some(k) => {
            constraint.validate_k(k, n)?;
            if k > n {
                return Err(invalid_err!("map: slate size {k} exceeds ground set {n}"));
            }
        }
        None => constraint.validate(n)?,
    }
    let include = constraint.include();
    // Upper bound on the slate length — the candidate rows' stride.
    let kmax = match k {
        Some(k) => k,
        None => n - constraint.exclude().len(),
    };
    out.clear();
    if kmax == 0 {
        return Ok(0.0);
    }

    scratch.gain.clear();
    scratch.gain.resize(n, 0.0);
    for i in 0..n {
        scratch.gain[i] = kernel.entry(i, i);
    }
    for &b in constraint.exclude() {
        scratch.gain[b] = f64::NEG_INFINITY;
    }
    scratch.ci.resize(n * kmax, 0.0);
    scratch.cj.clear();
    scratch.cj.resize(kmax, 0.0);

    let mut logdet = 0.0;
    let mut t = 0usize; // current slate size
    loop {
        // Pick the next item: forced includes first, then greedy argmax.
        let j = if t < include.len() {
            include[t]
        } else {
            if let Some(k) = k {
                if t >= k {
                    break;
                }
            }
            let mut best = usize::MAX;
            let mut best_gain = f64::NEG_INFINITY;
            for i in 0..n {
                let g = scratch.gain[i];
                if g > best_gain {
                    best_gain = g;
                    best = i;
                }
            }
            if best == usize::MAX || !best_gain.is_finite() {
                break; // no candidates left (auto-size exhausted the pool)
            }
            if k.is_none() && best_gain <= 1.0 {
                break; // gain rule: extension no longer improves det
            }
            best
        };

        let d = scratch.gain[j];
        if !(d > PD_FLOOR) {
            if t < include.len() {
                return Err(Error::Invalid(
                    "map: include set has zero probability (L_A not PD)".into(),
                ));
            }
            return Err(num_err!(
                "map: kernel not numerically PD on forced extension (gain {d:.3e} at item {j})"
            ));
        }
        logdet += d.ln();
        out.push(j);
        scratch.gain[j] = f64::NEG_INFINITY;
        // Snapshot c_j, then grow every surviving candidate's row by one
        // entry and downdate its gain — O(κ) per candidate.
        let row_j = j * kmax;
        for s in 0..t {
            scratch.cj[s] = scratch.ci[row_j + s];
        }
        let root = d.sqrt();
        for i in 0..n {
            if !scratch.gain[i].is_finite() {
                continue;
            }
            let row = i * kmax;
            let mut dot = 0.0;
            for s in 0..t {
                dot += scratch.ci[row + s] * scratch.cj[s];
            }
            let e = (kernel.entry(i, j) - dot) / root;
            scratch.ci[row + t] = e;
            scratch.gain[i] -= e * e;
        }
        t += 1;
        if t == kmax {
            break;
        }
    }
    out.sort_unstable();
    Ok(logdet)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{lu, Matrix};
    use crate::rng::Rng;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = rng.paper_init_kernel(n);
        m.scale_mut(1.5 / n as f64);
        m.add_diag_mut(0.4);
        m
    }

    #[test]
    fn diagonal_kernel_picks_top_k_entries() {
        let l = Matrix::diag(&[0.5, 3.0, 1.2, 0.1, 2.0, 0.9]);
        let kernel = Kernel::Full(l);
        assert_eq!(map_slate(&kernel, 3).unwrap(), vec![1, 2, 4]);
        // Auto-size keeps exactly the entries above one.
        assert_eq!(map_slate_auto(&kernel).unwrap(), vec![1, 2, 4]);
    }

    #[test]
    fn returned_logdet_matches_dense_determinant() {
        let kernel = Kernel::Kron2(spd(3, 1), spd(3, 2));
        let mut scratch = MapScratch::new();
        let mut out = Vec::new();
        for k in 1..=5usize {
            let ld =
                map_slate_into(&kernel, Some(k), &Constraint::none(), &mut scratch, &mut out)
                    .unwrap();
            assert_eq!(out.len(), k);
            let direct = lu::det(&kernel.principal_submatrix(&out)).unwrap().ln();
            assert!((ld - direct).abs() < 1e-9, "k={k}: {ld} vs {direct}");
        }
    }

    #[test]
    fn constraints_are_respected() {
        let kernel = Kernel::Kron2(spd(3, 3), spd(3, 4));
        let c = Constraint::new(vec![2, 7], vec![0, 5]).unwrap();
        let slate = map_slate_constrained(&kernel, Some(4), &c).unwrap();
        assert_eq!(slate.len(), 4);
        assert!(slate.contains(&2) && slate.contains(&7));
        assert!(!slate.contains(&0) && !slate.contains(&5));
        assert!(slate.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn singular_include_set_is_invalid() {
        // Rank-2 kernel: any three forced items have zero probability.
        let mut rng = Rng::new(9);
        let g = rng.normal_matrix(5, 2);
        let mut l = Matrix::zeros(5, 5);
        for i in 0..5 {
            for j in 0..5 {
                let mut v = 0.0;
                for t in 0..2 {
                    v += g.get(i, t) * g.get(j, t);
                }
                l.set(i, j, v);
            }
        }
        let kernel = Kernel::Full(l);
        let c = Constraint::including(vec![0, 1, 2]).unwrap();
        match map_slate_constrained(&kernel, Some(3), &c) {
            Err(Error::Invalid(msg)) => assert!(msg.contains("zero probability"), "{msg}"),
            other => panic!("expected Invalid, got {other:?}"),
        }
    }

    #[test]
    fn oversized_and_undersized_slates_are_invalid() {
        let kernel = Kernel::Kron2(spd(2, 5), spd(2, 6));
        assert!(map_slate(&kernel, 5).is_err());
        let c = Constraint::including(vec![0, 1]).unwrap();
        assert!(map_slate_constrained(&kernel, Some(1), &c).is_err());
    }

    #[test]
    fn scratch_reuse_matches_fresh_scratch() {
        let kernel = Kernel::Kron3(spd(2, 7), spd(2, 8), spd(3, 9));
        let mut scratch = MapScratch::new();
        let mut out = Vec::new();
        for k in [4usize, 2, 6, 1] {
            map_slate_into(&kernel, Some(k), &Constraint::none(), &mut scratch, &mut out)
                .unwrap();
            assert_eq!(out, map_slate(&kernel, k).unwrap(), "k={k} diverged under reuse");
        }
    }
}
