//! Kernel deltas — the unit of incremental catalog churn.
//!
//! A [`KernelDelta`] describes a small change to one factor of a tenant's
//! kernel: an item joining or leaving the catalog (a row/column of `L₁` or
//! `L₂` — one factor row is a whole slice of the Kronecker ground set), an
//! item being *retired* (its kernel row damped toward zero so it stops
//! being sampled, without a dimension change), or a general rank-r
//! symmetric perturbation (the shape of a learner's compressed minibatch
//! step).
//!
//! Two views of every delta:
//!
//! - [`KernelDelta::apply`] — the exact dense application, producing the
//!   post-delta [`Kernel`]. This is the ground truth: the registry always
//!   advances the tenant's stored kernel through it, so the kernel a
//!   forced exact republish refactorizes is bit-identical to the one the
//!   incremental path approximated.
//! - [`KernelDelta::as_perturbation`] — the same change expressed as
//!   `Σ_k ρ_k v_k v_kᵀ` on one factor, feeding
//!   [`crate::linalg::eigen_update::refresh_into`]. Dimension-changing
//!   deltas have no such form ([`KernelDelta::is_structural`]) and force
//!   an exact rebuild.
//!
//! Retiring item `i` with damping `α` is the congruence `D·L·D` with
//! `D = diag(1,…,α,…,1)`, which is *exactly* rank-2:
//! `ΔL = e_i·bᵀ + b·e_iᵀ` with `b = (α−1)·L[:,i] + ½(α−1)²·L_ii·e_i`,
//! split symmetrically as `+½(e_i+b)(e_i+b)ᵀ − ½(e_i−b)(e_i−b)ᵀ`
//! (verified against the dense congruence in the tests).

use crate::error::{Error, Result};
use crate::linalg::matrix::Matrix;

use super::kernel::Kernel;

/// A low-rank or structural change to one factor of a kernel.
#[derive(Clone, Debug)]
pub enum KernelDelta {
    /// Append an item to factor `side`: `row[j] = L(new, j)` against the
    /// existing items, `diag = L(new, new)`. Structural (dimension grows).
    AddItem {
        /// Which factor (0-based; 0 = `L₁`, dense kernels have only 0).
        side: usize,
        /// Off-diagonal couplings to the existing items (length `n_side`).
        row: Vec<f64>,
        /// New diagonal entry (item quality mass; must be positive).
        diag: f64,
    },
    /// Delete item `index` from factor `side` (row and column removed).
    /// Structural (dimension shrinks).
    RemoveItem {
        /// Which factor.
        side: usize,
        /// Item row to delete.
        index: usize,
    },
    /// Damp item `index`'s row/column by `damping ∈ [0, 1]` — the
    /// soft-removal that keeps dimensions (and downstream item ids)
    /// stable. `0` silences the item completely; rank-2 incremental.
    RetireItem {
        /// Which factor.
        side: usize,
        /// Item row to damp.
        index: usize,
        /// Scale applied to the row/column (`L' = D·L·D`).
        damping: f64,
    },
    /// General rank-r symmetric perturbation of factor `side`:
    /// `L' = L + Σ_k rhos[k]·vectors[:,k]·vectors[:,k]ᵀ` — the compressed
    /// form of a learner's minibatch step.
    Perturb {
        /// Which factor.
        side: usize,
        /// Signed coefficients, one per column of `vectors`.
        rhos: Vec<f64>,
        /// Perturbation directions (`n_side × r`).
        vectors: Matrix,
    },
}

/// Borrow factor `side` of a kernel (dense kernels expose factor 0).
fn factor(kernel: &Kernel, side: usize) -> Result<&Matrix> {
    let got = match kernel {
        Kernel::Full(l) => [Some(l), None, None][side.min(2)],
        Kernel::Kron2(a, b) => [Some(a), Some(b), None][side.min(2)],
        Kernel::Kron3(a, b, c) => [Some(a), Some(b), Some(c)][side.min(2)],
    };
    got.ok_or_else(|| {
        Error::Invalid(format!("delta: factor {side} out of range for this kernel"))
    })
}

/// Rebuild a kernel with factor `side` replaced.
fn with_factor(kernel: &Kernel, side: usize, new: Matrix) -> Kernel {
    match (kernel, side) {
        (Kernel::Full(_), _) => Kernel::Full(new),
        (Kernel::Kron2(_, b), 0) => Kernel::Kron2(new, b.clone()),
        (Kernel::Kron2(a, _), _) => Kernel::Kron2(a.clone(), new),
        (Kernel::Kron3(_, b, c), 0) => Kernel::Kron3(new, b.clone(), c.clone()),
        (Kernel::Kron3(a, _, c), 1) => Kernel::Kron3(a.clone(), new, c.clone()),
        (Kernel::Kron3(a, b, _), _) => Kernel::Kron3(a.clone(), b.clone(), new),
    }
}

impl KernelDelta {
    /// Which factor this delta touches.
    pub fn side(&self) -> usize {
        match self {
            KernelDelta::AddItem { side, .. }
            | KernelDelta::RemoveItem { side, .. }
            | KernelDelta::RetireItem { side, .. }
            | KernelDelta::Perturb { side, .. } => *side,
        }
    }

    /// Does this delta change the factor's dimension? Structural deltas
    /// have no low-rank form and always force an exact epoch rebuild.
    pub fn is_structural(&self) -> bool {
        matches!(self, KernelDelta::AddItem { .. } | KernelDelta::RemoveItem { .. })
    }

    /// Perturbation rank of the incremental form (0 for structural).
    pub fn rank(&self) -> usize {
        match self {
            KernelDelta::AddItem { .. } | KernelDelta::RemoveItem { .. } => 0,
            KernelDelta::RetireItem { .. } => 2,
            KernelDelta::Perturb { rhos, .. } => rhos.len(),
        }
    }

    /// Short operation label for metrics and reports.
    pub fn kind(&self) -> &'static str {
        match self {
            KernelDelta::AddItem { .. } => "add",
            KernelDelta::RemoveItem { .. } => "remove",
            KernelDelta::RetireItem { .. } => "retire",
            KernelDelta::Perturb { .. } => "perturb",
        }
    }

    /// Validate the delta against a kernel: factor bounds, operand shapes,
    /// finite entries. The *result* of application is screened separately
    /// by `Kernel::validate_finite` on the publish path.
    pub fn validate(&self, kernel: &Kernel) -> Result<()> {
        let f = factor(kernel, self.side())?;
        let n = f.rows();
        match self {
            KernelDelta::AddItem { row, diag, .. } => {
                if row.len() != n {
                    return Err(Error::Invalid(format!(
                        "delta add: row length {} != factor size {n}",
                        row.len()
                    )));
                }
                if !diag.is_finite() || *diag <= 0.0 {
                    return Err(Error::Invalid(format!("delta add: bad diagonal {diag}")));
                }
                if row.iter().any(|v| !v.is_finite()) {
                    return Err(Error::Invalid("delta add: non-finite row entry".into()));
                }
            }
            KernelDelta::RemoveItem { index, .. } => {
                if *index >= n {
                    return Err(Error::Invalid(format!(
                        "delta remove: index {index} outside factor of size {n}"
                    )));
                }
                if n <= 1 {
                    return Err(Error::Invalid(
                        "delta remove: factor would become empty".into(),
                    ));
                }
            }
            KernelDelta::RetireItem { index, damping, .. } => {
                if *index >= n {
                    return Err(Error::Invalid(format!(
                        "delta retire: index {index} outside factor of size {n}"
                    )));
                }
                if !damping.is_finite() || !(0.0..=1.0).contains(damping) {
                    return Err(Error::Invalid(format!(
                        "delta retire: damping {damping} outside [0, 1]"
                    )));
                }
            }
            KernelDelta::Perturb { rhos, vectors, .. } => {
                if vectors.rows() != n || vectors.cols() != rhos.len() {
                    return Err(Error::Invalid(format!(
                        "delta perturb: {}×{} directions vs factor size {n}, rank {}",
                        vectors.rows(),
                        vectors.cols(),
                        rhos.len()
                    )));
                }
                if rhos.is_empty() {
                    return Err(Error::Invalid("delta perturb: empty rank".into()));
                }
                if rhos.iter().any(|v| !v.is_finite())
                    || vectors.as_slice().iter().any(|v| !v.is_finite())
                {
                    return Err(Error::Invalid("delta perturb: non-finite operand".into()));
                }
            }
        }
        Ok(())
    }

    /// Exact application: the post-delta kernel (untouched factors are
    /// cloned). This is the registry's ground truth — deterministic
    /// arithmetic, so replaying the same delta sequence always reproduces
    /// bit-identical kernels.
    pub fn apply(&self, kernel: &Kernel) -> Result<Kernel> {
        self.validate(kernel)?;
        let f = factor(kernel, self.side())?;
        let n = f.rows();
        let new = match self {
            KernelDelta::AddItem { row, diag, .. } => Matrix::from_fn(n + 1, n + 1, |i, j| {
                match (i == n, j == n) {
                    (false, false) => f.get(i, j),
                    (true, false) => row[j],
                    (false, true) => row[i],
                    (true, true) => *diag,
                }
            }),
            KernelDelta::RemoveItem { index, .. } => {
                let skip = |k: usize| if k >= *index { k + 1 } else { k };
                Matrix::from_fn(n - 1, n - 1, |i, j| f.get(skip(i), skip(j)))
            }
            KernelDelta::RetireItem { index, damping, .. } => {
                // L' = D·L·D: row and column `index` scale by α, the
                // diagonal entry by α² (scaled once in each sweep).
                let mut out = f.clone();
                for k in 0..n {
                    let rv = out.get(*index, k) * damping;
                    out.set(*index, k, rv);
                }
                for k in 0..n {
                    let cv = out.get(k, *index) * damping;
                    out.set(k, *index, cv);
                }
                out
            }
            KernelDelta::Perturb { rhos, vectors, .. } => {
                let mut out = f.clone();
                for (k, &rho) in rhos.iter().enumerate() {
                    for i in 0..n {
                        let vi = rho * vectors.get(i, k);
                        if vi == 0.0 {
                            continue;
                        }
                        for j in 0..n {
                            let v = out.get(i, j) + vi * vectors.get(j, k);
                            out.set(i, j, v);
                        }
                    }
                }
                out.symmetrize_mut();
                out
            }
        };
        Ok(with_factor(kernel, self.side(), new))
    }

    /// The incremental form: `(side, rhos, vs)` with
    /// `L_side' = L_side + Σ_k rhos[k]·vs[:,k]·vs[:,k]ᵀ`, or `None` for
    /// structural deltas. Retirement is lowered through the rank-2
    /// congruence identity (module docs); perturbations pass through.
    pub fn as_perturbation(&self, kernel: &Kernel) -> Result<Option<(usize, Vec<f64>, Matrix)>> {
        self.validate(kernel)?;
        match self {
            KernelDelta::AddItem { .. } | KernelDelta::RemoveItem { .. } => Ok(None),
            KernelDelta::Perturb { side, rhos, vectors } => {
                Ok(Some((*side, rhos.clone(), vectors.clone())))
            }
            KernelDelta::RetireItem { side, index, damping } => {
                let f = factor(kernel, *side)?;
                let n = f.rows();
                let am1 = damping - 1.0;
                // b = (α−1)·L[:,index] + ½(α−1)²·L_ii·e_index
                let mut b = vec![0.0; n];
                for i in 0..n {
                    b[i] = am1 * f.get(i, *index);
                }
                b[*index] += 0.5 * am1 * am1 * f.get(*index, *index);
                let mut vs = Matrix::zeros(n, 2);
                for i in 0..n {
                    let e = if i == *index { 1.0 } else { 0.0 };
                    vs.set(i, 0, e + b[i]);
                    vs.set(i, 1, e - b[i]);
                }
                Ok(Some((*side, vec![0.5, -0.5], vs)))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut state = seed | 1;
        let x = Matrix::from_fn(n, n, |_, _| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state as f64 / u64::MAX as f64) - 0.5
        });
        let mut g = crate::linalg::matmul::matmul_nt(&x, &x).unwrap();
        g.add_diag_mut(n as f64 * 0.2);
        g
    }

    /// Apply the perturbation form densely to the named factor.
    fn apply_perturbation(kernel: &Kernel, side: usize, rhos: &[f64], vs: &Matrix) -> Kernel {
        let f = super::factor(kernel, side).unwrap();
        let n = f.rows();
        let mut out = f.clone();
        for (k, &rho) in rhos.iter().enumerate() {
            for i in 0..n {
                for j in 0..n {
                    let v = out.get(i, j) + rho * vs.get(i, k) * vs.get(j, k);
                    out.set(i, j, v);
                }
            }
        }
        super::with_factor(kernel, side, out)
    }

    #[test]
    fn add_and_remove_change_dimensions() {
        let kernel = Kernel::Kron2(spd(4, 1), spd(5, 2));
        let add = KernelDelta::AddItem { side: 1, row: vec![0.1, 0.2, -0.1, 0.05, 0.3], diag: 1.4 };
        let grown = add.apply(&kernel).unwrap();
        match &grown {
            Kernel::Kron2(a, b) => {
                assert_eq!((a.rows(), b.rows()), (4, 6));
                assert_eq!(b.get(5, 5), 1.4);
                assert_eq!(b.get(5, 2), -0.1);
                assert_eq!(b.get(2, 5), -0.1);
            }
            _ => panic!("structure changed"),
        }
        let rm = KernelDelta::RemoveItem { side: 1, index: 5 };
        let back = rm.apply(&grown).unwrap();
        match (&kernel, &back) {
            (Kernel::Kron2(_, b0), Kernel::Kron2(_, b1)) => {
                assert_eq!(b0.as_slice(), b1.as_slice(), "add→remove must round-trip");
            }
            _ => panic!("structure changed"),
        }
        assert!(add.is_structural() && rm.is_structural());
        assert!(add.as_perturbation(&kernel).unwrap().is_none());
    }

    #[test]
    fn retire_matches_congruence_and_rank_two_form() {
        let kernel = Kernel::Kron2(spd(6, 3), spd(4, 4));
        let delta = KernelDelta::RetireItem { side: 0, index: 2, damping: 0.25 };
        let applied = delta.apply(&kernel).unwrap();
        // Oracle: D·L·D.
        let l = match &kernel {
            Kernel::Kron2(a, _) => a.clone(),
            _ => unreachable!(),
        };
        let dld = Matrix::from_fn(6, 6, |i, j| {
            let di = if i == 2 { 0.25 } else { 1.0 };
            let dj = if j == 2 { 0.25 } else { 1.0 };
            di * l.get(i, j) * dj
        });
        match &applied {
            Kernel::Kron2(a, _) => assert!(a.rel_diff(&dld) < 1e-14),
            _ => panic!(),
        }
        // The rank-2 lowering reproduces the same kernel.
        let (side, rhos, vs) = delta.as_perturbation(&kernel).unwrap().unwrap();
        assert_eq!((side, rhos.len(), vs.cols()), (0, 2, 2));
        let via_pert = apply_perturbation(&kernel, side, &rhos, &vs);
        match (&applied, &via_pert) {
            (Kernel::Kron2(a, _), Kernel::Kron2(p, _)) => {
                assert!(a.rel_diff(p) < 1e-12, "rank-2 form diverges: {}", a.rel_diff(p));
            }
            _ => panic!(),
        }
        // Fully retiring silences the row.
        let dead = KernelDelta::RetireItem { side: 0, index: 2, damping: 0.0 };
        match dead.apply(&kernel).unwrap() {
            Kernel::Kron2(a, _) => {
                for k in 0..6 {
                    assert_eq!(a.get(2, k), 0.0);
                    assert_eq!(a.get(k, 2), 0.0);
                }
            }
            _ => panic!(),
        }
    }

    #[test]
    fn perturb_applies_symmetrically() {
        let kernel = Kernel::Full(spd(7, 5));
        let mut vs = Matrix::zeros(7, 2);
        for i in 0..7 {
            vs.set(i, 0, (i as f64 * 0.37).sin());
            vs.set(i, 1, (i as f64 * 0.81).cos() * 0.3);
        }
        let delta = KernelDelta::Perturb { side: 0, rhos: vec![0.7, -0.1], vectors: vs.clone() };
        let applied = delta.apply(&kernel).unwrap();
        let (side, rhos, pvs) = delta.as_perturbation(&kernel).unwrap().unwrap();
        let oracle = apply_perturbation(&kernel, side, &rhos, &pvs);
        match (&applied, &oracle) {
            (Kernel::Full(a), Kernel::Full(b)) => assert!(a.rel_diff(b) < 1e-13),
            _ => panic!(),
        }
        match &applied {
            Kernel::Full(a) => {
                for i in 0..7 {
                    for j in 0..7 {
                        assert_eq!(a.get(i, j), a.get(j, i), "asymmetric at ({i},{j})");
                    }
                }
            }
            _ => panic!(),
        }
        assert_eq!(delta.rank(), 2);
        assert!(!delta.is_structural());
    }

    #[test]
    fn validation_rejects_malformed_deltas() {
        let kernel = Kernel::Kron2(spd(4, 7), spd(5, 8));
        // Factor out of range.
        assert!(KernelDelta::RemoveItem { side: 2, index: 0 }.validate(&kernel).is_err());
        // Wrong row length.
        assert!(KernelDelta::AddItem { side: 0, row: vec![0.0; 5], diag: 1.0 }
            .validate(&kernel)
            .is_err());
        // Non-positive diagonal.
        assert!(KernelDelta::AddItem { side: 0, row: vec![0.0; 4], diag: 0.0 }
            .validate(&kernel)
            .is_err());
        // Index out of bounds.
        assert!(KernelDelta::RetireItem { side: 1, index: 9, damping: 0.5 }
            .validate(&kernel)
            .is_err());
        // Damping outside [0, 1].
        assert!(KernelDelta::RetireItem { side: 1, index: 0, damping: 1.5 }
            .validate(&kernel)
            .is_err());
        // NaN perturbation operand.
        let mut vs = Matrix::zeros(4, 1);
        vs.set(1, 0, f64::NAN);
        assert!(KernelDelta::Perturb { side: 0, rhos: vec![1.0], vectors: vs }
            .validate(&kernel)
            .is_err());
        // Shape mismatch between rhos and vectors.
        assert!(KernelDelta::Perturb {
            side: 0,
            rhos: vec![1.0, 2.0],
            vectors: Matrix::zeros(4, 1)
        }
        .validate(&kernel)
        .is_err());
    }
}
