//! MCMC (add/delete) sampler — the approximate-sampling baseline the
//! paper contrasts against (Kang [13]; see §4's discussion).
//!
//! The chain state is a subset `Y`; moves propose inserting or removing a
//! single item and accept with the Metropolis ratio of `det(L_Y)`.
//! Determinant ratios are computed incrementally through a **maintained**
//! Cholesky factor of `L_Y` held in insertion order (the determinant is
//! permutation-invariant):
//!
//! - insertion ratio: the Schur complement `L_ii − wᵀw` where `w` solves
//!   `F·w = L_{Y,i}` (one `O(κ²)` forward sweep, the same row-oriented
//!   substitution as [`crate::linalg::trisolve`]); an accepted insert
//!   *appends* `[wᵀ, √d]` as the factor's new row — the solve **is** the
//!   update, no refactorization;
//! - removal ratio: `[L_Y⁻¹]_pp = ‖F⁻¹·e_p‖²` via the same sweep; an
//!   accepted removal deletes the factor's row `p` and restores
//!   triangularity of the trailing block through the shared rank-r
//!   up/downdate machinery ([`crate::linalg::cholesky::rank_r_update`]
//!   with `r = 1` — the compaction is mathematically an *update*: the
//!   trailing block satisfies `L₃₃·L₃₃ᵀ + l₃₂·l₃₂ᵀ`, so the stable
//!   *plus*-sign sweep applies, never the hyperbolic downdate).
//!
//! A step therefore costs `O(κ²)` with **zero heap allocations in steady
//! state**: the factor, the solve buffers and the subset vector are all
//! caller-held and grown once (the previous implementation rebuilt
//! `Cholesky::factor(&kernel.principal_submatrix(..))` per accepted move,
//! allocating a fresh `κ×κ` matrix and factor each time). A periodic
//! exact refactorization (every [`FACTOR_REFRESH_EVERY`] accepted moves)
//! bounds floating-point drift over long chains, matching the sampler's
//! weight-refresh discipline.

use crate::dpp::kernel::Kernel;
use crate::error::{Error, Result};
use crate::linalg::cholesky::{rank_r_update, Cholesky};
use crate::linalg::Matrix;
use crate::rng::Rng;

/// Exact-refactorization cadence (accepted moves). Up/downdates are exact
/// in exact arithmetic; the refresh only bounds round-off accumulation.
const FACTOR_REFRESH_EVERY: usize = 256;

/// MCMC sampler state over subsets of a DPP.
pub struct McmcSampler<'a> {
    kernel: &'a Kernel,
    /// Current subset (sorted).
    y: Vec<usize>,
    /// Items in factor (insertion) order — `fac` factors `L[order, order]`.
    order: Vec<usize>,
    /// Row-major `κ×κ` lower Cholesky factor of `L[order, order]`,
    /// maintained across moves (stride = current `κ`).
    fac: Vec<f64>,
    /// Forward-solve workspace (doubles as the new factor row on insert
    /// and the deleted column on removal).
    w: Vec<f64>,
    /// Right-hand-side gather / second solve workspace.
    b: Vec<f64>,
    /// Cold-path staging: the gathered `L_Y` and its factor (the periodic
    /// exact refresh reuses the shared linalg factorization).
    sub: Matrix,
    lmat: Matrix,
    /// Accepted moves since the last exact refactorization.
    since_refresh: usize,
    /// Accepted / proposed counters (diagnostics).
    pub accepted: usize,
    pub proposed: usize,
}

impl<'a> McmcSampler<'a> {
    /// Start from the empty set.
    pub fn new(kernel: &'a Kernel) -> Self {
        McmcSampler {
            kernel,
            y: Vec::new(),
            order: Vec::new(),
            fac: Vec::new(),
            w: Vec::new(),
            b: Vec::new(),
            sub: Matrix::default(),
            lmat: Matrix::default(),
            since_refresh: 0,
            accepted: 0,
            proposed: 0,
        }
    }

    /// Start from a given subset.
    pub fn with_state(kernel: &'a Kernel, y: Vec<usize>) -> Result<Self> {
        let mut s = McmcSampler::new(kernel);
        s.set_state(y)?;
        Ok(s)
    }

    /// Replace the chain state, refactoring `L_Y` into the held buffers
    /// (`O(κ³)` once; no allocation once the buffers have capacity).
    fn set_state(&mut self, mut y: Vec<usize>) -> Result<()> {
        y.sort_unstable();
        y.dedup();
        self.order.clear();
        self.order.extend_from_slice(&y);
        self.y = y;
        self.refactor()
    }

    /// Exact refactorization of `L[order, order]` into `fac` — the cold
    /// path (state resets and the periodic drift refresh) goes through
    /// the shared factored gather and Cholesky, then lays the factor into
    /// the packed maintenance buffer. Allocation-free once the staging
    /// matrices have capacity.
    fn refactor(&mut self) -> Result<()> {
        self.kernel.principal_submatrix_into(&self.order, &mut self.sub);
        Cholesky::factor_into(&self.sub, &mut self.lmat).map_err(|e| {
            Error::Numerical(format!("mcmc: L_Y not PD (κ={}): {e}", self.order.len()))
        })?;
        self.fac.clear();
        self.fac.extend_from_slice(self.lmat.as_slice());
        self.since_refresh = 0;
        Ok(())
    }

    /// Current subset.
    pub fn state(&self) -> &[usize] {
        &self.y
    }

    /// Determinant ratio `det(L_{Y∪{i}}) / det(L_Y)` (Schur complement
    /// `L_ii − wᵀw`). Leaves `w` holding the prospective factor row, so an
    /// accepting caller finishes the insert with [`McmcSampler::append`]
    /// at no extra cost.
    fn insert_ratio(&mut self, item: usize) -> f64 {
        let k = self.order.len();
        self.b.clear();
        self.b.extend(self.order.iter().map(|&j| self.kernel.entry(j, item)));
        self.w.clear();
        self.w.resize(k, 0.0);
        let mut quad = 0.0;
        for i in 0..k {
            let mut v = self.b[i];
            let row = &self.fac[i * k..i * k + i];
            for (t, &l) in row.iter().enumerate() {
                v -= l * self.w[t];
            }
            let wi = v / self.fac[i * k + i];
            self.w[i] = wi;
            quad += wi * wi;
        }
        self.kernel.entry(item, item) - quad
    }

    /// Finish an accepted insert: grow the factor's stride in place and
    /// append `[wᵀ, √d]` as the new last row (`w`/`d` from the preceding
    /// [`McmcSampler::insert_ratio`] call).
    fn append(&mut self, item: usize, d: f64) {
        let k = self.order.len();
        let ns = k + 1;
        self.fac.resize(ns * ns, 0.0);
        // Re-stride rows back-to-front (regions shift right; row i's new
        // start i·(k+1) never overlaps any unread row j < i).
        for i in (1..k).rev() {
            self.fac.copy_within(i * k..i * k + k, i * ns);
        }
        // New (upper-triangle) column must be zero in every old row.
        for i in 0..k {
            self.fac[i * ns + k] = 0.0;
        }
        let base = k * ns;
        self.fac[base..base + k].copy_from_slice(&self.w[..k]);
        self.fac[base + k] = d.sqrt();
        self.order.push(item);
        let ins = self.y.binary_search(&item).unwrap_err();
        self.y.insert(ins, item);
    }

    /// Determinant ratio `det(L_{Y∖{pos}}) / det(L_Y)` where `pos` indexes
    /// into the current (sorted) subset. Equals `[L_Y⁻¹]_pp =
    /// ‖F⁻¹·e_p‖²` — one forward sweep starting at the item's factor row.
    fn remove_ratio(&mut self, pos: usize) -> f64 {
        let p = self.factor_pos(pos);
        let k = self.order.len();
        self.b.clear();
        self.b.resize(k, 0.0);
        let mut acc = 0.0;
        for i in p..k {
            let mut v = if i == p { 1.0 } else { 0.0 };
            let row = &self.fac[i * k + p..i * k + i];
            for (t, &l) in row.iter().enumerate() {
                v -= l * self.b[p + t];
            }
            let zi = v / self.fac[i * k + i];
            self.b[i] = zi;
            acc += zi * zi;
        }
        acc
    }

    /// Factor-order position of subset position `pos` (O(κ) scan).
    fn factor_pos(&self, pos: usize) -> usize {
        let item = self.y[pos];
        self.order.iter().position(|&o| o == item).expect("subset/order in sync")
    }

    /// Finish an accepted removal: drop the item's factor row/column in
    /// place and repair the trailing block with one rank-one update.
    fn remove(&mut self, pos: usize) {
        let p = self.factor_pos(pos);
        let k = self.order.len();
        let t = k - 1 - p;
        // Save the deleted column below the diagonal: the trailing block
        // then satisfies L₃₃·L₃₃ᵀ + l₃₂·l₃₂ᵀ.
        self.w.clear();
        self.w.resize(t, 0.0);
        for i in 0..t {
            self.w[i] = self.fac[(p + 1 + i) * k + p];
        }
        // Compact to stride k−1, dropping row/col p (writes trail reads).
        let ns = k - 1;
        for r in 0..ns {
            let s = if r < p { r } else { r + 1 };
            for c in 0..=r {
                let sc = if c < p { c } else { c + 1 };
                self.fac[r * ns + c] = self.fac[s * k + sc];
            }
            for c in (r + 1)..ns {
                self.fac[r * ns + c] = 0.0;
            }
        }
        self.fac.truncate(ns * ns);
        rank_r_update(&mut self.fac, ns, p, t, &mut self.w);
        self.order.remove(p);
        self.y.remove(pos);
    }

    /// One Metropolis step (insert-or-delete proposal mix).
    pub fn step(&mut self, rng: &mut Rng) -> Result<()> {
        let n = self.kernel.n();
        let item = rng.below(n);
        self.step_item(item, rng)
    }

    /// One Metropolis step with the proposal drawn uniformly from
    /// `candidates` instead of the full ground set. With `candidates = R =
    /// [N] ∖ (A ∪ B)` and a start state containing `A`, the chain walks
    /// the admissible lattice `A ⊆ Y ⊆ A ∪ R` (pinned items are never
    /// proposed for removal, banned items never for insertion) and its
    /// stationary law is `det(L_Y)` restricted to that lattice — the
    /// conditional DPP, with no Schur setup at all.
    pub fn step_candidates(&mut self, candidates: &[usize], rng: &mut Rng) -> Result<()> {
        let item = candidates[rng.below(candidates.len())];
        self.step_item(item, rng)
    }

    fn step_item(&mut self, item: usize, rng: &mut Rng) -> Result<()> {
        self.proposed += 1;
        match self.y.binary_search(&item) {
            Err(_) => {
                // Propose insertion: accept w.p. ratio/(1+ratio) — the
                // standard lazy insert/delete chain for DPPs keeps the
                // move reversible with this acceptance.
                let ratio = self.insert_ratio(item);
                let p = if ratio <= 0.0 { 0.0 } else { ratio / (1.0 + ratio) };
                if rng.bernoulli(p) {
                    self.append(item, ratio);
                    self.accepted += 1;
                    self.maybe_refresh()?;
                }
            }
            Ok(pos) => {
                // Propose removal: accept w.p. r/(1+r).
                let ratio = self.remove_ratio(pos).max(0.0);
                let p = ratio / (1.0 + ratio);
                if rng.bernoulli(p) {
                    self.remove(pos);
                    self.accepted += 1;
                    self.maybe_refresh()?;
                }
            }
        }
        Ok(())
    }

    /// One fixed-size *swap* proposal: remove the item at sorted position
    /// `pos`, insert `v ∉ Y`, accepting with the same Barker rule
    /// `p = r/(1+r)` where `r = det(L_{Y∖u∪v})/det(L_Y)` — detailed
    /// balance over the k-subset slice holds because the caller's
    /// proposal (uniform `u` from the removable part of `Y`, uniform `v`
    /// from the insertable pool) has state-independent pool sizes.
    /// Returns whether the swap was accepted; a rejected proposal
    /// restores the state exactly (the factor is rebuilt by re-insertion,
    /// which leaves the represented subset — and hence every future
    /// ratio — unchanged).
    pub fn step_swap(&mut self, pos: usize, v: usize, rng: &mut Rng) -> Result<bool> {
        self.proposed += 1;
        debug_assert!(self.y.binary_search(&v).is_err(), "swap target already in Y");
        let u = self.y[pos];
        let r1 = self.remove_ratio(pos).max(0.0);
        self.remove(pos);
        let r2 = self.insert_ratio(v);
        let ratio = if r2 <= 0.0 { 0.0 } else { r1 * r2 };
        let p = ratio / (1.0 + ratio);
        if rng.bernoulli(p) {
            self.append(v, r2);
            self.accepted += 1;
            self.maybe_refresh()?;
            Ok(true)
        } else {
            let ru = self.insert_ratio(u);
            debug_assert!(ru > 0.0, "re-inserting a just-removed member must be PD");
            self.append(u, ru);
            Ok(false)
        }
    }

    /// Periodic exact refactorization bounding up/downdate drift.
    fn maybe_refresh(&mut self) -> Result<()> {
        self.since_refresh += 1;
        if self.since_refresh >= FACTOR_REFRESH_EVERY {
            self.refactor()?;
        }
        Ok(())
    }

    /// Run `steps` moves and return the final state.
    pub fn run(&mut self, steps: usize, rng: &mut Rng) -> Result<Vec<usize>> {
        for _ in 0..steps {
            self.step(rng)?;
        }
        Ok(self.y.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = rng.paper_init_kernel(n);
        m.scale_mut(1.0 / n as f64);
        m.add_diag_mut(0.3);
        m
    }

    #[test]
    fn ratios_match_direct_determinants() {
        let kernel = Kernel::Full(spd(6, 1));
        let mut s = McmcSampler::with_state(&kernel, vec![0, 2, 4]).unwrap();
        // Insert 5
        let direct = {
            let d1 = crate::linalg::lu::det(&kernel.principal_submatrix(&[0, 2, 4, 5])).unwrap();
            let d0 = crate::linalg::lu::det(&kernel.principal_submatrix(&[0, 2, 4])).unwrap();
            d1 / d0
        };
        assert!((s.insert_ratio(5) - direct).abs() / direct.abs() < 1e-9);
        // Remove position 1 (item 2)
        let direct_rm = {
            let d1 = crate::linalg::lu::det(&kernel.principal_submatrix(&[0, 4])).unwrap();
            let d0 = crate::linalg::lu::det(&kernel.principal_submatrix(&[0, 2, 4])).unwrap();
            d1 / d0
        };
        assert!((s.remove_ratio(1) - direct_rm).abs() / direct_rm.abs() < 1e-9);
    }

    #[test]
    fn maintained_factor_tracks_refactorization() {
        // Drive the chain through inserts and removals; the up/downdated
        // factor must always equal a from-scratch factorization of the
        // *sorted* submatrix in the maintained order's permutation.
        let kernel = Kernel::Kron2(spd(3, 8), spd(3, 9));
        let mut s = McmcSampler::new(&kernel);
        let mut rng = Rng::new(13);
        for step in 0..400 {
            s.step(&mut rng).unwrap();
            let k = s.order.len();
            if k == 0 {
                continue;
            }
            let mut fresh = McmcSampler::new(&kernel);
            fresh.order = s.order.clone();
            fresh.fac = vec![0.0; k * k];
            fresh.refactor().unwrap();
            for i in 0..k * k {
                assert!(
                    (s.fac[i] - fresh.fac[i]).abs() < 1e-9,
                    "step {step}: factor drifted at {i}: {} vs {}",
                    s.fac[i],
                    fresh.fac[i]
                );
            }
        }
        assert!(s.accepted > 0);
    }

    #[test]
    fn long_chain_drift_stays_below_1e10_vs_periodic_exact_refactor() {
        // Satellite check for the rank-r routing: a *long* chain (several
        // multiples of FACTOR_REFRESH_EVERY accepted moves, so the
        // periodic exact refresh fires repeatedly) must keep the
        // incrementally maintained factor within 1e-10 of a from-scratch
        // refactorization at every checkpoint. This is the accumulated-
        // drift bound the delta-publish machinery inherits.
        let kernel = Kernel::Kron2(spd(4, 18), spd(4, 19));
        let mut s = McmcSampler::new(&kernel);
        let mut rng = Rng::new(29);
        let mut checked = 0usize;
        for step in 0..3000 {
            s.step(&mut rng).unwrap();
            if step % 50 != 0 {
                continue;
            }
            let k = s.order.len();
            if k == 0 {
                continue;
            }
            let mut fresh = McmcSampler::new(&kernel);
            fresh.order = s.order.clone();
            fresh.fac = vec![0.0; k * k];
            fresh.refactor().unwrap();
            for i in 0..k * k {
                assert!(
                    (s.fac[i] - fresh.fac[i]).abs() < 1e-10,
                    "step {step}: drift {} at {i}",
                    (s.fac[i] - fresh.fac[i]).abs()
                );
            }
            checked += 1;
        }
        assert!(checked > 30, "chain barely ran ({checked} checkpoints)");
        assert!(
            s.accepted > FACTOR_REFRESH_EVERY,
            "need at least one full refresh cycle, got {} accepted moves",
            s.accepted
        );
    }

    #[test]
    fn chain_moves_and_stays_valid() {
        let kernel = Kernel::Kron2(spd(2, 2), spd(3, 3));
        let mut s = McmcSampler::new(&kernel);
        let mut rng = Rng::new(5);
        let y = s.run(500, &mut rng).unwrap();
        assert!(y.windows(2).all(|w| w[0] < w[1]));
        assert!(y.iter().all(|&i| i < 6));
        assert!(s.accepted > 0, "chain never moved");
    }

    // Distributional correctness (stationary law vs enumeration, chain
    // marginals vs the factored K-diagonal) lives in the shared
    // statistical harness: `tests/sampler_conformance.rs` checks every
    // backend — this chain included — with chi-square and binomial-4σ
    // bounds against brute-force oracles. The unit tests here only cover
    // the incremental machinery.

    #[test]
    fn restricted_proposals_never_touch_pinned_or_banned_items() {
        let kernel = Kernel::Kron2(spd(3, 11), spd(3, 12));
        // Pin {0, 4}, ban {2, 7}: proposals come only from the rest.
        let rest: Vec<usize> = (0..9).filter(|i| ![0usize, 4, 2, 7].contains(i)).collect();
        let mut s = McmcSampler::with_state(&kernel, vec![0, 4]).unwrap();
        let mut rng = Rng::new(21);
        for _ in 0..600 {
            s.step_candidates(&rest, &mut rng).unwrap();
            let y = s.state();
            assert!(y.contains(&0) && y.contains(&4), "pinned item dropped: {y:?}");
            assert!(!y.contains(&2) && !y.contains(&7), "banned item inserted: {y:?}");
        }
        assert!(s.accepted > 0, "restricted chain never moved");
    }

    #[test]
    fn swap_steps_preserve_size_and_track_refactorization() {
        let kernel = Kernel::Kron2(spd(3, 14), spd(3, 15));
        let k = 4usize;
        let mut inside: Vec<usize> = vec![0, 2, 5, 8];
        let mut outside: Vec<usize> = (0..9).filter(|i| !inside.contains(i)).collect();
        let mut s = McmcSampler::with_state(&kernel, inside.clone()).unwrap();
        let mut rng = Rng::new(17);
        let mut accepted = 0usize;
        for step in 0..300 {
            let iu = rng.below(inside.len());
            let iv = rng.below(outside.len());
            let u = inside[iu];
            let pos = s.state().binary_search(&u).unwrap();
            if s.step_swap(pos, outside[iv], &mut rng).unwrap() {
                inside[iu] = outside[iv];
                outside[iv] = u;
                accepted += 1;
            }
            assert_eq!(s.state().len(), k, "step {step}: swap changed the size");
            let mut expect = inside.clone();
            expect.sort_unstable();
            assert_eq!(s.state(), &expect[..], "step {step}: bookkeeping diverged");
            // The maintained factor must still match a fresh
            // factorization after accepts *and* rejected round-trips.
            let kk = s.order.len();
            let mut fresh = McmcSampler::new(&kernel);
            fresh.order = s.order.clone();
            fresh.fac = vec![0.0; kk * kk];
            fresh.refactor().unwrap();
            for i in 0..kk * kk {
                assert!(
                    (s.fac[i] - fresh.fac[i]).abs() < 1e-9,
                    "step {step}: factor drifted at {i}"
                );
            }
        }
        assert!(accepted > 0, "swap chain never accepted");
    }
}
