//! MCMC (add/delete/swap) sampler — the approximate-sampling baseline the
//! paper contrasts against (Kang [13]; see §4's discussion).
//!
//! The chain state is a subset `Y`; moves propose inserting, removing, or
//! swapping a single item and accept with the Metropolis ratio of
//! `det(L_Y)`. Determinant ratios are computed incrementally through a
//! maintained Cholesky factor of `L_Y`:
//!
//! - insertion ratio: the Schur complement `L_ii − L_{Y,i}ᵀ L_Y⁻¹ L_{Y,i}`,
//! - removal ratio: `1 / (inverse diagonal)` via a solve,
//!
//! so a step costs `O(κ²)` instead of `O(κ³)`.

use crate::dpp::kernel::Kernel;
use crate::error::Result;
use crate::linalg::Cholesky;
use crate::rng::Rng;

/// MCMC sampler state over subsets of a DPP.
pub struct McmcSampler<'a> {
    kernel: &'a Kernel,
    /// Current subset (sorted).
    y: Vec<usize>,
    /// Cholesky factor of `L_Y` (refreshed after each accepted move).
    chol: Option<Cholesky>,
    /// Accepted / proposed counters (diagnostics).
    pub accepted: usize,
    pub proposed: usize,
}

impl<'a> McmcSampler<'a> {
    /// Start from the empty set.
    pub fn new(kernel: &'a Kernel) -> Self {
        McmcSampler { kernel, y: Vec::new(), chol: None, accepted: 0, proposed: 0 }
    }

    /// Start from a given subset.
    pub fn with_state(kernel: &'a Kernel, y: Vec<usize>) -> Result<Self> {
        let mut s = McmcSampler::new(kernel);
        s.set_state(y)?;
        Ok(s)
    }

    fn set_state(&mut self, mut y: Vec<usize>) -> Result<()> {
        y.sort_unstable();
        y.dedup();
        self.chol = if y.is_empty() {
            None
        } else {
            Some(Cholesky::factor(&self.kernel.principal_submatrix(&y))?)
        };
        self.y = y;
        Ok(())
    }

    /// Current subset.
    pub fn state(&self) -> &[usize] {
        &self.y
    }

    /// Determinant ratio `det(L_{Y∪{i}}) / det(L_Y)` (Schur complement).
    fn insert_ratio(&self, item: usize) -> f64 {
        let lii = self.kernel.entry(item, item);
        match &self.chol {
            None => lii,
            Some(ch) => {
                let b: Vec<f64> = self.y.iter().map(|&j| self.kernel.entry(j, item)).collect();
                let x = ch.solve_vec(&b).expect("dimension consistent");
                let quad: f64 = b.iter().zip(&x).map(|(p, q)| p * q).sum();
                lii - quad
            }
        }
    }

    /// Determinant ratio `det(L_{Y\{pos}}) / det(L_Y)` where `pos` indexes
    /// into the current subset. Equals the `pos`-th diagonal entry of
    /// `L_Y⁻¹` (inverse of the Schur complement).
    fn remove_ratio(&self, pos: usize) -> f64 {
        let ch = self.chol.as_ref().expect("non-empty state");
        let k = self.y.len();
        let mut e = vec![0.0; k];
        e[pos] = 1.0;
        let x = ch.solve_vec(&e).expect("dimension consistent");
        x[pos]
    }

    /// One Metropolis step (insert-or-delete proposal mix).
    pub fn step(&mut self, rng: &mut Rng) -> Result<()> {
        self.proposed += 1;
        let n = self.kernel.n();
        let item = rng.below(n);
        let pos = self.y.binary_search(&item);
        match pos {
            Err(_) => {
                // Propose insertion: accept w.p. min(1, ratio/(1+ratio))
                // — the standard lazy insert/delete chain for DPPs uses
                // ratio/(1+ratio) to keep the move reversible.
                let ratio = self.insert_ratio(item);
                let p = if ratio <= 0.0 { 0.0 } else { ratio / (1.0 + ratio) };
                if rng.bernoulli(p) {
                    let mut y = self.y.clone();
                    let ins = y.binary_search(&item).unwrap_err();
                    y.insert(ins, item);
                    self.set_state(y)?;
                    self.accepted += 1;
                }
            }
            Ok(pos) => {
                // Propose removal: accept w.p. min(1, r/(1+r)) with
                // r = det ratio of removal.
                let ratio = self.remove_ratio(pos).max(0.0);
                let p = ratio / (1.0 + ratio);
                if rng.bernoulli(p) {
                    let mut y = self.y.clone();
                    y.remove(pos);
                    self.set_state(y)?;
                    self.accepted += 1;
                }
            }
        }
        Ok(())
    }

    /// Run `steps` moves and return the final state.
    pub fn run(&mut self, steps: usize, rng: &mut Rng) -> Result<Vec<usize>> {
        for _ in 0..steps {
            self.step(rng)?;
        }
        Ok(self.y.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = rng.paper_init_kernel(n);
        m.scale_mut(1.0 / n as f64);
        m.add_diag_mut(0.3);
        m
    }

    #[test]
    fn ratios_match_direct_determinants() {
        let kernel = Kernel::Full(spd(6, 1));
        let s = McmcSampler::with_state(&kernel, vec![0, 2, 4]).unwrap();
        // Insert 5
        let direct = {
            let d1 = crate::linalg::lu::det(&kernel.principal_submatrix(&[0, 2, 4, 5])).unwrap();
            let d0 = crate::linalg::lu::det(&kernel.principal_submatrix(&[0, 2, 4])).unwrap();
            d1 / d0
        };
        assert!((s.insert_ratio(5) - direct).abs() / direct.abs() < 1e-9);
        // Remove position 1 (item 2)
        let direct_rm = {
            let d1 = crate::linalg::lu::det(&kernel.principal_submatrix(&[0, 4])).unwrap();
            let d0 = crate::linalg::lu::det(&kernel.principal_submatrix(&[0, 2, 4])).unwrap();
            d1 / d0
        };
        assert!((s.remove_ratio(1) - direct_rm).abs() / direct_rm.abs() < 1e-9);
    }

    #[test]
    fn chain_moves_and_stays_valid() {
        let kernel = Kernel::Kron2(spd(2, 2), spd(3, 3));
        let mut s = McmcSampler::new(&kernel);
        let mut rng = Rng::new(5);
        let y = s.run(500, &mut rng).unwrap();
        assert!(y.windows(2).all(|w| w[0] < w[1]));
        assert!(y.iter().all(|&i| i < 6));
        assert!(s.accepted > 0, "chain never moved");
    }

    #[test]
    fn long_run_marginals_approach_k_diagonal() {
        let kernel = Kernel::Full(spd(5, 7));
        let marg = kernel.marginal_kernel().unwrap();
        let mut s = McmcSampler::new(&kernel);
        let mut rng = Rng::new(9);
        // Burn-in.
        s.run(2000, &mut rng).unwrap();
        let mut counts = vec![0usize; 5];
        // Chain samples are autocorrelated (τ ≈ tens of steps for this
        // insert/delete chain), so the effective sample size is sweeps/2τ;
        // 60k sweeps with a 0.06 tolerance keeps every item's margin at
        // ≥ 4 effective standard errors (was 30k/0.05 ≈ 2.4σ — flaky).
        let sweeps = 60_000;
        for _ in 0..sweeps {
            s.step(&mut rng).unwrap();
            for &i in s.state() {
                counts[i] += 1;
            }
        }
        for i in 0..5 {
            let emp = counts[i] as f64 / sweeps as f64;
            let expect = marg[(i, i)];
            assert!((emp - expect).abs() < 0.06, "item {i}: {emp} vs {expect}");
        }
    }
}
