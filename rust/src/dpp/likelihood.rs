//! DPP log-likelihood (Eq. 3 of the paper):
//!
//! `φ(L) = (1/n) Σ_i [ log det(L_{Y_i}) − log det(L + I) ]`
//!
//! For structured kernels the normalizer uses sub-spectra and each
//! `log det(L_{Y_i})` is a `κ×κ` Cholesky, so evaluating the objective
//! costs `O(nκ³ + N^{3/2})` instead of `O(N³)` — the same structure
//! exploitation as the learning updates.

use crate::dpp::kernel::Kernel;
use crate::error::Result;
use crate::linalg::{cholesky, cholesky::Cholesky, Matrix};

/// Number of deterministic reduction stripes of the parallel sweeps below:
/// subset `i` belongs to stripe `i % LL_STRIPES` and stripes reduce in
/// ascending order, so the result is identical for any worker count.
const LL_STRIPES: usize = 16;

/// Below this many subsets the likelihood sweep stays inline (thread
/// spawns cost more than they save).
const LL_PAR_MIN: usize = 48;

/// Mean log-likelihood of `subsets` under kernel `kernel`.
///
/// The per-subset `log det(L_Y)` sweep runs in parallel with per-worker
/// submatrix/Cholesky buffers and a deterministic chunked reduction
/// (stripe partials summed in fixed order — worker-count invariant). This
/// is the generic path for callers without compressed statistics; learners
/// holding a [`crate::learn::stats::ThetaEngine`] get the same sweep fused
/// into their gradient pass (deduplicated, allocation-free) via
/// `Learner::objective`.
pub fn log_likelihood(kernel: &Kernel, subsets: &[Vec<usize>]) -> Result<f64> {
    if subsets.is_empty() {
        return Ok(0.0);
    }
    let normalizer = kernel.logdet_l_plus_i()?;
    let mut partials = [0.0f64; LL_STRIPES];
    let stripe_sum =
        |stripe: usize, sub: &mut Matrix, chol: &mut Matrix| -> Result<f64> {
            let mut acc = 0.0;
            let mut i = stripe;
            while i < subsets.len() {
                let y = &subsets[i];
                if !y.is_empty() {
                    // det(L_∅) = 1, log 0.0 — empty subsets contribute nothing.
                    kernel.principal_submatrix_into(y, sub);
                    acc += cholesky::logdet_pd_with(&*sub, chol)?;
                }
                i += LL_STRIPES;
            }
            Ok(acc)
        };
    let nthreads = crate::linalg::matmul::available_threads().min(LL_STRIPES);
    if nthreads > 1 && subsets.len() >= LL_PAR_MIN {
        let per = LL_STRIPES.div_ceil(nthreads);
        std::thread::scope(|sc| -> Result<()> {
            let mut handles = Vec::new();
            for (w, chunk) in partials.chunks_mut(per).enumerate() {
                let base = w * per;
                let stripe_sum = &stripe_sum;
                handles.push(sc.spawn(move || -> Result<()> {
                    let mut sub = Matrix::zeros(0, 0);
                    let mut chol = Matrix::zeros(0, 0);
                    for (off, p) in chunk.iter_mut().enumerate() {
                        *p = stripe_sum(base + off, &mut sub, &mut chol)?;
                    }
                    Ok(())
                }));
            }
            crate::linalg::matmul::join_first_error(handles)
        })?;
    } else {
        let mut sub = Matrix::zeros(0, 0);
        let mut chol = Matrix::zeros(0, 0);
        for (s, p) in partials.iter_mut().enumerate() {
            *p = stripe_sum(s, &mut sub, &mut chol)?;
        }
    }
    let total: f64 = partials.iter().sum();
    Ok(total / subsets.len() as f64 - normalizer)
}

/// `log det(L_Y)`; the empty set has determinant 1 (log 0.0).
pub fn subset_logdet(kernel: &Kernel, y: &[usize]) -> Result<f64> {
    if y.is_empty() {
        return Ok(0.0);
    }
    let sub = kernel.principal_submatrix(y);
    Ok(Cholesky::factor(&sub)?.logdet())
}

/// Exact probability `P(Y) = det(L_Y)/det(L+I)` (log-space).
pub fn log_prob(kernel: &Kernel, y: &[usize]) -> Result<f64> {
    Ok(subset_logdet(kernel, y)? - kernel.logdet_l_plus_i()?)
}

/// The full-gradient helper matrix `Θ = (1/n) Σ_i U_i L_{Y_i}⁻¹ U_iᵀ`
/// (dense). The gradient of φ is `Δ = Θ − (L+I)⁻¹` (Eq. 4).
///
/// This is the *oracle* Θ: the batch learners never materialize it any
/// more (their contractions come straight from the subset inverses — see
/// [`crate::learn::stats`]), but the full-kernel Picard path, the property
/// suites and the figures still need one. Both phases run in parallel:
/// the `O(nκ³)` inversions over contiguous chunks (slot-independent, so
/// deterministic), and the `O(nκ²)` scatter over disjoint Θ row panels —
/// each row receives its contributions in subset order, so the result is
/// worker-count invariant (no `Mutex`, no serial scatter; see
/// EXPERIMENTS.md §Perf).
pub fn theta_dense(kernel: &Kernel, subsets: &[Vec<usize>]) -> Result<Matrix> {
    let n = kernel.n();
    let mut theta = Matrix::zeros(n, n);
    let w = 1.0 / subsets.len().max(1) as f64;
    let nthreads = crate::linalg::matmul::available_threads().min(subsets.len().max(1));
    // Phase 1: per-subset L_Y⁻¹, written into disjoint chunks of a
    // preallocated slot vector.
    let inverses: Vec<Result<Option<Matrix>>> = if nthreads > 1 && subsets.len() > 8 {
        let mut slots: Vec<Result<Option<Matrix>>> = Vec::with_capacity(subsets.len());
        slots.resize_with(subsets.len(), || Ok(None));
        let chunk_len = subsets.len().div_ceil(nthreads);
        std::thread::scope(|s| {
            for (ochunk, schunk) in
                slots.chunks_mut(chunk_len).zip(subsets.chunks(chunk_len))
            {
                s.spawn(move || {
                    for (o, y) in ochunk.iter_mut().zip(schunk) {
                        *o = invert_subset(kernel, y);
                    }
                });
            }
        });
        slots
    } else {
        subsets.iter().map(|y| invert_subset(kernel, y)).collect()
    };
    let inverses: Vec<Option<Matrix>> = inverses.into_iter().collect::<Result<_>>()?;
    // Phase 2: scatter by disjoint row panels.
    if nthreads > 1 && n >= nthreads {
        let band = n.div_ceil(nthreads);
        let inverses = &inverses;
        std::thread::scope(|s| {
            let mut rest = theta.as_mut_slice();
            let mut lo = 0usize;
            while lo < n {
                let len = band.min(n - lo);
                let (chunk, tail) = rest.split_at_mut(len * n);
                rest = tail;
                let start = lo;
                s.spawn(move || {
                    for (y, inv) in subsets.iter().zip(inverses) {
                        if let Some(inv) = inv {
                            scatter_inverse_rows(chunk, n, start, start + len, y, inv, w);
                        }
                    }
                });
                lo += len;
            }
        });
    } else {
        for (y, inv) in subsets.iter().zip(&inverses) {
            if let Some(inv) = inv {
                scatter_inverse(&mut theta, y, inv, w);
            }
        }
    }
    Ok(theta)
}

fn invert_subset(kernel: &Kernel, y: &[usize]) -> Result<Option<Matrix>> {
    if y.is_empty() {
        return Ok(None);
    }
    let sub = kernel.principal_submatrix(y);
    Ok(Some(Cholesky::factor(&sub)?.inverse()))
}

/// Scatter one subset inverse onto the full Θ (the single shared scatter
/// loop — [`accumulate_theta`] and the serial path of [`theta_dense`] both
/// route through it).
fn scatter_inverse(theta: &mut Matrix, y: &[usize], inv: &Matrix, w: f64) {
    let n = theta.cols();
    scatter_inverse_rows(theta.as_mut_slice(), n, 0, n, y, inv, w);
}

/// Scatter the rows of `w·U_Y L_Y⁻¹ U_Yᵀ` that fall in `[lo, hi)` onto the
/// row band `band` (rows `lo..hi` of Θ, row-major, width `n`).
fn scatter_inverse_rows(
    band: &mut [f64],
    n: usize,
    lo: usize,
    hi: usize,
    y: &[usize],
    inv: &Matrix,
    w: f64,
) {
    for (a, &i) in y.iter().enumerate() {
        if i < lo || i >= hi {
            continue;
        }
        let src = inv.row(a);
        let row = &mut band[(i - lo) * n..(i - lo + 1) * n];
        for (b, &j) in y.iter().enumerate() {
            row[j] += w * src[b];
        }
    }
}

/// Scatter `w · U_Y L_Y⁻¹ U_Yᵀ` onto `theta`.
pub fn accumulate_theta(
    theta: &mut Matrix,
    kernel: &Kernel,
    y: &[usize],
    w: f64,
) -> Result<()> {
    if y.is_empty() {
        return Ok(());
    }
    let sub = kernel.principal_submatrix(y);
    let inv = Cholesky::factor(&sub)?.inverse();
    scatter_inverse(theta, y, &inv, w);
    Ok(())
}

/// Sparse Θ accumulation (for stochastic updates / §3.3 clustering).
pub fn theta_sparse(
    kernel: &Kernel,
    subsets: &[Vec<usize>],
    weight: f64,
) -> Result<crate::linalg::SparseMatrix> {
    let mut b = crate::linalg::SparseBuilder::new(kernel.n());
    for y in subsets {
        if y.is_empty() {
            continue;
        }
        let sub = kernel.principal_submatrix(y);
        let inv = Cholesky::factor(&sub)?.inverse();
        b.scatter_block(y, &inv, weight)?;
    }
    Ok(b.build())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = rng.paper_init_kernel(n);
        m.scale_mut(1.0 / n as f64);
        m.add_diag_mut(0.2);
        m
    }

    #[test]
    fn structured_matches_dense_likelihood() {
        let k = Kernel::Kron2(spd(3, 1), spd(4, 2));
        let full = Kernel::Full(k.to_dense());
        let subsets = vec![vec![0, 5, 7], vec![1], vec![2, 3, 4, 10]];
        let a = log_likelihood(&k, &subsets).unwrap();
        let b = log_likelihood(&full, &subsets).unwrap();
        assert!((a - b).abs() < 1e-8, "{a} vs {b}");
    }

    #[test]
    fn probabilities_normalize_on_tiny_ground_set() {
        // Σ_Y det(L_Y) = det(L + I): enumerate all subsets of a 4-item set.
        let l = spd(4, 3);
        let k = Kernel::Full(l);
        let mut total = 0.0;
        for mask in 0u32..16 {
            let y: Vec<usize> = (0..4).filter(|&i| mask >> i & 1 == 1).collect();
            total += log_prob(&k, &y).unwrap().exp();
        }
        assert!((total - 1.0).abs() < 1e-8, "total {total}");
    }

    #[test]
    fn empty_subset_logdet_zero() {
        let k = Kernel::Full(spd(3, 4));
        assert_eq!(subset_logdet(&k, &[]).unwrap(), 0.0);
    }

    #[test]
    fn theta_dense_symmetric_and_psd_on_support() {
        let k = Kernel::Full(spd(6, 5));
        let subsets = vec![vec![0, 2, 4], vec![1, 2], vec![3]];
        let theta = theta_dense(&k, &subsets).unwrap();
        assert!(theta.is_symmetric(1e-10));
        // Untouched items have zero rows.
        assert_eq!(theta[(5, 5)], 0.0);
        // Diagonal of Θ is positive where items occur.
        assert!(theta[(0, 0)] > 0.0);
        assert!(theta[(3, 3)] > 0.0);
    }

    #[test]
    fn theta_sparse_matches_dense() {
        let k = Kernel::Kron2(spd(2, 6), spd(3, 7));
        let subsets = vec![vec![0, 3], vec![1, 2, 5]];
        let dense = theta_dense(&k, &subsets).unwrap();
        let sparse = theta_sparse(&k, &subsets, 1.0 / 2.0).unwrap();
        assert!(sparse.to_dense().rel_diff(&dense) < 1e-12);
    }

    #[test]
    fn likelihood_increases_for_better_kernel() {
        // A kernel whose submatrices match observed co-occurrence should
        // beat a mismatched one: sample pairs {0,1}, compare a kernel with
        // strong {0,1} diversity vs one with near-duplicate items 0,1.
        let good = Matrix::from_rows(&[
            &[1.0, 0.0, 0.0],
            &[0.0, 1.0, 0.0],
            &[0.0, 0.0, 0.1],
        ])
        .unwrap();
        let mut bad = good.clone();
        bad.set(0, 1, 0.95);
        bad.set(1, 0, 0.95);
        let subsets = vec![vec![0, 1]; 5];
        let lg = log_likelihood(&Kernel::Full(good), &subsets).unwrap();
        let lb = log_likelihood(&Kernel::Full(bad), &subsets).unwrap();
        assert!(lg > lb);
    }
}
