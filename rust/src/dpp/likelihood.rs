//! DPP log-likelihood (Eq. 3 of the paper):
//!
//! `φ(L) = (1/n) Σ_i [ log det(L_{Y_i}) − log det(L + I) ]`
//!
//! For structured kernels the normalizer uses sub-spectra and each
//! `log det(L_{Y_i})` is a `κ×κ` Cholesky, so evaluating the objective
//! costs `O(nκ³ + N^{3/2})` instead of `O(N³)` — the same structure
//! exploitation as the learning updates.

use crate::dpp::kernel::Kernel;
use crate::error::Result;
use crate::linalg::{cholesky, cholesky::Cholesky, Matrix};

/// Mean log-likelihood of `subsets` under kernel `kernel`.
///
/// The per-subset `log det(L_Y)` sweep reuses one submatrix buffer and one
/// Cholesky factor buffer across all subsets (this runs once per learner
/// iteration, so it is a steady-state hot path).
pub fn log_likelihood(kernel: &Kernel, subsets: &[Vec<usize>]) -> Result<f64> {
    if subsets.is_empty() {
        return Ok(0.0);
    }
    let normalizer = kernel.logdet_l_plus_i()?;
    let mut total = 0.0;
    let mut sub = Matrix::zeros(0, 0);
    let mut chol = Matrix::zeros(0, 0);
    for y in subsets {
        if y.is_empty() {
            continue; // det(L_∅) = 1, log 0.0
        }
        kernel.principal_submatrix_into(y, &mut sub);
        total += cholesky::logdet_pd_with(&sub, &mut chol)?;
    }
    Ok(total / subsets.len() as f64 - normalizer)
}

/// `log det(L_Y)`; the empty set has determinant 1 (log 0.0).
pub fn subset_logdet(kernel: &Kernel, y: &[usize]) -> Result<f64> {
    if y.is_empty() {
        return Ok(0.0);
    }
    let sub = kernel.principal_submatrix(y);
    Ok(Cholesky::factor(&sub)?.logdet())
}

/// Exact probability `P(Y) = det(L_Y)/det(L+I)` (log-space).
pub fn log_prob(kernel: &Kernel, y: &[usize]) -> Result<f64> {
    Ok(subset_logdet(kernel, y)? - kernel.logdet_l_plus_i()?)
}

/// The full-gradient helper matrix `Θ = (1/n) Σ_i U_i L_{Y_i}⁻¹ U_iᵀ`
/// (dense). The gradient of φ is `Δ = Θ − (L+I)⁻¹` (Eq. 4).
///
/// The `O(nκ³)` subset inversions are embarrassingly parallel and run
/// across threads; the `O(nκ²)` scatter is serial (it would contend on
/// Θ) — see EXPERIMENTS.md §Perf.
pub fn theta_dense(kernel: &Kernel, subsets: &[Vec<usize>]) -> Result<Matrix> {
    let n = kernel.n();
    let mut theta = Matrix::zeros(n, n);
    let w = 1.0 / subsets.len().max(1) as f64;
    // Parallel phase: per-subset L_Y⁻¹.
    let nthreads = crate::linalg::matmul::available_threads().min(subsets.len().max(1));
    let inverses: Vec<Result<Option<Matrix>>> = if nthreads > 1 && subsets.len() > 8 {
        let results: Vec<std::sync::Mutex<Vec<(usize, Result<Option<Matrix>>)>>> =
            (0..nthreads).map(|_| std::sync::Mutex::new(Vec::new())).collect();
        std::thread::scope(|s| {
            for t in 0..nthreads {
                let bucket = &results[t];
                let subsets = &subsets;
                s.spawn(move || {
                    let mut local = Vec::new();
                    let mut i = t;
                    while i < subsets.len() {
                        local.push((i, invert_subset(kernel, &subsets[i])));
                        i += nthreads;
                    }
                    *bucket.lock().unwrap() = local;
                });
            }
        });
        let mut ordered: Vec<Option<Result<Option<Matrix>>>> =
            (0..subsets.len()).map(|_| None).collect();
        for bucket in results {
            for (i, r) in bucket.into_inner().unwrap() {
                ordered[i] = Some(r);
            }
        }
        ordered.into_iter().map(|o| o.expect("all indices covered")).collect()
    } else {
        subsets.iter().map(|y| invert_subset(kernel, y)).collect()
    };
    // Serial scatter.
    for (y, inv) in subsets.iter().zip(inverses) {
        if let Some(inv) = inv? {
            scatter_inverse(&mut theta, y, &inv, w);
        }
    }
    Ok(theta)
}

fn invert_subset(kernel: &Kernel, y: &[usize]) -> Result<Option<Matrix>> {
    if y.is_empty() {
        return Ok(None);
    }
    let sub = kernel.principal_submatrix(y);
    Ok(Some(Cholesky::factor(&sub)?.inverse()))
}

fn scatter_inverse(theta: &mut Matrix, y: &[usize], inv: &Matrix, w: f64) {
    for (a, &i) in y.iter().enumerate() {
        let row = inv.row(a);
        for (b, &j) in y.iter().enumerate() {
            let v = theta.get(i, j) + w * row[b];
            theta.set(i, j, v);
        }
    }
}

/// Scatter `w · U_Y L_Y⁻¹ U_Yᵀ` onto `theta`.
pub fn accumulate_theta(
    theta: &mut Matrix,
    kernel: &Kernel,
    y: &[usize],
    w: f64,
) -> Result<()> {
    if y.is_empty() {
        return Ok(());
    }
    let sub = kernel.principal_submatrix(y);
    let inv = Cholesky::factor(&sub)?.inverse();
    for (a, &i) in y.iter().enumerate() {
        let row = inv.row(a);
        for (b, &j) in y.iter().enumerate() {
            let v = theta.get(i, j) + w * row[b];
            theta.set(i, j, v);
        }
    }
    Ok(())
}

/// Sparse Θ accumulation (for stochastic updates / §3.3 clustering).
pub fn theta_sparse(
    kernel: &Kernel,
    subsets: &[Vec<usize>],
    weight: f64,
) -> Result<crate::linalg::SparseMatrix> {
    let mut b = crate::linalg::SparseBuilder::new(kernel.n());
    for y in subsets {
        if y.is_empty() {
            continue;
        }
        let sub = kernel.principal_submatrix(y);
        let inv = Cholesky::factor(&sub)?.inverse();
        b.scatter_block(y, &inv, weight)?;
    }
    Ok(b.build())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = rng.paper_init_kernel(n);
        m.scale_mut(1.0 / n as f64);
        m.add_diag_mut(0.2);
        m
    }

    #[test]
    fn structured_matches_dense_likelihood() {
        let k = Kernel::Kron2(spd(3, 1), spd(4, 2));
        let full = Kernel::Full(k.to_dense());
        let subsets = vec![vec![0, 5, 7], vec![1], vec![2, 3, 4, 10]];
        let a = log_likelihood(&k, &subsets).unwrap();
        let b = log_likelihood(&full, &subsets).unwrap();
        assert!((a - b).abs() < 1e-8, "{a} vs {b}");
    }

    #[test]
    fn probabilities_normalize_on_tiny_ground_set() {
        // Σ_Y det(L_Y) = det(L + I): enumerate all subsets of a 4-item set.
        let l = spd(4, 3);
        let k = Kernel::Full(l);
        let mut total = 0.0;
        for mask in 0u32..16 {
            let y: Vec<usize> = (0..4).filter(|&i| mask >> i & 1 == 1).collect();
            total += log_prob(&k, &y).unwrap().exp();
        }
        assert!((total - 1.0).abs() < 1e-8, "total {total}");
    }

    #[test]
    fn empty_subset_logdet_zero() {
        let k = Kernel::Full(spd(3, 4));
        assert_eq!(subset_logdet(&k, &[]).unwrap(), 0.0);
    }

    #[test]
    fn theta_dense_symmetric_and_psd_on_support() {
        let k = Kernel::Full(spd(6, 5));
        let subsets = vec![vec![0, 2, 4], vec![1, 2], vec![3]];
        let theta = theta_dense(&k, &subsets).unwrap();
        assert!(theta.is_symmetric(1e-10));
        // Untouched items have zero rows.
        assert_eq!(theta[(5, 5)], 0.0);
        // Diagonal of Θ is positive where items occur.
        assert!(theta[(0, 0)] > 0.0);
        assert!(theta[(3, 3)] > 0.0);
    }

    #[test]
    fn theta_sparse_matches_dense() {
        let k = Kernel::Kron2(spd(2, 6), spd(3, 7));
        let subsets = vec![vec![0, 3], vec![1, 2, 5]];
        let dense = theta_dense(&k, &subsets).unwrap();
        let sparse = theta_sparse(&k, &subsets, 1.0 / 2.0).unwrap();
        assert!(sparse.to_dense().rel_diff(&dense) < 1e-12);
    }

    #[test]
    fn likelihood_increases_for_better_kernel() {
        // A kernel whose submatrices match observed co-occurrence should
        // beat a mismatched one: sample pairs {0,1}, compare a kernel with
        // strong {0,1} diversity vs one with near-duplicate items 0,1.
        let good = Matrix::from_rows(&[
            &[1.0, 0.0, 0.0],
            &[0.0, 1.0, 0.0],
            &[0.0, 0.0, 0.1],
        ])
        .unwrap();
        let mut bad = good.clone();
        bad.set(0, 1, 0.95);
        bad.set(1, 0, 0.95);
        let subsets = vec![vec![0, 1]; 5];
        let lg = log_likelihood(&Kernel::Full(good), &subsets).unwrap();
        let lb = log_likelihood(&Kernel::Full(bad), &subsets).unwrap();
        assert!(lg > lb);
    }
}
