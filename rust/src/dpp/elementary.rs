//! Elementary symmetric polynomials over eigenvalue sets.
//!
//! `e_k(λ₁..λ_N)` drives k-DPP sampling (Kulesza & Taskar, ref. [16]):
//! the probability that the sampled elementary DPP uses eigenvector `n`
//! given a target cardinality `k` involves ratios `e_{k-1}^{n-1}/e_k^n`.
//! Computed with the standard `O(Nk)` dynamic program, in log-space-safe
//! normalized form (we rescale rows to avoid overflow for large N).

/// Table of elementary symmetric polynomials.
///
/// `e[n][j] = e_j(λ₁..λ_n)` for `0 ≤ j ≤ k`, with a per-row scaling factor
/// tracked in log-space for numerical stability.
pub struct ElementaryTable {
    /// e[n][j], scaled so that each row's max is O(1).
    table: Vec<Vec<f64>>,
    /// log of the scale factor applied to row n.
    log_scale: Vec<f64>,
    k: usize,
}

impl ElementaryTable {
    /// Build the DP table for eigenvalues `lambda` up to order `k`.
    pub fn new(lambda: &[f64], k: usize) -> Self {
        Self::new_with(lambda, k, crate::linalg::simd::active())
    }

    /// [`ElementaryTable::new`] pinned to an explicit dispatch arm — the
    /// conformance tests use this to check the vectorized DP sweep against
    /// the forced-scalar oracle in one process.
    pub fn new_with(lambda: &[f64], k: usize, kern: &crate::linalg::simd::Kernels) -> Self {
        let n = lambda.len();
        let mut table = Vec::with_capacity(n + 1);
        let mut log_scale = Vec::with_capacity(n + 1);
        let mut row = vec![0.0; k + 1];
        row[0] = 1.0;
        table.push(row.clone());
        log_scale.push(0.0);
        for i in 1..=n {
            let prev = &table[i - 1];
            let mut cur = vec![0.0; k + 1];
            // Full-row vectorized recurrence. Entries j > min(i, k) stay
            // exactly 0: prev[j] and prev[j-1] are both zero there, and
            // `0 + λ·0` is +0.0 bit-for-bit, so sweeping the whole row is
            // bitwise identical to the old `1..=k.min(i)` loop.
            kern.dp_row(&mut cur, prev, lambda[i - 1]);
            // Rescale to avoid overflow: bring max to ~1.
            let maxv = cur.iter().fold(0.0f64, |m, &x| m.max(x.abs()));
            let mut ls = log_scale[i - 1];
            if maxv > 1e100 || (maxv > 0.0 && maxv < 1e-100) {
                kern.div_assign(&mut cur, maxv);
                ls += maxv.ln();
            }
            table.push(cur);
            log_scale.push(ls);
        }
        ElementaryTable { table, log_scale, k }
    }

    /// `log e_j(λ₁..λ_n)`; `-inf` if zero.
    pub fn log_e(&self, n: usize, j: usize) -> f64 {
        debug_assert!(j <= self.k);
        let v = self.table[n][j];
        if v <= 0.0 {
            f64::NEG_INFINITY
        } else {
            v.ln() + self.log_scale[n]
        }
    }

    /// Ratio `λ_n · e_{j-1}(λ₁..λ_{n-1}) / e_j(λ₁..λ_n)` — the probability
    /// that eigenvalue `n` (1-based) is selected when `j` picks remain.
    pub fn select_prob(&self, lambda_n: f64, n: usize, j: usize) -> f64 {
        let num = self.log_e(n - 1, j - 1);
        let den = self.log_e(n, j);
        if den == f64::NEG_INFINITY {
            return 0.0;
        }
        (lambda_n.ln() + num - den).exp().clamp(0.0, 1.0)
    }

    /// Draw one k-subset of eigenvector indices (`P(J) ∝ Π_{i∈J} λ_i`,
    /// `|J| = k`) from this prebuilt table. `lambda` must be the spectrum
    /// the table was built from. The batched sampling engine shares one
    /// table across many draws of the same `k`, amortizing the `O(Nk)` DP.
    pub fn sample(&self, lambda: &[f64], rng: &mut crate::rng::Rng) -> Vec<usize> {
        let n = lambda.len();
        let k = self.k;
        assert!(k <= n, "k-DPP: k > N");
        let mut j = k;
        let mut out = Vec::with_capacity(k);
        for i in (1..=n).rev() {
            if j == 0 {
                break;
            }
            if i == j {
                // Must take all remaining.
                for t in (0..i).rev() {
                    out.push(t);
                }
                break;
            }
            let p = self.select_prob(lambda[i - 1], i, j);
            if rng.bernoulli(p) {
                out.push(i - 1);
                j -= 1;
            }
        }
        out.reverse();
        out
    }
}

/// Sample a k-subset of eigenvector indices with `P(J) ∝ Π_{i∈J} λ_i`
/// constrained to `|J| = k` (phase 1 of k-DPP sampling). Builds the DP
/// table for a single draw; use [`ElementaryTable::sample`] to share the
/// table across draws.
pub fn sample_k_eigenvectors(
    lambda: &[f64],
    k: usize,
    rng: &mut crate::rng::Rng,
) -> Vec<usize> {
    assert!(k <= lambda.len(), "k-DPP: k > N");
    ElementaryTable::new(lambda, k).sample(lambda, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn matches_bruteforce_small() {
        let lam = [0.5, 1.5, 2.0, 0.25];
        let table = ElementaryTable::new(&lam, 3);
        // e_1 = sum, e_2 = pairwise products sum, e_3 = triple products sum
        let e1: f64 = lam.iter().sum();
        let mut e2 = 0.0;
        let mut e3 = 0.0;
        for i in 0..4 {
            for j in (i + 1)..4 {
                e2 += lam[i] * lam[j];
                for k in (j + 1)..4 {
                    e3 += lam[i] * lam[j] * lam[k];
                }
            }
        }
        assert!((table.log_e(4, 1) - e1.ln()).abs() < 1e-12);
        assert!((table.log_e(4, 2) - e2.ln()).abs() < 1e-12);
        assert!((table.log_e(4, 3) - e3.ln()).abs() < 1e-12);
    }

    #[test]
    fn large_n_no_overflow() {
        let lam: Vec<f64> = (0..2000).map(|i| 1.0 + (i % 7) as f64).collect();
        let table = ElementaryTable::new(&lam, 50);
        let v = table.log_e(2000, 50);
        assert!(v.is_finite() && v > 0.0);
    }

    #[test]
    fn sampler_returns_k_distinct_sorted() {
        let mut rng = Rng::new(1);
        let lam: Vec<f64> = (1..=20).map(|i| i as f64 / 10.0).collect();
        for _ in 0..50 {
            let s = sample_k_eigenvectors(&lam, 5, &mut rng);
            assert_eq!(s.len(), 5);
            for w in s.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(*s.last().unwrap() < 20);
        }
    }

    #[test]
    fn sampler_respects_weights_statistically() {
        // With λ = [10, 10, 0.01, 0.01] and k=2, indices {0,1} dominate.
        let mut rng = Rng::new(2);
        let lam = [10.0, 10.0, 0.01, 0.01];
        let mut hits01 = 0;
        let trials = 500;
        for _ in 0..trials {
            let s = sample_k_eigenvectors(&lam, 2, &mut rng);
            if s == vec![0, 1] {
                hits01 += 1;
            }
        }
        assert!(hits01 as f64 / trials as f64 > 0.95, "{hits01}/{trials}");
    }

    #[test]
    fn shared_table_matches_per_draw_tables() {
        // One prebuilt table must reproduce the exact per-draw sequence.
        let lam: Vec<f64> = (1..=15).map(|i| (i as f64 * 0.37).sin().abs() + 0.1).collect();
        let table = ElementaryTable::new(&lam, 4);
        let mut ra = Rng::new(21);
        let mut rb = Rng::new(21);
        for _ in 0..30 {
            assert_eq!(table.sample(&lam, &mut ra), sample_k_eigenvectors(&lam, 4, &mut rb));
        }
    }

    #[test]
    fn k_equals_n_takes_all() {
        let mut rng = Rng::new(3);
        let lam = [1.0, 2.0, 3.0];
        let s = sample_k_eigenvectors(&lam, 3, &mut rng);
        assert_eq!(s, vec![0, 1, 2]);
    }
}
