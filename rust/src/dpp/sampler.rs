//! Exact DPP sampling (Alg. 2 of the paper, after Hough et al. [12]).
//!
//! Phase 1 selects an elementary DPP: eigenvector `i` joins `J` with
//! probability `λ_i/(λ_i+1)`. Phase 2 iteratively samples items with
//! probability `(1/|V|) Σ_{v∈V} v_i²` and contracts `V` to the orthonormal
//! basis of its subspace orthogonal to `e_i`.
//!
//! The cost split is exactly the paper's §4: the eigendecomposition
//! (`O(N³)` dense, `O(N^{3/2})` Kron2, `O(N)`-ish Kron3) happens once in
//! [`Sampler::new`] and is reused across draws; each draw then costs
//! `O(Nk² + k³)`-ish for the orthonormalizations (`O(Nk³)` in the paper's
//! coarser accounting).

use crate::dpp::elementary::sample_k_eigenvectors;
use crate::dpp::kernel::{Kernel, KernelEigen};
use crate::error::Result;
use crate::linalg::qr::orthonormal_complement_coord;
use crate::linalg::Matrix;
use crate::rng::Rng;

/// A reusable exact sampler holding the kernel's eigendecomposition.
pub struct Sampler {
    eigen: KernelEigen,
    n: usize,
}

impl Sampler {
    /// Eigendecompose `kernel` (the expensive, once-per-kernel step).
    pub fn new(kernel: &Kernel) -> Result<Self> {
        let eigen = kernel.eigen()?;
        let n = kernel.n();
        Ok(Sampler { eigen, n })
    }

    /// Ground-set size.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Borrow the eigendecomposition (e.g. to inspect the spectrum).
    pub fn eigen(&self) -> &KernelEigen {
        &self.eigen
    }

    /// Draw one subset `Y ~ DPP(L)`.
    pub fn sample(&self, rng: &mut Rng) -> Vec<usize> {
        // Phase 1: elementary DPP selection.
        let mut j = Vec::new();
        for (i, &lam) in self.eigen.values.iter().enumerate() {
            let lam = lam.max(0.0); // clamp tiny negative round-off
            if rng.bernoulli(lam / (lam + 1.0)) {
                j.push(i);
            }
        }
        self.sample_phase2(&j, rng)
    }

    /// Draw one subset of fixed size `k` (k-DPP, ref. [16]).
    pub fn sample_k(&self, k: usize, rng: &mut Rng) -> Vec<usize> {
        let lam: Vec<f64> = self.eigen.values.iter().map(|&l| l.max(0.0)).collect();
        let j = sample_k_eigenvectors(&lam, k, rng);
        self.sample_phase2(&j, rng)
    }

    /// Phase 2 of Alg. 2 given selected eigenvector indices.
    fn sample_phase2(&self, j: &[usize], rng: &mut Rng) -> Vec<usize> {
        if j.is_empty() {
            return Vec::new();
        }
        // Gather eigenvectors into V (N×k): O(Nk) thanks to the Kronecker
        // column structure (§4's "k eigenvectors in O(kN)").
        let mut v: Matrix = self.eigen.vectors.gather(j);
        let mut y = Vec::with_capacity(j.len());
        let mut weights = vec![0.0f64; self.n];
        while v.cols() > 0 {
            // P(item i) = (1/|V|) Σ_j V[i,j]².
            for i in 0..self.n {
                let row = v.row(i);
                weights[i] = row.iter().map(|x| x * x).sum();
            }
            let item = rng.weighted_index(&weights);
            y.push(item);
            // Contract V to the orthonormal basis orthogonal to e_item.
            v = orthonormal_complement_coord(&v, item);
        }
        y.sort_unstable();
        y
    }
}

/// Empirical inclusion frequencies over `draws` samples — used by the
/// statistical tests to check `P(i ∈ Y) = K_ii`.
pub fn empirical_marginals(sampler: &Sampler, draws: usize, rng: &mut Rng) -> Vec<f64> {
    let mut counts = vec![0usize; sampler.n()];
    for _ in 0..draws {
        for i in sampler.sample(rng) {
            counts[i] += 1;
        }
    }
    counts.into_iter().map(|c| c as f64 / draws as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = rng.paper_init_kernel(n);
        m.scale_mut(1.0 / n as f64);
        m.add_diag_mut(0.2);
        m
    }

    #[test]
    fn samples_are_valid_subsets() {
        let k = Kernel::Kron2(spd(3, 1), spd(4, 2));
        let s = Sampler::new(&k).unwrap();
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let y = s.sample(&mut rng);
            for w in y.windows(2) {
                assert!(w[0] < w[1], "sorted unique");
            }
            assert!(y.iter().all(|&i| i < 12));
        }
    }

    #[test]
    fn marginals_match_k_diagonal() {
        // P(i ∈ Y) = K_ii where K = L(L+I)^{-1}.
        let kernel = Kernel::Full(spd(6, 3));
        let s = Sampler::new(&kernel).unwrap();
        let mut rng = Rng::new(11);
        let draws = 6000;
        let emp = empirical_marginals(&s, draws, &mut rng);
        let marg = kernel.marginal_kernel().unwrap();
        for i in 0..6 {
            let expect = marg[(i, i)];
            let se = (expect * (1.0 - expect) / draws as f64).sqrt();
            assert!(
                (emp[i] - expect).abs() < 5.0 * se + 0.01,
                "item {i}: emp {} vs K_ii {expect}",
                emp[i]
            );
        }
    }

    #[test]
    fn kron_marginals_match_dense_marginals() {
        let k1 = spd(2, 4);
        let k2 = spd(3, 5);
        let kron_kernel = Kernel::Kron2(k1.clone(), k2.clone());
        let s = Sampler::new(&kron_kernel).unwrap();
        let mut rng = Rng::new(13);
        let draws = 6000;
        let emp = empirical_marginals(&s, draws, &mut rng);
        let marg = kron_kernel.marginal_kernel().unwrap();
        for i in 0..6 {
            let expect = marg[(i, i)];
            let se = (expect * (1.0 - expect) / draws as f64).sqrt();
            assert!(
                (emp[i] - expect).abs() < 5.0 * se + 0.01,
                "item {i}: emp {} vs {expect}",
                emp[i]
            );
        }
    }

    #[test]
    fn expected_size_matches_sum_of_k_diagonal() {
        let kernel = Kernel::Kron2(spd(3, 6), spd(3, 7));
        let s = Sampler::new(&kernel).unwrap();
        let mut rng = Rng::new(17);
        let draws = 4000;
        let mean_size: f64 =
            (0..draws).map(|_| s.sample(&mut rng).len() as f64).sum::<f64>() / draws as f64;
        let expect: f64 = kernel.marginal_kernel().unwrap().trace();
        assert!((mean_size - expect).abs() < 0.15, "mean {mean_size} vs {expect}");
    }

    #[test]
    fn k_dpp_returns_exact_size() {
        let kernel = Kernel::Kron2(spd(3, 8), spd(4, 9));
        let s = Sampler::new(&kernel).unwrap();
        let mut rng = Rng::new(19);
        for k in [1usize, 3, 5] {
            for _ in 0..20 {
                let y = s.sample_k(k, &mut rng);
                assert_eq!(y.len(), k);
            }
        }
    }

    #[test]
    fn diverse_pair_preferred_over_duplicate_pair() {
        // Items 0,1 nearly identical; items 0,2 orthogonal. DPP should
        // co-select {0,2} far more often than {0,1}.
        let l = Matrix::from_rows(&[
            &[1.0, 0.98, 0.0],
            &[0.98, 1.0, 0.0],
            &[0.0, 0.0, 1.0],
        ])
        .unwrap();
        let s = Sampler::new(&Kernel::Full(l)).unwrap();
        let mut rng = Rng::new(23);
        let (mut both01, mut both02) = (0, 0);
        for _ in 0..3000 {
            let y = s.sample(&mut rng);
            if y.contains(&0) && y.contains(&1) {
                both01 += 1;
            }
            if y.contains(&0) && y.contains(&2) {
                both02 += 1;
            }
        }
        assert!(both02 > 10 * both01.max(1), "{both02} vs {both01}");
    }

    #[test]
    fn empty_spectrum_gives_empty_sets() {
        let l = Matrix::diag(&[1e-12, 1e-12]);
        let s = Sampler::new(&Kernel::Full(l)).unwrap();
        let mut rng = Rng::new(29);
        let sizes: usize = (0..200).map(|_| s.sample(&mut rng).len()).sum();
        assert_eq!(sizes, 0);
    }
}
