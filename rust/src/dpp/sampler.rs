//! Exact DPP sampling (Alg. 2 of the paper, after Hough et al. [12]) —
//! incremental, batched engine.
//!
//! Phase 1 selects an elementary DPP: eigenvector `i` joins `J` with
//! probability `λ_i/(λ_i+1)` (or, for k-DPPs, via elementary symmetric
//! polynomials). Phase 2 iteratively samples items with probability
//! `(1/|V|) Σ_{v∈V} v_i²` and contracts `V` to the orthonormal basis of
//! its subspace orthogonal to `e_i`.
//!
//! The cost split is exactly the paper's §4: the eigendecomposition
//! (`O(N³)` dense, `O(N^{3/2})` Kron2, `O(N)`-ish Kron3) happens once in
//! [`Sampler::new`] and is reused across draws. Phase 2 is implemented
//! incrementally:
//!
//! - the contraction is one Householder reflection in coefficient space
//!   ([`crate::linalg::qr::contract_orthonormal_coord`]), `O(Nk)` per step
//!   instead of the `O(Nk²)` Gram–Schmidt rebuild;
//! - selection weights `w_i = Σ_j V[i,j]²` are maintained by a rank-1
//!   downdate `w_i -= p_i²` (where `p` is the unit direction removed from
//!   the span) instead of a full `O(Nk)` rescan each step, with a periodic
//!   exact refresh to bound floating-point drift;
//! - all per-draw buffers (`V`, weights, Householder workspace) live in a
//!   caller-held [`SampleScratch`], so repeated draws allocate nothing
//!   beyond their result vectors.
//!
//! [`Sampler::sample_batch`] fans independent draws across threads (one
//! scratch and one deterministic RNG stream per draw), which is how the
//! serving stack amortizes the per-kernel eigendecomposition across many
//! requests.

use crate::dpp::elementary::{sample_k_eigenvectors, ElementaryTable};
use crate::dpp::kernel::{Kernel, KernelEigen};
use crate::error::Result;
use crate::linalg::eigen::SymEigenScratch;
use crate::linalg::qr::{contract_orthonormal_coord, ContractScratch};
use crate::rng::Rng;

/// Refresh the weights exactly every this many rank-1 downdates. The
/// downdate is exact in exact arithmetic; the refresh only bounds
/// accumulated round-off over long contraction chains.
const WEIGHT_REFRESH_EVERY: usize = 64;

/// Reusable per-draw workspace for the phase-2 contraction loop. Holding
/// one `SampleScratch` across draws (per thread) removes every per-draw
/// allocation except the returned subset itself.
#[derive(Default)]
pub struct SampleScratch {
    /// Selected eigenvectors, column-major (`v[j*n + i]` = row `i`, col `j`).
    v: Vec<f64>,
    /// Selection weights `w_i = Σ_j V[i,j]²`.
    weights: Vec<f64>,
    /// Householder contraction buffers (includes the dropped direction).
    contract: ContractScratch,
    /// Phase-1 eigenvector index buffer.
    j: Vec<usize>,
    /// Clamped spectrum buffer (k-DPP phase 1).
    lam: Vec<f64>,
    /// Eigensolver workspaces — including the GEMM pack buffers — reused
    /// by [`Sampler::new_with_scratch`] so a worker that assembles kernels
    /// repeatedly (the coordinator's hot-swap path) re-decomposes without
    /// heap traffic beyond the sampler's own outputs.
    pub(crate) eigen: SymEigenScratch,
}

impl SampleScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// A reusable exact sampler holding the kernel's eigendecomposition.
pub struct Sampler {
    eigen: KernelEigen,
    n: usize,
}

impl Sampler {
    /// Eigendecompose `kernel` (the expensive, once-per-kernel step).
    pub fn new(kernel: &Kernel) -> Result<Self> {
        let eigen = kernel.eigen()?;
        let n = kernel.n();
        Ok(Sampler { eigen, n })
    }

    /// [`Sampler::new`] reusing the eigensolver workspaces (and their GEMM
    /// pack buffers) held in a caller's [`SampleScratch`] — the repeated
    /// kernel-assembly path of the serving coordinator: every epoch the
    /// [`crate::coordinator::KernelRegistry`] builds (tenant creation,
    /// hot-swap publish, lazy rebuild after eviction) re-decomposes
    /// through one registry-held swap scratch instead of reallocating.
    pub fn new_with_scratch(kernel: &Kernel, scratch: &mut SampleScratch) -> Result<Self> {
        let eigen = kernel.eigen_with(&mut scratch.eigen)?;
        let n = kernel.n();
        Ok(Sampler { eigen, n })
    }

    /// Wrap an already-computed eigendecomposition (the conditioning
    /// path: [`crate::dpp::ConditionedSampler`] eigendecomposes the
    /// Schur-complement kernel of the restricted problem itself and
    /// samples through the same phase-1/phase-2 engine).
    pub(crate) fn from_eigen(eigen: KernelEigen) -> Self {
        let n = eigen.n();
        Sampler { eigen, n }
    }

    /// Ground-set size.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Borrow the eigendecomposition (e.g. to inspect the spectrum).
    pub fn eigen(&self) -> &KernelEigen {
        &self.eigen
    }

    /// Draw one subset `Y ~ DPP(L)`.
    pub fn sample(&self, rng: &mut Rng) -> Vec<usize> {
        self.sample_with_scratch(rng, &mut SampleScratch::new())
    }

    /// Draw one subset of fixed size `k` (k-DPP, ref. [16]).
    pub fn sample_k(&self, k: usize, rng: &mut Rng) -> Vec<usize> {
        self.sample_k_with_scratch(k, rng, &mut SampleScratch::new())
    }

    /// [`Sampler::sample`] with caller-held scratch: identical draws,
    /// no per-draw buffer allocation.
    pub fn sample_with_scratch(&self, rng: &mut Rng, scratch: &mut SampleScratch) -> Vec<usize> {
        let mut y = Vec::new();
        self.sample_into_with_scratch(rng, scratch, &mut y);
        y
    }

    /// [`Sampler::sample_with_scratch`] writing the draw into a caller-held
    /// result buffer — with a warmed scratch *and* a warmed `out`, a draw
    /// performs zero heap allocations (the conditioned hot path asserted
    /// by `tests/alloc_free.rs`).
    pub fn sample_into_with_scratch(
        &self,
        rng: &mut Rng,
        scratch: &mut SampleScratch,
        out: &mut Vec<usize>,
    ) {
        let mut j = std::mem::take(&mut scratch.j);
        j.clear();
        // Reserve the worst case (every eigenvector selected) once, so a
        // warmed scratch never reallocates mid-draw regardless of how many
        // eigenvectors phase 1 happens to select.
        j.reserve(self.eigen.values.len());
        for (i, &lam) in self.eigen.values.iter().enumerate() {
            let lam = lam.max(0.0); // clamp tiny negative round-off
            if rng.bernoulli(lam / (lam + 1.0)) {
                j.push(i);
            }
        }
        self.sample_phase2_into(&j, rng, scratch, out);
        scratch.j = j;
    }

    /// [`Sampler::sample_k`] with caller-held scratch.
    pub fn sample_k_with_scratch(
        &self,
        k: usize,
        rng: &mut Rng,
        scratch: &mut SampleScratch,
    ) -> Vec<usize> {
        let mut y = Vec::new();
        self.sample_k_into_with_scratch(k, rng, scratch, &mut y);
        y
    }

    /// [`Sampler::sample_k_with_scratch`] writing into a caller-held
    /// result buffer (see [`Sampler::sample_into_with_scratch`]). Note the
    /// phase-1 elementary-DP table is rebuilt per call; grouped draws
    /// should go through [`Sampler::sample_k_each`].
    pub fn sample_k_into_with_scratch(
        &self,
        k: usize,
        rng: &mut Rng,
        scratch: &mut SampleScratch,
        out: &mut Vec<usize>,
    ) {
        scratch.lam.clear();
        scratch.lam.extend(self.eigen.values.iter().map(|&l| l.max(0.0)));
        let lam = std::mem::take(&mut scratch.lam);
        let j = sample_k_eigenvectors(&lam, k, rng);
        scratch.lam = lam;
        self.sample_phase2_into(&j, rng, scratch, out);
    }

    /// Draw `draws` k-DPP subsets sequentially from one RNG, sharing a
    /// single elementary-symmetric-polynomial table (and the scratch)
    /// across the whole group, delivering each draw to `each` as soon as
    /// it completes — the coordinator's per-worker path for coalesced
    /// same-`k` request batches (streaming responses keeps head-of-group
    /// latency at one draw instead of the whole group).
    pub fn sample_k_each(
        &self,
        k: usize,
        draws: usize,
        rng: &mut Rng,
        scratch: &mut SampleScratch,
        mut each: impl FnMut(Vec<usize>),
    ) {
        assert!(k <= self.n, "k-DPP: k > N");
        scratch.lam.clear();
        scratch.lam.extend(self.eigen.values.iter().map(|&l| l.max(0.0)));
        let lam = std::mem::take(&mut scratch.lam);
        let table = ElementaryTable::new(&lam, k);
        for _ in 0..draws {
            let j = table.sample(&lam, rng);
            each(self.sample_phase2(&j, rng, scratch));
        }
        scratch.lam = lam;
    }

    /// Collecting variant of [`Sampler::sample_k_each`].
    pub fn sample_k_many(
        &self,
        k: usize,
        draws: usize,
        rng: &mut Rng,
        scratch: &mut SampleScratch,
    ) -> Vec<Vec<usize>> {
        let mut out = Vec::with_capacity(draws);
        self.sample_k_each(k, draws, rng, scratch, |y| out.push(y));
        out
    }

    /// Draw `draws` independent samples, fanned across
    /// [`crate::linalg::matmul::available_threads`] worker threads.
    /// `k = None` draws unconstrained DPP samples, `k = Some(κ)` k-DPP
    /// samples of exactly that size.
    ///
    /// Draw `d` always uses RNG stream `d` derived from `seed`, so the
    /// result is deterministic in `seed` and **independent of the thread
    /// count** — `sample_batch` on 8 threads, on 1 thread, and
    /// [`Sampler::sample_batch_threads`] all agree element-wise.
    pub fn sample_batch(&self, draws: usize, k: Option<usize>, seed: u64) -> Vec<Vec<usize>> {
        self.sample_batch_threads(draws, k, seed, crate::linalg::matmul::available_threads())
    }

    /// [`Sampler::sample_batch`] with an explicit thread count (used by the
    /// benches and tests to compare sequential vs parallel throughput).
    pub fn sample_batch_threads(
        &self,
        draws: usize,
        k: Option<usize>,
        seed: u64,
        threads: usize,
    ) -> Vec<Vec<usize>> {
        self.sample_batch_offset(0, draws, k, seed, threads)
    }

    /// Batch draws `first .. first + draws` of the stream family defined by
    /// `seed` (so chunked producers like the coordinator's sampling jobs
    /// emit exact prefixes of `sample_batch(total, ..)`).
    pub(crate) fn sample_batch_offset(
        &self,
        first: usize,
        draws: usize,
        k: Option<usize>,
        seed: u64,
        threads: usize,
    ) -> Vec<Vec<usize>> {
        if let Some(kk) = k {
            assert!(kk <= self.n, "k-DPP: k > N");
        }
        let mut out: Vec<Vec<usize>> = vec![Vec::new(); draws];
        if draws == 0 {
            return out;
        }
        // k-DPP phase 1 shares one DP table across all draws and threads.
        let shared = k.map(|kk| {
            let lam: Vec<f64> = self.eigen.values.iter().map(|&l| l.max(0.0)).collect();
            (ElementaryTable::new(&lam, kk), lam)
        });
        let run = |slots: &mut [Vec<usize>], lo: usize| {
            let mut scratch = SampleScratch::new();
            for (off, slot) in slots.iter_mut().enumerate() {
                let mut rng = draw_stream(seed, first + lo + off);
                *slot = match &shared {
                    None => self.sample_with_scratch(&mut rng, &mut scratch),
                    Some((table, lam)) => {
                        let j = table.sample(lam, &mut rng);
                        self.sample_phase2(&j, &mut rng, &mut scratch)
                    }
                };
            }
        };
        let threads = threads.clamp(1, draws);
        if threads <= 1 {
            run(&mut out, 0);
            return out;
        }
        let chunk = draws.div_ceil(threads);
        std::thread::scope(|sc| {
            let run = &run;
            let mut rest: &mut [Vec<usize>] = &mut out;
            let mut start = 0usize;
            while start < draws {
                let len = chunk.min(draws - start);
                let (head, tail) = rest.split_at_mut(len);
                rest = tail;
                let lo = start;
                sc.spawn(move || run(head, lo));
                start += len;
            }
        });
        out
    }

    /// Phase 2 of Alg. 2 given selected eigenvector indices: gather the
    /// eigenvectors in `O(Nk)` (Kronecker column structure, §4), then per
    /// selected item do one `O(N)` weight downdate plus one `O(Nk)`
    /// Householder contraction — `O(Nk²)` per draw overall, vs the
    /// `O(Nk³)`-ish full-rebuild accounting of the naive loop.
    fn sample_phase2(&self, j: &[usize], rng: &mut Rng, s: &mut SampleScratch) -> Vec<usize> {
        let mut y = Vec::with_capacity(j.len());
        self.sample_phase2_into(j, rng, s, &mut y);
        y
    }

    /// [`Sampler::sample_phase2`] into a caller-held result buffer
    /// (cleared first) — the allocation-free form once `out` has capacity.
    fn sample_phase2_into(
        &self,
        j: &[usize],
        rng: &mut Rng,
        s: &mut SampleScratch,
        y: &mut Vec<usize>,
    ) {
        y.clear();
        let n = self.n;
        let mut k = j.len();
        if k == 0 {
            return;
        }
        s.v.clear();
        s.v.resize(n * k, 0.0);
        for (c, &idx) in j.iter().enumerate() {
            self.eigen.vectors.column_into(idx, &mut s.v[c * n..(c + 1) * n]);
        }
        s.weights.clear();
        s.weights.resize(n, 0.0);
        refresh_weights(&s.v, n, k, &mut s.weights);
        let mut since_refresh = 0usize;
        while k > 0 {
            // P(item i) = (1/|V|) Σ_j V[i,j]² ∝ w_i.
            let item = rng.weighted_index(&s.weights);
            y.push(item);
            // Contract V to the orthonormal basis orthogonal to e_item.
            let downdated = contract_orthonormal_coord(&mut s.v, n, k, item, &mut s.contract);
            k -= 1;
            if k == 0 {
                break;
            }
            if downdated {
                // Rank-1 downdate: the removed direction p carries exactly
                // p_i² of each item's weight (V'V'ᵀ = VVᵀ − ppᵀ).
                for (w, &p) in s.weights.iter_mut().zip(&s.contract.dropped) {
                    *w = (*w - p * p).max(0.0);
                }
                s.weights[item] = 0.0;
                since_refresh += 1;
                if since_refresh >= WEIGHT_REFRESH_EVERY {
                    since_refresh = 0;
                    refresh_weights(&s.v, n, k, &mut s.weights);
                }
            } else {
                // Degenerate contraction: recompute from V.
                refresh_weights(&s.v, n, k, &mut s.weights);
                since_refresh = 0;
            }
        }
        y.sort_unstable();
    }
}

/// Exact weights `w_i = Σ_j V[i,j]²` from the column-major basis.
fn refresh_weights(v: &[f64], n: usize, k: usize, weights: &mut [f64]) {
    for w in weights.iter_mut() {
        *w = 0.0;
    }
    for j in 0..k {
        let col = &v[j * n..(j + 1) * n];
        for (w, &x) in weights.iter_mut().zip(col) {
            *w += x * x;
        }
    }
}

/// Deterministic per-draw RNG: draw `d` of a batch always gets the same
/// independent PCG stream, no matter how draws are partitioned across
/// threads or chunks. (SplitMix64 finalizer decorrelates the seeds;
/// distinct stream ids make the sequences independent even on collisions.)
fn draw_stream(seed: u64, draw: usize) -> Rng {
    let d = draw as u64;
    let mut z = seed ^ d.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    Rng::with_stream(z, d)
}

/// Empirical inclusion frequencies over `draws` samples — used by the
/// statistical tests to check `P(i ∈ Y) = K_ii`.
pub fn empirical_marginals(sampler: &Sampler, draws: usize, rng: &mut Rng) -> Vec<f64> {
    let mut scratch = SampleScratch::new();
    let mut counts = vec![0usize; sampler.n()];
    for _ in 0..draws {
        for i in sampler.sample_with_scratch(rng, &mut scratch) {
            counts[i] += 1;
        }
    }
    counts.into_iter().map(|c| c as f64 / draws as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::rng::Rng;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = rng.paper_init_kernel(n);
        m.scale_mut(1.0 / n as f64);
        m.add_diag_mut(0.2);
        m
    }

    #[test]
    fn samples_are_valid_subsets() {
        let k = Kernel::Kron2(spd(3, 1), spd(4, 2));
        let s = Sampler::new(&k).unwrap();
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let y = s.sample(&mut rng);
            for w in y.windows(2) {
                assert!(w[0] < w[1], "sorted unique");
            }
            assert!(y.iter().all(|&i| i < 12));
        }
    }

    // Distributional assertions (marginals vs the factored K-diagonal,
    // expected size vs Tr K, batch-path marginals, full subset laws) live
    // in the shared statistical harness — `tests/sampler_conformance.rs`
    // with `tests/common/stats.rs` — which checks every sampling backend
    // against the same oracles with chi-square and binomial-4σ bounds.
    // The unit tests below only cover mechanics and determinism.

    #[test]
    fn k_dpp_returns_exact_size() {
        let kernel = Kernel::Kron2(spd(3, 8), spd(4, 9));
        let s = Sampler::new(&kernel).unwrap();
        let mut rng = Rng::new(19);
        for k in [1usize, 3, 5] {
            for _ in 0..20 {
                let y = s.sample_k(k, &mut rng);
                assert_eq!(y.len(), k);
            }
        }
    }

    #[test]
    fn diverse_pair_preferred_over_duplicate_pair() {
        // Items 0,1 nearly identical; items 0,2 orthogonal. DPP should
        // co-select {0,2} far more often than {0,1}.
        let l = Matrix::from_rows(&[
            &[1.0, 0.98, 0.0],
            &[0.98, 1.0, 0.0],
            &[0.0, 0.0, 1.0],
        ])
        .unwrap();
        let s = Sampler::new(&Kernel::Full(l)).unwrap();
        let mut rng = Rng::new(23);
        let (mut both01, mut both02) = (0, 0);
        for _ in 0..3000 {
            let y = s.sample(&mut rng);
            if y.contains(&0) && y.contains(&1) {
                both01 += 1;
            }
            if y.contains(&0) && y.contains(&2) {
                both02 += 1;
            }
        }
        assert!(both02 > 10 * both01.max(1), "{both02} vs {both01}");
    }

    #[test]
    fn empty_spectrum_gives_empty_sets() {
        let l = Matrix::diag(&[1e-12, 1e-12]);
        let s = Sampler::new(&Kernel::Full(l)).unwrap();
        let mut rng = Rng::new(29);
        let sizes: usize = (0..200).map(|_| s.sample(&mut rng).len()).sum();
        assert_eq!(sizes, 0);
    }

    #[test]
    fn scratch_reuse_matches_fresh_scratch() {
        // Reusing one scratch across draws must not change the draws.
        let kernel = Kernel::Kron2(spd(4, 31), spd(4, 32));
        let s = Sampler::new(&kernel).unwrap();
        let mut ra = Rng::new(41);
        let mut rb = Rng::new(41);
        let mut scratch = SampleScratch::new();
        for i in 0..60 {
            let reused = if i % 2 == 0 {
                s.sample_with_scratch(&mut ra, &mut scratch)
            } else {
                s.sample_k_with_scratch(3, &mut ra, &mut scratch)
            };
            let fresh = if i % 2 == 0 {
                s.sample(&mut rb)
            } else {
                s.sample_k(3, &mut rb)
            };
            assert_eq!(reused, fresh, "draw {i} diverged");
        }
    }

    #[test]
    fn sample_k_many_matches_individual_draws() {
        let kernel = Kernel::Kron2(spd(3, 33), spd(4, 34));
        let s = Sampler::new(&kernel).unwrap();
        let mut ra = Rng::new(43);
        let mut rb = Rng::new(43);
        let mut sa = SampleScratch::new();
        let mut sb = SampleScratch::new();
        let many = s.sample_k_many(4, 25, &mut ra, &mut sa);
        for (d, y) in many.iter().enumerate() {
            assert_eq!(y, &s.sample_k_with_scratch(4, &mut rb, &mut sb), "draw {d}");
        }
    }

    #[test]
    fn batch_deterministic_and_thread_invariant() {
        let kernel = Kernel::Kron2(spd(4, 35), spd(3, 36));
        let s = Sampler::new(&kernel).unwrap();
        for k in [None, Some(3usize)] {
            let a = s.sample_batch_threads(32, k, 99, 1);
            let b = s.sample_batch_threads(32, k, 99, 4);
            let c = s.sample_batch(32, k, 99);
            assert_eq!(a, b, "thread count changed draws (k={k:?})");
            assert_eq!(a, c, "default fan-out changed draws (k={k:?})");
            let d = s.sample_batch(32, k, 100);
            assert_ne!(a, d, "seed ignored (k={k:?})");
        }
    }

    #[test]
    fn batch_offset_is_a_prefix_slice() {
        let kernel = Kernel::Kron2(spd(3, 37), spd(3, 38));
        let s = Sampler::new(&kernel).unwrap();
        let whole = s.sample_batch(20, Some(2), 7);
        let head = s.sample_batch_offset(0, 8, Some(2), 7, 2);
        let tail = s.sample_batch_offset(8, 12, Some(2), 7, 3);
        assert_eq!(&whole[..8], &head[..]);
        assert_eq!(&whole[8..], &tail[..]);
    }

    #[test]
    fn batch_k_dpp_sizes_exact() {
        let kernel = Kernel::Kron2(spd(3, 44), spd(4, 45));
        let s = Sampler::new(&kernel).unwrap();
        for y in s.sample_batch(64, Some(5), 5) {
            assert_eq!(y.len(), 5);
            assert!(y.windows(2).all(|w| w[0] < w[1]));
            assert!(y.iter().all(|&i| i < 12));
        }
    }

    #[test]
    fn scratch_built_sampler_matches_fresh_sampler() {
        // Sampler::new_with_scratch reuses eigen workspaces across kernel
        // assemblies; draws must be identical to a fresh Sampler's.
        let mut scratch = SampleScratch::new();
        for seed in [51u64, 52, 53] {
            let kernel = Kernel::Kron2(spd(4, seed), spd(3, seed + 10));
            let a = Sampler::new(&kernel).unwrap();
            let b = Sampler::new_with_scratch(&kernel, &mut scratch).unwrap();
            assert_eq!(a.sample_batch(16, None, 9), b.sample_batch(16, None, 9));
            assert_eq!(a.sample_batch(8, Some(3), 9), b.sample_batch(8, Some(3), 9));
        }
    }

    #[test]
    fn long_contraction_chain_stays_consistent() {
        // k = N forces the maximum-length downdate chain (plus refreshes):
        // a k-DPP with k = N must return the full ground set every time.
        let kernel = Kernel::Kron2(spd(4, 46), spd(4, 47));
        let s = Sampler::new(&kernel).unwrap();
        let mut rng = Rng::new(48);
        let mut scratch = SampleScratch::new();
        for _ in 0..5 {
            let y = s.sample_k_with_scratch(16, &mut rng, &mut scratch);
            assert_eq!(y, (0..16).collect::<Vec<_>>());
        }
    }
}
