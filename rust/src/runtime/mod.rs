//! PJRT runtime: load + execute the AOT-lowered JAX/Pallas artifacts from
//! the Rust request path. See DESIGN.md §3 ("Runtime") — Python runs only
//! at build time (`make artifacts`); the binary is self-contained given
//! `artifacts/`.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, HloContractions};
pub use manifest::{ArtifactSpec, Manifest};
