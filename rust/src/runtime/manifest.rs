//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. The manifest records every lowered HLO artifact with its
//! input/output shapes so call sites are validated at load time rather
//! than failing inside PJRT.

use crate::error::{Error, Result};
use crate::ser::Json;
use std::path::{Path, PathBuf};

/// One lowered artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    /// HLO-text file, relative to the artifact directory.
    pub file: PathBuf,
    /// Input shapes in call order.
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes (tuple elements) in order.
    pub outputs: Vec<Vec<usize>>,
}

impl ArtifactSpec {
    /// Total element count of input `i`.
    pub fn input_len(&self, i: usize) -> usize {
        self.inputs[i].iter().product()
    }

    /// Total element count of output `i`.
    pub fn output_len(&self, i: usize) -> usize {
        self.outputs[i].iter().product()
    }
}

/// Parsed manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `manifest.json` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (directory recorded for file resolution).
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let json = Json::parse(text)?;
        let dtype = json.get("dtype")?.as_str()?;
        if dtype != "f64" {
            return Err(Error::Runtime(format!(
                "manifest dtype '{dtype}' unsupported (runtime is f64)"
            )));
        }
        let mut artifacts = Vec::new();
        for a in json.get("artifacts")?.as_arr()? {
            let shapes = |key: &str| -> Result<Vec<Vec<usize>>> {
                a.get(key)?
                    .as_arr()?
                    .iter()
                    .map(|s| s.as_arr()?.iter().map(|d| d.as_usize()).collect())
                    .collect()
            };
            artifacts.push(ArtifactSpec {
                name: a.get("name")?.as_str()?.to_string(),
                file: PathBuf::from(a.get("file")?.as_str()?),
                inputs: shapes("inputs")?,
                outputs: shapes("outputs")?,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    /// Find an artifact by name.
    pub fn find(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Absolute path of an artifact's HLO file.
    pub fn file_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

/// Default artifact directory: `$KRONDPP_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var("KRONDPP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "dtype": "f64",
      "artifacts": [
        {"name": "krk_contractions_8x8", "file": "krk_contractions_8x8.hlo.txt",
         "inputs": [[64,64],[8,8],[8,8]], "outputs": [[8,8],[8,8]], "dtype": "f64"},
        {"name": "gram_256x64", "file": "gram_256x64.hlo.txt",
         "inputs": [[256,64]], "outputs": [[64,64]], "dtype": "f64"}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/arts"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.find("krk_contractions_8x8").unwrap();
        assert_eq!(a.inputs[0], vec![64, 64]);
        assert_eq!(a.outputs.len(), 2);
        assert_eq!(a.input_len(0), 4096);
        assert_eq!(a.output_len(1), 64);
        assert!(m.find("nope").is_none());
        assert!(m.file_path(a).ends_with("krk_contractions_8x8.hlo.txt"));
    }

    #[test]
    fn rejects_wrong_dtype() {
        let text = SAMPLE.replace("\"dtype\": \"f64\",", "\"dtype\": \"f32\",");
        assert!(Manifest::parse(Path::new("."), &text).is_err());
    }

    #[test]
    fn missing_dir_is_actionable_error() {
        let err = Manifest::load(Path::new("/definitely/not/here")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
