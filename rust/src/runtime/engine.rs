//! PJRT execution engine: loads AOT-lowered HLO-text artifacts and runs
//! them on the XLA CPU client from the Rust hot path (the `xla` crate's
//! PJRT C-API bindings; pattern adapted from /opt/xla-example/load_hlo).
//!
//! Artifacts are compiled lazily on first use and cached for the life of
//! the engine, so the steady-state request path is: wrap inputs as
//! literals → `execute` → unwrap the output tuple. Python is never
//! involved at runtime.

use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::runtime::manifest::{ArtifactSpec, Manifest};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

/// PJRT engine over an artifact directory.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

fn xerr(context: &str, e: xla::Error) -> Error {
    Error::Runtime(format!("{context}: {e}"))
}

impl Engine {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| xerr("pjrt cpu client", e))?;
        Ok(Engine { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Load from the default artifact directory
    /// (`$KRONDPP_ARTIFACTS` or `./artifacts`).
    pub fn load_default() -> Result<Engine> {
        Self::load(&crate::runtime::manifest::default_dir())
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifact specs available.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Does the engine have an artifact of this name?
    pub fn has(&self, name: &str) -> bool {
        self.manifest.find(name).is_some()
    }

    /// Compile (or fetch cached) an executable.
    fn executable(&self, name: &str) -> Result<()> {
        let mut cache = self.cache.lock().unwrap();
        if cache.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .manifest
            .find(name)
            .ok_or_else(|| Error::Runtime(format!("no artifact named '{name}'")))?;
        let path = self.manifest.file_path(spec);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
        )
        .map_err(|e| xerr(&format!("parse {}", path.display()), e))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| xerr(&format!("compile {name}"), e))?;
        cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute artifact `name` on flat `f64` buffers (shapes validated
    /// against the manifest). Returns one flat buffer per tuple output.
    pub fn execute(&self, name: &str, inputs: &[&[f64]]) -> Result<Vec<Vec<f64>>> {
        let spec = self
            .manifest
            .find(name)
            .ok_or_else(|| Error::Runtime(format!("no artifact named '{name}'")))?
            .clone();
        if inputs.len() != spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, buf) in inputs.iter().enumerate() {
            if buf.len() != spec.input_len(i) {
                return Err(Error::Runtime(format!(
                    "{name}: input {i} expects shape {:?} ({} elems), got {}",
                    spec.inputs[i],
                    spec.input_len(i),
                    buf.len()
                )));
            }
            let dims: Vec<i64> = spec.inputs[i].iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(buf)
                .reshape(&dims)
                .map_err(|e| xerr(&format!("{name}: reshape input {i}"), e))?;
            literals.push(lit);
        }
        self.executable(name)?;
        let cache = self.cache.lock().unwrap();
        let exe = cache.get(name).expect("compiled above");
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| xerr(&format!("execute {name}"), e))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| xerr(&format!("{name}: fetch result"), e))?;
        // Artifacts are lowered with return_tuple=True.
        let parts = root.to_tuple().map_err(|e| xerr(&format!("{name}: untuple"), e))?;
        if parts.len() != spec.outputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: manifest promises {} outputs, runtime returned {}",
                spec.outputs.len(),
                parts.len()
            )));
        }
        let mut out = Vec::with_capacity(parts.len());
        for (i, part) in parts.into_iter().enumerate() {
            let v: Vec<f64> =
                part.to_vec().map_err(|e| xerr(&format!("{name}: read output {i}"), e))?;
            if v.len() != spec.output_len(i) {
                return Err(Error::Runtime(format!(
                    "{name}: output {i} expects {} elems, got {}",
                    spec.output_len(i),
                    v.len()
                )));
            }
            out.push(v);
        }
        Ok(out)
    }

    /// Execute on matrices, returning matrices shaped per the manifest.
    pub fn execute_matrices(&self, name: &str, inputs: &[&Matrix]) -> Result<Vec<Matrix>> {
        let bufs: Vec<&[f64]> = inputs.iter().map(|m| m.as_slice()).collect();
        let spec_outputs: Vec<Vec<usize>> = self
            .manifest
            .find(name)
            .ok_or_else(|| Error::Runtime(format!("no artifact named '{name}'")))?
            .outputs
            .clone();
        let flat = self.execute(name, &bufs)?;
        flat.into_iter()
            .zip(spec_outputs)
            .map(|(v, shape)| {
                let (r, c) = match shape.len() {
                    2 => (shape[0], shape[1]),
                    1 => (shape[0], 1),
                    _ => (v.len(), 1),
                };
                Matrix::from_vec(r, c, v)
            })
            .collect()
    }

    /// Artifact spec accessor.
    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.manifest.find(name)
    }
}

/// [`crate::learn::krk::Contractions`] backend that routes the two Θ
/// contractions through AOT-compiled artifacts when a size variant
/// exists, falling back to the CPU implementation otherwise.
///
/// This backend consumes a dense Θ (the HLO signature is
/// `(Θ, L₁, L₂) → (A₁, A₂)`), so it relies on the trait's default
/// `contract_compressed`, which synthesizes Θ from the compressed
/// statistics before dispatching here — the learner stays correct at the
/// backend's native `O(N²)` cost. Re-lowering the artifacts against the
/// CSR arena (`O(nκ²)` on device) is the natural next step; see
/// `crate::learn::stats` for the CPU reference semantics.
pub struct HloContractions {
    engine: Engine,
}

impl HloContractions {
    pub fn new(engine: Engine) -> Self {
        HloContractions { engine }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    fn artifact_name(n1: usize, n2: usize) -> String {
        format!("krk_contractions_{n1}x{n2}")
    }

    /// True if this (n1, n2) has a lowered variant.
    pub fn supports(&self, n1: usize, n2: usize) -> bool {
        self.engine.has(&Self::artifact_name(n1, n2))
    }
}

impl crate::learn::krk::Contractions for HloContractions {
    fn block_trace(&self, theta: &Matrix, l2: &Matrix, n1: usize, n2: usize) -> Result<Matrix> {
        let name = Self::artifact_name(n1, n2);
        if !self.engine.has(&name) {
            return crate::linalg::kron::block_trace(theta, l2, n1, n2);
        }
        // The artifact computes both contractions; L1 is only used for A2,
        // pass zeros (same shapes) and keep A1.
        let zero_l1 = Matrix::zeros(n1, n1);
        let out = self.engine.execute_matrices(&name, &[theta, &zero_l1, l2])?;
        Ok(out.into_iter().next().expect("two outputs"))
    }

    fn weighted_block_sum(
        &self,
        theta: &Matrix,
        w: &Matrix,
        n1: usize,
        n2: usize,
    ) -> Result<Matrix> {
        let name = Self::artifact_name(n1, n2);
        if !self.engine.has(&name) {
            return crate::linalg::kron::weighted_block_sum(theta, w, n1, n2);
        }
        let zero_l2 = Matrix::zeros(n2, n2);
        let out = self.engine.execute_matrices(&name, &[theta, w, &zero_l2])?;
        Ok(out.into_iter().nth(1).expect("two outputs"))
    }
}

// `xla::PjRtClient` wraps a thread-safe C++ client; executions are
// synchronized by the cache mutex at compile time and PJRT internally at
// run time.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}
