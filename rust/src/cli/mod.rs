//! Command-line parsing substrate (clap is not available offline).
//!
//! Grammar: `binary <subcommand> [--flag value | --switch] [positional...]`.
//! Flags may be given as `--key value` or `--key=value`. Unknown flags are
//! an error, which keeps typos from silently running a default experiment.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// Parsed arguments for one subcommand invocation.
#[derive(Debug, Default)]
pub struct Args {
    /// Subcommand name (first non-flag token), if any.
    pub command: Option<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    positional: Vec<String>,
    known: Vec<(String, String)>, // (name, help)
}

impl Args {
    /// Parse from an iterator of raw tokens (usually `std::env::args().skip(1)`).
    /// `switch_names` lists flags that take no value.
    pub fn parse(
        tokens: impl IntoIterator<Item = String>,
        switch_names: &[&str],
    ) -> Result<Args> {
        let mut args = Args::default();
        let mut iter = tokens.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if switch_names.contains(&stripped) {
                    args.switches.push(stripped.to_string());
                } else {
                    let v = iter.next().ok_or_else(|| {
                        Error::Parse(format!("flag --{stripped} expects a value"))
                    })?;
                    args.flags.insert(stripped.to_string(), v);
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Register a known flag for `usage()`; returns self for chaining.
    pub fn describe(mut self, name: &str, help: &str) -> Self {
        self.known.push((name.to_string(), help.to_string()));
        self
    }

    /// Get a string flag.
    pub fn str_flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Get a required string flag.
    pub fn require_str(&self, name: &str) -> Result<&str> {
        self.str_flag(name)
            .ok_or_else(|| Error::Parse(format!("missing required flag --{name}")))
    }

    /// Get a parsed flag with default.
    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Parse(format!("flag --{name}: cannot parse '{v}'"))),
        }
    }

    /// Get an optional parsed flag.
    pub fn get_opt<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>> {
        match self.flags.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| Error::Parse(format!("flag --{name}: cannot parse '{v}'"))),
        }
    }

    /// Was a boolean switch present?
    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    /// Positional arguments after the subcommand.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Reject any flag not in `allowed` (catches typos).
    pub fn check_known(&self, allowed: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !allowed.contains(&k.as_str()) {
                return Err(Error::Parse(format!("unknown flag --{k}")));
            }
        }
        for s in &self.switches {
            if !allowed.contains(&s.as_str()) {
                return Err(Error::Parse(format!("unknown switch --{s}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_command_flags_positional() {
        let a = Args::parse(toks("learn --n1 100 --algo krk data.kds"), &[]).unwrap();
        assert_eq!(a.command.as_deref(), Some("learn"));
        assert_eq!(a.str_flag("n1"), Some("100"));
        assert_eq!(a.str_flag("algo"), Some("krk"));
        assert_eq!(a.positional(), &["data.kds".to_string()]);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(toks("x --n1=42"), &[]).unwrap();
        assert_eq!(a.get_or::<usize>("n1", 0).unwrap(), 42);
    }

    #[test]
    fn switches() {
        let a = Args::parse(toks("x --verbose --n 3"), &["verbose"]).unwrap();
        assert!(a.switch("verbose"));
        assert!(!a.switch("quiet"));
        assert_eq!(a.get_or::<usize>("n", 0).unwrap(), 3);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(toks("x --n1"), &[]).is_err());
    }

    #[test]
    fn typed_parse_errors() {
        let a = Args::parse(toks("x --n abc"), &[]).unwrap();
        assert!(a.get_or::<usize>("n", 0).is_err());
        assert!(a.get_opt::<f64>("n").is_err());
        assert_eq!(a.get_opt::<f64>("missing").unwrap(), None);
    }

    #[test]
    fn require_and_unknown_checks() {
        let a = Args::parse(toks("x --good 1 --bad 2"), &[]).unwrap();
        assert!(a.require_str("good").is_ok());
        assert!(a.require_str("absent").is_err());
        assert!(a.check_known(&["good"]).is_err());
        assert!(a.check_known(&["good", "bad"]).is_ok());
    }
}
