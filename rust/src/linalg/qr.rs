//! QR factorization (Householder) and orthonormalization helpers.
//!
//! The exact DPP sampler (Alg. 2) repeatedly replaces its eigenvector set
//! `V` by an orthonormal basis of the subspace of `V` orthogonal to a
//! coordinate vector `e_i`; [`orthonormal_complement_coord`] implements that
//! step, and the general [`Qr`] supports the low-rank and Nyström-style
//! utilities.

use super::matrix::Matrix;
use crate::error::{Error, Result};
use crate::linalg::matmul::dot;

/// Householder QR: `A = Q·R` with `Q` (m×k) having orthonormal columns and
/// `R` (k×k) upper-triangular, `k = min(m, n)` (thin QR).
pub struct Qr {
    /// Orthonormal factor (thin).
    pub q: Matrix,
    /// Upper-triangular factor.
    pub r: Matrix,
}

impl Qr {
    /// Factor a (possibly rectangular, m ≥ n preferred) matrix.
    pub fn factor(a: &Matrix) -> Result<Self> {
        let (m, n) = a.shape();
        if m == 0 || n == 0 {
            return Err(Error::Shape("qr: empty matrix".into()));
        }
        let k = m.min(n);
        let mut work = a.clone();
        // Householder vectors stored per reflection.
        let mut vs: Vec<Vec<f64>> = Vec::with_capacity(k);
        for j in 0..k {
            // Build reflector for column j, rows j..m.
            let mut v: Vec<f64> = (j..m).map(|i| work.get(i, j)).collect();
            let alpha = -v[0].signum() * v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if alpha.abs() < f64::EPSILON {
                vs.push(vec![0.0; m - j]);
                continue;
            }
            v[0] -= alpha;
            let vnorm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if vnorm > 0.0 {
                for x in &mut v {
                    *x /= vnorm;
                }
            }
            // Apply reflector: work[j.., j..] -= 2 v (vᵀ work[j.., j..])
            for col in j..n {
                let mut proj = 0.0;
                for (i, vi) in v.iter().enumerate() {
                    proj += vi * work.get(j + i, col);
                }
                let proj2 = 2.0 * proj;
                for (i, vi) in v.iter().enumerate() {
                    let val = work.get(j + i, col) - proj2 * vi;
                    work.set(j + i, col, val);
                }
            }
            vs.push(v);
        }
        // R = leading k×n upper triangle of work.
        let mut r = Matrix::zeros(k, n);
        for i in 0..k {
            for j in i..n {
                r.set(i, j, work.get(i, j));
            }
        }
        // Q = (H_0 H_1 ... H_{k-1}) applied to identity columns 0..k.
        let mut q = Matrix::zeros(m, k);
        for i in 0..k {
            q.set(i, i, 1.0);
        }
        for j in (0..k).rev() {
            let v = &vs[j];
            if v.iter().all(|&x| x == 0.0) {
                continue;
            }
            for col in 0..k {
                let mut proj = 0.0;
                for (i, vi) in v.iter().enumerate() {
                    proj += vi * q.get(j + i, col);
                }
                let proj2 = 2.0 * proj;
                for (i, vi) in v.iter().enumerate() {
                    let val = q.get(j + i, col) - proj2 * vi;
                    q.set(j + i, col, val);
                }
            }
        }
        Ok(Qr { q, r })
    }
}

/// Orthonormalize the columns of `a` via modified Gram–Schmidt, dropping
/// columns whose residual norm falls below `tol` (rank-revealing-lite).
/// Returns a matrix whose columns form an orthonormal basis of span(a).
pub fn orthonormalize_columns(a: &Matrix, tol: f64) -> Matrix {
    let (m, n) = a.shape();
    // Work column-major for contiguous access.
    let at = a.transpose();
    let mut basis: Vec<Vec<f64>> = Vec::new();
    for j in 0..n {
        let mut v = at.row(j).to_vec();
        // Two rounds of MGS for numerical orthogonality.
        for _ in 0..2 {
            for b in &basis {
                let proj = dot(b, &v);
                for (vi, bi) in v.iter_mut().zip(b) {
                    *vi -= proj * bi;
                }
            }
        }
        let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm > tol {
            for x in &mut v {
                *x /= norm;
            }
            basis.push(v);
        }
    }
    let k = basis.len();
    let mut q = Matrix::zeros(m, k);
    for (j, b) in basis.iter().enumerate() {
        for i in 0..m {
            q.set(i, j, b[i]);
        }
    }
    q
}

/// Given orthonormal columns `V` (m×k), return an orthonormal basis of the
/// subspace `{x ∈ span(V) : x[coord] = 0}` — the `V⊥` step of DPP sampling
/// (Alg. 2). Output has k−1 columns (or fewer if span degenerates).
pub fn orthonormal_complement_coord(v: &Matrix, coord: usize) -> Matrix {
    let (m, k) = v.shape();
    debug_assert!(coord < m);
    if k == 0 {
        return Matrix::zeros(m, 0);
    }
    // Find the column with the largest |v[coord, j]| to use as the pivot.
    let mut pivot = 0usize;
    let mut pmax = 0.0f64;
    for j in 0..k {
        let val = v.get(coord, j).abs();
        if val > pmax {
            pmax = val;
            pivot = j;
        }
    }
    if pmax < 1e-14 {
        // Subspace already orthogonal to e_coord: drop nothing but one
        // dimension must still go (degenerate); return first k-1 columns.
        let idx: Vec<usize> = (0..k.saturating_sub(1)).collect();
        return v.select_cols(&idx);
    }
    let vt = v.transpose(); // rows are columns of v
    let pcol = vt.row(pivot).to_vec();
    let pval = pcol[coord];
    // Subtract multiples of the pivot column so every other column has a
    // zero at `coord`, then orthonormalize.
    let mut reduced = Matrix::zeros(m, k - 1);
    let mut out_j = 0usize;
    for j in 0..k {
        if j == pivot {
            continue;
        }
        let cj = vt.row(j);
        let factor = cj[coord] / pval;
        for i in 0..m {
            reduced.set(i, out_j, cj[i] - factor * pcol[i]);
        }
        out_j += 1;
    }
    orthonormalize_columns(&reduced, 1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{matmul, matmul_tn};

    fn rnd(m: usize, n: usize, seed: u64) -> Matrix {
        let mut state = seed | 1;
        Matrix::from_fn(m, n, |_, _| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state as f64 / u64::MAX as f64) - 0.5
        })
    }

    #[test]
    fn qr_reconstructs() {
        let a = rnd(20, 12, 1);
        let qr = Qr::factor(&a).unwrap();
        let rec = matmul(&qr.q, &qr.r).unwrap();
        assert!(rec.rel_diff(&a) < 1e-11);
    }

    #[test]
    fn q_orthonormal() {
        let a = rnd(15, 15, 2);
        let qr = Qr::factor(&a).unwrap();
        let qtq = matmul_tn(&qr.q, &qr.q).unwrap();
        assert!(qtq.rel_diff(&Matrix::identity(15)) < 1e-11);
    }

    #[test]
    fn r_upper_triangular() {
        let a = rnd(10, 8, 3);
        let qr = Qr::factor(&a).unwrap();
        for i in 0..qr.r.rows() {
            for j in 0..i.min(qr.r.cols()) {
                assert!(qr.r.get(i, j).abs() < 1e-13);
            }
        }
    }

    #[test]
    fn orthonormalize_drops_dependent_columns() {
        let mut a = rnd(10, 3, 4);
        // Make column 2 a copy of column 0.
        for i in 0..10 {
            let v = a.get(i, 0);
            a.set(i, 2, v);
        }
        let q = orthonormalize_columns(&a, 1e-10);
        assert_eq!(q.cols(), 2);
        let qtq = matmul_tn(&q, &q).unwrap();
        assert!(qtq.rel_diff(&Matrix::identity(2)) < 1e-11);
    }

    #[test]
    fn complement_zeroes_coordinate() {
        let a = rnd(8, 4, 5);
        let q = orthonormalize_columns(&a, 1e-12);
        assert_eq!(q.cols(), 4);
        let comp = orthonormal_complement_coord(&q, 3);
        assert_eq!(comp.cols(), 3);
        // Every basis vector has zero at coordinate 3.
        for j in 0..comp.cols() {
            assert!(comp.get(3, j).abs() < 1e-10, "coord leak {}", comp.get(3, j));
        }
        // Still orthonormal.
        let ctc = matmul_tn(&comp, &comp).unwrap();
        assert!(ctc.rel_diff(&Matrix::identity(comp.cols())) < 1e-10);
        // Still inside span(q): projecting onto q's span preserves them.
        let qt_c = matmul_tn(&q, &comp).unwrap();
        let back = matmul(&q, &qt_c).unwrap();
        assert!(back.rel_diff(&comp) < 1e-10);
    }

    #[test]
    fn complement_when_already_orthogonal() {
        // Basis = {e0, e1}; complement w.r.t. coordinate 3 keeps dimension-1.
        let mut v = Matrix::zeros(4, 2);
        v.set(0, 0, 1.0);
        v.set(1, 1, 1.0);
        let comp = orthonormal_complement_coord(&v, 3);
        assert_eq!(comp.cols(), 1);
    }
}
