//! QR factorization (Householder) and orthonormalization helpers.
//!
//! The exact DPP sampler (Alg. 2) repeatedly replaces its eigenvector set
//! `V` by an orthonormal basis of the subspace of `V` orthogonal to a
//! coordinate vector `e_i`. Two implementations live here:
//!
//! - [`orthonormal_complement_coord`]: the allocating reference path
//!   (pivoted elimination + modified Gram–Schmidt, `O(nk²)` per call);
//! - [`contract_orthonormal_coord`]: the in-place workspace variant used by
//!   the batched sampling engine — a single Householder reflection in
//!   coefficient space (`O(nk)` per call) that also exposes the dropped
//!   unit direction so selection weights can be rank-1-downdated instead
//!   of rescanned.
//!
//! The general [`Qr`] supports the low-rank and Nyström-style utilities.

use super::matrix::Matrix;
use crate::error::{Error, Result};
use crate::linalg::matmul::{axpy_slice, div_slice, dot};

/// Householder QR: `A = Q·R` with `Q` (m×k) having orthonormal columns and
/// `R` (k×k) upper-triangular, `k = min(m, n)` (thin QR).
pub struct Qr {
    /// Orthonormal factor (thin).
    pub q: Matrix,
    /// Upper-triangular factor.
    pub r: Matrix,
}

impl Qr {
    /// Factor a (possibly rectangular, m ≥ n preferred) matrix.
    pub fn factor(a: &Matrix) -> Result<Self> {
        let (m, n) = a.shape();
        if m == 0 || n == 0 {
            return Err(Error::Shape("qr: empty matrix".into()));
        }
        let k = m.min(n);
        let mut work = a.clone();
        // Householder vectors stored per reflection.
        let mut vs: Vec<Vec<f64>> = Vec::with_capacity(k);
        for j in 0..k {
            // Build reflector for column j, rows j..m.
            let mut v: Vec<f64> = (j..m).map(|i| work.get(i, j)).collect();
            let alpha = -v[0].signum() * v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if alpha.abs() < f64::EPSILON {
                vs.push(vec![0.0; m - j]);
                continue;
            }
            v[0] -= alpha;
            let vnorm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if vnorm > 0.0 {
                for x in &mut v {
                    *x /= vnorm;
                }
            }
            // Apply reflector: work[j.., j..] -= 2 v (vᵀ work[j.., j..])
            for col in j..n {
                let mut proj = 0.0;
                for (i, vi) in v.iter().enumerate() {
                    proj += vi * work.get(j + i, col);
                }
                let proj2 = 2.0 * proj;
                for (i, vi) in v.iter().enumerate() {
                    let val = work.get(j + i, col) - proj2 * vi;
                    work.set(j + i, col, val);
                }
            }
            vs.push(v);
        }
        // R = leading k×n upper triangle of work.
        let mut r = Matrix::zeros(k, n);
        for i in 0..k {
            for j in i..n {
                r.set(i, j, work.get(i, j));
            }
        }
        // Q = (H_0 H_1 ... H_{k-1}) applied to identity columns 0..k.
        let mut q = Matrix::zeros(m, k);
        for i in 0..k {
            q.set(i, i, 1.0);
        }
        for j in (0..k).rev() {
            let v = &vs[j];
            if v.iter().all(|&x| x == 0.0) {
                continue;
            }
            for col in 0..k {
                let mut proj = 0.0;
                for (i, vi) in v.iter().enumerate() {
                    proj += vi * q.get(j + i, col);
                }
                let proj2 = 2.0 * proj;
                for (i, vi) in v.iter().enumerate() {
                    let val = q.get(j + i, col) - proj2 * vi;
                    q.set(j + i, col, val);
                }
            }
        }
        Ok(Qr { q, r })
    }

    /// Least-squares solve `argmin_X ‖A·X − B‖_F` via `R·X = Qᵀ·B` —
    /// one GEMM plus a row-oriented upper-triangular sweep
    /// ([`crate::linalg::trisolve`]) shared with the Cholesky/LU solvers.
    /// Requires `A` to have full column rank (thin factor, `m ≥ n`).
    pub fn solve_matrix(&self, b: &Matrix) -> Result<Matrix> {
        if b.rows() != self.q.rows() {
            return Err(Error::Shape("qr solve: row mismatch".into()));
        }
        if self.r.rows() != self.r.cols() {
            return Err(Error::Shape("qr solve: wide factor (m < n)".into()));
        }
        let mut y = crate::linalg::matmul::matmul_tn(&self.q, b)?;
        crate::linalg::trisolve::solve_upper_in_place(self.r.view(), &mut y, false);
        Ok(y)
    }
}

/// Orthonormalize the columns of `a` via modified Gram–Schmidt, dropping
/// columns whose residual norm falls below `tol` (rank-revealing-lite).
/// Returns a matrix whose columns form an orthonormal basis of span(a).
pub fn orthonormalize_columns(a: &Matrix, tol: f64) -> Matrix {
    let (m, n) = a.shape();
    // Work column-major for contiguous access.
    let at = a.transpose();
    let mut basis: Vec<Vec<f64>> = Vec::new();
    for j in 0..n {
        let mut v = at.row(j).to_vec();
        // Two rounds of MGS for numerical orthogonality; the projection
        // subtraction is a dispatched axpy (`(-proj)·b_i` rounds exactly
        // like the old `v_i - proj·b_i`).
        for _ in 0..2 {
            for b in &basis {
                let proj = dot(b, &v);
                axpy_slice(&mut v, -proj, b);
            }
        }
        let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm > tol {
            div_slice(&mut v, norm);
            basis.push(v);
        }
    }
    let k = basis.len();
    let mut q = Matrix::zeros(m, k);
    for (j, b) in basis.iter().enumerate() {
        for i in 0..m {
            q.set(i, j, b[i]);
        }
    }
    q
}

/// Given orthonormal columns `V` (m×k), return an orthonormal basis of the
/// subspace `{x ∈ span(V) : x[coord] = 0}` — the `V⊥` step of DPP sampling
/// (Alg. 2). Output has k−1 columns (or fewer if span degenerates).
pub fn orthonormal_complement_coord(v: &Matrix, coord: usize) -> Matrix {
    let (m, k) = v.shape();
    debug_assert!(coord < m);
    if k == 0 {
        return Matrix::zeros(m, 0);
    }
    // Find the column with the largest |v[coord, j]| to use as the pivot.
    let mut pivot = 0usize;
    let mut pmax = 0.0f64;
    for j in 0..k {
        let val = v.get(coord, j).abs();
        if val > pmax {
            pmax = val;
            pivot = j;
        }
    }
    if pmax < 1e-14 {
        // Subspace already orthogonal to e_coord: drop nothing but one
        // dimension must still go (degenerate); return first k-1 columns.
        let idx: Vec<usize> = (0..k.saturating_sub(1)).collect();
        return v.select_cols(&idx);
    }
    let vt = v.transpose(); // rows are columns of v
    let pcol = vt.row(pivot).to_vec();
    let pval = pcol[coord];
    // Subtract multiples of the pivot column so every other column has a
    // zero at `coord`, then orthonormalize.
    let mut reduced = Matrix::zeros(m, k - 1);
    let mut out_j = 0usize;
    for j in 0..k {
        if j == pivot {
            continue;
        }
        let cj = vt.row(j);
        let factor = cj[coord] / pval;
        for i in 0..m {
            reduced.set(i, out_j, cj[i] - factor * pcol[i]);
        }
        out_j += 1;
    }
    orthonormalize_columns(&reduced, 1e-12)
}

/// Reusable buffers for [`contract_orthonormal_coord`] so the sampling hot
/// loop performs no per-step allocations.
#[derive(Default)]
pub struct ContractScratch {
    /// The unit direction `p = V·ĉ` removed from the span by the last
    /// contraction (length `n`). Valid after a call that returned `true`;
    /// callers maintaining weights `w_i = Σ_j V[i,j]²` downdate with
    /// `w_i -= p_i²`.
    pub dropped: Vec<f64>,
    /// Coefficient-space buffer (length `k`): holds the normalized row,
    /// then the Householder vector.
    coef: Vec<f64>,
    /// Item-space buffer for `q = V·û` (length `n`).
    q: Vec<f64>,
}

impl ContractScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// In-place, allocation-free variant of [`orthonormal_complement_coord`]
/// for the sampling hot loop. `v` holds `k` orthonormal columns of length
/// `n` stored **column-major** (`v[j*n + i]` is row `i` of column `j`).
/// The routine replaces the column set by an orthonormal basis of
/// `{x ∈ span(V) : x[coord] = 0}` and truncates `v` to `k − 1` columns.
///
/// Instead of re-orthonormalizing (`O(nk²)`), it applies one Householder
/// reflection `H = I − 2ûûᵀ` in coefficient space chosen so that the
/// normalized `coord`-row `ĉ` maps to `±e_{k−1}`: `V·H` then has orthonormal
/// columns, its last column is `±V·ĉ` (the direction leaving the span), and
/// its first `k − 1` columns all vanish at `coord` — total cost `O(nk)`.
///
/// Returns `true` when the contraction ran and `scratch.dropped` holds the
/// removed unit direction (enabling the `w_i -= p_i²` weight downdate).
/// Returns `false` on the degenerate path (row `coord` numerically zero,
/// matching [`orthonormal_complement_coord`]): the last column is dropped
/// unchanged and callers must recompute weights from `v`.
pub fn contract_orthonormal_coord(
    v: &mut Vec<f64>,
    n: usize,
    k: usize,
    coord: usize,
    scratch: &mut ContractScratch,
) -> bool {
    debug_assert_eq!(v.len(), n * k);
    debug_assert!(coord < n);
    debug_assert!(k > 0);
    // Row `coord` of V, in coefficient space.
    scratch.coef.clear();
    let mut rn2 = 0.0;
    for j in 0..k {
        let x = v[j * n + coord];
        scratch.coef.push(x);
        rn2 += x * x;
    }
    let rn = rn2.sqrt();
    if rn < 1e-14 {
        // span(V) is already (numerically) orthogonal to e_coord; one
        // dimension still goes (mirrors the reference degenerate path).
        v.truncate((k - 1) * n);
        return false;
    }
    // ĉ = row/‖row‖ and p = V·ĉ (the unit direction that leaves the span).
    scratch.dropped.clear();
    scratch.dropped.resize(n, 0.0);
    for j in 0..k {
        let c = scratch.coef[j] / rn;
        scratch.coef[j] = c;
        if c != 0.0 {
            let col = &v[j * n..(j + 1) * n];
            for (p, &x) in scratch.dropped.iter_mut().zip(col) {
                *p += c * x;
            }
        }
    }
    // Householder vector u = ĉ − α·e_{k−1} with α = −sign(ĉ_{k−1}) so the
    // subtraction never cancels (‖u‖² = 2(1 + |ĉ_{k−1}|) ≥ 2).
    let alpha = if scratch.coef[k - 1] >= 0.0 { -1.0 } else { 1.0 };
    scratch.coef[k - 1] -= alpha;
    let unorm = scratch.coef.iter().map(|&x| x * x).sum::<f64>().sqrt();
    let inv_unorm = 1.0 / unorm;
    for c in scratch.coef.iter_mut() {
        *c *= inv_unorm;
    }
    // q = V·û = (p − α·v_{k−1})/‖u‖; then column j < k−1: v_j -= 2·û_j·q.
    // (Column k−1 would become ±p; it is dropped, so we skip updating it.)
    scratch.q.clear();
    scratch.q.resize(n, 0.0);
    {
        let last = &v[(k - 1) * n..k * n];
        for ((q, &p), &vl) in scratch.q.iter_mut().zip(&scratch.dropped).zip(last) {
            *q = (p - alpha * vl) * inv_unorm;
        }
    }
    for j in 0..k - 1 {
        let uj2 = 2.0 * scratch.coef[j];
        if uj2 != 0.0 {
            let col = &mut v[j * n..(j + 1) * n];
            for (x, &q) in col.iter_mut().zip(&scratch.q) {
                *x -= uj2 * q;
            }
        }
        // Row `coord` of every surviving column is exactly zero in exact
        // arithmetic; pin it to kill accumulated round-off.
        v[j * n + coord] = 0.0;
    }
    v.truncate((k - 1) * n);
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{matmul, matmul_tn};

    fn rnd(m: usize, n: usize, seed: u64) -> Matrix {
        let mut state = seed | 1;
        Matrix::from_fn(m, n, |_, _| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state as f64 / u64::MAX as f64) - 0.5
        })
    }

    #[test]
    fn qr_reconstructs() {
        let a = rnd(20, 12, 1);
        let qr = Qr::factor(&a).unwrap();
        let rec = matmul(&qr.q, &qr.r).unwrap();
        assert!(rec.rel_diff(&a) < 1e-11);
    }

    #[test]
    fn q_orthonormal() {
        let a = rnd(15, 15, 2);
        let qr = Qr::factor(&a).unwrap();
        let qtq = matmul_tn(&qr.q, &qr.q).unwrap();
        assert!(qtq.rel_diff(&Matrix::identity(15)) < 1e-11);
    }

    #[test]
    fn least_squares_solve() {
        // Overdetermined: X* = (AᵀA)⁻¹AᵀB; check the normal equations
        // residual AᵀA X = Aᵀ B.
        let a = rnd(20, 8, 31);
        let b = rnd(20, 3, 32);
        let qr = Qr::factor(&a).unwrap();
        let x = qr.solve_matrix(&b).unwrap();
        assert_eq!(x.shape(), (8, 3));
        let ata_x = matmul(&matmul_tn(&a, &a).unwrap(), &x).unwrap();
        let atb = matmul_tn(&a, &b).unwrap();
        assert!(ata_x.rel_diff(&atb) < 1e-9);
        // Square consistent system: exact solve.
        let a2 = rnd(9, 9, 33);
        let want = rnd(9, 2, 34);
        let b2 = matmul(&a2, &want).unwrap();
        let x2 = Qr::factor(&a2).unwrap().solve_matrix(&b2).unwrap();
        assert!(x2.rel_diff(&want) < 1e-8);
    }

    #[test]
    fn r_upper_triangular() {
        let a = rnd(10, 8, 3);
        let qr = Qr::factor(&a).unwrap();
        for i in 0..qr.r.rows() {
            for j in 0..i.min(qr.r.cols()) {
                assert!(qr.r.get(i, j).abs() < 1e-13);
            }
        }
    }

    #[test]
    fn orthonormalize_drops_dependent_columns() {
        let mut a = rnd(10, 3, 4);
        // Make column 2 a copy of column 0.
        for i in 0..10 {
            let v = a.get(i, 0);
            a.set(i, 2, v);
        }
        let q = orthonormalize_columns(&a, 1e-10);
        assert_eq!(q.cols(), 2);
        let qtq = matmul_tn(&q, &q).unwrap();
        assert!(qtq.rel_diff(&Matrix::identity(2)) < 1e-11);
    }

    #[test]
    fn complement_zeroes_coordinate() {
        let a = rnd(8, 4, 5);
        let q = orthonormalize_columns(&a, 1e-12);
        assert_eq!(q.cols(), 4);
        let comp = orthonormal_complement_coord(&q, 3);
        assert_eq!(comp.cols(), 3);
        // Every basis vector has zero at coordinate 3.
        for j in 0..comp.cols() {
            assert!(comp.get(3, j).abs() < 1e-10, "coord leak {}", comp.get(3, j));
        }
        // Still orthonormal.
        let ctc = matmul_tn(&comp, &comp).unwrap();
        assert!(ctc.rel_diff(&Matrix::identity(comp.cols())) < 1e-10);
        // Still inside span(q): projecting onto q's span preserves them.
        let qt_c = matmul_tn(&q, &comp).unwrap();
        let back = matmul(&q, &qt_c).unwrap();
        assert!(back.rel_diff(&comp) < 1e-10);
    }

    #[test]
    fn complement_when_already_orthogonal() {
        // Basis = {e0, e1}; complement w.r.t. coordinate 3 keeps dimension-1.
        let mut v = Matrix::zeros(4, 2);
        v.set(0, 0, 1.0);
        v.set(1, 1, 1.0);
        let comp = orthonormal_complement_coord(&v, 3);
        assert_eq!(comp.cols(), 1);
    }

    /// Column-major copy of a Matrix (the layout the in-place contraction
    /// operates on).
    fn to_colmajor(m: &Matrix) -> Vec<f64> {
        let (rows, cols) = m.shape();
        let mut v = Vec::with_capacity(rows * cols);
        for j in 0..cols {
            for i in 0..rows {
                v.push(m.get(i, j));
            }
        }
        v
    }

    fn from_colmajor(v: &[f64], rows: usize, cols: usize) -> Matrix {
        Matrix::from_fn(rows, cols, |i, j| v[j * rows + i])
    }

    #[test]
    fn inplace_contract_matches_reference_span() {
        let (n, k, coord) = (12usize, 5usize, 3usize);
        let q = orthonormalize_columns(&rnd(n, k, 7), 1e-12);
        assert_eq!(q.cols(), k);
        let mut v = to_colmajor(&q);
        let mut ws = ContractScratch::new();
        let downdated = contract_orthonormal_coord(&mut v, n, k, coord, &mut ws);
        assert!(downdated);
        assert_eq!(v.len(), n * (k - 1));
        let got = from_colmajor(&v, n, k - 1);
        // Orthonormal and zero at `coord`.
        let gtg = matmul_tn(&got, &got).unwrap();
        assert!(gtg.rel_diff(&Matrix::identity(k - 1)) < 1e-10);
        for j in 0..k - 1 {
            assert!(got.get(coord, j).abs() < 1e-12);
        }
        // Same subspace as the allocating reference: equal projectors.
        let reference = orthonormal_complement_coord(&q, coord);
        let p_got = matmul(&got, &got.transpose()).unwrap();
        let p_ref = matmul(&reference, &reference.transpose()).unwrap();
        assert!(p_got.rel_diff(&p_ref) < 1e-9, "{}", p_got.rel_diff(&p_ref));
    }

    #[test]
    fn inplace_contract_weight_downdate_identity() {
        // New weights (recomputed) must equal old weights − dropped².
        let (n, k, coord) = (10usize, 4usize, 6usize);
        let q = orthonormalize_columns(&rnd(n, k, 11), 1e-12);
        let old_w: Vec<f64> =
            (0..n).map(|i| q.row(i).iter().map(|x| x * x).sum::<f64>()).collect();
        let mut v = to_colmajor(&q);
        let mut ws = ContractScratch::new();
        assert!(contract_orthonormal_coord(&mut v, n, k, coord, &mut ws));
        // dropped is a unit vector with dropped[coord] = ‖row coord‖.
        let pn: f64 = ws.dropped.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!((pn - 1.0).abs() < 1e-10, "‖p‖ = {pn}");
        let rn: f64 = q.row(coord).iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!((ws.dropped[coord] - rn).abs() < 1e-10);
        let got = from_colmajor(&v, n, k - 1);
        for i in 0..n {
            let new_w: f64 = got.row(i).iter().map(|x| x * x).sum();
            let want = old_w[i] - ws.dropped[i] * ws.dropped[i];
            assert!((new_w - want).abs() < 1e-10, "row {i}: {new_w} vs {want}");
        }
    }

    #[test]
    fn inplace_contract_degenerate_path() {
        // Basis = {e0, e1} (column-major, n = 4): coordinate-3 row is zero,
        // so the contraction reports `false` and drops the last column.
        let mut v = vec![0.0; 8];
        v[0] = 1.0; // column 0 = e0
        v[5] = 1.0; // column 1 = e1
        let mut ws = ContractScratch::new();
        let downdated = contract_orthonormal_coord(&mut v, 4, 2, 3, &mut ws);
        assert!(!downdated);
        assert_eq!(v.len(), 4);
        assert_eq!(v, vec![1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn inplace_contract_to_empty() {
        // k = 1: contracting removes the final dimension.
        let q = orthonormalize_columns(&rnd(6, 1, 13), 1e-12);
        let mut v = to_colmajor(&q);
        let mut ws = ContractScratch::new();
        assert!(contract_orthonormal_coord(&mut v, 6, 1, 2, &mut ws));
        assert!(v.is_empty());
    }
}
