//! Cholesky factorization for symmetric positive-definite matrices.
//!
//! The workhorse of the DPP likelihood: `log det(L_Y)` and `L_Y⁻¹` for every
//! observed subset `Y` go through here, as do PD checks on the KRK-Picard
//! iterates (Prop. 3.1 guarantees PD in exact arithmetic; we verify it
//! numerically as a safety rail).

use super::matrix::Matrix;
use crate::error::{Error, Result};

/// Lower-triangular Cholesky factor `L` with `A = L·Lᵀ`.
pub struct Cholesky {
    /// Lower-triangular factor (upper part zeroed).
    pub l: Matrix,
}

impl Cholesky {
    /// Factor a symmetric PD matrix. Fails with `Error::Numerical` if a
    /// pivot is non-positive (matrix not PD to machine precision).
    pub fn factor(a: &Matrix) -> Result<Self> {
        let mut l = Matrix::zeros(0, 0);
        Self::factor_into(a, &mut l)?;
        Ok(Cholesky { l })
    }

    /// Factor into a caller-held lower-triangular buffer (resized in
    /// place) — the allocation-free form behind [`is_pd_with`] and the
    /// learners' PD safeguards.
    pub fn factor_into(a: &Matrix, l: &mut Matrix) -> Result<()> {
        if !a.is_square() {
            return Err(Error::Shape("cholesky: matrix not square".into()));
        }
        Self::factor_raw(a, l).map_err(|(j, d)| {
            Error::Numerical(format!(
                "cholesky: non-PD pivot {d:.3e} at index {j} (n={})",
                a.rows()
            ))
        })
    }

    /// Allocation-free factorization core: reports a bad pivot as
    /// `(index, value)` without constructing an error string, so the PD
    /// *check* stays heap-silent even when it fails (which is its job in
    /// the learners' step-size safeguards).
    fn factor_raw(a: &Matrix, l: &mut Matrix) -> std::result::Result<(), (usize, f64)> {
        let n = a.rows();
        l.resize_zeroed(n, n);
        for j in 0..n {
            // diagonal
            let mut d = a.get(j, j);
            for k in 0..j {
                let v = l.get(j, k);
                d -= v * v;
            }
            if d <= 0.0 || !d.is_finite() {
                return Err((j, d));
            }
            let dj = d.sqrt();
            l.set(j, j, dj);
            // column below diagonal: L[i,j] = (A[i,j] - Σ_k L[i,k] L[j,k]) / dj
            // (4-wide unrolled dot over the two contiguous row prefixes)
            for i in (j + 1)..n {
                let mut v = a.get(i, j);
                let (ri, rj) = (i * n, j * n);
                let ldata = l.as_slice();
                v -= crate::linalg::matmul::dot(&ldata[ri..ri + j], &ldata[rj..rj + j]);
                l.set(i, j, v / dj);
            }
        }
        Ok(())
    }

    /// Matrix dimension.
    pub fn n(&self) -> usize {
        self.l.rows()
    }

    /// `log det(A) = 2 Σ log L[i,i]`.
    pub fn logdet(&self) -> f64 {
        let n = self.n();
        2.0 * (0..n).map(|i| self.l.get(i, i).ln()).sum::<f64>()
    }

    /// Solve `A x = b` via forward + backward substitution.
    pub fn solve_vec(&self, b: &[f64]) -> Result<Vec<f64>> {
        let n = self.n();
        if b.len() != n {
            return Err(Error::Shape("cholesky solve: length mismatch".into()));
        }
        let l = self.l.as_slice();
        // forward: L y = b
        let mut y = b.to_vec();
        for i in 0..n {
            let mut v = y[i];
            let row = &l[i * n..i * n + i];
            for (k, lik) in row.iter().enumerate() {
                v -= lik * y[k];
            }
            y[i] = v / l[i * n + i];
        }
        // backward: Lᵀ x = y
        for i in (0..n).rev() {
            let mut v = y[i];
            for k in (i + 1)..n {
                v -= l[k * n + i] * y[k];
            }
            y[i] = v / l[i * n + i];
        }
        Ok(y)
    }

    /// Solve `A X = B` — two row-oriented triangular sweeps across all
    /// right-hand sides at once ([`crate::linalg::trisolve`]); the `Lᵀ`
    /// sweep reads the factor through a transpose view.
    pub fn solve_matrix(&self, b: &Matrix) -> Result<Matrix> {
        let mut x = b.clone();
        self.solve_matrix_in_place(&mut x)?;
        Ok(x)
    }

    /// In-place form of [`Cholesky::solve_matrix`]: `x` holds `B` on entry
    /// and `A⁻¹B` on exit. No transposes, no per-column allocation.
    pub fn solve_matrix_in_place(&self, x: &mut Matrix) -> Result<()> {
        if x.rows() != self.n() {
            return Err(Error::Shape("cholesky solve: row mismatch".into()));
        }
        crate::linalg::trisolve::solve_lower_in_place(self.l.view(), x, false);
        crate::linalg::trisolve::solve_upper_in_place(self.l.view().t(), x, false);
        Ok(())
    }

    /// Full inverse `A⁻¹ = L⁻ᵀ·L⁻¹` (symmetric). Computes the triangular
    /// inverse `T = L⁻¹` in `n³/3` flops, then the symmetric product
    /// `TᵀT` (upper triangle only, mirrored), parallelized over row bands
    /// above a size threshold — ~6× faster than per-column solves at
    /// n = 512 (EXPERIMENTS.md §Perf).
    pub fn inverse(&self) -> Matrix {
        let n = self.n();
        let t = self.tri_inverse(); // T = L⁻¹ (lower triangular)
        // A⁻¹[i,j] = Σ_k T[k,i]·T[k,j] for k ≥ max(i,j); iterate rows of T
        // (contiguous) accumulating outer contributions into the upper
        // triangle.
        let tdata = t.as_slice();
        let mut inv = Matrix::zeros(n, n);
        let fill_rows = |rows: std::ops::Range<usize>, out: &mut [f64]| {
            let base = rows.start;
            for i in rows {
                let orow = &mut out[(i - base) * n..(i - base + 1) * n];
                for k in i..n {
                    let trow = &tdata[k * n..k * n + k + 1];
                    let tki = trow[i];
                    if tki == 0.0 {
                        continue;
                    }
                    // j ranges i..=k (T[k,j] nonzero for j ≤ k)
                    crate::linalg::matmul::axpy_slice(
                        &mut orow[i..k + 1],
                        tki,
                        &trow[i..k + 1],
                    );
                }
            }
        };
        let nthreads = if n >= 256 { crate::linalg::matmul::available_threads() } else { 1 };
        if nthreads <= 1 {
            let data = inv.as_mut_slice();
            fill_rows(0..n, data);
        } else {
            let band = n.div_ceil(nthreads).max(1);
            let data = inv.as_mut_slice();
            std::thread::scope(|s| {
                let mut rest = data;
                let mut start = 0usize;
                let mut handles = Vec::new();
                while start < n {
                    let len = band.min(n - start);
                    let (chunk, tail) = rest.split_at_mut(len * n);
                    rest = tail;
                    let range = start..start + len;
                    let fill = &fill_rows;
                    handles.push(s.spawn(move || fill(range, chunk)));
                    start += len;
                }
                for h in handles {
                    h.join().expect("inverse worker panicked");
                }
            });
        }
        // Mirror the upper triangle down.
        for i in 0..n {
            for j in (i + 1)..n {
                let v = inv.get(i, j);
                inv.set(j, i, v);
            }
        }
        inv
    }

    /// Triangular inverse `T = L⁻¹` (lower triangular), column-oriented.
    fn tri_inverse(&self) -> Matrix {
        let mut t = Matrix::zeros(0, 0);
        tri_inverse_into(&self.l, &mut t);
        t
    }
}

/// `t = L⁻¹` for a lower-triangular `L` (resized in place, upper part
/// zeroed), column-oriented. Shared by [`Cholesky::inverse`] and the
/// buffer-reusing [`inverse_from_factor_into`].
fn tri_inverse_into(lmat: &Matrix, t: &mut Matrix) {
    let n = lmat.rows();
    let l = lmat.as_slice();
    t.resize_zeroed(n, n);
    for j in 0..n {
        // Solve L·t_j = e_j for the lower part (rows j..n).
        t.set(j, j, 1.0 / l[j * n + j]);
        for i in (j + 1)..n {
            let row = &l[i * n + j..i * n + i];
            let mut acc = 0.0;
            for (k, lik) in row.iter().enumerate() {
                acc += lik * t.get(j + k, j);
            }
            t.set(i, j, -acc / l[i * n + i]);
        }
    }
}

/// `out = A⁻¹` from a precomputed Cholesky factor `l`, entirely in
/// caller-held buffers (`tri` receives `L⁻¹`). This is the serial
/// small-matrix path behind the compressed-statistics engine's per-subset
/// `L_Y⁻¹` sweep (`κ×κ` operands, allocation-free once the buffers have
/// capacity); the row-band-parallel large-`N` inverse stays in
/// [`Cholesky::inverse`].
pub fn inverse_from_factor_into(l: &Matrix, tri: &mut Matrix, out: &mut Matrix) {
    let n = l.rows();
    tri_inverse_into(l, tri);
    // A⁻¹[i,j] = Σ_{k ≥ max(i,j)} T[k,i]·T[k,j]: iterate rows of T
    // (contiguous) accumulating into the upper triangle, then mirror.
    let tdata = tri.as_slice();
    out.resize_zeroed(n, n);
    for i in 0..n {
        let orow = out.row_mut(i);
        for k in i..n {
            let trow = &tdata[k * n..k * n + k + 1];
            let tki = trow[i];
            if tki == 0.0 {
                continue;
            }
            crate::linalg::matmul::axpy_slice(&mut orow[i..k + 1], tki, &trow[i..k + 1]);
        }
    }
    for i in 0..n {
        for j in (i + 1)..n {
            let v = out.get(i, j);
            out.set(j, i, v);
        }
    }
}

/// `out = A⁻¹` for symmetric PD `A` in caller-held buffers (`chol` holds
/// the factor, `tri` the triangular inverse) — the fully allocation-free
/// composition of [`Cholesky::factor_into`] and
/// [`inverse_from_factor_into`].
pub fn inverse_pd_with(
    a: &Matrix,
    chol: &mut Matrix,
    tri: &mut Matrix,
    out: &mut Matrix,
) -> Result<()> {
    Cholesky::factor_into(a, chol)?;
    inverse_from_factor_into(chol, tri, out);
    Ok(())
}

/// In-place rank-one **update** of a lower-triangular Cholesky factor
/// block: the `t×t` lower-triangular block with top-left corner
/// `(row0, row0)` of the row-major, `stride`-wide buffer `fac` is
/// overwritten with the factor of `T·Tᵀ + x·xᵀ` (classic Givens-sweep
/// `cholupdate`, `O(t²)`, unconditionally stable for the *plus* sign).
/// `x` is consumed as workspace. Allocation-free — this is the
/// row-deletion maintenance step of the MCMC sampler's incrementally
/// factored `L_Y`: deleting row `p` leaves the trailing block satisfying
/// `L₃₃·L₃₃ᵀ + l₃₂·l₃₂ᵀ`, exactly one rank-one update.
pub fn rank_one_update_block(
    fac: &mut [f64],
    stride: usize,
    row0: usize,
    t: usize,
    x: &mut [f64],
) {
    debug_assert!(x.len() >= t);
    debug_assert!(t == 0 || (row0 + t - 1) * stride + row0 + t - 1 < fac.len());
    for j in 0..t {
        let jj = (row0 + j) * stride + row0 + j;
        let d = fac[jj];
        let r = d.hypot(x[j]);
        let c = r / d;
        let s = x[j] / d;
        fac[jj] = r;
        for i in (j + 1)..t {
            let ij = (row0 + i) * stride + row0 + j;
            fac[ij] = (fac[ij] + s * x[i]) / c;
            x[i] = c * x[i] - s * fac[ij];
        }
    }
}

/// In-place rank-r **update** of a lower-triangular Cholesky factor
/// block: the `t×t` block at `(row0, row0)` of `fac` is overwritten with
/// the factor of `T·Tᵀ + X·Xᵀ`, where the `r` update vectors live
/// contiguously in `xs` (`xs[k·t..(k+1)·t]` is column `k`, consumed as
/// workspace). Each vector is swept through the factor with the same
/// Givens recurrence as [`rank_one_update_block`] — `O(r·t²)`,
/// unconditionally stable, allocation-free. This is the factor-side
/// engine of delta publishing: a rank-r kernel perturbation costs
/// `O(r·N₁²)` here instead of the `O(N₁³)` refactorization.
pub fn rank_r_update(fac: &mut [f64], stride: usize, row0: usize, t: usize, xs: &mut [f64]) {
    debug_assert!(t == 0 || xs.len() % t == 0, "xs must hold whole length-t vectors");
    if t == 0 {
        return;
    }
    for x in xs.chunks_exact_mut(t) {
        rank_one_update_block(fac, stride, row0, t, x);
    }
}

/// In-place rank-one **downdate** of a lower-triangular Cholesky factor
/// block: overwrites the `t×t` block at `(row0, row0)` with the factor of
/// `T·Tᵀ − x·xᵀ` via hyperbolic rotations (LINPACK `dchdd`-style column
/// sweep). Unlike the update, a downdate can fail: if `T·Tᵀ − x·xᵀ` is not
/// PD the sweep hits a non-positive rotation pivot `d² − x_j²` and reports
/// it heap-silently as `(column, pivot)` — mirroring `factor_raw` — with
/// the factor left partially modified (callers that need the original on
/// failure must keep their own copy). `x` is consumed as workspace.
pub fn rank_one_downdate_block(
    fac: &mut [f64],
    stride: usize,
    row0: usize,
    t: usize,
    x: &mut [f64],
) -> std::result::Result<(), (usize, f64)> {
    debug_assert!(x.len() >= t);
    debug_assert!(t == 0 || (row0 + t - 1) * stride + row0 + t - 1 < fac.len());
    for j in 0..t {
        let jj = (row0 + j) * stride + row0 + j;
        let d = fac[jj];
        // d² − x_j², factored to avoid overflow of the squares.
        let r2 = (d - x[j]) * (d + x[j]);
        if r2 <= 0.0 || !r2.is_finite() {
            return Err((j, r2));
        }
        let r = r2.sqrt();
        let c = r / d;
        let s = x[j] / d;
        fac[jj] = r;
        for i in (j + 1)..t {
            let ij = (row0 + i) * stride + row0 + j;
            fac[ij] = (fac[ij] - s * x[i]) / c;
            x[i] = c * x[i] - s * fac[ij];
        }
    }
    Ok(())
}

/// In-place rank-r **downdate**: factor of `T·Tᵀ − X·Xᵀ`, vectors packed
/// in `xs` exactly as in [`rank_r_update`]. On a rejected vector the error
/// carries `(vector_index · t + column, pivot)` so the caller can name the
/// offending direction; the factor is partially modified on failure (keep
/// a copy if rollback is needed). The downdate-to-singular rejection is
/// the safety rail that keeps delta publishing from ever installing an
/// indefinite epoch: callers fall back to exact refactorization instead.
pub fn rank_r_downdate(
    fac: &mut [f64],
    stride: usize,
    row0: usize,
    t: usize,
    xs: &mut [f64],
) -> std::result::Result<(), (usize, f64)> {
    debug_assert!(t == 0 || xs.len() % t == 0, "xs must hold whole length-t vectors");
    if t == 0 {
        return Ok(());
    }
    for (k, x) in xs.chunks_exact_mut(t).enumerate() {
        rank_one_downdate_block(fac, stride, row0, t, x).map_err(|(j, d)| (k * t + j, d))?;
    }
    Ok(())
}

/// Convenience: `log det(A)` of a symmetric PD matrix.
pub fn logdet_pd(a: &Matrix) -> Result<f64> {
    Ok(Cholesky::factor(a)?.logdet())
}

/// [`logdet_pd`] into a caller-held factor buffer — allocation-free once
/// `work` has capacity (the per-subset likelihood sweep).
pub fn logdet_pd_with(a: &Matrix, work: &mut Matrix) -> Result<f64> {
    Cholesky::factor_into(a, work)?;
    let n = work.rows();
    Ok(2.0 * (0..n).map(|i| work.get(i, i).ln()).sum::<f64>())
}

/// Convenience: inverse of a symmetric PD matrix.
pub fn inverse_pd(a: &Matrix) -> Result<Matrix> {
    Ok(Cholesky::factor(a)?.inverse())
}

/// Fast PD check (factor succeeds).
pub fn is_pd(a: &Matrix) -> bool {
    Cholesky::factor(a).is_ok()
}

/// PD check into a caller-held factor buffer — the allocation-free form
/// used by the learners' step-size safeguards (heap-silent even when the
/// check fails).
pub fn is_pd_with(a: &Matrix, work: &mut Matrix) -> bool {
    a.is_square() && Cholesky::factor_raw(a, work).is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{matmul, matmul_nt};

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut state = seed | 1;
        let x = Matrix::from_fn(n, n, |_, _| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state as f64 / u64::MAX as f64) - 0.5
        });
        let mut g = matmul_nt(&x, &x).unwrap();
        g.add_diag_mut(n as f64 * 0.1);
        g
    }

    #[test]
    fn reconstructs_matrix() {
        let a = spd(25, 42);
        let ch = Cholesky::factor(&a).unwrap();
        let rec = matmul_nt(&ch.l, &ch.l).unwrap();
        assert!(rec.rel_diff(&a) < 1e-12);
    }

    #[test]
    fn logdet_matches_product_of_pivots() {
        let a = Matrix::diag(&[2.0, 3.0, 4.0]);
        let ch = Cholesky::factor(&a).unwrap();
        assert!((ch.logdet() - (24.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn solve_vec_residual() {
        let a = spd(30, 7);
        let ch = Cholesky::factor(&a).unwrap();
        let b: Vec<f64> = (0..30).map(|i| (i as f64).sin()).collect();
        let x = ch.solve_vec(&b).unwrap();
        let ax = a.matvec(&x).unwrap();
        let res: f64 = ax.iter().zip(&b).map(|(p, q)| (p - q).powi(2)).sum::<f64>().sqrt();
        assert!(res < 1e-9, "residual {res}");
    }

    #[test]
    fn inverse_is_inverse() {
        let a = spd(20, 3);
        let inv = inverse_pd(&a).unwrap();
        let prod = matmul(&a, &inv).unwrap();
        assert!(prod.rel_diff(&Matrix::identity(20)) < 1e-10);
    }

    #[test]
    fn rejects_non_pd() {
        let mut a = Matrix::identity(3);
        a.set(2, 2, -1.0);
        assert!(Cholesky::factor(&a).is_err());
        assert!(!is_pd(&a));
        assert!(is_pd(&Matrix::identity(3)));
    }

    #[test]
    fn rejects_non_square() {
        assert!(Cholesky::factor(&Matrix::zeros(2, 3)).is_err());
    }

    #[test]
    fn solve_matrix_matches_columns() {
        let a = spd(12, 9);
        let ch = Cholesky::factor(&a).unwrap();
        let b = spd(12, 11);
        let x = ch.solve_matrix(&b).unwrap();
        let ax = matmul(&a, &x).unwrap();
        assert!(ax.rel_diff(&b) < 1e-9);
        // The row-oriented multi-RHS solve must agree with per-vector
        // substitution.
        let bt = b.transpose();
        for j in 0..b.cols() {
            let col = ch.solve_vec(bt.row(j)).unwrap();
            for i in 0..12 {
                assert!((x[(i, j)] - col[i]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn inverse_pd_with_matches_inverse_across_sizes() {
        let (mut chol, mut tri, mut out) =
            (Matrix::zeros(0, 0), Matrix::zeros(0, 0), Matrix::zeros(0, 0));
        for (n, seed) in [(1usize, 21), (7, 22), (20, 23), (5, 24)] {
            let a = spd(n, seed);
            inverse_pd_with(&a, &mut chol, &mut tri, &mut out).unwrap();
            let want = inverse_pd(&a).unwrap();
            assert!(out.rel_diff(&want) < 1e-12, "n={n}: {}", out.rel_diff(&want));
        }
        // Fails cleanly on non-PD input, buffers stay reusable.
        let mut bad = Matrix::identity(3);
        bad.set(2, 2, -1.0);
        assert!(inverse_pd_with(&bad, &mut chol, &mut tri, &mut out).is_err());
        let a = spd(9, 25);
        inverse_pd_with(&a, &mut chol, &mut tri, &mut out).unwrap();
        assert!(out.rel_diff(&inverse_pd(&a).unwrap()) < 1e-12);
    }

    #[test]
    fn rank_one_update_matches_refactorization() {
        // Full-buffer update: chol(L·Lᵀ + x·xᵀ) from chol(L·Lᵀ).
        let a = spd(9, 31);
        let ch = Cholesky::factor(&a).unwrap();
        let mut fac: Vec<f64> = ch.l.as_slice().to_vec();
        let x0: Vec<f64> = (0..9).map(|i| ((i * 7 + 3) as f64 * 0.31).sin()).collect();
        let mut x = x0.clone();
        rank_one_update_block(&mut fac, 9, 0, 9, &mut x);
        let mut want = a.clone();
        for i in 0..9 {
            for j in 0..9 {
                let v = want.get(i, j) + x0[i] * x0[j];
                want.set(i, j, v);
            }
        }
        let ref_fac = Cholesky::factor(&want).unwrap();
        for i in 0..9 {
            for j in 0..=i {
                assert!(
                    (fac[i * 9 + j] - ref_fac.l.get(i, j)).abs() < 1e-10,
                    "({i},{j}): {} vs {}",
                    fac[i * 9 + j],
                    ref_fac.l.get(i, j)
                );
            }
        }
    }

    #[test]
    fn rank_one_update_block_touches_only_the_block() {
        // Update the trailing 4×4 block of a 7×7 factor in place; the
        // leading rows/columns must be untouched and the block must match
        // an independent refactorization of its updated Gram matrix.
        let a = spd(7, 33);
        let ch = Cholesky::factor(&a).unwrap();
        let mut fac: Vec<f64> = ch.l.as_slice().to_vec();
        let before = fac.clone();
        let x0 = [0.4, -0.2, 0.7, 0.1];
        let mut x = x0;
        rank_one_update_block(&mut fac, 7, 3, 4, &mut x);
        // Block Gram: T·Tᵀ + x·xᵀ over rows/cols 3..7 of the factor.
        let mut gram = Matrix::zeros(4, 4);
        for i in 0..4 {
            for j in 0..4 {
                let mut v = x0[i] * x0[j];
                for t in 0..4 {
                    v += before[(3 + i) * 7 + 3 + t] * before[(3 + j) * 7 + 3 + t];
                }
                gram.set(i, j, v);
            }
        }
        let ref_fac = Cholesky::factor(&gram).unwrap();
        for i in 0..7 {
            for j in 0..7 {
                let got = fac[i * 7 + j];
                if (3..7).contains(&i) && (3..=i).contains(&j) {
                    let want = ref_fac.l.get(i - 3, j - 3);
                    assert!((got - want).abs() < 1e-10, "({i},{j}): {got} vs {want}");
                } else {
                    assert_eq!(got, before[i * 7 + j], "({i},{j}) outside block changed");
                }
            }
        }
    }

    /// Deterministic pseudo-random update vectors, `r` packed columns of
    /// length `t` (the `rank_r_update`/`rank_r_downdate` workspace layout).
    fn packed_vectors(t: usize, r: usize, seed: u64, scale: f64) -> Vec<f64> {
        let mut state = seed | 1;
        (0..t * r)
            .map(|_| {
                state ^= state << 13;
                state ^= state >> 7;
                state ^= state << 17;
                ((state as f64 / u64::MAX as f64) - 0.5) * scale
            })
            .collect()
    }

    /// `A + sign·X·Xᵀ` for packed columns.
    fn perturbed(a: &Matrix, xs: &[f64], sign: f64) -> Matrix {
        let n = a.rows();
        let mut out = a.clone();
        for x in xs.chunks_exact(n) {
            for i in 0..n {
                for j in 0..n {
                    let v = out.get(i, j) + sign * x[i] * x[j];
                    out.set(i, j, v);
                }
            }
        }
        out
    }

    #[test]
    fn rank_r_update_matches_refactorization() {
        for (n, r, seed) in [(9usize, 1usize, 41u64), (12, 2, 43), (16, 8, 45)] {
            let a = spd(n, seed);
            let ch = Cholesky::factor(&a).unwrap();
            let mut fac: Vec<f64> = ch.l.as_slice().to_vec();
            let xs0 = packed_vectors(n, r, seed ^ 0x9e37, 0.8);
            let mut xs = xs0.clone();
            rank_r_update(&mut fac, n, 0, n, &mut xs);
            let want = Cholesky::factor(&perturbed(&a, &xs0, 1.0)).unwrap();
            for i in 0..n {
                for j in 0..=i {
                    assert!(
                        (fac[i * n + j] - want.l.get(i, j)).abs() < 1e-9,
                        "n={n} r={r} ({i},{j}): {} vs {}",
                        fac[i * n + j],
                        want.l.get(i, j)
                    );
                }
            }
        }
    }

    #[test]
    fn rank_r_downdate_matches_refactorization() {
        // Small vectors keep A − X·Xᵀ safely PD for every tested rank.
        for (n, r, seed) in [(9usize, 1usize, 51u64), (12, 2, 53), (16, 8, 55)] {
            let a = spd(n, seed);
            let ch = Cholesky::factor(&a).unwrap();
            let mut fac: Vec<f64> = ch.l.as_slice().to_vec();
            let xs0 = packed_vectors(n, r, seed ^ 0x517c, 0.15);
            let mut xs = xs0.clone();
            rank_r_downdate(&mut fac, n, 0, n, &mut xs).unwrap();
            let want = Cholesky::factor(&perturbed(&a, &xs0, -1.0)).unwrap();
            for i in 0..n {
                for j in 0..=i {
                    assert!(
                        (fac[i * n + j] - want.l.get(i, j)).abs() < 1e-9,
                        "n={n} r={r} ({i},{j}): {} vs {}",
                        fac[i * n + j],
                        want.l.get(i, j)
                    );
                }
            }
        }
    }

    #[test]
    fn update_then_downdate_round_trips() {
        let n = 11;
        let a = spd(n, 61);
        let ch = Cholesky::factor(&a).unwrap();
        let mut fac: Vec<f64> = ch.l.as_slice().to_vec();
        let xs0 = packed_vectors(n, 3, 77, 0.6);
        let mut up = xs0.clone();
        rank_r_update(&mut fac, n, 0, n, &mut up);
        let mut down = xs0;
        rank_r_downdate(&mut fac, n, 0, n, &mut down).unwrap();
        for i in 0..n {
            for j in 0..=i {
                assert!(
                    (fac[i * n + j] - ch.l.get(i, j)).abs() < 1e-9,
                    "({i},{j}) did not round-trip"
                );
            }
        }
    }

    #[test]
    fn downdate_to_singular_is_rejected() {
        // Removing more than the smallest eigendirection's mass makes the
        // target indefinite: the hyperbolic sweep must hit a non-positive
        // pivot and report it rather than produce NaNs.
        let n = 8;
        let a = spd(n, 71);
        let eig = crate::linalg::eigen::SymEigen::new(&a).unwrap();
        let lam0 = eig.values[0];
        let ch = Cholesky::factor(&a).unwrap();
        for overshoot in [1.5, 1.05] {
            let mut fac: Vec<f64> = ch.l.as_slice().to_vec();
            let mut x: Vec<f64> =
                (0..n).map(|i| eig.vectors.get(i, 0) * lam0.sqrt() * overshoot).collect();
            let err = rank_r_downdate(&mut fac, n, 0, n, &mut x);
            assert!(err.is_err(), "overshoot {overshoot} must reject");
            let (idx, pivot) = err.unwrap_err();
            assert!(idx < n && pivot <= 0.0, "idx {idx} pivot {pivot}");
        }
        // A mild downdate on the same factor still succeeds.
        let mut fac: Vec<f64> = ch.l.as_slice().to_vec();
        let mut x = packed_vectors(n, 1, 73, 0.1);
        rank_r_downdate(&mut fac, n, 0, n, &mut x).unwrap();
        assert!(fac.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn rank_r_block_forms_touch_only_the_block() {
        // Update + downdate restricted to a trailing 5×5 block of a 9×9
        // factor: everything outside the block must be bit-identical.
        let full = spd(9, 81);
        let ch = Cholesky::factor(&full).unwrap();
        let mut fac: Vec<f64> = ch.l.as_slice().to_vec();
        let before = fac.clone();
        let mut xs = packed_vectors(5, 2, 83, 0.4);
        let snapshot = xs.clone();
        rank_r_update(&mut fac, 9, 4, 5, &mut xs);
        xs.copy_from_slice(&snapshot);
        rank_r_downdate(&mut fac, 9, 4, 5, &mut xs).unwrap();
        for i in 0..9 {
            for j in 0..9 {
                let inside = (4..9).contains(&i) && (4..=i).contains(&j);
                if inside {
                    assert!(
                        (fac[i * 9 + j] - before[i * 9 + j]).abs() < 1e-9,
                        "({i},{j}) did not round-trip in block"
                    );
                } else {
                    assert_eq!(fac[i * 9 + j], before[i * 9 + j], "({i},{j}) outside block");
                }
            }
        }
    }

    #[test]
    fn factor_into_and_is_pd_with_reuse_buffer() {
        let mut work = Matrix::zeros(0, 0);
        let a = spd(10, 13);
        assert!(is_pd_with(&a, &mut work));
        let ch = Cholesky::factor(&a).unwrap();
        assert_eq!(work, ch.l);
        let mut bad = Matrix::identity(3);
        bad.set(2, 2, -1.0);
        assert!(!is_pd_with(&bad, &mut work));
        // Buffer is reusable after a failure and across sizes.
        assert!(is_pd_with(&spd(6, 14), &mut work));
    }
}
