//! LU factorization with partial pivoting.
//!
//! Used for general (non-symmetric or indefinite) solves: the marginal-kernel
//! conversion `L = K(I−K)⁻¹`, determinants of non-PD submatrices inside the
//! EM baseline, and as a fallback when a Cholesky pivot fails due to
//! round-off.

use super::matrix::Matrix;
use crate::error::{Error, Result};

/// LU decomposition `P·A = L·U` with row pivoting.
pub struct Lu {
    /// Packed LU factors (unit lower diagonal implicit).
    lu: Matrix,
    /// Row permutation: `perm[i]` is the source row of output row `i`.
    perm: Vec<usize>,
    /// Sign of the permutation (±1).
    sign: f64,
}

impl Lu {
    /// Factor a square matrix. Fails on exact singularity.
    pub fn factor(a: &Matrix) -> Result<Self> {
        if !a.is_square() {
            return Err(Error::Shape("lu: matrix not square".into()));
        }
        let n = a.rows();
        let mut lu = a.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut sign = 1.0;
        for k in 0..n {
            // pivot search
            let mut p = k;
            let mut pmax = lu.get(k, k).abs();
            for i in (k + 1)..n {
                let v = lu.get(i, k).abs();
                if v > pmax {
                    pmax = v;
                    p = i;
                }
            }
            if pmax == 0.0 || !pmax.is_finite() {
                return Err(Error::Numerical(format!("lu: singular at column {k}")));
            }
            if p != k {
                // swap rows p and k
                for j in 0..n {
                    let t = lu.get(k, j);
                    lu.set(k, j, lu.get(p, j));
                    lu.set(p, j, t);
                }
                perm.swap(k, p);
                sign = -sign;
            }
            let pivot = lu.get(k, k);
            for i in (k + 1)..n {
                let m = lu.get(i, k) / pivot;
                lu.set(i, k, m);
                if m != 0.0 {
                    for j in (k + 1)..n {
                        let v = lu.get(i, j) - m * lu.get(k, j);
                        lu.set(i, j, v);
                    }
                }
            }
        }
        Ok(Lu { lu, perm, sign })
    }

    /// Matrix dimension.
    pub fn n(&self) -> usize {
        self.lu.rows()
    }

    /// Determinant (sign · product of U diagonal).
    pub fn det(&self) -> f64 {
        let n = self.n();
        let mut d = self.sign;
        for i in 0..n {
            d *= self.lu.get(i, i);
        }
        d
    }

    /// `log |det(A)|` and its sign, computed stably in log-space.
    pub fn slogdet(&self) -> (f64, f64) {
        let n = self.n();
        let mut logabs = 0.0;
        let mut sign = self.sign;
        for i in 0..n {
            let u = self.lu.get(i, i);
            logabs += u.abs().ln();
            if u < 0.0 {
                sign = -sign;
            }
        }
        (sign, logabs)
    }

    /// Solve `A x = b`.
    pub fn solve_vec(&self, b: &[f64]) -> Result<Vec<f64>> {
        let n = self.n();
        if b.len() != n {
            return Err(Error::Shape("lu solve: length mismatch".into()));
        }
        // apply permutation
        let mut y: Vec<f64> = self.perm.iter().map(|&i| b[i]).collect();
        let lu = self.lu.as_slice();
        // forward (unit lower)
        for i in 1..n {
            let mut v = y[i];
            for k in 0..i {
                v -= lu[i * n + k] * y[k];
            }
            y[i] = v;
        }
        // backward
        for i in (0..n).rev() {
            let mut v = y[i];
            for k in (i + 1)..n {
                v -= lu[i * n + k] * y[k];
            }
            y[i] = v / lu[i * n + i];
        }
        Ok(y)
    }

    /// Solve `A X = B`: permute rows of `B`, then two row-oriented
    /// triangular sweeps over all right-hand sides at once
    /// ([`crate::linalg::trisolve`]) against the packed factors — no
    /// transposes, no per-column allocation.
    pub fn solve_matrix(&self, b: &Matrix) -> Result<Matrix> {
        let n = self.n();
        if b.rows() != n {
            return Err(Error::Shape("lu solve: row mismatch".into()));
        }
        let mut x = Matrix::zeros(n, b.cols());
        for (i, &src) in self.perm.iter().enumerate() {
            x.row_mut(i).copy_from_slice(b.row(src));
        }
        // Unit lower factor (multipliers below the diagonal of `lu`).
        crate::linalg::trisolve::solve_lower_in_place(self.lu.view(), &mut x, true);
        // Upper factor (the upper triangle of `lu`).
        crate::linalg::trisolve::solve_upper_in_place(self.lu.view(), &mut x, false);
        Ok(x)
    }

    /// Inverse `A⁻¹`.
    pub fn inverse(&self) -> Matrix {
        self.solve_matrix(&Matrix::identity(self.n()))
            .expect("lu inverse: identity solve cannot shape-fail")
    }
}

/// Convenience: determinant of a square matrix.
pub fn det(a: &Matrix) -> Result<f64> {
    match Lu::factor(a) {
        Ok(lu) => Ok(lu.det()),
        // Singular ⇒ determinant zero.
        Err(Error::Numerical(_)) => Ok(0.0),
        Err(e) => Err(e),
    }
}

/// Convenience: general inverse.
pub fn inverse(a: &Matrix) -> Result<Matrix> {
    Ok(Lu::factor(a)?.inverse())
}

/// Convenience: solve `A X = B` for general square `A`.
pub fn solve(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    Lu::factor(a)?.solve_matrix(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul;

    fn rnd(n: usize, seed: u64) -> Matrix {
        let mut state = seed | 1;
        Matrix::from_fn(n, n, |_, _| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state as f64 / u64::MAX as f64) - 0.5
        })
    }

    #[test]
    fn det_of_known_matrix() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]).unwrap();
        assert!((det(&a).unwrap() + 2.0).abs() < 1e-12);
    }

    #[test]
    fn det_of_singular_is_zero() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]).unwrap();
        assert_eq!(det(&a).unwrap(), 0.0);
    }

    #[test]
    fn solve_residual() {
        let a = rnd(25, 5);
        let lu = Lu::factor(&a).unwrap();
        let b: Vec<f64> = (0..25).map(|i| (i as f64).cos()).collect();
        let x = lu.solve_vec(&b).unwrap();
        let ax = a.matvec(&x).unwrap();
        let res: f64 = ax.iter().zip(&b).map(|(p, q)| (p - q).powi(2)).sum::<f64>().sqrt();
        assert!(res < 1e-9, "residual {res}");
    }

    #[test]
    fn inverse_roundtrip() {
        let a = rnd(18, 13);
        let inv = inverse(&a).unwrap();
        let prod = matmul(&a, &inv).unwrap();
        assert!(prod.rel_diff(&Matrix::identity(18)) < 1e-9);
    }

    #[test]
    fn slogdet_matches_det() {
        let a = rnd(10, 21);
        let lu = Lu::factor(&a).unwrap();
        let (sign, logabs) = lu.slogdet();
        let d = lu.det();
        assert!((sign * logabs.exp() - d).abs() / d.abs().max(1e-300) < 1e-9);
    }

    #[test]
    fn permutation_sign_tracked() {
        // A matrix requiring a swap: det([[0,1],[1,0]]) = -1
        let a = Matrix::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]).unwrap();
        assert!((det(&a).unwrap() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_non_square() {
        assert!(Lu::factor(&Matrix::zeros(2, 3)).is_err());
    }

    #[test]
    fn solve_matrix_matches_per_vector_solves() {
        let a = rnd(14, 31);
        let lu = Lu::factor(&a).unwrap();
        let b = rnd(14, 33).block(0, 0, 14, 6).unwrap();
        let x = lu.solve_matrix(&b).unwrap();
        let bt = b.transpose();
        for j in 0..6 {
            let col = lu.solve_vec(bt.row(j)).unwrap();
            for i in 0..14 {
                assert!((x[(i, j)] - col[i]).abs() < 1e-10, "({i},{j})");
            }
        }
        let ax = matmul(&a, &x).unwrap();
        assert!(ax.rel_diff(&b) < 1e-9);
    }
}
