//! Borrowed, stride-aware matrix views — the zero-copy core of the linalg
//! substrate (DESIGN.md §1).
//!
//! [`MatRef`] / [`MatMut`] describe a rectangular window into an `f64`
//! buffer through a `(row_stride, col_stride)` pair, so sub-blocks and
//! transposes are O(1) *views* rather than copies:
//!
//! - `Matrix::view()` / `Matrix::view_mut()` wrap the owned container
//!   (`row_stride = cols`, `col_stride = 1`);
//! - [`MatRef::t`] swaps the strides — `A·Bᵀ` and `Aᵀ·B` route through the
//!   exact same packed GEMM as `A·B` without materializing a transpose;
//! - [`MatRef::submatrix`] offsets into the buffer — the Kronecker block
//!   `M_(ij)` and eigensolver trailing blocks are strided windows.
//!
//! The packed GEMM ([`crate::linalg::matmul::gemm_into`]) copies panels of
//! either view layout into contiguous pack buffers before the micro-kernel
//! runs, so strided views carry no inner-loop penalty.

use super::matrix::Matrix;

/// Immutable stride-aware view of an `f64` matrix.
///
/// Entry `(i, j)` lives at `data[i·rs + j·cs]`. A row-major contiguous
/// matrix has `rs = cols, cs = 1`; its transpose view has `rs = 1,
/// cs = cols`.
#[derive(Clone, Copy)]
pub struct MatRef<'a> {
    data: &'a [f64],
    rows: usize,
    cols: usize,
    rs: usize,
    cs: usize,
}

impl<'a> MatRef<'a> {
    /// Build a view from raw parts. `data` must cover every addressed
    /// element (checked for the corner element).
    #[inline]
    pub fn from_parts(data: &'a [f64], rows: usize, cols: usize, rs: usize, cs: usize) -> Self {
        if rows > 0 && cols > 0 {
            debug_assert!((rows - 1) * rs + (cols - 1) * cs < data.len());
        }
        MatRef { data, rows, cols, rs, cs }
    }

    /// Number of rows.
    #[inline(always)]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline(always)]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)` pair.
    #[inline(always)]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Row stride.
    #[inline(always)]
    pub fn row_stride(&self) -> usize {
        self.rs
    }

    /// Column stride.
    #[inline(always)]
    pub fn col_stride(&self) -> usize {
        self.cs
    }

    /// Entry `(i, j)`.
    #[inline(always)]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.rs + j * self.cs]
    }

    /// Transpose view — O(1), no copy.
    #[inline]
    pub fn t(self) -> MatRef<'a> {
        MatRef { data: self.data, rows: self.cols, cols: self.rows, rs: self.cs, cs: self.rs }
    }

    /// `r × c` sub-block view starting at `(i0, j0)` — O(1), no copy.
    #[inline]
    pub fn submatrix(self, i0: usize, j0: usize, r: usize, c: usize) -> MatRef<'a> {
        debug_assert!(i0 + r <= self.rows && j0 + c <= self.cols);
        let off = if r > 0 && c > 0 { i0 * self.rs + j0 * self.cs } else { 0 };
        MatRef { data: &self.data[off..], rows: r, cols: c, rs: self.rs, cs: self.cs }
    }

    /// True when rows are contiguous (`col_stride == 1`): [`Self::row_slice`]
    /// is valid.
    #[inline(always)]
    pub fn rows_contiguous(&self) -> bool {
        self.cs == 1
    }

    /// Row `i` as a contiguous slice (requires `col_stride == 1`).
    #[inline(always)]
    pub fn row_slice(&self, i: usize) -> &'a [f64] {
        debug_assert!(self.cs == 1 && i < self.rows);
        &self.data[i * self.rs..i * self.rs + self.cols]
    }
}

/// Mutable stride-aware view of an `f64` matrix.
///
/// The mutable twin of [`MatRef`]; additionally supports splitting into
/// disjoint row bands ([`MatMut::split_rows_at`]) so parallel kernels can
/// hand each worker its own exclusive output window.
pub struct MatMut<'a> {
    data: &'a mut [f64],
    rows: usize,
    cols: usize,
    rs: usize,
    cs: usize,
}

impl<'a> MatMut<'a> {
    /// Build a mutable view from raw parts (corner element checked).
    #[inline]
    pub fn from_parts(
        data: &'a mut [f64],
        rows: usize,
        cols: usize,
        rs: usize,
        cs: usize,
    ) -> Self {
        if rows > 0 && cols > 0 {
            debug_assert!((rows - 1) * rs + (cols - 1) * cs < data.len());
        }
        MatMut { data, rows, cols, rs, cs }
    }

    /// Number of rows.
    #[inline(always)]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline(always)]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)` pair.
    #[inline(always)]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Row stride.
    #[inline(always)]
    pub fn row_stride(&self) -> usize {
        self.rs
    }

    /// Column stride.
    #[inline(always)]
    pub fn col_stride(&self) -> usize {
        self.cs
    }

    /// Entry `(i, j)`.
    #[inline(always)]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.rs + j * self.cs]
    }

    /// Set entry `(i, j)`.
    #[inline(always)]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.rs + j * self.cs] = v;
    }

    /// Immutable snapshot of this view.
    #[inline]
    pub fn as_const(&self) -> MatRef<'_> {
        MatRef { data: self.data, rows: self.rows, cols: self.cols, rs: self.rs, cs: self.cs }
    }

    /// Reborrow as a shorter-lived mutable view (keeps the original alive).
    #[inline]
    pub fn reborrow(&mut self) -> MatMut<'_> {
        MatMut { data: self.data, rows: self.rows, cols: self.cols, rs: self.rs, cs: self.cs }
    }

    /// `r × c` mutable sub-block starting at `(i0, j0)` — O(1), consumes
    /// the view (use [`MatMut::reborrow`] to keep the parent).
    #[inline]
    pub fn submatrix(self, i0: usize, j0: usize, r: usize, c: usize) -> MatMut<'a> {
        debug_assert!(i0 + r <= self.rows && j0 + c <= self.cols);
        let off = if r > 0 && c > 0 { i0 * self.rs + j0 * self.cs } else { 0 };
        MatMut { data: &mut self.data[off..], rows: r, cols: c, rs: self.rs, cs: self.cs }
    }

    /// Split into disjoint row bands `[0, i)` and `[i, rows)`.
    ///
    /// Requires contiguous rows (`col_stride == 1`) and `row_stride ≥ cols`
    /// so the cut lands between rows — true for every view derived from a
    /// row-major [`Matrix`] (including sub-blocks).
    #[inline]
    pub fn split_rows_at(self, i: usize) -> (MatMut<'a>, MatMut<'a>) {
        debug_assert!(self.cs == 1 && self.rs >= self.cols);
        debug_assert!(i <= self.rows);
        let cut = (i * self.rs).min(self.data.len());
        let (head, tail) = self.data.split_at_mut(cut);
        (
            MatMut { data: head, rows: i, cols: self.cols, rs: self.rs, cs: self.cs },
            MatMut { data: tail, rows: self.rows - i, cols: self.cols, rs: self.rs, cs: self.cs },
        )
    }

    /// Row `i` as a contiguous mutable slice (requires `col_stride == 1`).
    #[inline(always)]
    pub fn row_slice_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(self.cs == 1 && i < self.rows);
        &mut self.data[i * self.rs..i * self.rs + self.cols]
    }

    /// Copy every entry from `src` (shapes must match).
    pub fn copy_from(&mut self, src: MatRef<'_>) {
        assert_eq!(self.shape(), src.shape(), "copy_from: shape mismatch");
        if self.cs == 1 && src.rows_contiguous() {
            for i in 0..self.rows {
                let r = src.row_slice(i);
                self.row_slice_mut(i).copy_from_slice(r);
            }
        } else {
            for i in 0..self.rows {
                for j in 0..self.cols {
                    self.set(i, j, src.get(i, j));
                }
            }
        }
    }

    /// Fill with a constant.
    pub fn fill(&mut self, v: f64) {
        if self.cs == 1 {
            for i in 0..self.rows {
                self.row_slice_mut(i).fill(v);
            }
        } else {
            for i in 0..self.rows {
                for j in 0..self.cols {
                    self.set(i, j, v);
                }
            }
        }
    }
}

impl Matrix {
    /// Borrow as an immutable view (`row_stride = cols`, `col_stride = 1`).
    #[inline]
    pub fn view(&self) -> MatRef<'_> {
        MatRef::from_parts(self.as_slice(), self.rows(), self.cols(), self.cols(), 1)
    }

    /// Borrow as a mutable view.
    #[inline]
    pub fn view_mut(&mut self) -> MatMut<'_> {
        let (r, c) = self.shape();
        MatMut::from_parts(self.as_mut_slice(), r, c, c, 1)
    }

    /// Materialize a view into a new owned matrix.
    pub fn from_view(v: MatRef<'_>) -> Matrix {
        let mut m = Matrix::zeros(v.rows(), v.cols());
        m.view_mut().copy_from(v);
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Matrix {
        Matrix::from_fn(4, 5, |i, j| (i * 10 + j) as f64)
    }

    #[test]
    fn view_roundtrip() {
        let m = sample();
        let v = m.view();
        assert_eq!(v.shape(), (4, 5));
        assert_eq!(v.get(2, 3), 23.0);
        assert_eq!(v.row_slice(1), m.row(1));
        assert!(v.rows_contiguous());
    }

    #[test]
    fn transpose_view_is_free() {
        let m = sample();
        let t = m.view().t();
        assert_eq!(t.shape(), (5, 4));
        assert_eq!(t.get(3, 2), m[(2, 3)]);
        assert!(!t.rows_contiguous());
        // Double transpose restores.
        let tt = t.t();
        assert_eq!(tt.get(2, 3), m[(2, 3)]);
    }

    #[test]
    fn submatrix_views() {
        let m = sample();
        let s = m.view().submatrix(1, 2, 2, 3);
        assert_eq!(s.shape(), (2, 3));
        assert_eq!(s.get(0, 0), m[(1, 2)]);
        assert_eq!(s.get(1, 2), m[(2, 4)]);
        // Transposed sub-block.
        let st = s.t();
        assert_eq!(st.get(2, 1), m[(2, 4)]);
        // Materialize matches manual extraction.
        let owned = Matrix::from_view(s);
        assert_eq!(owned, m.block(1, 2, 2, 3).unwrap());
    }

    #[test]
    fn mut_views_write_through() {
        let mut m = Matrix::zeros(3, 3);
        {
            let mut v = m.view_mut().submatrix(1, 1, 2, 2);
            v.set(0, 0, 7.0);
            v.fill(5.0);
        }
        assert_eq!(m[(1, 1)], 5.0);
        assert_eq!(m[(2, 2)], 5.0);
        assert_eq!(m[(0, 0)], 0.0);
    }

    #[test]
    fn split_rows() {
        let mut m = sample();
        let (mut top, mut bot) = m.view_mut().split_rows_at(1);
        assert_eq!(top.shape(), (1, 5));
        assert_eq!(bot.shape(), (3, 5));
        top.set(0, 0, -1.0);
        bot.set(0, 0, -2.0);
        assert_eq!(m[(0, 0)], -1.0);
        assert_eq!(m[(1, 0)], -2.0);
    }

    #[test]
    fn split_rows_of_submatrix() {
        // Split a strided sub-block (rs > cols) — both halves must address
        // the parent buffer correctly.
        let mut m = sample();
        let sub = m.view_mut().submatrix(0, 1, 4, 3);
        let (mut a, mut b) = sub.split_rows_at(2);
        a.set(1, 0, 100.0);
        b.set(0, 2, 200.0);
        assert_eq!(m[(1, 1)], 100.0);
        assert_eq!(m[(2, 3)], 200.0);
    }

    #[test]
    fn copy_from_strided() {
        let m = sample();
        let mut out = Matrix::zeros(5, 4);
        out.view_mut().copy_from(m.view().t());
        assert_eq!(out, m.transpose());
    }

    #[test]
    fn empty_views() {
        let m = Matrix::zeros(0, 0);
        assert_eq!(m.view().shape(), (0, 0));
        let m2 = sample();
        let e = m2.view().submatrix(4, 5, 0, 0);
        assert_eq!(e.shape(), (0, 0));
    }
}
