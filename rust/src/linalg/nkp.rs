//! Nearest Kronecker product (Van Loan–Pitsianis, ref. [22] of the paper).
//!
//! Given `M` of size `N₁N₂ × N₁N₂`, find `U (N₁×N₁)`, `V (N₂×N₂)` minimizing
//! `‖M − U ⊗ V‖_F`. The rearrangement operator `R(M)` of shape `N₁²×N₂²`
//! with `R[(i,j),(p,q)] = M_(ij)[p,q]` turns the problem into a best rank-1
//! approximation: `R ≈ σ·u·vᵀ` gives `U = σ·mat(u)`, `V = mat(v)`
//! (we return `(mat(u), mat(v), σ)` and let callers fold `σ` as they wish).
//!
//! `R` is never materialized: the power iteration applies `R` and `Rᵀ`
//! directly against the blocks of `M` (same memory as `M` itself, but this
//! keeps the hot loop cache-friendly and avoids a second N²-sized buffer).
//!
//! This powers both the Joint-Picard iteration (§3.2 / App. C) and the
//! KronDPP initializer used in the Table-1 experiment (`L₁, L₂` chosen by
//! minimizing `‖L − L₁ ⊗ L₂‖`).

use super::matrix::Matrix;
use crate::error::{Error, Result};
use crate::linalg::matmul::dot;

/// Result of the rank-1 rearrangement approximation.
pub struct NkpResult {
    /// `mat(u)` — N₁×N₁ left factor (unit Frobenius norm).
    pub u: Matrix,
    /// `mat(v)` — N₂×N₂ right factor (unit Frobenius norm).
    pub v: Matrix,
    /// Leading singular value of the rearrangement `R`.
    pub sigma: f64,
    /// Power-iteration steps taken.
    pub iters: usize,
}

impl NkpResult {
    /// The actual nearest Kronecker product `σ · U ⊗ V`.
    pub fn product(&self) -> Matrix {
        crate::linalg::kron::kron(&self.u.scaled(self.sigma), &self.v)
    }
}

/// `y = R · x` with `x ∈ R^{N₂²}`: `y[(i,j)] = <M_(ij), mat(x)>_F`.
pub fn r_apply(m: &Matrix, n1: usize, n2: usize, x: &[f64]) -> Vec<f64> {
    let mut y = Vec::new();
    r_apply_into(m, n1, n2, x, &mut y);
    y
}

/// [`r_apply`] into a caller-held output — the allocation-free form behind
/// the NKP / Joint-Picard power iterations.
pub fn r_apply_into(m: &Matrix, n1: usize, n2: usize, x: &[f64], y: &mut Vec<f64>) {
    let n = n1 * n2;
    let data = m.as_slice();
    y.clear();
    y.resize(n1 * n1, 0.0);
    for i in 0..n1 {
        for j in 0..n1 {
            let mut acc = 0.0;
            for p in 0..n2 {
                let row = &data[(i * n2 + p) * n + j * n2..(i * n2 + p) * n + (j + 1) * n2];
                acc += dot(row, &x[p * n2..(p + 1) * n2]);
            }
            y[i * n1 + j] = acc;
        }
    }
}

/// `y = Rᵀ · x` with `x ∈ R^{N₁²}`: `mat(y) = Σ_{ij} x[(i,j)] · M_(ij)`.
pub fn rt_apply(m: &Matrix, n1: usize, n2: usize, x: &[f64]) -> Vec<f64> {
    let mut y = Vec::new();
    rt_apply_into(m, n1, n2, x, &mut y);
    y
}

/// [`rt_apply`] into a caller-held output (see [`r_apply_into`]).
pub fn rt_apply_into(m: &Matrix, n1: usize, n2: usize, x: &[f64], y: &mut Vec<f64>) {
    let n = n1 * n2;
    let data = m.as_slice();
    y.clear();
    y.resize(n2 * n2, 0.0);
    for i in 0..n1 {
        for j in 0..n1 {
            let w = x[i * n1 + j];
            if w == 0.0 {
                continue;
            }
            for p in 0..n2 {
                let src = &data[(i * n2 + p) * n + j * n2..(i * n2 + p) * n + (j + 1) * n2];
                let dst = &mut y[p * n2..(p + 1) * n2];
                for (d, s) in dst.iter_mut().zip(src) {
                    *d += w * s;
                }
            }
        }
    }
}

fn norm(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

/// Compute the nearest Kronecker product of `m` via power iteration on the
/// rearrangement. Converges when the singular-value estimate changes by
/// less than `tol` (relative), or after `max_iters`.
pub fn nearest_kronecker(
    m: &Matrix,
    n1: usize,
    n2: usize,
    max_iters: usize,
    tol: f64,
) -> Result<NkpResult> {
    if m.shape() != (n1 * n2, n1 * n2) {
        return Err(Error::Shape(format!(
            "nearest_kronecker: {}x{} does not factor as ({n1}·{n2})²",
            m.rows(),
            m.cols()
        )));
    }
    // Initialize v from the diagonal block structure (deterministic, aligned
    // with PD inputs so the power method never starts orthogonal to the top
    // singular vector for kernel-like matrices).
    let mut v: Vec<f64> = {
        let t2 = crate::linalg::kron::partial_trace_2(m, n1, n2)?;
        let mut v = t2.into_vec();
        let nv = norm(&v);
        if nv < 1e-300 {
            v = vec![0.0; n2 * n2];
            for p in 0..n2 {
                v[p * n2 + p] = 1.0;
            }
        }
        v
    };
    let nv = norm(&v);
    for x in &mut v {
        *x /= nv;
    }
    let mut sigma_prev = 0.0f64;
    let mut sigma = 0.0f64;
    let mut u = vec![0.0; n1 * n1];
    let mut iters = 0;
    for it in 0..max_iters {
        iters = it + 1;
        // Reused iterate buffers: the power loop allocates nothing.
        r_apply_into(m, n1, n2, &v, &mut u);
        let nu = norm(&u);
        if nu < 1e-300 {
            return Err(Error::Numerical("nearest_kronecker: zero iterate".into()));
        }
        for x in &mut u {
            *x /= nu;
        }
        rt_apply_into(m, n1, n2, &u, &mut v);
        sigma = norm(&v);
        if sigma < 1e-300 {
            return Err(Error::Numerical("nearest_kronecker: zero sigma".into()));
        }
        for x in &mut v {
            *x /= sigma;
        }
        if (sigma - sigma_prev).abs() <= tol * sigma {
            break;
        }
        sigma_prev = sigma;
    }
    Ok(NkpResult {
        u: Matrix::from_vec(n1, n1, u)?,
        v: Matrix::from_vec(n2, n2, v)?,
        sigma,
        iters,
    })
}

/// Split a PD matrix `m` into PD factors `(L₁, L₂)` with
/// `L₁ ⊗ L₂ ≈ m` and `‖L₁‖_F = ‖L₂‖_F` (App. C / Thm. C.1 sign fixing):
/// `U`, `V` from the rank-1 rearrangement are either both PD or both ND;
/// flip signs by `sgn(U₁₁)` and balance norms with `α`.
pub fn nearest_kronecker_pd(
    m: &Matrix,
    n1: usize,
    n2: usize,
    max_iters: usize,
    tol: f64,
) -> Result<(Matrix, Matrix)> {
    let nkp = nearest_kronecker(m, n1, n2, max_iters, tol)?;
    let sign = if nkp.u.get(0, 0) >= 0.0 { 1.0 } else { -1.0 };
    let u = nkp.u.scaled(sign);
    let v = nkp.v.scaled(sign);
    // Balance: L1 = α·u, L2 = (σ/α)·v with ‖L1‖ = ‖L2‖ ⇒
    // α·‖u‖ = (σ/α)·‖v‖ ⇒ α = sqrt(σ‖v‖/‖u‖).
    let alpha = (nkp.sigma * v.fro_norm() / u.fro_norm().max(1e-300)).sqrt();
    let l1 = u.scaled(alpha);
    let l2 = v.scaled(nkp.sigma / alpha);
    Ok((l1, l2))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::kron::kron;
    use crate::linalg::matmul::matmul_nt;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut state = seed | 1;
        let x = Matrix::from_fn(n, n, |_, _| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state as f64 / u64::MAX as f64) - 0.5
        });
        let mut g = matmul_nt(&x, &x).unwrap();
        g.add_diag_mut(0.3);
        g
    }

    #[test]
    fn exact_kron_input_recovered() {
        let a = spd(3, 1);
        let b = spd(4, 2);
        let m = kron(&a, &b);
        let r = nearest_kronecker(&m, 3, 4, 200, 1e-14).unwrap();
        assert!(r.product().rel_diff(&m) < 1e-10, "residual {}", r.product().rel_diff(&m));
    }

    #[test]
    fn pd_split_is_pd_and_balanced() {
        let a = spd(3, 5);
        let b = spd(3, 6);
        let mut m = kron(&a, &b);
        // perturb slightly so it is not an exact Kronecker product
        m.add_diag_mut(0.01);
        let (l1, l2) = nearest_kronecker_pd(&m, 3, 3, 300, 1e-13).unwrap();
        assert!(crate::linalg::cholesky::is_pd(&l1));
        assert!(crate::linalg::cholesky::is_pd(&l2));
        assert!((l1.fro_norm() - l2.fro_norm()).abs() / l1.fro_norm() < 1e-8);
        // Product should be close to m.
        let prod = kron(&l1, &l2);
        assert!(prod.rel_diff(&m) < 0.05);
    }

    #[test]
    fn beats_or_matches_random_rank1_guess() {
        // Optimality sanity: NKP residual ≤ residual of the partial-trace
        // based factorization.
        let m = spd(12, 9); // treat as 3⊗4 structured
        let r = nearest_kronecker(&m, 3, 4, 300, 1e-13).unwrap();
        let res_opt = (&m - &r.product()).fro_norm();

        let t1 = crate::linalg::kron::partial_trace_1(&m, 3, 4).unwrap();
        let t2 = crate::linalg::kron::partial_trace_2(&m, 3, 4).unwrap();
        // scale guess to match overall magnitude
        let guess = kron(&t1, &t2);
        let scale = m.fro_dot(&guess).unwrap() / guess.fro_dot(&guess).unwrap();
        let res_guess = (&m - &guess.scaled(scale)).fro_norm();
        assert!(res_opt <= res_guess + 1e-9, "{res_opt} vs {res_guess}");
    }

    #[test]
    fn r_apply_consistency() {
        // <R x, y> == <x, Rᵀ y>
        let m = spd(12, 21);
        let x: Vec<f64> = (0..16).map(|i| ((i * 7 % 5) as f64) - 2.0).collect();
        let y: Vec<f64> = (0..9).map(|i| ((i * 3 % 7) as f64) - 3.0).collect();
        let rx = r_apply(&m, 3, 4, &x);
        let rty = rt_apply(&m, 3, 4, &y);
        let lhs: f64 = rx.iter().zip(&y).map(|(a, b)| a * b).sum();
        let rhs: f64 = x.iter().zip(&rty).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-10);
    }

    #[test]
    fn shape_check() {
        assert!(nearest_kronecker(&Matrix::zeros(6, 6), 2, 4, 10, 1e-6).is_err());
    }
}
