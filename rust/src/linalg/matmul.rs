//! Matrix multiplication kernels.
//!
//! Three tiers, dispatched by size:
//!
//! 1. `matmul_small` — straightforward ikj loops, best below ~64².
//! 2. `matmul_blocked` — cache-blocked with a packed (transposed) RHS so the
//!    inner loop is two contiguous streams; dot product unrolled 4-wide so
//!    LLVM auto-vectorizes it.
//! 3. `matmul_parallel` — the blocked kernel sharded over row bands across
//!    `std::thread::scope` threads; used above a size threshold.
//!
//! `matmul` is the public entry point and picks the tier. Symmetric rank-k
//! style helpers (`gram`, `sandwich`) are provided for the common DPP
//! patterns `XᵀX` and `B A B`.

use super::matrix::Matrix;
use crate::error::{Error, Result};

/// Below this `m*n*k` volume, use the naive kernel.
const SMALL_VOLUME: usize = 48 * 48 * 48;
/// Above this `m*n*k` volume, shard across threads.
const PARALLEL_VOLUME: usize = 160 * 160 * 160;
/// Cache block edge (f64 elements). 64×64 doubles = 32 KiB ≈ L1-friendly.
const BLOCK: usize = 96;

/// `C = A · B`. Dispatches on problem volume.
pub fn matmul(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    if a.cols() != b.rows() {
        return Err(Error::Shape(format!(
            "matmul: {}x{} times {}x{}",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        )));
    }
    let volume = a.rows() * a.cols() * b.cols();
    if volume <= SMALL_VOLUME {
        Ok(matmul_small(a, b))
    } else if volume <= PARALLEL_VOLUME {
        Ok(matmul_blocked(a, b))
    } else {
        Ok(matmul_parallel(a, b, available_threads()))
    }
}

/// `A · Bᵀ` without materializing the transpose.
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    if a.cols() != b.cols() {
        return Err(Error::Shape(format!(
            "matmul_nt: {}x{} times ({}x{})ᵀ",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        )));
    }
    let (m, k) = a.shape();
    let n = b.rows();
    let mut c = Matrix::zeros(m, n);
    let run = |rows: std::ops::Range<usize>, out: &mut [f64]| {
        for (oi, i) in rows.clone().enumerate() {
            let arow = a.row(i);
            let crow = &mut out[oi * n..(oi + 1) * n];
            for j in 0..n {
                crow[j] = dot(arow, b.row(j));
            }
        }
        let _ = k;
    };
    shard_rows(m, n, a.cols(), &run, c.as_mut_slice());
    Ok(c)
}

/// `Aᵀ · B` without materializing the transpose.
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    if a.rows() != b.rows() {
        return Err(Error::Shape(format!(
            "matmul_tn: ({}x{})ᵀ times {}x{}",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        )));
    }
    // AᵀB with A row-major: accumulate outer products row by row. Output is
    // (a.cols x b.cols); parallelize over output row bands.
    let m = a.cols();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    let nthreads = if m * n * a.rows() > PARALLEL_VOLUME { available_threads() } else { 1 };
    let band = m.div_ceil(nthreads);
    let cdata = c.as_mut_slice();
    std::thread::scope(|s| {
        let mut rest = cdata;
        let mut start = 0usize;
        let mut handles = Vec::new();
        while start < m {
            let len = band.min(m - start);
            let (chunk, tail) = rest.split_at_mut(len * n);
            rest = tail;
            let lo = start;
            handles.push(s.spawn(move || {
                for r in 0..a.rows() {
                    let arow = a.row(r);
                    let brow = b.row(r);
                    for (oi, i) in (lo..lo + len).enumerate() {
                        let ai = arow[i];
                        if ai == 0.0 {
                            continue;
                        }
                        let crow = &mut chunk[oi * n..(oi + 1) * n];
                        for (cv, bv) in crow.iter_mut().zip(brow) {
                            *cv += ai * bv;
                        }
                    }
                }
            }));
            start += len;
        }
        for h in handles {
            h.join().expect("matmul_tn worker panicked");
        }
    });
    Ok(c)
}

/// Gram matrix `XᵀX` (symmetric; computes upper triangle and mirrors).
pub fn gram(x: &Matrix) -> Matrix {
    let n = x.cols();
    let xt = x.transpose(); // rows of xt are columns of x: contiguous dots
    let mut g = Matrix::zeros(n, n);
    for i in 0..n {
        let xi = xt.row(i);
        for j in i..n {
            let v = dot(xi, xt.row(j));
            g.set(i, j, v);
            g.set(j, i, v);
        }
    }
    g
}

/// Gram matrix `X Xᵀ` (rows as points).
pub fn gram_rows(x: &Matrix) -> Matrix {
    let n = x.rows();
    let mut g = Matrix::zeros(n, n);
    for i in 0..n {
        let xi = x.row(i);
        for j in i..n {
            let v = dot(xi, x.row(j));
            g.set(i, j, v);
            g.set(j, i, v);
        }
    }
    g
}

/// Three-factor product `A·B·C`, association chosen to minimize flops.
pub fn sandwich(a: &Matrix, b: &Matrix, c: &Matrix) -> Result<Matrix> {
    // cost((AB)C) = m·k·n + m·n·p ; cost(A(BC)) = k·n·p + m·k·p
    let (m, k) = a.shape();
    let n = b.cols();
    let p = c.cols();
    let left_first = m * k * n + m * n * p <= k * n * p + m * k * p;
    if left_first {
        matmul(&matmul(a, b)?, c)
    } else {
        matmul(a, &matmul(b, c)?)
    }
}

/// Unrolled dot product over two equal-length slices.
#[inline(always)]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// `y += alpha * x`.
#[inline(always)]
pub fn axpy_slice(y: &mut [f64], alpha: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

fn matmul_small(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        // split borrow: write into raw slice
        for l in 0..k {
            let al = arow[l];
            if al == 0.0 {
                continue;
            }
            let brow = b.row(l);
            let crow = &mut c.as_mut_slice()[i * n..(i + 1) * n];
            axpy_slice(crow, al, brow);
        }
    }
    c
}

fn matmul_blocked(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, _) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    block_kernel(a, b, 0..m, c.as_mut_slice());
    c
}

/// `c[j] += a0·b0[j] + a1·b1[j] + a2·b2[j] + a3·b3[j]` — four fused
/// rank-1 contributions per C-row traversal (4 FMAs per load/store of
/// `c`, vs 1 for a plain axpy). This is the matmul micro-kernel.
#[inline(always)]
fn axpy4_slice(
    c: &mut [f64],
    a0: f64,
    b0: &[f64],
    a1: f64,
    b1: &[f64],
    a2: f64,
    b2: &[f64],
    a3: f64,
    b3: &[f64],
) {
    let n = c.len();
    debug_assert!(b0.len() == n && b1.len() == n && b2.len() == n && b3.len() == n);
    for j in 0..n {
        c[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
    }
}

/// Blocked ikj kernel writing rows `rows` of the output into `out`
/// (`out` holds exactly those rows, row-major). The l loop is unrolled
/// 4-wide through [`axpy4_slice`].
fn block_kernel(a: &Matrix, b: &Matrix, rows: std::ops::Range<usize>, out: &mut [f64]) {
    let k = a.cols();
    let n = b.cols();
    let row0 = rows.start;
    for lb in (0..k).step_by(BLOCK) {
        let lmax = (lb + BLOCK).min(k);
        for jb in (0..n).step_by(BLOCK) {
            let jmax = (jb + BLOCK).min(n);
            let mut i = rows.start;
            // 2-row micro-tile: each loaded B panel row feeds two C rows.
            while i + 2 <= rows.end {
                let (a0row, a1row) = (a.row(i), a.row(i + 1));
                let base = (i - row0) * n;
                let (head, tail) = out.split_at_mut(base + n);
                let c0 = &mut head[base + jb..base + jmax];
                let c1 = &mut tail[jb..jmax];
                let mut l = lb;
                while l + 2 <= lmax {
                    let b0 = &b.row(l)[jb..jmax];
                    let b1 = &b.row(l + 1)[jb..jmax];
                    let (p0, p1) = (a0row[l], a0row[l + 1]);
                    let (q0, q1) = (a1row[l], a1row[l + 1]);
                    for j in 0..c0.len() {
                        c0[j] += p0 * b0[j] + p1 * b1[j];
                        c1[j] += q0 * b0[j] + q1 * b1[j];
                    }
                    l += 2;
                }
                while l < lmax {
                    let brow = &b.row(l)[jb..jmax];
                    axpy_slice(c0, a0row[l], brow);
                    axpy_slice(c1, a1row[l], brow);
                    l += 1;
                }
                i += 2;
            }
            // Remainder row: 4-wide l unroll.
            while i < rows.end {
                let arow = a.row(i);
                let crow = &mut out[(i - row0) * n + jb..(i - row0) * n + jmax];
                let mut l = lb;
                while l + 4 <= lmax {
                    axpy4_slice(
                        crow,
                        arow[l],
                        &b.row(l)[jb..jmax],
                        arow[l + 1],
                        &b.row(l + 1)[jb..jmax],
                        arow[l + 2],
                        &b.row(l + 2)[jb..jmax],
                        arow[l + 3],
                        &b.row(l + 3)[jb..jmax],
                    );
                    l += 4;
                }
                while l < lmax {
                    let al = arow[l];
                    if al != 0.0 {
                        axpy_slice(crow, al, &b.row(l)[jb..jmax]);
                    }
                    l += 1;
                }
                i += 1;
            }
        }
    }
}

fn matmul_parallel(a: &Matrix, b: &Matrix, nthreads: usize) -> Matrix {
    let (m, _) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    let band = m.div_ceil(nthreads).max(1);
    let cdata = c.as_mut_slice();
    std::thread::scope(|s| {
        let mut rest = cdata;
        let mut start = 0usize;
        let mut handles = Vec::new();
        while start < m {
            let len = band.min(m - start);
            let (chunk, tail) = rest.split_at_mut(len * n);
            rest = tail;
            let range = start..start + len;
            handles.push(s.spawn(move || block_kernel(a, b, range, chunk)));
            start += len;
        }
        for h in handles {
            h.join().expect("matmul worker panicked");
        }
    });
    c
}

/// Helper: run `f` over row bands, possibly in parallel, writing into `out`.
fn shard_rows(
    m: usize,
    n: usize,
    k: usize,
    f: &(dyn Fn(std::ops::Range<usize>, &mut [f64]) + Sync),
    out: &mut [f64],
) {
    let nthreads = if m * n * k > PARALLEL_VOLUME { available_threads() } else { 1 };
    if nthreads <= 1 {
        f(0..m, out);
        return;
    }
    let band = m.div_ceil(nthreads).max(1);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut start = 0usize;
        let mut handles = Vec::new();
        while start < m {
            let len = band.min(m - start);
            let (chunk, tail) = rest.split_at_mut(len * n);
            rest = tail;
            let range = start..start + len;
            handles.push(s.spawn(move || f(range, chunk)));
            start += len;
        }
        for h in handles {
            h.join().expect("shard_rows worker panicked");
        }
    });
}

/// Number of worker threads to use for parallel kernels.
pub fn available_threads() -> usize {
    static CACHED: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *CACHED.get_or_init(|| {
        std::env::var("KRONDPP_THREADS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
            })
            .max(1)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k) = a.shape();
        let n = b.cols();
        Matrix::from_fn(m, n, |i, j| (0..k).map(|l| a.get(i, l) * b.get(l, j)).sum())
    }

    fn pseudo_random(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        Matrix::from_fn(rows, cols, |_, _| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state as f64 / u64::MAX as f64) - 0.5
        })
    }

    #[test]
    fn small_matches_naive() {
        let a = pseudo_random(7, 11, 1);
        let b = pseudo_random(11, 5, 2);
        let c = matmul(&a, &b).unwrap();
        assert!(c.rel_diff(&naive(&a, &b)) < 1e-13);
    }

    #[test]
    fn blocked_matches_naive() {
        let a = pseudo_random(90, 77, 3);
        let b = pseudo_random(77, 85, 4);
        let c = matmul(&a, &b).unwrap();
        assert!(c.rel_diff(&naive(&a, &b)) < 1e-12);
    }

    #[test]
    fn parallel_matches_blocked() {
        let a = pseudo_random(200, 180, 5);
        let b = pseudo_random(180, 190, 6);
        let c = matmul_parallel(&a, &b, 4);
        assert!(c.rel_diff(&matmul_blocked(&a, &b)) < 1e-12);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 2);
        assert!(matmul(&a, &b).is_err());
    }

    #[test]
    fn nt_and_tn_match_explicit_transpose() {
        let a = pseudo_random(33, 21, 7);
        let b = pseudo_random(29, 21, 8);
        let c = matmul_nt(&a, &b).unwrap();
        assert!(c.rel_diff(&naive(&a, &b.transpose())) < 1e-12);

        let a2 = pseudo_random(21, 33, 9);
        let b2 = pseudo_random(21, 29, 10);
        let c2 = matmul_tn(&a2, &b2).unwrap();
        assert!(c2.rel_diff(&naive(&a2.transpose(), &b2)) < 1e-12);
    }

    #[test]
    fn tn_parallel_path() {
        // Force the threaded path in matmul_tn.
        let a = pseudo_random(180, 170, 19);
        let b = pseudo_random(180, 175, 20);
        let c = matmul_tn(&a, &b).unwrap();
        assert!(c.rel_diff(&naive(&a.transpose(), &b)) < 1e-11);
    }

    #[test]
    fn gram_is_symmetric_and_correct() {
        let x = pseudo_random(20, 9, 11);
        let g = gram(&x);
        assert!(g.is_symmetric(1e-12));
        assert!(g.rel_diff(&naive(&x.transpose(), &x)) < 1e-12);
        let gr = gram_rows(&x);
        assert!(gr.rel_diff(&naive(&x, &x.transpose())) < 1e-12);
    }

    #[test]
    fn sandwich_matches_two_muls() {
        let a = pseudo_random(8, 20, 12);
        let b = pseudo_random(20, 20, 13);
        let c = pseudo_random(20, 6, 14);
        let s = sandwich(&a, &b, &c).unwrap();
        let expect = naive(&naive(&a, &b), &c);
        assert!(s.rel_diff(&expect) < 1e-12);
    }

    #[test]
    fn dot_handles_remainders() {
        let a: Vec<f64> = (0..7).map(|i| i as f64).collect();
        let b = vec![2.0; 7];
        assert_eq!(dot(&a, &b), 42.0);
    }
}
