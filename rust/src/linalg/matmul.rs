//! Matrix multiplication: packed, register-tiled GEMM over views.
//!
//! Every variant (`matmul`, `matmul_nt`, `matmul_tn`, [`gram`],
//! [`sandwich`], the `_into` forms) is expressed once over stride-aware
//! views ([`MatRef`]/[`MatMut`]) and funnels into [`gemm_into`], which
//! dispatches by problem volume:
//!
//! 1. **naive** — ikj loops with vectorized row axpys, best below ~48³;
//! 2. **packed** — A and B panels are copied into contiguous pack buffers
//!    and the runtime-dispatched `MR×NR` f64 register-tile micro-kernel
//!    ([`crate::linalg::simd`]) runs over them;
//! 3. **parallel** — the packed kernel sharded over C row-panels with
//!    `std::thread::scope`, each worker packing A into its own buffer.
//!
//! Results are **bitwise deterministic and independent of the thread
//! count**: each output element is accumulated by exactly one worker in a
//! fixed k-order, so row-band partitioning never changes the arithmetic.
//! They are also independent of the dispatch arm — every micro-kernel
//! computes the same correctly-rounded FMA chain per element (see
//! `simd::scalar`), so `KRONDPP_FORCE_SCALAR=1` reproduces the AVX2/NEON
//! bits exactly.
//!
//! Blocking arithmetic (f64 = 8 bytes). `MR×NR` is **per-arch** — packing
//! reads the selected kernel's geometry at call time, so the panel layout
//! is kernel-width-aware:
//!
//! - scalar: `8×4` (one `mul_add` chain per element; LLVM keeps the 32
//!   accumulators in whatever vector registers the target offers);
//! - AVX2+FMA: `4×12` — a 4×3 grid of `__m256d` accumulators (12) + 3 B
//!   row vectors + 1 A broadcast = exactly the 16-register ymm file.
//!   A micro-panel `MR·KC = 8 KiB`, B micro-panel `NR·KC = 24 KiB`:
//!   together one 32 KiB L1d.
//! - NEON: `8×6` — an 8×3 grid of `float64x2_t` accumulators (24 of 32
//!   registers). A micro-panel 16 KiB, B micro-panel 12 KiB.
//! - `KC = 256` is shared by all arms — slab boundaries group the
//!   per-element accumulation chains, so KC must not vary with the
//!   dispatch arm or forced-scalar runs would change bits.
//! - `MC = 128`: a packed A block is `MC·KC = 256 KiB` ≈ half a typical
//!   512 KiB L2, leaving the other half for B panels and C traffic
//!   (`MC` is a multiple of every arm's `MR`, so blocks split evenly).
//! - B is packed across the full output width per `KC` slab (no `NC`
//!   blocking: ground-set sizes here keep `KC·N` comfortably inside L3).
//!
//! Pack buffers live in a [`GemmScratch`] (or a thread-local default for
//! the convenience API), so steady-state callers allocate nothing.

use super::matrix::Matrix;
use super::simd::{self, Kernels};
use super::view::{MatMut, MatRef};
use crate::error::{Error, Result};

/// Below this `m·n·k` volume, use the naive kernel.
const SMALL_VOLUME: usize = 48 * 48 * 48;
/// At or above this `m·n·k` volume, shard across threads.
const PARALLEL_VOLUME: usize = 160 * 160 * 160;

/// k-extent of one packed slab (arch-invariant — see module docs).
const KC: usize = 256;
/// Row extent of one packed A block: `MC·KC` = 256 KiB (≈ half of L2).
/// A multiple of every dispatch arm's `MR` (8, 4, 8).
const MC: usize = 128;

/// Reusable pack buffers for the packed GEMM. One `pack_b` slab is shared
/// by all workers of a call; each worker owns one `pack_a` buffer. Grown
/// on first use and reused, so repeated GEMMs allocate nothing.
#[derive(Default)]
pub struct GemmScratch {
    pack_a: Vec<Vec<f64>>,
    pack_b: Vec<f64>,
}

impl GemmScratch {
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, threads: usize, n: usize, kern: &Kernels) {
        // Kernel-width-aware sizing: panels are padded to the selected
        // arm's MR/NR, so buffer lengths depend on the dispatch.
        let (mr, nr) = (kern.mr(), kern.nr());
        let pb_len = n.div_ceil(nr) * nr * KC;
        if self.pack_b.len() < pb_len {
            self.pack_b.resize(pb_len, 0.0);
        }
        let pa_len = MC.div_ceil(mr) * mr * KC;
        if self.pack_a.len() < threads {
            self.pack_a.resize_with(threads, Vec::new);
        }
        for buf in &mut self.pack_a[..threads] {
            if buf.len() < pa_len {
                buf.resize(pa_len, 0.0);
            }
        }
    }
}

/// Run `f` with the calling thread's default [`GemmScratch`] — the
/// allocation-free backing of the convenience API.
fn with_scratch<R>(f: impl FnOnce(&mut GemmScratch) -> R) -> R {
    use std::cell::RefCell;
    thread_local! {
        static SCRATCH: RefCell<GemmScratch> = RefCell::new(GemmScratch::new());
    }
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// General matrix multiply over views:
/// `C = alpha·A·B` (or `C += alpha·A·B` when `accumulate`).
///
/// `A` and `B` may be any strided views (transposes and sub-blocks are
/// free); `C` needs contiguous rows for the packed path and falls back to
/// the naive kernel otherwise. Dispatches naive → packed → packed+parallel
/// by volume. Bitwise deterministic, independent of thread count.
pub fn gemm_into(
    c: MatMut<'_>,
    alpha: f64,
    a: MatRef<'_>,
    b: MatRef<'_>,
    accumulate: bool,
    scratch: &mut GemmScratch,
) {
    gemm_into_with(c, alpha, a, b, accumulate, scratch, simd::active())
}

/// [`gemm_into`] pinned to an explicit dispatch arm — the A/B seam the
/// conformance tests and benches use to compare the forced-scalar oracle
/// against the dispatched kernel in one process. Production callers use
/// [`gemm_into`], which resolves [`simd::active`] once per call (outside
/// all hot loops).
#[allow(clippy::too_many_arguments)]
pub fn gemm_into_with(
    mut c: MatMut<'_>,
    alpha: f64,
    a: MatRef<'_>,
    b: MatRef<'_>,
    accumulate: bool,
    scratch: &mut GemmScratch,
    kern: &Kernels,
) {
    let (m, k) = a.shape();
    let n = b.cols();
    assert_eq!(b.rows(), k, "gemm: inner dimension mismatch");
    assert_eq!(c.shape(), (m, n), "gemm: output shape mismatch");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        if !accumulate {
            c.fill(0.0);
        }
        return;
    }
    let volume = m * n * k;
    if volume <= SMALL_VOLUME || c.col_stride() != 1 {
        gemm_naive(c, alpha, a, b, accumulate);
        return;
    }
    let row_blocks = m.div_ceil(MC);
    let threads =
        if volume >= PARALLEL_VOLUME { available_threads().min(row_blocks) } else { 1 };
    scratch.ensure(threads, n, kern);
    let (pack_a_bufs, pack_b) = (&mut scratch.pack_a, &mut scratch.pack_b);
    let mut first = true;
    let mut pc = 0usize;
    while pc < k {
        let kc = KC.min(k - pc);
        pack_b_slab(b.submatrix(pc, 0, kc, n), pack_b, kc, kern.nr());
        let add = accumulate || !first;
        if threads <= 1 {
            gemm_row_band(
                c.reborrow(),
                a,
                0,
                pc,
                kc,
                pack_b,
                &mut pack_a_bufs[0],
                alpha,
                add,
                kern,
            );
        } else {
            let nblk = row_blocks.div_ceil(threads);
            let pb: &[f64] = pack_b;
            let rest0 = c.reborrow();
            let bufs0 = pack_a_bufs.iter_mut();
            std::thread::scope(|s| {
                let mut rest = rest0;
                let mut bufs = bufs0;
                let mut row0 = 0usize;
                let mut blk = 0usize;
                while blk < row_blocks {
                    let hi_blk = (blk + nblk).min(row_blocks);
                    let hi_row = (hi_blk * MC).min(m);
                    let rows = hi_row - row0;
                    let (band, tail) = rest.split_rows_at(rows);
                    rest = tail;
                    let pa = bufs.next().expect("pack buffers sized to thread count");
                    let lo = row0;
                    s.spawn(move || {
                        gemm_row_band(band, a, lo, pc, kc, pb, pa, alpha, add, kern);
                    });
                    row0 = hi_row;
                    blk = hi_blk;
                }
            });
        }
        first = false;
        pc += kc;
    }
}

/// `C = A·B`. Dispatches on problem volume; allocates only the result
/// (pack buffers come from the thread-local scratch).
pub fn matmul(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    if a.cols() != b.rows() {
        return Err(Error::Shape(format!(
            "matmul: {}x{} times {}x{}",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        )));
    }
    let mut c = Matrix::zeros(a.rows(), b.cols());
    with_scratch(|s| gemm_into(c.view_mut(), 1.0, a.view(), b.view(), false, s));
    Ok(c)
}

/// `C = A·B` into a caller-held output (resized in place; allocation-free
/// once `out` has capacity).
pub fn matmul_into(
    out: &mut Matrix,
    a: &Matrix,
    b: &Matrix,
    scratch: &mut GemmScratch,
) -> Result<()> {
    if a.cols() != b.rows() {
        return Err(Error::Shape(format!(
            "matmul_into: {}x{} times {}x{}",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        )));
    }
    out.resize_zeroed(a.rows(), b.cols());
    gemm_into(out.view_mut(), 1.0, a.view(), b.view(), false, scratch);
    Ok(())
}

/// `A · Bᵀ` — a transpose *view* of `B` routed through the same packed
/// kernel (never materializes the transpose).
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    if a.cols() != b.cols() {
        return Err(Error::Shape(format!(
            "matmul_nt: {}x{} times ({}x{})ᵀ",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        )));
    }
    let mut c = Matrix::zeros(a.rows(), b.rows());
    with_scratch(|s| gemm_into(c.view_mut(), 1.0, a.view(), b.view().t(), false, s));
    Ok(c)
}

/// `Aᵀ · B` — a transpose *view* of `A` routed through the same packed
/// kernel (never materializes the transpose).
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    if a.rows() != b.rows() {
        return Err(Error::Shape(format!(
            "matmul_tn: ({}x{})ᵀ times {}x{}",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        )));
    }
    let mut c = Matrix::zeros(a.cols(), b.cols());
    with_scratch(|s| gemm_into(c.view_mut(), 1.0, a.view().t(), b.view(), false, s));
    Ok(c)
}

/// Gram matrix `XᵀX` (exactly symmetric).
pub fn gram(x: &Matrix) -> Matrix {
    let n = x.cols();
    let mut g = Matrix::zeros(n, n);
    with_scratch(|s| gemm_into(g.view_mut(), 1.0, x.view().t(), x.view(), false, s));
    g.symmetrize_mut();
    g
}

/// Gram matrix `X Xᵀ` (rows as points; exactly symmetric).
pub fn gram_rows(x: &Matrix) -> Matrix {
    let n = x.rows();
    let mut g = Matrix::zeros(n, n);
    with_scratch(|s| gemm_into(g.view_mut(), 1.0, x.view(), x.view().t(), false, s));
    g.symmetrize_mut();
    g
}

/// Three-factor product `A·B·C`, association chosen to minimize flops.
pub fn sandwich(a: &Matrix, b: &Matrix, c: &Matrix) -> Result<Matrix> {
    // cost((AB)C) = m·k·n + m·n·p ; cost(A(BC)) = k·n·p + m·k·p
    let (m, k) = a.shape();
    let n = b.cols();
    let p = c.cols();
    let left_first = m * k * n + m * n * p <= k * n * p + m * k * p;
    if left_first {
        matmul(&matmul(a, b)?, c)
    } else {
        matmul(a, &matmul(b, c)?)
    }
}

/// `out = A·B·C` with caller-held temp and pack buffers — the
/// allocation-free form used by the learners' hot loops.
pub fn sandwich_into(
    out: &mut Matrix,
    a: &Matrix,
    b: &Matrix,
    c: &Matrix,
    tmp: &mut Matrix,
    scratch: &mut GemmScratch,
) -> Result<()> {
    let (m, k) = a.shape();
    let n = b.cols();
    let p = c.cols();
    let left_first = m * k * n + m * n * p <= k * n * p + m * k * p;
    if left_first {
        matmul_into(tmp, a, b, scratch)?;
        matmul_into(out, tmp, c, scratch)
    } else {
        matmul_into(tmp, b, c, scratch)?;
        matmul_into(out, a, tmp, scratch)
    }
}

/// `y = A·x` over a view, sharded across threads for large problems.
/// Deterministic: each `y[i]` is one fixed-order dot product.
pub fn matvec_into(y: &mut [f64], a: MatRef<'_>, x: &[f64]) {
    let (m, k) = a.shape();
    assert_eq!(y.len(), m, "matvec: output length");
    assert_eq!(x.len(), k, "matvec: input length");
    let run = |rows: std::ops::Range<usize>, out: &mut [f64]| {
        if a.rows_contiguous() {
            for (o, i) in rows.enumerate() {
                out[o] = dot(a.row_slice(i), x);
            }
        } else {
            for (o, i) in rows.enumerate() {
                let mut s = 0.0;
                for (j, xv) in x.iter().enumerate() {
                    s += a.get(i, j) * xv;
                }
                out[o] = s;
            }
        }
    };
    let threads = if m * k >= 1 << 21 { available_threads().min(m.max(1)) } else { 1 };
    if threads <= 1 {
        run(0..m, y);
        return;
    }
    let band = m.div_ceil(threads).max(1);
    std::thread::scope(|s| {
        let mut rest = y;
        let mut start = 0usize;
        while start < m {
            let len = band.min(m - start);
            let (chunk, tail) = rest.split_at_mut(len);
            rest = tail;
            let range = start..start + len;
            let run = &run;
            s.spawn(move || run(range, chunk));
            start += len;
        }
    });
}

/// Below this slice length the dispatched sweeps short-circuit to the
/// scalar arm: an atomic load + indirect call costs more than a tiny
/// sweep, and because every arm is bitwise-identical by contract the gate
/// never changes results — it is purely a latency cut for the panel-sized
/// dots/axpys inside the blocked eigensolver and QR.
const SWEEP_DISPATCH_MIN: usize = 64;

/// Dot product over two equal-length slices: four partial sums over
/// `i mod 4` combined `((s0+s1)+s2)+s3` — the cross-arch reduction
/// contract of [`simd`], vectorized via the dispatched kernel for long
/// slices.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    if a.len() < SWEEP_DISPATCH_MIN {
        return simd::forced_scalar().dot(a, b);
    }
    simd::active().dot(a, b)
}

/// `y += alpha * x`, via the dispatched kernel for long slices.
#[inline]
pub fn axpy_slice(y: &mut [f64], alpha: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    if y.len() < SWEEP_DISPATCH_MIN {
        simd::forced_scalar().axpy(y, alpha, x);
    } else {
        simd::active().axpy(y, alpha, x);
    }
}

/// `y *= alpha`, via the dispatched kernel for long slices.
#[inline]
pub fn scale_slice(y: &mut [f64], alpha: f64) {
    if y.len() < SWEEP_DISPATCH_MIN {
        simd::forced_scalar().scale(y, alpha);
    } else {
        simd::active().scale(y, alpha);
    }
}

/// `y /= d` — true division per element (never a reciprocal multiply),
/// via the dispatched kernel for long slices.
#[inline]
pub fn div_slice(y: &mut [f64], d: f64) {
    if y.len() < SWEEP_DISPATCH_MIN {
        simd::forced_scalar().div_assign(y, d);
    } else {
        simd::active().div_assign(y, d);
    }
}

// ---------------------------------------------------------------------------
// Packed kernel internals
// ---------------------------------------------------------------------------

/// Pack an `mc × kc` block of A into `mr`-row micro-panels, k-major
/// within each panel (`dst[panel·mr·kc + kk·mr + r]`), zero-padding the
/// row tail. `mr` comes from the selected kernel, so the panel layout is
/// kernel-width-aware.
fn pack_a_block(src: MatRef<'_>, dst: &mut [f64], kc: usize, mr: usize) {
    let mc = src.rows();
    debug_assert_eq!(src.cols(), kc);
    let npan = mc.div_ceil(mr);
    for ip in 0..npan {
        let base = ip * mr * kc;
        let m_eff = mr.min(mc - ip * mr);
        if src.rows_contiguous() {
            for r in 0..m_eff {
                let row = src.row_slice(ip * mr + r);
                for (kk, &v) in row.iter().enumerate() {
                    dst[base + kk * mr + r] = v;
                }
            }
            for kk in 0..kc {
                for d in &mut dst[base + kk * mr + m_eff..base + kk * mr + mr] {
                    *d = 0.0;
                }
            }
        } else {
            for kk in 0..kc {
                let d = &mut dst[base + kk * mr..base + kk * mr + mr];
                for (r, dv) in d.iter_mut().enumerate() {
                    *dv = if r < m_eff { src.get(ip * mr + r, kk) } else { 0.0 };
                }
            }
        }
    }
}

/// Pack a `kc × n` slab of B into `nr`-column micro-panels, k-major
/// within each panel (`dst[panel·nr·kc + kk·nr + c]`), zero-padding the
/// column tail. `nr` comes from the selected kernel.
fn pack_b_slab(src: MatRef<'_>, dst: &mut [f64], kc: usize, nr: usize) {
    let n = src.cols();
    debug_assert_eq!(src.rows(), kc);
    let npan = n.div_ceil(nr);
    for jp in 0..npan {
        let base = jp * nr * kc;
        let j0 = jp * nr;
        let n_eff = nr.min(n - j0);
        if src.rows_contiguous() {
            for kk in 0..kc {
                let row = &src.row_slice(kk)[j0..j0 + n_eff];
                let d = &mut dst[base + kk * nr..base + kk * nr + nr];
                d[..n_eff].copy_from_slice(row);
                for dv in &mut d[n_eff..] {
                    *dv = 0.0;
                }
            }
        } else {
            for kk in 0..kc {
                let d = &mut dst[base + kk * nr..base + kk * nr + nr];
                for (c, dv) in d.iter_mut().enumerate() {
                    *dv = if c < n_eff { src.get(kk, j0 + c) } else { 0.0 };
                }
            }
        }
    }
}

/// Write one `m_eff × n_eff` micro-tile into C from the kernel's staging
/// array (`nr`-strided rows). `add` accumulates, otherwise stores — the
/// first `KC` slab stores, later slabs accumulate.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn write_tile(
    c: &mut MatMut<'_>,
    r0: usize,
    j0: usize,
    m_eff: usize,
    n_eff: usize,
    tile: &[f64],
    nr: usize,
    alpha: f64,
    add: bool,
) {
    for r in 0..m_eff {
        let trow = &tile[r * nr..r * nr + n_eff];
        let crow = &mut c.row_slice_mut(r0 + r)[j0..j0 + n_eff];
        if add {
            for (cv, av) in crow.iter_mut().zip(trow) {
                *cv += alpha * av;
            }
        } else {
            for (cv, av) in crow.iter_mut().zip(trow) {
                *cv = alpha * av;
            }
        }
    }
}

/// Compute one C row band for one `KC` slab: pack A blocks into the
/// worker-local buffer, then sweep B panels × A panels with the selected
/// micro-kernel. `row0` is the band's global row offset into A. The
/// dispatch was resolved by the caller; here `kern` is plain field reads
/// and direct fn-pointer calls — nothing allocates and nothing re-detects
/// features inside the loops.
#[allow(clippy::too_many_arguments)]
fn gemm_row_band(
    mut c: MatMut<'_>,
    a: MatRef<'_>,
    row0: usize,
    pc: usize,
    kc: usize,
    pb: &[f64],
    pa_buf: &mut Vec<f64>,
    alpha: f64,
    add: bool,
    kern: &Kernels,
) {
    let (mr, nr) = (kern.mr(), kern.nr());
    let n = c.cols();
    let m_band = c.rows();
    let npan_b = n.div_ceil(nr);
    let pa = pa_buf.as_mut_slice();
    // One stack staging tile reused for every micro-panel product.
    let mut tile = [0.0f64; simd::MAX_TILE];
    for ic in (0..m_band).step_by(MC) {
        let mc = MC.min(m_band - ic);
        pack_a_block(a.submatrix(row0 + ic, pc, mc, kc), pa, kc, mr);
        let npan_a = mc.div_ceil(mr);
        for jp in 0..npan_b {
            let j0 = jp * nr;
            let n_eff = nr.min(n - j0);
            let pbp = &pb[jp * nr * kc..(jp + 1) * nr * kc];
            for ip in 0..npan_a {
                let r0 = ic + ip * mr;
                let m_eff = mr.min(mc - ip * mr);
                let pap = &pa[ip * mr * kc..(ip + 1) * mr * kc];
                kern.tile_into(pap, pbp, kc, &mut tile);
                write_tile(&mut c, r0, j0, m_eff, n_eff, &tile, nr, alpha, add);
            }
        }
    }
}

/// Naive ikj fallback for small volumes and exotically-strided outputs.
fn gemm_naive(mut c: MatMut<'_>, alpha: f64, a: MatRef<'_>, b: MatRef<'_>, accumulate: bool) {
    let (m, k) = a.shape();
    let n = b.cols();
    if !accumulate {
        c.fill(0.0);
    }
    if c.col_stride() == 1 && b.rows_contiguous() {
        for i in 0..m {
            for l in 0..k {
                let al = alpha * a.get(i, l);
                if al != 0.0 {
                    axpy_slice(c.row_slice_mut(i), al, b.row_slice(l));
                }
            }
        }
    } else {
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for l in 0..k {
                    s += a.get(i, l) * b.get(l, j);
                }
                let v = c.get(i, j) + alpha * s;
                c.set(i, j, v);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Legacy blocked kernel (kept for before/after benchmarking)
// ---------------------------------------------------------------------------

/// Cache block edge of the legacy kernel: 96×96 doubles = 72 KiB per
/// operand block — an L2-resident tile (it never fit L1; the stale
/// "64×64 = 32 KiB" note this constant used to carry was wrong). The
/// packed kernel above replaces it; this stays as the benchmark baseline.
const LEGACY_BLOCK: usize = 96;

/// The pre-refactor cache-blocked GEMM (RHS streamed unpacked, 2-row
/// micro-tile). Retained so `bench_linalg` can report packed-vs-legacy
/// speedups per commit; not used by any hot path.
pub fn matmul_blocked_legacy(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, _) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    legacy_block_kernel(a, b, 0..m, c.as_mut_slice());
    c
}

/// `c[j] += a0·b0[j] + ... + a3·b3[j]` — the legacy 4-wide fused axpy.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn axpy4_slice(
    c: &mut [f64],
    a0: f64,
    b0: &[f64],
    a1: f64,
    b1: &[f64],
    a2: f64,
    b2: &[f64],
    a3: f64,
    b3: &[f64],
) {
    let n = c.len();
    debug_assert!(b0.len() == n && b1.len() == n && b2.len() == n && b3.len() == n);
    for j in 0..n {
        c[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
    }
}

/// Legacy blocked ikj kernel writing rows `rows` of the output into `out`.
fn legacy_block_kernel(a: &Matrix, b: &Matrix, rows: std::ops::Range<usize>, out: &mut [f64]) {
    let k = a.cols();
    let n = b.cols();
    let row0 = rows.start;
    for lb in (0..k).step_by(LEGACY_BLOCK) {
        let lmax = (lb + LEGACY_BLOCK).min(k);
        for jb in (0..n).step_by(LEGACY_BLOCK) {
            let jmax = (jb + LEGACY_BLOCK).min(n);
            let mut i = rows.start;
            while i + 2 <= rows.end {
                let (a0row, a1row) = (a.row(i), a.row(i + 1));
                let base = (i - row0) * n;
                let (head, tail) = out.split_at_mut(base + n);
                let c0 = &mut head[base + jb..base + jmax];
                let c1 = &mut tail[jb..jmax];
                let mut l = lb;
                while l + 2 <= lmax {
                    let b0 = &b.row(l)[jb..jmax];
                    let b1 = &b.row(l + 1)[jb..jmax];
                    let (p0, p1) = (a0row[l], a0row[l + 1]);
                    let (q0, q1) = (a1row[l], a1row[l + 1]);
                    for j in 0..c0.len() {
                        c0[j] += p0 * b0[j] + p1 * b1[j];
                        c1[j] += q0 * b0[j] + q1 * b1[j];
                    }
                    l += 2;
                }
                while l < lmax {
                    let brow = &b.row(l)[jb..jmax];
                    axpy_slice(c0, a0row[l], brow);
                    axpy_slice(c1, a1row[l], brow);
                    l += 1;
                }
                i += 2;
            }
            while i < rows.end {
                let arow = a.row(i);
                let crow = &mut out[(i - row0) * n + jb..(i - row0) * n + jmax];
                let mut l = lb;
                while l + 4 <= lmax {
                    axpy4_slice(
                        crow,
                        arow[l],
                        &b.row(l)[jb..jmax],
                        arow[l + 1],
                        &b.row(l + 1)[jb..jmax],
                        arow[l + 2],
                        &b.row(l + 2)[jb..jmax],
                        arow[l + 3],
                        &b.row(l + 3)[jb..jmax],
                    );
                    l += 4;
                }
                while l < lmax {
                    let al = arow[l];
                    if al != 0.0 {
                        axpy_slice(crow, al, &b.row(l)[jb..jmax]);
                    }
                    l += 1;
                }
                i += 1;
            }
        }
    }
}

/// Join a batch of scoped fallible workers, surfacing the first error in
/// spawn order (a panicking worker propagates the panic). Shared by the
/// deterministic fan-outs in `learn::stats` and `dpp::likelihood` so the
/// join/error policy lives in one place.
pub(crate) fn join_first_error<'scope>(
    handles: Vec<std::thread::ScopedJoinHandle<'scope, crate::error::Result<()>>>,
) -> crate::error::Result<()> {
    let mut first = Ok(());
    for h in handles {
        let r = h.join().expect("worker thread panicked");
        if first.is_ok() {
            first = r;
        }
    }
    first
}

/// Number of worker threads to use for parallel kernels.
pub fn available_threads() -> usize {
    static CACHED: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *CACHED.get_or_init(|| {
        std::env::var("KRONDPP_THREADS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
            })
            .max(1)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k) = a.shape();
        let n = b.cols();
        Matrix::from_fn(m, n, |i, j| (0..k).map(|l| a.get(i, l) * b.get(l, j)).sum())
    }

    fn pseudo_random(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        Matrix::from_fn(rows, cols, |_, _| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state as f64 / u64::MAX as f64) - 0.5
        })
    }

    #[test]
    fn small_matches_naive() {
        let a = pseudo_random(7, 11, 1);
        let b = pseudo_random(11, 5, 2);
        let c = matmul(&a, &b).unwrap();
        assert!(c.rel_diff(&naive(&a, &b)) < 1e-13);
    }

    #[test]
    fn packed_matches_naive() {
        // Above SMALL_VOLUME, below PARALLEL_VOLUME: single-thread packed.
        let a = pseudo_random(90, 77, 3);
        let b = pseudo_random(77, 85, 4);
        let c = matmul(&a, &b).unwrap();
        assert!(c.rel_diff(&naive(&a, &b)) < 1e-12);
    }

    #[test]
    fn parallel_matches_naive() {
        let a = pseudo_random(200, 180, 5);
        let b = pseudo_random(180, 190, 6);
        let c = matmul(&a, &b).unwrap();
        assert!(c.rel_diff(&naive(&a, &b)) < 1e-12);
    }

    #[test]
    fn legacy_matches_packed() {
        let a = pseudo_random(130, 120, 15);
        let b = pseudo_random(120, 125, 16);
        let c = matmul(&a, &b).unwrap();
        assert!(c.rel_diff(&matmul_blocked_legacy(&a, &b)) < 1e-12);
    }

    #[test]
    fn ragged_panel_edges() {
        // Shapes straddling every MR/NR/KC boundary.
        for (m, k, n, seed) in
            [(8, 256, 4, 20), (9, 257, 5, 21), (65, 300, 67, 22), (1, 513, 1, 23)]
        {
            let a = pseudo_random(m, k, seed);
            let b = pseudo_random(k, n, seed + 100);
            let c = matmul(&a, &b).unwrap();
            assert!(
                c.rel_diff(&naive(&a, &b)) < 1e-12,
                "mismatch at {m}x{k}x{n}"
            );
        }
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 2);
        assert!(matmul(&a, &b).is_err());
    }

    #[test]
    fn empty_and_degenerate_shapes() {
        let a = Matrix::zeros(0, 3);
        let b = Matrix::zeros(3, 4);
        assert_eq!(matmul(&a, &b).unwrap().shape(), (0, 4));
        let a = Matrix::zeros(2, 0);
        let b = Matrix::zeros(0, 4);
        assert_eq!(matmul(&a, &b).unwrap(), Matrix::zeros(2, 4));
    }

    #[test]
    fn nt_and_tn_match_explicit_transpose() {
        let a = pseudo_random(33, 21, 7);
        let b = pseudo_random(29, 21, 8);
        let c = matmul_nt(&a, &b).unwrap();
        assert!(c.rel_diff(&naive(&a, &b.transpose())) < 1e-12);

        let a2 = pseudo_random(21, 33, 9);
        let b2 = pseudo_random(21, 29, 10);
        let c2 = matmul_tn(&a2, &b2).unwrap();
        assert!(c2.rel_diff(&naive(&a2.transpose(), &b2)) < 1e-12);
    }

    #[test]
    fn nt_tn_large_use_packed_path() {
        let a = pseudo_random(180, 170, 19);
        let b = pseudo_random(175, 170, 20);
        let c = matmul_nt(&a, &b).unwrap();
        assert!(c.rel_diff(&naive(&a, &b.transpose())) < 1e-11);
        let c2 = matmul_tn(&pseudo_random(180, 170, 24), &pseudo_random(180, 175, 25)).unwrap();
        let a2 = pseudo_random(180, 170, 24);
        let b2 = pseudo_random(180, 175, 25);
        assert!(c2.rel_diff(&naive(&a2.transpose(), &b2)) < 1e-11);
    }

    #[test]
    fn gemm_accumulate_and_alpha() {
        let a = pseudo_random(60, 70, 11);
        let b = pseudo_random(70, 55, 12);
        let mut c = pseudo_random(60, 55, 13);
        let c0 = c.clone();
        let mut s = GemmScratch::new();
        gemm_into(c.view_mut(), -2.0, a.view(), b.view(), true, &mut s);
        let mut want = c0.clone();
        want.axpy(-2.0, &naive(&a, &b)).unwrap();
        assert!(c.rel_diff(&want) < 1e-12);
    }

    #[test]
    fn gemm_on_strided_subviews() {
        let big_a = pseudo_random(40, 50, 14);
        let big_b = pseudo_random(50, 45, 15);
        let av = big_a.view().submatrix(3, 5, 20, 30);
        let bv = big_b.view().submatrix(7, 2, 30, 25);
        let mut c = Matrix::zeros(20, 25);
        let mut s = GemmScratch::new();
        gemm_into(c.view_mut(), 1.0, av, bv, false, &mut s);
        let a_owned = big_a.block(3, 5, 20, 30).unwrap();
        let b_owned = big_b.block(7, 2, 30, 25).unwrap();
        assert!(c.rel_diff(&naive(&a_owned, &b_owned)) < 1e-13);
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        // The parallel dispatch must be bitwise identical to a manually
        // driven single-worker slab loop: each element is a fixed-order
        // accumulation, so row-band partitioning never changes arithmetic.
        let (m, k, n) = (200usize, 180usize, 190usize);
        let a = pseudo_random(m, k, 26);
        let b = pseudo_random(k, n, 27);
        assert!(m * k * n >= PARALLEL_VOLUME, "test must exercise the parallel path");
        let c1 = matmul(&a, &b).unwrap();
        let kern = simd::active();
        let (mr, nr) = (kern.mr(), kern.nr());
        let mut c2 = Matrix::zeros(m, n);
        let mut pb = vec![0.0; n.div_ceil(nr) * nr * KC];
        let mut pa = vec![0.0; MC.div_ceil(mr) * mr * KC];
        let mut first = true;
        let mut pc = 0usize;
        while pc < k {
            let kc = KC.min(k - pc);
            pack_b_slab(b.view().submatrix(pc, 0, kc, n), &mut pb, kc, nr);
            gemm_row_band(
                c2.view_mut(),
                a.view(),
                0,
                pc,
                kc,
                &pb,
                &mut pa,
                1.0,
                !first,
                kern,
            );
            first = false;
            pc += kc;
        }
        assert_eq!(c1.as_slice(), c2.as_slice(), "parallel dispatch changed bits");
    }

    #[test]
    fn dispatch_arm_does_not_change_bits() {
        // The forced-scalar oracle and the detected kernel must agree
        // bitwise on the packed path (shape straddles MR/NR/KC edges).
        let (m, k, n) = (67usize, 300usize, 61usize);
        let a = pseudo_random(m, k, 30);
        let b = pseudo_random(k, n, 31);
        let mut c_active = Matrix::zeros(m, n);
        let mut c_scalar = Matrix::zeros(m, n);
        let mut s = GemmScratch::new();
        gemm_into_with(c_active.view_mut(), 1.0, a.view(), b.view(), false, &mut s, simd::active());
        gemm_into_with(
            c_scalar.view_mut(),
            1.0,
            a.view(),
            b.view(),
            false,
            &mut s,
            simd::forced_scalar(),
        );
        assert_eq!(c_active.as_slice(), c_scalar.as_slice(), "dispatch arm changed bits");
    }

    #[test]
    fn gram_is_symmetric_and_correct() {
        let x = pseudo_random(20, 9, 11);
        let g = gram(&x);
        assert!(g.is_symmetric(1e-12));
        assert!(g.rel_diff(&naive(&x.transpose(), &x)) < 1e-12);
        let gr = gram_rows(&x);
        assert!(gr.rel_diff(&naive(&x, &x.transpose())) < 1e-12);
    }

    #[test]
    fn sandwich_matches_two_muls() {
        let a = pseudo_random(8, 20, 12);
        let b = pseudo_random(20, 20, 13);
        let c = pseudo_random(20, 6, 14);
        let s = sandwich(&a, &b, &c).unwrap();
        let expect = naive(&naive(&a, &b), &c);
        assert!(s.rel_diff(&expect) < 1e-12);

        let mut out = Matrix::zeros(0, 0);
        let mut tmp = Matrix::zeros(0, 0);
        let mut gs = GemmScratch::new();
        sandwich_into(&mut out, &a, &b, &c, &mut tmp, &mut gs).unwrap();
        assert!(out.rel_diff(&expect) < 1e-12);
    }

    #[test]
    fn matvec_matches_matrix_path() {
        let a = pseudo_random(37, 53, 17);
        let x: Vec<f64> = (0..53).map(|i| (i as f64).sin()).collect();
        let mut y = vec![0.0; 37];
        matvec_into(&mut y, a.view(), &x);
        let want = a.matvec(&x).unwrap();
        for (p, q) in y.iter().zip(&want) {
            assert!((p - q).abs() < 1e-12);
        }
        // Transposed view.
        let mut yt = vec![0.0; 53];
        let xt: Vec<f64> = (0..37).map(|i| (i as f64).cos()).collect();
        matvec_into(&mut yt, a.view().t(), &xt);
        let want_t = a.vecmat(&xt).unwrap();
        for (p, q) in yt.iter().zip(&want_t) {
            assert!((p - q).abs() < 1e-12);
        }
    }

    #[test]
    fn dot_handles_remainders() {
        let a: Vec<f64> = (0..7).map(|i| i as f64).collect();
        let b = vec![2.0; 7];
        assert_eq!(dot(&a, &b), 42.0);
    }

}
