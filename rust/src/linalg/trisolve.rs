//! Row-oriented triangular solves with matrix right-hand sides.
//!
//! The factorizations ([`crate::linalg::cholesky`], [`crate::linalg::lu`],
//! [`crate::linalg::qr`]) all reduce `A X = B` to triangular solves. These
//! used to run column-by-column through transposed copies of `B`; here the
//! substitution sweeps *rows* of `X` instead:
//!
//! ```text
//! x_i ← (b_i − Σ_{k<i} T[i,k] · x_k) / T[i,i]
//! ```
//!
//! where `x_i` is the whole `i`-th row of `X`. Each step is a handful of
//! vectorized row axpys across all right-hand sides at once — no
//! transposes, no per-column allocation, and the triangular coefficient
//! matrix is read through a [`MatRef`] so `Lᵀ` solves are a free transpose
//! view of the same factor.

use super::matrix::Matrix;
use super::simd::{self, Kernels};
use super::view::MatRef;

/// Solve `T·X = B` in place where `T` is lower-triangular (entries read
/// from the lower triangle of `t`, which may be a transpose view). `x`
/// holds `B` on entry and `X` on exit. `unit_diag` skips the division
/// (LU's implicit unit lower factor).
pub fn solve_lower_in_place(t: MatRef<'_>, x: &mut Matrix, unit_diag: bool) {
    solve_lower_in_place_with(t, x, unit_diag, simd::active())
}

/// [`solve_lower_in_place`] pinned to an explicit dispatch arm — the
/// conformance tests and benches use this to compare the forced-scalar
/// oracle against the detected kernel in one process. The dispatch is
/// resolved here, once, before the substitution loops.
pub fn solve_lower_in_place_with(t: MatRef<'_>, x: &mut Matrix, unit_diag: bool, kern: &Kernels) {
    let n = t.rows();
    debug_assert_eq!(t.cols(), n, "trisolve: T not square");
    debug_assert_eq!(x.rows(), n, "trisolve: RHS row mismatch");
    let cols = x.cols();
    let data = x.as_mut_slice();
    for i in 0..n {
        let (prev, cur) = data.split_at_mut(i * cols);
        let xi = &mut cur[..cols];
        for k in 0..i {
            let tik = t.get(i, k);
            if tik != 0.0 {
                kern.axpy(xi, -tik, &prev[k * cols..(k + 1) * cols]);
            }
        }
        if !unit_diag {
            let inv = 1.0 / t.get(i, i);
            kern.scale(xi, inv);
        }
    }
}

/// Solve `T·X = B` in place where `T` is upper-triangular (entries read
/// from the upper triangle of `t`; pass `l.view().t()` to solve against
/// `Lᵀ` without materializing it).
pub fn solve_upper_in_place(t: MatRef<'_>, x: &mut Matrix, unit_diag: bool) {
    solve_upper_in_place_with(t, x, unit_diag, simd::active())
}

/// [`solve_upper_in_place`] pinned to an explicit dispatch arm (see
/// [`solve_lower_in_place_with`]).
pub fn solve_upper_in_place_with(t: MatRef<'_>, x: &mut Matrix, unit_diag: bool, kern: &Kernels) {
    let n = t.rows();
    debug_assert_eq!(t.cols(), n, "trisolve: T not square");
    debug_assert_eq!(x.rows(), n, "trisolve: RHS row mismatch");
    let cols = x.cols();
    let data = x.as_mut_slice();
    for i in (0..n).rev() {
        let (head, tail) = data.split_at_mut((i + 1) * cols);
        let xi = &mut head[i * cols..];
        for k in (i + 1)..n {
            let tik = t.get(i, k);
            if tik != 0.0 {
                kern.axpy(xi, -tik, &tail[(k - i - 1) * cols..(k - i) * cols]);
            }
        }
        if !unit_diag {
            let inv = 1.0 / t.get(i, i);
            kern.scale(xi, inv);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul;

    fn lower(n: usize, seed: u64) -> Matrix {
        let mut state = seed | 1;
        Matrix::from_fn(n, n, |i, j| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            let v = (state as f64 / u64::MAX as f64) - 0.5;
            match i.cmp(&j) {
                std::cmp::Ordering::Less => 0.0,
                std::cmp::Ordering::Equal => v.abs() + 1.0,
                std::cmp::Ordering::Greater => v,
            }
        })
    }

    fn rnd(r: usize, c: usize, seed: u64) -> Matrix {
        let mut state = seed | 1;
        Matrix::from_fn(r, c, |_, _| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state as f64 / u64::MAX as f64) - 0.5
        })
    }

    #[test]
    fn lower_solve_residual() {
        let l = lower(12, 1);
        let b = rnd(12, 5, 2);
        let mut x = b.clone();
        solve_lower_in_place(l.view(), &mut x, false);
        let lx = matmul(&l, &x).unwrap();
        assert!(lx.rel_diff(&b) < 1e-11);
    }

    #[test]
    fn upper_solve_via_transpose_view() {
        // Solve Lᵀ X = B through a transpose view of L.
        let l = lower(10, 3);
        let b = rnd(10, 4, 4);
        let mut x = b.clone();
        solve_upper_in_place(l.view().t(), &mut x, false);
        let ltx = matmul(&l.transpose(), &x).unwrap();
        assert!(ltx.rel_diff(&b) < 1e-11);
    }

    #[test]
    fn unit_diag_skips_division() {
        let mut l = lower(8, 5);
        // Unit solve must ignore whatever sits on the diagonal.
        let b = rnd(8, 3, 6);
        let mut x = b.clone();
        solve_lower_in_place(l.view(), &mut x, true);
        for i in 0..8 {
            l.set(i, i, 1.0);
        }
        let lx = matmul(&l, &x).unwrap();
        assert!(lx.rel_diff(&b) < 1e-11);
    }

    #[test]
    fn single_column_matches_vec_solve() {
        let l = lower(9, 7);
        let b = rnd(9, 1, 8);
        let mut x = b.clone();
        solve_lower_in_place(l.view(), &mut x, false);
        // forward-substitute manually
        let mut y: Vec<f64> = (0..9).map(|i| b[(i, 0)]).collect();
        for i in 0..9 {
            for k in 0..i {
                let t = l[(i, k)] * y[k];
                y[i] -= t;
            }
            y[i] /= l[(i, i)];
        }
        for i in 0..9 {
            assert!((x[(i, 0)] - y[i]).abs() < 1e-12);
        }
    }
}
