//! Symmetric eigendecomposition (Householder tridiagonalization + implicit
//! QL with Wilkinson shifts — the classic EISPACK `tred2`/`tql2` pair).
//!
//! This is the `O(n³)` substrate behind DPP sampling (Alg. 2 needs the
//! spectrum of `L`), the `(I+L)⁻¹` diagonal-space computations of KRK-Picard
//! (App. B computes `B` through the eigenbases of `L₁`, `L₂`), and the EM
//! baseline. For KronDPP kernels only the *sub-kernels* are decomposed
//! (`O(N₁³+N₂³) = O(N^{3/2})`), which is the source of the paper's speedups.
//!
//! jax's `eigh` lowers to LAPACK custom-calls that the pinned xla_extension
//! CPU runtime cannot execute, so eigensolves deliberately live here in Rust
//! rather than in the AOT artifacts (see DESIGN.md §3).

use super::matrix::Matrix;
use crate::error::{Error, Result};

/// Eigendecomposition `A = V · diag(λ) · Vᵀ` of a symmetric matrix.
/// Eigenvalues ascend; `vectors.col(i)` pairs with `values[i]`.
pub struct SymEigen {
    /// Eigenvalues in ascending order.
    pub values: Vec<f64>,
    /// Orthonormal eigenvectors, one per column.
    pub vectors: Matrix,
}

impl SymEigen {
    /// Decompose a symmetric matrix. The input is symmetrized defensively
    /// (average of `A` and `Aᵀ`) before reduction.
    pub fn new(a: &Matrix) -> Result<Self> {
        if !a.is_square() {
            return Err(Error::Shape("eigen: matrix not square".into()));
        }
        let n = a.rows();
        if n == 0 {
            return Ok(SymEigen { values: vec![], vectors: Matrix::zeros(0, 0) });
        }
        // Work on a symmetrized copy.
        let mut v = a.clone();
        v.symmetrize_mut();
        let mut d = vec![0.0; n];
        let mut e = vec![0.0; n];
        tred2(&mut v, &mut d, &mut e);
        tql2(&mut v, &mut d, &mut e)?;
        // Sort ascending (tql2 output is ascending already, but make it a
        // hard guarantee).
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&i, &j| d[i].partial_cmp(&d[j]).unwrap());
        let values: Vec<f64> = order.iter().map(|&i| d[i]).collect();
        let mut vectors = Matrix::zeros(n, n);
        for (new_j, &old_j) in order.iter().enumerate() {
            for i in 0..n {
                vectors.set(i, new_j, v.get(i, old_j));
            }
        }
        Ok(SymEigen { values, vectors })
    }

    /// Reconstruct `V diag(f(λ)) Vᵀ` — matrix functions of `A`.
    pub fn apply_fn(&self, f: impl Fn(f64) -> f64) -> Matrix {
        let n = self.values.len();
        let mut scaled = Matrix::zeros(n, n);
        // scaled = V * diag(f(λ))
        for i in 0..n {
            for j in 0..n {
                scaled.set(i, j, self.vectors.get(i, j) * f(self.values[j]));
            }
        }
        crate::linalg::matmul::matmul_nt(&scaled, &self.vectors)
            .expect("apply_fn: shapes consistent by construction")
    }

    /// Reconstruct the original matrix.
    pub fn reconstruct(&self) -> Matrix {
        self.apply_fn(|x| x)
    }

    /// Smallest eigenvalue.
    pub fn min_eig(&self) -> f64 {
        self.values.first().copied().unwrap_or(0.0)
    }

    /// Largest eigenvalue.
    pub fn max_eig(&self) -> f64 {
        self.values.last().copied().unwrap_or(0.0)
    }
}

/// Householder reduction of a real symmetric matrix to tridiagonal form,
/// accumulating the orthogonal transform in `v` (EISPACK tred2).
/// On exit `d` holds the diagonal, `e` the subdiagonal (`e[0]` unused).
fn tred2(v: &mut Matrix, d: &mut [f64], e: &mut [f64]) {
    let n = d.len();
    for i in 0..n {
        d[i] = v.get(n - 1, i);
    }
    // Householder reduction.
    for i in (1..n).rev() {
        let l = i; // columns 0..l of row i
        let mut h = 0.0;
        let mut scale = 0.0;
        if l > 1 {
            for k in 0..l {
                scale += d[k].abs();
            }
        }
        if scale == 0.0 || l <= 1 {
            e[i] = if l >= 1 { d[l - 1] } else { 0.0 };
            for j in 0..l {
                d[j] = v.get(l - 1, j);
                v.set(i, j, 0.0);
                v.set(j, i, 0.0);
            }
        } else {
            for k in 0..l {
                d[k] /= scale;
                h += d[k] * d[k];
            }
            let mut f = d[l - 1];
            let mut g = if f > 0.0 { -h.sqrt() } else { h.sqrt() };
            e[i] = scale * g;
            h -= f * g;
            d[l - 1] = f - g;
            for j in 0..l {
                e[j] = 0.0;
            }
            // Apply similarity transformation to remaining columns.
            for j in 0..l {
                f = d[j];
                v.set(j, i, f);
                g = e[j] + v.get(j, j) * f;
                for k in (j + 1)..l {
                    g += v.get(k, j) * d[k];
                    e[k] += v.get(k, j) * f;
                }
                e[j] = g;
            }
            f = 0.0;
            for j in 0..l {
                e[j] /= h;
                f += e[j] * d[j];
            }
            let hh = f / (h + h);
            for j in 0..l {
                e[j] -= hh * d[j];
            }
            for j in 0..l {
                f = d[j];
                g = e[j];
                for k in j..l {
                    let val = v.get(k, j) - (f * e[k] + g * d[k]);
                    v.set(k, j, val);
                }
                d[j] = v.get(l - 1, j);
                v.set(i, j, 0.0);
            }
        }
        d[i] = h;
    }
    // Accumulate transformations.
    for i in 0..(n - 1) {
        v.set(n - 1, i, v.get(i, i));
        v.set(i, i, 1.0);
        let l = i + 1;
        if d[l] != 0.0 {
            for k in 0..l {
                d[k] = v.get(k, l) / d[l];
            }
            for j in 0..l {
                let mut g = 0.0;
                for k in 0..l {
                    g += v.get(k, l) * v.get(k, j);
                }
                for k in 0..l {
                    let val = v.get(k, j) - g * d[k];
                    v.set(k, j, val);
                }
            }
        }
        for k in 0..l {
            v.set(k, l, 0.0);
        }
    }
    for j in 0..n {
        d[j] = v.get(n - 1, j);
        v.set(n - 1, j, 0.0);
    }
    v.set(n - 1, n - 1, 1.0);
    e[0] = 0.0;
}

/// Implicit QL with Wilkinson shifts on a symmetric tridiagonal matrix,
/// updating the eigenvector accumulation in `v` (EISPACK tql2).
fn tql2(v: &mut Matrix, d: &mut [f64], e: &mut [f64]) -> Result<()> {
    let n = d.len();
    if n == 1 {
        return Ok(());
    }
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;

    let mut f = 0.0f64;
    let mut tst1 = 0.0f64;
    let eps = f64::EPSILON;
    for l in 0..n {
        tst1 = tst1.max(d[l].abs() + e[l].abs());
        // Find small subdiagonal element.
        let mut m = l;
        while m < n {
            if e[m].abs() <= eps * tst1 {
                break;
            }
            m += 1;
        }
        if m == n {
            m = n - 1;
        }
        if m > l {
            let mut iter = 0;
            loop {
                iter += 1;
                if iter > 50 {
                    return Err(Error::Numerical(
                        "tql2: QL iteration failed to converge".into(),
                    ));
                }
                // Compute implicit shift.
                let mut g = d[l];
                let mut p = (d[l + 1] - g) / (2.0 * e[l]);
                let mut r = (p * p + 1.0).sqrt();
                d[l] = e[l] / (p + if p < 0.0 { -r } else { r });
                d[l + 1] = e[l] * (p + if p < 0.0 { -r } else { r });
                let dl1 = d[l + 1];
                let mut h = g - d[l];
                for i in (l + 2)..n {
                    d[i] -= h;
                }
                f += h;
                // Implicit QL transformation.
                p = d[m];
                let mut c = 1.0;
                let mut c2 = c;
                let mut c3 = c;
                let el1 = e[l + 1];
                let mut s = 0.0;
                let mut s2 = 0.0;
                for i in (l..m).rev() {
                    c3 = c2;
                    c2 = c;
                    s2 = s;
                    g = c * e[i];
                    h = c * p;
                    r = (p * p + e[i] * e[i]).sqrt();
                    e[i + 1] = s * r;
                    s = e[i] / r;
                    c = p / r;
                    p = c * d[i] - s * g;
                    d[i + 1] = h + s * (c * g + s * d[i]);
                    // Accumulate transformation (raw slice walk: this
                    // rotation is the O(n³) inner loop of tql2).
                    {
                        let vd = v.as_mut_slice();
                        let mut idx = i;
                        for _ in 0..n {
                            let h2 = vd[idx + 1];
                            let vi = vd[idx];
                            vd[idx + 1] = s * vi + c * h2;
                            vd[idx] = c * vi - s * h2;
                            idx += n;
                        }
                    }
                }
                p = -s * s2 * c3 * el1 * e[l] / dl1;
                e[l] = s * p;
                d[l] = c * p;
                // Check for convergence.
                if e[l].abs() <= eps * tst1 {
                    break;
                }
            }
        }
        d[l] += f;
        e[l] = 0.0;
    }
    Ok(())
}

/// Eigenvalues only (same reduction, no vector accumulation would be faster,
/// but decomposition dominates overall cost rarely enough that we reuse the
/// full path for simplicity and correctness).
pub fn eigvals(a: &Matrix) -> Result<Vec<f64>> {
    Ok(SymEigen::new(a)?.values)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{matmul_nt, matmul_tn};

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut state = seed | 1;
        let x = Matrix::from_fn(n, n, |_, _| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state as f64 / u64::MAX as f64) - 0.5
        });
        let mut g = matmul_nt(&x, &x).unwrap();
        g.add_diag_mut(0.5);
        g
    }

    #[test]
    fn diag_matrix_eigs() {
        let a = Matrix::diag(&[3.0, 1.0, 2.0]);
        let eig = SymEigen::new(&a).unwrap();
        assert!((eig.values[0] - 1.0).abs() < 1e-12);
        assert!((eig.values[1] - 2.0).abs() < 1e-12);
        assert!((eig.values[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1, 3
        let a = Matrix::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]).unwrap();
        let eig = SymEigen::new(&a).unwrap();
        assert!((eig.values[0] - 1.0).abs() < 1e-12);
        assert!((eig.values[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn reconstruction_small() {
        let a = spd(10, 77);
        let eig = SymEigen::new(&a).unwrap();
        assert!(eig.reconstruct().rel_diff(&a) < 1e-10);
    }

    #[test]
    fn reconstruction_medium() {
        let a = spd(120, 5);
        let eig = SymEigen::new(&a).unwrap();
        assert!(eig.reconstruct().rel_diff(&a) < 1e-9);
    }

    #[test]
    fn vectors_orthonormal() {
        let a = spd(40, 9);
        let eig = SymEigen::new(&a).unwrap();
        let vtv = matmul_tn(&eig.vectors, &eig.vectors).unwrap();
        assert!(vtv.rel_diff(&Matrix::identity(40)) < 1e-10);
    }

    #[test]
    fn eigenpairs_satisfy_definition() {
        let a = spd(25, 33);
        let eig = SymEigen::new(&a).unwrap();
        for j in 0..25 {
            let v = eig.vectors.col(j);
            let av = a.matvec(&v).unwrap();
            let residual: f64 = av
                .iter()
                .zip(&v)
                .map(|(p, q)| (p - eig.values[j] * q).powi(2))
                .sum::<f64>()
                .sqrt();
            assert!(residual < 1e-8, "eigenpair {j} residual {residual}");
        }
    }

    #[test]
    fn apply_fn_inverse() {
        let a = spd(15, 3);
        let eig = SymEigen::new(&a).unwrap();
        let inv = eig.apply_fn(|x| 1.0 / x);
        let prod = crate::linalg::matmul::matmul(&a, &inv).unwrap();
        assert!(prod.rel_diff(&Matrix::identity(15)) < 1e-9);
    }

    #[test]
    fn trace_equals_sum_of_eigs() {
        let a = spd(30, 12);
        let eig = SymEigen::new(&a).unwrap();
        let s: f64 = eig.values.iter().sum();
        assert!((s - a.trace()).abs() / a.trace().abs() < 1e-10);
    }

    #[test]
    fn handles_1x1_and_empty() {
        let a = Matrix::diag(&[5.0]);
        let eig = SymEigen::new(&a).unwrap();
        assert_eq!(eig.values, vec![5.0]);
        let e = SymEigen::new(&Matrix::zeros(0, 0)).unwrap();
        assert!(e.values.is_empty());
    }

    #[test]
    fn rejects_non_square() {
        assert!(SymEigen::new(&Matrix::zeros(2, 3)).is_err());
    }

    #[test]
    fn repeated_eigenvalues() {
        let a = Matrix::identity(6);
        let eig = SymEigen::new(&a).unwrap();
        for v in &eig.values {
            assert!((v - 1.0).abs() < 1e-12);
        }
        assert!(eig.reconstruct().rel_diff(&a) < 1e-12);
    }
}
