//! Symmetric eigendecomposition — a two-stage, GEMM-powered solver.
//!
//! Small matrices use the classic EISPACK `tred2`/`tql2` pair. At or above
//! [`BLOCKED_MIN_N`] the solver switches to a blocked two-stage path whose
//! flops run through the packed GEMM and across threads:
//!
//! 1. **Blocked Householder tridiagonalization** (LAPACK `dsytrd`-style
//!    panels): reflectors are generated column-by-column inside an
//!    `NB`-wide panel with lazily-applied rank-2 corrections, and the
//!    trailing submatrix update `A ← A − VWᵀ − WVᵀ` is two calls into the
//!    packed parallel GEMM.
//! 2. **Compact-WY back-transformation**: `Q = H₀H₁⋯` is accumulated by
//!    applying each panel's block reflector `I − V T Vᵀ` to the identity in
//!    reverse panel order (three GEMMs per panel, restricted to the
//!    trailing block that is actually non-trivial).
//! 3. **tql2 with rotation streaming**: the tridiagonal core stays the
//!    battle-tested implicit-QL iteration, but its Givens rotations are
//!    buffered and replayed onto `Q`'s rows in parallel row bands. Every
//!    row performs the identical arithmetic regardless of banding, so the
//!    result is **bitwise deterministic and thread-count invariant**.
//!
//! All workspaces live in a [`SymEigenScratch`] (including the GEMM pack
//! buffers), so steady-state callers — the KRK-Picard learners
//! re-decomposing sub-kernels every half-step, the samplers assembling
//! kernels per request — allocate nothing once warm.
//!
//! This is the `O(n³)` substrate behind DPP sampling (Alg. 2 needs the
//! spectrum of `L`), the `(I+L)⁻¹` diagonal-space computations of KRK-Picard
//! (App. B computes `B` through the eigenbases of `L₁`, `L₂`), and the EM
//! baseline. For KronDPP kernels only the *sub-kernels* are decomposed
//! (`O(N₁³+N₂³) = O(N^{3/2})`), which is the source of the paper's speedups.
//!
//! jax's `eigh` lowers to LAPACK custom-calls that the pinned xla_extension
//! CPU runtime cannot execute, so eigensolves deliberately live here in Rust
//! rather than in the AOT artifacts (see DESIGN.md §3).

use super::matrix::Matrix;
use crate::error::{Error, Result};
use crate::linalg::matmul::{self, GemmScratch};

/// Panel width of the blocked tridiagonalization (`NB` columns per
/// rank-2k trailing update).
const NB: usize = 32;
/// Below this dimension the classic sequential `tred2`/`tql2` path wins
/// (the blocked path pays extra flops for the separate Q accumulation).
pub const BLOCKED_MIN_N: usize = 128;
/// Rotations buffered before a parallel replay onto the eigenvector rows.
/// 16384 × 24 B ≈ 384 KiB — enough batching to amortize the fan-out.
const ROT_CHUNK: usize = 16384;

/// One Givens rotation of tql2, acting on eigenvector columns `(i, i+1)`.
#[derive(Clone, Copy)]
struct Rot {
    i: u32,
    c: f64,
    s: f64,
}

/// Which factorization path to run.
#[derive(Clone, Copy, PartialEq)]
enum Path {
    Auto,
    Sequential,
    Blocked,
}

/// Eigendecomposition `A = V · diag(λ) · Vᵀ` of a symmetric matrix.
/// Eigenvalues ascend; `vectors.col(i)` pairs with `values[i]`.
pub struct SymEigen {
    /// Eigenvalues in ascending order.
    pub values: Vec<f64>,
    /// Orthonormal eigenvectors, one per column.
    pub vectors: Matrix,
}

/// Reusable workspace (and outputs) for [`factor_into`]. Holding one of
/// these across repeated factorizations removes every allocation from the
/// eigensolve: panels, rotation buffers, the GEMM pack buffers, and the
/// output `values`/`vectors` are all recycled.
#[derive(Default)]
pub struct SymEigenScratch {
    /// Working copy of the input; after blocked reduction its strict lower
    /// part stores the Householder vectors.
    work: Matrix,
    /// Accumulated orthogonal factor (blocked path).
    q: Matrix,
    d: Vec<f64>,
    e: Vec<f64>,
    tau: Vec<f64>,
    /// Panel of Householder vectors (row-major `m × b`).
    vpanel: Matrix,
    /// Panel of `w` vectors (row-major `m × b`).
    wpanel: Matrix,
    /// Compact-WY triangular factor (`b × b`).
    tmat: Matrix,
    /// Panel products for the Q back-transform.
    ymat: Matrix,
    ymat2: Matrix,
    /// Panel start offsets (replayed in reverse by the Q pass).
    starts: Vec<(usize, usize)>,
    /// Buffered tql2 rotations.
    rot: Vec<Rot>,
    /// Householder / correction temporaries.
    hv: Vec<f64>,
    hp: Vec<f64>,
    htmp: Vec<f64>,
    order: Vec<usize>,
    /// Pack buffers shared with the GEMM (public so callers can lend the
    /// same buffers to other kernels between factorizations).
    pub gemm: GemmScratch,
    /// Output: eigenvalues ascending.
    pub values: Vec<f64>,
    /// Output: orthonormal eigenvectors, one per column.
    pub vectors: Matrix,
}

impl SymEigenScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

impl SymEigen {
    /// Decompose a symmetric matrix. The input is symmetrized defensively
    /// (average of `A` and `Aᵀ`) before reduction. Dispatches to the
    /// blocked parallel path above [`BLOCKED_MIN_N`].
    pub fn new(a: &Matrix) -> Result<Self> {
        let mut s = SymEigenScratch::default();
        factor_into_impl(a, &mut s, Path::Auto)?;
        Ok(take_outputs(&mut s))
    }

    /// Decompose reusing a caller-held scratch (workspaces and GEMM pack
    /// buffers recycled; only the returned `values`/`vectors` allocate).
    pub fn new_with(a: &Matrix, s: &mut SymEigenScratch) -> Result<Self> {
        factor_into_impl(a, s, Path::Auto)?;
        Ok(SymEigen { values: s.values.clone(), vectors: s.vectors.clone() })
    }

    /// Force the classic sequential `tred2`/`tql2` path (benchmark /
    /// verification baseline).
    pub fn new_seq(a: &Matrix) -> Result<Self> {
        let mut s = SymEigenScratch::default();
        factor_into_impl(a, &mut s, Path::Sequential)?;
        Ok(take_outputs(&mut s))
    }

    /// Force the blocked two-stage path regardless of size (tests /
    /// benchmarks).
    pub fn new_blocked(a: &Matrix) -> Result<Self> {
        let mut s = SymEigenScratch::default();
        factor_into_impl(a, &mut s, Path::Blocked)?;
        Ok(take_outputs(&mut s))
    }

    /// Reconstruct `V diag(f(λ)) Vᵀ` — matrix functions of `A`.
    pub fn apply_fn(&self, f: impl Fn(f64) -> f64) -> Matrix {
        let n = self.values.len();
        let mut scaled = Matrix::zeros(n, n);
        // scaled = V * diag(f(λ))
        for i in 0..n {
            for j in 0..n {
                scaled.set(i, j, self.vectors.get(i, j) * f(self.values[j]));
            }
        }
        crate::linalg::matmul::matmul_nt(&scaled, &self.vectors)
            .expect("apply_fn: shapes consistent by construction")
    }

    /// Reconstruct the original matrix.
    pub fn reconstruct(&self) -> Matrix {
        self.apply_fn(|x| x)
    }

    /// Smallest eigenvalue.
    pub fn min_eig(&self) -> f64 {
        self.values.first().copied().unwrap_or(0.0)
    }

    /// Largest eigenvalue.
    pub fn max_eig(&self) -> f64 {
        self.values.last().copied().unwrap_or(0.0)
    }
}

fn take_outputs(s: &mut SymEigenScratch) -> SymEigen {
    SymEigen {
        values: std::mem::take(&mut s.values),
        vectors: std::mem::replace(&mut s.vectors, Matrix::zeros(0, 0)),
    }
}

/// Factor `a` into `scratch.values` / `scratch.vectors`, reusing every
/// buffer in `scratch` — the allocation-free entry point of the learners'
/// hot loops.
pub fn factor_into(a: &Matrix, scratch: &mut SymEigenScratch) -> Result<()> {
    factor_into_impl(a, scratch, Path::Auto)
}

fn factor_into_impl(a: &Matrix, sc: &mut SymEigenScratch, path: Path) -> Result<()> {
    if !a.is_square() {
        return Err(Error::Shape("eigen: matrix not square".into()));
    }
    let n = a.rows();
    sc.values.clear();
    if n == 0 {
        sc.vectors.resize_zeroed(0, 0);
        return Ok(());
    }
    sc.work.copy_from(a);
    sc.work.symmetrize_mut();
    sc.d.clear();
    sc.d.resize(n, 0.0);
    sc.e.clear();
    sc.e.resize(n, 0.0);
    let blocked = match path {
        Path::Sequential => false,
        Path::Blocked => n >= 3,
        Path::Auto => n >= BLOCKED_MIN_N,
    };
    if blocked {
        tridiag_blocked(sc, n);
        accumulate_q(sc, n);
        tql2_streaming(sc, n)?;
    } else {
        tred2(&mut sc.work, &mut sc.d, &mut sc.e);
        tql2(&mut sc.work, &mut sc.d, &mut sc.e)?;
    }
    // Sort ascending (tql2 output is ascending already, but make it a hard
    // guarantee) and gather columns into the output buffer.
    sc.order.clear();
    sc.order.extend(0..n);
    let d = &sc.d;
    sc.order.sort_unstable_by(|&i, &j| d[i].partial_cmp(&d[j]).unwrap());
    sc.values.extend(sc.order.iter().map(|&i| sc.d[i]));
    sc.vectors.resize_zeroed(n, n);
    let src = if blocked { &sc.q } else { &sc.work };
    for (new_j, &old_j) in sc.order.iter().enumerate() {
        for i in 0..n {
            sc.vectors.set(i, new_j, src.get(i, old_j));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Stage 1: blocked Householder tridiagonalization
// ---------------------------------------------------------------------------

/// Build the Householder reflector for `x` in place: on exit `x` holds `v`
/// with `v[0] = 1`, and `(I − τ v vᵀ) x = β e₁`. Returns `(τ, β)`.
fn house_in_place(x: &mut [f64]) -> (f64, f64) {
    let alpha = x[0];
    let sigma: f64 = x[1..].iter().map(|&v| v * v).sum();
    if sigma == 0.0 {
        x[0] = 1.0;
        return (0.0, alpha);
    }
    let mu = (alpha * alpha + sigma).sqrt();
    let beta = if alpha >= 0.0 { -mu } else { mu };
    let v0 = alpha - beta;
    matmul::div_slice(&mut x[1..], v0);
    x[0] = 1.0;
    ((beta - alpha) / beta, beta)
}

/// Panel-blocked reduction of `sc.work` to tridiagonal `(sc.d, sc.e)`,
/// storing reflector `j` in `work[j+1.., j]` with scale `sc.tau[j]`.
/// Trailing submatrix updates are two packed-GEMM calls per panel.
fn tridiag_blocked(sc: &mut SymEigenScratch, n: usize) {
    sc.tau.clear();
    sc.tau.resize(n, 0.0);
    sc.starts.clear();
    let mut k = 0usize;
    while k < n - 2 {
        let b = NB.min(n - 2 - k);
        sc.starts.push((k, b));
        let m = n - k - 1; // rows k+1..n; panel row i ↔ global row k+1+i
        sc.vpanel.resize_zeroed(m, b);
        sc.wpanel.resize_zeroed(m, b);
        for j in 0..b {
            let col = k + j;
            let mlen = n - col - 1;
            // Column `col` under the diagonal, lazily corrected by the
            // panel's previous rank-2 contributions.
            sc.hv.clear();
            for r in 0..mlen {
                sc.hv.push(sc.work.get(col + 1 + r, col));
            }
            if j > 0 {
                let vrow: &[f64] = &sc.vpanel.row(j - 1)[..j];
                let wrow: &[f64] = &sc.wpanel.row(j - 1)[..j];
                sc.d[col] = sc.work.get(col, col) - 2.0 * matmul::dot(vrow, wrow);
                for (r, hv) in sc.hv.iter_mut().enumerate() {
                    *hv -= matmul::dot(&sc.vpanel.row(j + r)[..j], wrow)
                        + matmul::dot(&sc.wpanel.row(j + r)[..j], vrow);
                }
            } else {
                sc.d[col] = sc.work.get(col, col);
            }
            let (t, beta) = house_in_place(&mut sc.hv);
            sc.e[col + 1] = beta;
            sc.tau[col] = t;
            // Store the reflector (for the Q pass) and in the panel.
            for (r, &v) in sc.hv.iter().enumerate() {
                sc.work.set(col + 1 + r, col, v);
                sc.vpanel.set(j + r, j, v);
            }
            // p = A_upd[col+1.., col+1..]·v, with the panel corrections
            // folded in: A_upd = A − VWᵀ − WVᵀ.
            sc.hp.clear();
            sc.hp.resize(mlen, 0.0);
            matmul::matvec_into(
                &mut sc.hp,
                sc.work.view().submatrix(col + 1, col + 1, mlen, mlen),
                &sc.hv,
            );
            if j > 0 {
                sc.htmp.clear();
                sc.htmp.resize(2 * j, 0.0);
                let (wtv, vtv) = sc.htmp.split_at_mut(j);
                for (r, &vv) in sc.hv.iter().enumerate() {
                    if vv != 0.0 {
                        matmul::axpy_slice(wtv, vv, &sc.wpanel.row(j + r)[..j]);
                        matmul::axpy_slice(vtv, vv, &sc.vpanel.row(j + r)[..j]);
                    }
                }
                for (r, hp) in sc.hp.iter_mut().enumerate() {
                    *hp -= matmul::dot(&sc.vpanel.row(j + r)[..j], wtv)
                        + matmul::dot(&sc.wpanel.row(j + r)[..j], vtv);
                }
            }
            matmul::scale_slice(&mut sc.hp, t);
            // w = p − (τ/2)(pᵀv)·v
            let coef = 0.5 * t * matmul::dot(&sc.hp, &sc.hv);
            for r in 0..mlen {
                sc.wpanel.set(j + r, j, sc.hp[r] - coef * sc.hv[r]);
            }
        }
        // Trailing update A[k+b.., k+b..] −= V₂W₂ᵀ + W₂V₂ᵀ — the two GEMMs.
        let nt = n - (k + b);
        if nt > 0 {
            let v2 = sc.vpanel.view().submatrix(b - 1, 0, nt, b);
            let w2 = sc.wpanel.view().submatrix(b - 1, 0, nt, b);
            let trail = sc.work.view_mut().submatrix(k + b, k + b, nt, nt);
            matmul::gemm_into(trail, -1.0, v2, w2.t(), true, &mut sc.gemm);
            let trail = sc.work.view_mut().submatrix(k + b, k + b, nt, nt);
            matmul::gemm_into(trail, -1.0, w2, v2.t(), true, &mut sc.gemm);
        }
        k += b;
    }
    sc.d[n - 2] = sc.work.get(n - 2, n - 2);
    sc.d[n - 1] = sc.work.get(n - 1, n - 1);
    sc.e[n - 1] = sc.work.get(n - 1, n - 2);
    sc.e[0] = 0.0;
}

// ---------------------------------------------------------------------------
// Stage 1b: compact-WY accumulation of Q
// ---------------------------------------------------------------------------

/// Form `Q = H₀H₁⋯H_{n−3}` from the reflectors stored in `sc.work` by
/// applying each panel's block reflector `I − V T Vᵀ` to the identity in
/// reverse panel order. Each application is three GEMMs restricted to the
/// trailing block `[k+1.., k+1..]` (everything above/left is still
/// identity at that point).
fn accumulate_q(sc: &mut SymEigenScratch, n: usize) {
    sc.q.resize_zeroed(n, n);
    for i in 0..n {
        sc.q.set(i, i, 1.0);
    }
    for idx in (0..sc.starts.len()).rev() {
        let (k, b) = sc.starts[idx];
        let m = n - k - 1;
        sc.vpanel.resize_zeroed(m, b);
        for j in 0..b {
            let col = k + j;
            for r in 0..(n - col - 1) {
                sc.vpanel.set(j + r, j, sc.work.get(col + 1 + r, col));
            }
        }
        // Forward compact-WY factor: T[j,j] = τ_j,
        // T[..j, j] = −τ_j · T[..j, ..j] · (V[:, ..j]ᵀ v_j).
        sc.tmat.resize_zeroed(b, b);
        for j in 0..b {
            let t = sc.tau[k + j];
            if j > 0 && t != 0.0 {
                sc.htmp.clear();
                sc.htmp.resize(j, 0.0);
                for r in j..m {
                    let vj = sc.vpanel.get(r, j);
                    if vj != 0.0 {
                        matmul::axpy_slice(&mut sc.htmp, vj, &sc.vpanel.row(r)[..j]);
                    }
                }
                for i in 0..j {
                    let mut acc = 0.0;
                    for l in i..j {
                        acc += sc.tmat.get(i, l) * sc.htmp[l];
                    }
                    sc.tmat.set(i, j, -t * acc);
                }
            }
            sc.tmat.set(j, j, t);
        }
        // Q[k+1.., k+1..] −= V · (T · (Vᵀ · Q[k+1.., k+1..])).
        let nt = n - k - 1;
        sc.ymat.resize_zeroed(b, nt);
        matmul::gemm_into(
            sc.ymat.view_mut(),
            1.0,
            sc.vpanel.view().t(),
            sc.q.view().submatrix(k + 1, k + 1, nt, nt),
            false,
            &mut sc.gemm,
        );
        sc.ymat2.resize_zeroed(b, nt);
        matmul::gemm_into(
            sc.ymat2.view_mut(),
            1.0,
            sc.tmat.view(),
            sc.ymat.view(),
            false,
            &mut sc.gemm,
        );
        matmul::gemm_into(
            sc.q.view_mut().submatrix(k + 1, k + 1, nt, nt),
            -1.0,
            sc.vpanel.view(),
            sc.ymat2.view(),
            true,
            &mut sc.gemm,
        );
    }
}

// ---------------------------------------------------------------------------
// Stage 2: tql2 with rotation streaming
// ---------------------------------------------------------------------------

/// Apply a batch of rotations to every row of `q`, sharded over row bands.
/// Per-row arithmetic is identical regardless of banding, so the result is
/// bitwise independent of the thread count.
fn flush_rotations(q: &mut Matrix, rots: &[Rot]) {
    if rots.is_empty() {
        return;
    }
    let n = q.rows();
    let apply_row = |row: &mut [f64]| {
        for r in rots {
            let i = r.i as usize;
            let vi = row[i];
            let vi1 = row[i + 1];
            row[i + 1] = r.s * vi + r.c * vi1;
            row[i] = r.c * vi - r.s * vi1;
        }
    };
    let threads =
        if n * rots.len() >= 1 << 20 { matmul::available_threads().min(n.max(1)) } else { 1 };
    if threads <= 1 {
        for r in 0..n {
            apply_row(q.row_mut(r));
        }
        return;
    }
    let band = n.div_ceil(threads).max(1);
    let cols = q.cols();
    let data = q.as_mut_slice();
    std::thread::scope(|s| {
        let mut rest = data;
        let mut start = 0usize;
        while start < n {
            let len = band.min(n - start);
            let (chunk, tail) = rest.split_at_mut(len * cols);
            rest = tail;
            let apply_row = &apply_row;
            s.spawn(move || {
                for r in 0..len {
                    apply_row(&mut chunk[r * cols..(r + 1) * cols]);
                }
            });
            start += len;
        }
    });
}

/// The tql2 iteration on `(sc.d, sc.e)` with eigenvector rotations
/// buffered into `sc.rot` and replayed onto `sc.q` in parallel chunks.
/// Control flow is identical to [`tql2`].
fn tql2_streaming(sc: &mut SymEigenScratch, n: usize) -> Result<()> {
    if n == 1 {
        return Ok(());
    }
    sc.rot.clear();
    let d = &mut sc.d;
    let e = &mut sc.e;
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;

    let mut f = 0.0f64;
    let mut tst1 = 0.0f64;
    let eps = f64::EPSILON;
    for l in 0..n {
        tst1 = tst1.max(d[l].abs() + e[l].abs());
        let mut m = l;
        while m < n {
            if e[m].abs() <= eps * tst1 {
                break;
            }
            m += 1;
        }
        if m == n {
            m = n - 1;
        }
        if m > l {
            let mut iter = 0;
            loop {
                iter += 1;
                if iter > 50 {
                    return Err(Error::Numerical(
                        "tql2: QL iteration failed to converge".into(),
                    ));
                }
                let mut g = d[l];
                let mut p = (d[l + 1] - g) / (2.0 * e[l]);
                let mut r = (p * p + 1.0).sqrt();
                d[l] = e[l] / (p + if p < 0.0 { -r } else { r });
                d[l + 1] = e[l] * (p + if p < 0.0 { -r } else { r });
                let dl1 = d[l + 1];
                let mut h = g - d[l];
                for i in (l + 2)..n {
                    d[i] -= h;
                }
                f += h;
                p = d[m];
                let mut c = 1.0;
                let mut c2 = c;
                let mut c3 = c;
                let el1 = e[l + 1];
                let mut s = 0.0;
                let mut s2 = 0.0;
                for i in (l..m).rev() {
                    c3 = c2;
                    c2 = c;
                    s2 = s;
                    g = c * e[i];
                    h = c * p;
                    r = (p * p + e[i] * e[i]).sqrt();
                    e[i + 1] = s * r;
                    s = e[i] / r;
                    c = p / r;
                    p = c * d[i] - s * g;
                    d[i + 1] = h + s * (c * g + s * d[i]);
                    sc.rot.push(Rot { i: i as u32, c, s });
                    if sc.rot.len() >= ROT_CHUNK {
                        flush_rotations(&mut sc.q, &sc.rot);
                        sc.rot.clear();
                    }
                }
                p = -s * s2 * c3 * el1 * e[l] / dl1;
                e[l] = s * p;
                d[l] = c * p;
                if e[l].abs() <= eps * tst1 {
                    break;
                }
            }
        }
        d[l] += f;
        e[l] = 0.0;
    }
    flush_rotations(&mut sc.q, &sc.rot);
    sc.rot.clear();
    Ok(())
}

// ---------------------------------------------------------------------------
// Classic sequential path (small matrices, verification baseline)
// ---------------------------------------------------------------------------

/// Householder reduction of a real symmetric matrix to tridiagonal form,
/// accumulating the orthogonal transform in `v` (EISPACK tred2).
/// On exit `d` holds the diagonal, `e` the subdiagonal (`e[0]` unused).
fn tred2(v: &mut Matrix, d: &mut [f64], e: &mut [f64]) {
    let n = d.len();
    for i in 0..n {
        d[i] = v.get(n - 1, i);
    }
    // Householder reduction.
    for i in (1..n).rev() {
        let l = i; // columns 0..l of row i
        let mut h = 0.0;
        let mut scale = 0.0;
        if l > 1 {
            for k in 0..l {
                scale += d[k].abs();
            }
        }
        if scale == 0.0 || l <= 1 {
            e[i] = if l >= 1 { d[l - 1] } else { 0.0 };
            for j in 0..l {
                d[j] = v.get(l - 1, j);
                v.set(i, j, 0.0);
                v.set(j, i, 0.0);
            }
        } else {
            for k in 0..l {
                d[k] /= scale;
                h += d[k] * d[k];
            }
            let mut f = d[l - 1];
            let mut g = if f > 0.0 { -h.sqrt() } else { h.sqrt() };
            e[i] = scale * g;
            h -= f * g;
            d[l - 1] = f - g;
            for j in 0..l {
                e[j] = 0.0;
            }
            // Apply similarity transformation to remaining columns.
            for j in 0..l {
                f = d[j];
                v.set(j, i, f);
                g = e[j] + v.get(j, j) * f;
                for k in (j + 1)..l {
                    g += v.get(k, j) * d[k];
                    e[k] += v.get(k, j) * f;
                }
                e[j] = g;
            }
            f = 0.0;
            for j in 0..l {
                e[j] /= h;
                f += e[j] * d[j];
            }
            let hh = f / (h + h);
            for j in 0..l {
                e[j] -= hh * d[j];
            }
            for j in 0..l {
                f = d[j];
                g = e[j];
                for k in j..l {
                    let val = v.get(k, j) - (f * e[k] + g * d[k]);
                    v.set(k, j, val);
                }
                d[j] = v.get(l - 1, j);
                v.set(i, j, 0.0);
            }
        }
        d[i] = h;
    }
    // Accumulate transformations.
    for i in 0..(n - 1) {
        v.set(n - 1, i, v.get(i, i));
        v.set(i, i, 1.0);
        let l = i + 1;
        if d[l] != 0.0 {
            for k in 0..l {
                d[k] = v.get(k, l) / d[l];
            }
            for j in 0..l {
                let mut g = 0.0;
                for k in 0..l {
                    g += v.get(k, l) * v.get(k, j);
                }
                for k in 0..l {
                    let val = v.get(k, j) - g * d[k];
                    v.set(k, j, val);
                }
            }
        }
        for k in 0..l {
            v.set(k, l, 0.0);
        }
    }
    for j in 0..n {
        d[j] = v.get(n - 1, j);
        v.set(n - 1, j, 0.0);
    }
    v.set(n - 1, n - 1, 1.0);
    e[0] = 0.0;
}

/// Implicit QL with Wilkinson shifts on a symmetric tridiagonal matrix,
/// updating the eigenvector accumulation in `v` (EISPACK tql2).
fn tql2(v: &mut Matrix, d: &mut [f64], e: &mut [f64]) -> Result<()> {
    let n = d.len();
    if n == 1 {
        return Ok(());
    }
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;

    let mut f = 0.0f64;
    let mut tst1 = 0.0f64;
    let eps = f64::EPSILON;
    for l in 0..n {
        tst1 = tst1.max(d[l].abs() + e[l].abs());
        // Find small subdiagonal element.
        let mut m = l;
        while m < n {
            if e[m].abs() <= eps * tst1 {
                break;
            }
            m += 1;
        }
        if m == n {
            m = n - 1;
        }
        if m > l {
            let mut iter = 0;
            loop {
                iter += 1;
                if iter > 50 {
                    return Err(Error::Numerical(
                        "tql2: QL iteration failed to converge".into(),
                    ));
                }
                // Compute implicit shift.
                let mut g = d[l];
                let mut p = (d[l + 1] - g) / (2.0 * e[l]);
                let mut r = (p * p + 1.0).sqrt();
                d[l] = e[l] / (p + if p < 0.0 { -r } else { r });
                d[l + 1] = e[l] * (p + if p < 0.0 { -r } else { r });
                let dl1 = d[l + 1];
                let mut h = g - d[l];
                for i in (l + 2)..n {
                    d[i] -= h;
                }
                f += h;
                // Implicit QL transformation.
                p = d[m];
                let mut c = 1.0;
                let mut c2 = c;
                let mut c3 = c;
                let el1 = e[l + 1];
                let mut s = 0.0;
                let mut s2 = 0.0;
                for i in (l..m).rev() {
                    c3 = c2;
                    c2 = c;
                    s2 = s;
                    g = c * e[i];
                    h = c * p;
                    r = (p * p + e[i] * e[i]).sqrt();
                    e[i + 1] = s * r;
                    s = e[i] / r;
                    c = p / r;
                    p = c * d[i] - s * g;
                    d[i + 1] = h + s * (c * g + s * d[i]);
                    // Accumulate transformation (raw slice walk: this
                    // rotation is the O(n³) inner loop of tql2).
                    {
                        let vd = v.as_mut_slice();
                        let mut idx = i;
                        for _ in 0..n {
                            let h2 = vd[idx + 1];
                            let vi = vd[idx];
                            vd[idx + 1] = s * vi + c * h2;
                            vd[idx] = c * vi - s * h2;
                            idx += n;
                        }
                    }
                }
                p = -s * s2 * c3 * el1 * e[l] / dl1;
                e[l] = s * p;
                d[l] = c * p;
                // Check for convergence.
                if e[l].abs() <= eps * tst1 {
                    break;
                }
            }
        }
        d[l] += f;
        e[l] = 0.0;
    }
    Ok(())
}

/// Eigenvalues only (same reduction; decomposition rarely dominates enough
/// to justify a vector-free fast path).
pub fn eigvals(a: &Matrix) -> Result<Vec<f64>> {
    Ok(SymEigen::new(a)?.values)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{matmul_nt, matmul_tn};

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut state = seed | 1;
        let x = Matrix::from_fn(n, n, |_, _| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state as f64 / u64::MAX as f64) - 0.5
        });
        let mut g = matmul_nt(&x, &x).unwrap();
        g.add_diag_mut(0.5);
        g
    }

    #[test]
    fn diag_matrix_eigs() {
        let a = Matrix::diag(&[3.0, 1.0, 2.0]);
        let eig = SymEigen::new(&a).unwrap();
        assert!((eig.values[0] - 1.0).abs() < 1e-12);
        assert!((eig.values[1] - 2.0).abs() < 1e-12);
        assert!((eig.values[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1, 3
        let a = Matrix::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]).unwrap();
        let eig = SymEigen::new(&a).unwrap();
        assert!((eig.values[0] - 1.0).abs() < 1e-12);
        assert!((eig.values[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn reconstruction_small() {
        let a = spd(10, 77);
        let eig = SymEigen::new(&a).unwrap();
        assert!(eig.reconstruct().rel_diff(&a) < 1e-10);
    }

    #[test]
    fn reconstruction_medium() {
        let a = spd(120, 5);
        let eig = SymEigen::new(&a).unwrap();
        assert!(eig.reconstruct().rel_diff(&a) < 1e-9);
    }

    #[test]
    fn reconstruction_blocked_path() {
        // Above BLOCKED_MIN_N: the two-stage solver handles it.
        let a = spd(160, 6);
        let eig = SymEigen::new(&a).unwrap();
        assert!(eig.reconstruct().rel_diff(&a) < 1e-9);
        let vtv = matmul_tn(&eig.vectors, &eig.vectors).unwrap();
        assert!(vtv.rel_diff(&Matrix::identity(160)) < 1e-10);
    }

    #[test]
    fn blocked_matches_sequential() {
        for (n, seed) in [(33usize, 1u64), (64, 2), (97, 3), (130, 4)] {
            let a = spd(n, seed);
            let eb = SymEigen::new_blocked(&a).unwrap();
            let es = SymEigen::new_seq(&a).unwrap();
            for (p, q) in eb.values.iter().zip(&es.values) {
                assert!((p - q).abs() < 1e-9 * (1.0 + q.abs()), "n={n}: {p} vs {q}");
            }
            assert!(eb.reconstruct().rel_diff(&a) < 1e-10, "n={n}");
        }
    }

    #[test]
    fn blocked_is_bitwise_deterministic() {
        let a = spd(150, 11);
        let e1 = SymEigen::new_blocked(&a).unwrap();
        let e2 = SymEigen::new_blocked(&a).unwrap();
        assert_eq!(e1.values, e2.values);
        assert_eq!(e1.vectors.as_slice(), e2.vectors.as_slice());
    }

    #[test]
    fn scratch_reuse_across_sizes() {
        let mut sc = SymEigenScratch::new();
        for (n, seed) in [(40usize, 21u64), (160, 22), (12, 23), (131, 24)] {
            let a = spd(n, seed);
            let eig = SymEigen::new_with(&a, &mut sc).unwrap();
            assert!(eig.reconstruct().rel_diff(&a) < 1e-9, "n={n}");
            let fresh = SymEigen::new(&a).unwrap();
            assert_eq!(eig.values, fresh.values, "scratch reuse changed values at n={n}");
            assert_eq!(
                eig.vectors.as_slice(),
                fresh.vectors.as_slice(),
                "scratch reuse changed vectors at n={n}"
            );
        }
    }

    #[test]
    fn vectors_orthonormal() {
        let a = spd(40, 9);
        let eig = SymEigen::new(&a).unwrap();
        let vtv = matmul_tn(&eig.vectors, &eig.vectors).unwrap();
        assert!(vtv.rel_diff(&Matrix::identity(40)) < 1e-10);
    }

    #[test]
    fn eigenpairs_satisfy_definition() {
        let a = spd(25, 33);
        let eig = SymEigen::new(&a).unwrap();
        for j in 0..25 {
            let v = eig.vectors.col(j);
            let av = a.matvec(&v).unwrap();
            let residual: f64 = av
                .iter()
                .zip(&v)
                .map(|(p, q)| (p - eig.values[j] * q).powi(2))
                .sum::<f64>()
                .sqrt();
            assert!(residual < 1e-8, "eigenpair {j} residual {residual}");
        }
    }

    #[test]
    fn apply_fn_inverse() {
        let a = spd(15, 3);
        let eig = SymEigen::new(&a).unwrap();
        let inv = eig.apply_fn(|x| 1.0 / x);
        let prod = crate::linalg::matmul::matmul(&a, &inv).unwrap();
        assert!(prod.rel_diff(&Matrix::identity(15)) < 1e-9);
    }

    #[test]
    fn trace_equals_sum_of_eigs() {
        let a = spd(30, 12);
        let eig = SymEigen::new(&a).unwrap();
        let s: f64 = eig.values.iter().sum();
        assert!((s - a.trace()).abs() / a.trace().abs() < 1e-10);
    }

    #[test]
    fn handles_1x1_and_empty() {
        let a = Matrix::diag(&[5.0]);
        let eig = SymEigen::new(&a).unwrap();
        assert_eq!(eig.values, vec![5.0]);
        let e = SymEigen::new(&Matrix::zeros(0, 0)).unwrap();
        assert!(e.values.is_empty());
    }

    #[test]
    fn rejects_non_square() {
        assert!(SymEigen::new(&Matrix::zeros(2, 3)).is_err());
    }

    #[test]
    fn repeated_eigenvalues() {
        let a = Matrix::identity(6);
        let eig = SymEigen::new(&a).unwrap();
        for v in &eig.values {
            assert!((v - 1.0).abs() < 1e-12);
        }
        assert!(eig.reconstruct().rel_diff(&a) < 1e-12);
    }

    #[test]
    fn blocked_repeated_eigenvalues() {
        let a = Matrix::identity(140);
        let eig = SymEigen::new(&a).unwrap();
        for v in &eig.values {
            assert!((v - 1.0).abs() < 1e-12);
        }
        assert!(eig.reconstruct().rel_diff(&a) < 1e-11);
    }
}
